"""Bit-plane (any-precision) storage pins — the PR-7 tentpole invariants:

  * encode/decode round-trip bound and EXACT top-k-slice ≡ direct-k-bit
    equivalence (the MLWeaving claim: one artifact, every precision)
  * the Pallas qmm_bitplane kernel reconstructs codes in-register
    value-identically to QTensor.decode (f32), on odd shapes, lead dims,
    every scale family the kernel serves
  * quant_dense integration: both backends, transpose fallback, ShipWeight
    custom-vjp path untouched
  * the precision autoscaler: hysteresis walk on a virtual clock
  * the serving engine: set_weight_bits swaps sliced trees; serving a
    slice_planes(k) view ≡ serving a direct k-bit quantization
  * the weights-bitplane-v1 ship artifact: atomic layout, bits-at-load
"""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import quant
from repro.quant import QScheme, QTensor, quant_dense
from repro.serve import AutoscalerConfig, PrecisionAutoscaler

KEY = jax.random.PRNGKey(0)


def _w(shape, seed=0, sd=0.1):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, sd, shape), jnp.float32)


class TestBitplaneStorage:
    def test_scheme_validation(self):
        sch = QScheme.bitplane(4)
        assert sch.layout == "bitplane" and sch.code_bits == 5
        with pytest.raises(ValueError):
            QScheme.bitplane(9)
        with pytest.raises(ValueError):
            QScheme(bits=4, grid="levels", layout="bitplane")
        with pytest.raises(ValueError):
            QScheme(bits=4, grid="int", packed=True, layout="bitplane")

    def test_logical_shape_and_codes_layout(self):
        w = _w((6, 70))
        qt = quant.encode(w, QScheme.bitplane(4))
        assert qt.codes.shape == (5, 6, 3)        # (planes, rows, ceil(70/32))
        assert qt.codes.dtype == jnp.uint32
        assert qt.shape == (6, 70) and qt.ndim == 2 and qt.size == 420
        assert qt.nbytes == 5 * 6 * 3 * 4 + np.asarray(qt.scale).size * 4

    @pytest.mark.parametrize("k", [1, 2, 4, 8])
    def test_slice_equals_direct_encode(self, k):
        w = _w((16, 48), sd=2.0)
        full = quant.encode(w, QScheme.bitplane(8))
        direct = quant.encode(w, QScheme.bitplane(k))
        sliced = full.slice_planes(k)
        np.testing.assert_array_equal(np.asarray(sliced.codes),
                                      np.asarray(direct.codes))
        np.testing.assert_array_equal(np.asarray(sliced.decode()),
                                      np.asarray(direct.decode()))

    def test_slice_planes_validation(self):
        qt = quant.encode(_w((4, 32)), QScheme.bitplane(4))
        assert qt.slice_planes(4) is qt            # full slice: pure view
        for bad in (0, 5, -1):
            with pytest.raises(ValueError):
                qt.slice_planes(bad)
        dense = quant.encode(_w((4, 32)),
                             QScheme.int_symmetric(8, rounding="nearest"))
        with pytest.raises(ValueError):
            dense.slice_planes(4)

    def test_stacked_layers_scan_slice(self):
        """Stacked (L, R, D) weights keep the plane axis at -3, so lax.scan
        over layers hands each step a (P, R, W) slice that decodes alone."""
        w = _w((3, 8, 64))
        qt = quant.encode(w, QScheme.bitplane(4))
        assert qt.codes.shape == (3, 5, 8, 2)
        full = np.asarray(qt.decode())

        def body(c, q):
            return c, q.decode()

        _, per_layer = jax.lax.scan(body, 0, qt)
        np.testing.assert_array_equal(np.asarray(per_layer), full)


class TestQmmBitplaneKernel:
    @pytest.mark.parametrize("k", [1, 2, 4, 8])
    def test_kernel_matches_f32_decode(self, k):
        """The in-register reconstruction is value-identical to
        QTensor.decode in f32 — not merely close."""
        from repro.kernels.qmm_bitplane import qmm_bitplane

        w = _w((128, 128), sd=1.0)
        x = _w((128, 128), seed=1).astype(jnp.bfloat16)
        qt = quant.encode(w, QScheme.bitplane(k))
        want = jnp.einsum("mk,kn->mn", x.astype(jnp.float32), qt.decode())
        got = qmm_bitplane(x, qt.codes,
                           jnp.asarray(qt.scale, jnp.float32).reshape(1, -1))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)

    def test_quant_dense_both_backends_odd_shapes(self):
        w = _w((96, 200))
        x = _w((5, 96), seed=2).astype(jnp.bfloat16)
        qt = quant.encode(w, QScheme.bitplane(4))
        want = jnp.einsum("mk,kn->mn", x.astype(jnp.float32), qt.decode())
        for be, atol in (("ref", 2e-2), ("pallas", 1e-4)):
            got = quant_dense(x, qt, backend=be)
            assert got.shape == (5, 200)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=atol, rtol=5e-3)

    def test_quant_dense_transpose_fallback(self):
        """The backward/transpose contraction has no bitplane kernel yet —
        it must fall back to the decode path, not crash."""
        w = _w((32, 64))
        g = _w((5, 64), seed=3).astype(jnp.bfloat16)
        qt = quant.encode(w, QScheme.bitplane(4))
        want = jnp.einsum("mn,kn->mk", g.astype(jnp.float32), qt.decode())
        for be in ("ref", "pallas"):
            got = quant_dense(g, qt, transpose=True, backend=be)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=2e-2, rtol=5e-3)

    def test_quant_dense_grad_flows(self):
        w = _w((32, 64))
        x = _w((4, 32), seed=4).astype(jnp.bfloat16)
        qt = quant.encode(w, QScheme.bitplane(8))

        def loss(x):
            return jnp.sum(quant_dense(x, qt) ** 2)

        gx = jax.grad(loss)(x)
        assert gx.shape == x.shape
        assert bool(jnp.isfinite(gx.astype(jnp.float32)).all())


class TestPrecisionAutoscaler:
    CFG = dict(slo_admit_ms=10.0, breach_patience=2, restore_patience=3,
               restore_frac=0.5)

    def test_config_validation(self):
        with pytest.raises(ValueError):
            AutoscalerConfig(slo_admit_ms=0)
        with pytest.raises(ValueError):
            AutoscalerConfig(bits_ladder=())
        with pytest.raises(ValueError):
            AutoscalerConfig(bits_ladder=(4, 8))      # must decrease
        with pytest.raises(ValueError):
            AutoscalerConfig(restore_frac=1.5)

    def test_env_default(self, monkeypatch):
        monkeypatch.setenv("ZIPML_SLO_ADMIT_MS", "123.5")
        assert AutoscalerConfig.from_env().slo_admit_ms == 123.5
        assert AutoscalerConfig.from_env(slo_admit_ms=7.0).slo_admit_ms == 7.0

    def test_drop_restore_walk_with_hysteresis(self):
        asc = PrecisionAutoscaler(AutoscalerConfig(**self.CFG))
        assert asc.bits == 8
        # one breach is not enough (patience 2)
        assert asc.observe(admit_wait_ms=50, now=0.0) == 8
        assert asc.observe(admit_wait_ms=50, now=1.0) == 4
        # dead band (between 0.5·slo and slo) holds the rung and resets
        assert asc.observe(admit_wait_ms=7, now=2.0) == 4
        assert asc.observe(admit_wait_ms=50, now=3.0) == 4   # counter reset
        assert asc.observe(admit_wait_ms=50, now=4.0) == 2
        # healthy streak restores one rung per patience window
        for t in range(3):
            bits = asc.observe(admit_wait_ms=1, now=5.0 + t)
        assert bits == 4
        assert [d["action"] for d in asc.decisions] == \
            ["drop", "drop", "restore"]
        assert all(d["t"] is not None for d in asc.decisions)

    def test_floor_and_ceiling(self):
        asc = PrecisionAutoscaler(AutoscalerConfig(
            slo_admit_ms=10.0, bits_ladder=(8, 4), breach_patience=1,
            restore_patience=1))
        for _ in range(5):
            bits = asc.observe(admit_wait_ms=100)
        assert bits == 4                                 # clamped at floor
        for _ in range(5):
            bits = asc.observe(admit_wait_ms=0)
        assert bits == 8                                 # clamped at ceiling
        assert len(asc.decisions) == 2

    def test_queue_high_guard(self):
        asc = PrecisionAutoscaler(AutoscalerConfig(
            slo_admit_ms=10.0, breach_patience=1, queue_high=4))
        assert asc.observe(admit_wait_ms=0, queue_depth=10) == 4


def _tiny_engine(params, cfg, **kw):
    from repro.quant import PrecisionPlan
    from repro.serve import ServeEngine

    return ServeEngine(params, cfg, plan=PrecisionPlan(kv_bits=8),
                       max_slots=2, page_size=4, max_seq_len=32, **kw)


@pytest.fixture(scope="module")
def tiny_model():
    from repro import configs
    from repro.models import transformer as T

    cfg = configs.get_reduced("qwen2.5-14b")
    return cfg, T.init_params(KEY, cfg)


class TestEngineBitplaneServing:
    def _reqs(self, n=3):
        from repro.serve import Request

        return [Request(rid=i, prompt=np.arange(1, 5 + i), max_new_tokens=6)
                for i in range(n)]

    def test_set_weight_bits_requires_bitplane(self, tiny_model):
        cfg, params = tiny_model
        eng = _tiny_engine(params, cfg)
        with pytest.raises(ValueError, match="bitplane"):
            eng.set_weight_bits(4)

    def test_sliced_serving_equals_direct_quantization(self, tiny_model):
        """Serving the top-2 planes of the 8-bit artifact produces the same
        tokens as serving weights quantized directly at 2 bits — the
        any-precision invariant end-to-end through the engine."""
        from repro.precision.qat import quantize_param_tree

        cfg, params = tiny_model
        bp8 = quantize_param_tree(params, bits=8, layout="bitplane")
        bp2 = quantize_param_tree(params, bits=2, layout="bitplane")

        eng = _tiny_engine(bp8, cfg)
        eng.set_weight_bits(2)
        got = {r: f.tokens.tolist() for r, f in eng.run(self._reqs()).items()}
        direct = _tiny_engine(bp2, cfg)
        want = {r: f.tokens.tolist()
                for r, f in direct.run(self._reqs()).items()}
        assert got == want
        assert sorted(eng._params_by_bits) == [2]
        eng.allocator.check_leaks(0)

    def test_autoscaler_drives_engine_on_virtual_clock(self, tiny_model):
        from repro.precision.qat import quantize_param_tree
        from repro.serve import Request

        cfg, params = tiny_model
        bp = quantize_param_tree(params, bits=8, layout="bitplane")
        clk = [0.0]
        asc = PrecisionAutoscaler(AutoscalerConfig(
            slo_admit_ms=10.0, breach_patience=1, restore_patience=2))
        eng = _tiny_engine(bp, cfg, autoscaler=asc, clock=lambda: clk[0])
        for i in range(4):
            eng.submit(Request(rid=i, prompt=np.arange(1, 6),
                               max_new_tokens=4))
        clk[0] = 0.5                       # 500 ms head-of-line wait: breach
        done = {}
        for _ in range(60):
            clk[0] += 0.001
            for f in eng.step():
                done[f.rid] = f
            if not eng._queue and not eng._active.any():
                break
        assert sorted(done) == [0, 1, 2, 3]
        assert any(d["action"] == "drop" for d in asc.decisions)
        assert eng.weight_bits == asc.bits
        assert len(eng.admit_waits) >= 4
        eng.allocator.check_leaks(0)


class TestShipArtifact:
    def test_roundtrip_and_bits_at_load(self, tiny_model, tmp_path):
        from repro.ckpt import load_ship_weights, save_ship_weights
        from repro.precision.qat import quantize_param_tree

        cfg, params = tiny_model
        bp = quantize_param_tree(params, bits=8, layout="bitplane")
        d = str(tmp_path / "ship")
        save_ship_weights(d, bp, extra={"arch": "test"})
        assert sorted(os.listdir(d)) == [".complete", "arrays.npz",
                                         "manifest.json"]

        is_qt = lambda x: isinstance(x, QTensor)  # noqa: E731
        full = load_ship_weights(d)
        for a, b in zip(jax.tree.leaves(bp, is_leaf=is_qt),
                        jax.tree.leaves(full, is_leaf=is_qt)):
            if isinstance(a, QTensor):
                np.testing.assert_array_equal(np.asarray(a.codes),
                                              np.asarray(b.codes))
                np.testing.assert_array_equal(np.asarray(a.scale),
                                              np.asarray(b.scale))
                assert a.scheme == b.scheme
            else:
                assert a.dtype == b.dtype
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        k2 = load_ship_weights(d, bits=2)
        direct = quantize_param_tree(params, bits=2, layout="bitplane")
        for a, b in zip(jax.tree.leaves(k2, is_leaf=is_qt),
                        jax.tree.leaves(direct, is_leaf=is_qt)):
            if isinstance(a, QTensor):
                np.testing.assert_array_equal(np.asarray(a.codes),
                                              np.asarray(b.codes))

    def test_rejects_non_bitplane_and_bad_bits(self, tiny_model, tmp_path):
        from repro.ckpt import load_ship_weights, save_ship_weights
        from repro.precision.qat import quantize_param_tree

        cfg, params = tiny_model
        with pytest.raises(ValueError, match="bitplane"):
            save_ship_weights(str(tmp_path / "a"), params)
        with pytest.raises(ValueError, match="layout"):
            save_ship_weights(str(tmp_path / "b"),
                              quantize_param_tree(params, bits=8))
        bp = quantize_param_tree(params, bits=8, layout="bitplane")
        d = str(tmp_path / "ship")
        save_ship_weights(d, bp)
        with pytest.raises(ValueError, match="not servable"):
            load_ship_weights(d, bits=9)
        with pytest.raises(FileNotFoundError):
            load_ship_weights(str(tmp_path / "missing"))

    def test_truncated_arrays_raise_clean_error(self, tiny_model, tmp_path):
        """Post-commit corruption (torn copy, bit rot) must surface as
        ShipArtifactError naming the fix — never a raw numpy/zipfile
        traceback. The .complete marker only guards interrupted writes."""
        from repro.ckpt import (ShipArtifactError, load_ship_weights,
                                save_ship_weights)
        from repro.precision.qat import quantize_param_tree
        from repro.serve.faults import truncate_ship_artifact

        cfg, params = tiny_model
        d = str(tmp_path / "ship")
        save_ship_weights(d, quantize_param_tree(params, bits=8,
                                                 layout="bitplane"))
        truncate_ship_artifact(d, keep_bytes=128)
        with pytest.raises(ShipArtifactError,
                           match="corrupt or truncated") as ei:
            load_ship_weights(d)
        assert "save_ship_weights" in str(ei.value)   # names the fix

    def test_corrupt_manifest_raises_clean_error(self, tiny_model, tmp_path):
        from repro.ckpt import (ShipArtifactError, load_ship_weights,
                                save_ship_weights)
        from repro.precision.qat import quantize_param_tree

        cfg, params = tiny_model
        d = str(tmp_path / "ship")
        save_ship_weights(d, quantize_param_tree(params, bits=8,
                                                 layout="bitplane"))
        with open(os.path.join(d, "manifest.json"), "w") as f:
            f.write("{ not json")
        with pytest.raises(ShipArtifactError, match="manifest.json"):
            load_ship_weights(d)

    def test_truncate_helper_refuses_noop(self, tiny_model, tmp_path):
        from repro.ckpt import save_ship_weights
        from repro.precision.qat import quantize_param_tree
        from repro.serve.faults import truncate_ship_artifact

        cfg, params = tiny_model
        d = str(tmp_path / "ship")
        save_ship_weights(d, quantize_param_tree(params, bits=8,
                                                 layout="bitplane"))
        size = os.path.getsize(os.path.join(d, "arrays.npz"))
        with pytest.raises(ValueError, match="nothing truncated"):
            truncate_ship_artifact(d, keep_bytes=size)
