"""API-surface gate (fast — runs as its own CI job).

Pins three properties of the QTensor/PrecisionPlan redesign:

1. ``repro.quant``'s public exports are exactly the documented set.
2. Every deprecated alias still resolves to the canonical object AND emits
   ``DeprecationWarning`` (one release of compatibility, loudly).
3. No copy of the old inline quantizers survives anywhere in ``src/`` —
   exactly one encode/decode implementation remains per rounding mode.
"""
import os
import re
import warnings

import jax.numpy as jnp
import pytest

import repro.quant as quant
from repro.quant import PrecisionPlan, QTensor

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src", "repro")


class TestPublicExports:
    def test_quant_all(self):
        assert set(quant.__all__) == {
            "PrecisionPlan", "QScheme", "QTensor", "ShipWeight",
            "compute_scale", "decode", "dot", "ds_pair", "encode",
            "pack_bitplanes", "pack_int4", "quant_dense", "quant_dense_q",
            "quantize_to_levels_jnp", "tree_nbytes", "unpack_bitplanes",
            "unpack_int4",
        }
        for name in quant.__all__:
            assert hasattr(quant, name), name

    def test_quant_bitplane_symbols(self):
        from repro.quant import QScheme
        sch = QScheme.bitplane(4)
        assert sch.layout == "bitplane" and sch.code_bits == 5
        assert callable(QTensor.slice_planes)
        assert callable(quant.pack_bitplanes)
        assert callable(quant.unpack_bitplanes)

    def test_serve_autoscaler_exports(self):
        import repro.serve as serve
        from repro.serve.autoscaler import (AutoscalerConfig,
                                            PrecisionAutoscaler)
        assert {"PrecisionAutoscaler", "AutoscalerConfig"} <= set(serve.__all__)
        assert serve.PrecisionAutoscaler is PrecisionAutoscaler
        assert serve.AutoscalerConfig is AutoscalerConfig
        assert hasattr(serve.ServeEngine, "set_weight_bits")

    def test_ckpt_ship_exports(self):
        import repro.ckpt as ckpt
        assert callable(ckpt.save_ship_weights)
        assert callable(ckpt.load_ship_weights)
        assert ckpt.ship.FORMAT == "weights-bitplane-v1"

    def test_plan_canonical_fields(self):
        import dataclasses
        names = {f.name for f in dataclasses.fields(PrecisionPlan)}
        assert {"mode", "sample_bits", "model_bits", "grad_bits", "act_bits",
                "kv_bits", "model_storage", "optimal_levels"} <= names


class TestDeprecatedAliases:
    def test_linear_precision_warns_and_aliases(self):
        from repro.core import linear
        with pytest.warns(DeprecationWarning):
            P = linear.Precision
        assert P is PrecisionPlan

    def test_core_package_precision_warns_and_aliases(self):
        import repro.core as core
        with pytest.warns(DeprecationWarning):
            P = core.Precision
        assert P is PrecisionPlan

    def test_transformer_plan_warns_and_aliases(self):
        from repro.models import transformer as T
        with pytest.warns(DeprecationWarning):
            P = T.PrecisionPlan
        assert P is PrecisionPlan

    def test_quantized_constructor_warns(self):
        from repro.core.quantize import Quantized
        with pytest.warns(DeprecationWarning):
            q = Quantized(jnp.zeros((4,), jnp.int8), jnp.float32(1.0), 7)
        assert isinstance(q, QTensor) and q.s == 7

    def test_inttensor_constructor_warns(self):
        from repro.core.quantize import IntTensor
        with pytest.warns(DeprecationWarning):
            q = IntTensor(jnp.zeros((4,), jnp.int8), jnp.float32(1.0), 8)
        assert isinstance(q, QTensor) and q.scheme.grid == "int"

    def test_compressed_leaf_warns(self):
        from repro.precision.gradcomp import CompressedLeaf
        with pytest.warns(DeprecationWarning):
            q = CompressedLeaf(jnp.zeros((4,), jnp.int8), jnp.float32(1.0))
        assert isinstance(q, QTensor)

    def test_momentq_warns_and_aliases(self):
        from repro.optim.adamw import MomentQ
        with pytest.warns(DeprecationWarning):
            q = MomentQ(jnp.zeros((4,), jnp.int8), jnp.float32(1.0))
        assert isinstance(q, QTensor)

    def test_grad_transform_hook_warns(self):
        from repro import configs
        from repro.launch.steps import make_train_step
        from repro.optim.adamw import AdamWConfig
        cfg = configs.get_reduced("musicgen-medium")
        with pytest.warns(DeprecationWarning, match="grad_transform"):
            make_train_step(cfg, AdamWConfig(),
                            grad_transform=lambda g, k: g)

    def test_train_bits_kwargs_warn(self):
        from repro.launch.train import _train
        with pytest.warns(DeprecationWarning, match="PrecisionPlan"):
            _train("musicgen-medium", steps=0, batch=2, seq=8, grad_bits=8)

    def test_legacy_plan_kwargs_warn(self):
        with pytest.warns(DeprecationWarning):
            p = PrecisionPlan(weight_bits=8)
        assert p.model_bits == 8

    def test_legacy_plan_attrs_warn(self):
        p = PrecisionPlan(model_bits=8, act_bits=4)
        for attr, want in [("weight_bits", 8), ("bits_model", 8),
                           ("act_ds_bits", 4), ("use_optimal_levels", False),
                           ("weight_storage", "fake")]:
            with pytest.warns(DeprecationWarning):
                assert getattr(p, attr) == want

    def test_canonical_construction_is_silent(self):
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            PrecisionPlan("e2e", sample_bits=6, model_bits=8, grad_bits=8,
                          kv_bits=8, model_storage="int")


class TestNoSurvivingCopies:
    """grep the source tree: the deleted inline quantizers must not regrow."""

    BANNED = [
        r"def _quant\(",                      # act_quant's inline copy
        r"def _quantize_leaf\(",              # gradcomp's inline copy
        r"def _int_quantize_weight\(",        # qat's inline copy
        r"def _q_moment\(",                   # adamw's inline copy (the 5th)
        r"def _deq_moment\(",
        r"class Quantized\(NamedTuple\)",     # old storage NamedTuples
        r"class IntTensor\(NamedTuple\)",
        r"class CompressedLeaf\(NamedTuple\)",
        r"class MomentQ\(NamedTuple\)",       # optim's private codes+scale
        # the spliced weight dict formats are gone (error raised on sight);
        # nothing may read or write the keys except the error-path checks
        r"""\[(['"])w_q\1\]\s*=""",
        r"""\[(['"])w_lvl_codes\1\]""",
        r"""\[(['"])w_scale\1\]""",
        r"""\[(['"])w_levels\1\]""",
        r"""\.astype\(jnp\.bfloat16\)\s*\*\s*\w+\[(['"])w_scale""",
    ]
    # the single blessed home of the rounding-mode implementations
    ALLOWED_ROUNDING_HOME = os.path.join("quant", "qtensor.py")

    def _source_files(self):
        for root, _, files in os.walk(SRC):
            for f in files:
                if f.endswith(".py"):
                    yield os.path.join(root, f)

    def test_banned_patterns_absent(self):
        hits = []
        for path in self._source_files():
            text = open(path).read()
            for pat in self.BANNED:
                if re.search(pat, text):
                    hits.append((os.path.relpath(path, SRC), pat))
        assert not hits, f"old inline quantizers resurfaced: {hits}"

    def test_one_stochastic_round_implementation(self):
        """The floor+Bernoulli stochastic-rounding idiom exists exactly once
        in src/ (the Pallas kernel body in kernels/ is its uint16 fixed-point
        mirror, pinned bit-exact by tests/test_ds_fused.py)."""
        pat = re.compile(r"jax\.random\.uniform\([^)]*\)[^\n]*< \(t - lo\)"
                         r"|\(u < \(t - lo\)\)")
        homes = []
        for path in self._source_files():
            if "kernels" in path.split(os.sep):
                continue
            if pat.search(open(path).read()):
                homes.append(os.path.relpath(path, SRC))
        assert homes == [self.ALLOWED_ROUNDING_HOME], homes

    def test_one_bit_packing_implementation(self):
        """Bit-level packing (bit-plane word assembly, nibble packing) lives
        in repro.quant only — kernels/ hold the in-register unpack mirror
        (pinned value-identical by tests/test_bitplane.py) and are skipped
        like the stochastic-rounding mirror above."""
        pat = re.compile(r"<<\s*shifts|>>\s*shifts"     # bit-plane words
                         r"|\(hi\s*<<\s*4\)"            # nibble packing
                         r"|&\s*0xF\b")                 # nibble unpacking
        homes = []
        for path in self._source_files():
            if "kernels" in path.split(os.sep):
                continue
            if pat.search(open(path).read()):
                homes.append(os.path.relpath(path, SRC))
        assert homes == [self.ALLOWED_ROUNDING_HOME], homes
