"""repro.perf contracts: fingerprint/key stability, probe cache behavior,
and the autotune cache the kernel entry points resolve ``block=None``
through — a miss (or a corrupt/foreign cache) must fall back to the
hand-picked defaults bit-exactly, and a hit must not change elementwise
kernel outputs (block shape is a schedule, not semantics)."""
import json
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import registry
from repro.kernels import stoch_quant as sq_mod
from repro.perf import autotune, fingerprint, probe, report

KEY = jax.random.PRNGKey(0)


@pytest.fixture
def tuned_env(tmp_path, monkeypatch):
    """Isolated perf-cache env: lookups enabled, cache under tmp_path."""
    monkeypatch.setenv("ZIPML_PERF_CACHE_DIR", str(tmp_path))
    monkeypatch.setenv(autotune.CACHE_ENV, str(tmp_path / "autotune.json"))
    monkeypatch.setenv(autotune.DISABLE_ENV, "1")
    autotune.reload()
    yield tmp_path
    autotune.reload()
    jax.clear_caches()


class TestFingerprint:
    def test_key_stable_in_process(self):
        assert fingerprint.fingerprint_key() == fingerprint.fingerprint_key()
        assert len(fingerprint.fingerprint_key()) == 12

    def test_key_is_pure_function_of_dict(self):
        fp = {"backend": "cpu", "device_kind": "x", "n_devices": 1,
              "machine": "m", "cpu_count": 4}
        # insertion order must not matter (sorted-JSON hash)
        assert fingerprint.fingerprint_key(fp) == \
            fingerprint.fingerprint_key(dict(reversed(list(fp.items()))))
        fp2 = dict(fp, n_devices=2)
        assert fingerprint.fingerprint_key(fp) != fingerprint.fingerprint_key(fp2)

    @pytest.mark.slow
    def test_key_stable_across_processes(self):
        out = subprocess.run(
            [sys.executable, "-c",
             "from repro.perf import fingerprint; "
             "print(fingerprint.fingerprint_key())"],
            capture_output=True, text=True, check=True)
        assert out.stdout.strip() == fingerprint.fingerprint_key()


class TestProbeCache:
    def _fake_peaks(self, **over):
        peaks = {"version": probe.PROBE_VERSION,
                 "fingerprint": fingerprint.hardware_fingerprint(),
                 "key": fingerprint.fingerprint_key(), "smoke": True,
                 "peak_gbps": 42.0, "peak_gflops": 7.0,
                 "stream_sweep_gbps": {}, "fma_sweep_gflops": {}}
        peaks.update(over)
        return peaks

    def test_roundtrip_and_no_remeasure_on_hit(self, tuned_env, monkeypatch):
        calls = []
        monkeypatch.setattr(probe, "measure_peaks",
                            lambda smoke=False: calls.append(1) or
                            self._fake_peaks())
        p1 = probe.get_peaks(smoke=True)
        p2 = probe.get_peaks(smoke=True)
        assert p1["peak_gbps"] == p2["peak_gbps"] == 42.0
        assert calls == [1]                      # second call served from disk
        assert probe.get_peaks(refresh=True)["peak_gbps"] == 42.0
        assert calls == [1, 1]

    def test_corrupt_and_foreign_cache_remeasure(self, tuned_env, monkeypatch):
        monkeypatch.setattr(probe, "measure_peaks",
                            lambda smoke=False: self._fake_peaks())
        path = probe._cache_path()
        with open(path, "w") as f:
            f.write("{not json")
        assert probe.get_peaks()["peak_gbps"] == 42.0   # corrupt → re-measure
        with open(path, "w") as f:
            json.dump(self._fake_peaks(key="deadbeef0000", peak_gbps=9.0), f)
        assert probe.get_peaks()["peak_gbps"] == 42.0   # foreign → re-measure

    def test_report_annotation(self):
        row = report.annotate_row({"case": "x"}, bytes_moved=1e9, ms=100.0,
                                  peaks={"peak_gbps": 20.0})
        assert row["achieved_gbps"] == pytest.approx(10.0)
        assert row["roofline_fraction"] == pytest.approx(0.5)
        assert "roofline_fraction" in report.markdown_table([row])


class TestAutotuneCache:
    ENTRY = {"qmm/int8/k512_m300_n256": {
        "op": "qmm", "dtype": "int8", "bucket": "k512_m256_n256",
        "block": {"bm": 128, "bk": 256, "bn": 128}, "ms": 1.0}}

    def test_bucketing(self):
        assert autotune.bucket_dim(300) == 256
        assert autotune.bucket_dim(256) == 256
        assert autotune.bucket_dim(1) == 1
        # every dim in a bucket maps to the same entry key
        k1 = autotune.entry_key("qmm", "int8", {"m": 300, "k": 512, "n": 256})
        k2 = autotune.entry_key("qmm", "int8", {"m": 511, "k": 700, "n": 300})
        assert k1 == k2 == "qmm/int8/k512_m256_n256"

    def test_save_lookup_roundtrip(self, tuned_env):
        entries = {autotune.entry_key("qmm", "int8",
                                      {"m": 300, "k": 512, "n": 256}):
                   dict(self.ENTRY["qmm/int8/k512_m300_n256"])}
        path = autotune.save(entries)
        assert path == autotune.cache_path()
        hit = autotune.lookup("qmm", "int8", {"m": 260, "k": 700, "n": 300})
        assert hit == {"bm": 128, "bk": 256, "bn": 128}
        # merge keeps prior entries
        autotune.save({"ds_quant/f32/c512_r256": {"block": {"br": 128,
                                                            "bc": 256}}})
        assert autotune.lookup("qmm", "int8",
                               {"m": 260, "k": 700, "n": 300}) is not None
        assert autotune.lookup("ds_quant", "f32",
                               {"r": 256, "c": 512}) == {"br": 128, "bc": 256}

    def test_disabled_env_always_misses(self, tuned_env, monkeypatch):
        autotune.save({autotune.entry_key("ds_quant", "f32",
                                          {"r": 256, "c": 512}):
                       {"block": {"br": 128, "bc": 256}}})
        monkeypatch.setenv(autotune.DISABLE_ENV, "0")
        assert autotune.lookup("ds_quant", "f32",
                               {"r": 256, "c": 512}) is None

    def test_corrupt_cache_warns_and_defaults(self, tuned_env):
        with open(autotune.cache_path(), "w") as f:
            f.write("{broken")
        autotune.reload()
        with pytest.warns(UserWarning, match="unreadable"):
            hit = autotune.lookup("qmm", "int8", {"m": 256, "k": 512, "n": 256})
        assert hit is None

    def test_foreign_hardware_cache_warns_and_defaults(self, tuned_env):
        with open(autotune.cache_path(), "w") as f:
            json.dump({"version": autotune.CACHE_VERSION, "key": "ffff00001111",
                       "entries": dict(self.ENTRY)}, f)
        autotune.reload()
        with pytest.warns(UserWarning, match="different hardware"):
            hit = autotune.lookup("qmm", "int8", {"m": 300, "k": 512, "n": 256})
        assert hit is None

    def test_version_mismatch_defaults(self, tuned_env):
        with open(autotune.cache_path(), "w") as f:
            json.dump({"version": autotune.CACHE_VERSION + 1,
                       "key": fingerprint.fingerprint_key(),
                       "entries": dict(self.ENTRY)}, f)
        autotune.reload()
        with pytest.warns(UserWarning, match="version"):
            assert autotune.lookup("qmm", "int8",
                                   {"m": 300, "k": 512, "n": 256}) is None


class TestBlockResolution:
    def test_fit_block_exact_tiling(self):
        assert registry.fit_block(256, 1024) == 256
        assert registry.fit_block(256, 300) == 300     # no 128-divisor → full
        assert registry.fit_block(256, 384) == 128     # fall to lane multiple
        assert registry.fit_block(512, 256) == 256     # clamp to dim

    def test_explicit_beats_cache(self, tuned_env):
        autotune.save({autotune.entry_key("ds_quant", "f32",
                                          {"r": 256, "c": 512}):
                       {"block": {"br": 128, "bc": 256}}})
        got = registry.resolve_block("ds_quant", {"br": 256, "bc": 512},
                                     dtype="f32",
                                     explicit={"br": 256, "bc": 512})
        assert got == (256, 512)
        got = registry.resolve_block("ds_quant", {"br": 256, "bc": 512},
                                     dtype="f32")
        assert got == (128, 256)

    def test_cache_miss_falls_back_to_defaults(self, tuned_env):
        got = registry.resolve_block("qmm", {"bm": 512, "bk": 2048, "bn": 512},
                                     dtype="int8")
        d = registry.BLOCK_DEFAULTS["qmm"]
        assert got == (d["bm"], d["bk"], d["bn"])

    def test_kernel_bit_exact_across_cache_states(self, tuned_env):
        """ds_quant emits identical codes on a cache miss (defaults), with an
        explicit default block, and with a forced alternate tuned block —
        blocking is a schedule choice, never a semantics choice."""
        x = jax.random.normal(KEY, (256, 512), jnp.float32)
        rand = jax.random.bits(jax.random.fold_in(KEY, 1), (256, 512),
                               jnp.uint32)
        scale = sq_mod.row_absmax(x, interpret=True)

        def codes():
            c1, c2 = sq_mod.ds_quant(x, rand, scale, s=127, interpret=True)
            return np.asarray(c1), np.asarray(c2)

        miss = codes()                                   # empty cache
        explicit = sq_mod.ds_quant(x, rand, scale, s=127,
                                   block=sq_mod.DEFAULT_BLOCK, interpret=True)
        np.testing.assert_array_equal(miss[0], np.asarray(explicit[0]))
        np.testing.assert_array_equal(miss[1], np.asarray(explicit[1]))

        autotune.save({autotune.entry_key("ds_quant", "f32",
                                          {"r": 256, "c": 512}):
                       {"block": {"br": 128, "bc": 256}}})
        jax.clear_caches()          # block resolution happens at trace time
        hit = codes()
        np.testing.assert_array_equal(miss[0], hit[0])
        np.testing.assert_array_equal(miss[1], hit[1])


@pytest.mark.slow
class TestTune:
    def test_winner_no_worse_and_persisted(self, tuned_env):
        peaks = {"peak_gbps": 20.0, "peak_gflops": 5.0}
        rows = autotune.tune(ops=["ds_quant"], smoke=True, peaks=peaks)
        assert len(rows) == 1
        row = rows[0]
        assert row["autotune_no_worse"]           # exact by construction
        assert row["best_ms"] <= row["default_ms"]
        assert 0 < row["roofline_fraction"]
        # winners landed in the cache file and are visible to lookup()
        hit = autotune.lookup("ds_quant", "f32", {"r": 256, "c": 512})
        assert hit is not None and set(hit) == {"br", "bc"}
