"""Multi-device lane: these tests need ≥ 8 devices and run in CI as their own
job under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (locally:
``XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src
python -m pytest tests/test_multidevice.py``).

What they pin:
  * QTensor code planes survive ``shard_map`` — codes stay sharded, scales
    replicate, decode inside the mapped region equals global decode
    (dense int8, nibble-packed int4, and bit-plane uint32 layouts alike).
  * ``gradcomp.make_compressed_psum`` produces the exact mean of the
    per-member quantized terms across a real 8-way axis.
  * paged serve decode is batch-shardable: the paged-attention op under an
    8-way data sharding matches the single-device result, and the
    continuous-batching scheduler runs to completion (leak-free, output-
    identical) in a multi-device process.
  * the multi-replica driver: prefix-caching engine replicas pinned to
    distinct devices behind one shared queue finish a shared-prefix trace
    with balanced dispatch and leak-free pools — and with self-speculative
    decoding on under prefix-aware dispatch, token-identical to the
    non-speculative replica set.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import quant
from repro.precision import gradcomp
from repro.quant import QScheme

if jax.device_count() < 8:
    pytest.skip(
        "needs 8 devices — run under "
        "XLA_FLAGS=--xla_force_host_platform_device_count=8",
        allow_module_level=True)

KEY = jax.random.PRNGKey(0)


def _mesh(axis="data"):
    return Mesh(np.array(jax.devices()[:8]), (axis,))


class TestQTensorSharding:
    def test_code_plane_sharding_survives_shard_map(self):
        """Shard a QTensor's codes 8-way, map a decode over the shards: the
        output keeps the sharding and equals the global decode."""
        mesh = _mesh()
        x = jax.random.normal(KEY, (64, 16))
        qt = quant.encode(x, QScheme.int_symmetric(8, scaling="row"), KEY)
        qt_spec = jax.tree.unflatten(
            jax.tree.structure(qt), [P("data", None), P("data", None)])
        qt_sharded = jax.device_put(
            qt, jax.tree.map(lambda s: NamedSharding(mesh, s), qt_spec,
                             is_leaf=lambda s: isinstance(s, P)))
        shards = {s.device for s in qt_sharded.codes.addressable_shards}
        assert len(shards) == 8

        f = shard_map(lambda q: q.decode(), mesh=mesh, in_specs=(qt_spec,),
                      out_specs=P("data", None), check_rep=False)
        out = jax.jit(f)(qt_sharded)
        assert out.sharding.spec == P("data", None)
        np.testing.assert_allclose(np.asarray(out), np.asarray(qt.decode()),
                                   rtol=1e-6)

    def test_ds_pair_codes_shard_together(self):
        """Both double-sampling planes (codes + codes2) shard identically —
        the §2.2 pair is one storage object, not two tensors."""
        mesh = _mesh()
        x = jax.random.normal(KEY, (64, 16))
        qt = quant.ds_pair(x, QScheme.zipml(7, rounding="ds"), KEY)
        spec = jax.tree.unflatten(
            jax.tree.structure(qt), [P("data", None), P(), P("data", None)])
        qs = jax.device_put(qt, jax.tree.map(
            lambda s: NamedSharding(mesh, s), spec,
            is_leaf=lambda s: isinstance(s, P)))
        f = shard_map(lambda q: (q.decode() + q.decode2()) / 2, mesh=mesh,
                      in_specs=(spec,), out_specs=P("data", None),
                      check_rep=False)
        out = jax.jit(f)(qs)
        np.testing.assert_allclose(
            np.asarray(out),
            np.asarray((qt.decode() + qt.decode2()) / 2), rtol=1e-6)


class TestBitplaneSharding:
    def test_bitplane_code_planes_shard_over_rows(self):
        """A bit-plane weight shards 8-way over its contraction (row) axis —
        the tiny plane axis and the packed word axis stay whole. Decode
        inside shard_map equals global decode, and quant_dense over the
        sharded QTensor matches the f32 decode path on both backends."""
        from repro.quant import quant_dense

        mesh = _mesh()
        w = jax.random.normal(KEY, (64, 256)) * 0.1
        qt = quant.encode(w, QScheme.bitplane(4))
        assert qt.codes.shape == (5, 64, 8) and qt.codes.dtype == jnp.uint32
        spec = jax.tree.unflatten(
            jax.tree.structure(qt), [P(None, "data", None), P()])
        qs = jax.device_put(qt, jax.tree.map(
            lambda s: NamedSharding(mesh, s), spec,
            is_leaf=lambda s: isinstance(s, P)))
        assert len({s.device for s in qs.codes.addressable_shards}) == 8

        f = shard_map(lambda q: q.decode(), mesh=mesh, in_specs=(spec,),
                      out_specs=P("data", None), check_rep=False)
        out = jax.jit(f)(qs)
        assert out.sharding.spec in (P("data"), P("data", None))
        np.testing.assert_allclose(np.asarray(out), np.asarray(qt.decode()),
                                   rtol=1e-6)

        x = jax.random.normal(KEY, (16, 64)).astype(jnp.bfloat16)
        want = jnp.einsum("mk,kn->mn", x.astype(jnp.float32), qt.decode())
        with mesh:
            for be in ("ref", "pallas"):
                got = jax.jit(
                    lambda x, q: quant_dense(x, q, backend=be))(x, qs)
                # ref decodes through bf16 (one-epsilon per k-term); the
                # pallas kernel reconstructs in f32 and matches tightly
                np.testing.assert_allclose(
                    np.asarray(got), np.asarray(want),
                    atol=2e-2 if be == "ref" else 1e-4,
                    rtol=5e-3 if be == "ref" else 1e-5)


class TestPackedQuantDense:
    def test_packed_int4_code_plane_shards_and_matmuls(self):
        """A nibble-packed int4 weight plane shards 8-way over its (even)
        packed out-channel dim; quant_dense over the sharded QTensor equals
        the single-device f32 decode path (both backends)."""
        from repro.quant import quant_dense

        mesh = _mesh("model")
        w = jax.random.normal(KEY, (32, 128)) * 0.1
        qt = quant.encode(w, QScheme.int_symmetric(
            4, scaling="channel", channel_axis=-2, rounding="nearest",
            packed=True))
        assert qt.codes.dtype == jnp.uint8 and qt.codes.shape == (32, 64)
        spec = jax.tree.unflatten(
            jax.tree.structure(qt), [P(None, "model"), P(None, "model")])
        qs = jax.device_put(qt, jax.tree.map(
            lambda s: NamedSharding(mesh, s), spec,
            is_leaf=lambda s: isinstance(s, P)))
        assert len({s.device for s in qs.codes.addressable_shards}) == 8
        x = jax.random.normal(KEY, (16, 32)).astype(jnp.bfloat16)
        want = jnp.einsum("mk,kn->mn", x.astype(jnp.float32), qt.decode())
        with mesh:
            for be in ("ref", "pallas"):
                got = jax.jit(
                    lambda x, q: quant_dense(x, q, backend=be))(x, qs)
                np.testing.assert_allclose(
                    np.asarray(got), np.asarray(want), atol=1e-4,
                    rtol=5e-3 if be == "ref" else 1e-5)


class TestCompressedPsum:
    def test_mean_of_quantized_members_8way(self):
        """The C3 compressed all-reduce over a real 8-member axis equals the
        exact mean of each member's dequantized quantization (and stays
        within one quantization step of the true mean)."""
        mesh = _mesh("pod")
        n_dev, n = 8, 64
        rng = np.random.default_rng(0)
        per_member = jnp.asarray(rng.normal(0, 1, (n_dev, n)), jnp.float32)
        psum = gradcomp.make_compressed_psum("pod", 8)

        def member(g_slice, key):
            # each mesh member quantizes its own gradient with its own key
            idx = jax.lax.axis_index("pod")
            return psum({"g": g_slice[0]}, jax.random.fold_in(key, idx))

        f = shard_map(member, mesh=mesh, in_specs=(P("pod", None), P()),
                      out_specs=P(), check_rep=False)
        out = np.asarray(jax.jit(f)(per_member, KEY)["g"])

        # oracle: quantize each member with its folded key, average
        want = np.mean([np.asarray(gradcomp.decompress_tree(
            gradcomp.compress_tree({"g": per_member[i]}, 8,
                                   jax.random.fold_in(KEY, i))[0])["g"])
            for i in range(n_dev)], axis=0)
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-6)
        step = float(jnp.max(jnp.abs(per_member))) / 127
        true_mean = np.asarray(per_member.mean(0))
        assert np.max(np.abs(out - true_mean)) <= step + 1e-5


class TestShardedServe:
    def _op_args(self, kv_bits):
        rng = np.random.default_rng(1)
        b, h, g, d, page, maxp, n_pages = 8, 4, 2, 16, 8, 3, 24
        q = jnp.asarray(rng.normal(0, 1, (b, h, d)), jnp.float32)
        lens = jnp.asarray(rng.integers(1, page * maxp, (b,)), jnp.int32)
        bt = jnp.asarray(rng.integers(1, n_pages, (b, maxp)), jnp.int32)
        kv = rng.normal(0, 1, (2, n_pages, page, g, d)).astype(np.float32)
        if kv_bits:
            from repro.serve.pages import kv_scheme
            qk = quant.encode(jnp.asarray(kv[0]), kv_scheme(kv_bits))
            qv = quant.encode(jnp.asarray(kv[1]), kv_scheme(kv_bits))
            return q, qk.codes, qv.codes, qk.scale, qv.scale, bt, lens
        return (q, jnp.asarray(kv[0], jnp.bfloat16),
                jnp.asarray(kv[1], jnp.bfloat16), None, None, bt, lens)

    @pytest.mark.parametrize("kv_bits", [0, 8])
    def test_paged_attention_batch_sharded(self, kv_bits):
        """The serve decode hot path under an 8-way batch sharding (pool
        replicated, per-sequence state sharded) matches single-device."""
        from repro.kernels import registry

        args = self._op_args(kv_bits)
        q, kp, vp, ks, vs, bt, lens = args
        want = registry.get("ref").paged_attention(
            q, kp, vp, ks, vs, bt, lens, softmax_scale=q.shape[-1] ** -0.5)

        mesh = _mesh()
        dp = NamedSharding(mesh, P("data"))
        rep = NamedSharding(mesh, P())

        def put(x, s):
            return None if x is None else jax.device_put(x, s)

        out = jax.jit(lambda *a: registry.get("ref").paged_attention(
            *a, softmax_scale=q.shape[-1] ** -0.5))(
            put(q, NamedSharding(mesh, P("data", None, None))),
            put(kp, rep), put(vp, rep), put(ks, rep), put(vs, rep),
            put(bt, NamedSharding(mesh, P("data", None))), put(lens, dp))
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=2e-2, atol=2e-3)

    def test_scheduler_runs_on_multidevice_host(self):
        """End-to-end continuous batching in an 8-device process: every
        request finishes, no pages leak, tokens match the device_count=1
        greedy semantics (determinism is device-layout independent)."""
        from repro import configs
        from repro.models import transformer as T
        from repro.quant import PrecisionPlan
        from repro.serve import Request, ServeEngine

        cfg = configs.get_reduced("qwen2.5-14b")
        params = T.init_params(KEY, cfg)
        rng = np.random.default_rng(2)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            int(rng.integers(3, 14))),
                        max_new_tokens=4) for i in range(8)]
        eng = ServeEngine(params, cfg, plan=PrecisionPlan(kv_bits=8),
                          max_slots=4, page_size=8, max_seq_len=32)
        out = eng.run(reqs)
        assert sorted(out) == list(range(8))
        eng.allocator.check_leaks(0)
        solo = ServeEngine(params, cfg, plan=PrecisionPlan(kv_bits=8),
                           max_slots=1, page_size=8, max_seq_len=32)
        got = solo.run([reqs[3]])
        np.testing.assert_array_equal(got[3].tokens, out[3].tokens)

    def test_replica_set_prefix_sharing_device_pinned(self):
        """Two prefix-caching engine replicas pinned to distinct host
        devices behind one shared queue: a shared-system-prompt trace
        finishes completely, dispatch is balanced, at least one replica
        serves prefix hits, and both pools drain leak-free."""
        from benchmarks.bench_serve_engine import make_shared_trace
        from repro import configs
        from repro.launch.serve import ReplicaSet
        from repro.models import transformer as T
        from repro.quant import PrecisionPlan
        from repro.serve import ServeEngine

        cfg = configs.get_reduced("qwen2.5-14b")
        params = T.init_params(KEY, cfg)
        rs = ReplicaSet(
            lambda i: ServeEngine(params, cfg, plan=PrecisionPlan(kv_bits=8),
                                  max_slots=2, page_size=4, max_seq_len=32,
                                  prefix_cache=True, chunk_pages=2),
            2, devices=jax.devices()[:2])
        n = 12
        out = rs.run(make_shared_trace(n, cfg.vocab_size, page_size=4,
                                       sys_pages=2, max_new=4))
        assert sorted(out) == list(range(n))
        assert min(rs.dispatched) >= 2        # least-loaded spreads the work
        assert rs.stats_sum("prefix_hits") >= 1
        for eng in rs.engines:
            eng.release_prefix_cache()
            eng.allocator.check_leaks(0)

    def test_replica_set_spec_decode_prefix_dispatch(self):
        """Speculative decoding composes with the multi-replica front-end:
        two device-pinned replicas under **prefix-aware dispatch**, each
        drafting through the int4 bitplane view, finish a shared-prefix
        trace token-identical to a vanilla (non-speculative) replica set,
        with speculative windows on both replicas and leak-free pools."""
        from benchmarks.bench_serve_engine import make_shared_trace
        from repro import configs
        from repro.launch.serve import ReplicaSet
        from repro.models import transformer as T
        from repro.precision.qat import quantize_param_tree
        from repro.quant import PrecisionPlan
        from repro.serve import ServeEngine

        cfg = configs.get_reduced("qwen2.5-14b")
        params = quantize_param_tree(T.init_params(KEY, cfg), bits=8,
                                     layout="bitplane")

        def mk_set(spec):
            kw = dict(spec_decode=3, draft_bits=4) if spec else {}
            return ReplicaSet(
                lambda i: ServeEngine(params, cfg,
                                      plan=PrecisionPlan(kv_bits=8),
                                      max_slots=2, page_size=4,
                                      max_seq_len=32, prefix_cache=True,
                                      chunk_pages=2, **kw),
                2, devices=jax.devices()[:2], dispatch="prefix")

        n = 12
        trace = lambda: make_shared_trace(n, cfg.vocab_size, page_size=4,
                                          sys_pages=2, max_new=4)
        want = mk_set(spec=False).run(trace())
        rs = mk_set(spec=True)
        out = rs.run(trace())
        assert sorted(out) == list(range(n))
        assert rs.stats_sum("spec_steps") >= 2
        assert min(e.stats["spec_steps"] for e in rs.engines) >= 1
        for rid in want:
            np.testing.assert_array_equal(out[rid].tokens, want[rid].tokens)
        for eng in rs.engines:
            eng.release_prefix_cache()
            eng.allocator.check_leaks(0)
