"""Per-kernel allclose tests vs the ref.py oracles, swept over shapes/dtypes
(interpret=True executes the Pallas kernel bodies on CPU)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.kernels import qmm as qmm_mod
from repro.kernels import ssd as ssd_mod
from repro.kernels import stoch_quant as sq_mod

KEY = jax.random.PRNGKey(0)


class TestStochQuant:
    @pytest.mark.parametrize("shape", [
        (8, 128), (1, 128),
        pytest.param((256, 512), marks=pytest.mark.slow),
        pytest.param((300, 700), marks=pytest.mark.slow),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("s", [1, 7, 127])
    def test_matches_ref_bit_exact(self, shape, dtype, s):
        x = (jax.random.normal(KEY, shape) * 3).astype(dtype)
        rand = jax.random.bits(jax.random.fold_in(KEY, 1), shape, jnp.uint32)
        scale = ref.row_absmax_ref(x)
        got = sq_mod.stoch_quant(x, rand, scale, s=s, interpret=True)
        want = ref.stoch_quant_ref(x, rand, scale, s=s)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("shape", [(64, 256), (129, 640)])
    def test_row_absmax(self, shape):
        x = jax.random.normal(KEY, shape)
        got = sq_mod.row_absmax(x, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref.row_absmax_ref(x)),
                                   rtol=1e-6)

    @pytest.mark.slow
    def test_unbiased_end_to_end(self):
        x = jax.random.normal(KEY, (4, 128))
        s = 7
        keys = jax.random.split(KEY, 2048)
        deqs = jax.vmap(
            lambda k: ops.dequantize_rows(*ops.quantize_rows(x, s, k), s))(keys)
        se = deqs.std(0) / np.sqrt(len(keys)) + 1e-6
        np.testing.assert_array_less(np.abs(deqs.mean(0) - x), 6 * se + 1e-3)


class TestQMM:
    @pytest.mark.parametrize("mkn", [
        (128, 256, 128), (100, 300, 200),
        pytest.param((256, 512, 256), marks=pytest.mark.slow),
        pytest.param((384, 1024, 512), marks=pytest.mark.slow),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, mkn, dtype):
        m, k, n = mkn
        x = (jax.random.normal(KEY, (m, k)) * 0.5).astype(dtype)
        w = jax.random.normal(jax.random.fold_in(KEY, 2), (k, n))
        qmax = 127.0
        scale = jnp.max(jnp.abs(w), axis=0, keepdims=True) / qmax
        codes = jnp.clip(jnp.round(w / scale), -qmax, qmax).astype(jnp.int8)
        got = np.asarray(ops.quantized_matmul(x, codes, scale))
        want = np.asarray(ref.qmm_ref(x.astype(jnp.float32), codes, scale))
        # normalized RMS: pointwise relative error is meaningless where y≈0
        nrms = np.sqrt(((got - want) ** 2).mean()) / (np.sqrt((want ** 2).mean()) + 1e-9)
        assert nrms < (1e-2 if dtype == jnp.bfloat16 else 1e-5), nrms

    @pytest.mark.slow
    def test_blocked_equals_unblocked(self):
        m, k, n = 256, 1024, 256
        x = jax.random.normal(KEY, (m, k), jnp.float32)
        codes = jax.random.randint(jax.random.fold_in(KEY, 3), (k, n), -127, 128
                                   ).astype(jnp.int8)
        scale = jnp.abs(jax.random.normal(KEY, (1, n))) * 0.01 + 1e-3
        small = qmm_mod.qmm(x, codes, scale, bm=128, bk=128, bn=128, interpret=True)
        big = qmm_mod.qmm(x, codes, scale, bm=256, bk=1024, bn=256, interpret=True)
        # fp32 K-accumulation order differs between blockings
        np.testing.assert_allclose(np.asarray(small), np.asarray(big),
                                   rtol=1e-4, atol=1e-3)


class TestSSD:
    @pytest.mark.parametrize("dims", [
        (2, 4, 32, 4, 8, 16),
        pytest.param((1, 2, 64, 8, 16, 32), marks=pytest.mark.slow),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, dims, dtype):
        b, nc, L, h, p, n = dims
        k = jax.random.fold_in(KEY, 7)
        xh = (jax.random.normal(k, (b, nc, L, h, p)) * 0.5).astype(dtype)
        dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1),
                                               (b, nc, L, h)) - 1.0)
        a_log = jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32))
        logdec = dt * (-jnp.exp(a_log))[None, None, None, :]
        bm = jax.random.normal(jax.random.fold_in(k, 2), (b, nc, L, n)) * 0.3
        cm = jax.random.normal(jax.random.fold_in(k, 3), (b, nc, L, n)) * 0.3
        y, state = ssd_mod.ssd_chunk_scan(xh, dt, logdec, bm, cm, interpret=True)
        y_ref, state_ref = ref.ssd_chunk_scan_ref(xh, dt, logdec, bm, cm)
        tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(y_ref, np.float32),
                                   rtol=tol, atol=tol)
        np.testing.assert_allclose(np.asarray(state), np.asarray(state_ref),
                                   rtol=tol, atol=tol)

    def test_kernel_matches_model_ssd(self):
        """ops.ssd_chunked_kernel == models.ssm.ssd_chunked on the same inputs."""
        from repro.models.ssm import SSMSpec, ssd_chunked
        b, s, h, p, n = 2, 128, 4, 16, 32
        spec = SSMSpec(d_model=h * p // 2, d_state=n, head_dim=p, chunk=32)
        k = jax.random.fold_in(KEY, 9)
        xh = jax.random.normal(k, (b, s, h, p), jnp.float32) * 0.5
        dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1), (b, s, h)))
        a_log = jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32))
        bm = jax.random.normal(jax.random.fold_in(k, 2), (b, s, 1, n)) * 0.3
        cm = jax.random.normal(jax.random.fold_in(k, 3), (b, s, 1, n)) * 0.3
        y_model, st_model = ssd_chunked(xh, dt, a_log, bm, cm, spec)
        y_kern, st_kern = ops.ssd_chunked_kernel(
            xh, dt, a_log, bm.reshape(b, s, n), cm.reshape(b, s, n), chunk=32)
        np.testing.assert_allclose(np.asarray(y_kern), np.asarray(y_model),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(st_kern), np.asarray(st_model),
                                   rtol=1e-4, atol=1e-4)
