"""Bit-exact parity: the one canonical quantizer in repro/quant vs frozen
copies of the three deleted inline quantizers (satellite of the QTensor PR).

The old implementations — ``precision/act_quant._quant``,
``precision/gradcomp._quantize_leaf``, ``precision/qat._int_quantize_weight``
— are reproduced verbatim below; every test pins codes AND scales equal to
the new ``repro.quant.encode`` output under the same PRNG key. The zipml-grid
path (``core.quantize.quantize``) is pinned the same way.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import quant
from repro.core import quantize as qz
from repro.quant import QScheme

KEY = jax.random.PRNGKey(42)


# --- frozen copies of the deleted quantizers (seed-era code, verbatim) ------

def _old_act_quant(x, bits, key):
    """precision/act_quant._quant as of the seed."""
    x32 = x.astype(jnp.float32)
    qmax = float(2 ** (bits - 1) - 1)
    absmax = jax.lax.stop_gradient(jnp.max(jnp.abs(x32)))
    scale = jnp.where(absmax == 0, 1.0, absmax / qmax)
    t = x32 / scale
    lo = jnp.floor(t)
    codes = lo + (jax.random.uniform(key, x.shape) < (t - lo)).astype(jnp.float32)
    return jnp.clip(codes, -qmax, qmax).astype(jnp.int8), scale


def _old_gradcomp_leaf(g, bits, key):
    """precision/gradcomp._quantize_leaf as of the seed."""
    g32 = g.astype(jnp.float32)
    qmax = float(2 ** (bits - 1) - 1)
    absmax = jnp.max(jnp.abs(g32))
    scale = jnp.where(absmax == 0, 1.0, absmax / qmax)
    t = g32 / scale
    lo = jnp.floor(t)
    codes = lo + (jax.random.uniform(key, g.shape) < (t - lo)).astype(jnp.float32)
    return (jnp.clip(codes, -qmax, qmax).astype(jnp.int8),
            scale.astype(jnp.float32))


def _old_qat_weight(w, bits):
    """precision/qat._int_quantize_weight as of the seed."""
    w32 = w.astype(jnp.float32)
    qmax = float(2 ** (bits - 1) - 1)
    absmax = jnp.max(jnp.abs(w32), axis=-2, keepdims=True)
    scale = jnp.where(absmax == 0, 1.0, absmax / qmax)
    codes = jnp.clip(jnp.round(w32 / scale), -qmax, qmax).astype(jnp.int8)
    return {"w_q": codes, "w_scale": scale.astype(jnp.float32)}


def _old_zipml_quantize(v, s, key, scale, signed=True):
    """core/quantize.quantize as of the seed (codes + scale)."""
    v = jnp.asarray(v)
    x = (v / scale).astype(jnp.float32)
    mag = jnp.clip(jnp.abs(x) if signed else x, 0.0, 1.0)
    t = mag * s
    lo = jnp.clip(jnp.floor(t), 0, s - 1)
    p_up = t - lo
    u = jax.random.uniform(key, v.shape, dtype=jnp.float32)
    codes = lo + (u < p_up).astype(jnp.float32)
    if signed:
        codes = codes * jnp.sign(x)
    dt = jnp.int8 if s <= 127 else jnp.int32
    return codes.astype(dt), jnp.asarray(scale)


class TestIntGridParity:
    @pytest.mark.parametrize("bits", [4, 8])
    @pytest.mark.parametrize("shape", [(16,), (8, 32), (4, 8, 16)])
    def test_act_quant(self, bits, shape):
        x = jax.random.normal(KEY, shape) * 3
        k = jax.random.fold_in(KEY, bits)
        want_c, want_s = _old_act_quant(x, bits, k)
        got = quant.encode(x, QScheme.int_symmetric(bits), k)
        np.testing.assert_array_equal(np.asarray(got.codes), np.asarray(want_c))
        np.testing.assert_array_equal(np.asarray(got.scale), np.asarray(want_s))

    @pytest.mark.parametrize("bits", [2, 8])
    def test_gradcomp_leaf(self, bits):
        g = jax.random.normal(KEY, (64,)) * 0.1
        k = jax.random.fold_in(KEY, 7 + bits)
        want_c, want_s = _old_gradcomp_leaf(g, bits, k)
        got = quant.encode(g, QScheme.int_symmetric(bits), k)
        np.testing.assert_array_equal(np.asarray(got.codes), np.asarray(want_c))
        np.testing.assert_array_equal(np.asarray(got.scale), np.asarray(want_s))

    @pytest.mark.parametrize("bits", [4, 8])
    def test_qat_weight(self, bits):
        w = jax.random.normal(KEY, (32, 16)) * 0.05
        want = _old_qat_weight(w, bits)
        got = quant.encode(w, QScheme.int_symmetric(
            bits, scaling="channel", rounding="nearest", channel_axis=-2))
        np.testing.assert_array_equal(np.asarray(got.codes),
                                      np.asarray(want["w_q"]))
        np.testing.assert_array_equal(np.asarray(got.scale),
                                      np.asarray(want["w_scale"]))

    def test_ds_pair_matches_split_key_draws(self):
        """The double-sampled activation pair == two old _quant calls with the
        same split keys (what act_quant.ds_dense used to do)."""
        x = jax.random.normal(KEY, (16, 24))
        k1, k2 = jax.random.split(KEY)
        want1, s1 = _old_act_quant(x, 8, k1)
        want2, s2 = _old_act_quant(x, 8, k2)
        qt = quant.ds_pair(x, QScheme.int_symmetric(8, rounding="ds"), KEY)
        np.testing.assert_array_equal(np.asarray(qt.codes), np.asarray(want1))
        np.testing.assert_array_equal(np.asarray(qt.codes2), np.asarray(want2))
        np.testing.assert_array_equal(np.asarray(qt.scale), np.asarray(s1))
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


class TestZipmlGridParity:
    @pytest.mark.parametrize("s", [1, 7, 255])
    def test_stochastic(self, s):
        v = jax.random.normal(KEY, (8, 16)) * 2
        scale = qz.row_scale(v)
        k = jax.random.fold_in(KEY, s)
        want_c, want_s = _old_zipml_quantize(v, s, k, scale)
        got = qz.quantize(v, s, k, scale=scale)
        np.testing.assert_array_equal(np.asarray(got.codes), np.asarray(want_c))
        np.testing.assert_array_equal(np.asarray(got.scale), np.asarray(want_s))
        assert got.codes.dtype == want_c.dtype

    def test_column_scaled(self):
        v = jax.random.normal(KEY, (32, 5)) * jnp.asarray([1, 5, 0.2, 2, 9.0])
        scale = qz.column_scale(v)
        want_c, _ = _old_zipml_quantize(v, 15, KEY, scale)
        got = qz.quantize(v, 15, KEY, scale=scale)
        np.testing.assert_array_equal(np.asarray(got.codes), np.asarray(want_c))
