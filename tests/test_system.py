"""System-behaviour tests: checkpoint/restart/elastic, fault injection,
data-pipeline determinism, gradient compression, double-sampled activations,
quantized optimizer moments, end-to-end driver runs."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.ckpt.checkpoint import CheckpointManager
from repro.data.pipeline import Cursor, QuantizedSampleStore, TokenStream, TokenStreamConfig
from repro.precision import act_quant, gradcomp

KEY = jax.random.PRNGKey(0)


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = {"a": jnp.arange(12.0).reshape(3, 4), "b": {"c": jnp.ones(5)}}
        mgr.save(7, tree, extra={"cursor": {"step": 7, "epoch": 0}}, blocking=True)
        got, manifest = mgr.restore(jax.eval_shape(lambda: tree))
        assert manifest["step"] == 7
        np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))

    def test_keep_and_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"x": jnp.zeros(3)}
        for s in (1, 2, 3, 4):
            mgr.save(s, tree, blocking=True)
        assert mgr.all_steps() == [3, 4]

    def test_incomplete_checkpoint_ignored(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"x": jnp.ones(2)}, blocking=True)
        # simulate a crash mid-save: directory without the commit marker
        os.makedirs(tmp_path / "step_000000009")
        assert mgr.latest_step() == 1

    def test_elastic_restore_resharded(self, tmp_path):
        """Checkpoint written unsharded restores onto a different mesh."""
        mgr = CheckpointManager(str(tmp_path))
        tree = {"w": jnp.arange(64.0).reshape(8, 8)}
        mgr.save(3, tree, blocking=True)
        if hasattr(jax.sharding, "AxisType"):  # jax >= 0.5: explicit axis types
            mesh = jax.make_mesh((1,), ("data",),
                                 axis_types=(jax.sharding.AxisType.Auto,))
        else:
            mesh = jax.make_mesh((1,), ("data",))
        shardings = {"w": jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec("data", None))}
        got, _ = mgr.restore(jax.eval_shape(lambda: tree), shardings=shardings)
        np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))
        assert got["w"].sharding.is_equivalent_to(shardings["w"], 2)

    def test_shape_mismatch_rejected(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"w": jnp.zeros((4, 4))}, blocking=True)
        with pytest.raises(ValueError):
            mgr.restore(jax.eval_shape(lambda: {"w": jnp.zeros((2, 2))}))


@pytest.mark.slow
class TestTrainSupervisor:
    def test_fault_injection_recovers(self, tmp_path):
        """Injected fault at step 12 → restore from step-10 checkpoint →
        training completes all steps with the same final cursor."""
        from repro.launch.train import train
        _, losses = train("musicgen-medium", steps=16, batch=2, seq=16,
                          ckpt_dir=str(tmp_path), ckpt_every=10, fail_at=12,
                          log_every=100)
        # 16 real steps recorded after replaying 12→10
        assert len(losses) >= 16
        assert np.isfinite(losses).all()

    def test_grad_compression_trains(self):
        from repro.launch.train import train
        _, losses = train("musicgen-medium", steps=12, batch=2, seq=16,
                          grad_bits=8, log_every=100)
        assert losses[-1] < losses[0]

    def test_quantized_moments_train(self):
        from repro.launch.train import train
        _, losses = train("musicgen-medium", steps=12, batch=2, seq=16,
                          moment_bits=8, log_every=100)
        assert losses[-1] < losses[0]

    def test_qat_trains(self):
        from repro.launch.train import train
        _, losses = train("musicgen-medium", steps=12, batch=2, seq=16,
                          weight_bits=8, log_every=100)
        assert losses[-1] < losses[0]


class TestDataPipeline:
    def test_deterministic_and_resumable(self):
        cfg = TokenStreamConfig(vocab_size=100, seq_len=16, global_batch=4)
        s1 = TokenStream(cfg)
        batches = [s1.next_batch() for _ in range(5)]
        s2 = TokenStream(cfg)
        s2.skip_to(Cursor(step=3))
        np.testing.assert_array_equal(s2.next_batch()["tokens"],
                                      batches[3]["tokens"])

    def test_host_sharding_disjoint(self):
        full = TokenStream(TokenStreamConfig(100, 16, 4, n_hosts=1, host_id=0))
        h0 = TokenStream(TokenStreamConfig(100, 16, 4, n_hosts=2, host_id=0))
        h1 = TokenStream(TokenStreamConfig(100, 16, 4, n_hosts=2, host_id=1))
        b0, b1 = h0.next_batch(), h1.next_batch()
        assert b0["tokens"].shape == (2, 16)
        assert not np.array_equal(b0["tokens"], b1["tokens"])

    def test_targets_shifted(self):
        s = TokenStream(TokenStreamConfig(100, 16, 2))
        b = s.next_batch()
        np.testing.assert_array_equal(b["targets"][:, :-1], b["tokens"][:, 1:])

    def test_quantized_store_bytes(self):
        rng = np.random.default_rng(0)
        a = rng.normal(0, 1, (100, 64))
        store = QuantizedSampleStore.build(a, rng.normal(size=100), bits=4)
        assert store.bytes_per_sample() < 64 * 4  # < fp32
        aa, bb = store.draw(0, 8)
        assert aa.shape == (8, 64) and bb.shape == (8,)
        # dequantized values stay within one level width of the column scale
        width = store.scale / store.s
        assert (np.abs(np.asarray(aa)) <= store.scale + width + 1e-6).all()


class TestGradCompression:
    def test_roundtrip_error_bounded(self):
        g = {"a": jax.random.normal(KEY, (64,)), "b": jax.random.normal(KEY, (8, 8))}
        comp, err = gradcomp.compress_tree(g, 8, KEY)
        deq = gradcomp.decompress_tree(comp)
        for k in g:
            step = float(jnp.max(jnp.abs(g[k]))) / 127
            assert float(jnp.max(jnp.abs(deq[k] - g[k]))) <= step + 1e-6

    def test_unbiased(self):
        g = {"a": jax.random.normal(KEY, (32,))}
        keys = jax.random.split(KEY, 4096)
        deqs = jax.vmap(lambda k: gradcomp.decompress_tree(
            gradcomp.compress_tree(g, 4, k)[0])["a"])(keys)
        se = deqs.std(0) / np.sqrt(len(keys)) + 1e-6
        np.testing.assert_array_less(np.abs(deqs.mean(0) - g["a"]), 6 * se + 1e-3)

    def test_error_feedback_telescopes(self):
        """With EF, the *accumulated* applied update converges to the
        accumulated true gradient (residual stays bounded)."""
        g = {"a": jnp.ones((16,)) * 0.01}  # tiny gradient ≪ one quant step of 2 bits
        err = gradcomp.init_error_feedback(g)
        applied = jnp.zeros((16,))
        for i in range(50):
            comp, err = gradcomp.compress_tree(g, 2, jax.random.fold_in(KEY, i),
                                               error=err)
            applied += gradcomp.decompress_tree(comp)["a"]
        true_sum = 0.01 * 50
        np.testing.assert_allclose(np.asarray(applied), true_sum, atol=0.02)

    def test_compression_ratio(self):
        assert gradcomp.compression_ratio(8) == 2.0
        assert gradcomp.compression_ratio(4) == 4.0


class TestActDoubleSampling:
    def test_forward_close(self):
        x = jax.random.normal(KEY, (32, 64))
        w = jax.random.normal(jax.random.fold_in(KEY, 1), (64, 16)) * 0.1
        y = act_quant.ds_dense(x, w, KEY, 8)
        y_ref = x @ w
        rel = float(jnp.linalg.norm(y - y_ref) / jnp.linalg.norm(y_ref))
        assert rel < 0.02, rel

    def test_weight_grad_unbiased(self):
        """E[∂W] under double-sampled activations equals the exact ∂W."""
        x = jax.random.normal(KEY, (8, 16))
        w = jax.random.normal(jax.random.fold_in(KEY, 1), (16, 4)) * 0.1

        def loss(w_, key):
            return jnp.sum(act_quant.ds_dense(x, w_, key, 4))

        exact = jax.grad(lambda w_: jnp.sum(x @ w_))(w)
        keys = jax.random.split(KEY, 8192)
        grads = jax.vmap(lambda k: jax.grad(loss)(w, k))(keys)
        se = grads.std(0) / np.sqrt(len(keys)) + 1e-6
        np.testing.assert_array_less(np.abs(grads.mean(0) - exact),
                                     6 * np.asarray(se) + 1e-3)

    def test_mlp_trains(self):
        p = {"gate": {"w": jax.random.normal(KEY, (16, 32)) * 0.25},
             "up": {"w": jax.random.normal(jax.random.fold_in(KEY, 1), (16, 32)) * 0.25},
             "down": {"w": jax.random.normal(jax.random.fold_in(KEY, 2), (32, 16)) * 0.25}}
        x = jax.random.normal(KEY, (64, 16))
        target = jnp.roll(x, 1, axis=1)

        def loss(pp, key):
            return jnp.mean((act_quant.ds_mlp(pp, x, key) - target) ** 2)

        l0 = float(loss(p, KEY))
        for i in range(60):
            g = jax.grad(loss)(p, jax.random.fold_in(KEY, i))
            p = jax.tree.map(lambda a, b: a - 0.3 * b, p, g)
        l1 = float(loss(p, jax.random.fold_in(KEY, 999)))
        assert l1 < l0 * 0.9


class TestElasticController:
    def _fleet(self, n_pods=2):
        from repro.launch.elastic import ElasticController, HOSTS_PER_POD
        c = ElasticController(n_pods, heartbeat_timeout=10, rejoin_patience=2)
        t = 1000.0
        for pod in range(n_pods):
            for h in range(HOSTS_PER_POD):
                c.heartbeat(pod * HOSTS_PER_POD + h, pod, now=t)
        return c, t

    def test_steady_state(self):
        c, t = self._fleet()
        d = c.decide(latest_checkpoint_step=100, now=t + 1)
        assert d.n_pods == 2 and d.mesh_shape == (2, 16, 16)
        assert d.restore_step is None and not d.evicted_pods
        assert len(d.shard_assignment) == 128

    def test_pod_failure_shrinks_and_restores(self):
        c, t = self._fleet()
        c.report_failure(5)  # host 5 (pod 0) dies
        d = c.decide(latest_checkpoint_step=100, now=t + 1)
        assert d.n_pods == 1 and d.mesh_shape == (16, 16)
        assert d.evicted_pods == [0]
        assert d.restore_step == 100
        # surviving hosts get contiguous shard ids
        assert sorted(d.shard_assignment.values()) == list(range(64))

    def test_flap_protection(self):
        from repro.launch.elastic import HOSTS_PER_POD
        c, t = self._fleet()
        c.report_failure(5)
        c.decide(100, now=t + 1)            # pod 0 evicted
        # pod 0 comes back: one healthy round is not enough to re-admit
        for h in range(HOSTS_PER_POD):
            c.heartbeat(h, 0, now=t + 2)
        d = c.decide(100, now=t + 2)
        assert d.n_pods == 1
        d = c.decide(100, now=t + 3)        # second healthy round → admitted
        assert d.n_pods == 2

    def test_heartbeat_timeout_evicts(self):
        c, t = self._fleet()
        d = c.decide(100, now=t + 60)       # all heartbeats stale
        assert d.n_pods == 0 and "halt" in d.reason

    def test_rollback_budget(self):
        from repro.launch.elastic import plan_rollback
        assert plan_rollback([10, 50, 90], failed_at_step=95) == 90
        import pytest as _pytest
        with _pytest.raises(RuntimeError):
            plan_rollback([10], failed_at_step=5000, max_rollback=100)
