"""Hypothesis property-based tests for the system's core invariants.

Each property is the load-bearing guarantee of a subsystem:
  * quantizer unbiasedness & boundedness over arbitrary inputs/levels (C1)
  * DP-optimal levels never lose to uniform, monotone in s (C4)
  * double-sampling estimator unbiasedness for arbitrary (a, x, b) (C2)
  * gradient compression roundtrip bound & error-feedback telescoping (C3)
  * sharding rules always produce divisible, mesh-valid specs
  * data pipeline determinism under arbitrary cursors
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import repro.core.quantize as qz
from repro.core import optimal
from repro.core.double_sampling import (lsq_gradient_double_sampling,
                                        lsq_gradient_fullprec)
from repro.data.pipeline import Cursor, TokenStream, TokenStreamConfig
from repro.precision import gradcomp

SETTINGS = dict(max_examples=25, deadline=None)


@st.composite
def _vectors(draw, max_n=48):
    n = draw(st.integers(1, max_n))
    seed = draw(st.integers(0, 2**31 - 1))
    scale = draw(st.floats(1e-3, 1e3))
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, scale, n), jnp.float32)


class TestQuantizerProperties:
    @settings(**SETTINGS)
    @given(v=_vectors(), s=st.sampled_from([1, 2, 3, 7, 15, 127]),
           seed=st.integers(0, 2**31 - 1))
    def test_roundtrip_within_one_interval(self, v, s, seed):
        q = qz.stochastic_quantize(v, s, jax.random.PRNGKey(seed))
        width = qz.row_scale(v) / s
        assert float(jnp.max(jnp.abs(q - v))) <= float(width) + 1e-4

    @settings(**SETTINGS)
    @given(v=_vectors(max_n=16), s=st.sampled_from([1, 3, 7]),
           seed=st.integers(0, 2**31 - 1))
    def test_unbiased(self, v, s, seed):
        keys = jax.random.split(jax.random.PRNGKey(seed), 1500)
        qs = jax.vmap(lambda k: qz.stochastic_quantize(v, s, k))(keys)
        se = qs.std(0) / np.sqrt(1500) + 1e-6
        assert (np.abs(np.asarray(qs.mean(0) - v)) < 6 * se
                + 1e-3 * float(qz.row_scale(v))).all()

    @settings(**SETTINGS)
    @given(v=_vectors(), s=st.sampled_from([1, 3, 31]))
    def test_variance_bound(self, v, s):
        n = v.shape[0]
        tv = float(qz.tv_variance(v, s, scale=qz.row_scale(v, "l2")))
        bound = min(n / s**2, np.sqrt(n) / s) * float(jnp.sum(v * v))
        assert tv <= bound + 1e-4 * bound + 1e-6


class TestOptimalLevelProperties:
    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**31 - 1), s=st.sampled_from([2, 3, 7]),
           n=st.integers(10, 300))
    def test_never_worse_than_uniform(self, seed, s, n):
        xs = np.clip(np.random.default_rng(seed).beta(0.7, 2.0, n), 0, 1)
        lv = optimal.optimal_levels_discretized(xs, s, M=64)
        assert (optimal.mean_variance(xs, lv)
                <= optimal.mean_variance(xs, optimal.uniform_levels(s)) + 1e-12)

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_monotone_in_levels(self, seed):
        xs = np.random.default_rng(seed).uniform(0, 1, 200)
        mvs = [optimal.mean_variance(
            xs, optimal.optimal_levels_discretized(xs, s, M=64))
            for s in (2, 4, 8)]
        assert mvs[0] >= mvs[1] >= mvs[2] - 1e-12


class TestDoubleSamplingProperties:
    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 24),
           batch=st.integers(1, 8))
    def test_unbiased_for_any_instance(self, seed, n, batch):
        rng = np.random.default_rng(seed)
        a = jnp.asarray(rng.normal(0, 1, (batch, n)), jnp.float32)
        x = jnp.asarray(rng.normal(0, 2, n), jnp.float32)
        b = jnp.asarray(rng.normal(0, 1, batch), jnp.float32)
        truth = lsq_gradient_fullprec(x, a, b)
        keys = jax.random.split(jax.random.PRNGKey(seed), 2000)
        gs = jax.vmap(lambda k: lsq_gradient_double_sampling(x, a, b, 3, k))(keys)
        se = np.asarray(gs.std(0)) / np.sqrt(2000) + 1e-6
        assert (np.abs(np.asarray(gs.mean(0) - truth)) < 6 * se + 1e-2).all()


class TestGradCompressionProperties:
    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**31 - 1), bits=st.sampled_from([2, 4, 8]),
           n=st.integers(1, 64))
    def test_roundtrip_bound(self, seed, bits, n):
        g = {"x": jnp.asarray(np.random.default_rng(seed).normal(0, 1, n),
                              jnp.float32)}
        comp, _ = gradcomp.compress_tree(g, bits, jax.random.PRNGKey(seed))
        deq = gradcomp.decompress_tree(comp)
        step = float(jnp.max(jnp.abs(g["x"]))) / (2 ** (bits - 1) - 1)
        assert float(jnp.max(jnp.abs(deq["x"] - g["x"]))) <= step + 1e-5

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_error_feedback_residual_bounded(self, seed):
        rng = np.random.default_rng(seed)
        g = {"x": jnp.asarray(rng.normal(0, 0.01, 8), jnp.float32)}
        err = gradcomp.init_error_feedback(g)
        for i in range(30):
            _, err = gradcomp.compress_tree(g, 2, jax.random.PRNGKey(i), error=err)
        # EF residual stays bounded by one quantization step of the running sum
        assert float(jnp.max(jnp.abs(err["x"]))) < 1.0


class TestShardingRuleProperties:
    @settings(**SETTINGS)
    @given(arch=st.sampled_from(["gemma-2b", "mixtral-8x7b", "mamba2-780m",
                                 "zamba2-2.7b"]))
    def test_specs_divide_mesh(self, arch):
        """Every param spec must divide the production mesh axis sizes."""
        from repro import configs
        from repro.launch.sharding import param_spec, _path_str
        from repro.models import transformer as T
        sizes = {"data": 16, "model": 16}
        cfg = configs.get_config(arch)
        params = T.param_specs(cfg)

        def check(path, leaf):
            spec = param_spec(path, leaf)
            for dim, part in zip(leaf.shape, spec):
                if part is None:
                    continue
                parts = part if isinstance(part, tuple) else (part,)
                total = int(np.prod([sizes[p] for p in parts]))
                assert dim % total == 0, (_path_str(path), leaf.shape, spec)

        jax.tree_util.tree_map_with_path(check, params)


class TestPipelineProperties:
    @settings(**SETTINGS)
    @given(seed=st.integers(0, 1000), step=st.integers(0, 50),
           vocab=st.integers(10, 1000))
    def test_cursor_determinism(self, seed, step, vocab):
        cfg = TokenStreamConfig(vocab_size=vocab, seq_len=8, global_batch=2,
                                seed=seed)
        s1 = TokenStream(cfg)
        s1.skip_to(Cursor(step=step))
        b1 = s1.next_batch()
        s2 = TokenStream(cfg)
        s2.skip_to(Cursor(step=step))
        b2 = s2.next_batch()
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        assert b1["tokens"].max() < vocab
