"""Hypothesis property-based tests for the system's core invariants.

Each property is the load-bearing guarantee of a subsystem:
  * quantizer unbiasedness & boundedness over arbitrary inputs/levels (C1)
  * the full repro.quant QScheme grid: round-trip error bound and
    stochastic/double-sampling unbiasedness over bits × tensor/row/column/
    channel scaling × nearest/stochastic/ds rounding, incl. packed int4
  * DP-optimal levels never lose to uniform, monotone in s (C4)
  * double-sampling estimator unbiasedness for arbitrary (a, x, b) (C2)
  * gradient compression roundtrip bound & error-feedback telescoping (C3)
  * sharding rules always produce divisible, mesh-valid specs
  * data pipeline determinism under arbitrary cursors
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import repro.core.quantize as qz
from repro import quant
from repro.core import optimal
from repro.core.double_sampling import (lsq_gradient_double_sampling,
                                        lsq_gradient_fullprec)
from repro.data.pipeline import Cursor, TokenStream, TokenStreamConfig
from repro.precision import gradcomp
from repro.quant import QScheme

SETTINGS = dict(max_examples=25, deadline=None)


@st.composite
def _vectors(draw, max_n=48):
    n = draw(st.integers(1, max_n))
    seed = draw(st.integers(0, 2**31 - 1))
    scale = draw(st.floats(1e-3, 1e3))
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, scale, n), jnp.float32)


class TestQuantizerProperties:
    @settings(**SETTINGS)
    @given(v=_vectors(), s=st.sampled_from([1, 2, 3, 7, 15, 127]),
           seed=st.integers(0, 2**31 - 1))
    def test_roundtrip_within_one_interval(self, v, s, seed):
        q = qz.stochastic_quantize(v, s, jax.random.PRNGKey(seed))
        width = qz.row_scale(v) / s
        assert float(jnp.max(jnp.abs(q - v))) <= float(width) + 1e-4

    @settings(**SETTINGS)
    @given(v=_vectors(max_n=16), s=st.sampled_from([1, 3, 7]),
           seed=st.integers(0, 2**31 - 1))
    def test_unbiased(self, v, s, seed):
        keys = jax.random.split(jax.random.PRNGKey(seed), 1500)
        qs = jax.vmap(lambda k: qz.stochastic_quantize(v, s, k))(keys)
        se = qs.std(0) / np.sqrt(1500) + 1e-6
        assert (np.abs(np.asarray(qs.mean(0) - v)) < 6 * se
                + 1e-3 * float(qz.row_scale(v))).all()

    @settings(**SETTINGS)
    @given(v=_vectors(), s=st.sampled_from([1, 3, 31]))
    def test_variance_bound(self, v, s):
        n = v.shape[0]
        tv = float(qz.tv_variance(v, s, scale=qz.row_scale(v, "l2")))
        bound = min(n / s**2, np.sqrt(n) / s) * float(jnp.sum(v * v))
        assert tv <= bound + 1e-4 * bound + 1e-6


SCALINGS = ("tensor", "row", "column", "channel")


def _grid_matrix(seed, rows=4, cols=8, spread=True):
    rng = np.random.default_rng(seed)
    sd = rng.uniform(1e-2, 10.0) if spread else 1.0
    return jnp.asarray(rng.normal(0, sd, (rows, cols)), jnp.float32)


def _bcast_scale(qt, shape):
    return np.broadcast_to(np.asarray(qt.scale), shape)


class TestQSchemeGridProperties:
    """The repro.quant contract over the whole scheme grid: every
    (grid × bits × scaling × rounding) cell round-trips within one code step
    and the stochastic modes are unbiased — including the nibble-packed int4
    storage, which must be value-identical to unpacked int4."""

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**31 - 1), bits=st.sampled_from([2, 4, 8]),
           scaling=st.sampled_from(SCALINGS),
           rounding=st.sampled_from(["nearest", "stochastic", "ds"]))
    def test_int_grid_roundtrip_within_one_step(self, seed, bits, scaling,
                                                rounding):
        x = _grid_matrix(seed)
        sch = QScheme.int_symmetric(bits, scaling=scaling, rounding=rounding)
        qt = quant.encode(x, sch, key=jax.random.PRNGKey(seed))
        step = _bcast_scale(qt, x.shape)
        tol = step * (0.5 if rounding == "nearest" else 1.0) + 1e-5
        planes = [qt.decode()] + ([qt.decode2()] if rounding == "ds" else [])
        for deq in planes:
            err = np.abs(np.asarray(deq) - np.asarray(x))
            assert (err <= tol).all(), float((err - tol).max())

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**31 - 1), s=st.sampled_from([3, 7, 31]),
           scaling=st.sampled_from(["tensor", "row"]),
           rounding=st.sampled_from(["nearest", "stochastic", "ds"]))
    def test_zipml_grid_roundtrip_within_one_interval(self, seed, s, scaling,
                                                      rounding):
        x = _grid_matrix(seed)
        sch = QScheme.zipml(s, scaling=scaling, rounding=rounding)
        qt = quant.encode(x, sch, key=jax.random.PRNGKey(seed))
        width = _bcast_scale(qt, x.shape) / s
        tol = width * (0.5 if rounding == "nearest" else 1.0) + 1e-5
        planes = [qt.decode()] + ([qt.decode2()] if rounding == "ds" else [])
        for deq in planes:
            err = np.abs(np.asarray(deq) - np.asarray(x))
            assert (err <= tol).all(), float((err - tol).max())

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), bits=st.sampled_from([2, 4]),
           scaling=st.sampled_from(["tensor", "row"]))
    def test_stochastic_rounding_unbiased(self, seed, bits, scaling):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(0, 1, (12,)), jnp.float32)
        sch = QScheme.int_symmetric(bits, scaling=scaling)
        keys = jax.random.split(jax.random.PRNGKey(seed), 1500)
        deq = jax.vmap(lambda k: quant.encode(x, sch, key=k).decode())(keys)
        se = np.asarray(deq.std(0)) / np.sqrt(1500) + 1e-6
        bias = np.abs(np.asarray(deq.mean(0)) - np.asarray(x))
        smax = float(np.max(np.asarray(quant.compute_scale(x, sch))))
        assert (bias < 6 * se + 1e-3 * smax).all(), bias.max()

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), bits=st.sampled_from([2, 4]))
    def test_ds_planes_each_unbiased(self, seed, bits):
        """§2.2: both double-sampling planes are themselves unbiased draws
        (they share the scale and base level, not the up/down bits)."""
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(0, 1, (12,)), jnp.float32)
        sch = QScheme.int_symmetric(bits, rounding="ds")
        keys = jax.random.split(jax.random.PRNGKey(seed), 1500)
        smax = float(np.max(np.asarray(quant.compute_scale(x, sch))))
        for plane in ("decode", "decode2"):
            deq = jax.vmap(
                lambda k: getattr(quant.encode(x, sch, key=k), plane)())(keys)
            se = np.asarray(deq.std(0)) / np.sqrt(1500) + 1e-6
            bias = np.abs(np.asarray(deq.mean(0)) - np.asarray(x))
            assert (bias < 6 * se + 1e-3 * smax).all(), (plane, bias.max())

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**31 - 1), scaling=st.sampled_from(SCALINGS),
           rounding=st.sampled_from(["nearest", "stochastic"]))
    def test_packed_int4_value_identical(self, seed, scaling, rounding):
        """Nibble packing is a pure storage transform: same key ⇒ identical
        dequantized values, identical logical nbytes, half the physical
        code bytes."""
        x = _grid_matrix(seed, rows=6, cols=16)
        key = jax.random.PRNGKey(seed)
        qu = quant.encode(x, QScheme.int_symmetric(
            4, scaling=scaling, rounding=rounding), key=key)
        qp = quant.encode(x, QScheme.int_symmetric(
            4, scaling=scaling, rounding=rounding, packed=True), key=key)
        np.testing.assert_array_equal(np.asarray(qu.decode()),
                                      np.asarray(qp.decode()))
        assert qp.nbytes == qu.nbytes
        assert qp.codes.size * 2 == qu.codes.size
        assert qp.codes.dtype == jnp.uint8

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_pack_unpack_roundtrip_exact(self, seed):
        rng = np.random.default_rng(seed)
        codes = jnp.asarray(rng.integers(-7, 8, (5, 10)), jnp.int8)
        np.testing.assert_array_equal(
            np.asarray(quant.unpack_int4(quant.pack_int4(codes))),
            np.asarray(codes, np.float32))


class TestBitplaneProperties:
    """The any-precision contract of ``layout='bitplane'`` storage: the
    round-trip error bound holds at every bit width × scaling family, a
    top-k plane slice decodes EXACTLY like quantizing directly at k bits
    (scale is bits-independent and the magnitude truncation nests), and the
    physical bytes are linear in the planes kept."""

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**31 - 1), bits=st.integers(1, 8),
           scaling=st.sampled_from(SCALINGS))
    def test_roundtrip_within_one_step(self, seed, bits, scaling):
        x = _grid_matrix(seed)
        qt = quant.encode(x, QScheme.bitplane(bits, scaling=scaling))
        assert qt.codes.dtype == jnp.uint32
        assert qt.shape == x.shape
        step = _bcast_scale(qt, x.shape) * 2.0 ** -bits
        err = np.abs(np.asarray(qt.decode()) - np.asarray(x))
        # truncation: one full step, plus fp32 rounding of mag·scale·2^-bits
        # (relative to |x| ≈ step·2^bits, hence the 1e-4 headroom at bits=8)
        tol = step * (1 + 1e-4) + 1e-7
        assert (err <= tol).all(), float((err - tol).max())

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**31 - 1), k=st.sampled_from([1, 2, 4, 8]),
           scaling=st.sampled_from(SCALINGS))
    def test_plane_slice_equals_direct_encode(self, seed, k, scaling):
        """slice_planes(k) of the 8-bit artifact ≡ encoding at k bits — the
        MLWeaving any-precision invariant, exact (not approximate)."""
        x = _grid_matrix(seed)
        full = quant.encode(x, QScheme.bitplane(8, scaling=scaling))
        direct = quant.encode(x, QScheme.bitplane(k, scaling=scaling))
        sliced = full.slice_planes(k)
        np.testing.assert_array_equal(np.asarray(sliced.codes),
                                      np.asarray(direct.codes))
        np.testing.assert_array_equal(np.asarray(sliced.decode()),
                                      np.asarray(direct.decode()))
        assert sliced.scheme.bits == k

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**31 - 1),
           scaling=st.sampled_from(["tensor", "channel"]))
    def test_nbytes_linear_in_planes(self, seed, scaling):
        """Code bytes of a k-bit slice are exactly (k+1)/(B+1) of the full
        artifact's — the byte-per-plane increment is constant."""
        x = _grid_matrix(seed)
        full = quant.encode(x, QScheme.bitplane(8, scaling=scaling))
        scale_b = np.asarray(full.scale).size * 4
        code_b = {k: full.slice_planes(k).nbytes - scale_b for k in range(1, 9)}
        per_plane = code_b[8] // 9
        assert code_b[8] == 9 * per_plane
        for k in range(1, 9):
            assert code_b[k] == (k + 1) * per_plane, (k, code_b)

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**31 - 1), d=st.integers(1, 70))
    def test_pack_unpack_bitplanes_roundtrip(self, seed, d):
        rng = np.random.default_rng(seed)
        planes = jnp.asarray(rng.integers(0, 2, (3, 4, d)), jnp.uint32)
        words = quant.pack_bitplanes(planes)
        assert words.dtype == jnp.uint32
        assert words.shape == (3, 4, -(-d // 32))
        np.testing.assert_array_equal(
            np.asarray(quant.unpack_bitplanes(words, d)), np.asarray(planes))


class TestOptimalLevelProperties:
    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**31 - 1), s=st.sampled_from([2, 3, 7]),
           n=st.integers(10, 300))
    def test_never_worse_than_uniform(self, seed, s, n):
        xs = np.clip(np.random.default_rng(seed).beta(0.7, 2.0, n), 0, 1)
        lv = optimal.optimal_levels_discretized(xs, s, M=64)
        assert (optimal.mean_variance(xs, lv)
                <= optimal.mean_variance(xs, optimal.uniform_levels(s)) + 1e-12)

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_monotone_in_levels(self, seed):
        xs = np.random.default_rng(seed).uniform(0, 1, 200)
        mvs = [optimal.mean_variance(
            xs, optimal.optimal_levels_discretized(xs, s, M=64))
            for s in (2, 4, 8)]
        assert mvs[0] >= mvs[1] >= mvs[2] - 1e-12


class TestDoubleSamplingProperties:
    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 24),
           batch=st.integers(1, 8))
    def test_unbiased_for_any_instance(self, seed, n, batch):
        rng = np.random.default_rng(seed)
        a = jnp.asarray(rng.normal(0, 1, (batch, n)), jnp.float32)
        x = jnp.asarray(rng.normal(0, 2, n), jnp.float32)
        b = jnp.asarray(rng.normal(0, 1, batch), jnp.float32)
        truth = lsq_gradient_fullprec(x, a, b)
        keys = jax.random.split(jax.random.PRNGKey(seed), 2000)
        gs = jax.vmap(lambda k: lsq_gradient_double_sampling(x, a, b, 3, k))(keys)
        se = np.asarray(gs.std(0)) / np.sqrt(2000) + 1e-6
        assert (np.abs(np.asarray(gs.mean(0) - truth)) < 6 * se + 1e-2).all()


class TestGradCompressionProperties:
    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**31 - 1), bits=st.sampled_from([2, 4, 8]),
           n=st.integers(1, 64))
    def test_roundtrip_bound(self, seed, bits, n):
        g = {"x": jnp.asarray(np.random.default_rng(seed).normal(0, 1, n),
                              jnp.float32)}
        comp, _ = gradcomp.compress_tree(g, bits, jax.random.PRNGKey(seed))
        deq = gradcomp.decompress_tree(comp)
        step = float(jnp.max(jnp.abs(g["x"]))) / (2 ** (bits - 1) - 1)
        assert float(jnp.max(jnp.abs(deq["x"] - g["x"]))) <= step + 1e-5

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_error_feedback_residual_bounded(self, seed):
        rng = np.random.default_rng(seed)
        g = {"x": jnp.asarray(rng.normal(0, 0.01, 8), jnp.float32)}
        err = gradcomp.init_error_feedback(g)
        for i in range(30):
            _, err = gradcomp.compress_tree(g, 2, jax.random.PRNGKey(i), error=err)
        # EF residual stays bounded by one quantization step of the running sum
        assert float(jnp.max(jnp.abs(err["x"]))) < 1.0


class TestShardingRuleProperties:
    @settings(**SETTINGS)
    @given(arch=st.sampled_from(["gemma-2b", "mixtral-8x7b", "mamba2-780m",
                                 "zamba2-2.7b"]))
    def test_specs_divide_mesh(self, arch):
        """Every param spec must divide the production mesh axis sizes."""
        from repro import configs
        from repro.launch.sharding import param_spec, _path_str
        from repro.models import transformer as T
        sizes = {"data": 16, "model": 16}
        cfg = configs.get_config(arch)
        params = T.param_specs(cfg)

        def check(path, leaf):
            spec = param_spec(path, leaf)
            for dim, part in zip(leaf.shape, spec):
                if part is None:
                    continue
                parts = part if isinstance(part, tuple) else (part,)
                total = int(np.prod([sizes[p] for p in parts]))
                assert dim % total == 0, (_path_str(path), leaf.shape, spec)

        jax.tree_util.tree_map_with_path(check, params)


class TestPipelineProperties:
    @settings(**SETTINGS)
    @given(seed=st.integers(0, 1000), step=st.integers(0, 50),
           vocab=st.integers(10, 1000))
    def test_cursor_determinism(self, seed, step, vocab):
        cfg = TokenStreamConfig(vocab_size=vocab, seq_len=8, global_batch=2,
                                seed=seed)
        s1 = TokenStream(cfg)
        s1.skip_to(Cursor(step=step))
        b1 = s1.next_batch()
        s2 = TokenStream(cfg)
        s2.skip_to(Cursor(step=step))
        b2 = s2.next_batch()
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        assert b1["tokens"].max() < vocab
