"""Fused quantized-AdamW op tests: Pallas-vs-jnp-mirror parity, ref-vs-
pallas backend agreement, seed-numerics pinning of the QTensor moment
encoding, unbiasedness, and NaN-skip semantics."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref, registry
from repro.optim import adamw
from repro.quant import QTensor

KEY = jax.random.PRNGKey(0)


def _leaf(r=96, c=160, seed=0):
    k = jax.random.PRNGKey(seed)
    master = jax.random.normal(k, (r, c))
    g = jax.random.normal(jax.random.fold_in(k, 1), (r, c)) * 0.1
    mc = jax.random.randint(jax.random.fold_in(k, 2), (r, c), -127, 128,
                            jnp.int8)
    vc = jax.random.randint(jax.random.fold_in(k, 3), (r, c), 0, 128,
                            jnp.int8)
    ms = jnp.abs(jax.random.normal(jax.random.fold_in(k, 4), (c,))) * 0.01 \
        + 1e-4
    vs = jnp.abs(jax.random.normal(jax.random.fold_in(k, 5), (c,))) * 0.01 \
        + 1e-4
    rand = jax.random.bits(jax.random.fold_in(k, 6), (r, c), jnp.uint32)
    return master, g, mc, ms, vc, vs, rand


OPK = dict(qmax=127, b1=0.9, b2=0.95, eps=1e-8, wd=0.1, lr=1e-3,
           b1c=0.1, b2c=0.05, clip=1.0, finite=1.0)


class TestKernelVsMirror:
    """The fused kernel against its jnp mirror (ref.quant_adamw_ref): the
    EMA's adds-of-products are subject to FMA contraction, so the pinned
    contract is one-ULP parity on floats + (near-)exact code agreement, not
    bitwise equality (see kernels/quant_adamw.py)."""

    @pytest.mark.parametrize("shape", [(96, 160), (256, 512), (100, 130)])
    def test_parity(self, shape):
        args = _leaf(*shape, seed=shape[0])
        out_k = ops.quant_adamw_update(*args, **OPK)
        out_r = ref.quant_adamw_ref(*args, **OPK)
        nm_k, mc_k, ms_k, vc_k, vs_k = [np.asarray(x) for x in out_k]
        nm_r, mc_r, ms_r, vc_r, vs_r = [np.asarray(x) for x in out_r]
        np.testing.assert_allclose(nm_k, nm_r, rtol=2e-6, atol=2e-6)
        np.testing.assert_allclose(ms_k, ms_r, rtol=1e-6)
        np.testing.assert_allclose(vs_k, vs_r, rtol=1e-6)
        for ck, cr in ((mc_k, mc_r), (vc_k, vc_r)):
            frac = (ck == cr).mean()
            assert frac >= 0.999, frac
            # disagreeing codes differ by at most one level (the Bernoulli
            # comparison flipped on a one-ULP threshold difference)
            assert np.abs(ck.astype(int) - cr.astype(int)).max() <= 1

    def test_nan_skip(self):
        master, g, mc, ms, vc, vs, rand = _leaf()
        g = g.at[0, 0].set(jnp.nan)
        kw = dict(OPK, finite=0.0)
        nm, mc2, msn, vc2, vsn = ops.quant_adamw_update(
            master, g, mc, ms, vc, vs, rand, **kw)
        # master untouched; moments re-encoded from the previous values
        np.testing.assert_array_equal(np.asarray(nm), np.asarray(master))
        m_prev = np.asarray(mc, np.float32) * np.asarray(ms)
        m_new = np.asarray(mc2, np.float32) * np.asarray(msn)
        step = np.asarray(msn)
        assert (np.abs(m_new - m_prev) <= step + 1e-7).all()
        assert np.isfinite(m_new).all()


class TestBackendDispatch:
    def _inputs(self):
        master, g, mc, ms, vc, vs, _ = _leaf()
        sch = adamw.moment_scheme(8, 2)
        return master, g, QTensor(mc, ms, sch), QTensor(vc, vs, sch)

    KW = dict(bits=8, b1=0.9, b2=0.95, eps=1e-8, b1c=jnp.float32(0.1),
              b2c=jnp.float32(0.05), lr=jnp.float32(1e-3),
              clip=jnp.float32(1.0), finite=jnp.bool_(True), wd=0.1)

    def test_masters_agree_across_backends(self):
        """The master update only consumes the *decoded* old moments + g —
        both backends compute it from identical inputs, so they agree to one
        ULP; only the stochastic re-encoding differs."""
        master, g, m_q, v_q = self._inputs()
        km, kv = jax.random.split(KEY)
        nm_r, mr, vr = registry.get("ref").quant_adamw_update(
            master, g, m_q, v_q, km, kv, **self.KW)
        nm_p, mp, vp = registry.get("pallas").quant_adamw_update(
            master, g, m_q, v_q, km, kv, **self.KW)
        np.testing.assert_allclose(np.asarray(nm_r), np.asarray(nm_p),
                                   rtol=2e-6, atol=2e-6)
        # stored moments: same values up to one quantization step
        for a, b in ((mr, mp), (vr, vp)):
            d = np.abs(np.asarray(a.decode()) - np.asarray(b.decode()))
            step = np.asarray(a.scale) + np.asarray(b.scale)
            assert (d <= step + 1e-7).all()
        assert mp.scale.shape == (master.shape[1],)

    def test_vector_leaves_fall_back(self):
        """1-D leaves (norms, biases) take the jnp path on both backends —
        identical keys ⇒ bit-identical results."""
        g = jax.random.normal(KEY, (64,)) * 0.1
        master = jax.random.normal(jax.random.fold_in(KEY, 1), (64,))
        sch = adamw.moment_scheme(8, 1)
        m_q = QTensor(jnp.zeros((64,), jnp.int8), jnp.ones((), jnp.float32), sch)
        km, kv = jax.random.split(KEY)
        kw = dict(self.KW, wd=0.0)
        outs = []
        for name in ("ref", "pallas"):
            nm, mq, vq = registry.get(name).quant_adamw_update(
                master, g, m_q, m_q, km, kv, **kw)
            outs.append((np.asarray(nm), np.asarray(mq.codes),
                         np.asarray(mq.scale)))
        for a, b in zip(outs[0], outs[1]):
            np.testing.assert_array_equal(a, b)

    def test_unbiased_reencoding(self):
        """E[decode(new m)] over keys ≈ the exact new m (C1 unbiasedness of
        the stochastic re-encode, both backends)."""
        master = jnp.zeros((8, 16))
        g = jax.random.normal(KEY, (8, 16)) * 0.1
        sch = adamw.moment_scheme(8, 2)
        m_q = QTensor(jnp.zeros((8, 16), jnp.int8), jnp.ones((16,)), sch)
        exact_m = 0.1 * np.asarray(g)          # (1-b1)·g from zero moments
        for name in ("ref", "pallas"):
            be = registry.get(name)

            def one(k):
                km, kv = jax.random.split(k)
                _, mq, _ = be.quant_adamw_update(
                    master, g, m_q, m_q, km, kv, **self.KW)
                return mq.decode()
            deqs = np.stack([np.asarray(one(k))
                             for k in jax.random.split(KEY, 512)])
            se = deqs.std(0) / np.sqrt(len(deqs)) + 1e-7
            np.testing.assert_array_less(np.abs(deqs.mean(0) - exact_m),
                                         6 * se + 1e-4, err_msg=name)


class TestSeedNumericsPinned:
    def test_encode_moment_matches_old_q_moment(self):
        """encode/decode_moment must reproduce the deleted inline _q_moment
        bit-for-bit (the pre-refactor seed numerics, re-implemented here as
        the oracle)."""
        def old_q_moment(x, bits, key, positive=False):
            from repro.quant.qtensor import stochastic_round
            qmax = float(2 ** (bits - 1) - 1)
            t0 = jnp.sqrt(x) if positive else x
            red_axis = tuple(range(x.ndim - 1)) if x.ndim > 1 else None
            absmax = jnp.max(jnp.abs(t0), axis=red_axis, keepdims=x.ndim > 1)
            scale = jnp.where(absmax == 0, 1.0, absmax / qmax)
            codes = stochastic_round(t0 / scale, key)
            lo_clip = 0.0 if positive else -qmax
            return (jnp.clip(codes, lo_clip, qmax).astype(jnp.int8),
                    scale.astype(jnp.float32))

        for positive, seed in [(False, 0), (True, 1)]:
            x = jnp.abs(jax.random.normal(jax.random.PRNGKey(seed), (32, 48))) \
                if positive else \
                jax.random.normal(jax.random.PRNGKey(seed), (32, 48))
            k = jax.random.fold_in(KEY, seed)
            with registry.using("ref"):
                qt = adamw.encode_moment(x, 8, k, positive=positive)
            old_codes, old_scale = old_q_moment(x, 8, k, positive=positive)
            np.testing.assert_array_equal(np.asarray(qt.codes),
                                          np.asarray(old_codes))
            np.testing.assert_allclose(np.asarray(qt.scale).reshape(-1),
                                       np.asarray(old_scale).reshape(-1))
            deq = adamw.decode_moment(qt, positive=positive)
            old_deq = old_codes.astype(jnp.float32) * old_scale
            if positive:
                old_deq = old_deq * old_deq
            np.testing.assert_array_equal(np.asarray(deq), np.asarray(old_deq))

    def test_momentq_alias_warns_and_builds_qtensor(self):
        with pytest.warns(DeprecationWarning):
            q = adamw.MomentQ(jnp.zeros((4, 4), jnp.int8), 1.0)
        assert isinstance(q, QTensor)


class TestQuantizedTraining:
    @pytest.mark.parametrize("backend", ["ref", "pallas"])
    def test_quadratic_converges(self, backend):
        """int8 moments on a least-squares problem: loss drops >100× under
        both backends (the fused path trains, not just matches shapes)."""
        w_star = jnp.linspace(-1, 1, 128).reshape(8, 16)
        cfg = adamw.AdamWConfig(lr=0.05, moment_bits=8, weight_decay=0.0,
                                warmup_steps=1, decay_steps=200)
        params = {"w": jnp.zeros((8, 16)), "b": jnp.zeros((16,))}
        st = adamw.init(params, cfg)

        def loss(p):
            return 0.5 * jnp.mean((p["w"] + p["b"] - w_star) ** 2)

        with registry.using(backend):
            @jax.jit
            def step(p, s, k):
                g = jax.grad(loss)(p)
                return adamw.apply_updates(p, g, s, cfg, key=k)
            l0 = float(loss(params))
            for i in range(200):
                params, st, _ = step(params, st, jax.random.fold_in(KEY, i))
        l1 = float(loss(params))
        assert l1 < l0 / 100, (l0, l1)
        m_leaf = jax.tree.leaves(
            st.m, is_leaf=lambda x: isinstance(x, QTensor))[0]
        assert m_leaf.codes.dtype == jnp.int8


class TestHbmByteModel:
    def test_fused_moves_fewer_bytes(self):
        from benchmarks.bench_train_step import opt_sweep_bytes
        n = 1 << 20
        fused = opt_sweep_bytes(n, bits=8, fused=True)
        unfused = opt_sweep_bytes(n, bits=8, fused=False)
        assert fused < unfused
        # the unfused path materializes both fp32 moments twice (decode out,
        # re-encode in) — the fused one never writes them
        assert unfused - fused >= 2 * 4 * n


def test_registry_exposes_op():
    for name in ("ref", "pallas"):
        assert hasattr(registry.get(name), "quant_adamw_update")
