"""Serving-engine tests: paged-attention parity (ref + Pallas, bf16/int8/int4),
paged-vs-ring bit-exactness, engine-vs-legacy equivalence, and the scheduler
invariants (no page leaks, every admitted request finishes, outputs
independent of batch composition, preemption recovers)."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.kernels import registry
from repro.kernels import ref as kref
from repro.models import attention as attn
from repro.models import transformer as T
from repro.quant import PrecisionPlan, encode
from repro.serve import PageAllocator, Request, ServeEngine
from repro.serve import pages as pg

KEY = jax.random.PRNGKey(0)


def _mk_qkv(b, s, h, g, d, key=KEY):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, s, h, d), jnp.float32)
    k = jax.random.normal(kk, (b, s, g, d), jnp.float32)
    v = jax.random.normal(kv, (b, s, g, d), jnp.float32)
    return q, k, v


def _pool_from_rows(k, v, page, n_pages, kv_bits):
    """Pack per-sequence rows (B, S, G, D) into a single-layer pool with an
    in-order block table (page i of seq b = rows [i·page, (i+1)·page))."""
    b, s, g, d = k.shape
    maxp = -(-s // page)
    pool = pg.init_pool(1, n_pages, page, g, d, kv_bits=kv_bits)
    bt = np.zeros((b, maxp), np.int32)
    nxt = 1                                    # page 0 is the null page
    for i in range(b):
        ids = list(range(nxt, nxt + maxp))
        nxt += maxp
        pool = pg.write_prompt(pool, k[i][None], v[i][None],
                               jnp.asarray(ids, jnp.int32))
        bt[i] = ids
    return pool, jnp.asarray(bt)


class TestPagedAttentionParity:
    @pytest.mark.parametrize("kv_bits", [8, 4])
    def test_paged_vs_chunked_fp32_reference(self, kv_bits):
        """Quantized paged decode ≈ full-precision chunked_attention on the
        last query position, within the quantization tolerance."""
        b, s, h, g, d = 2, 24, 4, 2, 16
        q, k, v = _mk_qkv(b, s, h, g, d)
        spec = attn.AttnSpec(n_heads=h, n_kv_heads=g, head_dim=d, q_chunk=8)
        ref_full = attn.chunked_attention(q, k, v, spec)[:, -1]     # (B, H, D)

        pool, bt = _pool_from_rows(k, v, page=8, n_pages=16, kv_bits=kv_bits)
        lens = jnp.full((b,), s, jnp.int32)
        out = kref.paged_attention_ref(
            q[:, -1], pool.k_pages[0], pool.v_pages[0],
            None if pool.k_scale is None else pool.k_scale[0],
            None if pool.v_scale is None else pool.v_scale[0],
            bt, lens, softmax_scale=spec.scale)
        rel = float(jnp.linalg.norm(out.astype(jnp.float32) - ref_full)
                    / jnp.linalg.norm(ref_full))
        assert rel < (0.05 if kv_bits == 8 else 0.2), rel

    def test_paged_bf16_bitexact_vs_ring(self):
        """bf16 paged decode == ring-buffer decode bit-for-bit: with pages
        laid out in ring order the gathered tensor IS the ring tensor, and
        the ref backend runs the identical decode_attention on it."""
        b, s, h, g, d, page = 2, 16, 4, 2, 16, 8
        q, k, v = _mk_qkv(b, s, h, g, d)
        kb = k.astype(jnp.bfloat16)
        vb = v.astype(jnp.bfloat16)
        spec = attn.AttnSpec(n_heads=h, n_kv_heads=g, head_dim=d)
        lens = jnp.asarray([s, s - 5], jnp.int32)

        ring = attn.decode_attention(q[:, -1:].astype(jnp.bfloat16), kb, vb,
                                     spec, kv_len=lens)[:, 0]
        pool, bt = _pool_from_rows(kb, vb, page=page, n_pages=8, kv_bits=0)
        paged = kref.paged_attention_ref(
            q[:, -1].astype(jnp.bfloat16), pool.k_pages[0], pool.v_pages[0],
            None, None, bt, lens, softmax_scale=spec.scale)
        np.testing.assert_array_equal(np.asarray(paged, np.float32),
                                      np.asarray(ring, np.float32))

    @pytest.mark.parametrize("kv_bits", [8, 4])
    def test_paged_quantized_bitexact_vs_ring(self, kv_bits):
        """int8/int4 pages hold the same codes as the ring cache (same
        row-nearest scheme) and dequantize to the same bf16 rows."""
        b, s, g, d = 2, 16, 2, 16
        _, k, v = _mk_qkv(b, s, 4, g, d)
        ring = attn.prefill_cache_from_kv(k, v, kv_bits=kv_bits)
        ring_k, ring_v = ring.materialize()
        pool, bt = _pool_from_rows(k, v, page=8, n_pages=8, kv_bits=kv_bits)
        paged_k = kref.dequant_pages_ref(
            kref.gather_pages_ref(pool.k_pages[0], bt),
            kref.gather_pages_ref(pool.k_scale[0], bt))
        paged_v = kref.dequant_pages_ref(
            kref.gather_pages_ref(pool.v_pages[0], bt),
            kref.gather_pages_ref(pool.v_scale[0], bt))
        np.testing.assert_array_equal(np.asarray(paged_k, np.float32),
                                      np.asarray(ring_k, np.float32))
        np.testing.assert_array_equal(np.asarray(paged_v, np.float32),
                                      np.asarray(ring_v, np.float32))

    @pytest.mark.parametrize("kv_bits", [0, 8, 4])
    def test_pallas_matches_ref(self, kv_bits):
        """The Pallas flash kernel ≈ the gather-ref backend on active rows
        (f32 streaming softmax vs one-shot bf16 softmax associativity)."""
        rng = np.random.default_rng(0)
        b, h, g, d, page, maxp, n_pages = 3, 4, 2, 16, 8, 4, 12
        q = jnp.asarray(rng.normal(0, 1, (b, h, d)), jnp.float32)
        lens = jnp.asarray([17, 3, 29], jnp.int32)
        bt = jnp.asarray(rng.integers(1, n_pages, (b, maxp)), jnp.int32)
        kv = rng.normal(0, 1, (2, n_pages, page, g, d)).astype(np.float32)
        if kv_bits:
            sch = pg.kv_scheme(kv_bits)
            qk = encode(jnp.asarray(kv[0]), sch)
            qv = encode(jnp.asarray(kv[1]), sch)
            args = (qk.codes, qv.codes, qk.scale, qv.scale)
        else:
            args = (jnp.asarray(kv[0], jnp.bfloat16),
                    jnp.asarray(kv[1], jnp.bfloat16), None, None)
        r = registry.get("ref").paged_attention(
            q, *args, bt, lens, softmax_scale=d ** -0.5)
        p = registry.get("pallas").paged_attention(
            q, *args, bt, lens, softmax_scale=d ** -0.5)
        err = float(jnp.max(jnp.abs(r.astype(jnp.float32)
                                    - p.astype(jnp.float32))))
        assert err < 2e-2, err


def _cfg():
    return configs.get_reduced("qwen2.5-14b")


def _params(cfg):
    return T.init_params(KEY, cfg)


def _legacy_greedy(params, cfg, prompts, gen):
    """The ring-buffer greedy loop (what launch/serve.serve runs)."""
    from repro.launch.steps import make_serve_step

    s = prompts.shape[1]
    logits, state = T.prefill(params, prompts, cfg, pad_to=s + gen)
    toks = [jnp.argmax(logits, -1).astype(jnp.int32)[:, None]]
    step = jax.jit(make_serve_step(cfg))
    for _ in range(gen - 1):
        _, nxt, state = step(params, state, toks[-1])
        toks.append(nxt[:, None])
    return np.asarray(jnp.concatenate(toks, 1))


class TestEngineParity:
    @pytest.mark.parametrize("kv_bits", [0, 8])
    def test_engine_matches_legacy_greedy(self, kv_bits):
        """Paged engine greedy tokens == ring-buffer loop tokens (the codes
        are identical; only the cache layout changed)."""
        cfg = _cfg()
        params = _params(cfg)
        b, s, gen = 3, 12, 6
        prompts = jax.random.randint(jax.random.fold_in(KEY, 1), (b, s), 0,
                                     cfg.vocab_size)
        cfgp = dataclasses.replace(cfg,
                                   precision=PrecisionPlan(kv_bits=kv_bits))
        legacy = _legacy_greedy(params, cfgp, prompts, gen)
        eng = ServeEngine(params, cfg, plan=PrecisionPlan(kv_bits=kv_bits),
                          max_slots=b, page_size=8, max_seq_len=s + gen + 8)
        out = eng.run([Request(rid=i, prompt=np.asarray(prompts[i]),
                               max_new_tokens=gen) for i in range(b)])
        got = np.stack([out[i].tokens[s:] for i in range(b)])
        np.testing.assert_array_equal(got, legacy)

    @pytest.mark.slow
    def test_engine_int4_pallas_first_steps_match_ref(self):
        """int4 KV through the Pallas kernel: the prefill token and the
        first decode-step token match the ref backend exactly (one kernel
        call's numerics), and the run completes leak-free. Full-trajectory
        token equality is NOT asserted — flash (f32 streaming) vs one-shot
        (bf16) softmax differ at float granularity, which random-weight
        tiny-vocab models amplify into argmax flips after a few steps."""
        cfg = _cfg()
        params = _params(cfg)
        b, s, gen = 2, 8, 5
        prompts = jax.random.randint(jax.random.fold_in(KEY, 2), (b, s), 0,
                                     cfg.vocab_size)
        runs = {}
        for backend in ("ref", "pallas"):
            eng = ServeEngine(params, cfg, plan=PrecisionPlan(kv_bits=4),
                              max_slots=b, page_size=8,
                              max_seq_len=s + gen + 8, backend=backend)
            out = eng.run([Request(rid=i, prompt=np.asarray(prompts[i]),
                                   max_new_tokens=gen) for i in range(b)])
            eng.allocator.check_leaks(0)
            runs[backend] = np.stack([out[i].tokens[s:] for i in range(b)])
        np.testing.assert_array_equal(runs["pallas"][:, :2],
                                      runs["ref"][:, :2])


class TestSchedulerInvariants:
    def _mixed_requests(self, cfg, n, seed=0, **kw):
        rng = np.random.default_rng(seed)
        return [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            int(rng.integers(3, 20))),
                        max_new_tokens=int(rng.integers(2, 10)), **kw)
                for i in range(n)]

    @pytest.mark.parametrize("kv_bits", [0, 4])
    def test_all_finish_no_leaks(self, kv_bits):
        cfg = _cfg()
        eng = ServeEngine(_params(cfg), cfg, plan=PrecisionPlan(kv_bits=kv_bits),
                          max_slots=4, page_size=8, max_seq_len=48)
        reqs = self._mixed_requests(cfg, 8)
        out = eng.run(reqs)
        assert sorted(out) == list(range(8))
        for f in out.values():
            assert 1 <= f.n_generated <= 10
            assert f.reason in ("eos", "length")
            assert f.tokens.shape == (f.prompt_len + f.n_generated,)
        eng.allocator.check_leaks(0)          # raises on leaked pages

    @pytest.mark.slow
    def test_outputs_independent_of_batch_composition(self):
        """Every request (greedy and sampled) produces the same tokens
        served solo as in a churning mixed batch."""
        cfg = _cfg()
        params = _params(cfg)
        rng = np.random.default_rng(3)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size, 4 + 3 * i),
                        max_new_tokens=5,
                        temperature=0.8 if i % 2 else 0.0,
                        top_k=5 if i % 2 else 0, seed=7)
                for i in range(4)]
        mixed = ServeEngine(params, cfg, plan=PrecisionPlan(kv_bits=8),
                            max_slots=4, page_size=8,
                            max_seq_len=64).run(reqs)
        for r in reqs:
            solo = ServeEngine(params, cfg, plan=PrecisionPlan(kv_bits=8),
                               max_slots=1, page_size=8,
                               max_seq_len=64).run([r])
            np.testing.assert_array_equal(solo[r.rid].tokens,
                                          mixed[r.rid].tokens)

    def test_preemption_recovers_and_frees(self):
        """reserve='none' + a pool too small for everyone: the engine must
        preempt, replay, and still finish every request leak-free."""
        cfg = _cfg()
        eng = ServeEngine(_params(cfg), cfg, plan=PrecisionPlan(kv_bits=8),
                          max_slots=3, page_size=4, max_seq_len=32,
                          n_pages=8, reserve="none")
        rng = np.random.default_rng(4)
        reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 6),
                        max_new_tokens=8) for i in range(4)]
        out = eng.run(reqs)
        assert sorted(out) == list(range(4))
        assert eng.stats["preemptions"] >= 1
        for f in out.values():
            assert f.n_generated == 8
        eng.allocator.check_leaks(0)

    @pytest.mark.slow
    def test_preemption_replay_exact_at_quantized_kv(self):
        """Recompute preemption must not change a request's greedy output
        even at int4 KV: replay rebuilds the quantized pages through the
        same decode path that produced them (re-prefilling generated tokens
        as prompt would read full-precision K/V and diverge)."""
        cfg = _cfg()
        params = _params(cfg)
        rng = np.random.default_rng(7)
        prompts = [rng.integers(0, cfg.vocab_size, 6) for _ in range(4)]
        reqs = [Request(rid=i, prompt=p, max_new_tokens=16)
                for i, p in enumerate(prompts)]
        tight = ServeEngine(params, cfg, plan=PrecisionPlan(kv_bits=4),
                            max_slots=3, page_size=4, max_seq_len=32,
                            n_pages=9, reserve="none")
        out = tight.run(reqs)
        assert tight.stats["preemptions"] >= 1
        tight.allocator.check_leaks(0)
        for r in reqs:
            solo = ServeEngine(params, cfg, plan=PrecisionPlan(kv_bits=4),
                               max_slots=1, page_size=4,
                               max_seq_len=32).run([r])
            np.testing.assert_array_equal(solo[r.rid].tokens,
                                          out[r.rid].tokens)

    def test_eos_stops_early(self):
        cfg = _cfg()
        params = _params(cfg)
        probe = ServeEngine(params, cfg, max_slots=1, page_size=8,
                            max_seq_len=32)
        prompt = np.arange(5) % cfg.vocab_size
        first = probe.run([Request(rid=0, prompt=prompt, max_new_tokens=1)])
        eos = int(first[0].tokens[-1])        # greedy ⇒ reproduced below
        eng = ServeEngine(params, cfg, max_slots=1, page_size=8,
                          max_seq_len=32)
        out = eng.run([Request(rid=0, prompt=prompt, max_new_tokens=10,
                               eos_id=eos)])
        assert out[0].reason == "eos"
        assert out[0].n_generated == 1
        eng.allocator.check_leaks(0)

    def test_unsupported_family_raises(self):
        cfg = configs.get_reduced("mamba2-780m")
        with pytest.raises(ValueError, match="SSM"):
            ServeEngine({}, cfg)

    def test_oversized_request_rejected(self):
        cfg = _cfg()
        eng = ServeEngine(_params(cfg), cfg, max_slots=1, page_size=4,
                          max_seq_len=16)
        with pytest.raises(ValueError, match="max_seq_len"):
            eng.submit(Request(rid=0, prompt=np.zeros(20, np.int32)))


class TestPageAllocator:
    def test_null_page_reserved(self):
        a = PageAllocator(4)
        got = a.alloc(3)
        assert got is not None and 0 not in got
        assert a.alloc(1) is None             # exhausted, no partial alloc
        a.free(got)
        assert a.n_free == 3
        with pytest.raises(ValueError, match="null page"):
            a.free([0])

    def test_double_free_rejected(self):
        a = PageAllocator(4)
        (pid,) = a.alloc(1)
        a.free([pid])
        with pytest.raises(ValueError, match="double free"):
            a.free([pid])

    def test_leak_check(self):
        a = PageAllocator(4)
        a.alloc(2)
        with pytest.raises(AssertionError, match="leak"):
            a.check_leaks(0)


class TestKVBytesAccounting:
    def test_pool_nbytes_ratios(self):
        """QTensor.nbytes accounting: int8 ≈ 2× and packed int4 ≥ 3× fewer
        KV bytes than bf16 at head_dim 64 (scales included)."""
        kw = dict(n_layers=2, n_pages=8, page_size=8, n_kv=2, head_dim=64)
        nb = {bits: pg.pool_nbytes(pg.init_pool(**kw, kv_bits=bits))
              for bits in (0, 8, 4)}
        assert nb[0] / nb[8] >= 1.8
        assert nb[0] / nb[4] >= 3.0
