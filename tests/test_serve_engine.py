"""Serving-engine tests: paged-attention parity (ref + Pallas, bf16/int8/int4),
paged-vs-ring bit-exactness, engine-vs-legacy equivalence, the scheduler
invariants (no page leaks, every admitted request finishes, outputs
independent of batch composition, preemption recovers), prefix-sharing
copy-on-write invariants, chunked prefill, and the scheduler-clock /
autoscaler-deferral regressions."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.kernels import registry
from repro.kernels import ref as kref
from repro.models import attention as attn
from repro.models import transformer as T
from repro.quant import PrecisionPlan, encode
from repro.serve import PageAllocator, PrefixCache, Request, ServeEngine
from repro.serve import pages as pg

KEY = jax.random.PRNGKey(0)


def _mk_qkv(b, s, h, g, d, key=KEY):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, s, h, d), jnp.float32)
    k = jax.random.normal(kk, (b, s, g, d), jnp.float32)
    v = jax.random.normal(kv, (b, s, g, d), jnp.float32)
    return q, k, v


def _pool_from_rows(k, v, page, n_pages, kv_bits):
    """Pack per-sequence rows (B, S, G, D) into a single-layer pool with an
    in-order block table (page i of seq b = rows [i·page, (i+1)·page))."""
    b, s, g, d = k.shape
    maxp = -(-s // page)
    pool = pg.init_pool(1, n_pages, page, g, d, kv_bits=kv_bits)
    bt = np.zeros((b, maxp), np.int32)
    nxt = 1                                    # page 0 is the null page
    for i in range(b):
        ids = list(range(nxt, nxt + maxp))
        nxt += maxp
        pool = pg.write_prompt(pool, k[i][None], v[i][None],
                               jnp.asarray(ids, jnp.int32))
        bt[i] = ids
    return pool, jnp.asarray(bt)


class TestPagedAttentionParity:
    @pytest.mark.parametrize("kv_bits", [8, 4])
    def test_paged_vs_chunked_fp32_reference(self, kv_bits):
        """Quantized paged decode ≈ full-precision chunked_attention on the
        last query position, within the quantization tolerance."""
        b, s, h, g, d = 2, 24, 4, 2, 16
        q, k, v = _mk_qkv(b, s, h, g, d)
        spec = attn.AttnSpec(n_heads=h, n_kv_heads=g, head_dim=d, q_chunk=8)
        ref_full = attn.chunked_attention(q, k, v, spec)[:, -1]     # (B, H, D)

        pool, bt = _pool_from_rows(k, v, page=8, n_pages=16, kv_bits=kv_bits)
        lens = jnp.full((b,), s, jnp.int32)
        out = kref.paged_attention_ref(
            q[:, -1], pool.k_pages[0], pool.v_pages[0],
            None if pool.k_scale is None else pool.k_scale[0],
            None if pool.v_scale is None else pool.v_scale[0],
            bt, lens, softmax_scale=spec.scale)
        rel = float(jnp.linalg.norm(out.astype(jnp.float32) - ref_full)
                    / jnp.linalg.norm(ref_full))
        assert rel < (0.05 if kv_bits == 8 else 0.2), rel

    def test_paged_bf16_bitexact_vs_ring(self):
        """bf16 paged decode == ring-buffer decode bit-for-bit: with pages
        laid out in ring order the gathered tensor IS the ring tensor, and
        the ref backend runs the identical decode_attention on it."""
        b, s, h, g, d, page = 2, 16, 4, 2, 16, 8
        q, k, v = _mk_qkv(b, s, h, g, d)
        kb = k.astype(jnp.bfloat16)
        vb = v.astype(jnp.bfloat16)
        spec = attn.AttnSpec(n_heads=h, n_kv_heads=g, head_dim=d)
        lens = jnp.asarray([s, s - 5], jnp.int32)

        ring = attn.decode_attention(q[:, -1:].astype(jnp.bfloat16), kb, vb,
                                     spec, kv_len=lens)[:, 0]
        pool, bt = _pool_from_rows(kb, vb, page=page, n_pages=8, kv_bits=0)
        paged = kref.paged_attention_ref(
            q[:, -1].astype(jnp.bfloat16), pool.k_pages[0], pool.v_pages[0],
            None, None, bt, lens, softmax_scale=spec.scale)
        np.testing.assert_array_equal(np.asarray(paged, np.float32),
                                      np.asarray(ring, np.float32))

    @pytest.mark.parametrize("kv_bits", [8, 4])
    def test_paged_quantized_bitexact_vs_ring(self, kv_bits):
        """int8/int4 pages hold the same codes as the ring cache (same
        row-nearest scheme) and dequantize to the same bf16 rows."""
        b, s, g, d = 2, 16, 2, 16
        _, k, v = _mk_qkv(b, s, 4, g, d)
        ring = attn.prefill_cache_from_kv(k, v, kv_bits=kv_bits)
        ring_k, ring_v = ring.materialize()
        pool, bt = _pool_from_rows(k, v, page=8, n_pages=8, kv_bits=kv_bits)
        paged_k = kref.dequant_pages_ref(
            kref.gather_pages_ref(pool.k_pages[0], bt),
            kref.gather_pages_ref(pool.k_scale[0], bt))
        paged_v = kref.dequant_pages_ref(
            kref.gather_pages_ref(pool.v_pages[0], bt),
            kref.gather_pages_ref(pool.v_scale[0], bt))
        np.testing.assert_array_equal(np.asarray(paged_k, np.float32),
                                      np.asarray(ring_k, np.float32))
        np.testing.assert_array_equal(np.asarray(paged_v, np.float32),
                                      np.asarray(ring_v, np.float32))

    @pytest.mark.parametrize("kv_bits", [0, 8, 4])
    def test_pallas_matches_ref(self, kv_bits):
        """The Pallas flash kernel ≈ the gather-ref backend on active rows
        (f32 streaming softmax vs one-shot bf16 softmax associativity)."""
        rng = np.random.default_rng(0)
        b, h, g, d, page, maxp, n_pages = 3, 4, 2, 16, 8, 4, 12
        q = jnp.asarray(rng.normal(0, 1, (b, h, d)), jnp.float32)
        lens = jnp.asarray([17, 3, 29], jnp.int32)
        bt = jnp.asarray(rng.integers(1, n_pages, (b, maxp)), jnp.int32)
        kv = rng.normal(0, 1, (2, n_pages, page, g, d)).astype(np.float32)
        if kv_bits:
            sch = pg.kv_scheme(kv_bits)
            qk = encode(jnp.asarray(kv[0]), sch)
            qv = encode(jnp.asarray(kv[1]), sch)
            args = (qk.codes, qv.codes, qk.scale, qv.scale)
        else:
            args = (jnp.asarray(kv[0], jnp.bfloat16),
                    jnp.asarray(kv[1], jnp.bfloat16), None, None)
        r = registry.get("ref").paged_attention(
            q, *args, bt, lens, softmax_scale=d ** -0.5)
        p = registry.get("pallas").paged_attention(
            q, *args, bt, lens, softmax_scale=d ** -0.5)
        err = float(jnp.max(jnp.abs(r.astype(jnp.float32)
                                    - p.astype(jnp.float32))))
        assert err < 2e-2, err


def _cfg():
    return configs.get_reduced("qwen2.5-14b")


def _params(cfg):
    return T.init_params(KEY, cfg)


def _legacy_greedy(params, cfg, prompts, gen):
    """The ring-buffer greedy loop (what launch/serve.serve runs)."""
    from repro.launch.steps import make_serve_step

    s = prompts.shape[1]
    logits, state = T.prefill(params, prompts, cfg, pad_to=s + gen)
    toks = [jnp.argmax(logits, -1).astype(jnp.int32)[:, None]]
    step = jax.jit(make_serve_step(cfg))
    for _ in range(gen - 1):
        _, nxt, state = step(params, state, toks[-1])
        toks.append(nxt[:, None])
    return np.asarray(jnp.concatenate(toks, 1))


class TestEngineParity:
    @pytest.mark.parametrize("kv_bits", [0, 8])
    def test_engine_matches_legacy_greedy(self, kv_bits):
        """Paged engine greedy tokens == ring-buffer loop tokens (the codes
        are identical; only the cache layout changed)."""
        cfg = _cfg()
        params = _params(cfg)
        b, s, gen = 3, 12, 6
        prompts = jax.random.randint(jax.random.fold_in(KEY, 1), (b, s), 0,
                                     cfg.vocab_size)
        cfgp = dataclasses.replace(cfg,
                                   precision=PrecisionPlan(kv_bits=kv_bits))
        legacy = _legacy_greedy(params, cfgp, prompts, gen)
        eng = ServeEngine(params, cfg, plan=PrecisionPlan(kv_bits=kv_bits),
                          max_slots=b, page_size=8, max_seq_len=s + gen + 8)
        out = eng.run([Request(rid=i, prompt=np.asarray(prompts[i]),
                               max_new_tokens=gen) for i in range(b)])
        got = np.stack([out[i].tokens[s:] for i in range(b)])
        np.testing.assert_array_equal(got, legacy)

    @pytest.mark.slow
    def test_engine_int4_pallas_first_steps_match_ref(self):
        """int4 KV through the Pallas kernel: the prefill token and the
        first decode-step token match the ref backend exactly (one kernel
        call's numerics), and the run completes leak-free. Full-trajectory
        token equality is NOT asserted — flash (f32 streaming) vs one-shot
        (bf16) softmax differ at float granularity, which random-weight
        tiny-vocab models amplify into argmax flips after a few steps."""
        cfg = _cfg()
        params = _params(cfg)
        b, s, gen = 2, 8, 5
        prompts = jax.random.randint(jax.random.fold_in(KEY, 2), (b, s), 0,
                                     cfg.vocab_size)
        runs = {}
        for backend in ("ref", "pallas"):
            eng = ServeEngine(params, cfg, plan=PrecisionPlan(kv_bits=4),
                              max_slots=b, page_size=8,
                              max_seq_len=s + gen + 8, backend=backend)
            out = eng.run([Request(rid=i, prompt=np.asarray(prompts[i]),
                                   max_new_tokens=gen) for i in range(b)])
            eng.allocator.check_leaks(0)
            runs[backend] = np.stack([out[i].tokens[s:] for i in range(b)])
        np.testing.assert_array_equal(runs["pallas"][:, :2],
                                      runs["ref"][:, :2])


class TestSchedulerInvariants:
    def _mixed_requests(self, cfg, n, seed=0, **kw):
        rng = np.random.default_rng(seed)
        return [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            int(rng.integers(3, 20))),
                        max_new_tokens=int(rng.integers(2, 10)), **kw)
                for i in range(n)]

    @pytest.mark.parametrize("kv_bits", [0, 4])
    def test_all_finish_no_leaks(self, kv_bits):
        cfg = _cfg()
        eng = ServeEngine(_params(cfg), cfg, plan=PrecisionPlan(kv_bits=kv_bits),
                          max_slots=4, page_size=8, max_seq_len=48)
        reqs = self._mixed_requests(cfg, 8)
        out = eng.run(reqs)
        assert sorted(out) == list(range(8))
        for f in out.values():
            assert 1 <= f.n_generated <= 10
            assert f.reason in ("eos", "length")
            assert f.tokens.shape == (f.prompt_len + f.n_generated,)
        eng.allocator.check_leaks(0)          # raises on leaked pages

    @pytest.mark.slow
    def test_outputs_independent_of_batch_composition(self):
        """Every request (greedy and sampled) produces the same tokens
        served solo as in a churning mixed batch."""
        cfg = _cfg()
        params = _params(cfg)
        rng = np.random.default_rng(3)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size, 4 + 3 * i),
                        max_new_tokens=5,
                        temperature=0.8 if i % 2 else 0.0,
                        top_k=5 if i % 2 else 0, seed=7)
                for i in range(4)]
        mixed = ServeEngine(params, cfg, plan=PrecisionPlan(kv_bits=8),
                            max_slots=4, page_size=8,
                            max_seq_len=64).run(reqs)
        for r in reqs:
            solo = ServeEngine(params, cfg, plan=PrecisionPlan(kv_bits=8),
                               max_slots=1, page_size=8,
                               max_seq_len=64).run([r])
            np.testing.assert_array_equal(solo[r.rid].tokens,
                                          mixed[r.rid].tokens)

    def test_preemption_recovers_and_frees(self):
        """reserve='none' + a pool too small for everyone: the engine must
        preempt, replay, and still finish every request leak-free."""
        cfg = _cfg()
        eng = ServeEngine(_params(cfg), cfg, plan=PrecisionPlan(kv_bits=8),
                          max_slots=3, page_size=4, max_seq_len=32,
                          n_pages=8, reserve="none")
        rng = np.random.default_rng(4)
        reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 6),
                        max_new_tokens=8) for i in range(4)]
        out = eng.run(reqs)
        assert sorted(out) == list(range(4))
        assert eng.stats["preemptions"] >= 1
        for f in out.values():
            assert f.n_generated == 8
        eng.allocator.check_leaks(0)

    @pytest.mark.slow
    def test_preemption_replay_exact_at_quantized_kv(self):
        """Recompute preemption must not change a request's greedy output
        even at int4 KV: replay rebuilds the quantized pages through the
        same decode path that produced them (re-prefilling generated tokens
        as prompt would read full-precision K/V and diverge)."""
        cfg = _cfg()
        params = _params(cfg)
        rng = np.random.default_rng(7)
        prompts = [rng.integers(0, cfg.vocab_size, 6) for _ in range(4)]
        reqs = [Request(rid=i, prompt=p, max_new_tokens=16)
                for i, p in enumerate(prompts)]
        tight = ServeEngine(params, cfg, plan=PrecisionPlan(kv_bits=4),
                            max_slots=3, page_size=4, max_seq_len=32,
                            n_pages=9, reserve="none")
        out = tight.run(reqs)
        assert tight.stats["preemptions"] >= 1
        tight.allocator.check_leaks(0)
        for r in reqs:
            solo = ServeEngine(params, cfg, plan=PrecisionPlan(kv_bits=4),
                               max_slots=1, page_size=4,
                               max_seq_len=32).run([r])
            np.testing.assert_array_equal(solo[r.rid].tokens,
                                          out[r.rid].tokens)

    def test_eos_stops_early(self):
        cfg = _cfg()
        params = _params(cfg)
        probe = ServeEngine(params, cfg, max_slots=1, page_size=8,
                            max_seq_len=32)
        prompt = np.arange(5) % cfg.vocab_size
        first = probe.run([Request(rid=0, prompt=prompt, max_new_tokens=1)])
        eos = int(first[0].tokens[-1])        # greedy ⇒ reproduced below
        eng = ServeEngine(params, cfg, max_slots=1, page_size=8,
                          max_seq_len=32)
        out = eng.run([Request(rid=0, prompt=prompt, max_new_tokens=10,
                               eos_id=eos)])
        assert out[0].reason == "eos"
        assert out[0].n_generated == 1
        eng.allocator.check_leaks(0)

    def test_unsupported_family_raises(self):
        cfg = configs.get_reduced("mamba2-780m")
        with pytest.raises(ValueError, match="SSM"):
            ServeEngine({}, cfg)

    def test_oversized_request_rejected(self):
        cfg = _cfg()
        eng = ServeEngine(_params(cfg), cfg, max_slots=1, page_size=4,
                          max_seq_len=16)
        with pytest.raises(ValueError, match="max_seq_len"):
            eng.submit(Request(rid=0, prompt=np.zeros(20, np.int32)))


class TestPageAllocator:
    def test_null_page_reserved(self):
        a = PageAllocator(4)
        got = a.alloc(3)
        assert got is not None and 0 not in got
        assert a.alloc(1) is None             # exhausted, no partial alloc
        a.free(got)
        assert a.n_free == 3
        with pytest.raises(ValueError, match="null page"):
            a.free([0])

    def test_double_free_rejected(self):
        a = PageAllocator(4)
        (pid,) = a.alloc(1)
        a.free([pid])
        with pytest.raises(ValueError, match="double free"):
            a.free([pid])

    def test_leak_check(self):
        a = PageAllocator(4)
        a.alloc(2)
        with pytest.raises(AssertionError, match="leak"):
            a.check_leaks(0)


class TestPageAllocatorRefcount:
    def test_alloc_order_deterministic(self):
        a = PageAllocator(5)
        assert a.alloc(3) == [1, 2, 3]
        assert a.n_used == 3 and a.n_free == 1

    def test_incref_decref_and_n_used(self):
        a = PageAllocator(6)
        ids = a.alloc(2)
        a.incref(ids)                         # rc 2: one page, two sharers
        assert a.refcount(ids[0]) == 2
        assert a.n_used == 2                  # unique pages, not references
        a.free(ids)                           # first sharer drops out
        assert a.refcount(ids[0]) == 1
        assert a.n_used == 2                  # still mapped by the other
        a.free(ids)                           # last reference → really freed
        assert a.n_used == 0 and a.refcount(ids[0]) == 0
        a.check_leaks(0)

    def test_incref_of_free_page_rejected(self):
        a = PageAllocator(4)
        with pytest.raises(ValueError, match="free page"):
            a.incref([2])
        (pid,) = a.alloc(1)
        a.free([pid])
        with pytest.raises(ValueError, match="free page"):
            a.incref([pid])
        with pytest.raises(ValueError, match="null page"):
            a.incref([0])

    def test_double_free_after_full_decref_rejected(self):
        a = PageAllocator(4)
        (pid,) = a.alloc(1)
        a.incref([pid])
        a.free([pid])
        a.free([pid])                         # rc 2 → 1 → 0: both legal
        with pytest.raises(ValueError, match="double free"):
            a.free([pid])


class TestPrefixCache:
    """Trie unit tests against a bare allocator (no engine)."""

    def _cached(self, alloc, cache, prompt):
        """Register ``prompt``'s full pages as an engine drain would: alloc,
        insert (trie takes its ref), then drop the sequence's own refs."""
        ids = alloc.alloc(len(prompt) // cache.page_size)
        cache.insert(prompt, ids)
        alloc.free(ids)
        return ids

    def test_match_capped_below_full_prompt(self):
        """A hit must leave >= 1 suffix token to prefill (the last position's
        logits seed decode), so an exactly-page-aligned prompt matches one
        page short of itself."""
        a = PageAllocator(8)
        cache = PrefixCache(4, a)
        prompt = np.arange(8, dtype=np.int32)
        ids = self._cached(a, cache, prompt)
        assert cache.match(prompt) == ids[:1]          # cap (8-1)//4 = 1
        assert cache.match(np.arange(9, dtype=np.int32)) == ids
        assert cache.match(np.arange(4, 12, dtype=np.int32)) == []

    def test_use_takes_refs_match_does_not(self):
        a = PageAllocator(8)
        cache = PrefixCache(4, a)
        prompt = np.arange(9, dtype=np.int32)
        ids = self._cached(a, cache, prompt)
        assert all(a.refcount(i) == 1 for i in ids)    # trie-only
        cache.match(prompt)
        assert all(a.refcount(i) == 1 for i in ids)    # match is pure
        got = cache.use(prompt)
        assert got == ids
        assert all(a.refcount(i) == 2 for i in ids)    # caller owns one now
        a.free(got)

    def test_evict_skips_referenced_pages_lru_order(self):
        a = PageAllocator(16)
        cache = PrefixCache(4, a)
        p1 = np.arange(0, 5, dtype=np.int32)
        p2 = np.arange(100, 105, dtype=np.int32)
        (id1,) = self._cached(a, cache, p1)
        (id2,) = self._cached(a, cache, p2)
        held = cache.use(p1)                  # sharer pins p1's page
        assert cache.evict(2) == 1            # only p2's page evictable
        assert a.refcount(id2) == 0 and a.refcount(id1) == 2
        assert cache.evict(1) == 0            # p1 still pinned
        a.free(held)
        assert cache.evict(1) == 1            # now trie-only → evictable
        a.check_leaks(0)

    def test_lru_prefers_stalest_leaf(self):
        a = PageAllocator(16)
        cache = PrefixCache(4, a)
        p1 = np.arange(0, 5, dtype=np.int32)
        p2 = np.arange(100, 105, dtype=np.int32)
        (id1,) = self._cached(a, cache, p1)
        (id2,) = self._cached(a, cache, p2)
        cache.use(p1) and a.free(cache.match(p1))      # touch p1 → p2 stalest
        assert cache.evict(1) == 1
        assert a.refcount(id2) == 0 and a.refcount(id1) >= 1

    def test_release_all_drops_every_trie_ref(self):
        a = PageAllocator(16)
        cache = PrefixCache(4, a)
        self._cached(a, cache, np.arange(9, dtype=np.int32))
        self._cached(a, cache, np.arange(50, 63, dtype=np.int32))
        assert cache.n_pages == 5
        assert cache.release_all() == 5
        assert cache.n_pages == 0 and a.n_used == 0
        a.check_leaks(0)


def _family_trace(cfg, n, *, sys_len, n_families=2, max_new=4, seed=5):
    """Requests sharing ``n_families`` fixed system prompts + unique tails."""
    rng = np.random.default_rng(seed)
    fams = [rng.integers(0, cfg.vocab_size, sys_len) for _ in range(n_families)]
    return [Request(rid=i,
                    prompt=np.concatenate([
                        fams[i % n_families],
                        rng.integers(0, cfg.vocab_size,
                                     int(rng.integers(2, 6)))]),
                    max_new_tokens=max_new)
            for i in range(n)]


class TestPrefixSharing:
    @pytest.mark.parametrize("kv_bits", [0, 8, 4])
    def test_prefix_hit_token_identical_to_cold(self, kv_bits):
        """Warm (prefix hits) greedy tokens == cold chunked engine (empty
        cache) tokens at every KV precision — hits skip chunks whose values
        the fixed-width chunk fn would recompute bit-identically."""
        cfg = _cfg()
        params = _params(cfg)
        kw = dict(plan=PrecisionPlan(kv_bits=kv_bits), max_slots=3,
                  page_size=4, max_seq_len=32, chunk_pages=2)
        reqs = _family_trace(cfg, 6, sys_len=8)
        cold = ServeEngine(params, cfg, **kw).run(reqs)
        warm_eng = ServeEngine(params, cfg, prefix_cache=True, **kw)
        warm = warm_eng.run(reqs)
        assert warm_eng.stats["prefix_hits"] >= 1
        for rid in cold:
            np.testing.assert_array_equal(cold[rid].tokens, warm[rid].tokens)
        warm_eng.release_prefix_cache()
        warm_eng.allocator.check_leaks(0)

    def test_shared_pages_never_rewritten(self):
        """COW invariant: a shared prefix page's quantized codes are
        bit-identical before and after other sharers prefill + decode over
        it, and refcounts drop back to trie-only at drain."""
        cfg = _cfg()
        eng = ServeEngine(_params(cfg), cfg, plan=PrecisionPlan(kv_bits=8),
                          max_slots=2, page_size=4, max_seq_len=32,
                          prefix_cache=True, chunk_pages=1)
        reqs = _family_trace(cfg, 5, sys_len=8, n_families=1)
        eng.run([reqs[0]])
        # the family's shared pages: the 8-token system prompt = pages 0-1
        # (reqs[0]'s private suffix pages are cached too — not shared)
        shared = eng.prefix.match(reqs[0].prompt[:9])
        assert len(shared) == 2
        before = np.asarray(eng.pool.k_pages[:, shared])
        eng.run(reqs[1:])                     # two cohabiting sharers at once
        assert eng.stats["prefix_hits"] == 4
        after = np.asarray(eng.pool.k_pages[:, shared])
        np.testing.assert_array_equal(before, after)
        assert all(eng.allocator.refcount(i) == 1 for i in shared)
        assert eng.release_prefix_cache() >= 2
        eng.allocator.check_leaks(0)

    def test_sharer_preemption_never_frees_mapped_pages(self):
        """A pool too small for everyone: preemption decrefs the victim's
        shared pages but the trie + surviving sharers still hold them, so
        every request replays to its solo output and the pool drains clean."""
        cfg = _cfg()
        params = _params(cfg)
        eng = ServeEngine(params, cfg, plan=PrecisionPlan(kv_bits=8),
                          max_slots=3, page_size=4, max_seq_len=32,
                          n_pages=10, reserve="none",
                          prefix_cache=True, chunk_pages=1)
        reqs = _family_trace(cfg, 5, sys_len=8, n_families=1, max_new=6)
        out = eng.run(reqs)
        assert eng.stats["preemptions"] >= 1
        assert eng.stats["prefix_hits"] >= 1
        for r in reqs:
            solo = ServeEngine(params, cfg, plan=PrecisionPlan(kv_bits=8),
                               max_slots=1, page_size=4,
                               max_seq_len=32).run([r])
            np.testing.assert_array_equal(solo[r.rid].tokens,
                                          out[r.rid].tokens)
        eng.release_prefix_cache()
        eng.allocator.check_leaks(0)


class TestChunkedPrefill:
    def test_chunked_matches_monolithic_bf16(self):
        """chunk_pages=1 at bf16 KV reproduces the monolithic engine's greedy
        tokens exactly (no quantization → write-then-attend is lossless)."""
        cfg = _cfg()
        params = _params(cfg)
        reqs = _family_trace(cfg, 4, sys_len=9)
        mono = ServeEngine(params, cfg, max_slots=2, page_size=4,
                           max_seq_len=32).run(reqs)
        eng = ServeEngine(params, cfg, max_slots=2, page_size=4,
                          max_seq_len=32, chunk_pages=1)
        chunked = eng.run(reqs)
        for rid in mono:
            np.testing.assert_array_equal(mono[rid].tokens,
                                          chunked[rid].tokens)
        assert eng.stats["prefill_chunks"] >= 4
        assert eng.stats["max_prefill_tokens_per_step"] <= 4
        eng.allocator.check_leaks(0)

    def test_decode_interleaves_with_prefill(self):
        """While a long prompt trickles in chunk by chunk, already-admitted
        sequences keep decoding: some step must advance both."""
        cfg = _cfg()
        rng = np.random.default_rng(9)
        eng = ServeEngine(_params(cfg), cfg, max_slots=2, page_size=4,
                          max_seq_len=64, chunk_pages=1)
        eng.submit(Request(rid=0, prompt=rng.integers(0, cfg.vocab_size, 5),
                           max_new_tokens=12))
        eng.submit(Request(rid=1, prompt=rng.integers(0, cfg.vocab_size, 40),
                           max_new_tokens=4))
        out, both = {}, 0
        while eng.busy:
            pf0 = eng.stats["prefill_tokens"]
            ds0 = eng.stats["decode_steps"]
            for f in eng.step():
                out[f.rid] = f
            if (eng.stats["prefill_tokens"] > pf0
                    and eng.stats["decode_steps"] > ds0):
                both += 1
        assert both >= 3                      # 40-token prompt = 10 chunks
        assert sorted(out) == [0, 1]
        eng.allocator.check_leaks(0)


class TestSchedulerBugfixes:
    """Regression pins for the serving-scheduler bug sweep."""

    def test_preemption_preserves_t_submit(self):
        """The requeued victim keeps its original submit timestamp — a
        restarted clock would zero the admission-wait signal the autoscaler
        governs on. Virtual clock: submits at t=0,1,2,3; preemption later."""
        cfg = _cfg()
        clk = [0.0]
        eng = ServeEngine(_params(cfg), cfg, plan=PrecisionPlan(kv_bits=8),
                          max_slots=3, page_size=4, max_seq_len=32,
                          n_pages=8, reserve="none", clock=lambda: clk[0])
        rng = np.random.default_rng(4)
        submit_t = {}
        for i in range(4):
            clk[0] = float(i)
            eng.submit(Request(rid=i,
                               prompt=rng.integers(0, cfg.vocab_size, 6),
                               max_new_tokens=8))
            submit_t[i] = clk[0]
        seen_requeue = False
        out = {}
        while eng.busy:
            clk[0] += 1.0
            for f in eng.step():
                out[f.rid] = f
            if eng.stats["preemptions"] and eng._queue:
                head = eng._queue[0]
                if head["replay"].size:       # a preempted request, mid-gen
                    seen_requeue = True
                    assert head["t_submit"] == submit_t[head["req"].rid]
        assert eng.stats["preemptions"] >= 1 and seen_requeue
        assert sorted(out) == list(range(4))
        eng.allocator.check_leaks(0)

    def test_decode_timing_reads_injected_clock(self):
        """decode_times must come from the injected clock, not a hardwired
        perf_counter: under a frozen virtual clock every steady-state decode
        step measures exactly 0.0 s."""
        cfg = _cfg()
        eng = ServeEngine(_params(cfg), cfg, max_slots=2, page_size=8,
                          max_seq_len=32, clock=lambda: 0.0)
        rng = np.random.default_rng(2)
        eng.run([Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 6),
                         max_new_tokens=6) for i in range(3)])
        assert len(eng.decode_times) >= 1
        assert all(dt == 0.0 for dt in eng.decode_times)
        assert eng.stats["decode_seconds"] == 0.0

    def test_autoscaler_actuation_deferred_during_replay(self):
        """A rung move requested while a preemption replay is in flight must
        not land until the replay drains — replayed KV has to be rebuilt
        under the weights that produced it. The stub governor demands 4 bits
        exactly when a replay is pending, so any actuation would change
        weights mid-replay; deferral keeps the run token-identical to a
        fixed-8-bit engine."""
        from repro.precision.qat import quantize_param_tree

        cfg = _cfg()
        params = quantize_param_tree(_params(cfg), bits=8, layout="bitplane")

        class ReplayDipGovernor:
            def __init__(self, engine_ref):
                self.engine_ref = engine_ref
                self.calls = []

            def observe(self, *, admit_wait_ms, queue_depth, now=None):
                replaying = self.engine_ref[0]._replaying()
                self.calls.append(replaying)
                return 4 if replaying else 8

        ref = [None]
        gov = ReplayDipGovernor(ref)
        kw = dict(plan=PrecisionPlan(kv_bits=8), max_slots=3, page_size=4,
                  max_seq_len=32, n_pages=8, reserve="none")
        eng = ServeEngine(params, cfg, autoscaler=gov, **kw)
        ref[0] = eng
        rng = np.random.default_rng(4)
        reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 6),
                        max_new_tokens=8) for i in range(4)]
        out = eng.run(reqs)
        assert eng.stats["preemptions"] >= 1
        assert any(gov.calls), "governor never saw a replay in flight"
        assert eng.weight_bits == 8           # every drop request deferred
        fixed = ServeEngine(params, cfg, **kw).run(
            [Request(rid=r.rid, prompt=r.prompt, max_new_tokens=8)
             for r in reqs])
        for rid in out:
            np.testing.assert_array_equal(out[rid].tokens, fixed[rid].tokens)
        eng.allocator.check_leaks(0)


class TestKVBytesAccounting:
    def test_pool_nbytes_ratios(self):
        """QTensor.nbytes accounting: int8 ≈ 2× and packed int4 ≥ 3× fewer
        KV bytes than bf16 at head_dim 64 (scales included)."""
        kw = dict(n_layers=2, n_pages=8, page_size=8, n_kv=2, head_dim=64)
        nb = {bits: pg.pool_nbytes(pg.init_pool(**kw, kv_bits=bits))
              for bits in (0, 8, 4)}
        assert nb[0] / nb[8] >= 1.8
        assert nb[0] / nb[4] >= 3.0


def _bitplane_params(cfg, bits=8):
    from repro.precision.qat import quantize_param_tree

    return quantize_param_tree(_params(cfg), bits=bits, layout="bitplane")


class TestSpeculativeDecoding:
    """Self-speculative decoding: low-bit draft + full-precision verify.

    The guarantee under test: speculation is an *execution strategy*, not a
    model change — outputs are token-identical to vanilla decode at every
    (kv_bits × draft_bits) combination, greedy and sampled, through
    rejections, page-boundary crossings, and preemptions."""

    def _reqs(self, cfg, n=4, max_new=8, seed=11, **kw):
        rng = np.random.default_rng(seed)
        return [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            int(rng.integers(4, 12))),
                        max_new_tokens=max_new, **kw)
                for i in range(n)]

    @pytest.mark.parametrize("kv_bits", [0, 8, 4])
    def test_greedy_token_identical_every_draft_bits(self, kv_bits):
        """Spec output == vanilla output exactly, at bf16/int8/int4 KV ×
        {4,2}-bit drafts. Accepted rows are minted by the verify pass's own
        full-precision write-then-attend, so this holds regardless of how
        wrong the low-bit draft is."""
        cfg = _cfg()
        params = _bitplane_params(cfg)
        kw = dict(plan=PrecisionPlan(kv_bits=kv_bits), max_slots=2,
                  page_size=4, max_seq_len=32)
        reqs = self._reqs(cfg)
        vanilla = ServeEngine(params, cfg, **kw).run(reqs)
        for draft_bits in (4, 2):
            eng = ServeEngine(params, cfg, spec_decode=3,
                              draft_bits=draft_bits, **kw)
            out = eng.run(reqs)
            assert eng.stats["spec_steps"] >= 1
            assert eng.stats["spec_draft_tokens"] > 0
            for rid in vanilla:
                np.testing.assert_array_equal(out[rid].tokens,
                                              vanilla[rid].tokens)
            eng.allocator.check_leaks(0)

    def test_spec_zero_degenerates_to_vanilla(self):
        """spec_decode=0 is the vanilla engine bit-for-bit: same tokens,
        no speculative counters, NaN acceptance."""
        cfg = _cfg()
        params = _bitplane_params(cfg)
        kw = dict(plan=PrecisionPlan(kv_bits=8), max_slots=2, page_size=4,
                  max_seq_len=32)
        reqs = self._reqs(cfg)
        vanilla = ServeEngine(params, cfg, **kw).run(reqs)
        eng = ServeEngine(params, cfg, spec_decode=0, **kw)
        out = eng.run(reqs)
        for rid in vanilla:
            np.testing.assert_array_equal(out[rid].tokens,
                                          vanilla[rid].tokens)
        assert eng.stats["spec_steps"] == 0
        assert eng.stats["spec_draft_tokens"] == 0
        assert np.isnan(eng.acceptance_rate())

    def test_first_draft_token_rejection_recovers(self):
        """A window whose *first* draft token is wrong commits exactly one
        token (the verify chain's), and the run still matches vanilla. The
        2-bit draft on random weights is wrong often enough that such a
        window provably occurs in this trace."""
        cfg = _cfg()
        params = _bitplane_params(cfg)
        kw = dict(plan=PrecisionPlan(kv_bits=8), max_slots=2, page_size=4,
                  max_seq_len=48)
        reqs = self._reqs(cfg, n=3, max_new=12, seed=13)
        vanilla = ServeEngine(params, cfg, **kw).run(reqs)
        eng = ServeEngine(params, cfg, spec_decode=3, draft_bits=2, **kw)
        for r in reqs:
            eng.submit(r)
        out, first_rejected = {}, False
        while eng.busy:
            before = {s: len(eng._slots[s]["gen"])
                      for s in range(eng.max_slots) if eng._active[s]}
            sp0 = eng.stats["spec_steps"]
            for f in eng.step():
                out[f.rid] = f
            if eng.stats["spec_steps"] > sp0:
                for s, n0 in before.items():
                    st = eng._slots[s]
                    # +1 token and still running ⇒ the window accepted no
                    # draft token, only verify's correction
                    if st is not None and len(st["gen"]) == n0 + 1:
                        first_rejected = True
        assert first_rejected, "no window rejected its first draft token"
        assert eng.acceptance_rate() < 1.0
        for rid in vanilla:
            np.testing.assert_array_equal(out[rid].tokens,
                                          vanilla[rid].tokens)
        eng.allocator.check_leaks(0)

    def test_window_crosses_page_boundary(self):
        """k+1 == page_size: every unaligned window spans two pages, so the
        scratch-tail page allocation and the cross-page verify scatter are
        exercised on nearly every step."""
        cfg = _cfg()
        params = _bitplane_params(cfg)
        rng = np.random.default_rng(17)
        # prompt of 7 → first window rows 7..10 straddle pages 1|2 (page 4)
        reqs = [Request(rid=0, prompt=rng.integers(0, cfg.vocab_size, 7),
                        max_new_tokens=10)]
        kw = dict(plan=PrecisionPlan(kv_bits=4), max_slots=1, page_size=4,
                  max_seq_len=32)
        vanilla = ServeEngine(params, cfg, **kw).run(reqs)
        eng = ServeEngine(params, cfg, spec_decode=3, draft_bits=4, **kw)
        out = eng.run(reqs)
        assert eng.stats["spec_steps"] >= 2
        np.testing.assert_array_equal(out[0].tokens, vanilla[0].tokens)
        eng.allocator.check_leaks(0)

    def test_preemption_with_uncommitted_draft_tail_leak_free(self):
        """Pool pressure preempting a slot that ran speculative windows:
        the window's scratch pages joined the slot's page list at
        allocation, so preemption frees them — no leak, and every request
        still replays to its solo (vanilla) output."""
        cfg = _cfg()
        params = _bitplane_params(cfg)
        eng = ServeEngine(params, cfg, plan=PrecisionPlan(kv_bits=8),
                          max_slots=3, page_size=4, max_seq_len=32,
                          n_pages=10, reserve="none",
                          spec_decode=3, draft_bits=4)
        rng = np.random.default_rng(4)
        reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 6),
                        max_new_tokens=8) for i in range(4)]
        out = eng.run(reqs)
        assert eng.stats["preemptions"] >= 1
        assert eng.stats["spec_steps"] >= 1
        for r in reqs:
            solo = ServeEngine(params, cfg, plan=PrecisionPlan(kv_bits=8),
                               max_slots=1, page_size=4,
                               max_seq_len=32).run([r])
            np.testing.assert_array_equal(solo[r.rid].tokens,
                                          out[r.rid].tokens)
        eng.allocator.check_leaks(0)

    def test_sampled_verify_token_identical(self):
        """temperature > 0: the verify pass samples every window position
        with the same fold_in(base, position) key sequential decode would
        use, so a mixed greedy/sampled batch stays token-identical to
        vanilla."""
        cfg = _cfg()
        params = _bitplane_params(cfg)
        rng = np.random.default_rng(3)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size, 4 + 3 * i),
                        max_new_tokens=6,
                        temperature=0.8 if i % 2 else 0.0,
                        top_k=5 if i % 2 else 0, seed=7)
                for i in range(4)]
        kw = dict(plan=PrecisionPlan(kv_bits=8), max_slots=2, page_size=4,
                  max_seq_len=32)
        vanilla = ServeEngine(params, cfg, **kw).run(reqs)
        eng = ServeEngine(params, cfg, spec_decode=3, draft_bits=4, **kw)
        out = eng.run(reqs)
        assert eng.stats["spec_steps"] >= 1
        for rid in vanilla:
            np.testing.assert_array_equal(out[rid].tokens,
                                          vanilla[rid].tokens)
        eng.allocator.check_leaks(0)

    def test_decode_tokens_counted_exactly_once(self):
        """Exactly-once token accounting under speculation: a slot hitting
        eos or budget mid-window discards the rest of the accepted prefix,
        and only committed tokens count — ``decode_tokens`` must equal
        Σ (n_generated − 1) (the first token of each request comes from
        prefill). Frozen injected clock also pins that window timing reads
        the engine clock (every steady window measures 0.0 s)."""
        cfg = _cfg()
        params = _bitplane_params(cfg)
        probe = ServeEngine(params, cfg, max_slots=1, page_size=4,
                            max_seq_len=48)
        reqs = self._reqs(cfg, n=4, max_new=10, seed=19)
        ref = probe.run([reqs[0]])
        eos = int(ref[0].tokens[-4])          # forces an early mid-window eos
        clk = [0.0]
        eng = ServeEngine(params, cfg, plan=PrecisionPlan(kv_bits=8),
                          max_slots=2, page_size=4, max_seq_len=48,
                          spec_decode=3, draft_bits=4,
                          clock=lambda: clk[0])
        out = eng.run([dataclasses.replace(r, eos_id=eos) for r in reqs])
        assert eng.stats["spec_steps"] >= 1
        assert any(f.reason == "eos" for f in out.values())
        total = sum(f.n_generated - 1 for f in out.values())
        assert eng.stats["decode_tokens"] == total
        assert eng.stats["decode_seconds"] == 0.0
        assert all(dt == 0.0 for dt in eng.decode_times)
        eng.allocator.check_leaks(0)

    def test_autoscaler_drop_to_draft_bits_disables_spec(self):
        """Serving bits at (or below) draft_bits make the draft pure
        overhead: speculation must pause after a rung drop and resume on
        restore, with actuation only ever landing between windows."""
        cfg = _cfg()
        params = _bitplane_params(cfg)
        eng = ServeEngine(params, cfg, plan=PrecisionPlan(kv_bits=8),
                          max_slots=2, page_size=4, max_seq_len=64,
                          spec_decode=3, draft_bits=4)
        rng = np.random.default_rng(23)
        eng.submit(Request(rid=0, prompt=rng.integers(0, cfg.vocab_size, 6),
                           max_new_tokens=40))
        while eng.busy and not eng.stats["spec_steps"]:
            eng.step()
        assert eng.stats["spec_steps"] >= 1
        eng.set_weight_bits(4)                # == draft_bits → spec off
        frozen = eng.stats["spec_steps"]
        for _ in range(4):
            if eng.busy:
                eng.step()
        assert eng.stats["spec_steps"] == frozen
        eng.set_weight_bits(8)                # restored → spec resumes
        while eng.busy:
            eng.step()
        assert eng.stats["spec_steps"] > frozen
        eng.allocator.check_leaks(0)

    def test_constructor_validation(self):
        cfg = _cfg()
        params = _bitplane_params(cfg)
        dense = _params(cfg)
        with pytest.raises(ValueError, match="spec_decode"):
            ServeEngine(params, cfg, spec_decode=-1, draft_bits=4)
        with pytest.raises(ValueError, match="draft_bits"):
            ServeEngine(params, cfg, spec_decode=2)
        with pytest.raises(ValueError, match="draft_bits"):
            ServeEngine(params, cfg, draft_bits=4)
        with pytest.raises(ValueError, match="bitplane"):
            ServeEngine(dense, cfg, spec_decode=2, draft_bits=4)
