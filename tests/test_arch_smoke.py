"""Per-architecture smoke tests: reduced config, one forward + one train step
on CPU, asserting output shapes and NaN-freeness. Plus decode-path checks."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)
B, S = 2, 32

# the cheap trio stays in the fast CI lane; heavyweight reduced configs are @slow
FAST_ARCHS = {"gemma-2b", "mamba2-780m", "musicgen-medium"}


def _arch_params(archs):
    return [pytest.param(a, marks=() if a in FAST_ARCHS else (pytest.mark.slow,))
            for a in archs]


def _batch(cfg, batch=B, seq=S):
    ktok = jax.random.fold_in(KEY, 1)
    tokens = jax.random.randint(ktok, (batch, seq), 0, cfg.vocab_size)
    targets = jnp.roll(tokens, -1, axis=1)
    vis = None
    if cfg.family == "vlm":
        vis = jax.random.normal(jax.random.fold_in(KEY, 2),
                                (batch, cfg.n_vis_tokens, cfg.d_model), jnp.float32)
    return tokens, targets, vis


@pytest.mark.parametrize("arch", _arch_params(configs.ARCH_IDS))
def test_forward_and_train_step(arch):
    cfg = configs.get_reduced(arch)
    params = T.init_params(KEY, cfg)
    tokens, targets, vis = _batch(cfg)

    h = T.forward(params, tokens, cfg, vision_tokens=vis)
    assert h.shape == (B, S, cfg.d_model)
    assert not bool(jnp.isnan(h.astype(jnp.float32)).any())

    def loss(p):
        return T.loss_fn(p, tokens, targets, cfg, vision_tokens=vis)

    loss0, grads = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(loss0))
    # a correct next-token model at init should be near log(vocab)
    assert float(loss0) < np.log(cfg.vocab_size) * 1.5
    flat, _ = jax.tree.flatten(grads)
    assert all(bool(jnp.isfinite(g.astype(jnp.float32)).all()) for g in flat)
    # small gd step reduces loss on the same batch (sanity of gradient direction)
    lr = 0.02
    params2 = jax.tree.map(
        lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(p.dtype),
        params, grads)
    loss1 = float(loss(params2))
    assert loss1 < float(loss0) + 1e-3, (loss1, float(loss0))


@pytest.mark.parametrize("arch", _arch_params(configs.ARCH_IDS))
def test_decode_step(arch):
    cfg = configs.get_reduced(arch)
    params = T.init_params(KEY, cfg)
    tokens, _, vis = _batch(cfg)
    state = T.init_decode_state(cfg, B, smax=S, params=params, vision_tokens=vis)
    tok = tokens[:, :1]
    logits, state = T.decode_step(params, state, tok, cfg)
    assert logits.shape == (B, 1, cfg.vocab_padded)  # pad tail is masked
    assert bool(jnp.isfinite(logits).all())
    # second step advances the counter and stays finite
    logits2, state = T.decode_step(params, state, tok, cfg)
    assert bool(jnp.isfinite(logits2).all())
    assert int(state.step) == 2


@pytest.mark.parametrize("arch", _arch_params(["gemma-2b", "mixtral-8x7b",
                                                "mamba2-780m", "zamba2-2.7b"]))
def test_decode_matches_forward(arch):
    """Greedy decode logits ≈ teacher-forced forward logits position-by-position.

    This pins the KV-cache/ring-buffer/SSM-state bookkeeping to the chunked
    training attention path.
    """
    # fp32: this pins *bookkeeping* (cursor/ring/state update), not numerics —
    # bf16 accumulation-order noise would otherwise dominate the comparison.
    cfg = configs.get_reduced(arch, dtype=jnp.float32)
    if cfg.window:
        cfg = configs.get_reduced(arch, window=S, dtype=jnp.float32)
    params = T.init_params(KEY, cfg)
    tokens, _, vis = _batch(cfg, batch=1, seq=8)

    h = T.forward(params, tokens, cfg, vision_tokens=vis)
    full_logits = T._readout(params, cfg, h)  # (1, 8, V)

    state = T.init_decode_state(cfg, 1, smax=8, params=params, vision_tokens=vis)
    outs = []
    for t in range(8):
        lg, state = T.decode_step(params, state, tokens[:, t:t + 1], cfg)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_kv_cache_quantized_decode():
    cfg = configs.get_reduced("granite-3-8b",
                              precision=T.PrecisionPlan(kv_bits=8))
    params = T.init_params(KEY, cfg)
    tokens, _, _ = _batch(cfg)
    state = T.init_decode_state(cfg, B, smax=S)
    assert state.layers.k.dtype == jnp.int8
    logits, state = T.decode_step(params, state, tokens[:, :1], cfg)
    assert bool(jnp.isfinite(logits).all())


def test_int8_weight_storage_forward():
    """ZipML weight channel: int8 codes + scales give ≈ bf16 forward."""
    from repro.precision.qat import quantize_param_tree
    cfg = configs.get_reduced("granite-3-8b")
    params = T.init_params(KEY, cfg)
    tokens, _, _ = _batch(cfg)
    h_ref = T.forward(params, tokens, cfg)
    qparams = quantize_param_tree(params, bits=8)
    h_q = T.forward(qparams, tokens, cfg)
    err = float(jnp.mean(jnp.abs(h_q.astype(jnp.float32) - h_ref.astype(jnp.float32))))
    ref = float(jnp.mean(jnp.abs(h_ref.astype(jnp.float32)))) + 1e-9
    assert err / ref < 0.15, err / ref


def test_param_counts_match_analytic():
    for arch in ("gemma-2b", "mamba2-780m", "mixtral-8x7b"):
        cfg = configs.get_reduced(arch)
        params = T.init_params(KEY, cfg)
        actual = sum(int(np.prod(a.shape)) for a in jax.tree.leaves(params))
        # analytic count covers matmuls + embedding; small extras (norms, conv,
        # biases, dt/a/d vectors) should keep it within 10%
        analytic = cfg.n_params()
        assert abs(actual - analytic) / actual < 0.15, (arch, actual, analytic)


@pytest.mark.parametrize("arch", _arch_params(["gemma-7b", "mixtral-8x7b",
                                                "mamba2-780m", "zamba2-2.7b",
                                                "llama-3.2-vision-11b"]))
def test_prefill_then_decode_matches_forward(arch):
    """prefill(prompt) + decode_step(next) == teacher-forced forward (fp32)."""
    cfg = configs.get_reduced(arch, dtype=jnp.float32)
    params = T.init_params(KEY, cfg)
    tokens, _, vis = _batch(cfg, batch=1, seq=8)

    h = T.forward(params, tokens, cfg, vision_tokens=vis)
    full_logits = T._readout(params, cfg, h)

    pre_logits, state = T.prefill(params, tokens[:, :7], cfg,
                                  vision_tokens=vis, pad_to=8)
    np.testing.assert_allclose(np.asarray(pre_logits, np.float32),
                               np.asarray(full_logits[:, 6], np.float32),
                               rtol=2e-3, atol=2e-3)
    lg, state = T.decode_step(params, state, tokens[:, 7:8], cfg)
    np.testing.assert_allclose(np.asarray(lg[:, 0], np.float32),
                               np.asarray(full_logits[:, 7], np.float32),
                               rtol=2e-3, atol=2e-3)


def test_kv_cache_int4_packed_decode():
    """H2 follow-on: packed int4 KV (two codes/byte, uint8 storage) decodes
    finitely and the cache is half the int8 size."""
    cfg4 = configs.get_reduced("granite-3-8b", precision=T.PrecisionPlan(kv_bits=4))
    cfg8 = configs.get_reduced("granite-3-8b", precision=T.PrecisionPlan(kv_bits=8))
    s4 = T.init_decode_state(cfg4, B, smax=S)
    s8 = T.init_decode_state(cfg8, B, smax=S)
    assert s4.layers.k.dtype == jnp.uint8
    assert s4.layers.k.size * 2 == s8.layers.k.size * 1 or \
        s4.layers.k.shape[-1] * 2 == s8.layers.k.shape[-1]
    params = T.init_params(KEY, cfg4)
    tokens, _, _ = _batch(cfg4)
    lg, _ = T.decode_step(params, s4, tokens[:, :1], cfg4)
    assert bool(jnp.isfinite(lg).all())


@pytest.mark.slow
def test_kv_int4_quality_close_to_int8():
    """int4 KV decode logits stay close to bf16-cache logits (fp32 model)."""
    import numpy as _np
    base = configs.get_reduced("gemma-7b", dtype=jnp.float32)
    params = T.init_params(KEY, base)
    tokens, _, _ = _batch(base, batch=1, seq=8)
    outs = {}
    for bits in (0, 8, 4):
        cfg = configs.get_reduced("gemma-7b", dtype=jnp.float32,
                                  precision=T.PrecisionPlan(kv_bits=bits))
        state = T.init_decode_state(cfg, 1, smax=8)
        o = []
        for t in range(8):
            lg, state = T.decode_step(params, state, tokens[:, t:t+1], cfg)
            o.append(_np.asarray(lg[:, 0], _np.float32))
        outs[bits] = _np.stack(o, 1)
    err8 = _np.abs(outs[8] - outs[0]).mean()
    err4 = _np.abs(outs[4] - outs[0]).mean()
    scale = _np.abs(outs[0]).mean() + 1e-9
    assert err8 / scale < 0.05, err8 / scale
    # int4 with per-(token,head) scales: ~25% relative on this tiny head_dim;
    # per-64-channel group scales would tighten it (recorded follow-on)
    assert err4 / scale < 0.35, err4 / scale
