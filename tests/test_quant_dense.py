"""quant_dense — the fused dequant-GEMM registry op family.

Pins the PR-level guarantees:

* the **ref backend is bit-exact** with the pre-op decode-then-einsum model
  numerics on every path (dense layer, MoE expert/router stacks, tied
  unembed transpose, level tables), forward AND backward — so routing the
  model through the op is a pure data-movement change;
* the **pallas backend matches the f32 decode path to ≤ 1e-5** relative
  error forward and backward (f32-accumulation associativity only), for
  int8 and nibble-packed int4 code planes;
* **ShipWeight** carries the straight-through master gradient while the
  matmul consumes codes, including over ``lax.scan``-stacked layers, and
  packed-int4 ship is value-identical to unpacked int4 (nibbles round-trip
  exactly);
* the **quantize epilogue** (quant_dense_q / act_quant.ds_project) equals
  matmul → cast → ds row-pair on the ref backend, and the fused Pallas
  kernel is bit-identical to the unfused kernel path given the same rand
  bits;
* the removed spliced weight formats raise, and interpret-mode selection
  resolves in one place (registry.interpret_default + env flag).
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs, quant
from repro.kernels import registry
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import transformer as T
from repro.precision import act_quant, qat
from repro.quant import (PrecisionPlan, QScheme, QTensor, ShipWeight,
                         quant_dense, quant_dense_q)

KEY = jax.random.PRNGKey(0)


def _wq(shape, bits=8, packed=False, key=KEY):
    w = jax.random.normal(key, shape) * 0.05
    scheme = QScheme.int_symmetric(bits, scaling="channel", channel_axis=-2,
                                   rounding="nearest", packed=packed)
    return w, quant.encode(w, scheme)


# ---------------------------------------------------------------------------
# ref backend: bit-exact with the pre-op decode-then-einsum numerics
# ---------------------------------------------------------------------------

class TestRefBitExact:
    def test_dense_forward(self):
        _, qt = _wq((32, 24))
        x = jax.random.normal(KEY, (4, 6, 32)).astype(jnp.bfloat16)
        got = quant_dense(x, qt, backend="ref")
        want = jnp.einsum("...i,io->...o", x, qt.decode(jnp.bfloat16),
                          preferred_element_type=jnp.float32)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_dense_layer_forward(self):
        _, qt = _wq((32, 24))
        x = jax.random.normal(KEY, (2, 5, 32)).astype(jnp.bfloat16)
        got = L.dense({"w": qt}, x)
        want = jnp.einsum("...i,io->...o", x, qt.decode(jnp.bfloat16),
                          preferred_element_type=jnp.float32).astype(x.dtype)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_backward_matches_autodiff_through_decode(self):
        _, qt = _wq((32, 24))
        x = jax.random.normal(KEY, (4, 32)).astype(jnp.bfloat16)
        g1 = jax.grad(lambda x: quant_dense(x, qt, backend="ref").sum())(x)
        g2 = jax.grad(lambda x: jnp.einsum(
            "...i,io->...o", x, qt.decode(jnp.bfloat16),
            preferred_element_type=jnp.float32).sum())(x)
        np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))

    def test_stacked_expert_forward(self):
        _, qt = _wq((3, 16, 8))
        assert qt.scale.shape == (3, 1, 8)     # per-expert channel scales
        x = jax.random.normal(KEY, (5, 3, 7, 16)).astype(jnp.bfloat16)
        got = quant_dense(x, qt, backend="ref")
        want = jnp.einsum("gecd,edf->gecf", x, qt.decode(jnp.bfloat16),
                          preferred_element_type=jnp.float32)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_unembed_transpose(self):
        _, qt = _wq((40, 32))
        x = jax.random.normal(KEY, (2, 3, 32)).astype(jnp.bfloat16)
        got = L.unembed({"table": qt}, x)
        want = jnp.einsum("...d,vd->...v", x, qt.decode(jnp.bfloat16),
                          preferred_element_type=jnp.float32)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_levels_grid_falls_back_to_decode(self):
        w = jax.random.normal(KEY, (16, 8)) * 0.1
        qt = qat._optimal_quantize_weight(w, 4)
        x = jax.random.normal(KEY, (4, 16)).astype(jnp.bfloat16)
        for be in ("ref", "pallas"):
            got = quant_dense(x, qt, backend=be)
            want = jnp.einsum("...i,io->...o", x, qt.decode(jnp.bfloat16),
                              preferred_element_type=jnp.float32)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def _trees(self, arch):
        cfg = configs.get_reduced(arch)
        params = T.init_params(KEY, cfg)
        qp = qat.quantize_param_tree(params, bits=8)
        dec = jax.tree.map(
            lambda l: l.decode(jnp.bfloat16) if isinstance(l, QTensor) else l,
            qp, is_leaf=lambda l: isinstance(l, QTensor))
        return cfg, qp, dec

    def test_int_storage_prefill_bit_exact_vs_decode_einsum(self):
        """Whole-model parity: serving at int8 storage produces logits
        bit-identical to dequantizing every weight up front (the pre-op
        semantics of layers.dense). Unrolled layers — XLA's scan-vs-unrolled
        bf16 fusion already differed at ~1e-3 BEFORE this op existed (the
        same two programs diverge identically on the pre-op code), so only
        the unrolled form is a same-program bit-level comparison."""
        cfg, qp, dec = self._trees("musicgen-medium")
        cfg = dataclasses.replace(cfg, scan_layers=False)
        toks = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
        lq, _ = T.prefill(qp, toks, cfg)
        ld, _ = T.prefill(dec, toks, cfg)
        np.testing.assert_array_equal(np.asarray(lq), np.asarray(ld))

    def test_int_storage_prefill_scanned_close(self):
        cfg, qp, dec = self._trees("musicgen-medium")
        toks = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
        lq, _ = T.prefill(qp, toks, cfg)
        ld, _ = T.prefill(dec, toks, cfg)
        np.testing.assert_allclose(np.asarray(lq), np.asarray(ld),
                                   rtol=0.05, atol=0.5)

    def test_moe_prefill_and_decode_bit_exact_vs_decode_einsum(self):
        cfg, qp, dec = self._trees("mixtral-8x7b")
        cfg = dataclasses.replace(cfg, scan_layers=False)
        toks = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
        lq, sq = T.prefill(qp, toks, cfg)
        ld, sd = T.prefill(dec, toks, cfg)
        np.testing.assert_array_equal(np.asarray(lq), np.asarray(ld))
        dq, _ = T.decode_step(qp, sq, toks[:, :1], cfg)
        dd, _ = T.decode_step(dec, sd, toks[:, :1], cfg)
        np.testing.assert_array_equal(np.asarray(dq), np.asarray(dd))

    def test_moe_dispatch_path_bit_exact(self):
        """The capacity-dispatch (training/prefill) MoE path with QTensor
        expert tables equals the decoded-weight path bit for bit."""
        spec = moe_mod.MoESpec(n_experts=4, top_k=2, d_model=16, d_ff=32,
                               dense_path_max_tokens=0)
        p = moe_mod.init_moe(KEY, spec)
        qp = qat.quantize_param_tree(p, bits=8)
        dec = jax.tree.map(
            lambda l: l.decode(jnp.bfloat16) if isinstance(l, QTensor) else l,
            qp, is_leaf=lambda l: isinstance(l, QTensor))
        x = jax.random.normal(KEY, (2, 40, 16)).astype(jnp.bfloat16)
        yq = moe_mod.moe_block(qp, x, spec)
        yd = moe_mod.moe_block(dec, x, spec)
        np.testing.assert_array_equal(np.asarray(yq), np.asarray(yd))


# ---------------------------------------------------------------------------
# pallas backend: streams codes, ≤ 1e-5 vs the f32 decode path
# ---------------------------------------------------------------------------

class TestPallasParity:
    @pytest.mark.parametrize("bits,packed", [(8, False), (4, True)])
    def test_forward_and_backward(self, bits, packed):
        _, qt = _wq((96, 40), bits=bits, packed=packed)
        x = jax.random.normal(KEY, (7, 96)).astype(jnp.bfloat16)
        g = jax.random.normal(KEY, (7, 40)).astype(jnp.bfloat16)
        wd = qt.decode()                                  # f32 decode path
        p = registry.get("pallas")
        y = p.quant_dense(x, qt)
        y_ref = jnp.einsum("...k,kn->...n", x.astype(jnp.float32), wd)
        assert float(jnp.abs(y - y_ref).max() / jnp.abs(y_ref).max()) <= 1e-5
        dx = p.quant_dense(g, qt, transpose=True)
        dx_ref = jnp.einsum("...n,kn->...k", g.astype(jnp.float32), wd)
        assert float(jnp.abs(dx - dx_ref).max()
                     / jnp.abs(dx_ref).max()) <= 1e-5

    def test_stacked_and_lead_dims(self):
        _, qt = _wq((3, 32, 16))
        x = jax.random.normal(KEY, (2, 3, 5, 32)).astype(jnp.bfloat16)
        y = registry.get("pallas").quant_dense(x, qt)
        y_ref = jnp.einsum("gecd,edf->gecf", x.astype(jnp.float32),
                           qt.decode())
        assert float(jnp.abs(y - y_ref).max() / jnp.abs(y_ref).max()) <= 1e-5

    def test_packed_prefill_value_identical_to_unpacked(self):
        """Packed int4 storage is the same VALUES as unpacked int4 (offset
        nibbles round-trip exactly) — whole-model logits agree bit for bit
        on the ref backend."""
        cfg = configs.get_reduced("musicgen-medium")
        params = T.init_params(KEY, cfg)
        qp_packed = qat.quantize_param_tree(params, bits=4)      # auto-packs
        qp_plain = qat.quantize_param_tree(params, bits=4, packed=False)
        packed_planes = [l for l in jax.tree.leaves(qp_packed)
                         if hasattr(l, "dtype") and l.dtype == jnp.uint8]
        assert packed_planes, "4-bit weights should auto-pack"
        toks = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
        lp, _ = T.prefill(qp_packed, toks, cfg)
        lu, _ = T.prefill(qp_plain, toks, cfg)
        np.testing.assert_array_equal(np.asarray(lp), np.asarray(lu))


# ---------------------------------------------------------------------------
# ShipWeight: STE master gradient + scanned stacked layers + packed int4
# ---------------------------------------------------------------------------

class TestShipWeight:
    def test_ste_gradient_reaches_master(self):
        w, qt = _wq((32, 24))
        x = jax.random.normal(KEY, (4, 32)).astype(jnp.bfloat16)
        g = jnp.ones((4, 24), jnp.float32)

        def loss(w_):
            return jnp.vdot(quant_dense(x, ShipWeight(w_, qt),
                                        backend="ref"), g)
        dw = jax.grad(loss)(w)
        want = jnp.einsum("...k,...n->kn", x, g.astype(x.dtype),
                          preferred_element_type=jnp.float32
                          ).astype(w.dtype)
        np.testing.assert_allclose(np.asarray(dw), np.asarray(want),
                                   rtol=1e-6)

    def _ship_loss(self, bits, scan_layers, packed=None):
        plan = PrecisionPlan(model_bits=bits, model_storage="ship")
        cfg = configs.get_reduced("musicgen-medium", precision=plan)
        cfg = dataclasses.replace(cfg, scan_layers=scan_layers)
        params = T.init_params(KEY, cfg)
        from repro.train.channels import ModelChannel
        from repro.train.step import make_grads_fn
        ch = ModelChannel(plan, ship_min_size=0)
        if packed is not None:
            ch.apply = lambda p, s, k: (jax.tree_util.tree_map_with_path(
                lambda path, leaf: qat.ship_quant(leaf, bits, packed=packed)
                if qat._is_weight(path) and leaf.ndim >= 2 else leaf,
                p), s)
        grads_of = make_grads_fn(cfg, ch)
        batch = {"tokens": jax.random.randint(KEY, (2, 16), 0,
                                              cfg.vocab_size),
                 "targets": jax.random.randint(KEY, (2, 16), 0,
                                               cfg.vocab_size)}
        loss, grads = jax.jit(grads_of)(params, batch, KEY)
        return float(loss), grads

    def test_ship_int4_packed_equals_unpacked_under_scan(self):
        """QAT ship at 4-bit over lax.scan-stacked layers: the nibble-packed
        code plane must reproduce the unpacked decode path exactly — loss
        and master gradients bit-identical (ref backend)."""
        l_packed, g_packed = self._ship_loss(4, True, packed=True)
        l_plain, g_plain = self._ship_loss(4, True, packed=False)
        assert l_packed == l_plain
        for a, b in zip(jax.tree.leaves(g_packed), jax.tree.leaves(g_plain)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_ship_scan_matches_unrolled(self):
        l_scan, _ = self._ship_loss(8, True)
        l_unroll, _ = self._ship_loss(8, False)
        assert np.isclose(l_scan, l_unroll, rtol=1e-5)


# ---------------------------------------------------------------------------
# quantize epilogue
# ---------------------------------------------------------------------------

class TestEpilogue:
    def test_ref_equals_unfused_ds_pair(self):
        _, qt = _wq((32, 24))
        x = jax.random.normal(KEY, (6, 32)).astype(jnp.bfloat16)
        got = quant_dense_q(x, qt, KEY, bits=8, backend="ref")
        y = quant_dense(x, qt, backend="ref").astype(x.dtype)
        from repro.quant.qtensor import ds_pair_jnp
        want = ds_pair_jnp(y, QScheme.int_symmetric(8, scaling="row",
                                                    rounding="ds"), KEY)
        np.testing.assert_array_equal(np.asarray(got.codes),
                                      np.asarray(want.codes))
        np.testing.assert_array_equal(np.asarray(got.codes2),
                                      np.asarray(want.codes2))
        np.testing.assert_array_equal(np.asarray(got.scale),
                                      np.asarray(want.scale))

    def test_fused_bit_exact_vs_unfused_kernel_path(self):
        """Same rand bits → the fused epilogue emits exactly the codes the
        unfused (qmm → astype → ds row-quantize) pipeline would."""
        _, qt = _wq((64, 40))
        x = jax.random.normal(KEY, (9, 64)).astype(jnp.bfloat16)
        fused = quant_dense_q(x, qt, KEY, bits=8, backend="pallas")
        rand = jax.random.bits(KEY, (9, 40), jnp.uint32)
        yb = registry.get("pallas").quant_dense(x, qt).astype(x.dtype) \
            .astype(jnp.float32)
        absmax = jnp.max(jnp.abs(yb), axis=1, keepdims=True)
        sc = jnp.where(absmax == 0, 1.0, absmax / 127)
        t = yb / sc
        base = jnp.floor(t)
        u1 = (rand >> 16).astype(jnp.float32) / (1 << 16)
        u2 = (rand & 0xFFFF).astype(jnp.float32) / (1 << 16)
        c1 = jnp.clip(base + (u1 < (t - base)), -127, 127).astype(jnp.int8)
        c2 = jnp.clip(base + (u2 < (t - base)), -127, 127).astype(jnp.int8)
        np.testing.assert_array_equal(np.asarray(fused.codes),
                                      np.asarray(c1))
        np.testing.assert_array_equal(np.asarray(fused.codes2),
                                      np.asarray(c2))
        np.testing.assert_allclose(np.asarray(fused.scale),
                                   np.asarray(sc), rtol=1e-6)

    def test_ds_project_unbiased(self):
        """E[decode(Q₁)] ≈ y — the epilogue pair stays an unbiased
        estimator of the activation it replaces."""
        _, qt = _wq((16, 8))
        x = jnp.ones((4, 16), jnp.bfloat16) * 0.3
        y = quant_dense(x, qt, backend="ref").astype(jnp.float32)
        acc = jnp.zeros_like(y)
        n = 200
        for i in range(n):
            pair = act_quant.ds_project(x, qt, jax.random.fold_in(KEY, i),
                                        bits=4, backend="ref")
            acc = acc + pair.decode()
        err = float(jnp.abs(acc / n - y).max())
        width = float(jnp.abs(y).max()) / 7                # one 4-bit step
        assert err < 0.25 * width, (err, width)

    def test_lead_dims_roundtrip(self):
        _, qt = _wq((32, 24))
        x = jax.random.normal(KEY, (2, 3, 32)).astype(jnp.bfloat16)
        for be in ("ref", "pallas"):
            out = quant_dense_q(x, qt, KEY, bits=8, backend=be)
            assert out.codes.shape == (2, 3, 24)
            assert out.scale.shape == (2, 3, 1)
            assert out.is_ds


# ---------------------------------------------------------------------------
# removed splice formats + interpret-mode selection
# ---------------------------------------------------------------------------

class TestRemovedSplices:
    def test_dense_raises_on_wq_splice(self):
        with pytest.raises(ValueError, match="QTensor"):
            L.dense({"w_q": jnp.zeros((4, 4), jnp.int8),
                     "w_scale": jnp.ones((1, 4))},
                    jnp.zeros((2, 4), jnp.bfloat16))

    def test_dense_raises_on_levels_splice(self):
        with pytest.raises(ValueError, match="QTensor"):
            L.dense({"w_lvl_codes": jnp.zeros((4, 4), jnp.int8),
                     "w_levels": jnp.zeros((16,))},
                    jnp.zeros((2, 4), jnp.bfloat16))

    def test_moe_raises_on_splice(self):
        with pytest.raises(ValueError, match="QTensor"):
            moe_mod._qeinsum("ecd,edf->ecf",
                             jnp.zeros((1, 2, 4), jnp.bfloat16),
                             {"w_q": jnp.zeros((1, 4, 4), jnp.int8)})
        with pytest.raises(ValueError, match="QTensor"):
            moe_mod._gq_einsum("gecd,edf->gecf",
                               jnp.zeros((1, 1, 2, 4), jnp.bfloat16),
                               {"w_lvl_codes": jnp.zeros((1, 4, 4),
                                                         jnp.int16)})

    def test_migrate_spliced_weights_roundtrip(self):
        """The migration shim the error message points at: splice dicts →
        QTensor leaves with identical decode values, consumable by dense."""
        w = jax.random.normal(KEY, (16, 8)) * 0.05
        scale = jnp.max(jnp.abs(w), axis=0, keepdims=True) / 127
        codes = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
        lv_qt = qat._optimal_quantize_weight(
            jax.random.normal(jax.random.fold_in(KEY, 1), (4, 16, 8)) * 0.1,
            4)
        spliced = {
            "mlp": {"up": {"w_q": codes,
                           "w_scale": scale.astype(jnp.float32)}},
            # old stacked level splice: dim-less table next to stacked codes
            "stack": {"w_lvl_codes": lv_qt.codes,
                      "w_levels": lv_qt.levels[0]},
        }
        with pytest.raises(ValueError, match="migrate_spliced_weights"):
            L.dense(spliced["mlp"]["up"], jnp.zeros((2, 16), jnp.bfloat16))
        fixed = qat.migrate_spliced_weights(spliced)
        up = fixed["mlp"]["up"]["w"]
        assert isinstance(up, QTensor)
        np.testing.assert_array_equal(
            np.asarray(up.decode(jnp.bfloat16)),
            np.asarray(codes.astype(jnp.bfloat16)
                       * scale.astype(jnp.bfloat16)))
        stack = fixed["stack"]["w"]
        assert stack.levels.shape == (4, lv_qt.levels.shape[-1])
        np.testing.assert_array_equal(np.asarray(stack.decode()),
                                      np.asarray(lv_qt.decode()))
        x = jax.random.normal(KEY, (2, 16)).astype(jnp.bfloat16)
        y = L.dense(fixed["mlp"]["up"], x)
        want = jnp.einsum("...i,io->...o", x, up.decode(jnp.bfloat16),
                          preferred_element_type=jnp.float32).astype(x.dtype)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(want))


class TestInterpretSelection:
    def test_env_flag_forces_interpret(self, monkeypatch):
        monkeypatch.setenv(registry.INTERPRET_ENV, "1")
        assert registry.interpret_default() is True
        monkeypatch.setenv(registry.INTERPRET_ENV, "0")
        assert registry.interpret_default() is False

    def test_default_tracks_backend(self, monkeypatch):
        monkeypatch.delenv(registry.INTERPRET_ENV, raising=False)
        want = jax.default_backend() != "tpu"
        assert registry.interpret_default() is want

    def test_no_kernel_entrypoint_defaults_interpret_true(self):
        """The satellite fix: no Pallas entry point may hardcode
        ``interpret=True`` as its default again."""
        import inspect
        from repro.kernels import paged_attn, qmm, quant_adamw, ssd, stoch_quant
        for mod in (qmm, stoch_quant, quant_adamw, paged_attn, ssd):
            for name, fn in vars(mod).items():
                if not callable(fn) or not hasattr(fn, "__wrapped__"):
                    continue
                sig = inspect.signature(fn.__wrapped__)
                p = sig.parameters.get("interpret")
                if p is not None:
                    assert p.default is None, f"{mod.__name__}.{name}"


# ---------------------------------------------------------------------------
# chunked attention odd-length fix (satellite)
# ---------------------------------------------------------------------------

class TestChunkedAttentionOddLengths:
    @pytest.mark.parametrize("s,cq,window", [(37, 8, 0), (100, 32, 16),
                                             (17, 16, 8)])
    def test_odd_tail_is_padded_not_collapsed(self, s, cq, window):
        """Lengths not divisible by q_chunk must keep query chunking (the
        old fallback silently went O(S²)) and match the single-block
        softmax exactly."""
        b, h, hkv, d = 2, 4, 2, 16
        q = jax.random.normal(KEY, (b, s, h, d)).astype(jnp.bfloat16)
        k = jax.random.normal(jax.random.fold_in(KEY, 1),
                              (b, s, hkv, d)).astype(jnp.bfloat16)
        v = jax.random.normal(jax.random.fold_in(KEY, 2),
                              (b, s, hkv, d)).astype(jnp.bfloat16)
        spec = A.AttnSpec(h, hkv, d, q_chunk=cq, window=window)
        one = A.AttnSpec(h, hkv, d, q_chunk=s, window=window)
        out = A.chunked_attention(q, k, v, spec)
        ref = A.chunked_attention(q, k, v, one)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_odd_length_loss_runs_chunked(self):
        """A model loss at an odd sequence length exercises the padded-tail
        path end to end (was: silent single-block fallback)."""
        cfg = configs.get_reduced("musicgen-medium")
        cfg = dataclasses.replace(cfg, q_chunk=8)
        params = T.init_params(KEY, cfg)
        toks = jax.random.randint(KEY, (2, 13), 0, cfg.vocab_size)
        loss = T.loss_fn(params, toks, toks, cfg)
        assert np.isfinite(float(loss))
