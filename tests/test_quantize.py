"""Unit + property tests for core/quantize.py (C1) and core/optimal.py (C4)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import optimal
import repro.core.quantize as qz


KEY = jax.random.PRNGKey(0)


class TestStochasticQuantize:
    @pytest.mark.parametrize("s", [1, 3, 7, 15, 127])
    @pytest.mark.parametrize("n", [1, 17, 256])
    def test_unbiased(self, s, n):
        """E[Q(v,s)] = v (Lemma 6) — Monte-Carlo over many keys."""
        v = jax.random.normal(jax.random.PRNGKey(1), (n,)) * 3.0
        keys = jax.random.split(KEY, 2048)
        qs = jax.vmap(lambda k: qz.stochastic_quantize(v, s, k))(keys)
        mean = qs.mean(axis=0)
        se = qs.std(axis=0) / np.sqrt(len(keys)) + 1e-6
        np.testing.assert_array_less(np.abs(mean - v), 5 * se + 1e-4)

    @pytest.mark.parametrize("s", [1, 3, 15])
    def test_levels_are_grid(self, s):
        v = jax.random.normal(KEY, (64,))
        q = qz.quantize(v, s, KEY)
        assert q.codes.min() >= -s and q.codes.max() <= s
        deq = q.dequantize()
        grid = jnp.arange(-s, s + 1) / s * q.scale
        dists = jnp.min(jnp.abs(deq[:, None] - grid[None, :]), axis=1)
        assert float(dists.max()) < 1e-5

    def test_roundtrip_within_one_level(self):
        v = jax.random.normal(KEY, (128,))
        s = 15
        deq = qz.stochastic_quantize(v, s, KEY)
        width = qz.row_scale(v) / s
        assert float(jnp.max(jnp.abs(deq - v))) <= float(width) + 1e-5

    def test_variance_bound_lemma2(self):
        """TV_s(v) <= min(n/s², √n/s)·||v||² (Lemma 2)."""
        for s in (1, 3, 7, 31):
            for n in (8, 64, 512):
                v = jax.random.normal(jax.random.fold_in(KEY, s * n), (n,))
                tv = float(qz.tv_variance(v, s, scale=qz.row_scale(v, "l2")))
                bound = min(n / s**2, np.sqrt(n) / s) * float(jnp.sum(v * v))
                assert tv <= bound + 1e-5, (s, n, tv, bound)

    def test_zero_vector(self):
        q = qz.stochastic_quantize(jnp.zeros(8), 7, KEY)
        np.testing.assert_allclose(np.asarray(q), 0.0, atol=1e-6)

    def test_column_scaling_shared(self):
        data = jax.random.normal(KEY, (100, 5)) * jnp.array([1, 10, 0.1, 5, 2.0])
        cs = qz.column_scale(data)
        assert cs.shape == (5,)
        q = qz.stochastic_quantize(data, 15, KEY, scale=cs)
        assert float(jnp.max(jnp.abs(q - data))) <= float(cs.max() / 15) + 1e-5

    def test_nearest_rounding_is_deterministic(self):
        v = jax.random.normal(KEY, (32,))
        a = qz.quantize_nearest(v, 7).dequantize()
        b = qz.quantize_nearest(v, 7).dequantize()
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestLevelQuantize:
    def test_unbiased_on_levels(self):
        levels = jnp.asarray([0.0, 0.1, 0.4, 0.75, 1.0])
        v = jax.random.uniform(KEY, (50,))
        keys = jax.random.split(KEY, 4096)
        vals = jax.vmap(lambda k: qz.quantize_to_levels(v, levels, k)[1])(keys)
        mean = vals.mean(0)
        se = vals.std(0) / np.sqrt(len(keys)) + 1e-6
        np.testing.assert_array_less(np.abs(mean - v), 5 * se + 1e-4)

    def test_output_in_level_set(self):
        levels = jnp.asarray([0.0, 0.3, 0.9, 1.0])
        v = jax.random.uniform(KEY, (100,))
        codes, vals = qz.quantize_to_levels(v, levels, KEY)
        assert set(np.unique(np.asarray(vals))) <= set(np.asarray(levels).tolist())
        assert codes.max() <= 3


class TestIntQuantize:
    @pytest.mark.parametrize("bits", [4, 8])
    def test_roundtrip_error(self, bits):
        v = jax.random.normal(KEY, (64, 32))
        it = qz.int_quantize(v, bits, axis=0)
        err = jnp.abs(it.dequantize() - v)
        step = it.scale  # one code step
        assert float((err <= step + 1e-6).mean()) == 1.0

    def test_stochastic_unbiased(self):
        v = jax.random.normal(KEY, (16,))
        keys = jax.random.split(KEY, 4096)
        deqs = jax.vmap(lambda k: qz.int_quantize(v, 4, None, k).dequantize())(keys)
        se = deqs.std(0) / np.sqrt(len(keys)) + 1e-6
        np.testing.assert_array_less(np.abs(deqs.mean(0) - v), 5 * se + 1e-4)


class TestOptimalLevels:
    def test_exact_beats_uniform(self):
        rng = np.random.default_rng(0)
        xs = np.clip(rng.beta(0.5, 3.0, 400), 0, 1)  # skewed: uniform is bad
        for s in (2, 3, 7):
            opt = optimal.optimal_levels_exact(xs, s)
            mv_opt = optimal.mean_variance(xs, opt)
            mv_uni = optimal.mean_variance(xs, optimal.uniform_levels(s))
            assert mv_opt <= mv_uni + 1e-12, (s, mv_opt, mv_uni)

    @pytest.mark.slow
    def test_discretized_close_to_exact(self):
        rng = np.random.default_rng(1)
        xs = np.clip(rng.normal(0.5, 0.15, 500), 0, 1)
        for s in (3, 7):
            ex = optimal.mean_variance(xs, optimal.optimal_levels_exact(xs, s))
            ap = optimal.mean_variance(xs, optimal.optimal_levels_discretized(xs, s, M=128))
            assert ap <= ex * 1.25 + 1e-9  # Thm 2: O(1/Mk) gap

    def test_2approx_guarantee(self):
        rng = np.random.default_rng(2)
        xs = np.clip(np.concatenate([rng.normal(0.2, 0.03, 200),
                                     rng.normal(0.8, 0.05, 200)]), 0, 1)
        for s in (3, 7):
            ex = optimal.mean_variance(xs, optimal.optimal_levels_exact(xs, s))
            ap = optimal.mean_variance(xs, optimal.optimal_levels_2approx(xs, s, gamma=1.0))
            assert ap <= 2.0 * ex + 1e-9, (s, ap, ex)  # Thm 9 with γ=1

    def test_levels_sorted_and_cover(self):
        xs = np.random.default_rng(3).uniform(0, 1, 200)
        lv = optimal.optimal_levels_discretized(xs, 7)
        assert lv[0] == 0.0 and lv[-1] == 1.0
        assert np.all(np.diff(lv) >= 0)

    def test_bimodal_places_levels_at_modes(self):
        rng = np.random.default_rng(4)
        xs = np.clip(np.concatenate([rng.normal(0.15, 0.01, 300),
                                     rng.normal(0.85, 0.01, 300)]), 0, 1)
        lv = optimal.optimal_levels_exact(xs, 3)
        # interior levels should hug the modes, not sit at uniform 1/3, 2/3
        interior = lv[1:-1]
        assert np.any(np.abs(interior - 0.15) < 0.05) or np.any(np.abs(interior - 0.85) < 0.05)

    def test_fit_levels_symmetric(self):
        x = np.random.default_rng(5).normal(0, 1, 1000)
        lv = optimal.fit_levels(x, 8, symmetric=True)
        np.testing.assert_allclose(lv, -lv[::-1], atol=1e-9)

    def test_mean_variance_zero_when_levels_at_points(self):
        xs = np.array([0.1, 0.5, 0.9])
        lv = np.array([0.0, 0.1, 0.5, 0.9, 1.0])
        assert optimal.mean_variance(xs, lv) < 1e-12


@pytest.mark.slow
def test_property_sweep_unbiasedness():
    """Property: for random shapes/scales/levels, |MC mean − v| → 0."""
    rng = np.random.default_rng(7)
    for trial in range(5):
        n = int(rng.integers(2, 40))
        s = int(rng.choice([1, 3, 7, 15]))
        v = jnp.asarray(rng.normal(0, rng.uniform(0.1, 5.0), n), jnp.float32)
        keys = jax.random.split(jax.random.PRNGKey(trial), 3000)
        qs = jax.vmap(lambda k: qz.stochastic_quantize(v, s, k))(keys)
        err = np.abs(np.asarray(qs.mean(0) - v))
        se = np.asarray(qs.std(0)) / np.sqrt(3000) + 1e-6
        assert (err < 6 * se + 1e-3).all()
