"""Trainer / TrainState / channel tests: bit-exact parity with the legacy
driver, error-feedback threading + convergence, full-state checkpoint
resume, elastic composition, ship-quant over scanned layers, and the
dry-run specs for stateful-channel leaves."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.ckpt.checkpoint import CheckpointManager
from repro.data.pipeline import TokenStream, TokenStreamConfig
from repro.kernels import registry
from repro.launch import elastic
from repro.launch.steps import input_specs
from repro.launch.train import make_trainer, train
from repro.models import transformer as T
from repro.optim import adamw
from repro.precision import gradcomp
from repro.quant import PrecisionPlan, QTensor
from repro.train import GradChannel, ModelChannel, SampleChannel, Trainer

ARCH = "musicgen-medium"
KEY = jax.random.PRNGKey(0)


def _mk(steps=6, ckpt_dir=None, **kw) -> Trainer:
    return make_trainer(ARCH, batch=2, seq=16, steps=steps,
                        ckpt_dir=ckpt_dir, log_every=1000, **kw)


class _TrainShape:
    kind = "train"
    global_batch = 2
    seq_len = 16


# ---------------------------------------------------------------------------
# Bit-exact parity: legacy train() wrapper vs driving the Trainer directly
# ---------------------------------------------------------------------------

@pytest.mark.slow
class TestLegacyParity:
    def _run_both(self, precision, moment_bits, steps=20):
        with registry.using("ref"):
            _, l_legacy = train(ARCH, steps=steps, batch=2, seq=16,
                                log_every=1000, precision=precision,
                                moment_bits=moment_bits)
            tr = _mk(steps=steps, precision=precision,
                     moment_bits=moment_bits)
            state, l_new = tr.run(steps)
        return l_legacy, l_new, state

    def test_bf16_bit_exact(self):
        l_legacy, l_new, _ = self._run_both(PrecisionPlan(), 0)
        assert l_legacy == l_new          # float-identical, 20 steps

    def test_grad8_moment8_bit_exact(self):
        l_legacy, l_new, state = self._run_both(
            PrecisionPlan(grad_bits=8), 8)
        assert l_legacy == l_new
        # the stateful pieces really exist after 20 steps
        ef = state.channels["grad"]["ef"]
        assert sum(float(jnp.sum(jnp.abs(e)))
                   for e in jax.tree.leaves(ef)) > 0
        m_leaf = jax.tree.leaves(
            state.opt.m, is_leaf=lambda x: isinstance(x, QTensor))[0]
        assert isinstance(m_leaf, QTensor)
        assert m_leaf.codes.dtype == jnp.int8


# ---------------------------------------------------------------------------
# Error feedback: actually threads through jit, and earns its keep
# ---------------------------------------------------------------------------

class TestErrorFeedback:
    def test_ef_state_updates_through_jit(self):
        """The jitted step must carry the residual in and out — the property
        the old grad_transform closure could not provide (jit traced it once
        and froze the captured error at None forever)."""
        ch = GradChannel(PrecisionPlan(grad_bits=4))
        g = {"w": jnp.linspace(0.0003, 0.01, 16)}   # coarse 4-bit rounding
        state0 = ch.init(g)

        @jax.jit
        def step(grads, state, key):
            return ch.apply(grads, state, key)

        _, s1 = step(g, state0, KEY)
        _, s2 = step(g, s1, KEY)
        # residual is nonzero after one step and different after two
        assert float(jnp.sum(jnp.abs(s1["ef"]["w"]))) > 0
        assert not np.array_equal(np.asarray(s1["ef"]["w"]),
                                  np.asarray(s2["ef"]["w"]))

    def test_quadratic_ef_on_beats_ef_off(self):
        """Ill-conditioned quadratic at 4-bit gradients, nearest rounding
        (the §5.4 biased straw man — the regime where EF's telescoping
        identity is load-bearing; unbiased stochastic rounding self-corrects
        on a full-batch quadratic). The stiff pair sits at lr·λ = 2, so it
        oscillates forever and pins the per-tensor absmax high; the soft
        coordinates' gradients stay below half a quantization step and
        vanish without EF — their loss stalls at init. EF accumulates the
        dropped mass and releases it, converging to the granularity floor."""
        lr = 0.3
        lam = jnp.concatenate([jnp.full((2,), 2.0 / lr), jnp.full((30,), 0.5)])
        w_star = jnp.concatenate([jnp.full((2,), 2.0),
                                  jnp.linspace(0.5, 1.0, 30)])

        def loss(w):
            return 0.5 * jnp.sum(lam * (w - w_star) ** 2)

        def soft_loss(w):
            return 0.5 * jnp.sum(lam[2:] * (w[2:] - w_star[2:]) ** 2)

        def run(error_feedback):
            ch = GradChannel(PrecisionPlan(grad_bits=4),
                             error_feedback=error_feedback,
                             rounding="nearest")
            w = jnp.zeros(32)
            state = ch.init({"w": w})
            key = jax.random.PRNGKey(0)
            for i in range(200):
                g = {"w": jax.grad(loss)(w)}
                g, state = ch.apply(g, state, jax.random.fold_in(key, i))
                w = w - lr * g["w"]
            return float(soft_loss(w))

        on, off = run(True), run(False)
        assert on < off / 5, (on, off)
        assert off > 1.0, off     # without EF the soft block truly stalls


# ---------------------------------------------------------------------------
# Full-state checkpoint: restore → resume is bit-exact (EF + QTensor moments)
# ---------------------------------------------------------------------------

@pytest.mark.slow
class TestCheckpointResume:
    def test_resume_bit_exact(self, tmp_path):
        plan = PrecisionPlan(grad_bits=8)
        with registry.using("ref"):
            # uninterrupted reference run
            tr_a = _mk(steps=10, precision=plan, moment_bits=8)
            state_a, losses_a = tr_a.run(10)
            # interrupted run: 5 steps, checkpoint, then a *fresh* Trainer
            # resumes from disk
            tr_b = _mk(steps=10, ckpt_dir=str(tmp_path), precision=plan,
                       moment_bits=8)
            tr_b.ckpt_every = 5
            _, losses_b1 = tr_b.run(5)
            tr_c = _mk(steps=10, ckpt_dir=str(tmp_path), precision=plan,
                       moment_bits=8)
            state_c, losses_b2 = tr_c.run(10)
        assert losses_b1 + losses_b2 == losses_a
        for a, c in zip(jax.tree.leaves(state_a), jax.tree.leaves(state_c)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(c))

    def test_checkpoint_roundtrips_ef_and_moments(self, tmp_path):
        with registry.using("ref"):
            tr = _mk(steps=4, ckpt_dir=str(tmp_path),
                     precision=PrecisionPlan(grad_bits=8), moment_bits=8)
            state = tr.init_state()
            tr.stream.skip_to(state.cursor)
            for _ in range(3):
                state, _ = tr.step(state, tr.stream.next_batch())
            tr.save(state, blocking=True)
            restored, manifest = tr.restore()
        assert manifest["extra"]["format"] == "trainstate-v1"
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_legacy_momentq_checkpoint_shim(self, tmp_path):
        """Pre-Trainer checkpoints — (params, opt_state) with (codes, scale)
        moment splices — restore through the load-time shim with a warning."""
        with registry.using("ref"):
            tr = _mk(steps=4, ckpt_dir=str(tmp_path), moment_bits=8)
            state = tr.init_state()
            # fabricate the old on-disk layout from the new state
            def to_pair(q):
                sshape = (1,) * (q.codes.ndim - 1) + q.codes.shape[-1:] \
                    if q.codes.ndim > 1 else ()
                return (jnp.ones(q.codes.shape, jnp.int8),
                        jnp.full(sshape, 0.5, jnp.float32))
            is_q = lambda x: isinstance(x, QTensor)
            legacy_opt = adamw.OptState(
                state.opt.step,
                jax.tree.map(to_pair, state.opt.m, is_leaf=is_q),
                jax.tree.map(to_pair, state.opt.v, is_leaf=is_q),
                state.opt.master)
            mgr = CheckpointManager(str(tmp_path))
            mgr.save(3, (state.params, legacy_opt),
                     extra={"cursor": {"step": 3, "epoch": 0}}, blocking=True)
            with pytest.warns(DeprecationWarning, match="legacy MomentQ"):
                restored, manifest = tr.restore()
        assert manifest["step"] == 3 and int(restored.step) == 3
        m_leaf = jax.tree.leaves(
            restored.opt.m, is_leaf=is_q)[0]
        assert isinstance(m_leaf, QTensor)
        np.testing.assert_allclose(np.asarray(m_leaf.decode()), 0.5)
        # channel state comes back freshly initialized (no grad_bits → empty)
        assert restored.channels["grad"] == {}

    def test_legacy_fp32_checkpoint_shim(self, tmp_path):
        """The most common legacy format — fp32 moments, no MomentQ at all —
        must restore through the shim too (regression: to_pair used to
        assume every moment leaf had .codes)."""
        with registry.using("ref"):
            tr = _mk(steps=4, ckpt_dir=str(tmp_path), moment_bits=0)
            state = tr.init_state()
            mgr = CheckpointManager(str(tmp_path))
            mgr.save(2, (state.params, state.opt),
                     extra={"cursor": {"step": 2, "epoch": 0}}, blocking=True)
            restored, manifest = tr.restore()
        assert manifest["step"] == 2 and int(restored.step) == 2
        for a, b in zip(jax.tree.leaves(state.opt.m),
                        jax.tree.leaves(restored.opt.m)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_new_format_mismatch_raises_not_legacy(self, tmp_path):
        """A trainstate-v1 checkpoint whose leaves mismatch the template
        (plan drift) must surface its real error, not be retried as a
        legacy pair."""
        with registry.using("ref"):
            tr = _mk(steps=4, ckpt_dir=str(tmp_path),
                     precision=PrecisionPlan(grad_bits=8))
            tr.save(tr.init_state(), blocking=True)
            tr2 = _mk(steps=4, ckpt_dir=str(tmp_path))   # no grad_bits → no EF
            with pytest.raises(ValueError, match="leaves"):
                tr2.restore()


# ---------------------------------------------------------------------------
# Supervisor restart cap: deterministic crashes propagate, transient recover
# ---------------------------------------------------------------------------

class TestRestartCap:
    def test_deterministic_crash_hits_cap(self, tmp_path):
        """fail_count=None fires the injected fault every time the step is
        reached — restore lands on the same step forever, so after
        max_restarts consecutive no-progress crashes the real error must
        propagate instead of looping."""
        with registry.using("ref"):
            tr = _mk(steps=6, ckpt_dir=str(tmp_path), max_restarts=2)
            tr.ckpt_every = 2
            with pytest.raises(RuntimeError, match="injected fault"):
                tr.run(6, fail_at=3, fail_count=None)

    def test_transient_crashes_recover_within_cap(self, tmp_path):
        with registry.using("ref"):
            tr = _mk(steps=6, ckpt_dir=str(tmp_path), max_restarts=8)
            tr.ckpt_every = 2
            state, losses = tr.run(6, fail_at=3, fail_count=2)
        assert int(state.step) == 6
        # each crash replays ≥1 step (from the step-2 checkpoint, or from
        # scratch when the async save hasn't committed yet — timing decides
        # which, so the exact replay count isn't pinned)
        assert 8 <= len(losses) <= 12

    def test_cap_validation(self):
        with pytest.raises(ValueError, match="max_restarts"):
            _mk(steps=2, max_restarts=-1)


# ---------------------------------------------------------------------------
# Elastic composition: kill a pod, shrink, restore, nothing skipped/repeated
# ---------------------------------------------------------------------------

@pytest.mark.slow
class TestElasticComposition:
    def test_shrink_restore_rewind(self, tmp_path, monkeypatch):
        monkeypatch.setattr(elastic, "HOSTS_PER_POD", 1)
        ctl = elastic.ElasticController(2, heartbeat_timeout=10,
                                        rejoin_patience=2)
        t = 1000.0
        ctl.heartbeat(0, 0, now=t)
        ctl.heartbeat(1, 1, now=t)

        plan = PrecisionPlan(grad_bits=8)
        with registry.using("ref"):
            tr = _mk(steps=8, ckpt_dir=str(tmp_path), precision=plan,
                     moment_bits=8)
            # this process is host 0 of a 2-host fleet
            tr.stream_cfg = dataclasses.replace(tr.stream_cfg, n_hosts=2,
                                                host_id=0)
            tr.stream = TokenStream(tr.stream_cfg)
            state = tr.init_state()
            tr.stream.skip_to(state.cursor)
            for _ in range(4):
                state, _ = tr.step(state, tr.stream.next_batch())
            tr.save(state, blocking=True)
            saved_ef = jax.tree.map(lambda x: np.asarray(x),
                                    state.channels["grad"]["ef"])
            saved_m = jax.tree.map(
                lambda x: np.asarray(x),
                jax.tree.leaves(state.opt.m,
                                is_leaf=lambda q: isinstance(q, QTensor))[0].codes)
            # two more (to-be-lost) steps past the checkpoint
            for _ in range(2):
                state, _ = tr.step(state, tr.stream.next_batch())

            # pod 1 dies mid-run → controller shrinks the mesh
            ctl.report_failure(1)
            decision = ctl.decide(latest_checkpoint_step=4, now=t + 1)
            assert decision.evicted_pods == [1]
            assert decision.restore_step == 4
            assert elastic.stream_sharding(decision, 0) == (1, 0)

            state = tr.apply_fleet_decision(decision, state, host_id=0)
            # rolled back to the checkpoint; cursor rewound with it
            assert int(state.step) == 4
            assert tr.stream.cursor.step == 4
            assert tr.stream_cfg.n_hosts == 1

            # EF residuals and quantized moments survive the reshard
            for a, b in zip(jax.tree.leaves(saved_ef),
                            jax.tree.leaves(state.channels["grad"]["ef"])):
                np.testing.assert_array_equal(a, np.asarray(b))
            m0 = jax.tree.leaves(
                state.opt.m, is_leaf=lambda q: isinstance(q, QTensor))[0].codes
            np.testing.assert_array_equal(saved_m, np.asarray(m0))

            # the resumed stream consumes exactly steps 4, 5, … of the
            # 1-host configuration — nothing skipped, nothing repeated
            ref_stream = TokenStream(tr.stream_cfg)
            for i in (4, 5):
                got = tr.stream.next_batch()
                want = ref_stream._batch_at(i)
                np.testing.assert_array_equal(got["tokens"], want["tokens"])
                state, _ = tr.step(state, got)
            assert int(state.step) == 6


class TestStreamSharding:
    def test_unassigned_host_raises(self):
        """An evicted host must not silently fall back to shard 0 (duplicate
        data); it gets told it is out of the fleet."""
        d = elastic.FleetDecision(1, (16, 16), 4, {0: 0}, [1], "pod 1 out")
        assert elastic.stream_sharding(d, 0) == (1, 0)
        with pytest.raises(RuntimeError, match="not in the surviving fleet"):
            elastic.stream_sharding(d, 7)


# ---------------------------------------------------------------------------
# Ship-quantized weights over scanned stacked layers (the silent-fp32 fix)
# ---------------------------------------------------------------------------

class TestShipScanLayers:
    def _loss(self, scan_layers, plan):
        cfg = configs.get_reduced(ARCH, precision=plan)
        cfg = dataclasses.replace(cfg, scan_layers=scan_layers)
        params = T.init_params(KEY, cfg)
        from repro.train.step import make_grads_fn
        # reduced smoke weights are tiny — drop the worth-the-gather floor
        grads_of = make_grads_fn(cfg, ModelChannel(plan, ship_min_size=0))
        stream = TokenStream(TokenStreamConfig(cfg.vocab_size, 16, 2))
        b = stream.next_batch()
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        loss, grads = jax.jit(grads_of)(params, batch, KEY)
        return float(loss), grads

    def test_ship_applies_under_scan(self):
        ship = PrecisionPlan(model_bits=4, model_storage="ship")
        l_ship, _ = self._loss(True, ship)
        l_full, _ = self._loss(True, PrecisionPlan())
        # 4-bit shipped weights must actually perturb the loss — the old
        # `not cfg.scan_layers` gate silently trained at full precision
        assert l_ship != l_full

    def test_scan_matches_unrolled(self):
        ship = PrecisionPlan(model_bits=8, model_storage="ship")
        l_scan, _ = self._loss(True, ship)
        l_unroll, _ = self._loss(False, ship)
        assert np.isclose(l_scan, l_unroll, rtol=1e-5), (l_scan, l_unroll)


# ---------------------------------------------------------------------------
# Sample channel: e2e mode quantizes float sample tensors, others pass through
# ---------------------------------------------------------------------------

class TestSampleChannel:
    def test_full_mode_is_identity(self):
        ch = SampleChannel(PrecisionPlan(sample_bits=5))
        batch = {"tokens": jnp.arange(6).reshape(2, 3),
                 "vision": jax.random.normal(KEY, (2, 4))}
        out, _ = ch.apply(batch, {}, KEY)
        for k in batch:
            np.testing.assert_array_equal(np.asarray(out[k]),
                                          np.asarray(batch[k]))

    def test_e2e_mode_quantizes_float_leaves(self):
        ch = SampleChannel(PrecisionPlan("e2e", sample_bits=4))
        batch = {"tokens": jnp.arange(6).reshape(2, 3),
                 "vision": jax.random.normal(KEY, (2, 64))}
        out, _ = ch.apply(batch, {}, KEY)
        np.testing.assert_array_equal(np.asarray(out["tokens"]),
                                      np.asarray(batch["tokens"]))
        v, vq = np.asarray(batch["vision"]), np.asarray(out["vision"])
        assert not np.array_equal(v, vq)
        step = np.abs(v).max() / 7
        assert np.abs(v - vq).max() <= step + 1e-6


# ---------------------------------------------------------------------------
# Dry-run specs price the stateful-channel leaves
# ---------------------------------------------------------------------------

class TestInputSpecs:
    def _specs(self, plan, moment_bits):
        cfg = configs.get_reduced(ARCH, precision=plan)
        return input_specs(cfg, _TrainShape(),
                           opt_cfg=adamw.AdamWConfig(moment_bits=moment_bits))

    def test_moments_priced_at_stored_width(self):
        from repro.quant import tree_nbytes
        s8 = self._specs(PrecisionPlan(), 8)["state"]
        s0 = self._specs(PrecisionPlan(), 0)["state"]
        m8 = jax.tree.leaves(s8.opt.m, is_leaf=lambda x: isinstance(x, QTensor))
        assert all(q.codes.dtype == jnp.int8 for q in m8)
        assert tree_nbytes((s8.opt.m, s8.opt.v)) < \
            tree_nbytes((s0.opt.m, s0.opt.v)) / 3
    def test_ef_leaves_present_iff_grad_bits(self):
        s = self._specs(PrecisionPlan(grad_bits=8), 0)["state"]
        assert "ef" in s.channels["grad"]
        ef_leaves = jax.tree.leaves(s.channels["grad"]["ef"])
        assert ef_leaves and all(x.dtype == jnp.float32 for x in ef_leaves)
        s0 = self._specs(PrecisionPlan(), 0)["state"]
        assert s0.channels["grad"] == {}

    def test_state_spec_matches_real_state(self):
        """eval_shape spec tree == the structure the Trainer really builds."""
        with registry.using("ref"):
            tr = _mk(steps=2, precision=PrecisionPlan(grad_bits=8),
                     moment_bits=8)
            spec = tr.state_template()
            state = tr.init_state()
        a = jax.tree.structure(spec)
        b = jax.tree.structure(state)
        assert a == b
        for s, x in zip(jax.tree.leaves(spec), jax.tree.leaves(state)):
            assert tuple(s.shape) == tuple(x.shape) and s.dtype == x.dtype


class TestGradcompStateAPI:
    def test_compress_tree_error_none_equals_zeros(self):
        """EF-zeros init is bit-identical to the legacy error=None first
        step (g + 0 quantizes identically)."""
        g = {"a": jax.random.normal(KEY, (32,))}
        c0, e0 = gradcomp.compress_tree(g, 8, KEY)
        zeros = gradcomp.init_error_feedback(g)
        c1, e1 = gradcomp.compress_tree(g, 8, KEY, error=zeros)
        np.testing.assert_array_equal(np.asarray(c0["a"].codes),
                                      np.asarray(c1["a"].codes))
        np.testing.assert_array_equal(np.asarray(e0["a"]), np.asarray(e1["a"]))
