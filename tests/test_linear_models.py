"""End-to-end convergence tests for the paper's linear-model suite (§5, Fig. 4/9)."""
import numpy as np
import pytest

from repro.core.linear import Precision, make_dataset, eval_accuracy, train_linear


@pytest.fixture(scope="module")
def reg_ds():
    return make_dataset("synthetic100", n_train=2000, n_test=1000, seed=0)


@pytest.fixture(scope="module")
def cls_ds():
    return make_dataset("cod-rna", n_train=3000, n_test=1000, seed=1)


class TestLinearRegression:
    def test_full_precision_converges(self, reg_ds):
        r = train_linear(reg_ds, Precision("full"), epochs=10, lr=0.3)
        loss_at_zero = 0.5 * np.mean(reg_ds.b_train**2)  # trivial predictor x=0
        assert r.losses[-1] < loss_at_zero * 0.2

    @pytest.mark.slow
    def test_double_sampling_matches_full(self, reg_ds):
        """Fig. 4 claim: 5–6 bits with double sampling reaches the fp32 loss."""
        full = train_linear(reg_ds, Precision("full"), epochs=12, lr=0.3)
        ds6 = train_linear(reg_ds, Precision("double", bits_sample=6), epochs=12, lr=0.3)
        assert ds6.losses[-1] < full.losses[-1] * 1.15 + 1e-4

    @pytest.mark.slow
    def test_e2e_quantization_converges(self, reg_ds):
        """App. E: samples+model+gradient quantized, still converges."""
        full = train_linear(reg_ds, Precision("full"), epochs=12, lr=0.3)
        e2e = train_linear(
            reg_ds, Precision("e2e", bits_sample=6, bits_model=8, bits_grad=8),
            epochs=12, lr=0.3)
        assert e2e.losses[-1] < full.losses[-1] * 1.3 + 1e-4

    @pytest.mark.slow
    def test_naive_quantization_worse(self, reg_ds):
        """App. B.1: the biased estimator converges to a WORSE solution at low
        bits than double sampling with the same bits."""
        naive = train_linear(reg_ds, Precision("naive", bits_sample=3), epochs=12, lr=0.3)
        dbl = train_linear(reg_ds, Precision("double", bits_sample=3), epochs=12, lr=0.3)
        assert dbl.losses[-1] < naive.losses[-1]

    @pytest.mark.slow
    def test_optimal_levels_beat_uniform_low_bits(self, reg_ds):
        """Fig. 7a/8: optimal levels at 3 bits ≲ uniform at 3 bits."""
        uni = train_linear(reg_ds, Precision("double", bits_sample=3), epochs=10, lr=0.3)
        opt = train_linear(
            reg_ds, Precision("double", bits_sample=3, use_optimal_levels=True),
            epochs=10, lr=0.3)
        assert opt.losses[-1] <= uni.losses[-1] * 1.05

    def test_l1_prox_sparsifies(self, reg_ds):
        r = train_linear(reg_ds, Precision("full"), epochs=8, lr=0.3, reg="l1")
        # prox-l1 with default lam gives exact zeros on small coords
        assert (np.abs(r.x) < 1e-8).sum() >= 0  # runs through prox path


class TestLSSVM:
    @pytest.mark.slow
    def test_lssvm_low_precision(self, cls_ds):
        full = train_linear(cls_ds, Precision("full"), model="lssvm", epochs=10, lr=0.3)
        low = train_linear(cls_ds, Precision("double", bits_sample=6), model="lssvm",
                           epochs=10, lr=0.3)
        acc_f = eval_accuracy(cls_ds, full.x)
        acc_l = eval_accuracy(cls_ds, low.x)
        assert acc_l > acc_f - 0.03
        assert acc_f > 0.7


class TestLogistic:
    def test_full_converges(self, cls_ds):
        r = train_linear(cls_ds, Precision("full"), model="logistic", epochs=10, lr=0.5)
        assert r.losses[-1] < 0.69  # < log(2) = random init loss

    @pytest.mark.slow
    def test_chebyshev_8bit(self, cls_ds):
        """Fig. 9: Chebyshev with 4-bit samples × degree-15 ≈ full precision."""
        full = train_linear(cls_ds, Precision("full"), model="logistic", epochs=10, lr=0.5)
        cheb = train_linear(cls_ds, Precision("double", bits_sample=4),
                            model="logistic", epochs=10, lr=0.5)
        assert cheb.losses[-1] < full.losses[-1] + 0.08

    @pytest.mark.slow
    def test_nearest_straw_man_also_works(self, cls_ds):
        """§5.4 negative result: naive nearest rounding at 8 bits matches."""
        near = train_linear(cls_ds, Precision("nearest", bits_sample=8),
                            model="logistic", epochs=10, lr=0.5)
        full = train_linear(cls_ds, Precision("full"), model="logistic", epochs=10, lr=0.5)
        assert near.losses[-1] < full.losses[-1] + 0.05


class TestSVM:
    def test_full_converges(self, cls_ds):
        r = train_linear(cls_ds, Precision("full"), model="svm", epochs=10, lr=0.2,
                         reg="ball")
        assert r.losses[-1] < r.losses[0]
        assert eval_accuracy(cls_ds, r.x) > 0.7

    def test_refetch_heuristic(self, cls_ds):
        """App. G.4 + Fig. 12: ℓ1-refetching converges and refetches a small
        fraction at 8 bits."""
        r = train_linear(cls_ds, Precision("double", bits_sample=8), model="svm",
                         epochs=8, lr=0.2, reg="ball", refetch="l1")
        assert r.extra is not None
        final_frac = r.extra["refetch_frac"][-1]
        assert final_frac < 0.25  # paper: <6% on cod-rna; proxy data is noisier
        assert eval_accuracy(cls_ds, r.x) > 0.68

    @pytest.mark.slow
    def test_chebyshev_svm(self, cls_ds):
        r = train_linear(cls_ds, Precision("double", bits_sample=4), model="svm",
                         epochs=8, lr=0.2, reg="ball")
        assert eval_accuracy(cls_ds, r.x) > 0.6
