"""QTensor as a first-class pytree: jit/vmap/scan round-trips, checkpoint
save/restore, the compressed psum under shard_map, and the storage
accounting (nbits/nbytes host-side, +1 bit for the DS pair)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro import quant
from repro.ckpt.checkpoint import CheckpointManager
from repro.precision import gradcomp, qat
from repro.quant import PrecisionPlan, QScheme, QTensor

KEY = jax.random.PRNGKey(0)


def _qt(shape=(8, 16), bits=8, key=KEY):
    x = jax.random.normal(key, shape)
    return x, quant.encode(x, QScheme.int_symmetric(bits), key)


class TestPytree:
    def test_flatten_roundtrip(self):
        _, qt = _qt()
        leaves, treedef = jax.tree.flatten(qt)
        back = jax.tree.unflatten(treedef, leaves)
        assert isinstance(back, QTensor)
        assert back.scheme == qt.scheme
        np.testing.assert_array_equal(np.asarray(back.codes),
                                      np.asarray(qt.codes))

    def test_jit_through(self):
        x, qt = _qt()

        @jax.jit
        def f(q):
            return q.decode()

        np.testing.assert_array_equal(np.asarray(f(qt)),
                                      np.asarray(qt.decode()))

        @jax.jit
        def g(v, k):
            return quant.encode(v, QScheme.int_symmetric(8), k)

        out = g(x, KEY)
        assert isinstance(out, QTensor)
        np.testing.assert_array_equal(np.asarray(out.codes),
                                      np.asarray(qt.codes))

    def test_vmap_through(self):
        xs = jax.random.normal(KEY, (4, 8, 16))
        keys = jax.random.split(KEY, 4)

        def enc(v, k):
            return quant.encode(v, QScheme.int_symmetric(8), k)

        qts = jax.vmap(enc)(xs, keys)
        assert isinstance(qts, QTensor) and qts.codes.shape == (4, 8, 16)
        deq = jax.vmap(lambda q: q.decode())(qts)
        for i in range(4):
            want = enc(xs[i], keys[i]).decode()
            np.testing.assert_array_equal(np.asarray(deq[i]), np.asarray(want))

    def test_scan_carry(self):
        """A QTensor rides through lax.scan as the carry (the scheme is static
        aux data, so carry-in/carry-out structures match)."""
        x = jax.random.normal(KEY, (8, 16))
        scheme = QScheme.int_symmetric(8, rounding="nearest")
        qt = quant.encode(x, scheme)

        def body(carry, _):
            q = quant.encode(carry.decode(), scheme)  # re-encode: same scheme
            return q, q.decode().sum()

        final, ys = jax.lax.scan(body, qt, jnp.arange(3))
        assert isinstance(final, QTensor) and ys.shape == (3,)
        # int grid nearest re-encode of already-on-grid values is idempotent
        np.testing.assert_allclose(np.asarray(final.decode()),
                                   np.asarray(qt.decode()), rtol=1e-6)

    def test_optimal_levels_stacked_scans(self):
        """Regression (seed bug): the C4 level-table weight storage must ride
        lax.scan over stacked layers — the old splice format put a dim-less
        table next to (L, …) codes, which scan rejected."""
        w = jax.random.normal(KEY, (3, 8, 4))          # (L, d_in, d_out)
        qt = qat._optimal_quantize_weight(w, 4)
        assert qt.levels.shape[0] == 3 and qt.scale.shape == (3,)

        def body(c, layer_qt):
            return c + layer_qt.decode().sum(), None

        total, _ = jax.lax.scan(body, jnp.float32(0), qt)
        np.testing.assert_allclose(float(total), float(qt.decode().sum()),
                                   rtol=1e-5)

    def test_ds_pair_planes_and_grad_none_leaves(self):
        x = jax.random.normal(KEY, (8, 16))
        qt = quant.ds_pair(x, QScheme.zipml(7, rounding="ds"), KEY)
        assert qt.is_ds and qt.codes2.shape == x.shape
        # None children (levels) survive transformations
        out = jax.jit(lambda q: (q.decode() + q.decode2()) / 2)(qt)
        assert out.shape == x.shape


class TestEdgeCases:
    def test_wide_int_grid_uses_int32_codes(self):
        """bits > 8 must widen the code dtype, not saturate int8."""
        x = jax.random.normal(KEY, (64,))
        qt = quant.encode(x, QScheme.int_symmetric(16, rounding="nearest"))
        assert qt.codes.dtype == jnp.int32
        step = float(jnp.max(jnp.abs(x))) / (2**15 - 1)
        assert float(jnp.max(jnp.abs(qt.decode() - x))) <= step + 1e-7

    def test_ds_without_key_raises(self):
        x = jnp.ones((4, 4))
        with pytest.raises(ValueError, match="PRNG key"):
            quant.ds_pair(x, QScheme.zipml(7, rounding="ds"), None)
        with pytest.raises(ValueError, match="PRNG key"):
            quant.encode(x, QScheme.int_symmetric(8, rounding="ds"))

    def test_stochastic_without_key_raises(self):
        with pytest.raises(ValueError, match="PRNG key"):
            quant.encode(jnp.ones((4,)), QScheme.int_symmetric(8))


class TestAccounting:
    def test_nbits_host_side(self):
        """nbits must be a Python int computed without tracing (the old
        Quantized.nbits called jnp on a Python int)."""
        _, qt = _qt(bits=8)
        assert isinstance(qt.nbits, int) and qt.nbits == 8
        zq = quant.encode(jnp.ones((4, 4)), QScheme.zipml(7), KEY)
        assert zq.nbits == 3          # ceil(log2(7+1))
        dsq = quant.ds_pair(jnp.ones((4, 4)), QScheme.zipml(7, rounding="ds"),
                            KEY)
        assert dsq.nbits == 4         # +1 bit for the second DS plane (§2.2)

    def test_nbits_under_jit(self):
        _, qt = _qt()

        @jax.jit
        def f(q):
            return jnp.zeros((q.nbits,))  # host int → usable as a shape

        assert f(qt).shape == (8,)

    def test_nbytes(self):
        _, qt = _qt(shape=(16, 16), bits=8)
        # 256 int8 codes + 1 f32 scalar scale
        assert qt.nbytes == 256 + 4
        dsq = quant.ds_pair(jax.random.normal(KEY, (16, 16)),
                            QScheme.zipml(7, rounding="ds"), KEY)
        assert dsq.nbytes == (256 * 4 + 7) // 8 + 4   # 4 bits/coord + scale


class TestDot:
    @pytest.mark.parametrize("backend", ["ref", "pallas"])
    def test_dot_matches_decode(self, backend):
        x = jax.random.normal(KEY, (16, 32))
        v = jax.random.normal(jax.random.fold_in(KEY, 1), (32,))
        for scheme, scale in [
            (QScheme.zipml(7), None),
            (QScheme.zipml(15, scaling="column"), jnp.max(jnp.abs(x), axis=0)),
            (QScheme.int_symmetric(8), None),
        ]:
            qt = quant.encode(x, scheme, KEY, scale=scale)
            want = np.asarray(qt.decode() @ v)
            got = np.asarray(quant.dot(qt, v, backend=backend))
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


class TestBackendStructuralParity:
    def test_ds_pair_same_structure_both_backends(self):
        """ref- and pallas-produced QTensors must be interchangeable: same
        scale shape, same nbytes, same pytree structure (the pallas kernel's
        internal scale broadcast must not leak into the storage format)."""
        x = jax.random.normal(KEY, (8, 16))
        for scheme, scale in [
            (QScheme.zipml(15, rounding="ds"), None),
            (QScheme.zipml(15, scaling="column", rounding="ds"),
             jnp.max(jnp.abs(x), axis=0)),
        ]:
            qr = quant.ds_pair(x, scheme, KEY, scale=scale, backend="ref")
            qp = quant.ds_pair(x, scheme, KEY, scale=scale, backend="pallas")
            assert qr.scale.shape == qp.scale.shape, scheme
            assert qr.nbytes == qp.nbytes, scheme
            assert (jax.tree.structure(qr) == jax.tree.structure(qp))


class TestQuantizedConsumers:
    def test_moe_expert_weights_qtensor(self):
        """moe._wmat must read QTensor expert weights (int8 serving of MoE)."""
        from repro.models import moe as moe_mod

        spec = moe_mod.MoESpec(n_experts=4, top_k=2, d_model=16, d_ff=32,
                               dense_path_max_tokens=512)
        p = moe_mod.init_moe(KEY, spec, dtype=jnp.float32)
        x = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 8, 16))
        y_ref = moe_mod.moe_block(p, x, spec)
        qp = qat.quantize_param_tree(p, bits=8)
        assert isinstance(qp["up"]["w"], QTensor)
        assert isinstance(qp["down"]["w"], QTensor)
        y_q = moe_mod.moe_block(qp, x, spec)
        rel = float(jnp.linalg.norm(y_q - y_ref) /
                    (jnp.linalg.norm(y_ref) + 1e-9))
        assert rel < 0.1, rel

    def test_param_spec_shards_qtensor_codes(self):
        """QTensor code planes must inherit the dense weight's sharding;
        scales/levels replicate (sharding rules see flat-index child paths)."""
        from repro.launch import sharding as sh

        params = {"mlp": {"up": {"w": quant.encode(
            jnp.zeros((512, 512)), QScheme.int_symmetric(
                8, scaling="channel", rounding="nearest"))}}}
        specs = jax.tree_util.tree_map_with_path(
            lambda pth, leaf: sh.param_spec(pth, leaf), params)
        qt_specs = specs["mlp"]["up"]["w"]
        assert qt_specs.codes == P("data", "model")       # like a dense 'w'
        assert qt_specs.scale == P(None, None)            # replicated


class TestCheckpoint:
    def test_save_restore_qtensor_tree(self, tmp_path):
        x = jax.random.normal(KEY, (16, 8))
        tree = {
            "dense": {"w": quant.encode(x, QScheme.int_symmetric(
                8, scaling="channel", rounding="nearest"))},
            "step_scale": jnp.float32(0.5),
        }
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(3, tree, blocking=True)
        template = {
            "dense": {"w": quant.encode(jnp.zeros_like(x), QScheme.int_symmetric(
                8, scaling="channel", rounding="nearest"))},
            "step_scale": jnp.float32(0.0),
        }
        restored, manifest = mgr.restore(template)
        assert manifest["step"] == 3
        got = restored["dense"]["w"]
        assert isinstance(got, QTensor)
        np.testing.assert_array_equal(np.asarray(got.codes),
                                      np.asarray(tree["dense"]["w"].codes))
        np.testing.assert_array_equal(np.asarray(got.decode()),
                                      np.asarray(tree["dense"]["w"].decode()))

    def test_quantized_param_tree_roundtrip(self, tmp_path):
        """The serving weight format (qat.quantize_param_tree) checkpoints."""
        params = {"mlp": {"up": {"w": jax.random.normal(KEY, (8, 16))},
                          "norm": {"g": jnp.ones((8,))}}}
        qparams = qat.quantize_param_tree(params, bits=8)
        assert isinstance(qparams["mlp"]["up"]["w"], QTensor)
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, qparams, blocking=True)
        restored, _ = mgr.restore(jax.tree.map(jnp.zeros_like, qparams))
        np.testing.assert_array_equal(
            np.asarray(restored["mlp"]["up"]["w"].codes),
            np.asarray(qparams["mlp"]["up"]["w"].codes))


class TestShardMap:
    def test_compressed_psum_under_shard_map(self):
        mesh = Mesh(np.array(jax.devices()[:1]), ("pod",))
        psum = gradcomp.make_compressed_psum("pod", 8)
        g = {"a": jax.random.normal(KEY, (8, 4)),
             "b": jax.random.normal(jax.random.fold_in(KEY, 1), (16,))}
        f = shard_map(psum, mesh=mesh, in_specs=(P(), P()), out_specs=P(),
                      check_rep=False)
        out = f(g, KEY)
        # single member ⇒ the mean equals the dequantized compression of g
        comp, _ = gradcomp.compress_tree(g, 8, KEY)
        want = gradcomp.decompress_tree(comp)
        for k in g:
            np.testing.assert_allclose(np.asarray(out[k]),
                                       np.asarray(want[k]), rtol=1e-6)
            step = float(jnp.max(jnp.abs(g[k]))) / 127
            assert float(jnp.max(jnp.abs(out[k] - g[k]))) <= step + 1e-6


class TestPrecisionPlanRoundtrip:
    def test_to_from_dict(self):
        p = PrecisionPlan("e2e", sample_bits=6, model_bits=8, grad_bits=8,
                          kv_bits=4, model_storage="int")
        q = PrecisionPlan.from_dict(p.to_dict())
        assert p == q and hash(p) == hash(q)

    def test_legacy_kwargs_map_to_canonical(self):
        with pytest.warns(DeprecationWarning):
            p = PrecisionPlan(weight_bits=8, act_ds_bits=4,
                              weight_storage="int")
        assert (p.model_bits, p.act_bits, p.model_storage) == (8, 4, "int")

    def test_unknown_kwarg_rejected(self):
        with pytest.raises(TypeError):
            PrecisionPlan(frobnicate=3)

    def test_conflicting_legacy_and_canonical_kwargs_rejected(self):
        with pytest.raises(TypeError):
            PrecisionPlan(model_bits=4, weight_bits=8)
