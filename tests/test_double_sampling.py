"""Tests for C2/C3 (double sampling, e2e) and C6 (Chebyshev gradients)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import chebyshev as ch
from repro.core import double_sampling as ds
import repro.core.quantize as qz

KEY = jax.random.PRNGKey(0)


def _mc_gradient(grad_fn, n_mc=4096):
    keys = jax.random.split(KEY, n_mc)
    gs = jax.vmap(grad_fn)(keys)
    return gs.mean(0), gs.std(0) / np.sqrt(n_mc)


class TestDoubleSampling:
    def setup_method(self):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(42), 3)
        self.a = jax.random.normal(k1, (8, 16))
        self.x = jax.random.normal(k2, (16,)) * 2.0
        self.b = jax.random.normal(k3, (8,))
        self.g_full = ds.lsq_gradient_fullprec(self.x, self.a, self.b)

    def test_double_sampling_unbiased(self):
        """E[g_ds] = g_full — the paper's central claim (§2.2)."""
        mean, se = _mc_gradient(
            lambda k: ds.lsq_gradient_double_sampling(self.x, self.a, self.b, 3, k)
        )
        np.testing.assert_array_less(np.abs(mean - self.g_full), 5 * se + 1e-3)

    def test_naive_quantization_biased(self):
        """App. B.1: naive single-quantization estimator has bias D_a·x ≠ 0."""
        mean, se = _mc_gradient(
            lambda k: ds.lsq_gradient_naive_quant(self.x, self.a, self.b, 3, k)
        )
        bias = np.abs(np.asarray(mean - self.g_full))
        # bias must be statistically significant on at least some coordinates
        assert (bias > 6 * np.asarray(se)).sum() >= 4

    @pytest.mark.slow
    def test_e2e_unbiased(self):
        """App. E: model+gradient quantization keeps the estimator unbiased."""
        cfg = ds.DSConfig(s_sample=7, s_model=15, s_grad=15)
        mean, se = _mc_gradient(
            lambda k: ds.lsq_gradient_e2e(self.x, self.a, self.b, cfg, k), n_mc=8192
        )
        np.testing.assert_array_less(np.abs(mean - self.g_full), 5 * se + 5e-3)

    @pytest.mark.slow
    def test_variance_shrinks_with_bits(self):
        """Lemma 2 / Cor. 1: variance ~ 1/s² in the quantization term."""
        var = {}
        for s in (1, 3, 15):
            keys = jax.random.split(KEY, 2048)
            gs = jax.vmap(
                lambda k: ds.lsq_gradient_double_sampling(self.x, self.a, self.b, s, k)
            )(keys)
            var[s] = float(jnp.mean(jnp.sum((gs - self.g_full) ** 2, -1)))
        assert var[15] < var[3] < var[1]

    @pytest.mark.slow
    def test_polynomial_estimator_unbiased(self):
        """§4.1: Q(P) is unbiased for P(aᵀx) for any polynomial."""
        coeffs = jnp.asarray([0.5, -1.0, 0.25, 0.1])  # degree 3
        a = self.a[:4]
        truth = jnp.polyval(coeffs[::-1], a @ self.x)
        keys = jax.random.split(KEY, 16384)
        est = jax.vmap(lambda k: ds.polynomial_estimator(coeffs, a, self.x, 7, k))(keys)
        se = est.std(0) / np.sqrt(len(keys)) + 1e-6
        np.testing.assert_array_less(np.abs(est.mean(0) - truth), 6 * se + 1e-2)

    def test_storage_overhead_log2k(self):
        """§2.2: k samples of the same base cost log2(k) extra bits; check that
        the two double-sampling draws differ by at most one level step."""
        a = self.a
        scale = qz.row_scale(a)
        q1, q2 = ds.double_sample_pair(a, 7, KEY, scale=scale)
        diff_levels = jnp.abs(q1 - q2) / (scale / 7)
        assert float(diff_levels.max()) <= 1.0 + 1e-4


class TestChebyshev:
    def test_sigmoid_approx_error(self):
        for degree, tol in ((7, 0.05), (15, 0.01)):
            coeffs = ch.sigmoid_prime_coeffs(degree, R=4.0)
            z = np.linspace(-4, 4, 201)
            approx = ch.poly_eval(coeffs, z)
            exact = -1.0 / (1.0 + np.exp(z))
            assert np.max(np.abs(approx - exact)) < tol, degree

    def test_step_approx_outside_gap(self):
        coeffs = ch.step_coeffs(31, R=4.0, delta=0.25)
        z = np.linspace(-4, 4, 801)
        mask = np.abs(z) > 0.5
        approx = ch.poly_eval(coeffs, z)
        exact = (z >= 0).astype(float)
        assert np.max(np.abs(approx[mask] - exact[mask])) < 0.2

    @pytest.mark.slow
    def test_quantized_poly_gradient_matches_poly(self):
        """Protocol of §4.2: E[g] ≈ mean_b b·P(b aᵀx)·a (bias only from quant
        of the outer sample = 0, poly estimator unbiased)."""
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
        a = jax.random.normal(k1, (4, 6)) * 0.5
        x = jax.random.normal(k2, (6,))
        b = jnp.sign(jax.random.normal(k3, (4,)))
        coeffs = jnp.asarray(ch.sigmoid_prime_coeffs(5, R=4.0), jnp.float32)
        truth = (a * (b * jnp.polyval(coeffs[::-1], b * (a @ x)))[:, None]).mean(0)
        keys = jax.random.split(KEY, 30000)
        est = jax.vmap(lambda k: ch.quantized_poly_gradient(coeffs, x, a, b, 15, k))(keys)
        se = est.std(0) / np.sqrt(len(keys)) + 1e-6
        np.testing.assert_array_less(np.abs(est.mean(0) - truth), 6 * se + 2e-2)
