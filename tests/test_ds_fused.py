"""Fused double-sampling pipeline: ds_quant kernel parity + kernel registry.

Three layers of guarantees, matching the PR's acceptance criteria:
  * the fused Pallas ds_quant kernel is bit-exact with its pure-jnp oracle and
    structurally correct (shared base level → planes differ by ≤ 1 level);
  * the fused estimator is *distribution-identical* to two independent ref
    quantizations (fixed-seed marginals match within MC error) and unbiased
    (E[dequant] = x, E[g] = full-precision gradient);
  * the registry's 'ref' backend reproduces the seed core/quantize.py numerics
    bit-exactly, and selection (arg > select() > env > hardware) works.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.core.quantize as qz
from repro.core import double_sampling as ds
from repro.kernels import ops, ref, registry
from repro.kernels import stoch_quant as sq_mod

KEY = jax.random.PRNGKey(0)


def _mc_codes(x, scale, s, n_mc, seed=123):
    """Monte-Carlo fused code planes via the bit-exact oracle (fast pure jnp)."""
    rands = jax.random.bits(jax.random.PRNGKey(seed), (n_mc, *x.shape), jnp.uint32)
    return jax.vmap(lambda r: ref.ds_quant_ref(x, r, scale, s=s))(rands)


class TestFusedKernel:
    @pytest.mark.parametrize("shape", [
        (8, 128), (100, 260),
        pytest.param((256, 512), marks=pytest.mark.slow),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("s", [1, 7, 127])
    def test_bit_exact_vs_oracle(self, shape, dtype, s):
        x = (jax.random.normal(KEY, shape) * 3).astype(dtype)
        rand = jax.random.bits(jax.random.fold_in(KEY, 1), shape, jnp.uint32)
        scale = ref.row_absmax_ref(x)
        got1, got2 = sq_mod.ds_quant(x, rand, scale, s=s, interpret=True)
        want1, want2 = ref.ds_quant_ref(x, rand, scale, s=s)
        np.testing.assert_array_equal(np.asarray(got1), np.asarray(want1))
        np.testing.assert_array_equal(np.asarray(got2), np.asarray(want2))

    def test_bit_exact_column_scale(self):
        x = jax.random.normal(KEY, (64, 384))
        rand = jax.random.bits(jax.random.fold_in(KEY, 2), x.shape, jnp.uint32)
        scale = jnp.max(jnp.abs(x), axis=0, keepdims=True)  # (1, C)
        got1, got2 = sq_mod.ds_quant(x, rand, scale, s=15, scale_axis="col",
                                     interpret=True)
        want1, want2 = ref.ds_quant_ref(x, rand, scale, s=15)
        np.testing.assert_array_equal(np.asarray(got1), np.asarray(want1))
        np.testing.assert_array_equal(np.asarray(got2), np.asarray(want2))

    def test_int8_range_rejected(self):
        x = jnp.ones((8, 128))
        rand = jnp.zeros((8, 128), jnp.uint32)
        with pytest.raises(ValueError):
            sq_mod.ds_quant(x, rand, jnp.ones((8, 1)), s=255, interpret=True)

    def test_shared_base_one_level(self):
        """§2.2 storage claim: the planes share ⌊|x|s/M⌋, so they differ by at
        most one level — shipping Q₂ costs 1 bit, not another full plane."""
        x = jax.random.normal(KEY, (32, 256))
        c1, c2, _ = ops.ds_quantize(x, 7, jax.random.fold_in(KEY, 3))
        diff = np.abs(np.asarray(c1, np.int32) - np.asarray(c2, np.int32))
        assert diff.max() <= 1

    def test_unbiased_dequant(self):
        """E[dequant(cᵢ)] = x within MC error (acceptance criterion)."""
        x = jax.random.normal(KEY, (4, 96))
        scale = ref.row_absmax_ref(x)
        s = 7
        c1s, c2s = _mc_codes(x, scale, s, n_mc=4096)
        for cs in (c1s, c2s):
            deq = cs.astype(jnp.float32) / s * scale
            se = deq.std(0) / np.sqrt(deq.shape[0]) + 1e-6
            np.testing.assert_array_less(np.abs(deq.mean(0) - x), 6 * se + 1e-3)

    def test_marginals_match_ref_quantizer(self):
        """Each fused plane's per-coordinate code distribution matches the ref
        quantizer's (core/quantize.quantize) within MC error: same support
        {base, base+1}, same up-probability."""
        s = 7
        x = jax.random.normal(jax.random.fold_in(KEY, 4), (2, 64))
        scale = ref.row_absmax_ref(x)
        n_mc = 4096
        c1s, c2s = _mc_codes(x, scale, s, n_mc)
        keys = jax.random.split(jax.random.PRNGKey(7), n_mc)
        refs = jax.vmap(lambda k: qz.quantize(x, s, k, scale=scale).codes)(keys)
        # identical support
        assert set(np.unique(np.asarray(c1s))) <= set(np.unique(np.asarray(refs))) | \
            set(np.unique(np.asarray(refs) + np.sign(np.asarray(refs))))
        # per-coordinate mean code (≈ x·s/M) agrees within combined MC error
        for cs in (c1s, c2s):
            m_f = np.asarray(cs, np.float64).mean(0)
            m_r = np.asarray(refs, np.float64).mean(0)
            se = (np.asarray(cs, np.float64).std(0) +
                  np.asarray(refs, np.float64).std(0)) / np.sqrt(n_mc) + 1e-9
            np.testing.assert_array_less(np.abs(m_f - m_r), 6 * se + 2e-3 * s)

    def test_up_bits_independent_across_planes(self):
        """Q₁/Q₂ draws must be independent (the whole point of double
        sampling): P(up₁ ∧ up₂) = P(up₁)P(up₂) within MC error."""
        s = 7
        x = jnp.full((1, 128), 0.4321)
        scale = jnp.ones((1, 1))
        c1s, c2s = _mc_codes(x, scale, s, n_mc=8192)
        base = np.floor(0.4321 * s)
        up1 = (np.asarray(c1s, np.float64) > base).reshape(8192, -1)
        up2 = (np.asarray(c2s, np.float64) > base).reshape(8192, -1)
        p1, p2, p12 = up1.mean(), up2.mean(), (up1 * up2).mean()
        frac = 0.4321 * s - base
        n_eff = up1.size
        tol = 6 * np.sqrt(frac * (1 - frac) / n_eff) + 1e-2
        assert abs(p1 - frac) < tol and abs(p2 - frac) < tol
        assert abs(p12 - p1 * p2) < tol


class TestCodesGradient:
    def _problem(self, B=64, n=100):
        k = jax.random.fold_in(KEY, 5)
        a = jax.random.normal(k, (B, n))
        x = jax.random.normal(jax.random.fold_in(k, 1), (n,))
        b = jax.random.normal(jax.random.fold_in(k, 2), (B,))
        scale = jnp.maximum(jnp.max(jnp.abs(a), axis=0), 1e-12)
        return a, x, b, scale

    def test_matches_dequantized_math(self):
        """q₁ᵀ(q₂x−b) from int8 codes == the same math on dequantized f32
        tensors (up to fp32 accumulation order)."""
        a, x, b, scale = self._problem()
        s = 7
        c1, c2, sc = ops.ds_quantize(a, s, KEY, scale=scale)
        got = np.asarray(ops.ds_gradient_from_codes(c1, c2, x, b, sc, s))
        q1 = c1.astype(jnp.float32) / s * sc
        q2 = c2.astype(jnp.float32) / s * sc
        B = a.shape[0]
        want = np.asarray((q1.T @ (q2 @ x - b) + q2.T @ (q1 @ x - b)) / (2.0 * B))
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_fused_estimator_unbiased(self):
        """E[g_fused] = g_full over the fused estimator's own randomness
        (shared base + independent 16-bit up-draws)."""
        a, x, b, scale = self._problem(B=8, n=24)
        s = 3
        sc = scale[None, :]
        B = a.shape[0]
        g_full = ds.lsq_gradient_fullprec(x, a, b)

        def g_of(rand):
            c1, c2 = ref.ds_quant_ref(a, rand, sc, s=s)
            q1 = c1.astype(jnp.float32) / s * sc
            q2 = c2.astype(jnp.float32) / s * sc
            return (q1.T @ (q2 @ x - b) + q2.T @ (q1 @ x - b)) / (2.0 * B)

        rands = jax.random.bits(jax.random.PRNGKey(11), (4096, *a.shape),
                                jnp.uint32)
        gs = jax.vmap(g_of)(rands)
        se = np.asarray(gs.std(0)) / np.sqrt(gs.shape[0]) + 1e-6
        np.testing.assert_array_less(np.abs(np.asarray(gs.mean(0) - g_full)),
                                     6 * se + 1e-3)

    def test_pallas_backend_end_to_end(self):
        """Full registry dispatch: backend='pallas' gradient is finite, close
        in norm to full precision, and built without a f32 sample tensor."""
        a, x, b, scale = self._problem(B=32, n=64)
        g = ds.lsq_gradient_double_sampling(x, a, b, 7, KEY, scale=scale,
                                            backend="pallas")
        g_full = ds.lsq_gradient_fullprec(x, a, b)
        assert np.isfinite(np.asarray(g)).all()
        # single draw: within a few gradient norms (loose sanity, not MC)
        assert float(jnp.linalg.norm(g - g_full)) < 10 * float(
            jnp.linalg.norm(g_full) + 1.0)

    def test_uneven_contraction_blocks_exact(self):
        """Regression: padded dims that don't divide the 512 contraction block
        (e.g. 600 → 640) must not read out of bounds in qmv."""
        k = jax.random.fold_in(KEY, 13)
        codes = jax.random.randint(k, (64, 600), -127, 128).astype(jnp.int8)
        v = jax.random.normal(jax.random.fold_in(k, 1), (600,))
        got = np.asarray(ops.int8_matvec(codes, v))
        want = np.asarray(ref.qmv_ref(codes, v))
        assert np.isfinite(got).all()
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-2)
        # and through the full pallas gradient with n in the broken range
        a = jax.random.normal(k, (32, 600))
        x = jax.random.normal(jax.random.fold_in(k, 2), (600,))
        b = jax.random.normal(jax.random.fold_in(k, 3), (32,))
        g = ds.lsq_gradient_double_sampling(x, a, b, 7, KEY, backend="pallas")
        assert np.isfinite(np.asarray(g)).all()

    def test_default_scale_matches_ref_backend(self):
        """scale=None must resolve to the same global-scalar grid on both
        backends (ref semantics): every pallas value sits on ref's level grid."""
        a = jax.random.normal(KEY, (8, 128))
        q1, q2 = registry.get("pallas").ds_quant_values(a, 7, KEY)
        width = float(qz.row_scale(a)) / 7
        for q in (q1, q2):
            assert float(jnp.max(jnp.abs(q - a))) <= width + 1e-4
            on_grid = jnp.abs(q / width - jnp.round(q / width))
            assert float(on_grid.max()) < 1e-4

    def test_pallas_pair_within_one_level(self):
        """double_sample_pair(backend='pallas') values stay within one level
        width of the input — same invariant the ref pair satisfies."""
        a = jax.random.normal(KEY, (16, 128))
        scale = qz.row_scale(a)
        q1, q2 = ds.double_sample_pair(a, 7, KEY, scale=scale, backend="pallas")
        width = float(scale) / 7
        assert float(jnp.max(jnp.abs(q1 - a))) <= width + 1e-4
        assert float(jnp.max(jnp.abs(q2 - a))) <= width + 1e-4


class TestRegistry:
    def test_ref_pair_bit_exact(self):
        """registry 'ref' == the seed's two split-key stochastic_quantize calls."""
        a = jax.random.normal(KEY, (8, 16))
        scale = qz.row_scale(a)
        got1, got2 = registry.get("ref").ds_quant_values(a, 7, KEY, scale=scale)
        k1, k2 = jax.random.split(KEY)
        want1 = qz.stochastic_quantize(a, 7, k1, scale=scale)
        want2 = qz.stochastic_quantize(a, 7, k2, scale=scale)
        np.testing.assert_array_equal(np.asarray(got1), np.asarray(want1))
        np.testing.assert_array_equal(np.asarray(got2), np.asarray(want2))

    def test_ref_gradient_bit_exact(self):
        """registry 'ref' LSQ gradient == the original seed formula, bit-exact."""
        k = jax.random.fold_in(KEY, 9)
        a = jax.random.normal(k, (8, 16))
        x = jax.random.normal(jax.random.fold_in(k, 1), (16,))
        b = jax.random.normal(jax.random.fold_in(k, 2), (8,))
        got = ds.lsq_gradient_double_sampling(x, a, b, 3, KEY)
        k1, k2 = jax.random.split(KEY)
        q1 = qz.stochastic_quantize(a, 3, k1)
        q2 = qz.stochastic_quantize(a, 3, k2)
        want = (q1.T @ (q2 @ x - b) + q2.T @ (q1 @ x - b)) / (2.0 * 8)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_env_var_selection(self, monkeypatch):
        monkeypatch.delenv(registry.ENV_VAR, raising=False)
        registry.select(None)
        assert registry.get().name == registry.default_name()
        monkeypatch.setenv(registry.ENV_VAR, "pallas")
        assert registry.get().name == "pallas"
        monkeypatch.setenv(registry.ENV_VAR, "ref")
        assert registry.get().name == "ref"

    def test_select_overrides_env(self, monkeypatch):
        monkeypatch.setenv(registry.ENV_VAR, "ref")
        registry.select("pallas")
        try:
            assert registry.get().name == "pallas"
            # explicit argument still wins over select()
            assert registry.get("ref").name == "ref"
        finally:
            registry.select(None)

    def test_using_restores_previous_selection(self, monkeypatch):
        monkeypatch.delenv(registry.ENV_VAR, raising=False)
        registry.select(None)
        with registry.using("pallas") as be:
            assert be.name == "pallas"
            assert registry.get().name == "pallas"
        assert registry.get().name == registry.default_name()
        # None is a no-op passthrough
        with registry.using(None) as be:
            assert be.name == registry.get().name

    def test_unknown_backend_rejected(self):
        with pytest.raises(ValueError):
            registry.get("fpga")
        with pytest.raises(ValueError):
            registry.select("fpga")

    def test_available_lists_both(self):
        assert {"ref", "pallas"} <= set(registry.available())

    def test_resolve_accepts_instance(self):
        be = registry.get("ref")
        assert registry.resolve(be) is be
