"""Fault-tolerance tests: the deterministic injector itself (once-only
firing, replica filters, replayable audit log, seeded bit flips), per-request
NaN quarantine (injected AND genuinely NaN KV), prefix-trie checksum eviction
at every KV precision, the per-request retry budget, and the ReplicaSet
health machine — stall → suspect → recover, raise → dead → harvest/migrate →
restart, restart-failure → FAILED, submit fail-fast, and the all-replicas-
failed terminal error. Everything runs on the virtual clock: zero wall-time
waits, bit-identical replays."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import transformer as T
from repro.quant import PrecisionPlan
from repro.serve import (FaultInjector, FaultSpec, ReplicaDeviceLost,
                         Request, ServeEngine, VirtualClock)
from repro.serve.faults import corrupt_kv_page, flip_bits

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def tiny_model():
    cfg = configs.get_reduced("qwen2.5-14b")
    return cfg, T.init_params(KEY, cfg)


def _engine(params, cfg, **kw):
    kw.setdefault("plan", PrecisionPlan(kv_bits=8))
    kw.setdefault("max_slots", 2)
    kw.setdefault("page_size", 4)
    kw.setdefault("max_seq_len", 32)
    kw.setdefault("clock", VirtualClock())
    return ServeEngine(params, cfg, **kw)


def _reqs(n, cfg, *, prompt_len=6, gen=4, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, prompt_len),
                    max_new_tokens=gen) for i in range(n)]


class TestFaultInjector:
    def test_spec_validation(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            FaultSpec("meteor_strike", at_step=1)
        with pytest.raises(ValueError, match="at_step"):
            FaultSpec("replica_raise", at_step=-1)

    def test_fires_once_with_audit_log(self):
        clock = VirtualClock()
        inj = FaultInjector([FaultSpec("nan_logits", at_step=3, rid=7)],
                            clock=clock)
        assert inj.poll("nan_logits", step=2) == []
        clock.advance(1.5)
        fired = inj.poll("nan_logits", step=3)
        assert [sp.rid for sp in fired] == [7]
        assert inj.n_armed == 0
        # once-only: the same poll never fires the spec again
        assert inj.poll("nan_logits", step=3) == []
        assert inj.poll("nan_logits", step=99) == []
        (rec,) = inj.fired
        assert rec["kind"] == "nan_logits" and rec["step"] == 3
        assert rec["t"] == 1.5

    def test_replica_filter_and_late_fire(self):
        inj = FaultInjector([FaultSpec("replica_raise", at_step=2, replica=1)])
        assert inj.poll("replica_raise", step=5, replica=0) == []
        # a fault whose step was missed fires on the next matching poll
        assert len(inj.poll("replica_raise", step=5, replica=1)) == 1

    def test_virtual_clock_monotonic(self):
        clock = VirtualClock(t0=2.0)
        assert clock() == 2.0
        clock.advance(0.25)
        assert clock() == 2.25
        with pytest.raises(ValueError, match="forward"):
            clock.advance(-0.1)

    def test_flip_bits_seeded_and_pure(self):
        a = np.zeros(16, np.float32)
        b1 = flip_bits(a, n_flips=3, seed=9)
        b2 = flip_bits(a, n_flips=3, seed=9)
        np.testing.assert_array_equal(b1, b2)            # replayable
        assert not np.array_equal(flip_bits(a, n_flips=3, seed=10), b1)
        assert np.all(a == 0)                            # input untouched
        changed = np.unpackbits(b1.view(np.uint8)).sum()
        assert 1 <= changed <= 3


class TestNaNQuarantine:
    def test_injected_nan_fails_one_request_not_engine(self, tiny_model):
        cfg, params = tiny_model
        clean = _engine(params, cfg).run(_reqs(4, cfg, gen=8))

        inj = FaultInjector([FaultSpec("nan_logits", at_step=4, rid=1)])
        eng = _engine(params, cfg, fault_injector=inj)
        out = eng.run(_reqs(4, cfg, gen=8))
        assert sorted(out) == [0, 1, 2, 3]
        assert out[1].reason == "nan"
        assert eng.stats["quarantined"] == 1
        for rid in (0, 2, 3):
            assert clean[rid].reason in ("eos", "length")
            np.testing.assert_array_equal(clean[rid].tokens, out[rid].tokens)
        eng.allocator.check_leaks(0)

    def test_real_nan_kv_detected_and_scrubbed(self, tiny_model):
        """Not just the injected flag: genuinely non-finite KV rows must trip
        the per-slot isfinite guard. NaNs are written into one active slot's
        private page mid-run (kv_bits=0 — raw float pool) and that slot alone
        must quarantine; freed pages are scrubbed so the next owner never
        attends the poison (the 0×NaN softmax hole)."""
        cfg, params = tiny_model
        eng = _engine(params, cfg, plan=PrecisionPlan(kv_bits=0))
        reqs = _reqs(4, cfg, gen=6)
        for r in reqs:
            eng.submit(r)
        done = {}
        poisoned = None
        for _ in range(60):
            if poisoned is None and eng.n_active >= 2:
                slot = int(np.flatnonzero(eng._active)[0])
                poisoned = eng._slots[slot]["req"].rid
                page = int(eng._slots[slot]["pages"][0])
                eng.pool = eng.pool._replace(
                    k_pages=eng.pool.k_pages.at[:, page].set(jnp.nan))
            for f in eng.step():
                done[f.rid] = f
            if not eng.busy:
                break
        assert poisoned is not None
        assert sorted(done) == [0, 1, 2, 3]
        assert done[poisoned].reason == "nan"
        assert eng.stats["quarantined"] == 1
        for rid in done:
            if rid != poisoned:
                assert done[rid].reason in ("eos", "length")
        eng.allocator.check_leaks(0)
        # scrubbed on free: no page in the pool still carries the NaN rows
        assert bool(jnp.isfinite(eng.pool.k_pages).all())

    def test_kv_flip_fault_counts(self, tiny_model):
        """An injected KV bit flip lands in an allocated page and is counted;
        the engine keeps serving (the flip may or may not change tokens —
        that is the trie-checksum tests' business, not this one's)."""
        cfg, params = tiny_model
        inj = FaultInjector([FaultSpec("kv_flip", at_step=3, n_flips=2,
                                       seed=5)])
        eng = _engine(params, cfg, fault_injector=inj)
        out = eng.run(_reqs(3, cfg))
        assert len(out) == 3
        assert eng.stats["kv_flips"] == 1
        assert inj.n_armed == 0
        eng.allocator.check_leaks(0)


class TestTrieChecksum:
    @pytest.mark.parametrize("kv_bits", [0, 8, 4])
    def test_corrupt_shared_page_evicted_not_attended(self, tiny_model,
                                                      kv_bits):
        """Bit-flip a cached prefix page between two waves of the same
        prompt family: the checksum check at use() must evict it (and its
        descendants), the second wave re-prefills cold and stays
        token-identical to a cache-less engine, and the corruption never
        spreads to any output."""
        cfg, params = tiny_model
        rng = np.random.default_rng(3)
        sys_prompt = rng.integers(0, cfg.vocab_size, 8)   # 2 full pages
        suffixes = [rng.integers(0, cfg.vocab_size, 3) for _ in range(3)]

        def wave():
            return [Request(rid=i,
                            prompt=np.concatenate([sys_prompt, suffixes[i]]),
                            max_new_tokens=4)
                    for i in range(3)]

        cold = _engine(params, cfg, plan=PrecisionPlan(kv_bits=kv_bits),
                       chunk_pages=1)
        cold_out = cold.run(wave())
        cold.allocator.check_leaks(0)

        warm = _engine(params, cfg, plan=PrecisionPlan(kv_bits=kv_bits),
                       prefix_cache=True, chunk_pages=1)
        warm.run(wave())                      # wave 1 populates the trie
        victim = warm.prefix.match(
            np.asarray(wave()[0].prompt, np.int32))[0]
        warm.pool = corrupt_kv_page(warm.pool, victim, n_flips=3, seed=11)
        warm_out = warm.run(wave())
        assert warm.prefix.corrupt_evictions >= 1
        for rid in cold_out:
            np.testing.assert_array_equal(cold_out[rid].tokens,
                                          warm_out[rid].tokens)
        warm.release_prefix_cache()
        warm.allocator.check_leaks(0)

    def test_checksums_stamped_on_insert(self, tiny_model):
        cfg, params = tiny_model
        eng = _engine(params, cfg, prefix_cache=True, chunk_pages=1)
        eng.run(_reqs(2, cfg, prompt_len=9, seed=4))
        nodes = list(eng.prefix._root.children.values())
        assert nodes, "trie should have cached prompt pages"
        while nodes:
            n = nodes.pop()
            assert n.checksum == eng._page_checksum(n.page)
            nodes.extend(n.children.values())
        eng.release_prefix_cache()
        eng.allocator.check_leaks(0)


class TestRetryBudget:
    def test_exhausted_retries_fail_with_status(self, tiny_model):
        cfg, params = tiny_model
        eng = _engine(params, cfg, retry_budget=2)
        req = _reqs(1, cfg)[0]
        eng.submit_entry({"req": req,
                          "prompt": np.asarray(req.prompt, np.int32),
                          "t_submit": 0.0, "retries": 3})
        out = eng.run()
        assert out[req.rid].reason == "retries"
        assert out[req.rid].n_generated == 0
        assert eng.stats["retries_exhausted"] == 1
        eng.allocator.check_leaks(0)

    def test_within_budget_request_completes(self, tiny_model):
        cfg, params = tiny_model
        eng = _engine(params, cfg, retry_budget=3)
        req = _reqs(1, cfg)[0]
        eng.submit_entry({"req": req,
                          "prompt": np.asarray(req.prompt, np.int32),
                          "t_submit": 0.0, "retries": 3})
        out = eng.run()
        assert out[req.rid].reason in ("eos", "length")

    def test_budget_validation(self, tiny_model):
        cfg, params = tiny_model
        with pytest.raises(ValueError, match="retry_budget"):
            _engine(params, cfg, retry_budget=0)


def _replica_set(params, cfg, n=2, *, faults=None, health=None, factory=None,
                 ship_dir=None, **ekw):
    from repro.launch.serve import HealthConfig, ReplicaSet

    clock = VirtualClock()
    if faults is not None:
        faults.clock = clock

    def default_factory(i):
        return ServeEngine(params, cfg, plan=PrecisionPlan(kv_bits=8),
                           max_slots=2, page_size=4, max_seq_len=32,
                           clock=clock, fault_injector=faults, replica_id=i,
                           **ekw)

    rs = ReplicaSet(factory or default_factory, n, clock=clock,
                    fault_injector=faults,
                    health=health or HealthConfig(
                        step_deadline_s=1.0, dead_after=2,
                        restart_backoff_s=0.1, backoff_cap_s=0.5,
                        max_restarts=2),
                    ship_dir=ship_dir)
    return rs, clock


def _settle(rs, clock, max_steps=200):
    """Step an idle ReplicaSet until its health machine reaches a fixed
    point (the drain can finish before the last restart backoff elapses)."""
    for _ in range(max_steps):
        if all(h.state in ("healthy", "failed") for h in rs.health):
            return
        clock.advance(0.05)
        rs.step()
    raise AssertionError("health machine did not settle")


def _drain(rs, clock, reqs=(), max_steps=500):
    for r in reqs:
        rs.submit(r)
    out = {}
    for _ in range(max_steps):
        if not rs._queue and not any(e.busy for e in rs.engines):
            return out
        for rid, f in rs.step().items():
            assert rid not in out, f"request {rid} finished twice"
            out[rid] = f
        clock.advance(0.01)
    raise AssertionError("drain did not converge")


class TestReplicaHealth:
    def test_stall_suspect_then_recover(self, tiny_model):
        cfg, params = tiny_model
        faults = FaultInjector([
            FaultSpec("replica_stall", at_step=3, replica=0, stall_s=5.0)])
        rs, clock = _replica_set(params, cfg, faults=faults)
        out = _drain(rs, clock, _reqs(6, cfg))
        assert len(out) == 6
        assert rs.stats["step_failures"] == 1
        assert rs.stats["deaths"] == 0
        h = rs.health[0]
        assert h.state == "healthy"                       # recovered
        states = [t[2] for t in h.transitions]
        assert states == ["suspect", "healthy"]

    def test_death_migration_restart_token_identical(self, tiny_model):
        cfg, params = tiny_model
        reqs = _reqs(8, cfg, gen=6)
        rs, clock = _replica_set(params, cfg)
        clean = _drain(rs, clock, reqs)

        faults = FaultInjector([
            FaultSpec("replica_raise", at_step=s, replica=0)
            for s in (4, 5)])
        rs, clock = _replica_set(params, cfg, faults=faults)
        out = _drain(rs, clock, _reqs(8, cfg, gen=6))
        assert len(out) == 8
        assert rs.stats["deaths"] == 1
        assert rs.stats["migrated"] >= 1
        assert rs.stats["restarts"] == 1
        assert rs.health[0].state == "healthy"            # restarted
        states = [t[2] for t in rs.health[0].transitions]
        assert states == ["suspect", "dead", "recovering", "healthy"]
        for rid in clean:                                 # bit-exact replay
            np.testing.assert_array_equal(clean[rid].tokens, out[rid].tokens)
        for e in rs.engines:
            e.allocator.check_leaks(0)

    def test_restart_failure_exhausts_to_failed(self, tiny_model):
        cfg, params = tiny_model
        clock = VirtualClock()
        faults = FaultInjector(
            [FaultSpec("replica_raise", at_step=s, replica=0)
             for s in (3, 4)], clock=clock)
        built = [0, 0]

        def factory(i):
            built[i] += 1
            if i == 0 and built[0] > 1:                  # every rebuild dies
                raise RuntimeError("device gone for good")
            return ServeEngine(params, cfg, plan=PrecisionPlan(kv_bits=8),
                               max_slots=2, page_size=4, max_seq_len=32,
                               clock=clock, fault_injector=faults,
                               replica_id=i)

        from repro.launch.serve import HealthConfig, ReplicaSet
        rs = ReplicaSet(factory, 2, clock=clock, fault_injector=faults,
                        health=HealthConfig(step_deadline_s=1.0, dead_after=2,
                                            restart_backoff_s=0.1,
                                            backoff_cap_s=0.5,
                                            max_restarts=2))
        out = _drain(rs, clock, _reqs(8, cfg, gen=6))
        assert len(out) == 8                              # survivor drained it
        _settle(rs, clock)                 # let the last backoff run down
        assert rs.health[0].state == "failed"
        assert rs.health[0].restarts == 2
        assert "device gone" in rs.health[0].last_error
        assert rs.health[1].state == "healthy"

    def test_all_replicas_failed_raises(self, tiny_model):
        cfg, params = tiny_model
        clock = VirtualClock()
        faults = FaultInjector(
            [FaultSpec("replica_raise", at_step=s, replica=0)
             for s in (2, 3)], clock=clock)
        built = [0]

        def factory(i):
            built[0] += 1
            if built[0] > 1:
                raise RuntimeError("no devices left")
            return ServeEngine(params, cfg, plan=PrecisionPlan(kv_bits=8),
                               max_slots=2, page_size=4, max_seq_len=32,
                               clock=clock, fault_injector=faults,
                               replica_id=i)

        from repro.launch.serve import HealthConfig, ReplicaSet
        rs = ReplicaSet(factory, 1, clock=clock, fault_injector=faults,
                        health=HealthConfig(step_deadline_s=1.0, dead_after=2,
                                            restart_backoff_s=0.1,
                                            backoff_cap_s=0.2,
                                            max_restarts=1))
        with pytest.raises(RuntimeError, match="failed permanently"):
            rs.run(_reqs(4, cfg))

    def test_submit_fail_fast_rejects_unservable(self, tiny_model):
        cfg, params = tiny_model
        rs, clock = _replica_set(params, cfg)
        with pytest.raises(ValueError, match="no replica can ever admit"):
            rs.submit(Request(rid=0, prompt=np.arange(100),
                              max_new_tokens=4))
        assert rs.stats["rejected"] == 1
        with pytest.raises(ValueError, match="no replica"):
            rs.submit(Request(rid=1, prompt=np.zeros(0, np.int32),
                              max_new_tokens=4))
        assert rs.stats["rejected"] == 2
        # a servable request still goes through
        out = _drain(rs, clock, _reqs(2, cfg))
        assert len(out) == 2

    def test_dispatch_avoids_dead_replica(self, tiny_model):
        cfg, params = tiny_model
        faults = FaultInjector([
            FaultSpec("replica_raise", at_step=s, replica=0)
            for s in (2, 3)])
        from repro.launch.serve import HealthConfig
        # backoff far beyond the trace: replica 0 stays dead throughout
        rs, clock = _replica_set(
            params, cfg, faults=faults,
            health=HealthConfig(step_deadline_s=1.0, dead_after=2,
                                restart_backoff_s=1e6, backoff_cap_s=1e6,
                                max_restarts=1))
        before = None
        out = {}
        for r in _reqs(8, cfg):
            rs.submit(r)
        for _ in range(500):
            if not rs._queue and not any(e.busy for e in rs.engines):
                break
            if rs.health[0].state == "dead" and before is None:
                before = rs.dispatched[0]
            out.update(rs.step())
            clock.advance(0.01)
        assert len(out) == 8
        assert rs.health[0].state == "dead"
        assert rs.dispatched[0] == before                 # nothing after death

    def test_ship_truncate_fault_fails_restart(self, tiny_model, tmp_path):
        """The ship_truncate fault corrupts the artifact between death and
        restart: the rebuild raises ShipArtifactError, retries exhaust, and
        the replica lands in FAILED while the survivor drains the trace."""
        from repro.ckpt import (ShipArtifactError, load_ship_weights,
                                save_ship_weights)
        from repro.launch.serve import HealthConfig, ReplicaSet
        from repro.precision.qat import quantize_param_tree

        cfg, params = tiny_model
        ship = str(tmp_path / "ship")
        save_ship_weights(ship,
                          quantize_param_tree(params, bits=8,
                                              layout="bitplane"))
        clock = VirtualClock()
        faults = FaultInjector(
            [FaultSpec("replica_raise", at_step=s, replica=0)
             for s in (3, 4)]
            + [FaultSpec("ship_truncate", at_step=0, replica=0)],
            clock=clock)

        def factory(i):
            load_ship_weights(ship)        # the restart path reloads
            return ServeEngine(params, cfg, plan=PrecisionPlan(kv_bits=8),
                               max_slots=2, page_size=4, max_seq_len=32,
                               clock=clock, fault_injector=faults,
                               replica_id=i)

        rs = ReplicaSet(factory, 2, clock=clock, fault_injector=faults,
                        health=HealthConfig(step_deadline_s=1.0, dead_after=2,
                                            restart_backoff_s=0.1,
                                            backoff_cap_s=0.2,
                                            max_restarts=1),
                        ship_dir=ship)
        out = _drain(rs, clock, _reqs(8, cfg, gen=6))
        assert len(out) == 8
        _settle(rs, clock)
        assert rs.health[0].state == "failed"
        assert "ShipArtifactError" in rs.health[0].last_error
        with pytest.raises(ShipArtifactError):
            load_ship_weights(ship)


class TestAutoscalerLogCap:
    def test_decision_log_is_ring_buffer(self):
        from repro.serve import AutoscalerConfig, PrecisionAutoscaler

        asc = PrecisionAutoscaler(AutoscalerConfig(
            slo_admit_ms=10.0, bits_ladder=(8, 4, 2, 1),
            breach_patience=1, restore_patience=1, decision_log_max=4))
        for _ in range(3):                 # walk down the ladder: 3 drops
            asc.observe(admit_wait_ms=100.0)
        for _ in range(3):                 # walk back up: 3 restores
            asc.observe(admit_wait_ms=0.0)
        for _ in range(3):
            asc.observe(admit_wait_ms=100.0)
        assert asc.n_moves == 9
        assert len(asc.decisions) == 4     # ring kept only the newest 4
        assert [d["action"] for d in asc.decisions] == \
            ["restore", "drop", "drop", "drop"]

    def test_log_max_validation(self):
        from repro.serve import AutoscalerConfig

        with pytest.raises(ValueError, match="decision_log_max"):
            AutoscalerConfig(decision_log_max=0)
