"""The train step, built from the four channel objects over a TrainState.

``make_step(cfg, opt_cfg, channels)`` returns a pure jit-able
``step(state, batch) → (state, metrics)``. Channel state — notably the grad
channel's error-feedback residual — enters and leaves through
``TrainState.channels``, so it actually updates across steps under ``jit``
(the old ``grad_transform=fn(grads, key)`` closure hook could not thread
state: jit's trace-once semantics froze whatever the closure captured).

Per-step RNG discipline (bit-compatible with the seed driver): the step key
is ``fold_in(state.rng, state.step)``; it splits into the same three lanes
the legacy step used — kq (model channel / QAT), kg (grad channel), km
(quantized moments) — plus a fourth derived lane for the sample channel
(inactive outside the 'e2e' plan mode, so legacy numerics are unchanged).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch import sharding as shd
from repro.models import transformer as T
from repro.models.layers import shard_hint
from repro.optim import adamw
from repro.train.channels import Channel, default_channels
from repro.train.state import TrainState


def make_grads_fn(cfg: T.ModelConfig, model_channel: Channel,
                  accum_steps: int = 1):
    """Returns grads_of(params, batch, kq) → (loss, grads) with the model
    channel applied inside the loss (QAT fake-quant / ship-quant) and
    optional microbatch gradient accumulation."""

    def grads_of_one(params, tokens, targets, vision, kq):
        def loss(p):
            p, _ = model_channel.apply(p, {}, kq)
            return T.loss_fn(p, tokens, targets, cfg, vision_tokens=vision)
        return jax.value_and_grad(loss)(params)

    def grads_of(params, batch, kq):
        if accum_steps == 1:
            return grads_of_one(params, batch["tokens"], batch["targets"],
                                batch.get("vision"), kq)

        def resh(t):
            return t.reshape(accum_steps, t.shape[0] // accum_steps,
                             *t.shape[1:])
        mb = jax.tree.map(resh, dict(batch))

        def constrain(tree):
            # grad accumulators must live on the param sharding — without
            # the constraint GSPMD replicates the f32 accumulator tree
            return jax.tree_util.tree_map_with_path(
                lambda path, g: shard_hint(g, shd.param_spec(path, g)), tree)

        def micro(carry, mb_i):
            g_acc, l_acc = carry
            lv, g = grads_of_one(params, mb_i["tokens"], mb_i["targets"],
                                 mb_i.get("vision"), kq)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            return (constrain(g_acc), l_acc + lv), None

        zeros = constrain(jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))
        (g_sum, l_sum), _ = jax.lax.scan(micro, (zeros, jnp.float32(0.0)), mb)
        grads = jax.tree.map(lambda g: g / accum_steps, g_sum)
        return l_sum / accum_steps, grads

    return grads_of


def make_step(cfg: T.ModelConfig, opt_cfg: adamw.AdamWConfig,
              channels: dict[str, Channel] | None = None,
              accum_steps: int = 1):
    """Returns step(state: TrainState, batch) → (TrainState, metrics).

    ``batch``: {"tokens": (B,S), "targets": (B,S)[, "vision": (B,nv,d)]}.
    The batch must be the one at the state's cursor (``state.step``); the
    returned state has ``step`` advanced, channel state updated, and the same
    ``rng`` lane (per-step keys derive from it).
    """
    channels = channels if channels is not None else \
        default_channels(cfg.precision)
    grads_of = make_grads_fn(cfg, channels["model"], accum_steps)

    def step(state: TrainState, batch):
        key = jax.random.fold_in(state.rng, state.step)
        kq, kg, km = jax.random.split(key, 3)
        ks = jax.random.fold_in(key, 3)

        ch = dict(state.channels)
        batch, ch["sample"] = channels["sample"].apply(
            batch, ch.get("sample", {}), ks)
        loss_val, grads = grads_of(state.params, batch, kq)
        grads, ch["grad"] = channels["grad"].apply(
            grads, ch.get("grad", {}), kg)
        mkey = km if opt_cfg.moment_bits else None
        params, opt, metrics = adamw.apply_updates(
            state.params, grads, state.opt, opt_cfg, key=mkey)
        metrics["loss"] = loss_val
        new_state = TrainState(params, opt, ch, state.step + 1, state.rng,
                               state.epoch)
        return new_state, metrics

    return step
