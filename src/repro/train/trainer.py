"""Trainer — the one object that owns a training run.

Composes the pieces that used to be scattered across ``launch/steps.py``
(step building), ``launch/train.py`` (supervisor/restart loop),
``launch/elastic.py`` (fleet resize) and ``ckpt/checkpoint.py``
(persistence) behind one API::

    cfg = configs.get_reduced("gemma-2b", precision=PrecisionPlan(grad_bits=8))
    tr = Trainer(cfg, AdamWConfig(moment_bits=8),
                 stream_cfg=TokenStreamConfig(cfg.vocab_size, 64, 8),
                 ckpt_dir="/ckpt")
    state, losses = tr.run(steps=1000)

The checkpoint is the *full* :class:`~repro.train.state.TrainState` —
error-feedback residuals and quantized optimizer moments included — so
restart is bit-exact (pinned by tests/test_trainer.py). Checkpoints written
by the pre-Trainer driver ((params, opt_state) pairs, MomentQ moment
splices) restore through a load-time shim with a DeprecationWarning.

Elastic composition: feed an :class:`~repro.launch.elastic.ElasticController`
decision to :meth:`Trainer.apply_fleet_decision` — the data stream reshards
to the surviving hosts, and on shrink/grow the state rolls back to the last
committed checkpoint with the cursor rewound alongside it (nothing skipped,
nothing repeated beyond the rollback window).
"""
from __future__ import annotations

import collections
import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import CheckpointManager
from repro.data.pipeline import Cursor, TokenStream, TokenStreamConfig
from repro.launch.elastic import FleetDecision
from repro.models import transformer as T
from repro.optim import adamw
from repro.train.channels import Channel, default_channels
from repro.train.state import TrainState, init_state
from repro.train.step import make_step

CKPT_FORMAT = "trainstate-v1"


class StragglerMonitor:
    """Per-step timing ring buffer; flags hosts >3σ behind the fleet.

    On a synchronous pjit pod, one slow host gates every collective — the
    monitor's job is detection + data-shard rebalance advice, not recovery
    (recovery = evict + elastic restore, exercised in tests/test_trainer).
    """

    def __init__(self, window: int = 50):
        self.times = collections.deque(maxlen=window)
        self.flagged = 0

    def record(self, dt: float) -> bool:
        self.times.append(dt)
        if len(self.times) < 10:
            return False
        mu = float(np.mean(self.times))
        sd = float(np.std(self.times)) + 1e-9
        if dt > mu + 3 * sd:
            self.flagged += 1
            return True
        return False


class Trainer:
    """One training run: state init, jitted channel-composed step,
    supervisor loop with checkpoint/restart, elastic data resharding."""

    def __init__(self, cfg: T.ModelConfig,
                 opt_cfg: adamw.AdamWConfig | None = None, *,
                 stream_cfg: TokenStreamConfig | None = None,
                 channels: dict[str, Channel] | None = None,
                 error_feedback: bool = True, accum_steps: int = 1,
                 ckpt_dir: str | None = None, ckpt_every: int = 20,
                 log_every: int = 10, seed: int = 0,
                 max_restarts: int = 8, restart_backoff_s: float = 0.0):
        if max_restarts < 0:
            raise ValueError(f"max_restarts must be >= 0, got {max_restarts}")
        self.cfg = cfg
        self.plan = cfg.precision
        self.opt_cfg = opt_cfg if opt_cfg is not None else adamw.AdamWConfig()
        self.channels = channels if channels is not None else \
            default_channels(self.plan, error_feedback=error_feedback)
        self.accum_steps = accum_steps
        self.ckpt_every = ckpt_every
        self.log_every = log_every
        # supervisor restart cap: a *deterministic* crash (bad batch, code
        # bug) restores to the same step and crashes again forever without
        # one — after max_restarts consecutive failures with no forward
        # progress the underlying error propagates to the caller
        self.max_restarts = int(max_restarts)
        self.restart_backoff_s = float(restart_backoff_s)
        self.key = jax.random.PRNGKey(seed)
        self.stream_cfg = stream_cfg
        self.stream = TokenStream(stream_cfg) if stream_cfg else None
        self.mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self.monitor = StragglerMonitor()
        self._step_fn = jax.jit(
            make_step(cfg, self.opt_cfg, self.channels, accum_steps))

    # ------------------------------------------------------------ lifecycle --
    def init_state(self, key: jax.Array | None = None) -> TrainState:
        key = self.key if key is None else key
        params = T.init_params(key, self.cfg)
        opt = adamw.init(params, self.opt_cfg)
        ch = {name: c.init(params) for name, c in self.channels.items()}
        return init_state(params, opt, ch, key)

    def state_template(self) -> TrainState:
        """ShapeDtypeStruct skeleton of the run state (no allocation)."""
        return jax.eval_shape(lambda: self.init_state())

    # ----------------------------------------------------------------- step --
    def step(self, state: TrainState, batch: dict):
        """One training step. ``batch`` must be the one at ``state.step``
        (numpy or jnp leaves; vlm runs get zero vision stand-ins)."""
        batch_j = {k: jnp.asarray(v) for k, v in batch.items()}
        if self.cfg.family == "vlm" and "vision" not in batch_j:
            b = batch_j["tokens"].shape[0]
            batch_j["vision"] = jnp.zeros(
                (b, self.cfg.n_vis_tokens, self.cfg.d_model), jnp.float32)
        return self._step_fn(state, batch_j)

    # ---------------------------------------------------------- checkpoints --
    def _manifest_format(self, step: int | None) -> str | None:
        """The ``format`` field of a checkpoint's manifest (None = legacy)."""
        import json
        import os

        self.mgr.wait()
        if step is None:
            step = self.mgr.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.mgr.directory}")
        path = os.path.join(self.mgr.directory, f"step_{step:09d}",
                            "manifest.json")
        with open(path) as f:
            return json.load(f).get("extra", {}).get("format")

    def save(self, state: TrainState, blocking: bool = False):
        if self.mgr is None:
            raise RuntimeError("Trainer built without ckpt_dir")
        self.mgr.save(int(state.step), state,
                      extra={"cursor": state.cursor.to_dict(),
                             "precision": self.plan.to_dict(),
                             "format": CKPT_FORMAT},
                      blocking=blocking)

    def restore(self, step: int | None = None) -> tuple[TrainState, dict]:
        """Restore a TrainState checkpoint; legacy (params, opt_state) pairs
        (including MomentQ moment splices) go through the load-time shim.
        Format dispatch reads the manifest's ``format`` field — a mismatched
        *new*-format checkpoint (e.g. plan drift) raises its real error
        instead of being retried as a legacy pair."""
        if self.mgr is None:
            raise RuntimeError("Trainer built without ckpt_dir")
        template = self.state_template()
        if self._manifest_format(step) == CKPT_FORMAT:
            return self.mgr.restore(template, step=step)
        legacy_t = (template.params,
                    adamw.legacy_moment_template(template.opt))
        (params, opt), manifest = self.mgr.restore(legacy_t, step=step)
        if self.opt_cfg.moment_bits:
            opt = adamw.migrate_legacy_moments(opt, self.opt_cfg.moment_bits)
        ch = {name: c.init(params) for name, c in self.channels.items()}
        cursor = Cursor.from_dict(manifest["extra"]["cursor"])
        state = init_state(params, opt, ch, self.key,
                           step=manifest["step"], epoch=cursor.epoch)
        return state, manifest

    # -------------------------------------------------------------- elastic --
    def apply_fleet_decision(self, decision: FleetDecision,
                             state: TrainState,
                             host_id: int = 0) -> TrainState:
        """Apply an ElasticController decision: reshard this host's slice of
        the data stream to the surviving fleet, and — when pods were evicted —
        roll back to the decision's restore step. The stream cursor rewinds
        with the restored state, so the resumed run consumes exactly the
        deterministic batch sequence from the rollback point."""
        from repro.launch.elastic import stream_sharding

        if self.stream_cfg is None:
            raise RuntimeError("Trainer built without stream_cfg")
        if decision.n_pods == 0:
            raise RuntimeError(f"fleet halt: {decision.reason}")
        n_hosts, shard = stream_sharding(decision, host_id)
        self.stream_cfg = dataclasses.replace(
            self.stream_cfg, n_hosts=n_hosts, host_id=shard)
        if decision.restore_step is not None and self.mgr is not None \
                and self.mgr.latest_step() is not None:
            state, _ = self.restore(step=decision.restore_step)
        self.stream = TokenStream(self.stream_cfg)
        self.stream.skip_to(state.cursor)
        return state

    # ----------------------------------------------------------- supervisor --
    def run(self, steps: int, *, state: TrainState | None = None,
            fail_at: int | None = None, fail_count: int | None = 1):
        """The supervisor loop: resume-from-checkpoint, NaN-skip (inside the
        optimizer), straggler flagging, restore-and-replay on step failure.
        Returns (final TrainState, losses) — replayed steps re-append, so
        ``len(losses) ≥ steps`` when faults occurred.

        ``fail_at``/``fail_count`` inject a crash at that step the first
        ``fail_count`` times it is reached (``None`` = every time — a
        deterministic crash the restart loop can never outrun). Restarts
        without forward progress are capped at ``max_restarts``; past the
        cap the underlying error propagates instead of looping forever."""
        if self.stream is None:
            raise RuntimeError("Trainer built without stream_cfg")
        if state is None:
            state = self.init_state()
            if self.mgr and self.mgr.latest_step() is not None:
                state, _ = self.restore()
                print(f"[train] resumed from step {int(state.step)}")
        self.stream.skip_to(state.cursor)

        losses = []
        fired = 0
        # restart accounting: crashes only count against the cap while the
        # run is stuck at the same high-water step — any forward progress
        # (checkpoint replay reaching a new step) resets the count
        high_step, crash_count = -1, 0
        while int(state.step) < steps:
            try:
                step_i = int(state.step)
                batch_np = self.stream.next_batch()
                if (fail_at is not None and step_i == fail_at
                        and (fail_count is None or fired < fail_count)):
                    fired += 1
                    raise RuntimeError("injected fault (test)")
                t0 = time.time()
                state, metrics = self.step(state, batch_np)
                loss = float(metrics["loss"])
                dt = time.time() - t0
                if self.monitor.record(dt):
                    print(f"[train] step {step_i}: straggler flagged ({dt:.3f}s)")
                losses.append(loss)
                done = step_i + 1
                if done % self.log_every == 0:
                    print(f"[train] step {done}: loss={loss:.4f} "
                          f"gnorm={float(metrics['grad_norm']):.3f} "
                          f"skipped={float(metrics['skipped']):.0f} ({dt:.2f}s)")
                if self.mgr and done % self.ckpt_every == 0:
                    self.save(state)
            except (RuntimeError, jax.errors.JaxRuntimeError) as e:
                step_now = int(state.step)
                if step_now > high_step:
                    high_step, crash_count = step_now, 1
                else:
                    crash_count += 1
                if crash_count > self.max_restarts:
                    print(f"[train] step {step_now} crashed {crash_count} "
                          f"times with no forward progress "
                          f"(max_restarts={self.max_restarts}) — giving up")
                    raise
                if self.restart_backoff_s:
                    time.sleep(min(30.0, self.restart_backoff_s
                                   * 2.0 ** (crash_count - 1)))
                print(f"[train] step {step_now} FAILED ({e}); "
                      "restoring last checkpoint")
                if self.mgr is None or self.mgr.latest_step() is None:
                    print("[train] no checkpoint — restarting from scratch")
                    state = self.init_state()
                    self.stream.skip_to(Cursor(0, 0))
                    continue
                state, _ = self.restore()
                self.stream.skip_to(state.cursor)
        if self.mgr:
            self.save(state, blocking=True)
        return state, losses
