"""The four PrecisionPlan channels as stateful objects (ZipML §2.2/§3).

Every channel shares one tiny interface::

    state = channel.init(params)            # its slice of TrainState.channels
    value, state = channel.apply(value, state, key)

``apply`` is pure and jit-safe; whatever state a channel needs across steps
(the grad channel's error-feedback residual) flows through the jitted train
step inside ``TrainState.channels[name]`` — replacing the old stateless
``grad_transform`` closure, whose trace-once capture silently froze the
residual at None forever.

Channel map (what each transforms, and what state it contributes):

==========  =======================  ====================================
channel     transforms               state in TrainState.channels
==========  =======================  ====================================
sample      the input batch          — (LM tokens are already discrete;
                                     float sample tensors are DS-encoded
                                     only in the 'e2e' plan mode)
model       params inside the loss   — (fake-quant / ship-quant are
                                     re-drawn per step)
grad        the gradient tree        {'ef': fp32 residual tree} — the
                                     telescoping bias cancellation the
                                     multi-worker all-reduce needs
act         (inside the model)       — (the Q₂ plane is saved as the VJP
                                     residual by precision/act_quant)
==========  =======================  ====================================
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import quant
from repro.precision import gradcomp, qat
from repro.quant import PrecisionPlan, QScheme


class Channel:
    """Base: a stateless passthrough. Subclasses override what they need."""

    name = "abstract"

    def __init__(self, plan: PrecisionPlan):
        self.plan = plan

    def init(self, params) -> dict:
        """This channel's slice of ``TrainState.channels`` (a dict pytree)."""
        del params
        return {}

    def apply(self, value, state: dict, key):
        del key
        return value, state


class SampleChannel(Channel):
    """Q_s — the paper's sample channel.

    LM token streams are already discrete (the SampleStore compression
    happened upstream in data/pipeline.QuantizedSampleStore), so integer
    batch leaves pass through untouched. Floating-point sample tensors
    (e.g. pre-computed vision embeddings) are double-sample-encoded at
    ``sample_bits`` only in the end-to-end plan mode — ``mode='e2e'`` —
    keeping every other mode bit-identical to the pre-channel numerics.
    """

    name = "sample"

    def apply(self, batch, state, key):
        if self.plan.mode != "e2e" or not self.plan.sample_bits:
            return batch, state
        scheme = QScheme.int_symmetric(self.plan.sample_bits,
                                       scaling="tensor", rounding="stochastic")
        leaves, treedef = jax.tree.flatten(batch)
        keys = jax.random.split(key, len(leaves))
        out = [quant.encode(x, scheme, k).decode(x.dtype)
               if jnp.issubdtype(x.dtype, jnp.floating) else x
               for x, k in zip(leaves, keys)]
        return jax.tree.unflatten(treedef, out), state


class ModelChannel(Channel):
    """Q_m — weight quantization inside the loss.

    ``model_storage='fake'``: QAT straight-through fake quantization (weights
    stay bf16 at rest). ``'ship'``: quantize-on-gather — int8 (or packed
    int4) codes move through the FSDP all-gather as
    :class:`repro.quant.ShipWeight` leaves, the model matmuls stream the
    codes through the ``quant_dense`` registry op (no local full-width
    dequantized weight exists), and the straight-through gradient flows to
    the master; works over scanned stacked layer params (per-layer
    (L, 1, d_out) channel scales). ``'int'`` is the at-rest serving format
    and does not apply inside a train step.
    """

    name = "model"

    def __init__(self, plan: PrecisionPlan, ship_min_size: int = 1 << 16):
        super().__init__(plan)
        self.ship_min_size = ship_min_size

    def apply(self, params, state, key):
        plan = self.plan
        if not plan.model_bits:
            return params, state
        if plan.model_storage == "fake":
            return qat.fake_quant_tree(params, plan.model_bits, key), state
        if plan.model_storage == "ship":
            return qat.ship_quant_tree(params, plan.model_bits,
                                       min_size=self.ship_min_size), state
        if plan.model_storage == "int":
            # at-rest serving format: a train step runs on the dense masters;
            # serve/prefill steps are what consume the QTensor storage
            return params, state
        raise ValueError(
            f"unknown model_storage {plan.model_storage!r} "
            "(have 'fake' | 'ship' | 'int')")


class GradChannel(Channel):
    """Q_g — compressed gradient collective with error feedback.

    The residual e_t = (g_t + e_{t-1}) − Q(g_t + e_{t-1}) carries to the next
    step through ``TrainState.channels['grad']['ef']``; the sum of applied
    updates then telescopes to the sum of true gradients (the accumulated
    bias cancellation the single-worker analysis of App. D does not give a
    multi-worker all-reduce).
    """

    name = "grad"

    def __init__(self, plan: PrecisionPlan, error_feedback: bool = True,
                 rounding: str = "stochastic"):
        super().__init__(plan)
        self.error_feedback = error_feedback
        self.rounding = rounding

    def init(self, params):
        if self.plan.grad_bits and self.error_feedback:
            return {"ef": gradcomp.init_error_feedback(params)}
        return {}

    def apply(self, grads, state, key):
        bits = self.plan.grad_bits
        if not bits:
            return grads, state
        comp, new_err = gradcomp.compress_tree(
            grads, bits, key, error=state.get("ef"), rounding=self.rounding)
        grads = gradcomp.decompress_tree(comp)
        if self.error_feedback:
            state = {"ef": new_err}
        return grads, state


class ActChannel(Channel):
    """Q_a — double-sampled activation quantization (§3.4 beyond-paper).

    The quantization itself happens *inside* the model forward
    (``precision/act_quant.ds_dense``, enabled by ``plan.act_bits`` through
    the model config); its per-step state — the saved Q₂ code plane — is the
    VJP residual, managed by autodiff, not by TrainState. The channel object
    exists so the four-channel composition is uniform and so step builders
    have one place to hang act-channel accounting.
    """

    name = "act"


def default_channels(plan: PrecisionPlan, *, error_feedback: bool = True
                     ) -> dict[str, Channel]:
    """The standard four-channel composition for a PrecisionPlan."""
    return {
        "sample": SampleChannel(plan),
        "model": ModelChannel(plan),
        "grad": GradChannel(plan, error_feedback=error_feedback),
        "act": ActChannel(plan),
    }
