"""TrainState — ONE pytree holding everything a training run is.

Before the Trainer refactor the run state was scattered: params and opt
state flowed through the jitted step, the error-feedback residual lived in a
Python closure (where jit trace-once semantics silently froze it — the
residual never actually fed back), and the data cursor / RNG / step counter
were loose locals of the supervisor loop. TrainState gathers all of it:

* ``params``    — model parameters (bf16/f32 leaves; QTensor leaves for the
  int-storage serving format).
* ``opt``       — :class:`repro.optim.adamw.OptState`; with ``moment_bits``
  the m/v moments are QTensor leaves (int8 codes + fp32 scales).
* ``channels``  — per-channel state dict keyed by channel name. The grad
  channel's error-feedback residual tree lives here, which is what lets it
  thread *through* the jitted step (HALP-style full-precision correction
  state around a low-precision inner loop).
* ``step``      — int32 scalar; also the data-cursor position (the stream's
  determinism contract: batch i is a pure function of (seed, i, host)).
* ``rng``       — the run's base PRNG key; per-step keys are
  ``fold_in(rng, step)`` so restore-and-replay is bit-exact.
* ``epoch``     — int32 scalar, the cursor epoch.

A checkpoint of a TrainState is therefore the *complete* run: restoring it
resumes bit-exactly, error-feedback residuals and quantized moments
included (pinned by tests/test_trainer.py).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.data.pipeline import Cursor


@jax.tree_util.register_pytree_node_class
class TrainState:
    """The complete, checkpointable, jit-able state of a training run."""

    __slots__ = ("params", "opt", "channels", "step", "rng", "epoch")

    def __init__(self, params: Any, opt: Any, channels: dict,
                 step: jax.Array, rng: jax.Array, epoch: jax.Array):
        self.params = params
        self.opt = opt
        self.channels = channels
        self.step = step
        self.rng = rng
        self.epoch = epoch

    # -------------------------------------------------------------- pytree --
    def tree_flatten(self):
        return ((self.params, self.opt, self.channels, self.step, self.rng,
                 self.epoch), None)

    @classmethod
    def tree_unflatten(cls, _, children):
        return cls(*children)

    # ------------------------------------------------------------- helpers --
    @property
    def cursor(self) -> Cursor:
        """The data-pipeline position this state expects to consume next."""
        return Cursor(int(self.step), int(self.epoch))

    def replace(self, **kw) -> "TrainState":
        fields = {k: getattr(self, k) for k in self.__slots__}
        fields.update(kw)
        return TrainState(**fields)

    def __repr__(self):
        try:
            step = int(self.step)
        except (TypeError, jax.errors.ConcretizationTypeError):
            step = self.step
        return (f"TrainState(step={step}, "
                f"channels={sorted(self.channels)}, "
                f"n_params={len(jax.tree.leaves(self.params))})")


def init_state(params, opt, channels: dict, key: jax.Array,
               step: int = 0, epoch: int = 0) -> TrainState:
    return TrainState(params, opt, dict(channels),
                      jnp.asarray(step, jnp.int32), key,
                      jnp.asarray(epoch, jnp.int32))
