"""repro.train — the unified training subsystem.

* :class:`TrainState` — ONE pytree for the whole run: params, (quantized)
  optimizer moments, per-channel state (error-feedback residuals), data
  cursor, RNG lane, step counter (state.py).
* channel objects — the four PrecisionPlan channels (sample / model / grad /
  act) as stateful ``init``/``apply`` objects; the grad channel threads its
  error-feedback residual through the jitted step (channels.py).
* :func:`make_step` — the channel-composed train step over a TrainState
  (step.py).
* :class:`Trainer` — step + supervisor/restart loop + full-state
  checkpointing + elastic fleet resize in one object; ``launch/train.py``
  is now a thin CLI over it (trainer.py).
"""
from .channels import (
    ActChannel,
    Channel,
    GradChannel,
    ModelChannel,
    SampleChannel,
    default_channels,
)
from .state import TrainState, init_state
from .step import make_grads_fn, make_step
from .trainer import StragglerMonitor, Trainer

__all__ = [
    "ActChannel",
    "Channel",
    "GradChannel",
    "ModelChannel",
    "SampleChannel",
    "StragglerMonitor",
    "TrainState",
    "Trainer",
    "default_channels",
    "init_state",
    "make_grads_fn",
    "make_step",
]
