"""Continuous-batching serving engine over the paged quantized KV pool.

The ZipML/MLWeaving serving thesis is that inference is data-movement-bound,
so int8/int4 KV storage buys near-linear decode speedups — but a fixed-batch
fixed-length loop (the old launch/serve.py) can't exploit it under real
traffic. This engine serves a **mixed stream**: requests with arbitrary
prompt/generation lengths are admitted into decode slots as they fit,
decode runs one batched step over every live sequence, and finished
sequences free their pages immediately for the next admission.

Scheduling model (Orca-style iteration-level batching):

* ``submit()`` queues requests FIFO; ``step()`` = admit → ensure-pages →
  one batched decode.
* **admission**: a request is admitted when a decode slot is free and the
  allocator can hand it its pages — ``reserve='full'`` takes the worst-case
  page count up front (no mid-flight eviction, ever); ``reserve='none'``
  takes only the prompt pages and grows on demand.
* **prefill** runs per request at its exact prompt length (no padding, no
  masking subtleties) through the unmodified ``transformer.prefill``; the
  raw post-RoPE K/V rows are then quantized per (token, head) and scattered
  into pages — bit-identical codes to the legacy ring buffer.
* **decode** is one jitted step over all ``max_slots`` slots: append each
  slot's token KV into its current page (inactive slots write to the null
  page), run the paged-attention op through the kernel registry (ref or
  Pallas), sample with per-request keys (greedy / temperature / top-k).
* **eviction/preemption** (``reserve='none'``): when a sequence needs a page
  and none is free, the youngest sequence is evicted — its pages return to
  the pool and it is re-queued (front) carrying its generated tokens as a
  **replay list**. Re-admission recomputes: prefill the original prompt
  (same call as the first admission), then force-feed the replayed tokens
  through ordinary decode steps (batched with everyone else) instead of
  sampling. That rebuilds the quantized KV pages through the *same*
  computation path that produced them, so the post-replay continuation is
  bit-identical to the never-preempted run — re-prefilling generated tokens
  as prompt would instead read full-precision K/V where the original decode
  read quantized pages, and diverge.

* **precision autoscaling** (optional): bit-plane weights
  (``quantize_param_tree(..., layout='bitplane')``) make serving precision a
  per-step dial — ``set_weight_bits(k)`` swaps in a cached
  ``slice_planes(k)`` view of every weight (zero repack, no reload; decode
  streams (k+1)/(B+1) of the code bytes). Attach a
  :class:`repro.serve.autoscaler.PrecisionAutoscaler` and ``step()`` feeds
  it the head-of-line admission wait + queue depth each iteration and
  actuates the bits it returns.

Invariants the tests pin: every admitted request finishes; no page leaks;
per-request outputs are independent of batch composition; paged decode
matches the legacy ring path.

Throughput accounting deliberately excludes the first decode call (jit
compile) — ``stats['decode_seconds']`` is steady-state only, the fix the
old serve loop needed (its t0 sat before compilation).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.models import attention as attn
from repro.models import transformer as T
from repro.models.layers import dense, embed, rmsnorm
from repro.quant import PrecisionPlan, QTensor
from repro.serve import pages as pg
from repro.serve import sampling

SUPPORTED_FAMILIES = ("dense", "moe", "audio")


@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request. ``temperature<=0`` → greedy; ``top_k<=0`` → no
    top-k filter; ``eos_id=None`` → length-only stopping."""

    rid: int
    prompt: Any                      # 1-D int array-like of token ids
    max_new_tokens: int = 16
    temperature: float = 0.0
    top_k: int = 0
    eos_id: int | None = None
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class Finished:
    rid: int
    tokens: np.ndarray               # prompt + generated, 1-D int32
    prompt_len: int
    n_generated: int
    reason: str                      # 'eos' | 'length'


class ServeEngine:
    def __init__(self, params, cfg, *, plan: PrecisionPlan | None = None,
                 max_slots: int = 4, page_size: int = 8,
                 max_seq_len: int = 128, n_pages: int | None = None,
                 reserve: str = "full", backend: str | None = None,
                 autoscaler=None, clock=None):
        if cfg.family not in SUPPORTED_FAMILIES:
            raise ValueError(
                f"ServeEngine supports {SUPPORTED_FAMILIES} families, "
                f"got {cfg.family!r} (SSM/hybrid/VLM caches are not paged yet)")
        if cfg.window:
            raise ValueError("sliding-window models are not paged yet")
        if reserve not in ("full", "none"):
            raise ValueError(f"reserve must be 'full' or 'none', got {reserve!r}")
        plan = plan if plan is not None else cfg.precision
        self.cfg = dataclasses.replace(cfg, precision=plan)
        # prefill runs with kv_bits=0: the ring cache it fills is then the
        # raw post-RoPE K/V, which the pool quantizes page-wise itself
        self._cfg_fp = dataclasses.replace(
            cfg, precision=dataclasses.replace(plan, kv_bits=0))
        self.plan = plan
        self.params = params
        self.backend = backend
        self.page_size = int(page_size)
        self.max_slots = int(max_slots)
        self.max_seq_len = int(max_seq_len)
        self.max_pages_per_seq = pg.pages_needed(max_seq_len, page_size)
        self.reserve = reserve
        if n_pages is None:
            n_pages = self.max_slots * self.max_pages_per_seq + 1
        self.allocator = pg.PageAllocator(n_pages)
        self.pool = pg.init_pool(
            cfg.n_layers, n_pages, page_size, cfg.n_kv_heads, cfg.head_dim,
            kv_bits=plan.kv_bits, dtype=cfg.dtype)

        B, MP = self.max_slots, self.max_pages_per_seq
        self._bt = np.zeros((B, MP), np.int32)
        self._lens = np.zeros((B,), np.int32)
        self._active = np.zeros((B,), bool)
        self._temps = np.zeros((B,), np.float32)
        self._topks = np.zeros((B,), np.int32)
        self._base_keys = np.zeros((B, 2), np.uint32)
        self._last_tok = np.zeros((B,), np.int32)
        self._slots: list[dict | None] = [None] * B
        self._queue: collections.deque = collections.deque()
        self._admit_seq = 0
        self._compiled_variants: set[tuple] = set()
        self.stats = {"admitted": 0, "finished": 0, "preemptions": 0,
                      "decode_steps": 0, "decode_tokens": 0,
                      "decode_seconds": 0.0, "steady_decode_tokens": 0,
                      "prefill_tokens": 0, "admit_wait_seconds": 0.0}
        self.admit_waits: list[float] = []      # per-admission queue wait, s
        self.decode_times: list[float] = []     # steady per-step decode, s
        self._clock = clock if clock is not None else time.perf_counter
        self.autoscaler = autoscaler
        self._params_full = params
        self._params_by_bits: dict[int, Any] = {}
        self.weight_bits: int | None = None     # None until set_weight_bits

        # two decode variants: the greedy-only one skips the sort +
        # categorical machinery entirely (the common case); lazily compiled
        self._decode_jits: dict[bool, Any] = {}
        self._prefill_jits: dict[int, Any] = {}
        self._sample1 = jax.jit(
            lambda lg, t, k, key: sampling.sample_tokens(
                lg[None], t[None], k[None], key[None])[0])

    # ------------------------------------------------------------ device fns
    def _make_decode_fn(self, sampled: bool):
        """``sampled=False`` compiles the greedy-only fast path (no vocab
        sort, no categorical) — picked per step from host state."""
        cfg, spec = self.cfg, self.cfg.attn_spec
        page = self.page_size
        kb = registry.get(self.backend)

        def decode_fn(params, pool, tokens, positions, block_table, active,
                      base_keys, temps, topks):
            b = tokens.shape[0]
            pos = positions.astype(jnp.int32)
            x = embed(params["embed"], tokens).astype(cfg.dtype)      # (B,1,d)
            page_ids = jnp.take_along_axis(
                block_table, (pos // page)[:, None], axis=1)[:, 0]
            page_ids = jnp.where(active, page_ids, 0)                 # null page
            offs = pos % page
            new_lens = pos + active.astype(jnp.int32)

            def body(h, inp):
                layer, kp, vp, ks, vs = inp
                box = {}

                def attend(z):
                    q, k, v = attn.decode_qkv(layer["attn"], z, spec,
                                              pos[:, None])
                    kp2, vp2, ks2, vs2 = pg.append_rows(
                        kp, vp, ks, vs, k[:, 0], v[:, 0], page_ids, offs)
                    box["planes"] = (kp2, vp2, ks2, vs2)
                    out = kb.paged_attention(
                        q[:, 0], kp2, vp2, ks2, vs2, block_table, new_lens,
                        softmax_scale=spec.scale)
                    return dense(layer["attn"]["o"], out.reshape(
                        b, 1, spec.n_heads * spec.head_dim))

                h = T.decode_layer_block(cfg, layer, h, attend)
                return h, box["planes"]

            xs = (params["layers"], pool.k_pages, pool.v_pages,
                  pool.k_scale, pool.v_scale)
            x, planes = jax.lax.scan(body, x, xs)
            new_pool = pg.PagedKVPool(*planes)
            x = rmsnorm(params["final_norm"], x)
            logits = T._readout(params, cfg, x)[:, 0]                 # (B, V)
            if sampled:
                keys = jax.vmap(sampling.slot_key)(base_keys, pos + 1)
                tok = sampling.sample_tokens(logits, temps, topks, keys)
            else:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return jnp.where(active, tok, 0), logits, new_pool

        return decode_fn

    def _decode_jit(self, sampled: bool):
        fn = self._decode_jits.get(sampled)
        if fn is None:
            fn = self._decode_jits[sampled] = jax.jit(
                self._make_decode_fn(sampled))
        return fn

    def _prefill_jit(self, bucket: int):
        """One compile per page-multiple *bucket*, not per exact prompt
        length: prompts are right-padded to the bucket, logits read at the
        true last position (causality shields it from the pad garbage), and
        the pad rows land in the last page masked by seq_len — decode
        appends overwrite them one by one as generation proceeds."""
        fn = self._prefill_jits.get(bucket)
        if fn is None:
            cfg_fp = self._cfg_fp

            def prefill_fn(params, toks, last_pos, page_ids, pool):
                logits, state = T.prefill(params, toks, cfg_fp,
                                          last_pos=last_pos)
                k_all = state.layers.k[:, 0]       # (L, bucket, Hkv, D)
                v_all = state.layers.v[:, 0]
                return logits[0], pg.write_prompt(pool, k_all, v_all, page_ids)

            fn = self._prefill_jits[bucket] = jax.jit(prefill_fn)
        return fn

    # -------------------------------------------------------------- host API
    def submit(self, req: Request) -> None:
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if prompt.size >= self.max_seq_len:
            raise ValueError(
                f"prompt of {prompt.size} tokens needs max_seq_len > that "
                f"(engine has {self.max_seq_len})")
        worst = pg.pages_needed(
            min(prompt.size + req.max_new_tokens, self.max_seq_len),
            self.page_size)
        if worst > self.allocator.n_pages - 1:
            raise ValueError(
                f"request {req.rid} can never fit: needs {worst} pages, "
                f"pool has {self.allocator.n_pages - 1}")
        self._queue.append({"req": req, "prompt": prompt,
                            "replay": np.zeros((0,), np.int32),
                            "t_submit": self._clock()})

    @property
    def n_pending(self) -> int:
        return len(self._queue)

    @property
    def n_active(self) -> int:
        return int(self._active.sum())

    def set_weight_bits(self, k: int) -> None:
        """Serve the next decode batches at ``k`` weight bits.

        Swaps ``self.params`` for the tree whose bitplane QTensor weights are
        ``slice_planes(k)`` views of the full artifact — a zero-copy plane
        slice, so no weight reload and no repacking; decode simply streams
        fewer code planes. Trees are cached per k (each k is one extra jit
        trace of the decode step — the shapes differ — amortized after the
        first switch). Requires ``layout='bitplane'`` weights
        (``quantize_param_tree(..., layout='bitplane')``)."""
        tree = self._params_by_bits.get(k)
        if tree is None:
            n_hit = [0]

            def slice_leaf(leaf):
                if (isinstance(leaf, QTensor)
                        and leaf.scheme.layout == "bitplane"):
                    n_hit[0] += 1
                    return leaf.slice_planes(min(int(k), leaf.scheme.bits))
                return leaf

            tree = jax.tree.map(slice_leaf, self._params_full,
                                is_leaf=lambda x: isinstance(x, QTensor))
            if not n_hit[0]:
                raise ValueError(
                    "set_weight_bits needs layout='bitplane' QTensor weights "
                    "— quantize with quantize_param_tree(..., "
                    "layout='bitplane')")
            self._params_by_bits[k] = tree
        self.params = tree
        self.weight_bits = int(k)

    def kv_pool_nbytes(self, used_only: bool = False) -> int:
        """Logical KV HBM bytes (QTensor.nbytes accounting; §2.2)."""
        if used_only:
            used = sum(len(s["pages"]) for s in self._slots if s)
            return pg.pool_nbytes(self.pool, n_pages=used)
        return pg.pool_nbytes(self.pool)

    # ------------------------------------------------------------- scheduler
    def _free_slot(self) -> int | None:
        idx = np.flatnonzero(~self._active)
        return int(idx[0]) if idx.size else None

    def _budget(self, entry) -> int:
        """Generation budget: the request's ask, capped by the context."""
        return min(entry["req"].max_new_tokens,
                   self.max_seq_len - len(entry["prompt"]))

    def _bucket(self, s: int) -> int:
        return pg.pages_needed(max(s, 1), self.page_size) * self.page_size

    def _admit(self, finished: list) -> None:
        while self._queue:
            slot = self._free_slot()
            if slot is None:
                return
            entry = self._queue[0]
            prompt = entry["prompt"]
            replay = entry["replay"]
            s = int(prompt.size)
            budget = self._budget(entry)
            if budget <= 0:                        # context already full
                self._queue.popleft()
                finished.append(self._finish_entry(entry, reason="length"))
                continue
            n_now = pg.pages_needed(s + 1, self.page_size)
            n_res = (pg.pages_needed(min(s + budget, self.max_seq_len),
                                     self.page_size)
                     if self.reserve == "full" else n_now)
            ids = self.allocator.alloc(max(n_res, n_now))
            if ids is None:
                return                              # FIFO head-of-line wait
            self._queue.popleft()
            wait = max(0.0, self._clock() - entry["t_submit"])
            self.stats["admit_wait_seconds"] += wait
            self.admit_waits.append(wait)
            req = entry["req"]
            row = np.zeros((self.max_pages_per_seq,), np.int32)
            row[:len(ids)] = ids
            self._bt[slot] = row
            self._lens[slot] = s
            self._temps[slot] = req.temperature
            self._topks[slot] = req.top_k
            base = np.asarray(jax.random.fold_in(
                jax.random.PRNGKey(req.seed), req.rid), np.uint32)
            self._base_keys[slot] = base

            bucket = self._bucket(s)
            padded = np.zeros((bucket,), np.int32)
            padded[:s] = prompt
            fn = self._prefill_jit(bucket)
            logits, self.pool = fn(
                self.params, jnp.asarray(padded)[None], jnp.int32(s - 1),
                jnp.asarray(ids[:bucket // self.page_size], jnp.int32),
                self.pool)
            if replay.size:
                # recompute preemption: the first generated token is known;
                # the rest replays through forced decode steps
                tok, replay_left = int(replay[0]), list(replay[1:])
            else:
                tok = int(self._sample1(
                    logits, jnp.float32(req.temperature),
                    jnp.int32(req.top_k),
                    sampling.slot_key(jnp.asarray(base), jnp.int32(s))))
                replay_left = []
            self._active[slot] = True
            self._last_tok[slot] = tok
            self._slots[slot] = {"req": req, "prompt": prompt, "gen": [tok],
                                 "replay_left": replay_left,
                                 "pages": list(ids),
                                 "admit_seq": self._admit_seq}
            self._admit_seq += 1
            self.stats["admitted"] += 1
            self.stats["prefill_tokens"] += s
            self._maybe_finish(slot, finished)

    def _full_tokens(self, state) -> np.ndarray:
        return np.concatenate([state["prompt"],
                               np.asarray(state["gen"], np.int32)])

    def _finish_entry(self, entry, *, reason: str) -> Finished:
        """A queue entry finished without (re-)admission (context full)."""
        replay = entry["replay"]
        tokens = np.concatenate([entry["prompt"], replay])
        self.stats["finished"] += 1
        return Finished(rid=entry["req"].rid, tokens=tokens,
                        prompt_len=len(entry["prompt"]),
                        n_generated=int(replay.size), reason=reason)

    def _maybe_finish(self, slot: int, finished: list) -> bool:
        state = self._slots[slot]
        req = state["req"]
        n_gen = len(state["gen"])
        full_len = len(state["prompt"]) + n_gen
        reason = None
        if req.eos_id is not None and state["gen"][-1] == req.eos_id:
            reason = "eos"
        elif n_gen >= self._budget(state) or full_len >= self.max_seq_len:
            reason = "length"
        if reason is None:
            return False
        self.allocator.free(state["pages"])
        self._active[slot] = False
        self._bt[slot] = 0
        self._lens[slot] = 0
        self._slots[slot] = None
        self.stats["finished"] += 1
        finished.append(Finished(
            rid=req.rid, tokens=self._full_tokens(state),
            prompt_len=len(state["prompt"]), n_generated=n_gen,
            reason=reason))
        return True

    def _preempt_one(self) -> int | None:
        """Evict the youngest active sequence; requeue it (front) with its
        generated tokens as the replay list. Returns the freed slot."""
        cands = [(s["admit_seq"], i) for i, s in enumerate(self._slots) if s]
        if not cands:
            return None
        _, slot = max(cands)
        state = self._slots[slot]
        self.allocator.free(state["pages"])
        self._active[slot] = False
        self._bt[slot] = 0
        self._lens[slot] = 0
        self._slots[slot] = None
        replay = np.concatenate([
            np.asarray(state["gen"], np.int32),
            np.asarray(state["replay_left"], np.int32)])
        self._queue.appendleft({"req": state["req"],
                                "prompt": state["prompt"], "replay": replay,
                                "t_submit": self._clock()})
        self.stats["preemptions"] += 1
        return slot

    def _ensure_pages(self) -> None:
        """Before decode: every active slot must own the page its next KV row
        lands in; grow on demand, preempting (youngest-first) when the pool
        is exhausted."""
        for slot in range(self.max_slots):
            while True:
                if not self._active[slot] or self._slots[slot] is None:
                    break
                pidx = int(self._lens[slot]) // self.page_size
                if self._bt[slot, pidx] != 0:
                    break
                ids = self.allocator.alloc(1)
                if ids is not None:
                    self._bt[slot, pidx] = ids[0]
                    self._slots[slot]["pages"].append(ids[0])
                    break
                victim = self._preempt_one()
                if victim is None or victim == slot:
                    break                      # this slot itself got evicted

    def step(self) -> list[Finished]:
        """One scheduler iteration: admit what fits, decode one token for
        every live sequence. Returns the requests that finished."""
        finished: list[Finished] = []
        if self.autoscaler is not None:
            now = self._clock()
            wait = (max(0.0, now - self._queue[0]["t_submit"])
                    if self._queue else 0.0)
            bits = self.autoscaler.observe(
                admit_wait_ms=wait * 1e3, queue_depth=len(self._queue),
                now=now)
            if bits != self.weight_bits:
                self.set_weight_bits(bits)
        self._admit(finished)
        self._ensure_pages()
        if not self._active.any():
            return finished

        sampled = bool((self._temps[self._active] > 0).any())
        t0 = time.perf_counter()
        tok, _, self.pool = self._decode_jit(sampled)(
            self.params, self.pool,
            jnp.asarray(self._last_tok)[:, None],
            jnp.asarray(self._lens), jnp.asarray(self._bt),
            jnp.asarray(self._active), jnp.asarray(self._base_keys),
            jnp.asarray(self._temps), jnp.asarray(self._topks))
        tok_np = np.asarray(tok)               # blocks until ready
        dt = time.perf_counter() - t0
        n_live = int(self._active.sum())
        self.stats["decode_steps"] += 1
        self.stats["decode_tokens"] += n_live
        variant = (sampled, self.weight_bits)
        if variant in self._compiled_variants:  # steady state: skip compiles
            self.stats["decode_seconds"] += dt
            self.stats["steady_decode_tokens"] += n_live
            self.decode_times.append(dt)
        self._compiled_variants.add(variant)

        for slot in range(self.max_slots):
            if not self._active[slot]:
                continue
            state = self._slots[slot]
            if state["replay_left"]:
                # forced replay (recompute preemption): the decode step
                # rebuilt this position's KV exactly; the token is known
                tok = state["replay_left"].pop(0)
            else:
                tok = int(tok_np[slot])
            self._lens[slot] += 1
            state["gen"].append(tok)
            self._last_tok[slot] = tok
            self._maybe_finish(slot, finished)
        return finished

    def run(self, requests=None, max_steps: int = 100_000) -> dict[int, Finished]:
        """Serve until the queue drains and every sequence finishes."""
        for r in requests or ():
            self.submit(r)
        out: dict[int, Finished] = {}
        for _ in range(max_steps):
            if not self._queue and not self._active.any():
                break
            before = (len(self._queue), int(self._active.sum()),
                      self.stats["decode_steps"])
            for f in self.step():
                out[f.rid] = f
            after = (len(self._queue), int(self._active.sum()),
                     self.stats["decode_steps"])
            if before == after:
                raise RuntimeError(
                    "scheduler stalled (pool too small for any queued "
                    "request?) — nothing admitted, decoded, or finished")
        else:
            raise RuntimeError(f"run() exceeded {max_steps} steps")
        return out

    def throughput(self) -> float:
        """Steady-state decode tokens/s (compile step excluded)."""
        if self.stats["decode_seconds"] == 0:
            return float("nan")
        return self.stats["steady_decode_tokens"] / self.stats["decode_seconds"]


__all__ = ["Request", "Finished", "ServeEngine", "SUPPORTED_FAMILIES"]
