"""Continuous-batching serving engine over the paged quantized KV pool.

The ZipML/MLWeaving serving thesis is that inference is data-movement-bound,
so int8/int4 KV storage buys near-linear decode speedups — but a fixed-batch
fixed-length loop (the old launch/serve.py) can't exploit it under real
traffic. This engine serves a **mixed stream**: requests with arbitrary
prompt/generation lengths are admitted into decode slots as they fit,
decode runs one batched step over every live sequence, and finished
sequences free their pages immediately for the next admission.

Scheduling model (Orca-style iteration-level batching):

* ``submit()`` queues requests FIFO; ``step()`` = admit → prefill-chunk →
  ensure-pages → one batched decode.
* **admission**: a request is admitted when a decode slot is free and the
  allocator can hand it its pages — ``reserve='full'`` takes the worst-case
  page count up front (no mid-flight eviction, ever); ``reserve='none'``
  takes only the prompt pages and grows on demand. Monolithic-reserve-only
  admission (the ``reserve`` knob with neither ``prefix_cache`` nor
  ``chunk_pages``) is the legacy compatibility mode — chunked admission
  bounds the per-step prefill stall and is the intended serving default.
* **prefill** has two paths:

  - *monolithic* (default, bit-exact with the pre-chunking engine): per
    request at its page-bucketed prompt length through the unmodified
    ``transformer.prefill``; the raw post-RoPE K/V rows are then quantized
    per (token, head) and scattered into pages — bit-identical codes to the
    legacy ring buffer.
  - *chunked* (``chunk_pages=N`` or ``prefix_cache=True``, Orca/Sarathi
    style): the prompt runs through fixed-width chunks of ``N`` pages at
    absolute page-aligned boundaries, one chunk per scheduler step,
    interleaved with decode — a 2k-token admission no longer stalls the
    live batch. Each chunk **writes its quantized K/V pages first, then
    attends the dequantized context** (exactly the decode step's
    append-then-attend order), so a token's KV codes are the same whether
    it arrived via chunked prefill, decode, or replay — which is what makes
    prefix reuse exact (below). One jit compile total: every chunk call has
    the same static shapes.

* **prefix reuse** (``prefix_cache=True``): a radix/trie index
  (:class:`repro.serve.prefix.PrefixCache`) maps page-aligned token runs of
  completed prompts to their pool pages. Admission walks the trie and
  points the new block-table row at the shared pages (copy-on-write by
  refcount: full pages are immutable — decode only appends past them — and
  are freed only when the last sharer *and* the trie drop them; the partial
  tail page is always private). The chunks fully covered by the hit are
  skipped outright; the first partially-covered chunk is recomputed at
  identical shapes but writes to the null page instead of the shared pages.
  A warm admission therefore executes byte-identical chunk calls to a cold
  one — prefix-hit outputs are **bit-identical to cold-start at every
  kv-bits setting by construction**, not by tolerance.
* **decode** is one jitted step over all ``max_slots`` slots: append each
  slot's token KV into its current page (inactive slots write to the null
  page), run the paged-attention op through the kernel registry (ref or
  Pallas), sample with per-request keys (greedy / temperature / top-k).
* **eviction/preemption** (``reserve='none'``): when a sequence needs a page
  and none is free, the engine first evicts unreferenced prefix-cache
  leaves, then preempts the youngest sequence — its pages return to the
  pool and it is re-queued (front, original ``t_submit`` preserved so the
  admission-latency signal keeps accruing) carrying its generated tokens as
  a **replay list**. Re-admission recomputes: prefill the original prompt
  (same calls as the first admission — chunked prefill is deterministic and
  prefix hits are exact, so the rebuilt pages carry identical codes), then
  force-feed the replayed tokens through ordinary decode steps (batched
  with everyone else) instead of sampling. That rebuilds the quantized KV
  pages through the *same* computation path that produced them, so the
  post-replay continuation is bit-identical to the never-preempted run —
  re-prefilling generated tokens as prompt would instead read
  full-precision K/V where the original decode read quantized pages, and
  diverge.

* **self-speculative decoding** (``spec_decode=k, draft_bits=b``): the
  low-bit model sliced from the served bit-plane artifact
  (``slice_planes(b)`` — zero extra weight memory) drafts ``k`` greedy
  tokens per slot through the ordinary paged decode step, writing
  **scratch** KV rows past each slot's committed length; one batched
  full-precision forward then scores all ``k+1`` window positions
  (embed→rope→quantize-K/V→scatter→attend, the chunked-prefill shape), and
  the longest prefix of draft tokens matching the verify chain is committed
  — their KV rows were minted *by the verify pass itself* (write-then-
  attend), so accepted rows are byte-identical to what sequential decode
  would have written. Rollback is free: rejected rows sit past ``_lens``
  (masked garbage, overwritten by the next window). Greedy output is
  **token-identical to vanilla greedy decode by construction**; with
  temperature > 0 the window samples with the same per-(request, position)
  keys sequential decode uses, so sampled chains are identical too —
  acceptance just compares the greedy draft against the sampled target.
  The engine falls back to a vanilla step while a replay is in flight,
  when any active slot lacks ``k+1`` rows of page runway, or when the
  autoscaler has dropped serving bits to (or below) the draft's — and
  autoscaler actuation happens at the top of ``step()``, so a bits change
  can never land mid-window (the same deferral discipline as replay).

* **precision autoscaling** (optional): bit-plane weights
  (``quantize_param_tree(..., layout='bitplane')``) make serving precision a
  per-step dial — ``set_weight_bits(k)`` swaps in a cached
  ``slice_planes(k)`` view of every weight (zero repack, no reload; decode
  streams (k+1)/(B+1) of the code bytes). Attach a
  :class:`repro.serve.autoscaler.PrecisionAutoscaler` and ``step()`` feeds
  it the head-of-line admission wait + queue depth (queued requests plus
  slots still chunk-prefilling — admitted-but-not-decoding work is load the
  governor must see) each iteration and actuates the bits it returns.
  Actuation is **deferred while any replay is in flight** (a slot holds
  ``replay_left`` or a requeued entry carries replay tokens): switching
  weight bits between eviction and replay would rebuild the replayed KV
  under different weights than the original decode and break the bit-exact
  replay invariant above. The governor still observes every step; the rung
  move lands on the first replay-free step. An actuated bits change also
  flushes the prefix cache and marks in-flight prefills non-cacheable —
  pages computed under other weights must never serve a prefix hit.

Invariants the tests pin: every admitted request finishes; no page leaks
(shared pages freed exactly at refcount 0); per-request outputs are
independent of batch composition; paged decode matches the legacy ring
path; shared prefix pages are never written after sharing.

Throughput accounting deliberately excludes the first decode call (jit
compile) — ``stats['decode_seconds']`` is steady-state only, the fix the
old serve loop needed (its t0 sat before compilation). All scheduler timing
goes through the injectable ``clock`` (``admit_waits`` *and*
``decode_times``), so virtual-clock replays never mix real and virtual
time.
"""
from __future__ import annotations

import collections
import dataclasses
import time
import zlib
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.ops import kv_bits_of
from repro.kernels.ref import dequant_pages_ref, gather_pages_ref
from repro.models import attention as attn
from repro.models import transformer as T
from repro.models.layers import apply_rope, dense, embed
from repro.quant import PrecisionPlan, QTensor
from repro.serve import pages as pg
from repro.serve import sampling
from repro.serve.prefix import PrefixCache

SUPPORTED_FAMILIES = ("dense", "moe", "audio")


@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request. ``temperature<=0`` → greedy; ``top_k<=0`` → no
    top-k filter; ``eos_id=None`` → length-only stopping."""

    rid: int
    prompt: Any                      # 1-D int array-like of token ids
    max_new_tokens: int = 16
    temperature: float = 0.0
    top_k: int = 0
    eos_id: int | None = None
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class Finished:
    rid: int
    tokens: np.ndarray               # prompt + generated, 1-D int32
    prompt_len: int
    n_generated: int
    reason: str                      # 'eos' | 'length' | 'nan' | 'retries'


class ServeEngine:
    def __init__(self, params, cfg, *, plan: PrecisionPlan | None = None,
                 max_slots: int = 4, page_size: int = 8,
                 max_seq_len: int = 128, n_pages: int | None = None,
                 reserve: str = "full", backend: str | None = None,
                 autoscaler=None, clock=None, prefix_cache: bool = False,
                 chunk_pages: int | None = None, spec_decode: int = 0,
                 draft_bits: int | None = None, fault_injector=None,
                 replica_id: int = 0, retry_budget: int = 32):
        if cfg.family not in SUPPORTED_FAMILIES:
            raise ValueError(
                f"ServeEngine supports {SUPPORTED_FAMILIES} families, "
                f"got {cfg.family!r} (SSM/hybrid/VLM caches are not paged yet)")
        if cfg.window:
            raise ValueError("sliding-window models are not paged yet")
        if reserve not in ("full", "none"):
            raise ValueError(f"reserve must be 'full' or 'none', got {reserve!r}")
        plan = plan if plan is not None else cfg.precision
        self.cfg = dataclasses.replace(cfg, precision=plan)
        # prefill runs with kv_bits=0: the ring cache it fills is then the
        # raw post-RoPE K/V, which the pool quantizes page-wise itself
        self._cfg_fp = dataclasses.replace(
            cfg, precision=dataclasses.replace(plan, kv_bits=0))
        self.plan = plan
        self.params = params
        self.backend = backend
        self.page_size = int(page_size)
        self.max_slots = int(max_slots)
        self.max_seq_len = int(max_seq_len)
        self.max_pages_per_seq = pg.pages_needed(max_seq_len, page_size)
        self.reserve = reserve
        if n_pages is None:
            n_pages = self.max_slots * self.max_pages_per_seq + 1
        self.allocator = pg.PageAllocator(n_pages)
        self.pool = pg.init_pool(
            cfg.n_layers, n_pages, page_size, cfg.n_kv_heads, cfg.head_dim,
            kv_bits=plan.kv_bits, dtype=cfg.dtype)

        # prefix sharing requires the chunked path: a prefix hit attends
        # quantized shared pages, so the cold computation that *minted* them
        # must have attended its own quantized pages the same way
        if chunk_pages is not None and int(chunk_pages) < 1:
            raise ValueError(f"chunk_pages must be >= 1, got {chunk_pages}")
        self.chunk_pages = (min(int(chunk_pages), self.max_pages_per_seq)
                            if chunk_pages is not None
                            else (self.max_pages_per_seq if prefix_cache
                                  else None))
        self._chunked = self.chunk_pages is not None
        self.prefix = (PrefixCache(self.page_size, self.allocator)
                       if prefix_cache else None)

        B, MP = self.max_slots, self.max_pages_per_seq
        self._bt = np.zeros((B, MP), np.int32)
        self._lens = np.zeros((B,), np.int32)
        self._active = np.zeros((B,), bool)
        self._temps = np.zeros((B,), np.float32)
        self._topks = np.zeros((B,), np.int32)
        self._base_keys = np.zeros((B, 2), np.uint32)
        self._last_tok = np.zeros((B,), np.int32)
        self._slots: list[dict | None] = [None] * B
        self._queue: collections.deque = collections.deque()
        self._admit_seq = 0
        self._compiled_variants: set[tuple] = set()
        self.stats = {"admitted": 0, "finished": 0, "preemptions": 0,
                      "decode_steps": 0, "decode_tokens": 0,
                      "decode_seconds": 0.0, "steady_decode_tokens": 0,
                      "prefill_tokens": 0, "admit_wait_seconds": 0.0,
                      "prefill_chunks": 0, "max_prefill_tokens_per_step": 0,
                      "prefix_hits": 0, "prefix_misses": 0,
                      "prefix_hit_tokens": 0, "spec_steps": 0,
                      "spec_draft_tokens": 0, "spec_accepted_tokens": 0,
                      "quarantined": 0, "retries_exhausted": 0,
                      "kv_flips": 0}
        self.admit_waits: list[float] = []      # per-admission queue wait, s
        self.decode_times: list[float] = []     # steady per-step decode, s
        self._clock = clock if clock is not None else time.perf_counter
        self.autoscaler = autoscaler
        # fault tolerance: an optional deterministic injector polled once
        # per scheduler step (nan_logits / kv_flip fire at this seam), a
        # poison set marking requests whose next logits must be treated as
        # non-finite, and a per-request retry budget — a request that keeps
        # getting preempted or migrated off dying replicas eventually fails
        # with reason='retries' instead of circulating forever
        self._faults = fault_injector
        self.replica_id = int(replica_id)
        if retry_budget < 1:
            raise ValueError(f"retry_budget must be >= 1, got {retry_budget}")
        self.retry_budget = int(retry_budget)
        self._poison_rids: set[int] = set()
        self._step_no = 0
        if self.prefix is not None:
            # integrity guard: stamp trie pages with a content checksum at
            # insert, re-verified at use — see PrefixCache / _page_checksum
            self.prefix.checksum_fn = self._page_checksum
        self._params_full = params
        self._params_by_bits: dict[int, Any] = {}
        self.weight_bits: int | None = None     # None until set_weight_bits

        # self-speculative decoding: the b-bit draft is a zero-copy
        # slice_planes view of the served bit-plane artifact — built (and
        # validated) eagerly so a spec engine without bitplane weights
        # fails at construction, not mid-trace
        if int(spec_decode) < 0:
            raise ValueError(f"spec_decode must be >= 0, got {spec_decode}")
        if spec_decode and draft_bits is None:
            raise ValueError(
                "spec_decode needs draft_bits (the low-bit draft view, "
                "e.g. draft_bits=4)")
        if draft_bits is not None and not spec_decode:
            raise ValueError("draft_bits without spec_decode has no effect")
        self.spec_decode = int(spec_decode)
        self.draft_bits = int(draft_bits) if draft_bits is not None else None
        self._params_draft = (self._sliced_tree(self.draft_bits)
                              if self.spec_decode else None)

        # two decode variants: the greedy-only one skips the sort +
        # categorical machinery entirely (the common case); lazily compiled
        self._decode_jits: dict[bool, Any] = {}
        self._prefill_jits: dict[int, Any] = {}
        self._chunk_jit_fn = None
        self._draft_jit_fn = None
        self._verify_jits: dict[bool, Any] = {}
        self._sample1 = jax.jit(
            lambda lg, t, k, key: sampling.sample_tokens(
                lg[None], t[None], k[None], key[None])[0])

    # ------------------------------------------------------------ device fns
    def _make_decode_fn(self, sampled: bool):
        """``sampled=False`` compiles the greedy-only fast path (no vocab
        sort, no categorical) — picked per step from host state."""
        cfg, spec = self.cfg, self.cfg.attn_spec
        page = self.page_size
        kb = registry.get(self.backend)

        def decode_fn(params, pool, tokens, positions, block_table, active,
                      base_keys, temps, topks):
            b = tokens.shape[0]
            pos = positions.astype(jnp.int32)
            x = embed(params["embed"], tokens).astype(cfg.dtype)      # (B,1,d)
            page_ids = jnp.take_along_axis(
                block_table, (pos // page)[:, None], axis=1)[:, 0]
            page_ids = jnp.where(active, page_ids, 0)                 # null page
            offs = pos % page
            new_lens = pos + active.astype(jnp.int32)

            def body(h, inp):
                layer, kp, vp, ks, vs = inp
                box = {}

                def attend(z):
                    q, k, v = attn.decode_qkv(layer["attn"], z, spec,
                                              pos[:, None])
                    kp2, vp2, ks2, vs2 = pg.append_rows(
                        kp, vp, ks, vs, k[:, 0], v[:, 0], page_ids, offs)
                    box["planes"] = (kp2, vp2, ks2, vs2)
                    out = kb.paged_attention(
                        q[:, 0], kp2, vp2, ks2, vs2, block_table, new_lens,
                        softmax_scale=spec.scale)
                    return dense(layer["attn"]["o"], out.reshape(
                        b, 1, spec.n_heads * spec.head_dim))

                h = T.decode_layer_block(cfg, layer, h, attend)
                return h, box["planes"]

            xs = (params["layers"], pool.k_pages, pool.v_pages,
                  pool.k_scale, pool.v_scale)
            x, planes = jax.lax.scan(body, x, xs)
            new_pool = pg.PagedKVPool(*planes)
            logits = T.final_logits(params, cfg, x)[:, 0]             # (B, V)
            if sampled:
                keys = jax.vmap(sampling.slot_key)(base_keys, pos + 1)
                tok = sampling.sample_tokens(logits, temps, topks, keys)
            else:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            # per-slot integrity flag: a NaN/inf anywhere in a slot's
            # logits means its context is poisoned — the scheduler
            # quarantines that one request instead of failing the batch
            ok = jnp.isfinite(logits).all(axis=-1)
            return jnp.where(active, tok, 0), ok, new_pool

        return decode_fn

    def _decode_jit(self, sampled: bool):
        fn = self._decode_jits.get(sampled)
        if fn is None:
            fn = self._decode_jits[sampled] = jax.jit(
                self._make_decode_fn(sampled))
        return fn

    def _prefill_jit(self, bucket: int):
        """One compile per page-multiple *bucket*, not per exact prompt
        length: prompts are right-padded to the bucket, logits read at the
        true last position (causality shields it from the pad garbage), and
        the pad rows land in the last page masked by seq_len — decode
        appends overwrite them one by one as generation proceeds."""
        fn = self._prefill_jits.get(bucket)
        if fn is None:
            cfg_fp = self._cfg_fp

            def prefill_fn(params, toks, last_pos, page_ids, pool):
                logits, state = T.prefill(params, toks, cfg_fp,
                                          last_pos=last_pos)
                k_all = state.layers.k[:, 0]       # (L, bucket, Hkv, D)
                v_all = state.layers.v[:, 0]
                return logits[0], pg.write_prompt(pool, k_all, v_all, page_ids)

            fn = self._prefill_jits[bucket] = jax.jit(prefill_fn)
        return fn

    def _make_chunk_fn(self):
        """One prefill chunk: ``C = chunk_pages × page_size`` tokens at an
        absolute page-aligned offset, **write-then-attend-quantized**.

        Every call has the same static shapes — chunk width, block-table
        row, gathered context — so the engine compiles this exactly once,
        and a warm (prefix-hit) admission replays byte-identical calls to
        the cold run that minted the shared pages: same tokens, same
        positions, same gathered values (shared pages hold the codes the
        cold run wrote); only the write targets differ (``page_ids`` entry 0
        parks a shared page's recomputed rows on the null page). Bit-exact
        hit-vs-cold outputs follow structurally.

        Per layer the chunk's K/V rows are quantized and scattered into the
        pool *first*, then every query attends the dequantized gathered
        context (earlier pages + this chunk, causally masked) — the decode
        step's append-then-attend order, so chunked prefill and decode
        produce identical codes for the same token stream.
        """
        cfg, spec = self.cfg, self.cfg.attn_spec
        page, cp = self.page_size, self.chunk_pages
        C = cp * page
        n_ctx = self.max_pages_per_seq * page
        g, d = spec.n_kv_heads, spec.head_dim

        def chunk_fn(params, pool, toks, pos0, page_ids, true_len, bt_row,
                     last_rel):
            positions = pos0 + jnp.arange(C, dtype=jnp.int32)         # (C,)
            h = embed(params["embed"], toks[None]).astype(cfg.dtype)  # (1,C,d)
            key_pos = jnp.arange(n_ctx, dtype=jnp.int32)
            # causal within the valid context; pad queries (positions ≥
            # true_len) still see ≥1 key, so no all-masked softmax rows
            mask = ((key_pos[None, :] <= positions[:, None])
                    & (key_pos[None, :] < true_len))                  # (C,S)
            bt = bt_row[None]                                         # (1,MP)

            def body(h, inp):
                layer, kp, vp, ks, vs = inp
                kv_bits = kv_bits_of(kp)
                box = {}

                def attend(z):
                    pa = layer["attn"]
                    q = dense(pa["q"], z).reshape(1, C, spec.n_heads, d)
                    k = dense(pa["k"], z).reshape(1, C, g, d)
                    v = dense(pa["v"], z).reshape(1, C, g, d)
                    q = apply_rope(q, positions[None], spec.rope_theta)
                    k = apply_rope(k, positions[None], spec.rope_theta)
                    kc, ksc = pg.quant_rows(
                        k[0].reshape(cp, page, g, d), kv_bits, kp.dtype)
                    vc, vsc = pg.quant_rows(
                        v[0].reshape(cp, page, g, d), kv_bits, vp.dtype)
                    kp2 = kp.at[page_ids].set(kc)
                    vp2 = vp.at[page_ids].set(vc)
                    ks2 = ks.at[page_ids].set(ksc) if kv_bits else ks
                    vs2 = vs.at[page_ids].set(vsc) if kv_bits else vs
                    box["planes"] = (kp2, vp2, ks2, vs2)
                    kk = dequant_pages_ref(
                        gather_pages_ref(kp2, bt),
                        gather_pages_ref(ks2, bt) if kv_bits else None)
                    vv = dequant_pages_ref(
                        gather_pages_ref(vp2, bt),
                        gather_pages_ref(vs2, bt) if kv_bits else None)
                    out = attn._attend_block(q, kk, vv, spec.scale, mask)
                    return dense(pa["o"], out.reshape(
                        1, C, spec.n_heads * d))

                h = T.decode_layer_block(cfg, layer, h, attend)
                return h, box["planes"]

            xs = (params["layers"], pool.k_pages, pool.v_pages,
                  pool.k_scale, pool.v_scale)
            h, planes = jax.lax.scan(body, h, xs)
            new_pool = pg.PagedKVPool(*planes)
            logits = T.final_logits(params, cfg, h)[0]                # (C, V)
            return logits[last_rel], new_pool

        return chunk_fn

    def _chunk_jit(self):
        if self._chunk_jit_fn is None:
            self._chunk_jit_fn = jax.jit(self._make_chunk_fn())
        return self._chunk_jit_fn

    def _make_draft_fn(self):
        """``spec_decode`` greedy decode steps through the low-bit draft
        tree against each slot's **scratch KV tail**: every scan iteration
        is the vanilla greedy decode fn (same append-then-attend paged
        step), just under ``slice_planes(draft_bits)`` weights, writing rows
        past the committed length. The draft attends its own draft-minted
        scratch rows — it is only a guesser; the verify pass overwrites
        every window row with full-precision-minted codes *before* it
        attends, so no draft bit ever reaches committed state."""
        decode_fn = self._make_decode_fn(sampled=False)
        k = self.spec_decode

        def draft_fn(params, pool, last_tok, lens, block_table, active,
                     base_keys, temps, topks):
            def body(carry, _):
                pool, tok, lens = carry
                nxt, _, pool = decode_fn(params, pool, tok[:, None], lens,
                                         block_table, active, base_keys,
                                         temps, topks)
                return (pool, nxt, lens + active.astype(jnp.int32)), nxt

            (pool, _, _), toks = jax.lax.scan(
                body, (pool, last_tok, lens), None, length=k)
            return jnp.moveaxis(toks, 0, 1), pool              # (B, k)

        return draft_fn

    def _draft_jit(self):
        if self._draft_jit_fn is None:
            self._draft_jit_fn = jax.jit(self._make_draft_fn())
        return self._draft_jit_fn

    def _make_verify_fn(self, sampled: bool):
        """Score all ``W = spec_decode + 1`` window positions — the slot's
        pending token plus its k draft tokens — in ONE full-precision
        forward, batched over slots (the chunked-prefill shape, batched:
        per-slot positions, per-slot scatter targets, per-slot causal mask).

        Write-then-attend: each layer quantizes and scatters the window's
        K/V rows into the pool first (:func:`repro.serve.pages.write_rows`,
        overwriting the draft's scratch rows), then attends the dequantized
        gathered context — so window position i reads codes identical to
        what sequential decode would have read at that position, and the
        rows of *accepted* tokens are already exactly the rows a sequential
        decode would have written. That structural identity, not a
        tolerance, is the token-identity guarantee.

        ``sampled`` draws every window position with the same
        fold_in(base, position) key sequential decode uses
        (:func:`repro.serve.sampling.window_keys`) — temperature > 0 falls
        back to verify-step sampling with unchanged output."""
        cfg, spec = self.cfg, self.cfg.attn_spec
        page = self.page_size
        W = self.spec_decode + 1
        n_ctx = self.max_pages_per_seq * page
        g, d = spec.n_kv_heads, spec.head_dim

        def verify_fn(params, pool, draft, last_tok, lens, block_table,
                      active, base_keys, temps, topks):
            b = last_tok.shape[0]
            toks = jnp.concatenate([last_tok[:, None], draft], axis=1)
            positions = lens[:, None] + jnp.arange(W, dtype=jnp.int32)
            page_ids = jnp.take_along_axis(
                block_table, positions // page, axis=1)
            page_ids = jnp.where(active[:, None], page_ids, 0)    # null page
            offs = positions % page
            h = embed(params["embed"], toks).astype(cfg.dtype)    # (B, W, d)
            key_pos = jnp.arange(n_ctx, dtype=jnp.int32)
            # per-slot causal mask: rows ≤ the query's absolute position are
            # either committed history or freshly written this window; pages
            # past the runway are unreachable (key_pos > position)
            mask = key_pos[None, None, :] <= positions[:, :, None]  # (B,W,S)

            def body(h, inp):
                layer, kp, vp, ks, vs = inp
                kv_bits = kv_bits_of(kp)
                box = {}

                def attend(z):
                    pa = layer["attn"]
                    q = dense(pa["q"], z).reshape(b, W, spec.n_heads, d)
                    kx = dense(pa["k"], z).reshape(b, W, g, d)
                    vx = dense(pa["v"], z).reshape(b, W, g, d)
                    q = apply_rope(q, positions, spec.rope_theta)
                    kx = apply_rope(kx, positions, spec.rope_theta)
                    kp2, vp2, ks2, vs2 = pg.write_rows(
                        kp, vp, ks, vs, kx, vx, page_ids, offs)
                    box["planes"] = (kp2, vp2, ks2, vs2)
                    kk = dequant_pages_ref(
                        gather_pages_ref(kp2, block_table),
                        gather_pages_ref(ks2, block_table) if kv_bits
                        else None)
                    vv = dequant_pages_ref(
                        gather_pages_ref(vp2, block_table),
                        gather_pages_ref(vs2, block_table) if kv_bits
                        else None)
                    out = attn._attend_block(q, kk, vv, spec.scale, mask)
                    return dense(pa["o"], out.reshape(
                        b, W, spec.n_heads * d))

                h = T.decode_layer_block(cfg, layer, h, attend)
                return h, box["planes"]

            xs = (params["layers"], pool.k_pages, pool.v_pages,
                  pool.k_scale, pool.v_scale)
            h, planes = jax.lax.scan(body, h, xs)
            new_pool = pg.PagedKVPool(*planes)
            logits = T.final_logits(params, cfg, h)               # (B, W, V)
            if sampled:
                keys = sampling.window_keys(base_keys, positions + 1)
                tgt = sampling.sample_tokens(
                    logits.reshape(b * W, -1), jnp.repeat(temps, W),
                    jnp.repeat(topks, W),
                    keys.reshape(b * W, 2)).reshape(b, W)
            else:
                tgt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            ok = jnp.isfinite(logits).all(axis=(1, 2))
            return jnp.where(active[:, None], tgt, 0), ok, new_pool

        return verify_fn

    def _verify_jit(self, sampled: bool):
        fn = self._verify_jits.get(sampled)
        if fn is None:
            fn = self._verify_jits[sampled] = jax.jit(
                self._make_verify_fn(sampled))
        return fn

    # -------------------------------------------------------------- host API
    def admit_impossible(self, prompt_len: int, max_new_tokens: int) -> str | None:
        """Why a request of this shape can NEVER be admitted here (None =
        admissible once capacity frees up). The ReplicaSet asks every
        replica at submit time so an unservable request is rejected up
        front instead of circulating in the shared queue forever."""
        prompt_len = int(prompt_len)
        if prompt_len == 0:
            return "empty prompt"
        if prompt_len >= self.max_seq_len:
            return (f"prompt of {prompt_len} tokens needs max_seq_len > "
                    f"that (engine has {self.max_seq_len})")
        worst = pg.pages_needed(
            min(prompt_len + max_new_tokens, self.max_seq_len),
            self.page_size)
        if worst > self.allocator.n_pages - 1:
            return (f"needs {worst} pages, pool has "
                    f"{self.allocator.n_pages - 1}")
        return None

    def submit(self, req: Request) -> None:
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        why = self.admit_impossible(prompt.size, req.max_new_tokens)
        if why is not None:
            raise ValueError(f"request {req.rid} can never fit: {why}")
        self._queue.append({"req": req, "prompt": prompt,
                            "replay": np.zeros((0,), np.int32),
                            "t_submit": self._clock(), "retries": 0})

    def submit_entry(self, entry: dict) -> None:
        """Queue a prepared entry — the migration path: a request harvested
        off a dead replica re-enters here with its original ``t_submit``
        (the admission-latency signal keeps accruing across the failure),
        its committed tokens as the replay list (bit-exact recompute), and
        its retry count (the budget is per-request, not per-replica)."""
        self._queue.append({
            "req": entry["req"],
            "prompt": np.asarray(entry["prompt"], np.int32).reshape(-1),
            "replay": np.asarray(entry.get("replay",
                                           np.zeros((0,), np.int32)),
                                 np.int32).reshape(-1),
            "t_submit": entry["t_submit"],
            "retries": int(entry.get("retries", 0))})

    def harvest(self) -> list[dict]:
        """Strip every in-flight and queued request out of the engine for
        re-dispatch elsewhere (replica death). In-flight requests come back
        as queue entries (admission order first, then the queue) whose
        replay lists carry their committed tokens — replaying prompt +
        committed tokens through the recompute-preemption machinery on a
        survivor is bit-exact, so the migration is output-invisible. Each
        entry's retry count increments (the budget bounds how many deaths
        one request may survive). **No pages are freed**: the pool died
        with the replica; host scheduler state is simply cleared."""
        entries = []
        for _, slot in sorted((s["admit_seq"], i)
                              for i, s in enumerate(self._slots) if s):
            st = self._slots[slot]
            replay = np.concatenate([
                np.asarray(st["gen"], np.int32),
                np.asarray(st["replay_left"], np.int32)])
            entries.append({"req": st["req"], "prompt": st["prompt"],
                            "replay": replay, "t_submit": st["t_submit"],
                            "retries": int(st.get("retries", 0)) + 1})
        for e in self._queue:
            entries.append({**e, "retries": int(e.get("retries", 0)) + 1})
        self._queue.clear()
        self._slots = [None] * self.max_slots
        self._active[:] = False
        self._bt[:] = 0
        self._lens[:] = 0
        return entries

    @property
    def n_pending(self) -> int:
        return len(self._queue)

    @property
    def n_active(self) -> int:
        return int(self._active.sum())

    @property
    def n_prefilling(self) -> int:
        """Slots admitted but still chunk-prefilling (not yet decoding)."""
        return sum(1 for s in self._slots
                   if s is not None and "prefill_pos" in s)

    @property
    def busy(self) -> bool:
        return bool(self._queue) or any(s is not None for s in self._slots)

    def set_weight_bits(self, k: int) -> None:
        """Serve the next decode batches at ``k`` weight bits.

        Swaps ``self.params`` for the tree whose bitplane QTensor weights are
        ``slice_planes(k)`` views of the full artifact — a zero-copy plane
        slice, so no weight reload and no repacking; decode simply streams
        fewer code planes. Trees are cached per k (each k is one extra jit
        trace of the decode step — the shapes differ — amortized after the
        first switch). Requires ``layout='bitplane'`` weights
        (``quantize_param_tree(..., layout='bitplane')``).

        An effective change flushes the prefix cache and marks in-flight
        chunked prefills non-cacheable: pages minted under other weight bits
        must never serve a prefix hit (hit-vs-cold bit-identity is per
        weight precision)."""
        tree = self._sliced_tree(k)
        if tree is not self.params:
            if self.prefix is not None:
                self.prefix.release_all()
            for st in self._slots:
                if st is not None and "prefill_pos" in st:
                    st["no_insert"] = True
        self.params = tree
        self.weight_bits = int(k)

    def _sliced_tree(self, k: int):
        """The cached ``slice_planes(k)`` view of the full artifact — one
        tree per k, shared by :meth:`set_weight_bits` (serving precision)
        and the speculative draft (``draft_bits``). Zero-copy plane slices;
        each distinct k costs one extra jit trace of its decode variant."""
        tree = self._params_by_bits.get(k)
        if tree is None:
            n_hit = [0]

            def slice_leaf(leaf):
                if (isinstance(leaf, QTensor)
                        and leaf.scheme.layout == "bitplane"):
                    n_hit[0] += 1
                    return leaf.slice_planes(min(int(k), leaf.scheme.bits))
                return leaf

            tree = jax.tree.map(slice_leaf, self._params_full,
                                is_leaf=lambda x: isinstance(x, QTensor))
            if not n_hit[0]:
                raise ValueError(
                    "k-bit weight views need layout='bitplane' QTensor "
                    "weights — quantize with quantize_param_tree(..., "
                    "layout='bitplane')")
            self._params_by_bits[k] = tree
        return tree

    def acceptance_rate(self) -> float:
        """Fraction of drafted tokens the full-precision verify accepted
        (NaN before the first speculative window)."""
        drafted = self.stats["spec_draft_tokens"]
        if not drafted:
            return float("nan")
        return self.stats["spec_accepted_tokens"] / drafted

    def kv_pool_nbytes(self, used_only: bool = False) -> int:
        """Logical KV HBM bytes (QTensor.nbytes accounting; §2.2).
        ``used_only`` counts **unique** referenced pages via the allocator —
        a prefix page shared by five block-table rows is five rows of
        logical context but one page of HBM, which is the point."""
        if used_only:
            return pg.pool_nbytes(self.pool, n_pages=self.allocator.n_used)
        return pg.pool_nbytes(self.pool)

    def release_prefix_cache(self) -> int:
        """Drop every trie-held page reference (drain / shutdown); returns
        pages released. In-flight sharers keep theirs."""
        return self.prefix.release_all() if self.prefix is not None else 0

    # ------------------------------------------------------------- scheduler
    def _free_slot(self) -> int | None:
        # occupied ≠ active: a chunk-prefilling slot is occupied but not yet
        # decoding, so scanning ~self._active would double-book it
        for i, s in enumerate(self._slots):
            if s is None:
                return i
        return None

    def _budget(self, entry) -> int:
        """Generation budget: the request's ask, capped by the context."""
        return min(entry["req"].max_new_tokens,
                   self.max_seq_len - len(entry["prompt"]))

    def _bucket(self, s: int) -> int:
        return pg.pages_needed(max(s, 1), self.page_size) * self.page_size

    def _alloc_pages(self, n: int) -> list[int] | None:
        """Allocate ``n`` pages, evicting unreferenced prefix-cache leaves
        (LRU) under pressure before giving up."""
        ids = self.allocator.alloc(n)
        while ids is None and self.prefix is not None \
                and self.prefix.evict(1):
            ids = self.allocator.alloc(n)
        return ids

    def _replaying(self) -> bool:
        """True while any recompute-replay is in flight (in a slot or still
        queued) — the window where weight-bits actuation must be deferred."""
        return (any(s is not None and s["replay_left"] for s in self._slots)
                or any(e["replay"].size for e in self._queue))

    def _admit(self, finished: list) -> None:
        while self._queue:
            entry = self._queue[0]
            if int(entry.get("retries", 0)) > self.retry_budget:
                # preempted/migrated past the budget: fail it with a status
                # (its committed tokens ride along) rather than letting a
                # pathological evict-replay or die-migrate cycle spin forever
                self._queue.popleft()
                self.stats["retries_exhausted"] += 1
                finished.append(self._finish_entry(entry, reason="retries"))
                continue
            slot = self._free_slot()
            if slot is None:
                return
            prompt = entry["prompt"]
            replay = entry["replay"]
            s = int(prompt.size)
            budget = self._budget(entry)
            if budget <= 0:                        # context already full
                self._queue.popleft()
                finished.append(self._finish_entry(entry, reason="length"))
                continue
            n_now = pg.pages_needed(s + 1, self.page_size)
            n_res = (pg.pages_needed(min(s + budget, self.max_seq_len),
                                     self.page_size)
                     if self.reserve == "full" else n_now)
            # prefix hit: the first m pages come shared from the trie (one
            # reference taken per page — ours now); only the rest is
            # allocated. Referencing before _alloc_pages keeps the eviction
            # scan from freeing the very pages we just matched.
            shared = self.prefix.use(prompt) if self.prefix is not None else []
            m = len(shared)
            ids = self._alloc_pages(max(n_res, n_now) - m)
            if ids is None:
                if shared:
                    self.allocator.free(shared)    # hand the refs back
                return                             # FIFO head-of-line wait
            self._queue.popleft()
            wait = max(0.0, self._clock() - entry["t_submit"])
            self.stats["admit_wait_seconds"] += wait
            self.admit_waits.append(wait)
            req = entry["req"]
            all_ids = shared + ids
            row = np.zeros((self.max_pages_per_seq,), np.int32)
            row[:len(all_ids)] = all_ids
            self._bt[slot] = row
            self._temps[slot] = req.temperature
            self._topks[slot] = req.top_k
            base = np.asarray(jax.random.fold_in(
                jax.random.PRNGKey(req.seed), req.rid), np.uint32)
            self._base_keys[slot] = base
            if self.prefix is not None:
                if m:
                    self.stats["prefix_hits"] += 1
                    self.stats["prefix_hit_tokens"] += m * self.page_size
                else:
                    self.stats["prefix_misses"] += 1
            state = {"req": req, "prompt": prompt, "gen": [],
                     "replay_left": list(replay), "pages": all_ids,
                     "admit_seq": self._admit_seq,
                     "t_submit": entry["t_submit"],
                     "retries": int(entry.get("retries", 0))}
            self._admit_seq += 1
            self.stats["admitted"] += 1

            if self._chunked:
                # chunks are skipped only when the hit covers them entirely;
                # a partially-hit chunk recomputes at identical shapes (its
                # shared pages write to the null page) — see _make_chunk_fn
                C = self.chunk_pages * self.page_size
                state["prefill_pos"] = (m // self.chunk_pages) * C
                state["shared_pages"] = m
                self._lens[slot] = 0
                self._slots[slot] = state
                continue

            bucket = self._bucket(s)
            padded = np.zeros((bucket,), np.int32)
            padded[:s] = prompt
            fn = self._prefill_jit(bucket)
            logits, self.pool = fn(
                self.params, jnp.asarray(padded)[None], jnp.int32(s - 1),
                jnp.asarray(all_ids[:bucket // self.page_size], jnp.int32),
                self.pool)
            self._lens[slot] = s
            self._slots[slot] = state
            self.stats["prefill_tokens"] += s
            self._start_decode(slot, logits, finished)

    def _start_decode(self, slot: int, last_logits, finished: list) -> None:
        """Prompt fully prefilled: take the first token (sampled, or the
        replay head after a preemption) and activate the slot for decode."""
        state = self._slots[slot]
        req = state["req"]
        if state["replay_left"]:
            # recompute preemption: the first generated token is known;
            # the rest replays through forced decode steps
            tok = int(state["replay_left"].pop(0))
        else:
            if req.rid in self._poison_rids \
                    or not bool(np.isfinite(np.asarray(last_logits)).all()):
                # non-finite prefill logits: quarantine before the slot
                # commits a garbage first token (a 1-token request would
                # otherwise *finish* with it)
                self._poison_rids.discard(req.rid)
                self._quarantine_slot(slot, finished)
                return
            s = len(state["prompt"])
            tok = int(self._sample1(
                last_logits, jnp.float32(req.temperature),
                jnp.int32(req.top_k),
                sampling.slot_key(jnp.asarray(self._base_keys[slot]),
                                  jnp.int32(s))))
        state["gen"] = [tok]
        self._active[slot] = True
        self._last_tok[slot] = tok
        self._maybe_finish(slot, finished)

    def _advance_prefills(self, finished: list) -> None:
        """Run ONE chunk for the oldest prefilling slot — per-step prefill
        work is bounded by ``chunk_pages × page_size`` tokens, so long
        admissions interleave with live decode instead of stalling it."""
        cands = [(st["admit_seq"], i) for i, st in enumerate(self._slots)
                 if st is not None and "prefill_pos" in st]
        if not cands:
            return
        _, slot = min(cands)
        st = self._slots[slot]
        prompt = st["prompt"]
        s = int(prompt.size)
        page, cp = self.page_size, self.chunk_pages
        C = cp * page
        start = st["prefill_pos"]
        end = min(s, start + C)
        toks = np.zeros((C,), np.int32)
        toks[:end - start] = prompt[start:end]
        m = st["shared_pages"]
        pids = np.zeros((cp,), np.int32)
        p0 = start // page
        for j in range(cp):
            gp = p0 + j
            if m <= gp < self.max_pages_per_seq:
                pids[j] = self._bt[slot, gp]      # 0 (null) when shared
        logits, self.pool = self._chunk_jit()(
            self.params, self.pool, jnp.asarray(toks), jnp.int32(start),
            jnp.asarray(pids), jnp.int32(end), jnp.asarray(self._bt[slot]),
            jnp.int32(min(s - 1 - start, C - 1)))
        self.stats["prefill_tokens"] += end - start
        self.stats["prefill_chunks"] += 1
        st["prefill_pos"] = start + C
        if end < s:
            return
        del st["prefill_pos"]
        self._lens[slot] = s
        if self.prefix is not None and not st.get("no_insert"):
            self.prefix.insert(
                prompt, [int(p) for p in self._bt[slot, :s // page]])
        self._start_decode(slot, logits, finished)

    def _full_tokens(self, state) -> np.ndarray:
        return np.concatenate([state["prompt"],
                               np.asarray(state["gen"], np.int32)])

    def _finish_entry(self, entry, *, reason: str) -> Finished:
        """A queue entry finished without (re-)admission (context full)."""
        replay = entry["replay"]
        tokens = np.concatenate([entry["prompt"], replay])
        self.stats["finished"] += 1
        return Finished(rid=entry["req"].rid, tokens=tokens,
                        prompt_len=len(entry["prompt"]),
                        n_generated=int(replay.size), reason=reason)

    def _maybe_finish(self, slot: int, finished: list) -> bool:
        state = self._slots[slot]
        req = state["req"]
        n_gen = len(state["gen"])
        full_len = len(state["prompt"]) + n_gen
        reason = None
        if req.eos_id is not None and state["gen"][-1] == req.eos_id:
            reason = "eos"
        elif n_gen >= self._budget(state) or full_len >= self.max_seq_len:
            reason = "length"
        if reason is None:
            return False
        self.allocator.free(state["pages"])        # decref: shared survive
        self._active[slot] = False
        self._bt[slot] = 0
        self._lens[slot] = 0
        self._slots[slot] = None
        self.stats["finished"] += 1
        finished.append(Finished(
            rid=req.rid, tokens=self._full_tokens(state),
            prompt_len=len(state["prompt"]), n_generated=n_gen,
            reason=reason))
        return True

    def _quarantine_slot(self, slot: int, finished: list) -> None:
        """Fail ONE request whose logits went non-finite — with a status,
        not an engine crash — and keep the rest of the batch untouched.

        Page hygiene is the subtle part: the poisoned decode steps appended
        NaN K/V rows into this slot's **private** pages, and masked
        attention does not protect a recycled page's next owner (a masked
        score's softmax weight is 0, but ``0 × NaN = NaN`` through the
        value matmul). Private (refcount-1) pages are therefore scrubbed
        back to zeros before the free; shared pages (prefix hits, trie
        refs) were minted before the poison and stay as they are."""
        state = self._slots[slot]
        req = state["req"]
        private = [p for p in state["pages"]
                   if self.allocator.refcount(p) == 1]
        if private:
            self.pool = pg.scrub_pages(self.pool, private)
        self.allocator.free(state["pages"])
        self._active[slot] = False
        self._bt[slot] = 0
        self._lens[slot] = 0
        self._slots[slot] = None
        self.stats["quarantined"] += 1
        self.stats["finished"] += 1
        finished.append(Finished(
            rid=req.rid, tokens=self._full_tokens(state),
            prompt_len=len(state["prompt"]),
            n_generated=len(state["gen"]), reason="nan"))

    def _page_checksum(self, pid: int) -> int:
        """CRC32 over one pool page's raw code (and scale) bytes across all
        layers — the cheap content fingerprint the prefix trie stamps at
        insert and re-verifies at use, so a corrupted shared page is caught
        before a new sharer ever attends it."""
        pid = int(pid)
        parts = [np.asarray(self.pool.k_pages[:, pid]),
                 np.asarray(self.pool.v_pages[:, pid])]
        if self.plan.kv_bits:
            parts += [np.asarray(self.pool.k_scale[:, pid]),
                      np.asarray(self.pool.v_scale[:, pid])]
        crc = 0
        for a in parts:
            crc = zlib.crc32(np.ascontiguousarray(a).tobytes(), crc)
        return crc

    def _inject_kv_flip(self, spec) -> None:
        """Apply one armed ``kv_flip`` fault: seeded bit flips in a pool
        page's K codes (an explicit ``spec.page``, or a seeded pick among
        currently-allocated pages)."""
        from repro.serve.faults import corrupt_kv_page

        page = spec.page
        if page is None:
            used = self.allocator.used_pages()
            if not used:
                return
            rng = np.random.default_rng(spec.seed)
            page = int(used[int(rng.integers(len(used)))])
        self.pool = corrupt_kv_page(self.pool, page, n_flips=spec.n_flips,
                                    seed=spec.seed)
        self.stats["kv_flips"] += 1

    def _preempt_one(self) -> int | None:
        """Evict the youngest occupied slot (decoding or still prefilling);
        requeue it (front) with its generated tokens as the replay list and
        its **original** ``t_submit`` — restarting the clock here would
        zero the very admission-wait signal the autoscaler governs on.
        Returns the freed slot."""
        cands = [(s["admit_seq"], i) for i, s in enumerate(self._slots) if s]
        if not cands:
            return None
        _, slot = max(cands)
        state = self._slots[slot]
        self.allocator.free(state["pages"])
        self._active[slot] = False
        self._bt[slot] = 0
        self._lens[slot] = 0
        self._slots[slot] = None
        replay = np.concatenate([
            np.asarray(state["gen"], np.int32),
            np.asarray(state["replay_left"], np.int32)])
        self._queue.appendleft({"req": state["req"],
                                "prompt": state["prompt"], "replay": replay,
                                "t_submit": state["t_submit"],
                                "retries": int(state.get("retries", 0)) + 1})
        self.stats["preemptions"] += 1
        return slot

    def _ensure_pages(self) -> None:
        """Before decode: every active slot must own the page its next KV row
        lands in; grow on demand — evicting idle prefix-cache pages first,
        then preempting (youngest-first) when the pool is exhausted."""
        for slot in range(self.max_slots):
            while True:
                if not self._active[slot] or self._slots[slot] is None:
                    break
                pidx = int(self._lens[slot]) // self.page_size
                if self._bt[slot, pidx] != 0:
                    break
                ids = self._alloc_pages(1)
                if ids is not None:
                    self._bt[slot, pidx] = ids[0]
                    self._slots[slot]["pages"].append(ids[0])
                    break
                victim = self._preempt_one()
                if victim is None or victim == slot:
                    break                      # this slot itself got evicted

    def _spec_ready(self) -> bool:
        """Can this step run a speculative window? Checked *after*
        ``_ensure_pages`` so a preemption it caused (replay in flight)
        forces the vanilla fallback; a draft at ≥ the serving precision
        would be pure overhead, so an autoscaler drop to (or below)
        ``draft_bits`` disables speculation until bits are restored."""
        if not self.spec_decode:
            return False
        if self._replaying():
            return False
        if (self.weight_bits is not None
                and self.weight_bits <= self.draft_bits):
            return False
        return True

    def _ensure_spec_pages(self) -> bool:
        """Extend every active slot's block table to cover the window rows
        (positions ``lens .. lens+k``). Speculation never preempts anyone:
        when a slot lacks runway (sequence near ``max_seq_len``) or the
        pool can't supply the scratch pages, the step falls back to vanilla
        decode. Pages allocated here join the slot's ``pages`` list — they
        are committed rows' pages on acceptance, ordinary growth pages
        later otherwise, and are freed with the slot either way (the
        preemption-with-draft-tail leak test pins this)."""
        k = self.spec_decode
        for slot in range(self.max_slots):
            if not self._active[slot] or self._slots[slot] is None:
                continue
            n = int(self._lens[slot])
            if n + k + 1 > self.max_seq_len:
                return False
            for pidx in range(n // self.page_size,
                              (n + k) // self.page_size + 1):
                if self._bt[slot, pidx] != 0:
                    continue
                ids = self._alloc_pages(1)
                if ids is None:
                    return False
                self._bt[slot, pidx] = ids[0]
                self._slots[slot]["pages"].append(ids[0])
        return True

    def _spec_step(self, finished: list) -> None:
        """One speculative window: k greedy draft steps at ``draft_bits``
        + one batched full-precision verify, then commit the longest
        accepted prefix per slot. Token accounting is exactly-once: a token
        is counted when (and only when) it is committed to ``gen``, and
        committing stops the moment the slot finishes (eos / budget), so a
        slot finishing mid-window never counts its discarded tail — the
        invariant ``decode_tokens == Σ (n_generated - 1)`` holds with or
        without speculation. One wall-clock entry (draft + verify) lands in
        ``decode_times`` per window."""
        k = self.spec_decode
        sampled = bool((self._temps[self._active] > 0).any())
        args = (jnp.asarray(self._last_tok), jnp.asarray(self._lens),
                jnp.asarray(self._bt), jnp.asarray(self._active),
                jnp.asarray(self._base_keys), jnp.asarray(self._temps),
                jnp.asarray(self._topks))
        t0 = self._clock()
        draft, pool = self._draft_jit()(self._params_draft, self.pool, *args)
        tgt, ok, self.pool = self._verify_jit(sampled)(
            self.params, pool, draft, *args)
        draft_np = np.asarray(draft)
        tgt_np = np.asarray(tgt)               # blocks until ready
        ok_np = np.asarray(ok)
        dt = self._clock() - t0

        committed = 0
        for slot in range(self.max_slots):
            if not self._active[slot]:
                continue
            state = self._slots[slot]
            rid = state["req"].rid
            if rid in self._poison_rids or not bool(ok_np[slot]):
                self._poison_rids.discard(rid)
                self._quarantine_slot(slot, finished)
                continue
            m = 0
            while m < k and draft_np[slot, m] == tgt_np[slot, m]:
                m += 1
            self.stats["spec_draft_tokens"] += k
            self.stats["spec_accepted_tokens"] += m
            # commit the verify chain: the m accepted draft tokens plus the
            # verify step's own token at the first divergence — exactly the
            # tokens sequential decode would have produced
            for tok in tgt_np[slot, :m + 1]:
                tok = int(tok)
                self._lens[slot] += 1
                state["gen"].append(tok)
                self._last_tok[slot] = tok
                committed += 1
                if self._maybe_finish(slot, finished):
                    break

        self.stats["decode_steps"] += 1
        self.stats["spec_steps"] += 1
        self.stats["decode_tokens"] += committed
        variant = ("spec", sampled, self.weight_bits)
        if variant in self._compiled_variants:  # steady state: skip compiles
            self.stats["decode_seconds"] += dt
            self.stats["steady_decode_tokens"] += committed
            self.decode_times.append(dt)
        self._compiled_variants.add(variant)

    def step(self) -> list[Finished]:
        """One scheduler iteration: admit what fits, advance one prefill
        chunk, decode one token for every live sequence — or, with
        ``spec_decode``, run one speculative window (up to k+1 tokens per
        slot). Returns the requests that finished."""
        finished: list[Finished] = []
        self._step_no += 1
        if self._faults is not None:
            for sp in self._faults.poll("nan_logits", step=self._step_no,
                                        replica=self.replica_id):
                self._poison_rids.add(sp.rid)
            for sp in self._faults.poll("kv_flip", step=self._step_no,
                                        replica=self.replica_id):
                self._inject_kv_flip(sp)
        if self.autoscaler is not None:
            now = self._clock()
            wait = (max(0.0, now - self._queue[0]["t_submit"])
                    if self._queue else 0.0)
            depth = len(self._queue) + self.n_prefilling
            bits = self.autoscaler.observe(
                admit_wait_ms=wait * 1e3, queue_depth=depth, now=now)
            # defer actuation while a replay is in flight: switching weight
            # bits between eviction and replay would rebuild the replayed KV
            # under different weights than the original decode
            if bits != self.weight_bits and not self._replaying():
                self.set_weight_bits(bits)
        pt0 = self.stats["prefill_tokens"]
        self._admit(finished)
        if self._chunked:
            self._advance_prefills(finished)
        self._ensure_pages()
        step_prefill = self.stats["prefill_tokens"] - pt0
        if step_prefill > self.stats["max_prefill_tokens_per_step"]:
            self.stats["max_prefill_tokens_per_step"] = step_prefill
        if not self._active.any():
            return finished

        # speculative window: k cheap draft steps + one full-precision
        # verify. Falls back to a vanilla step while a replay is in flight,
        # when the autoscaler sits at/below the draft's bits, or when any
        # active slot lacks k+1 rows of page runway.
        if self._spec_ready() and self._ensure_spec_pages():
            self._spec_step(finished)
            return finished

        sampled = bool((self._temps[self._active] > 0).any())
        t0 = self._clock()
        tok, ok, self.pool = self._decode_jit(sampled)(
            self.params, self.pool,
            jnp.asarray(self._last_tok)[:, None],
            jnp.asarray(self._lens), jnp.asarray(self._bt),
            jnp.asarray(self._active), jnp.asarray(self._base_keys),
            jnp.asarray(self._temps), jnp.asarray(self._topks))
        tok_np = np.asarray(tok)               # blocks until ready
        ok_np = np.asarray(ok)
        dt = self._clock() - t0
        n_live = int(self._active.sum())
        self.stats["decode_steps"] += 1
        self.stats["decode_tokens"] += n_live
        variant = (sampled, self.weight_bits)
        if variant in self._compiled_variants:  # steady state: skip compiles
            self.stats["decode_seconds"] += dt
            self.stats["steady_decode_tokens"] += n_live
            self.decode_times.append(dt)
        self._compiled_variants.add(variant)

        for slot in range(self.max_slots):
            if not self._active[slot]:
                continue
            state = self._slots[slot]
            rid = state["req"].rid
            if rid in self._poison_rids or not bool(ok_np[slot]):
                # non-finite logits (or an injected poison): fail THIS
                # request with a status instead of crashing the engine —
                # scrub + free its pages so the NaN rows can't leak into
                # the next owner, and leave every other slot untouched
                self._poison_rids.discard(rid)
                self._quarantine_slot(slot, finished)
                continue
            if state["replay_left"]:
                # forced replay (recompute preemption): the decode step
                # rebuilt this position's KV exactly; the token is known
                tok = state["replay_left"].pop(0)
            else:
                tok = int(tok_np[slot])
            self._lens[slot] += 1
            state["gen"].append(tok)
            self._last_tok[slot] = tok
            self._maybe_finish(slot, finished)
        return finished

    def run(self, requests=None, max_steps: int = 100_000) -> dict[int, Finished]:
        """Serve until the queue drains and every sequence finishes."""
        for r in requests or ():
            self.submit(r)
        out: dict[int, Finished] = {}
        for _ in range(max_steps):
            if not self.busy:
                break
            before = (len(self._queue), self.n_active, self.n_prefilling,
                      self.stats["decode_steps"], self.stats["prefill_tokens"])
            for f in self.step():
                out[f.rid] = f
            after = (len(self._queue), self.n_active, self.n_prefilling,
                     self.stats["decode_steps"], self.stats["prefill_tokens"])
            if before == after:
                raise RuntimeError(
                    "scheduler stalled (pool too small for any queued "
                    "request?) — nothing admitted, prefilled, decoded, or "
                    "finished")
        else:
            raise RuntimeError(f"run() exceeded {max_steps} steps")
        return out

    def throughput(self) -> float:
        """Steady-state decode tokens/s (compile step excluded)."""
        if self.stats["decode_seconds"] == 0:
            return float("nan")
        return self.stats["steady_decode_tokens"] / self.stats["decode_seconds"]


__all__ = ["Request", "Finished", "ServeEngine", "SUPPORTED_FAMILIES"]
