"""Deterministic fault injection for the serving stack.

ZipML's serving thesis treats precision as a dial for graceful degradation;
this module applies the same discipline to *faults*: every failure the
fleet must survive — a replica raising mid-step (device loss), a stalled
step, NaN logits for one request, bit flips in KV code planes, a truncated
ship artifact — is injectable as a **seeded, scheduler-step-addressed
event**, so a chaos trace replays bit-for-bit on the injected clock. The
failure path is as testable and pinned as the happy path.

Pieces:

* :class:`VirtualClock` — an injectable clock (the same protocol
  ``ServeEngine(clock=...)`` and the autoscaler already use): calling it
  reads the time, ``advance`` moves it. Chaos runs drive all scheduler
  timing (admission waits, step deadlines, restart backoff) on it, so a
  "30 s stall" costs zero wall-clock and two identical runs see identical
  timestamps.
* :class:`FaultSpec` / :class:`FaultInjector` — the armed fault list.
  Components poll the injector at their seam (``poll(kind, step=...,
  replica=...)``); each armed spec fires **exactly once**, at the first
  poll whose step reaches ``at_step`` on the matching replica, and lands in
  the ``fired`` audit log. The injector holds no hidden state beyond the
  armed/fired lists — replaying the same specs against the same trace
  fires the same faults at the same steps.
* :func:`flip_bits` / :func:`corrupt_kv_page` — seeded bit-level
  corruption of KV code planes (the trie-page-checksum guard's adversary).
* :func:`truncate_ship_artifact` — chop a committed artifact's
  ``arrays.npz`` mid-file (the crash-during-copy case
  ``load_ship_weights`` must turn into a clean error).

Fault kinds and where they fire:

=================  =======================================================
``replica_raise``  ReplicaSet: the replica's next ``step()`` raises
                   :class:`ReplicaDeviceLost` (device loss / OOM stand-in)
``replica_stall``  ReplicaSet: ``stall_s`` seconds elapse inside the step
                   (virtual clocks advance; real clocks sleep)
``nan_logits``     ServeEngine: request ``rid``'s logits read as
                   non-finite → per-request quarantine
``kv_flip``        ServeEngine: ``n_flips`` seeded bit flips in pool page
                   ``page`` (or a seeded pick of an allocated page)
``ship_truncate``  artifact level: callers apply
                   :func:`truncate_ship_artifact` before a restart
=================  =======================================================
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

FAULT_KINDS = ("replica_raise", "replica_stall", "nan_logits", "kv_flip",
               "ship_truncate")


class ReplicaDeviceLost(RuntimeError):
    """An injected (or real) replica device loss surfaced from ``step()``."""


class VirtualClock:
    """A deterministic injectable clock: ``clock()`` reads, ``advance``
    moves. Drop-in for ``time.perf_counter`` everywhere the serving stack
    takes ``clock=`` — chaos benches step it a fixed dt per scheduler
    iteration so stalls, deadlines and restart backoff cost no wall time
    and replay identically."""

    def __init__(self, t0: float = 0.0):
        self._t = float(t0)

    def __call__(self) -> float:
        return self._t

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError(f"clocks only move forward, got dt={dt}")
        self._t += float(dt)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One armed fault: ``kind`` fires once at the first poll of matching
    ``replica`` whose scheduler step reaches ``at_step``. ``rid`` targets
    one request (``nan_logits``); ``page`` targets one pool page
    (``kv_flip``; None = seeded pick of an allocated page); ``stall_s`` is
    the injected step duration (``replica_stall``); ``seed`` drives every
    random choice the fault makes."""

    kind: str
    at_step: int
    replica: int = 0
    rid: int | None = None
    page: int | None = None
    stall_s: float = 0.0
    n_flips: int = 1
    seed: int = 0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; expected one of "
                f"{FAULT_KINDS}")
        if self.at_step < 0:
            raise ValueError(f"at_step must be >= 0, got {self.at_step}")


class FaultInjector:
    """The armed fault list components poll at their seams.

    ``poll(kind, step=, replica=)`` returns the specs of that kind due now
    (``step >= at_step`` and, when the caller names a replica, matching
    ``spec.replica``), disarming each — a spec fires exactly once. Every
    firing is appended to ``fired`` (kind, step, replica, spec), which is
    the replayable chaos trace: same specs + same schedule ⇒ same log.
    """

    def __init__(self, specs=(), *, clock=None):
        self._armed: list[FaultSpec] = []
        for sp in specs:
            if not isinstance(sp, FaultSpec):
                sp = FaultSpec(**sp)
            self._armed.append(sp)
        self.clock = clock
        self.fired: list[dict] = []

    @property
    def n_armed(self) -> int:
        return len(self._armed)

    def arm(self, spec: FaultSpec) -> None:
        self._armed.append(spec)

    def poll(self, kind: str, *, step: int,
             replica: int | None = None) -> list[FaultSpec]:
        """Fire-and-disarm every armed ``kind`` spec due at ``step`` for
        ``replica`` (None matches any replica)."""
        if kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {kind!r}")
        due, rest = [], []
        for sp in self._armed:
            if (sp.kind == kind and step >= sp.at_step
                    and (replica is None or sp.replica == replica)):
                due.append(sp)
                self.fired.append({
                    "kind": kind, "step": int(step), "replica": replica,
                    "t": self.clock() if self.clock is not None else None,
                    "spec": sp})
            else:
                rest.append(sp)
        self._armed = rest
        return due


def flip_bits(arr: np.ndarray, n_flips: int = 1, seed: int = 0) -> np.ndarray:
    """Return a copy of ``arr`` with ``n_flips`` seeded single-bit flips at
    uniformly random bit positions (byte-granular XOR, dtype-agnostic —
    works on int8 codes, packed-nibble uint8 planes, and bf16 rows alike)."""
    out = np.ascontiguousarray(np.asarray(arr)).copy()
    flat = out.reshape(-1).view(np.uint8)
    rng = np.random.default_rng(seed)
    for pos in rng.integers(0, flat.size * 8, size=int(n_flips)):
        flat[pos // 8] ^= np.uint8(1 << (pos % 8))
    return out


def corrupt_kv_page(pool, page: int, *, n_flips: int = 4, seed: int = 0):
    """Flip ``n_flips`` seeded bits in pool page ``page``'s K code plane
    (all layers) — the silent-corruption adversary the trie's page
    checksums exist to catch. Returns the updated pool (same structure)."""
    import jax.numpy as jnp

    page = int(page)
    if not 0 <= page < pool.n_pages:
        raise ValueError(f"page {page} outside pool of {pool.n_pages}")
    k = np.asarray(pool.k_pages)
    corrupted = flip_bits(k[:, page], n_flips=n_flips, seed=seed)
    k = k.copy()
    k[:, page] = corrupted
    return pool._replace(k_pages=jnp.asarray(k))


def truncate_ship_artifact(directory: str, keep_bytes: int = 128) -> str:
    """Truncate a committed ship artifact's ``arrays.npz`` to ``keep_bytes``
    — the torn-copy/partial-restore case. The ``.complete`` marker is left
    in place on purpose: the marker guards against *interrupted writes*;
    this simulates corruption **after** commit, which only a clean loader
    error (:class:`repro.ckpt.ship.ShipArtifactError`) can surface."""
    path = os.path.join(directory, "arrays.npz")
    size = os.path.getsize(path)
    if keep_bytes >= size:
        raise ValueError(
            f"keep_bytes={keep_bytes} >= file size {size} — nothing truncated")
    with open(path, "r+b") as f:
        f.truncate(int(keep_bytes))
    return path


__all__ = ["FAULT_KINDS", "FaultInjector", "FaultSpec", "ReplicaDeviceLost",
           "VirtualClock", "corrupt_kv_page", "flip_bits",
           "truncate_ship_artifact"]
