"""repro.serve — the continuous-batching low-precision serving engine.

* :class:`ServeEngine` / :class:`Request` / :class:`Finished` — the
  iteration-level scheduler (admit / chunked or monolithic prefill /
  batched paged decode / evict) over a mixed request stream; with
  ``spec_decode=k, draft_bits=b`` it runs **self-speculative decoding** —
  a k-token greedy draft through the b-bit ``slice_planes`` view of the
  served bitplane weights, verified by one batched full-precision forward,
  token-identical to vanilla decode (engine.py).
* :class:`PagedKVPool` + :class:`PageAllocator` — the paged KV cache whose
  pages are QTensor code planes: bf16 / int8 / packed int4 per
  ``PrecisionPlan.kv_bits``; the allocator refcounts pages so full
  (immutable) pages can be shared read-only across sequences (pages.py).
* :class:`PrefixCache` — the radix/trie prefix index over completed prompt
  pages behind ``ServeEngine(prefix_cache=True)``: page-aligned shared
  prompt prefixes skip prefill and point block-table rows at the shared
  quantized code pages, copy-on-write by refcount (prefix.py).
* :func:`sample_tokens` — greedy / temperature / top-k with per-request
  keys (sampling.py).
* :class:`PrecisionAutoscaler` + :class:`AutoscalerConfig` — the
  load-adaptive precision governor: walks a bits ladder against an
  admission-latency SLO with hysteresis; the engine actuates it through
  ``set_weight_bits`` on bit-plane weights (autoscaler.py).

* :class:`FaultInjector` + :class:`FaultSpec` + :class:`VirtualClock` —
  seeded, deterministic fault injection at the engine/replica seams
  (device loss, stalls, NaN logits, KV bit flips, truncated artifacts);
  the fired log is a replayable chaos trace (faults.py).

The decode hot loop dispatches through :mod:`repro.kernels.registry`'s
``paged_attention`` op: ``ref`` gathers pages and reuses the legacy decode
softmax (bit-exact with the ring buffer); ``pallas`` streams pages by block
table with in-kernel int8/int4 dequantization (kernels/paged_attn.py).
"""
from .autoscaler import AutoscalerConfig, PrecisionAutoscaler
from .faults import (FaultInjector, FaultSpec, ReplicaDeviceLost,
                     VirtualClock)
from .engine import Finished, Request, ServeEngine
from .pages import (PageAllocator, PagedKVPool, init_pool, pool_nbytes,
                    scrub_pages)
from .prefix import PrefixCache
from .sampling import sample_tokens

__all__ = [
    "AutoscalerConfig",
    "FaultInjector",
    "FaultSpec",
    "Finished",
    "PageAllocator",
    "PagedKVPool",
    "PrecisionAutoscaler",
    "PrefixCache",
    "ReplicaDeviceLost",
    "Request",
    "ServeEngine",
    "VirtualClock",
    "init_pool",
    "pool_nbytes",
    "sample_tokens",
    "scrub_pages",
]
