"""Paged KV-cache pool: fixed-size pages of QTensor code planes + a host-side
page allocator.

Layout (vLLM-style, quantized à la ZipML/MLWeaving):

* ``PagedKVPool`` — one pool per model, pages stacked over layers:
  ``k_pages``/``v_pages``: (L, P, page, Hkv, D) bf16 or int8 codes, or
  (L, P, page, Hkv, D/2) uint8 **packed int4** (two offset-binary nibbles per
  byte — :func:`repro.quant.pack_int4`). Quantized pools carry per-(token,
  head) fp32 scales (L, P, page, Hkv, 1) — the same row-symmetric nearest
  scheme the legacy ring buffer used (``QScheme.int_symmetric(bits,
  scaling='row', rounding='nearest')``), so paged and ring codes are
  identical bit-for-bit.
* a **block table** (B_slots, MAXP) int32 of page indices per sequence plus
  ``seq_lens`` (B_slots,) — owned by the engine, passed into every kernel
  call. All layers of one sequence share one block-table row (each layer has
  its own page storage at the same indices).
* **page 0 is the null page**: the allocator never hands it out, inactive
  slots point at it, and masked decode writes land there — so a dead slot
  can never corrupt a live sequence.

``pool_nbytes`` reports logical HBM bytes straight from ``QTensor.nbytes``
(shape-only views — nothing is materialized), which is what
benchmarks/bench_serve_engine.py charts: int8 ≈ 2×, packed int4 ≈ 3.5× fewer
KV bytes than bf16 at production head dims.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.quant import QScheme, QTensor, encode


def kv_scheme(kv_bits: int) -> QScheme:
    """The pool's quantization scheme: row-symmetric (per token×head)
    deterministic-nearest int grid; packed nibbles at 4 bits. Matches
    models/attention._quant_rows so paged == ring codes exactly."""
    if kv_bits not in (4, 8):
        raise ValueError(f"quantized KV pools support 4/8 bits, got {kv_bits}")
    return QScheme.int_symmetric(kv_bits, scaling="row", rounding="nearest",
                                 packed=(kv_bits == 4))


class PagedKVPool(NamedTuple):
    """Device-side page storage (a pytree — rides through jit/scan)."""

    k_pages: jax.Array                 # (L, P, page, Hkv, D or D/2)
    v_pages: jax.Array
    k_scale: jax.Array | None = None   # (L, P, page, Hkv, 1) f32 if quantized
    v_scale: jax.Array | None = None

    @property
    def n_layers(self) -> int:
        return self.k_pages.shape[0]

    @property
    def n_pages(self) -> int:
        return self.k_pages.shape[1]

    @property
    def page_size(self) -> int:
        return self.k_pages.shape[2]

    @property
    def kv_bits(self) -> int:
        from repro.kernels.ops import kv_bits_of   # the one dtype→bits rule

        return kv_bits_of(self.k_pages)


def init_pool(n_layers: int, n_pages: int, page_size: int, n_kv: int,
              head_dim: int, *, kv_bits: int = 0,
              dtype=jnp.bfloat16) -> PagedKVPool:
    shape = (n_layers, n_pages, page_size, n_kv)
    if kv_bits == 4:
        if head_dim % 2:
            raise ValueError("packed int4 pool needs an even head_dim")
        return PagedKVPool(
            k_pages=jnp.zeros((*shape, head_dim // 2), jnp.uint8),
            v_pages=jnp.zeros((*shape, head_dim // 2), jnp.uint8),
            k_scale=jnp.ones((*shape, 1), jnp.float32),
            v_scale=jnp.ones((*shape, 1), jnp.float32),
        )
    if kv_bits:
        return PagedKVPool(
            k_pages=jnp.zeros((*shape, head_dim), jnp.int8),
            v_pages=jnp.zeros((*shape, head_dim), jnp.int8),
            k_scale=jnp.ones((*shape, 1), jnp.float32),
            v_scale=jnp.ones((*shape, 1), jnp.float32),
        )
    return PagedKVPool(k_pages=jnp.zeros((*shape, head_dim), dtype),
                       v_pages=jnp.zeros((*shape, head_dim), dtype))


def quant_rows(x: jax.Array, kv_bits: int, dtype=jnp.bfloat16):
    """Quantize new KV rows (…, Hkv, D) → (codes, scale|None) in the pool's
    storage format (``dtype`` is the unquantized page dtype)."""
    if not kv_bits:
        return x.astype(dtype), None
    qt = encode(x, kv_scheme(kv_bits))
    return qt.codes, qt.scale


def write_prompt(pool: PagedKVPool, k: jax.Array, v: jax.Array,
                 page_ids: jax.Array) -> PagedKVPool:
    """Write one sequence's prefill K/V into freshly-allocated pages.

    k/v: (L, S, Hkv, D) post-RoPE rows; page_ids: (n,) int32 with
    n = ceil(S / page). Rows are quantized per (token, head) — identical
    codes to the ring path's prefill_cache_from_kv — padded rows (scale 1,
    codes 0) fill the tail of the last page and stay masked by seq_len.
    """
    L, s, hkv, d = k.shape
    page = pool.page_size
    n = page_ids.shape[0]
    pad = n * page - s
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    k = k.reshape(L, n, page, hkv, d)
    v = v.reshape(L, n, page, hkv, d)
    kc, ks = quant_rows(k, pool.kv_bits, pool.k_pages.dtype)
    vc, vs = quant_rows(v, pool.kv_bits, pool.v_pages.dtype)
    new = pool._replace(k_pages=pool.k_pages.at[:, page_ids].set(kc),
                        v_pages=pool.v_pages.at[:, page_ids].set(vc))
    if pool.kv_bits:
        new = new._replace(k_scale=pool.k_scale.at[:, page_ids].set(ks),
                           v_scale=pool.v_scale.at[:, page_ids].set(vs))
    return new


def append_rows(k_pages: jax.Array, v_pages: jax.Array,
                k_scale: jax.Array | None, v_scale: jax.Array | None,
                k_new: jax.Array, v_new: jax.Array,
                page_ids: jax.Array, offsets: jax.Array):
    """Append one decode token's K/V per slot into ONE layer's page planes
    (the engine calls this inside its per-layer scan body).

    k/v_pages: (P, page, Hkv, Dk); k/v_new: (B, Hkv, D) pre-quantization;
    page_ids/offsets: (B,) int32 — the target (page, row) of each slot
    (inactive slots target the null page 0). Returns the updated planes.
    """
    from repro.kernels.ops import kv_bits_of

    kv_bits = kv_bits_of(k_pages)
    kc, ks = quant_rows(k_new, kv_bits, k_pages.dtype)
    vc, vs = quant_rows(v_new, kv_bits, v_pages.dtype)
    k_pages = k_pages.at[page_ids, offsets].set(kc)
    v_pages = v_pages.at[page_ids, offsets].set(vc)
    if kv_bits:
        k_scale = k_scale.at[page_ids, offsets].set(ks)
        v_scale = v_scale.at[page_ids, offsets].set(vs)
    return k_pages, v_pages, k_scale, v_scale


def write_rows(k_pages: jax.Array, v_pages: jax.Array,
               k_scale: jax.Array | None, v_scale: jax.Array | None,
               k_new: jax.Array, v_new: jax.Array,
               page_ids: jax.Array, offsets: jax.Array):
    """Scatter a **window** of KV rows per slot into ONE layer's page planes
    — the W-wide generalization of :func:`append_rows` used by the
    speculative-decode verify step (W = k_draft + 1 rows per slot, all
    quantized and written before the window attends).

    k/v_new: (B, W, Hkv, D) pre-quantization; page_ids/offsets: (B, W) int32
    per-row targets (rows of inactive slots target the null page 0). The
    codes are minted by the same :func:`quant_rows` row scheme as single-row
    appends — per-(token, head) scaling is row-local, so a row's code is
    identical whether it arrived via decode, chunked prefill, or a verify
    window; that identity is what makes accepted speculative rows committable
    as-is.
    """
    from repro.kernels.ops import kv_bits_of

    kv_bits = kv_bits_of(k_pages)
    kc, ks = quant_rows(k_new, kv_bits, k_pages.dtype)
    vc, vs = quant_rows(v_new, kv_bits, v_pages.dtype)
    k_pages = k_pages.at[page_ids, offsets].set(kc)
    v_pages = v_pages.at[page_ids, offsets].set(vc)
    if kv_bits:
        k_scale = k_scale.at[page_ids, offsets].set(ks)
        v_scale = v_scale.at[page_ids, offsets].set(vs)
    return k_pages, v_pages, k_scale, v_scale


def scrub_pages(pool: PagedKVPool, page_ids) -> PagedKVPool:
    """Zero the codes (and reset scales to 1) of ``page_ids`` across every
    layer — quarantine hygiene. A quarantined sequence's pages can hold
    non-finite K/V rows, and a recycled page must never leak them: masked
    attention zeros a dead position's softmax *probability*, but
    ``0 × NaN = NaN`` straight through the value matmul, so a NaN row in a
    reallocated page would poison its next owner. Scrubbing before the free
    restores the allocator's clean-page invariant."""
    ids = np.asarray(list(page_ids), np.int32)
    if ids.size == 0:
        return pool
    new = pool._replace(
        k_pages=pool.k_pages.at[:, ids].set(0),
        v_pages=pool.v_pages.at[:, ids].set(0))
    if pool.kv_bits:
        new = new._replace(k_scale=pool.k_scale.at[:, ids].set(1.0),
                           v_scale=pool.v_scale.at[:, ids].set(1.0))
    return new


def pool_nbytes(pool: PagedKVPool, n_pages: int | None = None) -> int:
    """Logical KV HBM bytes of ``n_pages`` pages (default: the whole pool),
    accounted through :attr:`repro.quant.QTensor.nbytes` shape-only views —
    the same §2.2 accounting as the training-side benchmarks."""
    P = pool.n_pages if n_pages is None else n_pages
    bits = pool.kv_bits

    def plane(codes_like, scale_like):
        shape = (pool.n_layers, P, *codes_like.shape[2:])
        codes = jax.ShapeDtypeStruct(shape, codes_like.dtype)
        if bits:
            scale = jax.ShapeDtypeStruct((pool.n_layers, P, *scale_like.shape[2:]),
                                         jnp.float32)
            return QTensor(codes, scale, kv_scheme(bits)).nbytes
        # bf16: 16-bit codes, no scale plane (a zero-size struct contributes 0)
        scale = jax.ShapeDtypeStruct((0,), jnp.float32)
        return QTensor(codes, scale,
                       QScheme(bits=16, grid="int", rounding="nearest")).nbytes

    return int(plane(pool.k_pages, pool.k_scale)
               + plane(pool.v_pages, pool.v_scale))


class PageAllocator:
    """Host-side refcounted free-list over pool pages. Page 0 is the reserved
    null page (never allocated): the write target of masked slots.

    Pages are **refcounted** so full (immutable) pages can be shared
    read-only across block-table rows (prefix caching): ``alloc`` hands a
    page out at refcount 1, every additional sharer takes :meth:`incref`, and
    ``free`` is a *decref* — the page returns to the free list only when the
    last reference drops. Double frees (decref of an already-free page) and
    null-page frees still raise, and :meth:`check_leaks` still counts pages
    in use (a shared page counts once, however many rows map it).

    The free list is a LIFO stack mirrored by a set: membership checks
    (double-free detection) are O(1) instead of the old O(n_free) list scan —
    at production pool sizes the scan made ``free`` O(P²) per drain. Alloc
    order is unchanged (a fresh allocator yields 1, 2, …; freed pages are
    reused LIFO), so replay traces stay deterministic.
    """

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError("pool needs at least 2 pages (one is the null page)")
        self.n_pages = n_pages
        self._free = list(range(n_pages - 1, 0, -1))   # pop() yields 1, 2, …
        self._free_set = set(self._free)
        self._rc: dict[int, int] = {}                  # page id → refcount

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        """Unique pages currently referenced (shared pages count once)."""
        return (self.n_pages - 1) - len(self._free)

    def alloc(self, n: int) -> list[int] | None:
        """Allocate ``n`` pages (each at refcount 1), or None (and no
        change) if not enough free."""
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        for i in out:
            self._free_set.discard(i)
            self._rc[i] = 1
        return out

    def incref(self, ids) -> None:
        """Take an additional reference on allocated pages (prefix sharing)."""
        for i in ids:
            i = int(i)
            if i == 0:
                raise ValueError("page 0 is the null page — never allocated")
            if i in self._free_set or i not in self._rc:
                raise ValueError(f"incref of free page {i}")
            self._rc[i] += 1

    def refcount(self, i: int) -> int:
        return self._rc.get(int(i), 0)

    def used_pages(self) -> list[int]:
        """Sorted ids of currently-allocated pages (shared pages once)."""
        return sorted(self._rc)

    def free(self, ids) -> None:
        """Drop one reference per page; pages reaching refcount 0 return to
        the free list. Decref of an already-free page raises (double free)."""
        for i in ids:
            i = int(i)
            if i == 0:
                raise ValueError("page 0 is the null page — never allocated")
            if i in self._free_set:
                raise ValueError(f"double free of page {i}")
            rc = self._rc.get(i, 0)
            if rc <= 0:
                raise ValueError(f"double free of page {i}")
            if rc == 1:
                del self._rc[i]
                self._free.append(i)
                self._free_set.add(i)
            else:
                self._rc[i] = rc - 1

    def check_leaks(self, expected_in_use: int = 0) -> None:
        in_use = (self.n_pages - 1) - len(self._free)
        if in_use != expected_in_use:
            raise AssertionError(
                f"page leak: {in_use} pages in use, expected {expected_in_use}")


def pages_needed(n_tokens: int, page_size: int) -> int:
    return -(-int(n_tokens) // int(page_size))


__all__ = ["PagedKVPool", "PageAllocator", "init_pool", "write_prompt",
           "append_rows", "write_rows", "quant_rows", "pool_nbytes",
           "scrub_pages", "kv_scheme", "pages_needed"]
