"""Load-adaptive precision governor for the serving engine.

Bit-plane weights (``repro.quant`` ``layout='bitplane'``) make serving
precision a *runtime dial*: ``QTensor.slice_planes(k)`` is a zero-copy view
of the top-k magnitude planes, so the engine can drop weight bits under
pressure — decode streams (k+1)/(B+1) of the code bytes, no weight reload,
no repacking — and restore them when the burst passes. Self-speculative
decoding (``ServeEngine(spec_decode=k, draft_bits=b)``) reuses the same
views for its draft pass; the two compose, with two engine-side rules:
rung moves are actuated at the top of ``step()`` only — never inside a
draft/verify window, so a window always runs under one weight precision —
and whenever the governor has walked the serving bits down to or below
``draft_bits`` the engine falls back to vanilla decode (a draft at-or-above
the target's precision predicts nothing the target step wouldn't).

This module is the control loop. :class:`PrecisionAutoscaler` watches the
admission signal the engine already measures (head-of-line queue wait, queue
depth) against an SLO and walks a bits ladder (default 8→4→2→1) with
hysteresis. Under chunked prefill the engine's ``queue_depth`` counts
queued requests **plus** slots still chunk-prefilling — admitted-but-not-
yet-decoding work is load the governor must see, or a burst of long
prompts would read as an empty queue. The engine may also *defer* acting
on the returned bits while a preemption replay is in flight (replayed KV
must be rebuilt under the original weights); the governor itself is
oblivious — it keeps observing every step and the rung move lands on the
first replay-free step:

* ``breach_patience`` consecutive SLO breaches → drop one rung (fewer bits,
  faster decode, more admission throughput).
* ``restore_patience`` consecutive *healthy* observations — wait under
  ``restore_frac × slo`` — → restore one rung.
* anything in between (the dead band) resets both counters, so the governor
  never oscillates across the SLO boundary.

Every rung move is appended to ``decisions`` — a **ring buffer** of the
last ``decision_log_max`` moves (a long-running serve loop must not grow
its audit log unboundedly; ``n_moves`` keeps the lifetime count) — for
offline audit/replay. Time is injected via ``observe(..., now=)`` so tests
and the bench's bursty-trace replay run on a virtual clock.

The governor is engine-agnostic on purpose: it maps observations → bits and
nothing else. The engine owns the actuation (``ServeEngine.set_weight_bits``
swaps in the cached per-k sliced param tree).
"""
from __future__ import annotations

import collections
import dataclasses
import os

SLO_ENV = "ZIPML_SLO_ADMIT_MS"           # default admission-latency SLO (ms)


@dataclasses.dataclass(frozen=True)
class AutoscalerConfig:
    """Knobs for :class:`PrecisionAutoscaler`.

    ``slo_admit_ms`` — the admission-latency SLO: head-of-line queue wait a
    request may accumulate before the governor calls it a breach.
    ``bits_ladder`` — precisions to walk, most→least bits; every entry must
    be servable by the weights' ``slice_planes`` (≤ their stored bits).
    ``queue_high`` — optional depth guard: a queue deeper than this breaches
    even before its head's wait crosses the SLO (None disables).
    """

    slo_admit_ms: float = 50.0
    bits_ladder: tuple[int, ...] = (8, 4, 2, 1)
    breach_patience: int = 2
    restore_patience: int = 4
    restore_frac: float = 0.5
    queue_high: int | None = None
    decision_log_max: int = 256

    def __post_init__(self):
        if self.slo_admit_ms <= 0:
            raise ValueError(f"slo_admit_ms must be > 0, got {self.slo_admit_ms}")
        if not self.bits_ladder:
            raise ValueError("bits_ladder must not be empty")
        if list(self.bits_ladder) != sorted(set(self.bits_ladder), reverse=True):
            raise ValueError(
                f"bits_ladder must be strictly decreasing, got {self.bits_ladder}")
        if not 0.0 < self.restore_frac < 1.0:
            raise ValueError(
                f"restore_frac must be in (0, 1) — it is the hysteresis dead "
                f"band's lower edge — got {self.restore_frac}")
        if self.breach_patience < 1 or self.restore_patience < 1:
            raise ValueError("patience counts must be >= 1")
        if self.decision_log_max < 1:
            raise ValueError(
                f"decision_log_max must be >= 1, got {self.decision_log_max}")

    @classmethod
    def from_env(cls, **overrides) -> "AutoscalerConfig":
        """Config with ``slo_admit_ms`` from ``$ZIPML_SLO_ADMIT_MS`` (if set);
        explicit keyword overrides win."""
        env = os.environ.get(SLO_ENV)
        if env and "slo_admit_ms" not in overrides:
            overrides["slo_admit_ms"] = float(env)
        return cls(**overrides)


class PrecisionAutoscaler:
    """Maps (admit wait, queue depth) observations → serving weight bits.

    Stateless w.r.t. the engine: call :meth:`observe` once per scheduler
    step and actuate when the returned bits change. ``decisions`` logs every
    rung move as ``{"t", "admit_wait_ms", "queue_depth", "bits", "action"}``.
    """

    def __init__(self, config: AutoscalerConfig | None = None):
        self.config = config or AutoscalerConfig.from_env()
        self._idx = 0                        # rung: index into bits_ladder
        self._breach = 0
        self._healthy = 0
        self.n_observations = 0
        # bounded audit trail: a long-running serve loop observes every
        # step forever, so the log is a ring buffer of the last
        # ``decision_log_max`` rung moves; ``n_moves`` keeps the lifetime
        # count after old entries age out
        self.decisions: collections.deque = collections.deque(
            maxlen=self.config.decision_log_max)
        self.n_moves = 0

    @property
    def bits(self) -> int:
        return self.config.bits_ladder[self._idx]

    def observe(self, *, admit_wait_ms: float, queue_depth: int = 0,
                now: float | None = None) -> int:
        """One control-loop tick; returns the bits to serve the next batch."""
        cfg = self.config
        self.n_observations += 1
        deep = cfg.queue_high is not None and queue_depth > cfg.queue_high
        breach = admit_wait_ms > cfg.slo_admit_ms or deep
        healthy = (admit_wait_ms < cfg.restore_frac * cfg.slo_admit_ms
                   and not deep)
        if breach:
            self._healthy = 0
            self._breach += 1
            if (self._breach >= cfg.breach_patience
                    and self._idx + 1 < len(cfg.bits_ladder)):
                self._idx += 1
                self._breach = 0
                self._log("drop", admit_wait_ms, queue_depth, now)
        elif healthy:
            self._breach = 0
            self._healthy += 1
            if self._healthy >= cfg.restore_patience and self._idx > 0:
                self._idx -= 1
                self._healthy = 0
                self._log("restore", admit_wait_ms, queue_depth, now)
        else:                                # dead band: hold the rung
            self._breach = 0
            self._healthy = 0
        return self.bits

    def _log(self, action: str, wait_ms: float, depth: int,
             now: float | None) -> None:
        self.n_moves += 1
        self.decisions.append({
            "t": now, "admit_wait_ms": round(float(wait_ms), 3),
            "queue_depth": int(depth), "bits": self.bits, "action": action})


__all__ = ["SLO_ENV", "AutoscalerConfig", "PrecisionAutoscaler"]
