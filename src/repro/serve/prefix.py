"""Radix/trie prefix index over completed prompt pages.

ZipML's serving thesis is that inference is data-movement-bound — the int4
pool already cut KV bytes 3.5× — yet without sharing, the engine re-prefills
and re-stores identical prompt prefixes (system prompts, few-shot headers)
for every request, wasting exactly the bytes the quantized pool saved. Pages
are **immutable once full** (decode only ever appends to the page holding
position ``seq_len``, which is strictly past every full prompt page), so
prefix sharing via the block table is free: it is the same
one-artifact-serves-all reuse philosophy as MLWeaving's any-precision
bit-planes, applied to the KV cache.

The trie is keyed on *page-sized token runs*: each edge is the byte string
of one page's ``page_size`` token ids, each node owns the pool page holding
that run's quantized K/V codes. Lookup walks the prompt page-by-page and
returns the longest matched chain of pages — capped at ``(len(prompt) - 1)
// page_size`` pages so the un-matched suffix always keeps at least one
token (the engine needs the last prompt position's logits to sample the
first token).

Ownership is refcount-based (see :class:`repro.serve.pages.PageAllocator`):

* the trie itself holds **one reference** per registered page (taken at
  :meth:`insert`), so cached prefixes survive the sequence that created
  them;
* every sharer takes one more reference via :meth:`use`; finishing or
  evicting a sharer decrefs only its own references, so eviction of one
  sequence can never free a page another sequence still maps;
* a trie node is evictable only while it is a **leaf whose page refcount is
  exactly 1** — i.e. no live sequence maps it and no longer cached prefix
  extends it. :meth:`evict` releases such leaves in LRU order (the engine
  calls it under pool pressure, before resorting to preemption).

The trie never stores token values beyond the page keys and never touches
device memory: pages stay where the prefill wrote them; sharing is purely a
block-table and refcount affair.
"""
from __future__ import annotations

import itertools

import numpy as np


class _Node:
    __slots__ = ("key", "page", "children", "parent", "last_used", "checksum")

    def __init__(self, key: bytes, page: int, parent: "_Node | None"):
        self.key = key
        self.page = page
        self.parent = parent
        self.children: dict[bytes, _Node] = {}
        self.last_used = 0
        self.checksum: int | None = None


class PrefixCache:
    """Page-granular prefix index (one instance per engine/replica)."""

    def __init__(self, page_size: int, allocator, *, checksum_fn=None):
        self.page_size = int(page_size)
        self.allocator = allocator
        self._root = _Node(b"", 0, None)       # sentinel: owns no page
        self._ticks = itertools.count(1)
        self.evictions = 0
        # integrity guard: ``checksum_fn(page_id) -> int`` over the page's
        # raw code/scale bytes. insert() stamps each fresh node; use()
        # re-verifies before handing pages to a new sharer, so a corrupted
        # shared page (bit flip, torn write) is evicted and re-prefilled
        # cold instead of silently feeding garbage KV to every sharer.
        self.checksum_fn = checksum_fn
        self.corrupt_evictions = 0

    # ------------------------------------------------------------- internals
    def _page_keys(self, prompt: np.ndarray, n: int) -> list[bytes]:
        p = self.page_size
        toks = np.ascontiguousarray(np.asarray(prompt, np.int32))
        return [toks[i * p:(i + 1) * p].tobytes() for i in range(n)]

    def _walk(self, prompt: np.ndarray) -> list[_Node]:
        """Longest matched node chain, capped so ≥1 suffix token remains."""
        n_max = (len(prompt) - 1) // self.page_size
        node, chain = self._root, []
        for key in self._page_keys(prompt, n_max):
            node = node.children.get(key)
            if node is None:
                break
            chain.append(node)
        return chain

    # ------------------------------------------------------------ public API
    @property
    def n_pages(self) -> int:
        """Pages currently registered (== references the trie holds)."""
        count = 0
        stack = list(self._root.children.values())
        while stack:
            node = stack.pop()
            count += 1
            stack.extend(node.children.values())
        return count

    def match(self, prompt) -> list[int]:
        """Matched prefix page ids — pure read, no refcount side effects."""
        return [n.page for n in self._walk(prompt)]

    def use(self, prompt) -> list[int]:
        """Match and take one reference per matched page for the caller.

        The caller owns the returned references (frees them like its own
        allocations); on a failed admission it must hand them straight back
        via ``allocator.free``. Touches the matched chain's LRU clock.
        """
        chain = self._walk(prompt)
        if self.checksum_fn is not None:
            for idx, node in enumerate(chain):
                if node.checksum is None \
                        or self.checksum_fn(node.page) == node.checksum:
                    continue
                # corrupted shared page: drop it and everything cached past
                # it (descendants' contexts attended the bad rows when
                # minted, so they are suspect too) and truncate the match —
                # the admission re-prefills from here, never attending the
                # corrupt page. Live sharers keep their own references.
                self._drop_subtree(node)
                chain = chain[:idx]
                break
        tick = next(self._ticks)
        for node in chain:
            node.last_used = tick
        pages = [n.page for n in chain]
        self.allocator.incref(pages)
        return pages

    def _drop_subtree(self, node: _Node) -> int:
        """Unlink ``node`` and its descendants, releasing the trie's own
        reference on each page (checksum-mismatch eviction)."""
        del node.parent.children[node.key]
        dropped, stack = 0, [node]
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            self.allocator.free([n.page])
            dropped += 1
            self.corrupt_evictions += 1
        return dropped

    def insert(self, prompt, page_ids) -> int:
        """Register a completed prompt's **full** pages; returns how many
        pages were newly registered (the trie increfs each of those).

        ``page_ids``: the sequence's block-table pages in order, at least
        ``len(prompt) // page_size`` of them. Pages whose token run is
        already cached are skipped — the caller's copy simply stays private
        (two concurrent misses on one prompt race benignly: first to finish
        becomes canonical). The partial tail page is never registered.
        """
        n_full = len(prompt) // self.page_size
        node, fresh = self._root, 0
        tick = next(self._ticks)
        for key, page in zip(self._page_keys(prompt, n_full), page_ids):
            child = node.children.get(key)
            if child is None:
                page = int(page)
                self.allocator.incref([page])
                child = node.children[key] = _Node(key, page, node)
                if self.checksum_fn is not None:
                    child.checksum = self.checksum_fn(page)
                fresh += 1
            child.last_used = tick
            node = child
        return fresh

    def evict(self, n: int = 1) -> int:
        """Release up to ``n`` LRU leaf pages nobody else references
        (refcount exactly 1 — the trie's own). Returns pages freed; evicting
        a leaf may expose its parent, so callers loop until satisfied."""
        freed = 0
        while freed < n:
            victim = None
            stack = list(self._root.children.values())
            while stack:
                node = stack.pop()
                if node.children:
                    stack.extend(node.children.values())
                elif self.allocator.refcount(node.page) == 1:
                    if victim is None or node.last_used < victim.last_used:
                        victim = node
            if victim is None:
                break
            del victim.parent.children[victim.key]
            self.allocator.free([victim.page])
            self.evictions += 1
            freed += 1
        return freed

    def release_all(self) -> int:
        """Drop every trie-held reference and clear the index (drain /
        weight-precision flush). In-flight sharers keep their own references;
        their pages return to the pool when they finish."""
        released = 0
        stack = list(self._root.children.values())
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            self.allocator.free([node.page])
            released += 1
        self._root.children.clear()
        return released


__all__ = ["PrefixCache"]
