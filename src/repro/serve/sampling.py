"""Token sampling for the serving engine: greedy + temperature / top-k.

Every slot samples with its **own** PRNG key, derived from (request seed,
sequence position) — never from the batch layout — so a request's sampled
continuation is identical whether it runs alone or packed into a mixed batch
(the scheduler-invariant the engine tests pin).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_tokens(logits: jax.Array, temperature: jax.Array,
                  top_k: jax.Array, keys: jax.Array) -> jax.Array:
    """logits (B, V) f32 → token ids (B,) int32.

    temperature (B,): ≤ 0 means greedy argmax for that slot.
    top_k (B,) int32: ≤ 0 means no top-k filter; otherwise logits outside the
    k largest are masked before the categorical draw.
    keys (B, 2) uint32: one PRNG key per slot.
    """
    b, v = logits.shape
    greedy_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    # per-slot dynamic top-k: threshold at the k-th largest logit
    sorted_desc = -jnp.sort(-logits, axis=-1)                  # (B, V) desc
    kth_idx = jnp.clip(top_k.astype(jnp.int32), 1, v) - 1
    kth = jnp.take_along_axis(sorted_desc, kth_idx[:, None], axis=-1)
    keep = (top_k[:, None] <= 0) | (logits >= kth)
    masked = jnp.where(keep, logits, -jnp.inf)

    temp = jnp.where(temperature > 0, temperature, 1.0).astype(jnp.float32)
    sampled = jax.vmap(lambda lg, k: jax.random.categorical(k, lg))(
        masked / temp[:, None], keys).astype(jnp.int32)
    return jnp.where(temperature > 0, sampled, greedy_tok)


def slot_key(seed_key: jax.Array, position: jax.Array) -> jax.Array:
    """The per-step sampling key: fold the absolute sequence position into
    the request's base key (batch-composition independent)."""
    return jax.random.fold_in(seed_key, position)


def window_keys(seed_keys: jax.Array, positions: jax.Array) -> jax.Array:
    """:func:`slot_key` over a decode **window**: seed_keys (B, 2) uint32 ×
    positions (B, W) int32 → (B, W, 2). The speculative verify step samples
    all W = k+1 window positions in one pass; because each draw's key is the
    same ``fold_in(base, position)`` a sequential decode would use at that
    position, the verify-sampled chain is token-identical to vanilla sampled
    decode — the property that lets temperature > 0 fall back to verify-step
    sampling instead of disabling speculation."""
    return jax.vmap(jax.vmap(slot_key, in_axes=(None, 0)))(
        seed_keys, positions)


__all__ = ["sample_tokens", "slot_key", "window_keys"]
