"""Training driver: supervisor loop with checkpoint/restart, NaN-skip,
straggler monitoring, and the ZipML precision plan end-to-end.

Runs anywhere: `--arch gemma-2b --reduced` trains the smoke-scale config on
this CPU; on a pod the same flags drive the production mesh. The supervisor
catches step failures, restores the last checkpoint, and resumes — the
1000-node fault model (DESIGN.md §3.2).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --reduced \
      --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import collections
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.ckpt.checkpoint import CheckpointManager
from repro.kernels import registry
from repro.data.pipeline import Cursor, TokenStream, TokenStreamConfig
from repro.launch.steps import make_train_step
from repro.models import transformer as T
from repro.quant import PrecisionPlan
from repro.optim import adamw
from repro.precision import gradcomp


class StragglerMonitor:
    """Per-step timing ring buffer; flags hosts >3σ behind the fleet.

    On a synchronous pjit pod, one slow host gates every collective — the
    monitor's job is detection + data-shard rebalance advice, not recovery
    (recovery = evict + elastic restore, exercised in tests/test_checkpoint).
    """

    def __init__(self, window: int = 50):
        self.times = collections.deque(maxlen=window)
        self.flagged = 0

    def record(self, dt: float) -> bool:
        self.times.append(dt)
        if len(self.times) < 10:
            return False
        mu = float(np.mean(self.times))
        sd = float(np.std(self.times)) + 1e-9
        if dt > mu + 3 * sd:
            self.flagged += 1
            return True
        return False


def train(arch: str, *, kernel_backend: str | None = None, **kwargs):
    """Returns (final_params, losses). See ``_train`` for the remaining kwargs.

    ``kernel_backend`` pins the quantization kernel backend for this run only
    ('ref'/'pallas'); None keeps the registry default (env var / hardware).
    The previous registry selection is restored when the run finishes.
    """
    with registry.using(kernel_backend) as backend:
        print(f"[train] kernel backend: {backend.name} "
              f"(available: {', '.join(registry.available())})")
        return _train(arch, **kwargs)


def _train(arch: str, *, reduced: bool = True, steps: int = 50, batch: int = 8,
           seq: int = 64, ckpt_dir: str | None = None, ckpt_every: int = 20,
           lr: float = 1e-3, grad_bits: int = 0, weight_bits: int = 0,
           moment_bits: int = 0, fail_at: int | None = None,
           log_every: int = 10, precision: PrecisionPlan | None = None):
    """Supervisor body; ``fail_at`` injects a fault (testing).

    ``precision``: a full four-channel :class:`repro.quant.PrecisionPlan`;
    when None one is assembled from the individual ``*_bits`` knobs.
    """
    if precision is None:
        precision = PrecisionPlan(model_bits=weight_bits, grad_bits=grad_bits)
    grad_bits = precision.grad_bits
    get = configs.get_reduced if reduced else configs.get_config
    cfg = get(arch, precision=precision)
    opt_cfg = adamw.AdamWConfig(lr=lr, warmup_steps=max(steps // 10, 1),
                                decay_steps=steps, moment_bits=moment_bits)

    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    opt_state = adamw.init(params, opt_cfg)
    stream = TokenStream(TokenStreamConfig(
        vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch))

    grad_transform = None
    ef_state = {"err": None}
    if grad_bits:
        # C3 gradient-channel compression with error feedback: quantize →
        # dequantize the update stream (the collective itself is GSPMD-managed
        # on this host mesh; wire-format accounting in bench_bandwidth_model)
        def grad_transform(grads, k):  # noqa: F811
            comp, ef_state["err"] = gradcomp.compress_tree(
                grads, grad_bits, k, error=ef_state["err"])
            return gradcomp.decompress_tree(comp)

    step_fn = jax.jit(make_train_step(cfg, opt_cfg, grad_transform=grad_transform))
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    monitor = StragglerMonitor()

    # resume if a checkpoint exists
    start_step = 0
    if mgr and mgr.latest_step() is not None:
        (params, opt_state), manifest = mgr.restore((params, opt_state))
        start_step = manifest["step"]
        stream.skip_to(Cursor.from_dict(manifest["extra"]["cursor"]))
        print(f"[train] resumed from step {start_step}")

    losses = []
    step = start_step
    while step < steps:
        try:
            batch_np = stream.next_batch()
            batch_j = {k: jnp.asarray(v) for k, v in batch_np.items()}
            if cfg.family == "vlm":
                batch_j["vision"] = jnp.zeros(
                    (batch, cfg.n_vis_tokens, cfg.d_model), jnp.float32)
            if fail_at is not None and step == fail_at:
                fail_at = None
                raise RuntimeError("injected fault (test)")
            t0 = time.time()
            params, opt_state, metrics = step_fn(
                params, opt_state, batch_j, jax.random.fold_in(key, step))
            loss = float(metrics["loss"])
            dt = time.time() - t0
            if monitor.record(dt):
                print(f"[train] step {step}: straggler flagged ({dt:.3f}s)")
            losses.append(loss)
            step += 1
            if step % log_every == 0:
                print(f"[train] step {step}: loss={loss:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"skipped={float(metrics['skipped']):.0f} ({dt:.2f}s)")
            if mgr and step % ckpt_every == 0:
                mgr.save(step, (params, opt_state),
                         extra={"cursor": stream.cursor.to_dict(),
                                "precision": precision.to_dict()})
        except (RuntimeError, jax.errors.JaxRuntimeError) as e:
            print(f"[train] step {step} FAILED ({e}); restoring last checkpoint")
            if mgr is None or mgr.latest_step() is None:
                print("[train] no checkpoint — restarting from scratch")
                params = T.init_params(key, cfg)
                opt_state = adamw.init(params, opt_cfg)
                step = 0
                stream.skip_to(Cursor(0, 0))
                continue
            (params, opt_state), manifest = mgr.restore((params, opt_state))
            step = manifest["step"]
            stream.skip_to(Cursor.from_dict(manifest["extra"]["cursor"]))
    if mgr:
        mgr.save(steps, (params, opt_state),
                 extra={"cursor": stream.cursor.to_dict(),
                        "precision": precision.to_dict()}, blocking=True)
    return params, losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--grad-bits", type=int, default=0)
    ap.add_argument("--weight-bits", type=int, default=0)
    ap.add_argument("--moment-bits", type=int, default=0)
    ap.add_argument("--kernel-backend", default=None,
                    choices=registry.available(),
                    help="quantization kernel backend (default: "
                         "$ZIPML_KERNEL_BACKEND or per jax.default_backend())")
    args = ap.parse_args(argv)
    _, losses = train(args.arch, reduced=args.reduced, steps=args.steps,
                      batch=args.batch, seq=args.seq, lr=args.lr,
                      ckpt_dir=args.ckpt_dir, grad_bits=args.grad_bits,
                      weight_bits=args.weight_bits, moment_bits=args.moment_bits,
                      kernel_backend=args.kernel_backend)
    print(f"[train] done: first loss {losses[0]:.4f} → last {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
