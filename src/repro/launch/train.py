"""Training CLI — a thin shell over :class:`repro.train.Trainer`.

The supervisor loop, checkpoint/restart, straggler monitoring and the
stateful precision channels all live in :mod:`repro.train`; this module
parses flags, builds the Trainer, and keeps the legacy ``train(arch, ...)``
entry point as a compatibility wrapper (losses are bit-exact with driving
the Trainer directly — it *is* the Trainer).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --reduced \
      --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import warnings

from repro import configs
from repro.kernels import registry
from repro.data.pipeline import TokenStreamConfig
from repro.quant import PrecisionPlan
from repro.optim import adamw
from repro.train import StragglerMonitor, Trainer  # noqa: F401  (re-export)


def make_trainer(arch: str, *, reduced: bool = True, batch: int = 8,
                 seq: int = 64, steps: int = 50, lr: float = 1e-3,
                 moment_bits: int = 0, ckpt_dir: str | None = None,
                 ckpt_every: int = 20, log_every: int = 10,
                 precision: PrecisionPlan | None = None,
                 error_feedback: bool = True,
                 max_restarts: int = 8,
                 restart_backoff_s: float = 0.0) -> Trainer:
    """Build the standard Trainer for an (arch, shape) training run."""
    if precision is None:
        precision = PrecisionPlan()
    get = configs.get_reduced if reduced else configs.get_config
    cfg = get(arch, precision=precision)
    opt_cfg = adamw.AdamWConfig(lr=lr, warmup_steps=max(steps // 10, 1),
                                decay_steps=steps, moment_bits=moment_bits)
    stream_cfg = TokenStreamConfig(
        vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch)
    return Trainer(cfg, opt_cfg, stream_cfg=stream_cfg, ckpt_dir=ckpt_dir,
                   ckpt_every=ckpt_every, log_every=log_every,
                   error_feedback=error_feedback, max_restarts=max_restarts,
                   restart_backoff_s=restart_backoff_s)


def train(arch: str, *, kernel_backend: str | None = None, **kwargs):
    """Returns (final_params, losses). Compatibility wrapper over Trainer.

    ``kernel_backend`` pins the quantization kernel backend for this run only
    ('ref'/'pallas'); None keeps the registry default (env var / hardware).
    The previous registry selection is restored when the run finishes.

    The per-channel ``grad_bits=``/``weight_bits=`` kwargs are deprecated —
    pass a full four-channel ``precision=PrecisionPlan(...)`` instead
    (``moment_bits`` stays: it is optimizer config, not a plan channel).
    """
    with registry.using(kernel_backend) as backend:
        print(f"[train] kernel backend: {backend.name} "
              f"(available: {', '.join(registry.available())})")
        return _train(arch, **kwargs)


def _train(arch: str, *, reduced: bool = True, steps: int = 50, batch: int = 8,
           seq: int = 64, ckpt_dir: str | None = None, ckpt_every: int = 20,
           lr: float = 1e-3, grad_bits: int = 0, weight_bits: int = 0,
           moment_bits: int = 0, fail_at: int | None = None,
           log_every: int = 10, precision: PrecisionPlan | None = None):
    """Supervisor body; ``fail_at`` injects a fault (testing)."""
    if precision is None:
        if grad_bits or weight_bits:
            warnings.warn(
                "train(grad_bits=/weight_bits=) is deprecated; pass a full "
                "precision=PrecisionPlan(...) (see the README deprecation "
                "table)", DeprecationWarning, stacklevel=3)
        precision = PrecisionPlan(model_bits=weight_bits, grad_bits=grad_bits)
    trainer = make_trainer(
        arch, reduced=reduced, batch=batch, seq=seq, steps=steps, lr=lr,
        moment_bits=moment_bits, ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
        log_every=log_every, precision=precision)
    state, losses = trainer.run(steps, fail_at=fail_at)
    return state.params, losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--grad-bits", type=int, default=0)
    ap.add_argument("--weight-bits", type=int, default=0)
    ap.add_argument("--weight-storage", default="fake",
                    choices=("fake", "ship", "int"))
    ap.add_argument("--moment-bits", type=int, default=0)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject one fault at this step (supervisor test)")
    ap.add_argument("--fail-count", type=int, default=1,
                    help="how many times --fail-at fires (0 = every time — "
                         "a deterministic crash the restart loop hits the "
                         "--max-restarts cap on)")
    ap.add_argument("--max-restarts", type=int, default=8,
                    help="supervisor restarts without forward progress "
                         "before the underlying error propagates")
    ap.add_argument("--kernel-backend", default=None,
                    choices=registry.available(),
                    help="quantization kernel backend (default: "
                         "$ZIPML_KERNEL_BACKEND or per jax.default_backend())")
    args = ap.parse_args(argv)
    precision = PrecisionPlan(model_bits=args.weight_bits,
                              model_storage=args.weight_storage,
                              grad_bits=args.grad_bits)
    with registry.using(args.kernel_backend) as backend:
        print(f"[train] kernel backend: {backend.name} "
              f"(available: {', '.join(registry.available())})")
        trainer = make_trainer(
            args.arch, reduced=args.reduced, batch=args.batch, seq=args.seq,
            steps=args.steps, lr=args.lr, moment_bits=args.moment_bits,
            ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
            precision=precision, max_restarts=args.max_restarts)
        _, losses = trainer.run(
            args.steps, fail_at=args.fail_at,
            fail_count=None if args.fail_count == 0 else args.fail_count)
    print(f"[train] done: first loss {losses[0]:.4f} → last {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
