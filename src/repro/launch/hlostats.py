"""Shared HLO cost/collective extraction + TPU v5e hardware model.

Used by launch/dryrun.py (full-program compiles) and benchmarks/bench_roofline
(compositional per-piece accounting). Importing this module does NOT touch jax
device state.
"""
from __future__ import annotations

import re

# --- TPU v5e hardware model (roofline constants) ---------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~per direction)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 0.5, "u4": 0.5,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_COLL_RE = re.compile(
    r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[^\]]*\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")


def shape_bytes(shape_str: str) -> float:
    """'bf16[16,512,4096]{...}' → bytes."""
    m = re.match(r"([a-z0-9]+)\[([\d,]*)\]", shape_str)
    if not m:
        return 0.0
    dt, dims = m.groups()
    nb = _DTYPE_BYTES.get(dt)
    if nb is None:
        return 0.0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nb


def parse_collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-device bytes moved by each collective family.

    Accounting (ring algorithms, wire bytes per participating device):
      all-reduce: 2× payload (reduce-scatter + all-gather phases)
      all-gather: output bytes; reduce-scatter: input bytes
      all-to-all / collective-permute: 1× payload
    '-start' counted, '-done' skipped (same transfer).
    """
    out = {k: 0.0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line.strip())
        if not m:
            continue
        shape_part, op, variant = m.groups()
        if variant == "-done":
            continue
        if shape_part.startswith("("):
            nbytes = sum(shape_bytes(s)
                         for s in re.findall(r"[a-z0-9]+\[[\d,]*\]", shape_part))
        else:
            nbytes = shape_bytes(shape_part)
        if op == "all-reduce":
            nbytes *= 2.0
        out[op] += nbytes
    return out


def compiled_stats(compiled) -> dict:
    """flops / hbm bytes (cost_analysis) + collective bytes (HLO parse),
    all per device, for one compiled executable."""
    ca = compiled.cost_analysis() or {}
    coll = parse_collective_bytes(compiled.as_text())
    ma = compiled.memory_analysis()
    return {
        "flops": float(ca.get("flops", 0.0)),
        "hbm_bytes": float(ca.get("bytes accessed", 0.0)),
        "collective_bytes": float(sum(coll.values())),
        "collective_breakdown": coll,
        "temp_bytes": float(getattr(ma, "temp_size_in_bytes", 0)) if ma else 0.0,
    }


def add_stats(*stats: dict, weights=None) -> dict:
    """Weighted sum of compiled_stats dicts (piece composition)."""
    weights = weights or [1.0] * len(stats)
    out = {"flops": 0.0, "hbm_bytes": 0.0, "collective_bytes": 0.0,
           "collective_breakdown": {k: 0.0 for k in COLLECTIVES}, "temp_bytes": 0.0}
    for w, s in zip(weights, stats):
        out["flops"] += w * s["flops"]
        out["hbm_bytes"] += w * s["hbm_bytes"]
        out["collective_bytes"] += w * s["collective_bytes"]
        out["temp_bytes"] = max(out["temp_bytes"], s.get("temp_bytes", 0.0))
        for k in COLLECTIVES:
            out["collective_breakdown"][k] += w * s["collective_breakdown"].get(k, 0.0)
    return out


def roofline_terms(stats: dict) -> dict:
    return {
        "compute_term_s": stats["flops"] / PEAK_FLOPS,
        "memory_term_s": stats["hbm_bytes"] / HBM_BW,
        "collective_term_s": stats["collective_bytes"] / ICI_BW,
    }
