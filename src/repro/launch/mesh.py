"""Production mesh builders (functions, not module constants — importing this
module never touches jax device state)."""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # jax < 0.5 has no sharding.AxisType; Auto is its only behavior anyway
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 multi-pod (512 chips).

    Axes: 'pod' (DCI-connected pods), 'data' (DP/FSDP), 'model' (TP).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Degenerate mesh over however many devices exist (CPU tests/examples)."""
    n = len(jax.devices())
    return _make_mesh((n // model, model), ("data", "model"))
