"""Launchers: mesh construction, sharding rules, step builders, dry-run,
train/serve drivers. NOTE: dryrun sets XLA_FLAGS device_count=512 at import —
never import repro.launch.dryrun from tests or benches."""
