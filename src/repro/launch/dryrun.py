"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production mesh, prove it fits (memory_analysis), and extract the roofline
terms (cost_analysis + HLO collective parsing).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod --out dryrun.json

The XLA_FLAGS lines below MUST run before any other import touches jax — jax
locks the device count on first backend init. Smoke tests and benches never
import this module, so they see the single real CPU device.
"""
from __future__ import annotations

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"  # noqa: E402
# ^ before ANY jax-touching import — jax locks device count on first init.

import argparse
import dataclasses
import json
import re
import sys
import time

import jax
import numpy as np

from repro import configs
from repro.launch import sharding as sh
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import input_specs, make_prefill_step, make_serve_step
from repro.models import transformer as T
from repro.optim import adamw
from repro.quant import PrecisionPlan

# --- TPU v5e hardware model (roofline constants) ---------------------------
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (≈ per-direction)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 0.5, "u4": 0.5,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> float:
    """'bf16[16,512,4096]{...}' → bytes. Tuples handled by the caller."""
    m = re.match(r"([a-z0-9]+)\[([\d,]*)\]", shape_str)
    if not m:
        return 0.0
    dt, dims = m.groups()
    nb = _DTYPE_BYTES.get(dt)
    if nb is None:
        return 0.0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nb


def parse_collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-device bytes moved by each collective family.

    Accounting (ring algorithms, bytes on the wire per participating device):
      all-reduce: 2× payload (reduce-scatter + all-gather phases)
      all-gather: output bytes (each device receives the full gathered tensor)
      reduce-scatter: input bytes
      all-to-all / collective-permute: 1× payload
    '-start' variants counted, '-done' skipped (same transfer).
    """
    out = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[^\]]*\][^ ]*)\s+"
                     r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                     r"collective-permute)(-start|-done)?\(", line)
        if not m:
            continue
        shape_part, op, variant = m.groups()
        if variant == "-done":
            continue
        if shape_part.startswith("("):
            shapes = re.findall(r"[a-z0-9]+\[[\d,]*\]", shape_part)
            nbytes = sum(_shape_bytes(s) for s in shapes)
        else:
            nbytes = _shape_bytes(shape_part)
        if op == "all-reduce":
            nbytes *= 2.0
        out[op] += nbytes
    return out


@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    ok: bool
    error: str = ""
    compile_s: float = 0.0
    per_device_bytes: float = 0.0       # peak HBM (args+outs+temps, aliased)
    arg_bytes: float = 0.0
    temp_bytes: float = 0.0
    out_bytes: float = 0.0
    flops_per_device: float = 0.0
    hbm_bytes_accessed: float = 0.0     # per device
    collective_bytes: float = 0.0       # per device, weighted
    collective_breakdown: dict = dataclasses.field(default_factory=dict)
    compute_term_s: float = 0.0
    memory_term_s: float = 0.0
    collective_term_s: float = 0.0
    model_flops: float = 0.0            # 6·N·D (train) or 2·N·B (decode), global
    useful_ratio: float = 0.0
    n_devices: int = 0

    def dominant(self) -> str:
        terms = {"compute": self.compute_term_s, "memory": self.memory_term_s,
                 "collective": self.collective_term_s}
        return max(terms, key=terms.get)


def model_flops_for(cfg: T.ModelConfig, shape: configs.ShapeSpec) -> float:
    """Analytic useful FLOPs per step: 6·N_active·D for training, 2·N_active·B
    tokens for decode, 2·N_active·D for prefill (forward only)."""
    n_act = cfg.n_active_params()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        return 2.0 * n_act * tokens
    return 2.0 * n_act * shape.global_batch  # decode: one token per sequence


# per-arch gradient-accumulation for train_4k: microbatching halves activation
# + MoE dispatch memory where one pass would exceed the 16 GB v5e budget
TRAIN_ACCUM = {"mixtral-8x7b": 8, "granite-moe-3b-a800m": 2, "gemma-2b": 2,
               "llama-3.2-vision-11b": 2, "gemma-7b": 2, "qwen2.5-14b": 2,
               "zamba2-2.7b": 2}


def build_step(cfg: T.ModelConfig, shape: configs.ShapeSpec, mesh,
               opt_cfg: "adamw.AdamWConfig | None" = None):
    """Returns (jitted_fn, ordered_args list of spec-trees).

    Train cells compile the channel-composed TrainState step, so the
    memory_analysis prices the *whole* run state: quantized optimizer
    moments at their stored width (int8 when ``opt_cfg.moment_bits=8``, not
    the fp32 the old opt_state spec assumed) and the grad channel's fp32
    error-feedback residual when the plan sets ``grad_bits``.
    """
    from repro.train.step import make_step

    opt_cfg = opt_cfg if opt_cfg is not None else adamw.AdamWConfig()
    specs = input_specs(cfg, shape, opt_cfg=opt_cfg)
    p_sh = sh.make_param_shardings(mesh, specs["params"])
    if shape.kind == "train":
        fn = make_step(cfg, opt_cfg,
                       accum_steps=TRAIN_ACCUM.get(cfg.name, 1))
        st_sh = sh.make_state_shardings(mesh, specs["state"])
        b_sh = sh.train_batch_shardings(mesh, specs["batch"])
        jfn = jax.jit(fn, in_shardings=(st_sh, b_sh), donate_argnums=(0,))
        args = (specs["state"], specs["batch"])
    elif shape.kind == "prefill":
        fn = make_prefill_step(cfg)
        b_sh = sh.train_batch_shardings(mesh, specs["batch"])
        jfn = jax.jit(fn, in_shardings=(p_sh, b_sh))
        args = (specs["params"], specs["batch"])
    else:
        fn = make_serve_step(cfg)
        c_sh = sh.cache_shardings(mesh, specs["decode_state"], shape.global_batch)
        t_sh = sh.train_batch_shardings(mesh, {"t": specs["tokens"]})["t"]
        jfn = jax.jit(fn, in_shardings=(p_sh, c_sh, t_sh), donate_argnums=(1,))
        args = (specs["params"], specs["decode_state"], specs["tokens"])
    return jfn, args


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             precision: "PrecisionPlan | None" = None,
             opt_cfg: "adamw.AdamWConfig | None" = None,
             verbose: bool = True) -> CellResult:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    shape = configs.SHAPES[shape_name]
    dp_axes = ("pod", "data") if multi_pod else ("data",)
    # Full-program compile runs in scan mode (fast, buffer-reusing — its
    # memory_analysis is the true peak). Exact FLOP/byte/collective totals come
    # from the compositional per-piece pass in benchmarks/bench_roofline.py,
    # because XLA's cost analysis counts while-loop bodies once.
    overrides = {"dp_axes": dp_axes}
    if precision is not None:
        overrides["precision"] = precision
    cfg = configs.get_config(arch, **overrides)
    res = CellResult(arch=arch, shape=shape_name, mesh=mesh_name, ok=False,
                     n_devices=int(np.prod(mesh.devices.shape)))
    t0 = time.time()
    try:
        jfn, args = build_step(cfg, shape, mesh, opt_cfg=opt_cfg)
        # jax < 0.5 has no sharding.set_mesh; Mesh is its own context manager
        mesh_ctx = jax.sharding.set_mesh(mesh) \
            if hasattr(jax.sharding, "set_mesh") else mesh
        with mesh_ctx:
            lowered = jfn.lower(*args)
            compiled = lowered.compile()
        res.compile_s = time.time() - t0
        ma = compiled.memory_analysis()
        if ma is not None:
            res.arg_bytes = float(getattr(ma, "argument_size_in_bytes", 0))
            res.out_bytes = float(getattr(ma, "output_size_in_bytes", 0))
            res.temp_bytes = float(getattr(ma, "temp_size_in_bytes", 0))
            alias = float(getattr(ma, "alias_size_in_bytes", 0))
            res.per_device_bytes = res.arg_bytes + res.out_bytes + res.temp_bytes - alias
        ca = compiled.cost_analysis()
        if isinstance(ca, list):        # jax < 0.5 returns [dict]
            ca = ca[0] if ca else None
        if ca:
            res.flops_per_device = float(ca.get("flops", 0.0))
            res.hbm_bytes_accessed = float(ca.get("bytes accessed", 0.0))
        coll = parse_collective_bytes(compiled.as_text())
        res.collective_breakdown = coll
        res.collective_bytes = float(sum(coll.values()))
        res.compute_term_s = res.flops_per_device / PEAK_FLOPS
        res.memory_term_s = res.hbm_bytes_accessed / HBM_BW
        res.collective_term_s = res.collective_bytes / ICI_BW
        res.model_flops = model_flops_for(cfg, shape)
        total_flops = res.flops_per_device * res.n_devices
        res.useful_ratio = res.model_flops / total_flops if total_flops else 0.0
        res.ok = True
        if verbose:
            print(f"[{arch} × {shape_name} × {mesh_name}] compile {res.compile_s:.1f}s")
            print(f"  memory/device: args {res.arg_bytes/2**30:.2f} GiB, "
                  f"temps {res.temp_bytes/2**30:.2f} GiB, outs {res.out_bytes/2**30:.2f} GiB")
            print(f"  flops/device {res.flops_per_device:.3e}, hbm bytes {res.hbm_bytes_accessed:.3e}, "
                  f"coll bytes {res.collective_bytes:.3e}")
            print(f"  terms: compute {res.compute_term_s*1e3:.2f} ms | "
                  f"memory {res.memory_term_s*1e3:.2f} ms | "
                  f"collective {res.collective_term_s*1e3:.2f} ms → {res.dominant()}-bound")
            print(f"  MODEL_FLOPS/HLO_FLOPS = {res.useful_ratio:.3f}")
    except Exception as e:  # noqa: BLE001 — report, don't crash the matrix
        res.error = f"{type(e).__name__}: {e}"
        res.compile_s = time.time() - t0
        if verbose:
            print(f"[{arch} × {shape_name} × {mesh_name}] FAILED after "
                  f"{res.compile_s:.1f}s: {res.error[:500]}")
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--kv-bits", type=int, default=0)
    ap.add_argument("--weight-bits", type=int, default=0)
    ap.add_argument("--grad-bits", type=int, default=0)
    ap.add_argument("--moment-bits", type=int, default=0,
                    help="optimizer moment storage width (train cells price "
                         "int8 moments instead of fp32)")
    ap.add_argument("--weight-storage", default="int",
                    choices=("int", "ship", "fake"))
    args = ap.parse_args(argv)

    precision = None
    if args.kv_bits or args.weight_bits or args.grad_bits:
        precision = PrecisionPlan(model_bits=args.weight_bits,
                                  model_storage=args.weight_storage,
                                  kv_bits=args.kv_bits, grad_bits=args.grad_bits)
    opt_cfg = adamw.AdamWConfig(moment_bits=args.moment_bits) \
        if args.moment_bits else None

    if args.all:
        cells = configs.all_cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    for arch, shape in cells:
        for mp in meshes:
            results.append(dataclasses.asdict(run_cell(
                arch, shape, mp, precision=precision, opt_cfg=opt_cfg)))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2)
    n_ok = sum(r["ok"] for r in results)
    print(f"\n{n_ok}/{len(results)} cells compiled OK")
    return 0 if n_ok == len(results) else 1


if __name__ == "__main__":
    sys.exit(main())
