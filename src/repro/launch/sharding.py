"""Sharding rules: pytree-path → PartitionSpec for params, optimizer state,
batches and decode caches.

Layout (DESIGN.md §3.1):
* TP over 'model': matmul out-dims for in-projections (qkv, mlp up/gate, mamba
  in_proj, expert up/gate), matmul in-dims for out-projections (attn o, mlp
  down, mamba out_proj, expert down), vocab dim of the embedding table.
* FSDP over 'data': the *other* matmul dim of every large weight — GSPMD
  inserts the per-layer all-gathers (ZeRO-3). Multi-pod keeps params
  replicated over 'pod' (the cross-pod gradient all-reduce is the paper's
  gradient channel — the thing ZipML compresses).
* Optimizer state mirrors param specs (QTensor moment scales replicate).
* Small tensors (norms, biases, scalars, per-head vectors) replicate.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

# parents whose 'w' contracts over the TP dim (out-projections)
_OUT_PROJ = ("o", "down", "out_proj")
# parents whose 'w' is small enough to replicate
_REPLICATE = ("router",)

MIN_SHARD_ELEMS = 1 << 16   # replicate anything smaller (norms, biases, dt, …)


def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "name"):      # GetAttrKey (NamedTuple fields)
            parts.append(str(e.name))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
        else:
            parts.append(str(e))
    return "/".join(parts)


def param_spec(path, leaf) -> P:
    ps = _path_str(path)
    name = ps.split("/")[-1]
    parent = ps.split("/")[-2] if "/" in ps else ""
    ndim = leaf.ndim

    # QTensor children flatten as indexed leaves under the weight key:
    # (0=codes, 1=scale, 2=codes2, 3=levels). Code planes shard exactly like
    # the dense weight they replace; scales/level tables replicate.
    if parent == "w" and name in ("0", "1", "2", "3"):
        if name in ("0", "2"):
            parts = ps.split("/")
            name = "w"
            parent = parts[-3] if len(parts) >= 3 else ""
        else:
            return P(*([None] * ndim))

    def with_lead(base):
        return P(*([None] * (ndim - len(base)) + list(base)))

    if name == "table":                       # (V, d): vocab-parallel
        return P("model", None)
    if name == "w":
        if ndim < 2 or np.prod(leaf.shape) < MIN_SHARD_ELEMS or parent in _REPLICATE:
            return P(*([None] * ndim))
        if parent in _OUT_PROJ:
            return with_lead(["model", "data"])
        return with_lead(["data", "model"])
    if name == "conv_w":                      # (lead…, K, conv_dim)
        return P(*([None] * (ndim - 1) + ["model"]))
    # biases, norms, scales, a_log/dt_bias/d_skip, levels → replicate
    return P(*([None] * ndim))


def make_param_shardings(mesh, params_tree):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_spec(path, leaf)), params_tree)


def make_opt_shardings(mesh, opt_tree):
    """Optimizer state: m/v/master mirror the params; step & scales replicate.

    Quantized moments are QTensor leaves whose children flatten as indexed
    entries under the param key (0=codes, 1=scale, 2=codes2, 3=levels): the
    code plane shards exactly like the dense weight it shadows — the param
    rules apply for free — and scales/level tables replicate.
    """

    def spec(path, leaf):
        ps = _path_str(path)
        field = ps.split("/")[0]
        if field == "step" or ps.endswith("/scale") or leaf.ndim == 0:
            return NamedSharding(mesh, P())
        sub = list(path)[1:]  # drop the OptState field (m/v/master)
        last = _path_str(sub[-1:]) if sub else ""
        if last in ("codes", "0", "2"):
            sub = sub[:-1]  # moment code planes share the param's layout
        elif last in ("1", "3"):
            return NamedSharding(mesh, P(*([None] * leaf.ndim)))
        return NamedSharding(mesh, param_spec(sub, leaf))

    return jax.tree_util.tree_map_with_path(spec, opt_tree)


def make_state_shardings(mesh, state):
    """Shardings for a full :class:`repro.train.TrainState` (or its
    eval_shape template): params/opt per the rules above; channel state
    trees (the error-feedback residual mirrors the grad/param tree) shard
    like the params they shadow; scalars (step, rng, epoch) replicate."""
    from repro.train.state import TrainState

    rep = NamedSharding(mesh, P())

    def ch_spec(path, leaf):
        sub = list(path)[2:]   # drop (channel name, state key) e.g. grad/ef
        if not sub or leaf.ndim == 0:
            return rep
        return NamedSharding(mesh, param_spec(sub, leaf))

    return TrainState(
        params=make_param_shardings(mesh, state.params),
        opt=make_opt_shardings(mesh, state.opt),
        channels=jax.tree_util.tree_map_with_path(ch_spec, state.channels),
        step=rep, rng=rep,
        epoch=rep)


# ---------------------------------------------------------------------------
# Batch / cache shardings per input shape
# ---------------------------------------------------------------------------

def dp_axes_for(mesh) -> tuple:
    names = tuple(mesh.axis_names)
    return ("pod", "data") if "pod" in names else ("data",)


def _dp_size(mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return int(np.prod([sizes[a] for a in dp_axes_for(mesh)]))


def batch_spec(mesh, global_batch: int):
    """P over the DP axes when divisible, else replicate (e.g. batch=1)."""
    dp = dp_axes_for(mesh)
    if global_batch % _dp_size(mesh) == 0:
        return dp if len(dp) > 1 else dp[0]
    return None


def train_batch_shardings(mesh, batch_tree):
    """tokens/targets (B, S); vision (B, nv, d)."""
    def spec(path, leaf):
        b = batch_spec(mesh, leaf.shape[0])
        return NamedSharding(mesh, P(*([b] + [None] * (leaf.ndim - 1))))
    return jax.tree_util.tree_map_with_path(spec, batch_tree)


def cache_shardings(mesh, state_tree, global_batch: int):
    """DecodeState: KV caches (L, B, S, kv, D) → batch over DP, seq over
    'model' (sequence-parallel decode attention). batch=1 shards seq over
    ('data','model') so all 256 chips hold cache slices. SSM states shard
    heads over 'model'; conv caches shard channels over 'model'."""
    dp = batch_spec(mesh, global_batch)

    def spec(path, leaf):
        ps = _path_str(path)
        name = ps.split("/")[-1]
        nd = leaf.ndim
        if nd == 0:
            return NamedSharding(mesh, P())
        if name in ("k", "v", "k_scale", "v_scale") and nd >= 4:
            seq_axis = "model" if dp is not None else ("data", "model")
            # (lead…, B, S, kv, D/1)
            lead = [None] * (nd - 4)
            return NamedSharding(mesh, P(*lead, dp, seq_axis, None, None))
        if name == "ssm" and nd >= 4:       # (L, B, H, P, N)
            lead = [None] * (nd - 4)
            return NamedSharding(mesh, P(*lead, dp, "model", None, None))
        if name == "conv" and nd >= 3:      # (L, B, K-1, conv_dim)
            lead = [None] * (nd - 3)
            return NamedSharding(mesh, P(*lead, dp, None, "model"))
        if name == "length" and nd >= 1:    # (lead…, B)
            lead = [None] * (nd - 1)
            return NamedSharding(mesh, P(*lead, dp))
        return NamedSharding(mesh, P(*([None] * nd)))

    return jax.tree_util.tree_map_with_path(spec, state_tree)


def logits_sharding(mesh, global_batch: int):
    return NamedSharding(mesh, P(batch_spec(mesh, global_batch), None, "model"))
