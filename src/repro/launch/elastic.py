"""Elastic fleet controller: slice assignment, failure domains, restart policy.

The single-host pieces (checkpoint/restore-with-resharding, deterministic
data cursors, straggler monitor) live in ckpt/ and train.py; this module is
the 1000-node control-plane logic that composes them. It is deliberately
jax-free and unit-testable: given a fleet state (host heartbeats, failure
events), it decides the mesh to run, which checkpoint to restore, and each
surviving host's data-shard assignment.

Policy (DESIGN.md §3.2):
* The mesh is chosen as the largest (pods, 16, 16) grid coverable by healthy
  hosts, shrinking pod-by-pod (a v5e pod is the failure domain — losing any
  host in a pod takes its ICI torus out).
* On shrink/grow, training resumes from the last committed checkpoint; the
  data pipeline cursor is rewound to the checkpoint step, and host shard ids
  are recomputed from rank order — no data is skipped or repeated beyond the
  rollback window.
* Flapping protection: a pod must stay healthy `rejoin_patience` heartbeats
  before it is re-admitted.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterable


HOSTS_PER_POD = 64          # v5e: 64 hosts × 4 chips = 256 chips/pod


@dataclasses.dataclass
class HostState:
    host_id: int
    pod_id: int
    last_heartbeat: float
    healthy: bool = True


@dataclasses.dataclass
class FleetDecision:
    n_pods: int
    mesh_shape: tuple
    restore_step: int | None
    shard_assignment: dict          # host_id -> data shard index
    evicted_pods: list
    reason: str


class ElasticController:
    def __init__(self, n_pods: int, *, heartbeat_timeout: float = 30.0,
                 rejoin_patience: int = 3):
        self.n_pods = n_pods
        self.heartbeat_timeout = heartbeat_timeout
        self.rejoin_patience = rejoin_patience
        self.hosts: dict[int, HostState] = {}
        self._pod_health_streak: dict[int, int] = {p: rejoin_patience
                                                   for p in range(n_pods)}
        self._admitted: set[int] = set(range(n_pods))

    # ---------------------------------------------------------------- events
    def heartbeat(self, host_id: int, pod_id: int, now: float | None = None):
        now = time.time() if now is None else now
        self.hosts[host_id] = HostState(host_id, pod_id, now, True)

    def report_failure(self, host_id: int):
        if host_id in self.hosts:
            self.hosts[host_id].healthy = False

    # -------------------------------------------------------------- decision
    def _pod_healthy(self, pod_id: int, now: float) -> bool:
        members = [h for h in self.hosts.values() if h.pod_id == pod_id]
        if len(members) < HOSTS_PER_POD:
            return False
        return all(h.healthy and (now - h.last_heartbeat) < self.heartbeat_timeout
                   for h in members)

    def decide(self, latest_checkpoint_step: int | None,
               now: float | None = None) -> FleetDecision:
        """Compute the mesh + assignments for the next training epoch-segment."""
        now = time.time() if now is None else now
        evicted = []
        for pod in range(self.n_pods):
            if self._pod_healthy(pod, now):
                self._pod_health_streak[pod] += 1
            else:
                self._pod_health_streak[pod] = 0
                if pod in self._admitted:
                    evicted.append(pod)
            # admit only after a sustained healthy streak (flap protection)
            if self._pod_health_streak[pod] >= self.rejoin_patience:
                self._admitted.add(pod)
            else:
                self._admitted.discard(pod)
        n_live = max(len(self._admitted), 0)
        if n_live == 0:
            return FleetDecision(0, (), latest_checkpoint_step, {}, evicted,
                                 "no healthy pods — halt and page")
        mesh_shape = (n_live, 16, 16) if n_live > 1 else (16, 16)
        # rank-ordered shard assignment over surviving hosts
        live_hosts = sorted(
            h.host_id for h in self.hosts.values()
            if h.pod_id in self._admitted and h.healthy)
        assignment = {hid: i for i, hid in enumerate(live_hosts)}
        reason = ("steady state" if not evicted else
                  f"pods {evicted} evicted → restore step "
                  f"{latest_checkpoint_step} and reshard")
        return FleetDecision(
            n_pods=n_live, mesh_shape=mesh_shape,
            restore_step=latest_checkpoint_step if evicted else None,
            shard_assignment=assignment, evicted_pods=evicted, reason=reason)


def stream_sharding(decision: FleetDecision, host_id: int) -> tuple[int, int]:
    """(n_hosts, shard_id) for one host under a fleet decision — the data-
    pipeline reshard that accompanies a mesh shrink/grow. Shards are
    rank-ordered and contiguous, so feeding the pair into
    ``TokenStreamConfig(n_hosts=, host_id=)`` keeps the determinism contract:
    batch i of shard s is a pure function of (seed, i, s), independent of
    which physical hosts survived. ``repro.train.Trainer.apply_fleet_decision``
    composes this with checkpoint rollback + cursor rewind.

    Raises for a host the decision did not assign (evicted / stale): a
    defaulted shard would silently consume another host's batch sequence —
    duplicated gradients — instead of stopping the zombie."""
    if host_id not in decision.shard_assignment:
        raise RuntimeError(
            f"host {host_id} is not in the surviving fleet "
            f"({sorted(decision.shard_assignment)}) — {decision.reason}")
    return len(decision.shard_assignment), decision.shard_assignment[host_id]


def plan_rollback(checkpoint_steps: Iterable[int], failed_at_step: int,
                  max_rollback: int = 1000) -> int:
    """Pick the restore step: newest committed checkpoint ≤ failure point,
    refusing rollbacks larger than ``max_rollback`` (page instead — data
    budget guard)."""
    candidates = [s for s in checkpoint_steps if s <= failed_at_step]
    if not candidates:
        raise RuntimeError("no checkpoint precedes the failure — cold restart")
    step = max(candidates)
    if failed_at_step - step > max_rollback:
        raise RuntimeError(
            f"rollback {failed_at_step - step} steps exceeds budget "
            f"{max_rollback} — operator intervention required")
    return step
