"""Step builders: train_step / prefill_step / serve_step as pure jit-able
functions, plus ShapeDtypeStruct input_specs for the dry-run.

The ZipML channels hook in here:
* QAT fake-quant (C5) — weights quantized inside the loss when
  precision.model_bits > 0 and storage == 'fake'.
* int weight storage (C1/C5) — serve/prefill steps accept params whose matmul
  weights are int8 codes (layers.dense dequantizes on the fly).
* gradient compression (C3) — compressed cross-pod/DP all-reduce of gradients
  via precision/gradcomp.py when precision.grad_bits > 0.
* KV-cache quantization — decode caches store int8 when precision.kv_bits > 0.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.launch import sharding as shd
from repro.models import transformer as T
from repro.models.layers import shard_hint
from repro.optim import adamw
from repro.precision import qat


def make_train_step(cfg: T.ModelConfig, opt_cfg: adamw.AdamWConfig,
                    grad_transform=None, accum_steps: int = 1):
    """Returns train_step(params, opt_state, batch, key) → (params, opt, metrics).

    ``batch``: {"tokens": (B,S), "targets": (B,S)[, "vision": (B,nv,d)]}.
    ``grad_transform``: optional fn(grads, key) — the quantized-collective hook.
    ``accum_steps``: microbatch gradient accumulation — divides activation
    (and MoE dispatch-buffer) memory by A at the cost of re-gathering FSDP
    params per microbatch.
    """
    plan = cfg.precision

    def grads_of(params, tokens, targets, vision, kq):
        def loss(p):
            if plan.model_bits and plan.model_storage == "fake":
                p = qat.fake_quant_tree(p, plan.model_bits, kq)
            elif plan.model_bits and plan.model_storage == "ship" \
                    and not cfg.scan_layers:
                # per-layer int8 gather; on scanned stacked params the
                # replication pin would gather every layer at once
                p = qat.ship_quant_tree(p, plan.model_bits)
            return T.loss_fn(p, tokens, targets, cfg, vision_tokens=vision)
        return jax.value_and_grad(loss)(params)

    def train_step(params, opt_state, batch, key):
        kq, kg, km = jax.random.split(key, 3)
        if accum_steps == 1:
            loss_val, grads = grads_of(params, batch["tokens"], batch["targets"],
                                       batch.get("vision"), kq)
        else:
            def resh(t):
                return t.reshape(accum_steps, t.shape[0] // accum_steps,
                                 *t.shape[1:])
            mb = jax.tree.map(resh, dict(batch))

            def constrain(tree):
                # grad accumulators must live on the param sharding — without
                # the constraint GSPMD replicates the f32 accumulator tree
                return jax.tree_util.tree_map_with_path(
                    lambda path, g: shard_hint(g, shd.param_spec(path, g)), tree)

            def micro(carry, mb_i):
                g_acc, l_acc = carry
                lv, g = grads_of(params, mb_i["tokens"], mb_i["targets"],
                                 mb_i.get("vision"), kq)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (constrain(g_acc), l_acc + lv), None

            zeros = constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (g_sum, l_sum), _ = jax.lax.scan(micro, (zeros, jnp.float32(0.0)), mb)
            grads = jax.tree.map(lambda g: g / accum_steps, g_sum)
            loss_val = l_sum / accum_steps
        if grad_transform is not None:
            grads = grad_transform(grads, kg)
        mkey = km if opt_cfg.moment_bits else None
        params, opt_state, metrics = adamw.apply_updates(
            params, grads, opt_state, opt_cfg, key=mkey)
        metrics["loss"] = loss_val
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: T.ModelConfig):
    def prefill_step(params, batch):
        return T.prefill(params, batch["tokens"], cfg,
                         vision_tokens=batch.get("vision"))
    return prefill_step


def make_serve_step(cfg: T.ModelConfig):
    def serve_step(params, state, tokens):
        logits, new_state = T.decode_step(params, state, tokens, cfg)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return logits, next_tok, new_state
    return serve_step


# ---------------------------------------------------------------------------
# Dry-run input specs (ShapeDtypeStructs — no allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: T.ModelConfig, shape: "configs.ShapeSpec") -> dict[str, Any]:
    """Stand-ins for every model input of the (arch × shape) cell.

    train  → params, opt_state, batch{tokens,targets[,vision]}, key
    prefill→ params, batch{tokens[,vision]}
    decode → params, decode_state (cache of seq_len), tokens (B, 1)
    """
    b, s = shape.global_batch, shape.seq_len
    out: dict[str, Any] = {}
    params = T.param_specs(cfg)
    out["params"] = params
    if shape.kind == "train":
        batch = {"tokens": _sds((b, s), jnp.int32),
                 "targets": _sds((b, s), jnp.int32)}
        if cfg.family == "vlm":
            batch["vision"] = _sds((b, cfg.n_vis_tokens, cfg.d_model), jnp.float32)
        out["batch"] = batch
        out["opt_state"] = jax.eval_shape(
            lambda p: adamw.init(p, adamw.AdamWConfig()), params)
        out["key"] = _sds((2,), jnp.uint32)
    elif shape.kind == "prefill":
        batch = {"tokens": _sds((b, s), jnp.int32)}
        if cfg.family == "vlm":
            batch["vision"] = _sds((b, cfg.n_vis_tokens, cfg.d_model), jnp.float32)
        out["batch"] = batch
    else:  # decode
        out["decode_state"] = jax.eval_shape(
            lambda: T.init_decode_state(cfg, b, smax=s))
        out["tokens"] = _sds((b, 1), jnp.int32)
    return out
