"""Step builders: train_step / prefill_step / serve_step as pure jit-able
functions, plus ShapeDtypeStruct input_specs for the dry-run.

The train step itself now lives in :mod:`repro.train.step`, composed from
the four stateful PrecisionPlan channel objects over a
:class:`repro.train.TrainState`; ``make_train_step`` here is the legacy
``(params, opt_state, batch, key)`` surface kept for existing callers
(the ``grad_transform=`` hook is deprecated — a stateless ``fn(grads, key)``
cannot thread the error-feedback residual; use
``repro.train.GradChannel``).

The serving-side channels hook in here directly:
* int weight storage (C1/C5) — serve/prefill steps accept params whose matmul
  weights are int8 codes (layers.dense dequantizes on the fly).
* KV-cache quantization — decode caches store int8 when precision.kv_bits > 0.
"""
from __future__ import annotations

import warnings
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.optim import adamw
from repro.precision import gradcomp


def make_train_step(cfg: T.ModelConfig, opt_cfg: adamw.AdamWConfig,
                    grad_transform=None, accum_steps: int = 1):
    """Returns train_step(params, opt_state, batch, key) → (params, opt, metrics).

    Legacy surface over :func:`repro.train.step.make_grads_fn`; the
    channel-composed TrainState step is :func:`repro.train.make_step`.

    ``batch``: {"tokens": (B,S), "targets": (B,S)[, "vision": (B,nv,d)]}.
    ``grad_transform``: DEPRECATED stateless hook fn(grads, key) — it cannot
    carry error-feedback state across steps (jit traces it once and freezes
    whatever it captured). Use a :class:`repro.train.GradChannel`.
    ``accum_steps``: microbatch gradient accumulation — divides activation
    (and MoE dispatch-buffer) memory by A at the cost of re-gathering FSDP
    params per microbatch.
    """
    from repro.train.channels import ModelChannel
    from repro.train.step import make_grads_fn

    if grad_transform is not None:
        warnings.warn(
            "make_train_step(grad_transform=...) is deprecated: a stateless "
            "fn(grads, key) cannot thread error feedback through jit; use "
            "repro.train.GradChannel (see the README deprecation table)",
            DeprecationWarning, stacklevel=2)
    grads_of = make_grads_fn(cfg, ModelChannel(cfg.precision), accum_steps)

    def train_step(params, opt_state, batch, key):
        kq, kg, km = jax.random.split(key, 3)
        loss_val, grads = grads_of(params, batch, kq)
        if grad_transform is not None:
            grads = grad_transform(grads, kg)
        mkey = km if opt_cfg.moment_bits else None
        params, opt_state, metrics = adamw.apply_updates(
            params, grads, opt_state, opt_cfg, key=mkey)
        metrics["loss"] = loss_val
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: T.ModelConfig):
    def prefill_step(params, batch):
        return T.prefill(params, batch["tokens"], cfg,
                         vision_tokens=batch.get("vision"))
    return prefill_step


def make_serve_step(cfg: T.ModelConfig):
    def serve_step(params, state, tokens):
        logits, new_state = T.decode_step(params, state, tokens, cfg)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return logits, next_tok, new_state
    return serve_step


# ---------------------------------------------------------------------------
# Dry-run input specs (ShapeDtypeStructs — no allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: T.ModelConfig, shape: "configs.ShapeSpec",
                opt_cfg: adamw.AdamWConfig | None = None) -> dict[str, Any]:
    """Stand-ins for every model input of the (arch × shape) cell.

    train  → params, state (full TrainState: opt moments at their *stored*
             width, error-feedback residuals when grad_bits — so dry-run
             memory prices what actually resides), batch, key
    prefill→ params, batch{tokens[,vision]}
    decode → params, decode_state (cache of seq_len), tokens (B, 1)
    """
    b, s = shape.global_batch, shape.seq_len
    out: dict[str, Any] = {}
    params = T.param_specs(cfg)
    out["params"] = params
    if shape.kind == "train":
        from repro.train.channels import default_channels
        from repro.train.state import init_state

        opt_cfg = opt_cfg if opt_cfg is not None else adamw.AdamWConfig()
        batch = {"tokens": _sds((b, s), jnp.int32),
                 "targets": _sds((b, s), jnp.int32)}
        if cfg.family == "vlm":
            batch["vision"] = _sds((b, cfg.n_vis_tokens, cfg.d_model), jnp.float32)
        out["batch"] = batch
        out["opt_state"] = jax.eval_shape(
            lambda p: adamw.init(p, opt_cfg), params)
        out["key"] = _sds((2,), jnp.uint32)
        channels = default_channels(cfg.precision)

        def mk_state(p, o):
            ch = {name: c.init(p) for name, c in channels.items()}
            return init_state(p, o, ch, jnp.zeros((2,), jnp.uint32))
        out["state"] = jax.eval_shape(mk_state, params, out["opt_state"])
    elif shape.kind == "prefill":
        batch = {"tokens": _sds((b, s), jnp.int32)}
        if cfg.family == "vlm":
            batch["vision"] = _sds((b, cfg.n_vis_tokens, cfg.d_model), jnp.float32)
        out["batch"] = batch
    else:  # decode
        out["decode_state"] = jax.eval_shape(
            lambda: T.init_decode_state(cfg, b, smax=s))
        out["tokens"] = _sds((b, 1), jnp.int32)
    return out


def channel_state_bytes(cfg: T.ModelConfig,
                        opt_cfg: adamw.AdamWConfig | None = None) -> dict:
    """Logical bytes of the stateful-channel residents per train step: the
    error-feedback tree (fp32, grad channel) and the optimizer moments at
    their stored width — the dry-run line items PrecisionPlan changes move."""
    from repro.quant.qtensor import tree_nbytes

    opt_cfg = opt_cfg if opt_cfg is not None else adamw.AdamWConfig()
    params = T.param_specs(cfg)
    opt = jax.eval_shape(lambda p: adamw.init(p, opt_cfg), params)
    ef = 0
    if cfg.precision.grad_bits:
        ef = jax.eval_shape(gradcomp.init_error_feedback, params)
        ef = tree_nbytes(ef)
    return {"moments": tree_nbytes((opt.m, opt.v)),
            "master": tree_nbytes(opt.master),
            "error_feedback": ef}
