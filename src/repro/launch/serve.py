"""Serving driver — a thin CLI over the continuous-batching engine
(repro/serve/engine.py) with the ZipML serving channels: int8 weights at
rest, bf16/int8/packed-int4 paged KV cache.

Engine mode (default) serves a mixed-length synthetic trace:

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
      --requests 16 --max-new 24 --kv-bits 4 --page-size 8

Legacy single-shot mode (the pre-engine fixed-batch greedy loop, kept as a
compatibility wrapper around the ring-buffer cache):

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
      --legacy --batch 4 --prompt-len 32 --gen 16 --kv-bits 8
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.launch.steps import make_serve_step
from repro.models import transformer as T
from repro.precision.qat import quantize_param_tree
from repro.quant import PrecisionPlan


def _resolve_plan(plan, kv_bits, weight_bits, optimal_levels) -> PrecisionPlan:
    if plan is None:
        plan = PrecisionPlan(kv_bits=kv_bits, model_bits=weight_bits,
                             model_storage="int" if weight_bits else "fake",
                             optimal_levels=optimal_levels)
    if plan.model_bits and plan.model_storage != "int":
        # 'fake'/'ship' are train-time storages; at serve time model_bits>0
        # always means real int codes at rest — normalize so a plan built for
        # training can't silently serve bf16 weights labeled as quantized
        plan = dataclasses.replace(plan, model_storage="int")
    return plan


def _build(arch: str, *, reduced: bool, plan: PrecisionPlan, seed: int,
           weight_layout: str = "dense"):
    get = configs.get_reduced if reduced else configs.get_config
    cfg = get(arch, precision=plan)
    key = jax.random.PRNGKey(seed)
    params = T.init_params(key, cfg)
    if plan.model_bits:
        params = quantize_param_tree(
            params, bits=plan.model_bits,
            optimal=plan.optimal_levels and weight_layout == "dense",
            layout=weight_layout)
    return cfg, params, key


def serve(arch: str, *, reduced: bool = True, batch: int = 4,
          prompt_len: int = 32, gen: int = 16, kv_bits: int = 0,
          weight_bits: int = 0, optimal_levels: bool = False, seed: int = 0,
          plan: PrecisionPlan | None = None):
    """Legacy single-shot serve: greedy-decode ``gen`` tokens for one random
    fixed-length prompt batch against the ring-buffer cache.

    ``plan``: a full :class:`repro.quant.PrecisionPlan`; when given it
    overrides the individual ``kv_bits``/``weight_bits``/``optimal_levels``
    knobs. Returns (tokens (B, prompt+gen), steady-state tokens/s).

    The reported tokens/s measures **steady-state decode only**: a warmup
    step runs (and is discarded — state is functional) before the clock
    starts, so jit compilation of the decode step is never billed. The old
    implementation took t0 before the first prefill, which billed the entire
    XLA compile to throughput.
    """
    plan = _resolve_plan(plan, kv_bits, weight_bits, optimal_levels)
    cfg, params, key = _build(arch, reduced=reduced, plan=plan, seed=seed)
    prompts = jax.random.randint(jax.random.fold_in(key, 1),
                                 (batch, prompt_len), 0, cfg.vocab_size)
    vis = None
    if cfg.family == "vlm":
        vis = jnp.zeros((batch, cfg.n_vis_tokens, cfg.d_model), jnp.float32)

    smax = prompt_len + gen
    logits, state = T.prefill(params, prompts, cfg, vision_tokens=vis,
                              pad_to=smax)
    next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    step_fn = jax.jit(make_serve_step(cfg))
    # warmup: trigger compile + first-dispatch costs on a throwaway call
    # (state is immutable — discarding the result leaves the decode unchanged)
    _, warm_tok, _ = step_fn(params, state, next_tok)
    warm_tok.block_until_ready()

    out = [prompts, next_tok]
    t0 = time.perf_counter()
    for _ in range(gen - 1):
        _, nxt, state = step_fn(params, state, out[-1])
        out.append(nxt[:, None])
    tokens = jnp.concatenate(out, axis=1)
    tokens.block_until_ready()
    dt = time.perf_counter() - t0
    # gen=1 times zero decode steps — report NaN rather than batch/ε nonsense
    tps = batch * (gen - 1) / dt if gen > 1 else float("nan")
    return np.asarray(tokens), tps


def make_trace(n_requests: int, vocab_size: int, *, max_new: int = 16,
               min_prompt: int = 4, max_prompt: int = 32, seed: int = 0,
               temperature: float = 0.0, top_k: int = 0):
    """A mixed-length synthetic request trace (varied prompt/gen lengths)."""
    from repro.serve import Request

    rng = np.random.default_rng(seed)
    reqs = []
    for rid in range(n_requests):
        s = int(rng.integers(min_prompt, max_prompt + 1))
        g = int(rng.integers(max(1, max_new // 4), max_new + 1))
        reqs.append(Request(
            rid=rid, prompt=rng.integers(0, vocab_size, s),
            max_new_tokens=g, temperature=temperature, top_k=top_k, seed=seed))
    return reqs


def serve_engine(arch: str, *, reduced: bool = True, n_requests: int = 16,
                 max_new: int = 16, min_prompt: int = 4, max_prompt: int = 32,
                 kv_bits: int = 0, weight_bits: int = 0,
                 optimal_levels: bool = False, seed: int = 0,
                 plan: PrecisionPlan | None = None, max_slots: int = 4,
                 page_size: int = 8, temperature: float = 0.0,
                 top_k: int = 0, backend: str | None = None,
                 weight_layout: str = "dense", autoscale: bool = False,
                 slo_admit_ms: float | None = None):
    """Serve a mixed-length trace through the continuous-batching engine.

    ``weight_layout='bitplane'`` stores the weights bit-serially (one
    artifact, any precision); ``autoscale=True`` then attaches the
    :class:`repro.serve.PrecisionAutoscaler` so load drops/restores weight
    bits against the admission SLO (``slo_admit_ms``, default from
    ``$ZIPML_SLO_ADMIT_MS``). Returns (engine, results dict rid → Finished).
    Throughput/byte stats via ``engine.throughput()`` /
    ``engine.kv_pool_nbytes()`` / ``engine.stats``.
    """
    from repro.serve import AutoscalerConfig, PrecisionAutoscaler, ServeEngine

    plan = _resolve_plan(plan, kv_bits, weight_bits, optimal_levels)
    cfg, params, _ = _build(arch, reduced=reduced, plan=plan, seed=seed,
                            weight_layout=weight_layout)
    autoscaler = None
    if autoscale:
        if weight_layout != "bitplane" or not plan.model_bits:
            raise ValueError(
                "autoscale needs --weight-layout bitplane with weight_bits > 0")
        over = {} if slo_admit_ms is None else {"slo_admit_ms": slo_admit_ms}
        ladder = tuple(b for b in (8, 4, 2, 1) if b <= plan.model_bits)
        autoscaler = PrecisionAutoscaler(
            AutoscalerConfig.from_env(bits_ladder=ladder, **over))
    max_seq_len = max_prompt + max_new + page_size
    engine = ServeEngine(params, cfg, plan=plan, max_slots=max_slots,
                         page_size=page_size, max_seq_len=max_seq_len,
                         backend=backend, autoscaler=autoscaler)
    trace = make_trace(n_requests, cfg.vocab_size, max_new=max_new,
                       min_prompt=min_prompt, max_prompt=max_prompt,
                       seed=seed, temperature=temperature, top_k=top_k)
    results = engine.run(trace)
    return engine, results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--kv-bits", type=int, default=0, choices=(0, 4, 8))
    ap.add_argument("--weight-bits", type=int, default=0)
    ap.add_argument("--optimal-levels", action="store_true")
    ap.add_argument("--weight-layout", default="dense",
                    choices=("dense", "bitplane"),
                    help="bitplane = bit-serial any-precision weight storage")
    ap.add_argument("--autoscale", action="store_true",
                    help="adapt weight bits to load (needs bitplane layout)")
    ap.add_argument("--slo-admit-ms", type=float, default=None,
                    help="admission-latency SLO for --autoscale "
                         "(default $ZIPML_SLO_ADMIT_MS or 50)")
    ap.add_argument("--kernel-backend", default=None, choices=(None, "ref", "pallas"))
    # engine mode (default)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--min-prompt", type=int, default=4)
    ap.add_argument("--max-prompt", type=int, default=32)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    # legacy single-shot mode
    ap.add_argument("--legacy", action="store_true",
                    help="old fixed-batch greedy loop (ring-buffer cache)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    if args.legacy:
        tokens, tps = serve(args.arch, reduced=args.reduced, batch=args.batch,
                            prompt_len=args.prompt_len, gen=args.gen,
                            kv_bits=args.kv_bits, weight_bits=args.weight_bits,
                            optimal_levels=args.optimal_levels)
        print(f"[serve] generated {tokens.shape} tokens at {tps:.1f} tok/s "
              f"steady-state (kv_bits={args.kv_bits}, "
              f"weight_bits={args.weight_bits})")
        return

    engine, results = serve_engine(
        args.arch, reduced=args.reduced, n_requests=args.requests,
        max_new=args.max_new, min_prompt=args.min_prompt,
        max_prompt=args.max_prompt, kv_bits=args.kv_bits,
        weight_bits=args.weight_bits, optimal_levels=args.optimal_levels,
        max_slots=args.max_slots, page_size=args.page_size,
        temperature=args.temperature, top_k=args.top_k,
        backend=args.kernel_backend, weight_layout=args.weight_layout,
        autoscale=args.autoscale, slo_admit_ms=args.slo_admit_ms)
    st = engine.stats
    gen_total = sum(f.n_generated for f in results.values())
    print(f"[serve-engine] {len(results)} requests, {gen_total} tokens "
          f"generated in {st['decode_steps']} decode steps "
          f"(+{st['prefill_tokens']} prefill tokens)")
    print(f"[serve-engine] steady-state decode: {engine.throughput():.1f} "
          f"tok/s; preemptions={st['preemptions']}")
    print(f"[serve-engine] KV pool: {engine.kv_pool_nbytes():,} bytes "
          f"(kv_bits={args.kv_bits or 'bf16'}, "
          f"page_size={args.page_size}) via QTensor.nbytes")
    if engine.autoscaler is not None:
        asc = engine.autoscaler
        print(f"[serve-engine] autoscaler: bits={asc.bits} after "
              f"{asc.n_observations} observations, "
              f"{len(asc.decisions)} rung moves "
              f"(slo_admit_ms={asc.config.slo_admit_ms})")


if __name__ == "__main__":
    main()
