"""Serving driver — a thin CLI over the continuous-batching engine
(repro/serve/engine.py) with the ZipML serving channels: int8 weights at
rest, bf16/int8/packed-int4 paged KV cache, prefix sharing + chunked
prefill, self-speculative decoding, and a multi-replica data-parallel
front-end.

Engine mode (default) serves a mixed-length synthetic trace:

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
      --requests 16 --max-new 24 --kv-bits 4 --page-size 8 \
      --prefix-cache --chunk-pages 2

Self-speculative decoding drafts k tokens per slot through a low-bit
``slice_planes`` view of the served bitplane weights and verifies them in
one batched full-precision step (output token-identical to vanilla):

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
      --weight-bits 8 --weight-layout bitplane --spec-decode 3 --draft-bits 4

Multi-replica mode (``--replicas N``) runs N engines — one paged pool and
prefix cache each, data-parallel over the host's devices when several are
visible (same placement policy as launch/sharding.py's data axis) — behind
one shared submit queue (``--dispatch`` picks least-loaded, round-robin, or
prefix-aware routing):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
      --requests 64 --replicas 4 --prefix-cache

Legacy single-shot mode (the pre-engine fixed-batch greedy loop, kept as a
compatibility wrapper around the ring-buffer cache):

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
      --legacy --batch 4 --prompt-len 32 --gen 16 --kv-bits 8
"""
from __future__ import annotations

import argparse
import collections
import contextlib
import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.launch.steps import make_serve_step
from repro.models import transformer as T
from repro.precision.qat import quantize_param_tree
from repro.quant import PrecisionPlan
from repro.serve.faults import ReplicaDeviceLost


def _resolve_plan(plan, kv_bits, weight_bits, optimal_levels) -> PrecisionPlan:
    if plan is None:
        plan = PrecisionPlan(kv_bits=kv_bits, model_bits=weight_bits,
                             model_storage="int" if weight_bits else "fake",
                             optimal_levels=optimal_levels)
    if plan.model_bits and plan.model_storage != "int":
        # 'fake'/'ship' are train-time storages; at serve time model_bits>0
        # always means real int codes at rest — normalize so a plan built for
        # training can't silently serve bf16 weights labeled as quantized
        plan = dataclasses.replace(plan, model_storage="int")
    return plan


def _build(arch: str, *, reduced: bool, plan: PrecisionPlan, seed: int,
           weight_layout: str = "dense"):
    get = configs.get_reduced if reduced else configs.get_config
    cfg = get(arch, precision=plan)
    key = jax.random.PRNGKey(seed)
    params = T.init_params(key, cfg)
    if plan.model_bits:
        params = quantize_param_tree(
            params, bits=plan.model_bits,
            optimal=plan.optimal_levels and weight_layout == "dense",
            layout=weight_layout)
    return cfg, params, key


def serve(arch: str, *, reduced: bool = True, batch: int = 4,
          prompt_len: int = 32, gen: int = 16, kv_bits: int = 0,
          weight_bits: int = 0, optimal_levels: bool = False, seed: int = 0,
          plan: PrecisionPlan | None = None):
    """Legacy single-shot serve: greedy-decode ``gen`` tokens for one random
    fixed-length prompt batch against the ring-buffer cache.

    ``plan``: a full :class:`repro.quant.PrecisionPlan`; when given it
    overrides the individual ``kv_bits``/``weight_bits``/``optimal_levels``
    knobs. Returns (tokens (B, prompt+gen), steady-state tokens/s).

    The reported tokens/s measures **steady-state decode only**: a warmup
    step runs (and is discarded — state is functional) before the clock
    starts, so jit compilation of the decode step is never billed. The old
    implementation took t0 before the first prefill, which billed the entire
    XLA compile to throughput.
    """
    plan = _resolve_plan(plan, kv_bits, weight_bits, optimal_levels)
    cfg, params, key = _build(arch, reduced=reduced, plan=plan, seed=seed)
    prompts = jax.random.randint(jax.random.fold_in(key, 1),
                                 (batch, prompt_len), 0, cfg.vocab_size)
    vis = None
    if cfg.family == "vlm":
        vis = jnp.zeros((batch, cfg.n_vis_tokens, cfg.d_model), jnp.float32)

    smax = prompt_len + gen
    logits, state = T.prefill(params, prompts, cfg, vision_tokens=vis,
                              pad_to=smax)
    next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    step_fn = jax.jit(make_serve_step(cfg))
    # warmup: trigger compile + first-dispatch costs on a throwaway call
    # (state is immutable — discarding the result leaves the decode unchanged)
    _, warm_tok, _ = step_fn(params, state, next_tok)
    warm_tok.block_until_ready()

    out = [prompts, next_tok]
    t0 = time.perf_counter()
    for _ in range(gen - 1):
        _, nxt, state = step_fn(params, state, out[-1])
        out.append(nxt[:, None])
    tokens = jnp.concatenate(out, axis=1)
    tokens.block_until_ready()
    dt = time.perf_counter() - t0
    # gen=1 times zero decode steps — report NaN rather than batch/ε nonsense
    tps = batch * (gen - 1) / dt if gen > 1 else float("nan")
    return np.asarray(tokens), tps


def make_trace(n_requests: int, vocab_size: int, *, max_new: int = 16,
               min_prompt: int = 4, max_prompt: int = 32, seed: int = 0,
               temperature: float = 0.0, top_k: int = 0):
    """A mixed-length synthetic request trace (varied prompt/gen lengths)."""
    from repro.serve import Request

    rng = np.random.default_rng(seed)
    reqs = []
    for rid in range(n_requests):
        s = int(rng.integers(min_prompt, max_prompt + 1))
        g = int(rng.integers(max(1, max_new // 4), max_new + 1))
        reqs.append(Request(
            rid=rid, prompt=rng.integers(0, vocab_size, s),
            max_new_tokens=g, temperature=temperature, top_k=top_k, seed=seed))
    return reqs


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Knobs for the per-replica health state machine.

    ``step_deadline_s`` — a scheduler step slower than this counts as a
    failure (stalled device); measured on the injected clock.
    ``dead_after`` — consecutive step failures before the replica is
    declared dead (healthy → suspect on the first, dead on the Nth).
    ``restart_backoff_s`` / ``backoff_cap_s`` — capped exponential backoff
    between death and the restart attempt (``base × 2^restarts``).
    ``max_restarts`` — restart attempts before the replica is FAILED for
    good (its work migrates; it takes no more).
    """

    step_deadline_s: float = 30.0
    dead_after: int = 2
    restart_backoff_s: float = 0.5
    backoff_cap_s: float = 30.0
    max_restarts: int = 5

    def __post_init__(self):
        if self.step_deadline_s <= 0:
            raise ValueError(
                f"step_deadline_s must be > 0, got {self.step_deadline_s}")
        if self.dead_after < 1:
            raise ValueError(f"dead_after must be >= 1, got {self.dead_after}")
        if self.restart_backoff_s < 0 or self.backoff_cap_s < 0:
            raise ValueError("backoff times must be >= 0")
        if self.max_restarts < 0:
            raise ValueError(
                f"max_restarts must be >= 0, got {self.max_restarts}")


class ReplicaHealth:
    """One replica's health record: state machine position, failure
    counters, restart bookkeeping, and an audit trail of transitions
    ``(t, from, to, why)`` on the injected clock."""

    STATES = ("healthy", "suspect", "dead", "recovering", "failed")

    def __init__(self):
        self.state = "healthy"
        self.consecutive_failures = 0
        self.restarts = 0
        self.restart_at = 0.0
        self.last_error: str | None = None
        self.transitions: list[tuple] = []

    def to(self, state: str, now: float, why: str = "") -> None:
        if state not in self.STATES:
            raise ValueError(f"unknown health state {state!r}")
        if state != self.state:
            self.transitions.append(
                (round(float(now), 6), self.state, state, why))
            self.state = state

    def __repr__(self):
        return (f"ReplicaHealth({self.state!r}, "
                f"failures={self.consecutive_failures}, "
                f"restarts={self.restarts})")


class ReplicaSet:
    """N serving engines behind one shared submit queue (data parallelism at
    the request level — the multi-replica rung below tensor sharding).

    Each replica is a full :class:`~repro.serve.ServeEngine` — its own paged
    pool, prefix cache (sharing is per-replica; the dispatcher's job is to
    keep a prefix family's requests landing on the same replica via the
    shared queue's FIFO order + least-loaded choice), and jit caches.
    ``devices`` optionally pins replica i's arrays and dispatches to
    ``devices[i % len(devices)]`` (``jax.default_device``), which is exactly
    the data-parallel placement ``launch/sharding.py`` meshes give one
    process per device group.

    Dispatch policies (all with bounded backlog — a replica whose
    in-flight-plus-pending count reaches ``2 × max_slots`` takes no more
    work, so one slow replica can't hoard the tail of the trace):

    * ``least_loaded`` (default): fewest in-flight-plus-pending requests.
    * ``round_robin``: strict rotation — the affinity-blind baseline the
      prefix bench row compares against.
    * ``prefix``: **prefix-aware** — the head-of-queue request's prompt is
      matched against every replica's prefix-cache trie (a pure read, no
      refcount side effects) and routed to the replica holding the deepest
      page match; on a miss (or when every matching replica is backlogged)
      it falls back to least-loaded. Keeping a prefix family on the replica
      that owns its trie pages is what turns per-replica caches into
      fleet-wide warm hits.

    **Fault tolerance.** Each replica carries a :class:`ReplicaHealth`
    state machine (healthy → suspect → dead → recovering, or failed for
    good) driven by step deadlines and consecutive-failure counts on the
    injected ``clock``. A dead replica's in-flight and queued requests are
    harvested back into the shared queue (front, original order) and
    re-dispatched to survivors, where they **replay from prompt + committed
    tokens** through the engine's recompute-preemption machinery — bit-exact,
    so migration is output-invisible. Restarts rebuild the engine through
    the original ``factory`` under capped exponential backoff; after
    ``max_restarts`` failed attempts the replica is FAILED and only the
    survivors serve. Dispatch only targets HEALTHY replicas.
    """

    def __init__(self, factory, n_replicas: int, *, devices=None,
                 dispatch: str = "least_loaded", clock=None,
                 fault_injector=None, health: HealthConfig | None = None,
                 ship_dir: str | None = None):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        if dispatch not in ("least_loaded", "round_robin", "prefix"):
            raise ValueError(
                "dispatch must be 'least_loaded', 'round_robin' or "
                f"'prefix', got {dispatch!r}")
        self.devices = list(devices) if devices else None
        self.dispatch = dispatch
        self._factory = factory
        self._clock = clock if clock is not None else time.perf_counter
        self._faults = fault_injector
        self.health_cfg = health or HealthConfig()
        self.ship_dir = ship_dir
        self.engines = []
        for i in range(n_replicas):
            with self._device_ctx(i):
                self.engines.append(factory(i))
        if dispatch == "prefix" and any(e.prefix is None for e in self.engines):
            raise ValueError("dispatch='prefix' needs prefix_cache=True "
                             "engines (nothing to match against otherwise)")
        self._queue: collections.deque = collections.deque()
        self.dispatched = [0] * n_replicas
        self._rr = 0
        self._step_no = 0
        self.health = [ReplicaHealth() for _ in range(n_replicas)]
        self.stats = {"rejected": 0, "migrated": 0, "deaths": 0,
                      "restarts": 0, "step_failures": 0}

    def _device_ctx(self, i: int):
        if self.devices is None:
            return contextlib.nullcontext()
        return jax.default_device(self.devices[i % len(self.devices)])

    def submit(self, req) -> None:
        """Queue a request — or reject it up front (``ValueError`` +
        ``rejected`` stat) when **no** replica could ever admit its shape,
        so an unservable request fails fast instead of circulating
        forever."""
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        reasons = {e.admit_impossible(prompt.size, req.max_new_tokens)
                   for e in self.engines}
        if None not in reasons:
            self.stats["rejected"] += 1
            raise ValueError(
                f"request {req.rid} rejected: no replica can ever admit it "
                f"({'; '.join(sorted(reasons))})")
        self._queue.append({"req": req, "prompt": prompt,
                            "replay": np.zeros((0,), np.int32),
                            "t_submit": self._clock(), "retries": 0})

    @property
    def n_pending(self) -> int:
        return len(self._queue) + sum(e.n_pending for e in self.engines)

    def _dispatch(self) -> None:
        n = len(self.engines)
        while self._queue:
            loads = [e.n_active + e.n_prefilling + e.n_pending
                     for e in self.engines]
            ok = [self.health[j].state == "healthy"
                  and loads[j] < 2 * self.engines[j].max_slots
                  for j in range(n)]
            if not any(self.health[j].state == "healthy" for j in range(n)):
                return
            i = None
            entry = self._queue[0]
            if self.dispatch == "prefix":
                prompt = np.asarray(entry["prompt"], np.int32).reshape(-1)
                best = 0
                for j, e in enumerate(self.engines):
                    if not ok[j] or e.prefix is None:
                        continue
                    depth = len(e.prefix.match(prompt))
                    if depth > best:
                        best, i = depth, j
            elif self.dispatch == "round_robin":
                for off in range(n):
                    j = (self._rr + off) % n
                    if self.health[j].state != "healthy":
                        continue          # rotation skips dead replicas
                    if not ok[j]:
                        return            # healthy target backlogged → wait
                    i = j
                    self._rr = j + 1
                    break
                if i is None:
                    return
            if i is None:                      # miss → least-loaded
                cand = [j for j in range(n) if ok[j]]
                if not cand:
                    return
                i = min(cand, key=lambda j: loads[j])
            with self._device_ctx(i):
                self.engines[i].submit_entry(self._queue.popleft())
            self.dispatched[i] += 1

    def step(self) -> dict:
        """One restart/dispatch pass + one scheduler step on every busy
        live replica, with deadline + failure accounting per replica."""
        self._step_no += 1
        self._maybe_skip_idle_wait()
        self._maybe_restart(self._clock())
        self._dispatch()
        finished = {}
        hc = self.health_cfg
        for i, eng in enumerate(self.engines):
            h = self.health[i]
            if h.state in ("dead", "recovering", "failed") or not eng.busy:
                continue
            t0 = self._clock()
            try:
                if self._faults is not None:
                    for sp in self._faults.poll("replica_stall",
                                                step=self._step_no, replica=i):
                        self._advance_or_sleep(sp.stall_s)
                    for sp in self._faults.poll("replica_raise",
                                                step=self._step_no, replica=i):
                        raise ReplicaDeviceLost(
                            f"replica {i}: injected device loss at "
                            f"set step {self._step_no}")
                with self._device_ctx(i):
                    for f in eng.step():
                        finished[f.rid] = f
            except Exception as e:           # device loss shows up as raises
                self._record_failure(i, e)
                continue
            dt = self._clock() - t0
            if dt > hc.step_deadline_s:
                self._record_failure(i, TimeoutError(
                    f"replica {i}: step took {dt:.3f}s > deadline "
                    f"{hc.step_deadline_s}s"))
            else:
                h.consecutive_failures = 0
                if h.state == "suspect":
                    h.to("healthy", self._clock(), "step within deadline")
        return finished

    def _advance_or_sleep(self, dt: float) -> None:
        adv = getattr(self._clock, "advance", None)
        if adv is not None:
            adv(float(dt))                   # virtual stall: no wall time
        else:
            time.sleep(float(dt))

    def _record_failure(self, i: int, err: BaseException) -> None:
        h = self.health[i]
        now = self._clock()
        h.consecutive_failures += 1
        h.last_error = f"{type(err).__name__}: {err}"
        self.stats["step_failures"] += 1
        if h.consecutive_failures >= self.health_cfg.dead_after:
            self._kill(i, now)
        elif h.state == "healthy":
            h.to("suspect", now, h.last_error)

    def _kill(self, i: int, now: float) -> None:
        """Declare replica ``i`` dead: harvest its in-flight + queued
        requests back onto the front of the shared queue (original order)
        for bit-exact replay on survivors, and schedule the restart."""
        h = self.health[i]
        h.to("dead", now, h.last_error or "killed")
        self.stats["deaths"] += 1
        entries = self.engines[i].harvest()
        for e in reversed(entries):
            self._queue.appendleft(e)
        self.stats["migrated"] += len(entries)
        h.restart_at = now + min(
            self.health_cfg.backoff_cap_s,
            self.health_cfg.restart_backoff_s * (2.0 ** h.restarts))

    def _maybe_restart(self, now: float) -> None:
        for i, h in enumerate(self.health):
            if h.state != "dead" or now < h.restart_at:
                continue
            if h.restarts >= self.health_cfg.max_restarts:
                h.to("failed", now,
                     f"max_restarts={self.health_cfg.max_restarts} "
                     f"exhausted; last error: {h.last_error}")
                continue
            h.to("recovering", now, "backoff elapsed")
            h.restarts += 1
            self.stats["restarts"] += 1
            if self._faults is not None and self.ship_dir is not None:
                from repro.serve.faults import truncate_ship_artifact
                for sp in self._faults.poll("ship_truncate",
                                            step=self._step_no, replica=i):
                    truncate_ship_artifact(self.ship_dir)
            try:
                with self._device_ctx(i):
                    self.engines[i] = self._factory(i)
            except Exception as e:           # bad artifact, OOM, ... → retry
                h.last_error = f"{type(e).__name__}: {e}"
                h.to("dead", self._clock(),
                     f"restart failed: {h.last_error}")
                h.restart_at = self._clock() + min(
                    self.health_cfg.backoff_cap_s,
                    self.health_cfg.restart_backoff_s * (2.0 ** h.restarts))
            else:
                h.consecutive_failures = 0
                h.to("healthy", self._clock(), "engine rebuilt")

    def _maybe_skip_idle_wait(self) -> None:
        """On a virtual clock with every replica down, jump straight to the
        earliest restart time instead of spinning through empty steps."""
        adv = getattr(self._clock, "advance", None)
        if adv is None:
            return
        if any(h.state in ("healthy", "suspect") for h in self.health):
            return
        due = [h.restart_at for h in self.health if h.state == "dead"]
        if not due:
            return
        dt = min(due) - self._clock()
        if dt > 0:
            adv(dt)

    def run(self, requests=None, max_steps: int = 100_000) -> dict:
        for r in requests or ():
            self.submit(r)
        out: dict = {}
        for _ in range(max_steps):
            if not self._queue and not any(e.busy for e in self.engines):
                return out
            if all(h.state == "failed" for h in self.health):
                errs = "; ".join(
                    f"replica {i}: {h.last_error}"
                    for i, h in enumerate(self.health))
                raise RuntimeError(
                    f"all {len(self.health)} replicas failed permanently "
                    f"with work outstanding — {errs}")
            before = self._progress()
            out.update(self.step())
            # a dead/recovering replica makes no engine progress while its
            # backoff runs down — that is a wait, not a stall
            if (self._progress() == before
                    and not any(h.state in ("dead", "recovering")
                                for h in self.health)):
                raise RuntimeError("replica set stalled — no engine "
                                   "admitted, prefilled, decoded, or finished")
        raise RuntimeError(f"ReplicaSet.run exceeded {max_steps} steps")

    def _progress(self) -> tuple:
        return (len(self._queue), self.stats["step_failures"],
                tuple((h.state, h.consecutive_failures, h.restarts)
                      for h in self.health),
                tuple((e.n_pending, e.n_active, e.n_prefilling,
                       e.stats["decode_steps"], e.stats["prefill_tokens"])
                      for e in self.engines))

    def stats_sum(self, key: str):
        return sum(e.stats[key] for e in self.engines)

    def throughput(self) -> float:
        """Aggregate steady-state decode tokens/s across replicas."""
        tps = [e.throughput() for e in self.engines]
        good = [t for t in tps if t == t]          # drop NaN (idle replica)
        return sum(good) if good else float("nan")


def serve_engine(arch: str, *, reduced: bool = True, n_requests: int = 16,
                 max_new: int = 16, min_prompt: int = 4, max_prompt: int = 32,
                 kv_bits: int = 0, weight_bits: int = 0,
                 optimal_levels: bool = False, seed: int = 0,
                 plan: PrecisionPlan | None = None, max_slots: int = 4,
                 page_size: int = 8, temperature: float = 0.0,
                 top_k: int = 0, backend: str | None = None,
                 weight_layout: str = "dense", autoscale: bool = False,
                 slo_admit_ms: float | None = None,
                 prefix_cache: bool = False, chunk_pages: int | None = None,
                 replicas: int = 1, devices=None, spec_decode: int = 0,
                 draft_bits: int | None = None,
                 dispatch: str = "least_loaded", clock=None,
                 fault_injector=None, health: HealthConfig | None = None,
                 ship_dir: str | None = None, retry_budget: int = 32):
    """Serve a mixed-length trace through the continuous-batching engine.

    ``weight_layout='bitplane'`` stores the weights bit-serially (one
    artifact, any precision); ``autoscale=True`` then attaches the
    :class:`repro.serve.PrecisionAutoscaler` so load drops/restores weight
    bits against the admission SLO (``slo_admit_ms``, default from
    ``$ZIPML_SLO_ADMIT_MS``). ``prefix_cache``/``chunk_pages`` enable prefix
    sharing and chunked prefill; ``spec_decode=k, draft_bits=b`` turns on
    self-speculative decoding (k-token draft through the b-bit
    ``slice_planes`` view of the same bitplane artifact, one batched
    full-precision verify — token-identical output, needs
    ``weight_layout='bitplane'``); ``replicas > 1`` serves the trace through
    a :class:`ReplicaSet` (one engine per replica, shared queue; ``devices``
    pins replicas round-robin; ``dispatch`` picks the routing policy —
    ``'prefix'`` routes prompt families to the replica owning their trie
    pages). Returns (engine-or-replicaset, results dict rid → Finished).
    Throughput/byte stats via ``engine.throughput()`` /
    ``engine.kv_pool_nbytes()`` / ``engine.stats``.

    Fault tolerance: ``clock`` injects the time source (a
    :class:`repro.serve.VirtualClock` makes chaos runs deterministic and
    wall-time-free), ``fault_injector`` arms a
    :class:`repro.serve.FaultInjector` on every engine and the replica
    set, ``health`` tunes the replica state machine, and ``ship_dir``
    saves the bitplane weights as a ship artifact once and rebuilds every
    replica from it — including restarts after a replica death (needs
    ``weight_layout='bitplane'`` with ``weight_bits > 0``).
    """
    from repro.serve import AutoscalerConfig, PrecisionAutoscaler, ServeEngine

    plan = _resolve_plan(plan, kv_bits, weight_bits, optimal_levels)
    if spec_decode and (weight_layout != "bitplane" or not plan.model_bits):
        raise ValueError(
            "spec_decode needs --weight-layout bitplane with weight_bits > 0 "
            "(the draft is a slice_planes view of the served artifact)")
    cfg, params, _ = _build(arch, reduced=reduced, plan=plan, seed=seed,
                            weight_layout=weight_layout)
    if ship_dir is not None:
        if weight_layout != "bitplane" or not plan.model_bits:
            raise ValueError(
                "ship_dir needs --weight-layout bitplane with "
                "weight_bits > 0 (the restart path reloads the artifact)")
        from repro.ckpt import save_ship_weights
        save_ship_weights(ship_dir, params)

    def mk_autoscaler():
        if not autoscale:
            return None
        if weight_layout != "bitplane" or not plan.model_bits:
            raise ValueError(
                "autoscale needs --weight-layout bitplane with weight_bits > 0")
        over = {} if slo_admit_ms is None else {"slo_admit_ms": slo_admit_ms}
        ladder = tuple(b for b in (8, 4, 2, 1) if b <= plan.model_bits)
        return PrecisionAutoscaler(
            AutoscalerConfig.from_env(bits_ladder=ladder, **over))

    max_seq_len = max_prompt + max_new + page_size

    def factory(i):
        p = params
        if ship_dir is not None:
            from repro.ckpt import load_ship_weights
            p = load_ship_weights(ship_dir, bits=plan.model_bits or None)
        return ServeEngine(p, cfg, plan=plan, max_slots=max_slots,
                           page_size=page_size, max_seq_len=max_seq_len,
                           backend=backend, autoscaler=mk_autoscaler(),
                           prefix_cache=prefix_cache, chunk_pages=chunk_pages,
                           spec_decode=spec_decode, draft_bits=draft_bits,
                           clock=clock, fault_injector=fault_injector,
                           replica_id=i, retry_budget=retry_budget)

    trace = make_trace(n_requests, cfg.vocab_size, max_new=max_new,
                       min_prompt=min_prompt, max_prompt=max_prompt,
                       seed=seed, temperature=temperature, top_k=top_k)
    if replicas > 1:
        rs = ReplicaSet(factory, replicas, devices=devices,
                        dispatch=dispatch, clock=clock,
                        fault_injector=fault_injector, health=health,
                        ship_dir=ship_dir)
        return rs, rs.run(trace)
    engine = factory(0)
    results = engine.run(trace)
    return engine, results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--kv-bits", type=int, default=0, choices=(0, 4, 8))
    ap.add_argument("--weight-bits", type=int, default=0)
    ap.add_argument("--optimal-levels", action="store_true")
    ap.add_argument("--weight-layout", default="dense",
                    choices=("dense", "bitplane"),
                    help="bitplane = bit-serial any-precision weight storage")
    ap.add_argument("--autoscale", action="store_true",
                    help="adapt weight bits to load (needs bitplane layout)")
    ap.add_argument("--slo-admit-ms", type=float, default=None,
                    help="admission-latency SLO for --autoscale "
                         "(default $ZIPML_SLO_ADMIT_MS or 50)")
    ap.add_argument("--kernel-backend", default=None, choices=(None, "ref", "pallas"))
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share page-aligned prompt prefixes across requests")
    ap.add_argument("--chunk-pages", type=int, default=None,
                    help="chunked prefill: pages per prefill chunk "
                         "(implies interleaved prefill/decode)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replicas behind a shared submit queue")
    ap.add_argument("--dispatch", default="least_loaded",
                    choices=("least_loaded", "round_robin", "prefix"),
                    help="replica routing: prefix = route prompt families "
                         "to the replica owning their trie pages")
    ap.add_argument("--spec-decode", type=int, default=0, metavar="K",
                    help="speculative decoding: draft K tokens per slot "
                         "through the low-bit weight view, verify in one "
                         "full-precision step (needs bitplane layout)")
    ap.add_argument("--draft-bits", type=int, default=None,
                    help="weight bits of the speculative draft view "
                         "(e.g. 4 or 2; must be below the serving bits)")
    # engine mode (default)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--min-prompt", type=int, default=4)
    ap.add_argument("--max-prompt", type=int, default=32)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    # legacy single-shot mode
    ap.add_argument("--legacy", action="store_true",
                    help="old fixed-batch greedy loop (ring-buffer cache)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    if args.legacy:
        tokens, tps = serve(args.arch, reduced=args.reduced, batch=args.batch,
                            prompt_len=args.prompt_len, gen=args.gen,
                            kv_bits=args.kv_bits, weight_bits=args.weight_bits,
                            optimal_levels=args.optimal_levels)
        print(f"[serve] generated {tokens.shape} tokens at {tps:.1f} tok/s "
              f"steady-state (kv_bits={args.kv_bits}, "
              f"weight_bits={args.weight_bits})")
        return

    engine, results = serve_engine(
        args.arch, reduced=args.reduced, n_requests=args.requests,
        max_new=args.max_new, min_prompt=args.min_prompt,
        max_prompt=args.max_prompt, kv_bits=args.kv_bits,
        weight_bits=args.weight_bits, optimal_levels=args.optimal_levels,
        max_slots=args.max_slots, page_size=args.page_size,
        temperature=args.temperature, top_k=args.top_k,
        backend=args.kernel_backend, weight_layout=args.weight_layout,
        autoscale=args.autoscale, slo_admit_ms=args.slo_admit_ms,
        prefix_cache=args.prefix_cache, chunk_pages=args.chunk_pages,
        replicas=args.replicas, dispatch=args.dispatch,
        spec_decode=args.spec_decode, draft_bits=args.draft_bits)
    gen_total = sum(f.n_generated for f in results.values())
    if isinstance(engine, ReplicaSet):
        rs = engine
        print(f"[serve-engine] {len(results)} requests across "
              f"{len(rs.engines)} replicas, {gen_total} tokens generated "
              f"(dispatch={rs.dispatched})")
        print(f"[serve-engine] aggregate steady-state decode: "
              f"{rs.throughput():.1f} tok/s; "
              f"preemptions={rs.stats_sum('preemptions')}")
        if args.spec_decode:
            drafted = rs.stats_sum("spec_draft_tokens")
            accepted = rs.stats_sum("spec_accepted_tokens")
            rate = accepted / drafted if drafted else float("nan")
            print(f"[serve-engine] speculative decode: "
                  f"{rs.stats_sum('spec_steps')} windows, "
                  f"{accepted}/{drafted} draft tokens accepted "
                  f"({rate:.2f}, k={args.spec_decode}, "
                  f"draft_bits={args.draft_bits})")
        for i, eng in enumerate(rs.engines):
            st = eng.stats
            line = (f"[serve-engine]   replica {i}: "
                    f"{st['decode_steps']} decode steps, "
                    f"{st['prefill_tokens']} prefill tokens "
                    f"[{rs.health[i].state}]")
            if args.prefix_cache:
                line += (f", prefix hits={st['prefix_hits']} "
                         f"({st['prefix_hit_tokens']} tokens skipped)")
            print(line)
        if any(rs.stats.values()):
            print(f"[serve-engine] fault tolerance: "
                  f"{rs.stats['deaths']} deaths, "
                  f"{rs.stats['migrated']} migrations, "
                  f"{rs.stats['restarts']} restarts, "
                  f"{rs.stats['step_failures']} step failures, "
                  f"{rs.stats['rejected']} rejected")
        return
    st = engine.stats
    print(f"[serve-engine] {len(results)} requests, {gen_total} tokens "
          f"generated in {st['decode_steps']} decode steps "
          f"(+{st['prefill_tokens']} prefill tokens)")
    print(f"[serve-engine] steady-state decode: {engine.throughput():.1f} "
          f"tok/s; preemptions={st['preemptions']}")
    if args.spec_decode:
        print(f"[serve-engine] speculative decode: {st['spec_steps']} windows, "
              f"{st['spec_accepted_tokens']}/{st['spec_draft_tokens']} draft "
              f"tokens accepted ({engine.acceptance_rate():.2f}, "
              f"k={args.spec_decode}, draft_bits={args.draft_bits})")
    if args.prefix_cache:
        print(f"[serve-engine] prefix cache: {st['prefix_hits']} hits / "
              f"{st['prefix_misses']} misses, "
              f"{st['prefix_hit_tokens']} prefill tokens skipped "
              f"({engine.prefix.n_pages} pages cached)")
    if args.chunk_pages or args.prefix_cache:
        print(f"[serve-engine] chunked prefill: {st['prefill_chunks']} chunks, "
              f"max {st['max_prefill_tokens_per_step']} prefill tokens/step")
    print(f"[serve-engine] KV pool: {engine.kv_pool_nbytes():,} bytes "
          f"(kv_bits={args.kv_bits or 'bf16'}, "
          f"page_size={args.page_size}) via QTensor.nbytes")
    if engine.autoscaler is not None:
        asc = engine.autoscaler
        print(f"[serve-engine] autoscaler: bits={asc.bits} after "
              f"{asc.n_observations} observations, "
              f"{len(asc.decisions)} rung moves "
              f"(slo_admit_ms={asc.config.slo_admit_ms})")


if __name__ == "__main__":
    main()
