"""Serving driver: batched prefill + decode with the ZipML serving channels
(int8 weights at rest, int8/int4 KV cache).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
      --batch 4 --prompt-len 32 --gen 16 --kv-bits 8 --weight-bits 8
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.launch.steps import make_serve_step
from repro.models import transformer as T
from repro.precision.qat import quantize_param_tree
from repro.quant import PrecisionPlan


def serve(arch: str, *, reduced: bool = True, batch: int = 4,
          prompt_len: int = 32, gen: int = 16, kv_bits: int = 0,
          weight_bits: int = 0, optimal_levels: bool = False, seed: int = 0,
          plan: PrecisionPlan | None = None):
    """Greedy-decode ``gen`` tokens for a random prompt batch.

    ``plan``: a full :class:`repro.quant.PrecisionPlan`; when given it
    overrides the individual ``kv_bits``/``weight_bits``/``optimal_levels``
    knobs (the one-plan workflow). Returns (tokens (B, prompt+gen), tokens/s)."""
    if plan is None:
        plan = PrecisionPlan(kv_bits=kv_bits, model_bits=weight_bits,
                             model_storage="int" if weight_bits else "fake",
                             optimal_levels=optimal_levels)
    if plan.model_bits and plan.model_storage != "int":
        # 'fake'/'ship' are train-time storages; at serve time model_bits>0
        # always means real int codes at rest — normalize so a plan built for
        # training can't silently serve bf16 weights labeled as quantized
        plan = dataclasses.replace(plan, model_storage="int")
    get = configs.get_reduced if reduced else configs.get_config
    cfg = get(arch, precision=plan)
    key = jax.random.PRNGKey(seed)
    params = T.init_params(key, cfg)
    if plan.model_bits:
        params = quantize_param_tree(params, bits=plan.model_bits,
                                     optimal=plan.optimal_levels)
    prompts = jax.random.randint(jax.random.fold_in(key, 1),
                                 (batch, prompt_len), 0, cfg.vocab_size)
    vis = None
    if cfg.family == "vlm":
        vis = jnp.zeros((batch, cfg.n_vis_tokens, cfg.d_model), jnp.float32)

    smax = prompt_len + gen
    t0 = time.time()
    logits, state = T.prefill(params, prompts, cfg, vision_tokens=vis,
                              pad_to=smax)
    next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    step_fn = jax.jit(make_serve_step(cfg))
    out = [prompts, next_tok]
    for _ in range(gen - 1):
        _, nxt, state = step_fn(params, state, out[-1])
        out.append(nxt[:, None])
    tokens = jnp.concatenate(out, axis=1)
    tokens.block_until_ready()
    dt = time.time() - t0
    tps = batch * gen / dt
    return np.asarray(tokens), tps


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--kv-bits", type=int, default=0)
    ap.add_argument("--weight-bits", type=int, default=0)
    ap.add_argument("--optimal-levels", action="store_true")
    args = ap.parse_args(argv)
    tokens, tps = serve(args.arch, reduced=args.reduced, batch=args.batch,
                        prompt_len=args.prompt_len, gen=args.gen,
                        kv_bits=args.kv_bits, weight_bits=args.weight_bits,
                        optimal_levels=args.optimal_levels)
    print(f"[serve] generated {tokens.shape} tokens at {tps:.1f} tok/s "
          f"(kv_bits={args.kv_bits}, weight_bits={args.weight_bits})")


if __name__ == "__main__":
    main()
