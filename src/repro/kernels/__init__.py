"""Pallas TPU kernels for the ZipML hot spots + the SSD intra-chunk block.

stoch_quant — C1 stochastic rounding quantizer (int8 codes + row scales)
qmm         — fused dequantize(int8 W)·matmul with fp32 MXU accumulation
ssd         — Mamba2 SSD intra-chunk dual form
ops         — jit'd padded wrappers; ref — pure-jnp oracles
"""
from . import ops, ref  # noqa: F401
