"""Pallas TPU kernels for the ZipML hot spots + the SSD intra-chunk block.

stoch_quant — C1 stochastic rounding quantizer (int8 codes + row scales) and
              the fused §2.2 double-sampling quantizer ds_quant (one HBM read
              → both Q₁/Q₂ code planes: shared base level + two up/down bits)
qmm         — fused dequantize(int8 W)·matmul with fp32 MXU accumulation, and
              qmv: the int8 code·vector product the DS gradient is built from
ssd         — Mamba2 SSD intra-chunk dual form
ops         — jit'd padded wrappers; ref — pure-jnp oracles
registry    — the 'ref'/'pallas' kernel-backend switch (ZIPML_KERNEL_BACKEND);
              also the dispatch point of the repro.quant QTensor entry points
              (encode/decode/ds_pair/dot)
"""
from . import ops, ref, registry  # noqa: F401
