"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def stoch_quant_ref(x, rand, scale, *, s: int):
    """Bit-exact reference for kernels/stoch_quant.py (same uint32→[0,1) map)."""
    x32 = x.astype(jnp.float32)
    uf = (rand >> 8).astype(jnp.float32) * (1.0 / (1 << 24))
    mag = jnp.abs(x32) / jnp.maximum(scale.astype(jnp.float32), 1e-30)
    t = jnp.clip(mag, 0.0, 1.0) * s
    lo = jnp.clip(jnp.floor(t), 0, s - 1)
    codes = lo + (uf < (t - lo)).astype(jnp.float32)
    return (codes * jnp.sign(x32)).astype(jnp.int8)


def ds_quant_ref(x, rand, scale, *, s: int):
    """Bit-exact reference for the fused kernels/stoch_quant.ds_quant: shared
    base level, two up/down bits from the high/low 16 bits of one uint32."""
    x32 = x.astype(jnp.float32)
    u1 = (rand >> 16).astype(jnp.float32) * (1.0 / (1 << 16))
    u2 = (rand & 0xFFFF).astype(jnp.float32) * (1.0 / (1 << 16))
    mag = jnp.abs(x32) / jnp.maximum(scale.astype(jnp.float32), 1e-30)
    t = jnp.clip(mag, 0.0, 1.0) * s
    base = jnp.clip(jnp.floor(t), 0, s - 1)
    frac = t - base
    sign = jnp.sign(x32)
    c1 = ((base + (u1 < frac).astype(jnp.float32)) * sign).astype(jnp.int8)
    c2 = ((base + (u2 < frac).astype(jnp.float32)) * sign).astype(jnp.int8)
    return c1, c2


def qmv_ref(codes, v):
    return jnp.dot(codes.astype(jnp.float32), v.astype(jnp.float32))


def row_absmax_ref(x):
    return jnp.max(jnp.abs(x.astype(jnp.float32)), axis=1, keepdims=True)


def qmm_ref(x, codes, scale):
    w = codes.astype(jnp.float32) * scale.astype(jnp.float32)
    return jnp.dot(x.astype(jnp.float32), w)


def dequant_pages_ref(pages, scale):
    """Dequantize KV pages to bf16 rows — exactly ``KVCache.materialize``'s
    per-row math, so bf16/int8/int4 paged serving stays bit-compatible with
    the legacy ring buffer.

    pages: (…, page, Hkv, D) bf16 | int8 codes | uint8 packed int4 (…, D/2);
    scale: (…, page, Hkv, 1) f32 or None (bf16 passthrough).
    """
    if scale is None:
        return pages
    if pages.dtype == jnp.uint8:
        from repro.quant import unpack_int4

        codes = unpack_int4(pages)
    else:
        codes = pages.astype(jnp.float32)
    return (codes * scale).astype(jnp.bfloat16)


def gather_pages_ref(pages, block_table):
    """(P, page, Hkv, Dk) pool + (B, MAXP) table → (B, MAXP·page, Hkv, Dk)
    contiguous per-sequence KV rows (rows past seq_len are garbage — the
    attention mask is what makes them unread)."""
    g = pages[block_table]                       # (B, MAXP, page, Hkv, Dk)
    b, mp, page = g.shape[:3]
    return g.reshape(b, mp * page, *g.shape[3:])


def paged_attention_ref(q, k_pages, v_pages, k_scale, v_scale, block_table,
                        seq_lens, *, softmax_scale):
    """Oracle for kernels/paged_attn.py: gather pages through the block table,
    dequantize with the ring-buffer math, run models.attention.decode_attention
    (the legacy masked-softmax decode) — bit-exact with the ring path whenever
    the gathered rows equal the ring rows.

    q: (B, H, D); k/v_pages: (P, page, Hkv, D[/2]); block_table: (B, MAXP)
    int32; seq_lens: (B,) int32. Returns (B, H, D) in q.dtype.
    """
    from repro.models import attention as attn

    k = dequant_pages_ref(gather_pages_ref(k_pages, block_table),
                          gather_pages_ref(k_scale, block_table)
                          if k_scale is not None else None)
    v = dequant_pages_ref(gather_pages_ref(v_pages, block_table),
                          gather_pages_ref(v_scale, block_table)
                          if v_scale is not None else None)
    b, h, d = q.shape
    spec = attn.AttnSpec(n_heads=h, n_kv_heads=k.shape[2], head_dim=d,
                         softmax_scale=softmax_scale)
    out = attn.decode_attention(q[:, None], k, v, spec, kv_len=seq_lens)
    return out[:, 0]


def ssd_chunk_scan_ref(xh, dt, logdec, bmat, cmat):
    """Reference chunked SSD (mirrors models/ssm.ssd_chunked math).

    xh: (B, NC, L, H, P); dt/logdec: (B, NC, L, H); b/c: (B, NC, L, N).
    """
    b, nc, L, h, p = xh.shape
    n = bmat.shape[-1]

    def per_batch(x_b, dt_b, ld_b, bm_b, cm_b):
        def chunk(state, inp):
            xc, dtc, ldc, bc, cc = inp
            cum = jnp.cumsum(ldc, axis=0)
            xw = xc.astype(jnp.float32) * dtc[:, :, None]
            diff = cum[:, None, :] - cum[None, :, :]
            mask = jnp.tril(jnp.ones((L, L), bool))
            dec = jnp.exp(jnp.where(mask[:, :, None], diff, -jnp.inf))
            scores = jnp.dot(cc, bc.T)
            att = scores[:, :, None] * dec
            y_intra = jnp.einsum("lmh,mhp->lhp", att, xw)
            y_inter = jnp.einsum("ln,hpn->lhp", cc, state) * jnp.exp(cum)[:, :, None]
            tail = jnp.exp(cum[-1:, :] - cum)
            bx = jnp.einsum("lhp,ln->hpn", xw * tail[:, :, None], bc)
            state = state * jnp.exp(cum[-1])[:, None, None] + bx
            return state, (y_intra + y_inter).astype(xh.dtype)

        init = jnp.zeros((h, p, n), jnp.float32)
        state, ys = jax.lax.scan(
            chunk, init,
            (x_b, dt_b.astype(jnp.float32), ld_b.astype(jnp.float32),
             bm_b.astype(jnp.float32), cm_b.astype(jnp.float32)))
        return ys, state

    ys, states = jax.vmap(per_batch)(xh, dt, logdec, bmat, cmat)
    return ys, states
