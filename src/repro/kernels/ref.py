"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def stoch_quant_ref(x, rand, scale, *, s: int):
    """Bit-exact reference for kernels/stoch_quant.py (same uint32→[0,1) map)."""
    x32 = x.astype(jnp.float32)
    uf = (rand >> 8).astype(jnp.float32) * (1.0 / (1 << 24))
    mag = jnp.abs(x32) / jnp.maximum(scale.astype(jnp.float32), 1e-30)
    t = jnp.clip(mag, 0.0, 1.0) * s
    lo = jnp.clip(jnp.floor(t), 0, s - 1)
    codes = lo + (uf < (t - lo)).astype(jnp.float32)
    return (codes * jnp.sign(x32)).astype(jnp.int8)


def ds_quant_ref(x, rand, scale, *, s: int):
    """Bit-exact reference for the fused kernels/stoch_quant.ds_quant: shared
    base level, two up/down bits from the high/low 16 bits of one uint32."""
    x32 = x.astype(jnp.float32)
    u1 = (rand >> 16).astype(jnp.float32) * (1.0 / (1 << 16))
    u2 = (rand & 0xFFFF).astype(jnp.float32) * (1.0 / (1 << 16))
    mag = jnp.abs(x32) / jnp.maximum(scale.astype(jnp.float32), 1e-30)
    t = jnp.clip(mag, 0.0, 1.0) * s
    base = jnp.clip(jnp.floor(t), 0, s - 1)
    frac = t - base
    sign = jnp.sign(x32)
    c1 = ((base + (u1 < frac).astype(jnp.float32)) * sign).astype(jnp.int8)
    c2 = ((base + (u2 < frac).astype(jnp.float32)) * sign).astype(jnp.int8)
    return c1, c2


def quant_adamw_ref(master, g, m_codes, m_scale, v_codes, v_scale, rand, *,
                    qmax: int, b1: float, b2: float, eps: float, wd: float,
                    lr, b1c, b2c, clip, finite, uclip: float = 0.0):
    """Bit-exact reference for kernels/quant_adamw.py (same 16-bit high/low
    uniform map as the fused kernel). master/g (R, C) f32; codes int8;
    scales (C,)/(1, C) f32; rand (R, C) uint32. Returns
    (new_master, m_codes, m_scale_new, v_codes, v_scale_new) with (C,) scales.
    """
    m_scale = jnp.asarray(m_scale, jnp.float32).reshape(1, -1)
    v_scale = jnp.asarray(v_scale, jnp.float32).reshape(1, -1)
    g32 = g.astype(jnp.float32) * clip
    m_prev = m_codes.astype(jnp.float32) * m_scale
    v_sqrt = v_codes.astype(jnp.float32) * v_scale
    v_prev = v_sqrt * v_sqrt
    m = b1 * m_prev + (1 - b1) * g32
    v = b2 * v_prev + (1 - b2) * g32 * g32
    ok = finite > 0
    m_store = jnp.where(ok, m, m_prev)
    v_store = jnp.where(ok, v, v_prev)
    update = (m_store / b1c) / (jnp.sqrt(v_store / b2c) + eps)
    if uclip:
        update = jnp.clip(update, -uclip, uclip)
    mst = master.astype(jnp.float32)
    new_master = jnp.where(ok, mst - lr * (update + wd * mst), mst)
    mx = jnp.max(jnp.abs(m_store), axis=0)
    vx = jnp.max(jnp.sqrt(v_store), axis=0)
    msn = jnp.where(mx == 0, 1.0, mx / qmax).astype(jnp.float32)
    vsn = jnp.where(vx == 0, 1.0, vx / qmax).astype(jnp.float32)
    u1 = (rand >> 16).astype(jnp.float32) * (1.0 / (1 << 16))
    u2 = (rand & 0xFFFF).astype(jnp.float32) * (1.0 / (1 << 16))
    m_t = m_store / msn
    lo = jnp.floor(m_t)
    mc = jnp.clip(lo + (u1 < (m_t - lo)).astype(jnp.float32),
                  -qmax, qmax).astype(jnp.int8)
    v_t = jnp.sqrt(v_store) / vsn
    lo2 = jnp.floor(v_t)
    vc = jnp.clip(lo2 + (u2 < (v_t - lo2)).astype(jnp.float32),
                  -qmax, qmax).astype(jnp.int8)
    return new_master, mc, msn, vc, vsn


def qmv_ref(codes, v):
    return jnp.dot(codes.astype(jnp.float32), v.astype(jnp.float32))


def row_absmax_ref(x):
    return jnp.max(jnp.abs(x.astype(jnp.float32)), axis=1, keepdims=True)


def qmm_ref(x, codes, scale):
    w = codes.astype(jnp.float32) * scale.astype(jnp.float32)
    return jnp.dot(x.astype(jnp.float32), w)


def dequant_pages_ref(pages, scale):
    """Dequantize KV pages to bf16 rows — exactly ``KVCache.materialize``'s
    per-row math, so bf16/int8/int4 paged serving stays bit-compatible with
    the legacy ring buffer.

    pages: (…, page, Hkv, D) bf16 | int8 codes | uint8 packed int4 (…, D/2);
    scale: (…, page, Hkv, 1) f32 or None (bf16 passthrough).
    """
    if scale is None:
        return pages
    if pages.dtype == jnp.uint8:
        from repro.quant import unpack_int4

        codes = unpack_int4(pages)
    else:
        codes = pages.astype(jnp.float32)
    return (codes * scale).astype(jnp.bfloat16)


def gather_pages_ref(pages, block_table):
    """(P, page, Hkv, Dk) pool + (B, MAXP) table → (B, MAXP·page, Hkv, Dk)
    contiguous per-sequence KV rows (rows past seq_len are garbage — the
    attention mask is what makes them unread)."""
    g = pages[block_table]                       # (B, MAXP, page, Hkv, Dk)
    b, mp, page = g.shape[:3]
    return g.reshape(b, mp * page, *g.shape[3:])


def paged_attention_ref(q, k_pages, v_pages, k_scale, v_scale, block_table,
                        seq_lens, *, softmax_scale):
    """Oracle for kernels/paged_attn.py: gather pages through the block table,
    dequantize with the ring-buffer math, run models.attention.decode_attention
    (the legacy masked-softmax decode) — bit-exact with the ring path whenever
    the gathered rows equal the ring rows.

    q: (B, H, D); k/v_pages: (P, page, Hkv, D[/2]); block_table: (B, MAXP)
    int32; seq_lens: (B,) int32. Returns (B, H, D) in q.dtype.
    """
    from repro.models import attention as attn

    k = dequant_pages_ref(gather_pages_ref(k_pages, block_table),
                          gather_pages_ref(k_scale, block_table)
                          if k_scale is not None else None)
    v = dequant_pages_ref(gather_pages_ref(v_pages, block_table),
                          gather_pages_ref(v_scale, block_table)
                          if v_scale is not None else None)
    b, h, d = q.shape
    spec = attn.AttnSpec(n_heads=h, n_kv_heads=k.shape[2], head_dim=d,
                         softmax_scale=softmax_scale)
    out = attn.decode_attention(q[:, None], k, v, spec, kv_len=seq_lens)
    return out[:, 0]


def ssd_chunk_scan_ref(xh, dt, logdec, bmat, cmat):
    """Reference chunked SSD (mirrors models/ssm.ssd_chunked math).

    xh: (B, NC, L, H, P); dt/logdec: (B, NC, L, H); b/c: (B, NC, L, N).
    """
    b, nc, L, h, p = xh.shape
    n = bmat.shape[-1]

    def per_batch(x_b, dt_b, ld_b, bm_b, cm_b):
        def chunk(state, inp):
            xc, dtc, ldc, bc, cc = inp
            cum = jnp.cumsum(ldc, axis=0)
            xw = xc.astype(jnp.float32) * dtc[:, :, None]
            diff = cum[:, None, :] - cum[None, :, :]
            mask = jnp.tril(jnp.ones((L, L), bool))
            dec = jnp.exp(jnp.where(mask[:, :, None], diff, -jnp.inf))
            scores = jnp.dot(cc, bc.T)
            att = scores[:, :, None] * dec
            y_intra = jnp.einsum("lmh,mhp->lhp", att, xw)
            y_inter = jnp.einsum("ln,hpn->lhp", cc, state) * jnp.exp(cum)[:, :, None]
            tail = jnp.exp(cum[-1:, :] - cum)
            bx = jnp.einsum("lhp,ln->hpn", xw * tail[:, :, None], bc)
            state = state * jnp.exp(cum[-1])[:, None, None] + bx
            return state, (y_intra + y_inter).astype(xh.dtype)

        init = jnp.zeros((h, p, n), jnp.float32)
        state, ys = jax.lax.scan(
            chunk, init,
            (x_b, dt_b.astype(jnp.float32), ld_b.astype(jnp.float32),
             bm_b.astype(jnp.float32), cm_b.astype(jnp.float32)))
        return ys, state

    ys, states = jax.vmap(per_batch)(xh, dt, logdec, bmat, cmat)
    return ys, states
