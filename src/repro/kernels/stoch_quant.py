"""Pallas TPU kernel: stochastic quantization (C1) — the ZipML hot spot.

The FPGA pipeline quantizes samples in-line with the data stream; the TPU
analogue streams bf16/f32 blocks HBM→VMEM, rounds stochastically against
uniform random bits, and writes int8 codes (+ per-row scales computed in a
first reduction kernel). Rounding consumes explicit uint32 random bits passed
as an operand — `pltpu.prng_random_bits` exists on real TPUs, but an explicit
operand keeps the kernel bit-exact under `interpret=True` on CPU (how we
validate against ref.py).

Tiling: rows × 128-lane blocks; both MXU/VPU-aligned and big enough to keep
the VPU busy while the next block streams in. For a (R, C) input with block
(br, 128·k): VMEM footprint = br·128k·(4+4+1) bytes ≤ ~2 MiB per default.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import registry


DEFAULT_BLOCK = (256, 512)


def _resolve(block, r: int, c: int, dtype: str):
    """``block=None`` → registry.resolve_block("ds_quant", …): the autotune
    cache winner for this (dtype, shape-bucket) when one exists, else
    DEFAULT_BLOCK — always fitted so both grid axes tile exactly."""
    explicit = {"br": block[0], "bc": block[1]} if block is not None else {}
    return registry.resolve_block("ds_quant", {"br": r, "bc": c},
                                  dtype=registry.dtype_key(dtype),
                                  explicit=explicit)


def _sq_kernel(x_ref, rand_ref, scale_ref, codes_ref, *, s: int):
    """One (br, bc) block: codes = sign ⊙ stochastic_round(|x|/scale · s)."""
    x = x_ref[...].astype(jnp.float32)
    scale = scale_ref[...].astype(jnp.float32)          # (br, 1) row scales
    u = rand_ref[...]                                   # uint32
    # uniform in [0,1): top 24 bits / 2^24 (exact in f32)
    uf = (u >> 8).astype(jnp.float32) * (1.0 / (1 << 24))
    mag = jnp.abs(x) / jnp.maximum(scale, 1e-30)
    t = jnp.clip(mag, 0.0, 1.0) * s
    lo = jnp.clip(jnp.floor(t), 0, s - 1)
    codes = lo + (uf < (t - lo)).astype(jnp.float32)
    codes = codes * jnp.sign(x)
    codes_ref[...] = codes.astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("s", "block", "interpret"))
def stoch_quant(x: jax.Array, rand: jax.Array, scale: jax.Array, *, s: int,
                block=None, interpret: bool | None = None):
    """x: (R, C) f32/bf16; rand: (R, C) uint32; scale: (R, 1) f32 row scales.
    Returns int8 codes in [-s, s]. ``block=None`` resolves through the
    autotune cache → DEFAULT_BLOCK. (interpret=True on CPU; False on TPU.)
    """
    r, c = x.shape
    br, bc = _resolve(block, r, c, x.dtype)
    grid = (pl.cdiv(r, br), pl.cdiv(c, bc))
    return pl.pallas_call(
        functools.partial(_sq_kernel, s=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((br, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.int8),
        interpret=registry.resolve_interpret(interpret),
    )(x, rand, scale)


def _ds_quant_kernel(x_ref, rand_ref, scale_ref, c1_ref, c2_ref, *, s: int):
    """Fused double-sampling quantizer: one HBM read of x, two int8 code planes.

    Q₁/Q₂ share the base level ⌊|x|/scale·s⌋ (paper §2.2 'Overhead of Storing
    Samples': shipping both costs 1 extra up/down bit, not 2×) and differ only
    in independent Bernoulli(frac) up-bits, drawn from the high/low 16 bits of
    a single uint32 plane. E[Qᵢ] = x exactly up to 2⁻¹⁶ probability granularity.
    """
    x = x_ref[...].astype(jnp.float32)
    scale = scale_ref[...].astype(jnp.float32)
    u = rand_ref[...]                                   # uint32
    u1 = (u >> 16).astype(jnp.float32) * (1.0 / (1 << 16))
    u2 = (u & 0xFFFF).astype(jnp.float32) * (1.0 / (1 << 16))
    mag = jnp.abs(x) / jnp.maximum(scale, 1e-30)
    t = jnp.clip(mag, 0.0, 1.0) * s
    base = jnp.clip(jnp.floor(t), 0, s - 1)             # shared base level
    frac = t - base                                     # P(round up)
    sign = jnp.sign(x)
    c1_ref[...] = ((base + (u1 < frac).astype(jnp.float32)) * sign).astype(jnp.int8)
    c2_ref[...] = ((base + (u2 < frac).astype(jnp.float32)) * sign).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("s", "scale_axis", "block", "interpret"))
def ds_quant(x: jax.Array, rand: jax.Array, scale: jax.Array, *, s: int,
             scale_axis: str = "row", block=None, interpret: bool | None = None):
    """Fused double-sampling quantization (the ZipML §2.2 hot path).

    x: (R, C) f32/bf16; rand: (R, C) uint32 (one plane feeds both draws);
    scale: (R, 1) row scales or (1, C) column scales per ``scale_axis``.
    Returns (codes1, codes2) int8 in [-s, s] — both emitted from a single
    streaming pass over x, vs two full passes for the naive two-call path.
    ``block=None`` resolves through the autotune cache → DEFAULT_BLOCK;
    block choice never changes the emitted codes (elementwise kernel).
    """
    if s > 127:
        raise ValueError(f"int8 code planes need s <= 127, got {s}")
    r, c = x.shape
    br, bc = _resolve(block, r, c, x.dtype)
    grid = (pl.cdiv(r, br), pl.cdiv(c, bc))
    if scale_axis == "row":
        scale_spec = pl.BlockSpec((br, 1), lambda i, j: (i, 0))
    elif scale_axis == "col":
        scale_spec = pl.BlockSpec((1, bc), lambda i, j: (0, j))
    else:
        raise ValueError(f"unknown scale_axis {scale_axis!r}")
    out_spec = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    return pl.pallas_call(
        functools.partial(_ds_quant_kernel, s=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            scale_spec,
        ],
        out_specs=[out_spec, out_spec],
        out_shape=[jax.ShapeDtypeStruct((r, c), jnp.int8),
                   jax.ShapeDtypeStruct((r, c), jnp.int8)],
        interpret=registry.resolve_interpret(interpret),
    )(x, rand, scale)


def _absmax_kernel(x_ref, out_ref):
    """Per-(row-block, col-block) absmax; the host wrapper reduces col blocks.
    (Cross-step accumulation on a revisited out block is legal on TPU but not
    honored by the CPU interpreter — per-block outputs keep both paths exact.)"""
    out_ref[...] = jnp.max(jnp.abs(x_ref[...].astype(jnp.float32)),
                           axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def row_absmax(x: jax.Array, *, block=None, interpret: bool | None = None):
    """(R, C) → (R, 1) f32 row scales M(v) = max|v| (the paper's linf row
    scaling; grid dim 1 iterates sequentially so the max accumulates)."""
    r, c = x.shape
    br, bc = _resolve(block, r, c, x.dtype)
    # pad columns: out-of-bounds reads are undefined (on TPU and in interpret
    # mode) and would fold garbage into the max
    if c % bc:
        x = jnp.pad(x, ((0, 0), (0, bc - c % bc)))
        c = x.shape[1]
    ncb = pl.cdiv(c, bc)
    per_block = pl.pallas_call(
        _absmax_kernel,
        grid=(pl.cdiv(r, br), ncb),
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((br, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, ncb), jnp.float32),
        interpret=registry.resolve_interpret(interpret),
    )(x)
    return jnp.max(per_block, axis=1, keepdims=True)
