"""Pallas TPU kernel: fused quantized-moment AdamW update.

The optimizer sweep is the last per-step full-tree HBM pass not behind a
fused kernel: the jnp path decodes the int8 m/v codes to f32 (round-trip 1),
updates them (round-trip 2), then re-quantizes — absmax reduction plus a
stochastic-rounding pass (round-trip 3), materializing two full fp32 moment
tensors in HBM along the way. The fused pipeline never materializes them:

* pass 1 (``qadamw_absmax``) recomputes the new m / √v per tile in VMEM from
  (codes, scales, g) and emits only per-tile column absmaxes — the host
  reduces those to the new quantization scales (the same trick as
  ``stoch_quant.row_absmax``: cross-block accumulation is kept out of the
  kernel so interpret mode stays bit-exact with TPU).
* pass 2 (``qadamw_update``) recomputes m / v again, writes the new fp32
  master and both int8 code planes in one VMEM pass.

g and the code planes are read twice (int8 + f32 streams); the fp32 moments
exist only as VMEM tiles. Rounding consumes the high/low 16 bits of one
explicit uint32 plane (m and v draws are independent), exactly like
``stoch_quant.ds_quant``. Unlike ds_quant the tile math contains adds of
products (the EMA), which XLA may or may not contract to FMAs depending on
the surrounding program — so the pinned contract against the jnp mirror
(``ref.quant_adamw_ref``) is one-ULP parity on masters/scales plus exact
agreement of (almost all) code planes, not bitwise equality
(tests/test_quant_adamw.py).

Scalar step inputs (clip, finite, lr, bias corrections) arrive as one (8,)
f32 SMEM operand — they are traced values (lr depends on the step counter),
so they cannot be baked in statically like b1/b2/eps/wd.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import registry


DEFAULT_BLOCK = (256, 512)

# params layout in the (8,) f32 SMEM operand
P_CLIP, P_FINITE, P_LR, P_B1C, P_B2C = 0, 1, 2, 3, 4


def _resolve(block, r: int, c: int):
    """``block=None`` → registry.resolve_block("quant_adamw", …): autotune
    winner per shape-bucket when tuned, else DEFAULT_BLOCK, fitted so both
    grid axes tile exactly. Both passes resolve independently — pass 1's
    per-row-block absmax output is reduced on the host, so the passes don't
    need matching blocks."""
    explicit = {"br": block[0], "bc": block[1]} if block is not None else {}
    return registry.resolve_block("quant_adamw", {"br": r, "bc": c},
                                  dtype="f32", explicit=explicit)


def _moments(g, m_codes, m_scale, v_codes, v_scale, clip, finite,
             *, b1: float, b2: float):
    """Shared tile math: decode old moments, apply the EMA update, select
    prev on non-finite steps. Returns (m_prev, v_prev, m_store, v_store)."""
    g32 = g.astype(jnp.float32) * clip
    m_prev = m_codes.astype(jnp.float32) * m_scale
    v_sqrt = v_codes.astype(jnp.float32) * v_scale
    v_prev = v_sqrt * v_sqrt
    m = b1 * m_prev + (1 - b1) * g32
    v = b2 * v_prev + (1 - b2) * g32 * g32
    ok = finite > 0
    return m_prev, v_prev, jnp.where(ok, m, m_prev), jnp.where(ok, v, v_prev)


def _absmax_kernel(g_ref, mc_ref, ms_ref, vc_ref, vs_ref, par_ref,
                   mx_ref, vx_ref, *, b1: float, b2: float):
    """Per-(row-block, col-block) column absmax of the *stored* new m and √v."""
    _, _, m_store, v_store = _moments(
        g_ref[...], mc_ref[...], ms_ref[...].astype(jnp.float32),
        vc_ref[...], vs_ref[...].astype(jnp.float32),
        par_ref[P_CLIP], par_ref[P_FINITE], b1=b1, b2=b2)
    mx_ref[...] = jnp.max(jnp.abs(m_store), axis=0, keepdims=True)
    vx_ref[...] = jnp.max(jnp.sqrt(v_store), axis=0, keepdims=True)


def _update_kernel(mst_ref, g_ref, mc_ref, ms_ref, vc_ref, vs_ref,
                   msn_ref, vsn_ref, rand_ref, par_ref,
                   out_mst, out_mc, out_vc,
                   *, b1: float, b2: float, eps: float, wd: float, qmax: int,
                   uclip: float):
    """Decode → AdamW update → stochastic re-encode, one VMEM tile at a time."""
    finite = par_ref[P_FINITE]
    m_prev, v_prev, m_store, v_store = _moments(
        g_ref[...], mc_ref[...], ms_ref[...].astype(jnp.float32),
        vc_ref[...], vs_ref[...].astype(jnp.float32),
        par_ref[P_CLIP], finite, b1=b1, b2=b2)
    # master update uses the un-requantized moments (decode error enters once)
    update = (m_store / par_ref[P_B1C]) / (
        jnp.sqrt(v_store / par_ref[P_B2C]) + eps)
    if uclip:
        # √v-underflow guard: see AdamWConfig.update_clip
        update = jnp.clip(update, -uclip, uclip)
    mst = mst_ref[...].astype(jnp.float32)
    new_mst = mst - par_ref[P_LR] * (update + wd * mst)
    out_mst[...] = jnp.where(finite > 0, new_mst, mst)
    # stochastic re-encode: independent 16-bit up/down draws for m and √v
    u = rand_ref[...]
    u1 = (u >> 16).astype(jnp.float32) * (1.0 / (1 << 16))
    u2 = (u & 0xFFFF).astype(jnp.float32) * (1.0 / (1 << 16))
    m_t = m_store / msn_ref[...].astype(jnp.float32)
    lo = jnp.floor(m_t)
    mc = lo + (u1 < (m_t - lo)).astype(jnp.float32)
    out_mc[...] = jnp.clip(mc, -qmax, qmax).astype(jnp.int8)
    v_t = jnp.sqrt(v_store) / vsn_ref[...].astype(jnp.float32)
    lo2 = jnp.floor(v_t)
    vc = lo2 + (u2 < (v_t - lo2)).astype(jnp.float32)
    out_vc[...] = jnp.clip(vc, -qmax, qmax).astype(jnp.int8)


def _specs(br, bc):
    tile = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    colrow = pl.BlockSpec((1, bc), lambda i, j: (0, j))
    smem = pl.BlockSpec(memory_space=pltpu.SMEM)
    return tile, colrow, smem


@functools.partial(jax.jit,
                   static_argnames=("b1", "b2", "block", "interpret"))
def qadamw_absmax(g, m_codes, m_scale, v_codes, v_scale, params, *,
                  b1: float, b2: float, block=None,
                  interpret: bool | None = None):
    """g (R, C) f32; codes (R, C) int8; scales (1, C) f32; params (8,) f32.
    Returns per-row-block column absmaxes: (R/br, C) for new-m and new-√v."""
    r, c = g.shape
    br, bc = _resolve(block, r, c)
    grid = (pl.cdiv(r, br), pl.cdiv(c, bc))
    tile, colrow, smem = _specs(br, bc)
    out_spec = pl.BlockSpec((1, bc), lambda i, j: (i, j))
    return pl.pallas_call(
        functools.partial(_absmax_kernel, b1=b1, b2=b2),
        grid=grid,
        in_specs=[tile, tile, colrow, tile, colrow, smem],
        out_specs=[out_spec, out_spec],
        out_shape=[jax.ShapeDtypeStruct((grid[0], c), jnp.float32),
                   jax.ShapeDtypeStruct((grid[0], c), jnp.float32)],
        interpret=registry.resolve_interpret(interpret),
    )(g, m_codes, m_scale, v_codes, v_scale, params)


@functools.partial(jax.jit,
                   static_argnames=("b1", "b2", "eps", "wd", "qmax", "uclip",
                                    "block", "interpret"))
def qadamw_update(master, g, m_codes, m_scale, v_codes, v_scale,
                  m_scale_new, v_scale_new, rand, params, *,
                  b1: float, b2: float, eps: float, wd: float, qmax: int,
                  uclip: float = 0.0, block=None,
                  interpret: bool | None = None):
    """The pass-2 fused update. master/g (R, C) f32; codes (R, C) int8;
    old/new scales (1, C) f32; rand (R, C) uint32; params (8,) f32.
    Returns (new_master f32, new_m_codes int8, new_v_codes int8)."""
    r, c = master.shape
    br, bc = _resolve(block, r, c)
    grid = (pl.cdiv(r, br), pl.cdiv(c, bc))
    tile, colrow, smem = _specs(br, bc)
    return pl.pallas_call(
        functools.partial(_update_kernel, b1=b1, b2=b2, eps=eps, wd=wd,
                          qmax=qmax, uclip=uclip),
        grid=grid,
        in_specs=[tile, tile, tile, colrow, tile, colrow, colrow, colrow,
                  tile, smem],
        out_specs=[tile, tile, tile],
        out_shape=[jax.ShapeDtypeStruct((r, c), jnp.float32),
                   jax.ShapeDtypeStruct((r, c), jnp.int8),
                   jax.ShapeDtypeStruct((r, c), jnp.int8)],
        interpret=registry.resolve_interpret(interpret),
    )(master, g, m_codes, m_scale, v_codes, v_scale,
      m_scale_new, v_scale_new, rand, params)
