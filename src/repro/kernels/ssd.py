"""Pallas TPU kernel: Mamba2 SSD intra-chunk block (the hot spot of the
state-space dual form, models/ssm.py::ssd_chunked).

One grid step processes one (batch, chunk) pair entirely in VMEM:

    cum      = cumsum(logdec)                  (L, H)
    dec(l,m) = exp(cum_l − cum_m)  masked causal
    scores   = C · Bᵀ                          (L, L)      ← MXU
    y_intra  = (scores ⊙ dec) · (dt ⊙ x)       (L, H, P)   ← MXU
    y_inter  = (C · state) ⊙ exp(cum)
    state'   = state ⊙ exp(cum_L) + (dt⊙x⊙tail)ᵀ · B

The chunk dim L (=256) and head dims are MXU-aligned; VMEM working set for
L=256, H=48, P=64, N=128 ≈ 6 MiB. The inter-chunk state is carried by the
sequential chunk grid axis (grid dim 1), matching the lax.scan reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import registry


def _ssd_kernel(x_ref, dt_ref, ld_ref, b_ref, c_ref, y_ref, state_ref):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)     # (L, H, P)
    dt = dt_ref[0, 0].astype(jnp.float32)   # (L, H)
    ld = ld_ref[0, 0].astype(jnp.float32)   # (L, H)
    bmat = b_ref[0, 0].astype(jnp.float32)  # (L, N)
    cmat = c_ref[0, 0].astype(jnp.float32)  # (L, N)
    state = state_ref[0]                    # (H, P, N) f32

    L = x.shape[0]
    cum = jnp.cumsum(ld, axis=0)            # (L, H)
    xw = x * dt[:, :, None]                 # (L, H, P)
    diff = cum[:, None, :] - cum[None, :, :]            # (L, L, H)
    mask = jnp.tril(jnp.ones((L, L), bool))
    dec = jnp.exp(jnp.where(mask[:, :, None], diff, -jnp.inf))
    scores = jnp.dot(cmat, bmat.T, preferred_element_type=jnp.float32)  # (L, L)
    att = scores[:, :, None] * dec                       # (L, L, H)
    y_intra = jnp.einsum("lmh,mhp->lhp", att, xw)
    y_inter = jnp.einsum("ln,hpn->lhp", cmat, state) * jnp.exp(cum)[:, :, None]
    tail = jnp.exp(cum[-1:, :] - cum)                    # (L, H)
    bx = jnp.einsum("lhp,ln->hpn", xw * tail[:, :, None], bmat)
    state_ref[0] = state * jnp.exp(cum[-1])[:, None, None] + bx
    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_scan(xh, dt, logdec, bmat, cmat, *, interpret: bool | None = None):
    """Chunked SSD over pre-chunked inputs.

    xh: (B, NC, L, H, P); dt/logdec: (B, NC, L, H); b/c: (B, NC, L, N).
    Returns y (B, NC, L, H, P) and final state (B, H, P, N). Grid = (B, NC)
    with NC sequential (carries the state block).
    """
    b, nc, L, h, p = xh.shape
    n = bmat.shape[-1]
    grid = (b, nc)
    y, state = pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, L, h, p), lambda i, c: (i, c, 0, 0, 0)),
            pl.BlockSpec((1, 1, L, h), lambda i, c: (i, c, 0, 0)),
            pl.BlockSpec((1, 1, L, h), lambda i, c: (i, c, 0, 0)),
            pl.BlockSpec((1, 1, L, n), lambda i, c: (i, c, 0, 0)),
            pl.BlockSpec((1, 1, L, n), lambda i, c: (i, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, h, p), lambda i, c: (i, c, 0, 0, 0)),
            pl.BlockSpec((1, h, p, n), lambda i, c: (i, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nc, L, h, p), xh.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        interpret=registry.resolve_interpret(interpret),
    )(xh, dt, logdec, bmat, cmat)
    return y, state
