"""Kernel-backend registry — one switch for every hot quantization path.

The ZipML hot loop (double-sample quantization + the LSQ gradient built from
it) has two implementations:

* ``ref``    — the pure-jnp path of core/quantize.py: two independent
  full-precision quantization passes. Bit-exact with the original seed
  numerics; the ground truth every other backend is tested against.
* ``pallas`` — the fused pipeline: kernels/stoch_quant.ds_quant emits both
  Q₁/Q₂ int8 code planes in a single HBM read (shared base level + two
  up/down bits, the paper's "1 extra bit, not 2×" storage claim), and
  kernels/qmm.qmv computes q₁ᵀ(q₂x − b) straight from codes+scales without
  ever materializing a dequantized f32 sample tensor.

Selection precedence: explicit ``backend=`` argument > ``select()`` >
``ZIPML_KERNEL_BACKEND`` env var > default per ``jax.default_backend()``
(pallas on TPU, ref elsewhere — interpret-mode Pallas is correctness-only on
CPU). Resolution happens at Python trace time, so the choice is static under
``jax.jit``/``lax.scan``.
"""
from __future__ import annotations

import contextlib
import os

import jax
import jax.numpy as jnp

_BACKENDS: dict[str, "KernelBackend"] = {}
_ACTIVE: str | None = None

ENV_VAR = "ZIPML_KERNEL_BACKEND"


class KernelBackend:
    """Interface of a quantization kernel backend.

    The QTensor entry points — ``encode``/``decode``/``ds_pair``/``qt_dot`` —
    are what :mod:`repro.quant` dispatches through; the base class provides
    the pure-jnp reference implementations, so a backend only overrides the
    paths it fuses.

    The lower-level tuple API remains for the hot LSQ loop:
    ``ds_quant_values`` returns the two dequantized draws (the numerical form
    the gradient math is written in); ``ds_quant_codes`` the storage form
    (codes1, codes2, scale); ``lsq_ds_gradient`` the symmetrized §2.2
    estimator ½[Q₁ᵀ(Q₂x−b) + Q₂ᵀ(Q₁x−b)]/B.
    """

    name: str = "abstract"

    # ---------------------------------------------------- QTensor surface --
    def encode(self, x, scheme, key=None, scale=None, levels=None):
        """Quantize ``x`` under ``scheme`` → QTensor (single plane)."""
        from repro.quant.qtensor import encode_jnp

        return encode_jnp(x, scheme, key, scale=scale, levels=levels)

    def decode(self, qt, dtype=None):
        return qt.decode(dtype)

    def ds_pair(self, x, scheme, key, scale=None):
        """Draw the §2.2 double-sampling pair → QTensor with ``codes2``."""
        from repro.quant.qtensor import ds_pair_jnp

        return ds_pair_jnp(x, scheme, key, scale=scale)

    def qt_dot(self, qt, v):
        """decode(qt) @ v; backends may stream codes instead."""
        return qt.decode() @ v

    def paged_attention(self, q, k_pages, v_pages, k_scale, v_scale,
                        block_table, seq_lens, *, softmax_scale):
        """Decode attention of q (B, H, D) against a paged, possibly
        quantized KV pool (repro/serve/pages.py layout). The base
        implementation gathers pages through the block table and reuses the
        legacy masked-softmax decode — bit-exact with the ring-buffer cache;
        backends may stream codes page-by-page instead."""
        from repro.kernels import ref

        return ref.paged_attention_ref(
            q, k_pages, v_pages, k_scale, v_scale, block_table, seq_lens,
            softmax_scale=softmax_scale)

    def quant_adamw_update(self, p_master, g, m_old, v_old, km, kv, *,
                           bits: int, b1: float, b2: float, eps: float,
                           b1c, b2c, lr, clip, finite, wd: float,
                           uclip: float = 0.0):
        """One quantized-moment AdamW leaf update: decode int8 m/v QTensors,
        EMA-update, write the fp32 master, stochastically re-encode.
        ``uclip`` bounds the per-coordinate |update| (the √v-underflow guard
        — see AdamWConfig.update_clip).

        The base implementation is the pure-jnp seed numerics (three
        full-tensor passes); the Pallas backend fuses them into the two-pass
        VMEM pipeline of kernels/quant_adamw.py. Returns
        (new_master, new_m: QTensor, new_v: QTensor).
        """
        from repro.optim.adamw import decode_moment, encode_moment

        g32 = g.astype(jnp.float32) * clip
        m_prev = decode_moment(m_old)
        v_prev = decode_moment(v_old, positive=True)
        m = b1 * m_prev + (1 - b1) * g32
        v = b2 * v_prev + (1 - b2) * g32 * g32
        update = (m / b1c) / (jnp.sqrt(v / b2c) + eps)
        if uclip:
            update = jnp.clip(update, -uclip, uclip)
        new_master = p_master - lr * (update + wd * p_master)
        new_master = jnp.where(finite, new_master, p_master)
        m_q = encode_moment(jnp.where(finite, m, m_prev), bits, km)
        v_q = encode_moment(jnp.where(finite, v, v_prev), bits, kv,
                            positive=True)
        return new_master, m_q, v_q

    # ------------------------------------------------- tuple-form hot loop --
    def ds_quant_values(self, a, s, key, scale=None):
        raise NotImplementedError

    def ds_quant_codes(self, a, s, key, scale=None):
        raise NotImplementedError

    def lsq_ds_gradient(self, x, a, b, s, key, scale=None):
        raise NotImplementedError


def register(backend: KernelBackend) -> KernelBackend:
    _BACKENDS[backend.name] = backend
    return backend


def available() -> list[str]:
    return sorted(_BACKENDS)


def default_name() -> str:
    """pallas where it compiles (TPU); ref everywhere else."""
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def select(name: str | None) -> None:
    """Set the process-wide backend (None resets to env/hardware default)."""
    global _ACTIVE
    if name is not None and name not in _BACKENDS:
        raise ValueError(f"unknown kernel backend {name!r}; have {available()}")
    _ACTIVE = name


@contextlib.contextmanager
def using(name: str | None):
    """Temporarily select a backend; the previous selection is restored on
    exit (``None`` selects nothing and just yields the resolved backend)."""
    global _ACTIVE
    prev = _ACTIVE
    if name is not None:
        select(name)
    try:
        yield get()
    finally:
        _ACTIVE = prev


def get(name: str | None = None) -> KernelBackend:
    name = name or _ACTIVE or os.environ.get(ENV_VAR) or default_name()
    if name not in _BACKENDS:
        raise ValueError(f"unknown kernel backend {name!r}; have {available()}")
    return _BACKENDS[name]


class _RefBackend(KernelBackend):
    """Two independent core/quantize.py passes — the seed's exact numerics."""

    name = "ref"

    def _zipml_pair(self, a, s, key, scale=None):
        from repro.quant.qtensor import ds_pair_jnp
        from repro.quant.scheme import QScheme

        return ds_pair_jnp(a, QScheme.zipml(s), key, scale=scale)

    def ds_quant_values(self, a, s, key, scale=None):
        qt = self._zipml_pair(a, s, key, scale=scale)
        return qt.decode(), qt.decode2()

    def ds_quant_codes(self, a, s, key, scale=None):
        qt = self._zipml_pair(a, s, key, scale=scale)
        return qt.codes, qt.codes2, qt.scale

    def lsq_ds_gradient(self, x, a, b, s, key, scale=None):
        q1, q2 = self.ds_quant_values(a, s, key, scale=scale)
        B = a.shape[0]
        r2 = q2 @ x - b
        r1 = q1 @ x - b
        return (q1.T @ r2 + q2.T @ r1) / (2.0 * B)


class _PallasBackend(KernelBackend):
    """Fused ds_quant + int8-codes matvecs (kernels/stoch_quant, kernels/qmm).

    ``scale=None`` resolves to the same global-scalar absmax the ref backend
    uses (core/quantize.row_scale), so the two backends quantize against
    identical grids; column scales — the data-pipeline convention — pass
    through. Per-row scales are not used here: they don't factor through
    q₁ᵀ(q₂x − b), which ds_gradient_from_codes relies on.
    """

    name = "pallas"

    def _resolve_scale(self, a, scale):
        if scale is None:
            from repro.core.quantize import row_scale

            return row_scale(a)  # scalar global absmax, as in ref
        return scale

    def ds_quant_values(self, a, s, key, scale=None):
        c1, c2, sc = self.ds_quant_codes(a, s, key, scale=scale)
        return (c1.astype(jnp.float32) / s * sc,
                c2.astype(jnp.float32) / s * sc)

    def ds_quant_codes(self, a, s, key, scale=None):
        from repro.kernels import ops

        return ops.ds_quantize(a, s, key, scale=self._resolve_scale(a, scale))

    def lsq_ds_gradient(self, x, a, b, s, key, scale=None):
        from repro.kernels import ops

        c1, c2, sc = self.ds_quant_codes(a, s, key, scale=scale)
        return ops.ds_gradient_from_codes(c1, c2, x, b, sc, s)

    # ---------------------------------------------------- QTensor surface --
    def ds_pair(self, x, scheme, key, scale=None):
        """Fused single-read pair draw for the 2-D zipml grid; everything
        else falls back to the reference implementation."""
        from repro.quant.qtensor import QTensor, compute_scale

        if scheme.grid != "zipml" or x.ndim != 2 or not scheme.signed \
                or scheme.s > 127:
            return KernelBackend.ds_pair(self, x, scheme, key, scale=scale)
        from repro.kernels import ops

        if scale is None:
            scale = compute_scale(x, scheme)
        scale = jnp.asarray(scale, jnp.float32)
        c1, c2, _ = ops.ds_quantize(x, scheme.s, key, scale=scale)
        # store the caller's scale, not the kernel's broadcast copy — ref and
        # pallas QTensors stay structurally identical (same nbytes, stackable,
        # checkpoint-compatible)
        return QTensor(c1, scale, scheme.with_rounding("ds"), codes2=c2)

    def paged_attention(self, q, k_pages, v_pages, k_scale, v_scale,
                        block_table, seq_lens, *, softmax_scale):
        """Fused paged flash-decode: block-table-indexed page DMA (scalar
        prefetch) + in-VMEM int8/int4 dequant (kernels/paged_attn.py)."""
        from repro.kernels import ops

        return ops.paged_attention(
            q, k_pages, v_pages, k_scale, v_scale, block_table, seq_lens,
            softmax_scale=softmax_scale)

    def quant_adamw_update(self, p_master, g, m_old, v_old, km, kv, *,
                           bits: int, b1: float, b2: float, eps: float,
                           b1c, b2c, lr, clip, finite, wd: float,
                           uclip: float = 0.0):
        """Fused decode→update→re-encode (kernels/quant_adamw.py): the fp32
        moments never round-trip HBM. 2-D+ leaves only (vectors/scalars fall
        back to the jnp path — sub-tile shapes aren't worth a kernel launch);
        rounding bits come from the high/low 16 bits of one uint32 plane
        drawn from ``km`` (distribution-identical to the ref backend's two
        key-based draws, pinned by tests/test_quant_adamw.py)."""
        if p_master.ndim < 2 or bits > 8 or km is None:
            return KernelBackend.quant_adamw_update(
                self, p_master, g, m_old, v_old, km, kv, bits=bits, b1=b1,
                b2=b2, eps=eps, b1c=b1c, b2c=b2c, lr=lr, clip=clip,
                finite=finite, wd=wd, uclip=uclip)
        from repro.kernels import ops
        from repro.optim.adamw import moment_scheme
        from repro.quant.qtensor import QTensor

        shape = p_master.shape
        c = shape[-1]
        rand = jax.random.bits(km, shape, jnp.uint32).reshape(-1, c)
        nm, mc, msn, vc, vsn = ops.quant_adamw_update(
            p_master.astype(jnp.float32).reshape(-1, c),
            g.astype(jnp.float32).reshape(-1, c),
            m_old.codes.reshape(-1, c), m_old.scale,
            v_old.codes.reshape(-1, c), v_old.scale, rand,
            qmax=2 ** (bits - 1) - 1, b1=b1, b2=b2, eps=eps, wd=wd,
            uclip=uclip, lr=lr, b1c=b1c, b2c=b2c, clip=clip,
            finite=finite.astype(jnp.float32))
        scheme = moment_scheme(bits, len(shape))
        return (nm.reshape(shape),
                QTensor(mc.reshape(shape), msn, scheme),
                QTensor(vc.reshape(shape), vsn, scheme))

    def qt_dot(self, qt, v):
        """Stream int8 codes through the qmv kernel when the scale factors
        out of the product (scalar / per-row / per-column families)."""
        codes, scale = qt.codes, qt.scale
        if (codes.ndim != 2 or jnp.ndim(v) != 1 or codes.dtype != jnp.int8
                or qt.scheme.grid == "levels"):
            return qt.decode() @ v
        from repro.kernels import ops

        denom = float(qt.scheme.s) if qt.scheme.grid == "zipml" else 1.0
        r, c = codes.shape
        shp = jnp.shape(scale)
        v32 = jnp.asarray(v, jnp.float32)
        if shp in ((), (1,), (1, 1)):
            return ops.int8_matvec(codes, v32) * (jnp.reshape(scale, ()) / denom)
        if shp == (r, 1):
            return scale.ravel() * ops.int8_matvec(codes, v32) / denom
        if shp in ((c,), (1, c)):
            return ops.int8_matvec(codes, jnp.ravel(scale) * v32) / denom
        return qt.decode() @ v


register(_RefBackend())
register(_PallasBackend())


def resolve(backend: "str | KernelBackend | None") -> KernelBackend:
    """Accept a name, an instance, or None (→ active/env/hardware default)."""
    if isinstance(backend, KernelBackend):
        return backend
    return get(backend)
