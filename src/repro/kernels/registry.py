"""Kernel-backend registry — one switch for every hot quantization path.

The ZipML hot loop (double-sample quantization + the LSQ gradient built from
it) has two implementations:

* ``ref``    — the pure-jnp path of core/quantize.py: two independent
  full-precision quantization passes. Bit-exact with the original seed
  numerics; the ground truth every other backend is tested against.
* ``pallas`` — the fused pipeline: kernels/stoch_quant.ds_quant emits both
  Q₁/Q₂ int8 code planes in a single HBM read (shared base level + two
  up/down bits, the paper's "1 extra bit, not 2×" storage claim), and
  kernels/qmm.qmv computes q₁ᵀ(q₂x − b) straight from codes+scales without
  ever materializing a dequantized f32 sample tensor.

Selection precedence: explicit ``backend=`` argument > ``select()`` >
``ZIPML_KERNEL_BACKEND`` env var > default per ``jax.default_backend()``
(pallas on TPU, ref elsewhere — interpret-mode Pallas is correctness-only on
CPU). Resolution happens at Python trace time, so the choice is static under
``jax.jit``/``lax.scan``.
"""
from __future__ import annotations

import contextlib
import os

import jax
import jax.numpy as jnp

_BACKENDS: dict[str, "KernelBackend"] = {}
_ACTIVE: str | None = None

ENV_VAR = "ZIPML_KERNEL_BACKEND"


class KernelBackend:
    """Interface of a quantization kernel backend.

    ``ds_quant_values`` returns the two dequantized draws (the numerical form
    the gradient math is written in); ``ds_quant_codes`` the storage form
    (codes1, codes2, scale); ``lsq_ds_gradient`` the symmetrized §2.2
    estimator ½[Q₁ᵀ(Q₂x−b) + Q₂ᵀ(Q₁x−b)]/B.
    """

    name: str = "abstract"

    def ds_quant_values(self, a, s, key, scale=None):
        raise NotImplementedError

    def ds_quant_codes(self, a, s, key, scale=None):
        raise NotImplementedError

    def lsq_ds_gradient(self, x, a, b, s, key, scale=None):
        raise NotImplementedError


def register(backend: KernelBackend) -> KernelBackend:
    _BACKENDS[backend.name] = backend
    return backend


def available() -> list[str]:
    return sorted(_BACKENDS)


def default_name() -> str:
    """pallas where it compiles (TPU); ref everywhere else."""
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def select(name: str | None) -> None:
    """Set the process-wide backend (None resets to env/hardware default)."""
    global _ACTIVE
    if name is not None and name not in _BACKENDS:
        raise ValueError(f"unknown kernel backend {name!r}; have {available()}")
    _ACTIVE = name


@contextlib.contextmanager
def using(name: str | None):
    """Temporarily select a backend; the previous selection is restored on
    exit (``None`` selects nothing and just yields the resolved backend)."""
    global _ACTIVE
    prev = _ACTIVE
    if name is not None:
        select(name)
    try:
        yield get()
    finally:
        _ACTIVE = prev


def get(name: str | None = None) -> KernelBackend:
    name = name or _ACTIVE or os.environ.get(ENV_VAR) or default_name()
    if name not in _BACKENDS:
        raise ValueError(f"unknown kernel backend {name!r}; have {available()}")
    return _BACKENDS[name]


class _RefBackend(KernelBackend):
    """Two independent core/quantize.py passes — the seed's exact numerics."""

    name = "ref"

    def ds_quant_values(self, a, s, key, scale=None):
        from repro.core.quantize import stochastic_quantize

        k1, k2 = jax.random.split(key)
        q1 = stochastic_quantize(a, s, k1, scale=scale)
        q2 = stochastic_quantize(a, s, k2, scale=scale)
        return q1, q2

    def ds_quant_codes(self, a, s, key, scale=None):
        from repro.core.quantize import quantize, row_scale

        if scale is None:
            scale = row_scale(a)
        k1, k2 = jax.random.split(key)
        q1 = quantize(a, s, k1, scale=scale)
        q2 = quantize(a, s, k2, scale=scale)
        return q1.codes, q2.codes, jnp.asarray(scale)

    def lsq_ds_gradient(self, x, a, b, s, key, scale=None):
        q1, q2 = self.ds_quant_values(a, s, key, scale=scale)
        B = a.shape[0]
        r2 = q2 @ x - b
        r1 = q1 @ x - b
        return (q1.T @ r2 + q2.T @ r1) / (2.0 * B)


class _PallasBackend(KernelBackend):
    """Fused ds_quant + int8-codes matvecs (kernels/stoch_quant, kernels/qmm).

    ``scale=None`` resolves to the same global-scalar absmax the ref backend
    uses (core/quantize.row_scale), so the two backends quantize against
    identical grids; column scales — the data-pipeline convention — pass
    through. Per-row scales are not used here: they don't factor through
    q₁ᵀ(q₂x − b), which ds_gradient_from_codes relies on.
    """

    name = "pallas"

    def _resolve_scale(self, a, scale):
        if scale is None:
            from repro.core.quantize import row_scale

            return row_scale(a)  # scalar global absmax, as in ref
        return scale

    def ds_quant_values(self, a, s, key, scale=None):
        c1, c2, sc = self.ds_quant_codes(a, s, key, scale=scale)
        return (c1.astype(jnp.float32) / s * sc,
                c2.astype(jnp.float32) / s * sc)

    def ds_quant_codes(self, a, s, key, scale=None):
        from repro.kernels import ops

        return ops.ds_quantize(a, s, key, scale=self._resolve_scale(a, scale))

    def lsq_ds_gradient(self, x, a, b, s, key, scale=None):
        from repro.kernels import ops

        c1, c2, sc = self.ds_quant_codes(a, s, key, scale=scale)
        return ops.ds_gradient_from_codes(c1, c2, x, b, sc, s)


register(_RefBackend())
register(_PallasBackend())


def resolve(backend: "str | KernelBackend | None") -> KernelBackend:
    """Accept a name, an instance, or None (→ active/env/hardware default)."""
    if isinstance(backend, KernelBackend):
        return backend
    return get(backend)
