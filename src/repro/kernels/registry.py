"""Kernel-backend registry — one switch for every hot quantization path.

The ZipML hot loop (double-sample quantization + the LSQ gradient built from
it) has two implementations:

* ``ref``    — the pure-jnp path of core/quantize.py: two independent
  full-precision quantization passes. Bit-exact with the original seed
  numerics; the ground truth every other backend is tested against.
* ``pallas`` — the fused pipeline: kernels/stoch_quant.ds_quant emits both
  Q₁/Q₂ int8 code planes in a single HBM read (shared base level + two
  up/down bits, the paper's "1 extra bit, not 2×" storage claim), and
  kernels/qmm.qmv computes q₁ᵀ(q₂x − b) straight from codes+scales without
  ever materializing a dequantized f32 sample tensor.

Selection precedence: explicit ``backend=`` argument > ``select()`` >
``ZIPML_KERNEL_BACKEND`` env var > default per ``jax.default_backend()``
(pallas on TPU, ref elsewhere — interpret-mode Pallas is correctness-only on
CPU). Resolution happens at Python trace time, so the choice is static under
``jax.jit``/``lax.scan``.
"""
from __future__ import annotations

import contextlib
import os

import jax
import jax.numpy as jnp

_BACKENDS: dict[str, "KernelBackend"] = {}
_ACTIVE: str | None = None

ENV_VAR = "ZIPML_KERNEL_BACKEND"
INTERPRET_ENV = "ZIPML_PALLAS_INTERPRET"


def interpret_default() -> bool:
    """THE one place deciding Pallas interpret mode: real compile on TPU,
    interpret elsewhere (CPU CI) or when ``ZIPML_PALLAS_INTERPRET=1`` forces
    it. Kernel entry points default ``interpret=None`` and resolve here —
    a caller can no longer silently run interpret-mode Pallas in a hot loop
    because a default said ``True``."""
    env = os.environ.get(INTERPRET_ENV)
    if env is not None:
        return env.lower() not in ("0", "false", "")
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """``None`` → :func:`interpret_default`; kernels call this at entry."""
    return interpret_default() if interpret is None else interpret


# hand-picked block defaults per kernel op — the end of the
# ``block=None → autotune-cache lookup → default`` resolution chain
BLOCK_DEFAULTS = {
    "qmm": {"bm": 256, "bk": 512, "bn": 256},
    "qmm_bitplane": {"bm": 256, "bk": 512, "bn": 256},
    "qmm_t": {"bm": 256, "bk": 256, "bn": 512},
    "qmm_qout": {"bm": 256, "bk": 512},
    "qmv": {"br": 256, "bc": 512},
    "ds_quant": {"br": 256, "bc": 512},
    "quant_adamw": {"br": 256, "bc": 512},
}


def dtype_key(dt) -> str:
    """Canonical short dtype tag for autotune-cache keys (f32/bf16/int8/…)."""
    name = jnp.dtype(dt).name
    return {"float32": "f32", "bfloat16": "bf16", "float16": "f16"}.get(
        name, name)


def fit_block(want: int, dim: int) -> int:
    """Clamp a wanted block size to one that tiles ``dim`` exactly.

    Partial blocks on a *contraction* grid axis read out of bounds and fold
    garbage into valid outputs, so every resolved block must divide its dim:
    min(want, dim) when that divides; else 128 (every ops.py entry point
    pads to 128 multiples); else the dim itself (one exact block).
    """
    b = min(want, dim)
    if dim % b == 0:
        return b
    return 128 if dim % 128 == 0 else dim


def resolve_block(op: str, dims: dict[str, int], *, dtype: str = "f32",
                  explicit: dict | None = None) -> tuple[int, ...]:
    """THE block-shape resolution path every Pallas kernel entry runs.

    ``dims`` maps block-arg names to the actual tensor dims (``{"bm": m,
    "bk": k, "bn": n}``). Per axis: an explicitly-passed value wins; else
    the autotune cache (repro.perf.autotune — keyed by hardware fingerprint
    and the power-of-two shape bucket); else the hand-picked default from
    :data:`BLOCK_DEFAULTS`. Everything is then fitted via :func:`fit_block`.
    Resolution happens at trace time, so the choice is static under jit —
    re-tuning requires ``jax.clear_caches()`` to take effect on shapes
    already traced with ``block=None``.
    """
    want = dict(BLOCK_DEFAULTS[op])
    explicit = {k: v for k, v in (explicit or {}).items() if v is not None}
    if len(explicit) < len(want):        # any axis left to resolve?
        from repro.perf import autotune

        hit = autotune.lookup(op, dtype,
                              {k.lstrip("b"): v for k, v in dims.items()})
        if hit:
            want.update({k: int(v) for k, v in hit.items() if k in want})
    want.update(explicit)
    return tuple(fit_block(want[k], dims[k]) for k in dims)


def matmul_eq(x_ndim: int, w_ndim: int, transpose: bool = False) -> str:
    """The einsum equation of the ``quant_dense`` op family.

    w: (*stack, K, N); x: (*lead, *stack, M, K) — the stack dims (e.g. the
    MoE expert axis) ride on both operands, extra leading x dims broadcast.
    ``transpose`` contracts against Wᵀ: x (*lead, *stack, M, N) → (..., K)
    (the code-domain backward, and the tied-unembed forward).
    """
    s = w_ndim - 2
    stack = "abcdefg"[:s]
    if s and x_ndim < s + 2:
        raise ValueError(f"x needs ≥ {s + 2} dims for {s} stack dims")
    if s == 0:
        return "...k,nk->...n" if transpose else "...k,kn->...n"
    x_lbl = f"...{stack}mn" if transpose else f"...{stack}mk"
    out = f"...{stack}mk" if transpose else f"...{stack}mn"
    return f"{x_lbl},{stack}kn->{out}"


class KernelBackend:
    """Interface of a quantization kernel backend.

    The QTensor entry points — ``encode``/``decode``/``ds_pair``/``qt_dot`` —
    are what :mod:`repro.quant` dispatches through; the base class provides
    the pure-jnp reference implementations, so a backend only overrides the
    paths it fuses.

    The lower-level tuple API remains for the hot LSQ loop:
    ``ds_quant_values`` returns the two dequantized draws (the numerical form
    the gradient math is written in); ``ds_quant_codes`` the storage form
    (codes1, codes2, scale); ``lsq_ds_gradient`` the symmetrized §2.2
    estimator ½[Q₁ᵀ(Q₂x−b) + Q₂ᵀ(Q₁x−b)]/B.
    """

    name: str = "abstract"

    # ---------------------------------------------------- QTensor surface --
    def encode(self, x, scheme, key=None, scale=None, levels=None):
        """Quantize ``x`` under ``scheme`` → QTensor (single plane)."""
        from repro.quant.qtensor import encode_jnp

        return encode_jnp(x, scheme, key, scale=scale, levels=levels)

    def decode(self, qt, dtype=None):
        return qt.decode(dtype)

    def ds_pair(self, x, scheme, key, scale=None):
        """Draw the §2.2 double-sampling pair → QTensor with ``codes2``."""
        from repro.quant.qtensor import ds_pair_jnp

        return ds_pair_jnp(x, scheme, key, scale=scale)

    def qt_dot(self, qt, v):
        """decode(qt) @ v; backends may stream codes instead."""
        return qt.decode() @ v

    def quant_dense(self, x, qt, *, transpose: bool = False):
        """The quantized-matmul op family: y = x · decode(qt) (or · ᵀ) with
        fp32 accumulation, f32 result (callers cast). The base implementation
        is decode-then-einsum at bf16 — bit-exact with the pre-op model
        numerics of ``layers.dense`` / ``moe`` — and handles every grid
        (int / zipml / levels / packed int4) and stacked (*S, K, N) weights.
        Backends may stream the codes instead of materializing the weight."""
        w = qt.decode(jnp.bfloat16)
        return jnp.einsum(matmul_eq(jnp.ndim(x), w.ndim, transpose), x, w,
                          preferred_element_type=jnp.float32)

    def quant_dense_out_q(self, x, qt, key, *, bits: int = 8,
                          out_dtype=None):
        """``quant_dense`` with a fused quantize epilogue: returns the §2.2
        double-sampled row-scaled int-grid pair of the output activation as a
        QTensor (codes + codes2 + (…, 1) row scales) instead of the dense y —
        what a quantized activation consumer (precision/act_quant) stores.

        Base implementation: einsum → cast to the activation dtype → the
        reference ds_pair draw. The Pallas backend emits both code planes
        straight from the fp32 accumulator tile in VMEM, so the full-width
        activation never reaches HBM (kernels/qmm.qmm_qout)."""
        from repro.quant.qtensor import ds_pair_jnp
        from repro.quant.scheme import QScheme

        dtype = out_dtype or (x.dtype if jnp.issubdtype(x.dtype, jnp.floating)
                              else jnp.float32)
        y = self.quant_dense(x, qt).astype(dtype)
        scheme = QScheme.int_symmetric(bits, scaling="row", rounding="ds")
        return ds_pair_jnp(y, scheme, key)

    def paged_attention(self, q, k_pages, v_pages, k_scale, v_scale,
                        block_table, seq_lens, *, softmax_scale):
        """Decode attention of q (B, H, D) against a paged, possibly
        quantized KV pool (repro/serve/pages.py layout). The base
        implementation gathers pages through the block table and reuses the
        legacy masked-softmax decode — bit-exact with the ring-buffer cache;
        backends may stream codes page-by-page instead."""
        from repro.kernels import ref

        return ref.paged_attention_ref(
            q, k_pages, v_pages, k_scale, v_scale, block_table, seq_lens,
            softmax_scale=softmax_scale)

    def quant_adamw_update(self, p_master, g, m_old, v_old, km, kv, *,
                           bits: int, b1: float, b2: float, eps: float,
                           b1c, b2c, lr, clip, finite, wd: float,
                           uclip: float = 0.0):
        """One quantized-moment AdamW leaf update: decode int8 m/v QTensors,
        EMA-update, write the fp32 master, stochastically re-encode.
        ``uclip`` bounds the per-coordinate |update| (the √v-underflow guard
        — see AdamWConfig.update_clip).

        The base implementation is the pure-jnp seed numerics (three
        full-tensor passes); the Pallas backend fuses them into the two-pass
        VMEM pipeline of kernels/quant_adamw.py. Returns
        (new_master, new_m: QTensor, new_v: QTensor).
        """
        from repro.optim.adamw import decode_moment, encode_moment

        g32 = g.astype(jnp.float32) * clip
        m_prev = decode_moment(m_old)
        v_prev = decode_moment(v_old, positive=True)
        m = b1 * m_prev + (1 - b1) * g32
        v = b2 * v_prev + (1 - b2) * g32 * g32
        update = (m / b1c) / (jnp.sqrt(v / b2c) + eps)
        if uclip:
            update = jnp.clip(update, -uclip, uclip)
        new_master = p_master - lr * (update + wd * p_master)
        new_master = jnp.where(finite, new_master, p_master)
        m_q = encode_moment(jnp.where(finite, m, m_prev), bits, km)
        v_q = encode_moment(jnp.where(finite, v, v_prev), bits, kv,
                            positive=True)
        return new_master, m_q, v_q

    # ------------------------------------------------- tuple-form hot loop --
    def ds_quant_values(self, a, s, key, scale=None):
        raise NotImplementedError

    def ds_quant_codes(self, a, s, key, scale=None):
        raise NotImplementedError

    def lsq_ds_gradient(self, x, a, b, s, key, scale=None):
        raise NotImplementedError


def register(backend: KernelBackend) -> KernelBackend:
    _BACKENDS[backend.name] = backend
    return backend


def available() -> list[str]:
    return sorted(_BACKENDS)


def default_name() -> str:
    """pallas where it compiles (TPU); ref everywhere else."""
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def select(name: str | None) -> None:
    """Set the process-wide backend (None resets to env/hardware default)."""
    global _ACTIVE
    if name is not None and name not in _BACKENDS:
        raise ValueError(f"unknown kernel backend {name!r}; have {available()}")
    _ACTIVE = name


@contextlib.contextmanager
def using(name: str | None):
    """Temporarily select a backend; the previous selection is restored on
    exit (``None`` selects nothing and just yields the resolved backend)."""
    global _ACTIVE
    prev = _ACTIVE
    if name is not None:
        select(name)
    try:
        yield get()
    finally:
        _ACTIVE = prev


def get(name: str | None = None) -> KernelBackend:
    name = name or _ACTIVE or os.environ.get(ENV_VAR) or default_name()
    if name not in _BACKENDS:
        raise ValueError(f"unknown kernel backend {name!r}; have {available()}")
    return _BACKENDS[name]


class _RefBackend(KernelBackend):
    """Two independent core/quantize.py passes — the seed's exact numerics."""

    name = "ref"

    def _zipml_pair(self, a, s, key, scale=None):
        from repro.quant.qtensor import ds_pair_jnp
        from repro.quant.scheme import QScheme

        return ds_pair_jnp(a, QScheme.zipml(s), key, scale=scale)

    def ds_quant_values(self, a, s, key, scale=None):
        qt = self._zipml_pair(a, s, key, scale=scale)
        return qt.decode(), qt.decode2()

    def ds_quant_codes(self, a, s, key, scale=None):
        qt = self._zipml_pair(a, s, key, scale=scale)
        return qt.codes, qt.codes2, qt.scale

    def lsq_ds_gradient(self, x, a, b, s, key, scale=None):
        q1, q2 = self.ds_quant_values(a, s, key, scale=scale)
        B = a.shape[0]
        r2 = q2 @ x - b
        r1 = q1 @ x - b
        return (q1.T @ r2 + q2.T @ r1) / (2.0 * B)


class _PallasBackend(KernelBackend):
    """Fused ds_quant + int8-codes matvecs (kernels/stoch_quant, kernels/qmm).

    ``scale=None`` resolves to the same global-scalar absmax the ref backend
    uses (core/quantize.row_scale), so the two backends quantize against
    identical grids; column scales — the data-pipeline convention — pass
    through. Per-row scales are not used here: they don't factor through
    q₁ᵀ(q₂x − b), which ds_gradient_from_codes relies on.
    """

    name = "pallas"

    def _resolve_scale(self, a, scale):
        if scale is None:
            from repro.core.quantize import row_scale

            return row_scale(a)  # scalar global absmax, as in ref
        return scale

    def ds_quant_values(self, a, s, key, scale=None):
        c1, c2, sc = self.ds_quant_codes(a, s, key, scale=scale)
        return (c1.astype(jnp.float32) / s * sc,
                c2.astype(jnp.float32) / s * sc)

    def ds_quant_codes(self, a, s, key, scale=None):
        from repro.kernels import ops

        return ops.ds_quantize(a, s, key, scale=self._resolve_scale(a, scale))

    def lsq_ds_gradient(self, x, a, b, s, key, scale=None):
        from repro.kernels import ops

        c1, c2, sc = self.ds_quant_codes(a, s, key, scale=scale)
        return ops.ds_gradient_from_codes(c1, c2, x, b, sc, s)

    # ---------------------------------------------------- QTensor surface --
    def ds_pair(self, x, scheme, key, scale=None):
        """Fused single-read pair draw for the 2-D zipml grid; everything
        else falls back to the reference implementation."""
        from repro.quant.qtensor import QTensor, compute_scale

        if scheme.grid != "zipml" or x.ndim != 2 or not scheme.signed \
                or scheme.s > 127:
            return KernelBackend.ds_pair(self, x, scheme, key, scale=scale)
        from repro.kernels import ops

        if scale is None:
            scale = compute_scale(x, scheme)
        scale = jnp.asarray(scale, jnp.float32)
        c1, c2, _ = ops.ds_quantize(x, scheme.s, key, scale=scale)
        # store the caller's scale, not the kernel's broadcast copy — ref and
        # pallas QTensors stay structurally identical (same nbytes, stackable,
        # checkpoint-compatible)
        return QTensor(c1, scale, scheme.with_rounding("ds"), codes2=c2)

    def paged_attention(self, q, k_pages, v_pages, k_scale, v_scale,
                        block_table, seq_lens, *, softmax_scale):
        """Fused paged flash-decode: block-table-indexed page DMA (scalar
        prefetch) + in-VMEM int8/int4 dequant (kernels/paged_attn.py)."""
        from repro.kernels import ops

        return ops.paged_attention(
            q, k_pages, v_pages, k_scale, v_scale, block_table, seq_lens,
            softmax_scale=softmax_scale)

    def quant_adamw_update(self, p_master, g, m_old, v_old, km, kv, *,
                           bits: int, b1: float, b2: float, eps: float,
                           b1c, b2c, lr, clip, finite, wd: float,
                           uclip: float = 0.0):
        """Fused decode→update→re-encode (kernels/quant_adamw.py): the fp32
        moments never round-trip HBM. 2-D+ leaves only (vectors/scalars fall
        back to the jnp path — sub-tile shapes aren't worth a kernel launch);
        rounding bits come from the high/low 16 bits of one uint32 plane
        drawn from ``km`` (distribution-identical to the ref backend's two
        key-based draws, pinned by tests/test_quant_adamw.py)."""
        if p_master.ndim < 2 or bits > 8 or km is None:
            return KernelBackend.quant_adamw_update(
                self, p_master, g, m_old, v_old, km, kv, bits=bits, b1=b1,
                b2=b2, eps=eps, b1c=b1c, b2c=b2c, lr=lr, clip=clip,
                finite=finite, wd=wd, uclip=uclip)
        from repro.kernels import ops
        from repro.optim.adamw import moment_scheme
        from repro.quant.qtensor import QTensor

        shape = p_master.shape
        c = shape[-1]
        rand = jax.random.bits(km, shape, jnp.uint32).reshape(-1, c)
        nm, mc, msn, vc, vsn = ops.quant_adamw_update(
            p_master.astype(jnp.float32).reshape(-1, c),
            g.astype(jnp.float32).reshape(-1, c),
            m_old.codes.reshape(-1, c), m_old.scale,
            v_old.codes.reshape(-1, c), v_old.scale, rand,
            qmax=2 ** (bits - 1) - 1, b1=b1, b2=b2, eps=eps, wd=wd,
            uclip=uclip, lr=lr, b1c=b1c, b2c=b2c, clip=clip,
            finite=finite.astype(jnp.float32))
        scheme = moment_scheme(bits, len(shape))
        return (nm.reshape(shape),
                QTensor(mc.reshape(shape), msn, scheme),
                QTensor(vc.reshape(shape), vsn, scheme))

    # ------------------------------------------------- quant_dense family --
    def _qd_plan(self, qt):
        """Kernel-ready (codes, scale (*S, 1, N), packed) for the fused GEMM,
        or None when the storage needs the decode fallback (level tables,
        wide codes, per-row weight scales)."""
        sch = qt.scheme
        if sch.grid == "levels":
            return None
        packed = bool(sch.packed)
        codes = qt.codes
        if codes.dtype != (jnp.uint8 if packed else jnp.int8):
            return None
        stack = codes.shape[:-2]
        n = codes.shape[-1] * (2 if packed else 1)
        scale = jnp.asarray(qt.scale, jnp.float32)
        shp = scale.shape
        if shp in ((), (1,), (1, 1)):
            scale = jnp.broadcast_to(scale.reshape((1,) * (len(stack) + 2)),
                                     (*stack, 1, n))
        elif shp == (n,):
            scale = jnp.broadcast_to(scale.reshape(1, n), (*stack, 1, n))
        elif shp != (*stack, 1, n):
            return None
        if sch.grid == "zipml":
            scale = scale / sch.s
        return codes, scale, packed

    def _bitplane_scale(self, qt):
        """Kernel-ready (1, N) scale for a 2-D bitplane weight, or None when
        the scaling family needs the decode fallback (per-row scales don't
        broadcast over the GEMM's N axis)."""
        n = qt.scheme.vec_dim
        scale = jnp.asarray(qt.scale, jnp.float32)
        shp = scale.shape
        if shp in ((), (1,), (1, 1)):
            return jnp.broadcast_to(scale.reshape(1, 1), (1, n))
        if shp == (n,):
            return scale.reshape(1, n)
        if shp == (1, n):
            return scale
        return None

    def quant_dense(self, x, qt, *, transpose: bool = False):
        """Stream the code plane through the fused dequant-GEMM kernels
        (kernels/qmm.qmm / qmm_t): int8 moves ~2× fewer HBM bytes than the
        bf16 decode path, packed int4 ~4×, bitplane (k+1)/16ths
        (kernels/qmm_bitplane — only the sliced planes move). Stacked
        (S, K, N) weights (the MoE expert axis) run one kernel launch per
        slice — S is small and static."""
        if qt.scheme.layout == "bitplane":
            scale = None if transpose or qt.codes.ndim != 3 \
                else self._bitplane_scale(qt)
            if scale is None:
                # transpose / stacked / per-row scales → bf16 decode fallback
                return KernelBackend.quant_dense(self, x, qt,
                                                 transpose=transpose)
            from repro.kernels import ops

            return ops.quant_dense_bitplane(x, qt.codes, scale,
                                            qt.scheme.vec_dim)
        plan = self._qd_plan(qt)
        if plan is None or qt.ndim > 3:
            return KernelBackend.quant_dense(self, x, qt, transpose=transpose)
        codes, scale, packed = plan
        from repro.kernels import ops

        if qt.ndim == 2:
            return ops.quant_dense_apply(x, codes, scale, packed=packed,
                                         transpose=transpose)
        xs = jnp.moveaxis(x, x.ndim - 3, 0)       # stack dim sits at -3
        outs = [ops.quant_dense_apply(xs[i], codes[i], scale[i],
                                      packed=packed, transpose=transpose)
                for i in range(codes.shape[0])]
        return jnp.moveaxis(jnp.stack(outs), 0, x.ndim - 3)

    def quant_dense_out_q(self, x, qt, key, *, bits: int = 8,
                          out_dtype=None):
        """Fused quantize epilogue (kernels/qmm.qmm_qout): the §2.2 DS pair
        of the output is emitted from the fp32 accumulator tile in VMEM —
        rounding bits from the hi/lo 16 bits of one uint32 plane, exactly
        the kernels/stoch_quant.ds_quant convention (distribution-identical
        to the ref backend's split-key draws, pinned by tests)."""
        plan = self._qd_plan(qt)
        if plan is None or qt.ndim != 2 or bits > 8:
            return KernelBackend.quant_dense_out_q(self, x, qt, key,
                                                   bits=bits,
                                                   out_dtype=out_dtype)
        codes, scale, packed = plan
        from repro.kernels import ops
        from repro.quant.qtensor import QTensor
        from repro.quant.scheme import QScheme

        dtype = out_dtype or (x.dtype if jnp.issubdtype(x.dtype, jnp.floating)
                              else jnp.float32)
        lead = x.shape[:-1]
        n = codes.shape[-1] * (2 if packed else 1)
        x2 = x.reshape(-1, x.shape[-1])
        rand = jax.random.bits(key, (x2.shape[0], n), jnp.uint32)
        c1, c2, oscale = ops.quant_dense_out_q(
            x2, codes, scale, rand, qmax=2 ** (bits - 1) - 1, packed=packed,
            out_dtype=dtype)
        scheme = QScheme.int_symmetric(bits, scaling="row", rounding="ds")
        return QTensor(c1.reshape(*lead, n), oscale.reshape(*lead, 1),
                       scheme, codes2=c2.reshape(*lead, n))

    def qt_dot(self, qt, v):
        """Stream int8 codes through the qmv kernel when the scale factors
        out of the product (scalar / per-row / per-column families)."""
        codes, scale = qt.codes, qt.scale
        if (codes.ndim != 2 or jnp.ndim(v) != 1 or codes.dtype != jnp.int8
                or qt.scheme.grid == "levels"):
            return qt.decode() @ v
        from repro.kernels import ops

        denom = float(qt.scheme.s) if qt.scheme.grid == "zipml" else 1.0
        r, c = codes.shape
        shp = jnp.shape(scale)
        v32 = jnp.asarray(v, jnp.float32)
        if shp in ((), (1,), (1, 1)):
            return ops.int8_matvec(codes, v32) * (jnp.reshape(scale, ()) / denom)
        if shp == (r, 1):
            return scale.ravel() * ops.int8_matvec(codes, v32) / denom
        if shp in ((c,), (1, c)):
            return ops.int8_matvec(codes, jnp.ravel(scale) * v32) / denom
        return qt.decode() @ v


register(_RefBackend())
register(_PallasBackend())


def resolve(backend: "str | KernelBackend | None") -> KernelBackend:
    """Accept a name, an instance, or None (→ active/env/hardware default)."""
    if isinstance(backend, KernelBackend):
        return backend
    return get(backend)
