"""Pallas TPU kernel: bit-plane (MLWeaving) dequant-GEMM.

``y[M, N] = x[M, K] · decode(planes[P, K, W] ⊙ scale[1, N])``

The weight is stored bit-serially (``repro.quant.pack_bitplanes``): plane 0
is the sign, planes 1..k the magnitude MSB-first, each plane packing 32
consecutive N-elements per uint32 word (W = N/32). The kernel streams ONLY
the planes present in the operand HBM→VMEM — the block carries the full
plane axis, which is tiny (≤ 9) — so serving a ``slice_planes(k)`` view
moves (k+1)/(B+1) of the full artifact's code bytes with zero repacking.

Codes are reconstructed in-register: a broadcast shift+mask unpack (the
word→bit expansion is a contiguous reshape, never a stride interleave), a
plane-weighted sum for the magnitude, then ``sign · mag · 2^-k · scale``.
The reconstruction is value-identical to ``QTensor.decode()`` of the same
planes (integers < 2^8 are exact in f32 and the plane weights are powers of
two; pinned by tests/test_bitplane.py), so parity vs the ref backend is
bounded by the usual bf16-decode epsilon.

Blocking mirrors ``qmm``: (bm, bk)×(bk, bn) with the contraction as the
sequential minor grid axis and an fp32 accumulator tile; ``bn`` must be a
multiple of 32 (whole words). ``bm/bk/bn=None`` resolve through
``registry.resolve_block``; ops.quant_dense_bitplane is the padded entry.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import registry


def _qmm_bitplane_kernel(x_ref, w_ref, scale_ref, o_ref, *, k_bits: int):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    words = w_ref[...]                            # (P, bk, bn/32) uint32
    p, bk, bw = words.shape
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 1, 32), 3)
    bits = ((words[..., None] >> shifts) & jnp.uint32(1)).astype(jnp.float32)
    bits = bits.reshape(p, bk, bw * 32)           # contiguous: bit j of word
    sign = 1.0 - 2.0 * bits[0]                    # w is element 32·w + j
    mag = jnp.zeros_like(sign)
    for i in range(k_bits):                       # static: k_bits ≤ 8 planes
        mag = mag + bits[1 + i] * (2.0 ** (k_bits - 1 - i))
    w = sign * mag * (2.0 ** -k_bits) * scale_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                          preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def qmm_bitplane(x: jax.Array, planes: jax.Array, scale: jax.Array, *,
                 bm: int | None = None, bk: int | None = None,
                 bn: int | None = None,
                 interpret: bool | None = None) -> jax.Array:
    """x: (M, K) bf16/f32 · bitplane codes (P, K, N/32) uint32 with scale
    (1, N) → (M, N) f32. P = k_bits + 1 (sign plane first).

    Bytes streamed for the weight are K·N·P/8 — linear in the requested
    planes. ``bm/bk/bn=None`` resolve through registry.resolve_block; use
    ops.quant_dense_bitplane for the padded general entry point.
    """
    interpret = registry.resolve_interpret(interpret)
    m, k = x.shape
    p, k2, w = planes.shape
    n = w * 32
    assert k == k2, (x.shape, planes.shape)
    assert scale.shape == (1, n), (scale.shape, n)
    bm, bk, bn = registry.resolve_block(
        "qmm_bitplane", {"bm": m, "bk": k, "bn": n}, dtype="uint32",
        explicit={"bm": bm, "bk": bk, "bn": bn})
    assert bn % 32 == 0, bn
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk))
    return pl.pallas_call(
        functools.partial(_qmm_bitplane_kernel, k_bits=p - 1),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((p, bk, bn // 32), lambda i, j, kk: (0, kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, planes, scale)
