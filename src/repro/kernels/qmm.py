"""Pallas TPU kernels: fused dequantize-matmul over quantized code planes.

The ZipML weight channel stores W as integer codes + fp32 scales
(int8, or nibble-packed int4 — two offset-binary codes per byte). These
kernels stream the code blocks HBM→VMEM (2×/4× fewer bytes than bf16 — the
memory-roofline win), dequantize in VMEM, and feed the MXU with fp32
accumulation. Three shapes cover the whole model path:

* ``qmm``   — forward  ``y[M, N] = x[M, K] · (codes[K, N] ⊙ scale[1, N])``
* ``qmm_t`` — transpose ``dx[M, K] = dy[M, N] · (codes[K, N] ⊙ scale)ᵀ`` —
  the code-domain backward (HALP's point: the bwd must stay low-precision
  too, or the bandwidth win evaporates). Also the tied-readout forward
  ``logits = h · tableᵀ``.
* ``qmm_qout`` — forward with a fused **quantize epilogue**: when the
  consumer is a quantized activation channel, the §2.2 double-sampling pair
  (row scales + both int8 code planes) is emitted straight from the fp32
  accumulator tile in VMEM — the full-width activation never touches HBM
  (mirrors kernels/stoch_quant.ds_quant, but fused at the matmul output).

Blocking: (bm, bk)×(bk, bn); ``bm/bk/bn=None`` resolve through
``registry.resolve_block`` — autotune-cache winner per (op, dtype,
shape-bucket) when repro.perf.autotune has tuned this hardware, else the
hand-picked defaults (bm=bn=256, bk=512 → VMEM working set bm·bk·2 +
bk·bn·1 + bm·bn·4 ≈ 0.6 MiB). The contraction axis is the sequential minor
grid axis so the fp32 accumulator tile lives across its loop. All dims
padded to multiples of 128 by the caller (ops.py) — MXU-aligned; resolved
blocks are fitted so every grid axis tiles its dim exactly. ``qmm_qout``
holds a (bm, N) accumulator (N unblocked), so its VMEM bound is
bm·N·(4+4+2·1) bytes — callers cap bm accordingly.

``interpret=None`` resolves through :func:`repro.kernels.registry.
interpret_default` — the ONE place deciding real-compile vs interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import registry


def _dequant_block(w, scale, *, packed: bool):
    """codes block (bk, bn[/2]) + scale (1, bn) → dequantized (bk, bn) f32.

    Dequantizes at full f32 precision in VMEM — the same values as
    ``QTensor.decode()`` (f32 default), i.e. strictly *more* accurate than
    the ref backend's bf16 decode-then-einsum, whose decoded weight carries
    one bf16 rounding. Parity vs ref is therefore bounded by bf16 epsilon;
    the code-domain gradient matches the f32 decode path to f32-accumulation
    associativity (≤ 1e-5 rel — the bench CHECK). Packed int4 planes unpack
    through the canonical :func:`repro.quant.unpack_int4` (pure jnp — traces
    inside the kernel body)."""
    if packed:
        from repro.quant import unpack_int4

        x = unpack_int4(w)
    else:
        x = w.astype(jnp.float32)
    return x * scale.astype(jnp.float32)


def _qmm_kernel(x_ref, w_ref, scale_ref, o_ref, *, packed: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    w = _dequant_block(w_ref[...], scale_ref[...], packed=packed)
    o_ref[...] += jnp.dot(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)


def _qmm_t_kernel(g_ref, w_ref, scale_ref, o_ref, *, packed: bool):
    n = pl.program_id(2)

    @pl.when(n == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    g = g_ref[...]
    w = _dequant_block(w_ref[...], scale_ref[...], packed=packed)
    # dx[bm, bk] += dy[bm, bn] · w[bk, bn]ᵀ — contraction over the N axis
    o_ref[...] += jax.lax.dot_general(
        g.astype(jnp.float32), w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)


def _qmv_kernel(c_ref, v_ref, o_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    c = c_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(c, v_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("br", "bc", "interpret"))
def qmv(codes: jax.Array, v: jax.Array, *, br: int | None = None,
        bc: int | None = None, interpret: bool | None = None) -> jax.Array:
    """int8 codes (R, C) · f32 v (C, 1) → (R, 1) f32, fp32 accumulation.

    The double-sampling gradient q₁ᵀ(q₂x − b) reduces to two of these matvecs
    on raw code planes (scales factor out), so the samples stream HBM→VMEM as
    int8 — 4× fewer bytes than the dequantized-f32 two-pass path.
    ``br/bc=None`` resolve through registry.resolve_block (autotune cache →
    hand-picked default, fitted to the dims); ops.int8_matvec is the padded
    entry point.
    """
    interpret = registry.resolve_interpret(interpret)
    r, c = codes.shape
    br, bc = registry.resolve_block("qmv", {"br": r, "bc": c}, dtype="int8",
                                   explicit={"br": br, "bc": bc})
    grid = (pl.cdiv(r, br), pl.cdiv(c, bc))
    return pl.pallas_call(
        _qmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, k: (i, k)),
            pl.BlockSpec((bc, 1), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((br, 1), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, 1), jnp.float32),
        interpret=interpret,
    )(codes, v)


@functools.partial(jax.jit,
                   static_argnames=("packed", "bm", "bk", "bn", "interpret"))
def qmm(x: jax.Array, codes: jax.Array, scale: jax.Array, *,
        packed: bool = False, bm: int | None = None, bk: int | None = None,
        bn: int | None = None, interpret: bool | None = None) -> jax.Array:
    """x: (M, K) bf16/f32 · codes (K, N) int8 [or (K, N/2) packed-int4 uint8]
    with scale (1, N) → (M, N) f32.

    ``bm/bk/bn=None`` resolve through registry.resolve_block (autotune cache
    → hand-picked default), fitted so every grid axis tiles its dim exactly —
    use ops.quant_dense_apply for the padded general entry point.
    """
    interpret = registry.resolve_interpret(interpret)
    m, k = x.shape
    k2, n = codes.shape
    if packed:
        n *= 2
    assert k == k2, (x.shape, codes.shape)
    assert scale.shape == (1, n), (scale.shape, n)
    bm, bk, bn = registry.resolve_block(
        "qmm", {"bm": m, "bk": k, "bn": n},
        dtype="int4" if packed else "int8",
        explicit={"bm": bm, "bk": bk, "bn": bn})
    pdiv = 2 if packed else 1
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk))
    return pl.pallas_call(
        functools.partial(_qmm_kernel, packed=packed),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn // pdiv), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, codes, scale)


@functools.partial(jax.jit,
                   static_argnames=("packed", "bm", "bk", "bn", "interpret"))
def qmm_t(g: jax.Array, codes: jax.Array, scale: jax.Array, *,
          packed: bool = False, bm: int | None = None, bk: int | None = None,
          bn: int | None = None, interpret: bool | None = None) -> jax.Array:
    """g: (M, N) · codes (K, N) [or (K, N/2) packed] with scale (1, N)
    → (M, K) f32: the transpose product ``g · (codes ⊙ scale)ᵀ``.

    This is the code-domain backward of ``qmm`` (dx streams int8 HBM→VMEM
    instead of re-decoding a bf16 weight) and the tied-unembed forward
    (logits = h · tableᵀ). Contraction runs over N as the sequential minor
    grid axis; ``bm/bk/bn=None`` resolve through registry.resolve_block —
    see ops.quant_dense_apply for the padded entry point.
    """
    interpret = registry.resolve_interpret(interpret)
    m, n = g.shape
    k, n2 = codes.shape
    if packed:
        n2 *= 2
    assert n == n2, (g.shape, codes.shape)
    assert scale.shape == (1, n), (scale.shape, n)
    bm, bk, bn = registry.resolve_block(
        "qmm_t", {"bm": m, "bk": k, "bn": n},
        dtype="int4" if packed else "int8",
        explicit={"bm": bm, "bk": bk, "bn": bn})
    pdiv = 2 if packed else 1
    grid = (pl.cdiv(m, bm), pl.cdiv(k, bk), pl.cdiv(n, bn))
    return pl.pallas_call(
        functools.partial(_qmm_t_kernel, packed=packed),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, nn: (i, nn)),
            pl.BlockSpec((bk, bn // pdiv), lambda i, j, nn: (j, nn)),
            pl.BlockSpec((1, bn), lambda i, j, nn: (0, nn)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j, nn: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, k), jnp.float32),
        interpret=interpret,
    )(g, codes, scale)


def _qmm_qout_kernel(x_ref, w_ref, scale_ref, rand_ref, c1_ref, c2_ref,
                     os_ref, acc_ref, *, packed: bool, qmax: int,
                     out_dtype):
    """GEMM with fused double-sampling quantize epilogue.

    The (bm, N) fp32 accumulator lives in VMEM scratch across the K loop; at
    the last K step the row absmax → scale, and both §2.2 stochastic planes
    are emitted from the high/low 16 bits of one uint32 rand plane — the
    exact rounding convention of kernels/stoch_quant._ds_quant_kernel, so
    fused and unfused (qmm → ds row quantize) paths are bit-identical given
    the same rand bits. The full-width activation never reaches HBM.
    """
    kk = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    w = _dequant_block(w_ref[...], scale_ref[...], packed=packed)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(kk == nk - 1)
    def _epilogue():
        # quantize the dtype-rounded activation (bf16 in the model) so the
        # fused path matches einsum→astype(x.dtype)→quantize exactly
        y = acc_ref[...].astype(out_dtype).astype(jnp.float32)
        absmax = jnp.max(jnp.abs(y), axis=1, keepdims=True)       # (bm, 1)
        scale = jnp.where(absmax == 0, 1.0, absmax / qmax)
        t = y / scale
        base = jnp.floor(t)
        frac = t - base
        u = rand_ref[...]
        u1 = (u >> 16).astype(jnp.float32) * (1.0 / (1 << 16))
        u2 = (u & 0xFFFF).astype(jnp.float32) * (1.0 / (1 << 16))
        c1 = jnp.clip(base + (u1 < frac).astype(jnp.float32), -qmax, qmax)
        c2 = jnp.clip(base + (u2 < frac).astype(jnp.float32), -qmax, qmax)
        c1_ref[...] = c1.astype(jnp.int8)
        c2_ref[...] = c2.astype(jnp.int8)
        os_ref[...] = scale.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=(
    "packed", "qmax", "out_dtype", "bm", "bk", "interpret"))
def qmm_qout(x: jax.Array, codes: jax.Array, scale: jax.Array,
             rand: jax.Array, *, qmax: int, packed: bool = False,
             out_dtype=jnp.bfloat16, bm: int | None = None,
             bk: int | None = None, interpret: bool | None = None):
    """Fused ``y = x·dequant(codes)`` + double-sampled row quantization of y.

    x: (M, K); codes (K, N[/2]); scale (1, N); rand (M, N) uint32. Returns
    (codes1, codes2) int8 (M, N) and row scales (M, 1) f32 — the symmetric
    int-grid DS pair of y.astype(out_dtype), with y never written to HBM.
    N is unblocked (full-width accumulator row in VMEM); ``bm/bk=None``
    resolve through registry.resolve_block — ops.quant_dense_out_q is the
    padded entry point.
    """
    interpret = registry.resolve_interpret(interpret)
    m, k = x.shape
    k2, n = codes.shape
    if packed:
        n *= 2
    assert k == k2, (x.shape, codes.shape)
    assert scale.shape == (1, n) and rand.shape == (m, n)
    bm, bk = registry.resolve_block(
        "qmm_qout", {"bm": m, "bk": k},
        dtype="int4" if packed else "int8", explicit={"bm": bm, "bk": bk})
    pdiv = 2 if packed else 1
    grid = (pl.cdiv(m, bm), pl.cdiv(k, bk))
    out_block = pl.BlockSpec((bm, n), lambda i, kk: (i, 0))
    c1, c2, oscale = pl.pallas_call(
        functools.partial(_qmm_qout_kernel, packed=packed, qmax=qmax,
                          out_dtype=out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, kk: (i, kk)),
            pl.BlockSpec((bk, n // pdiv), lambda i, kk: (kk, 0)),
            pl.BlockSpec((1, n), lambda i, kk: (0, 0)),
            pl.BlockSpec((bm, n), lambda i, kk: (i, 0)),
        ],
        out_specs=[out_block, out_block,
                   pl.BlockSpec((bm, 1), lambda i, kk: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((m, n), jnp.int8),
                   jax.ShapeDtypeStruct((m, n), jnp.int8),
                   jax.ShapeDtypeStruct((m, 1), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bm, n), jnp.float32)],
        interpret=interpret,
    )(x, codes, scale, rand)
    return c1, c2, oscale
