"""Pallas TPU kernel: fused dequantize-matmul (int8 weights × bf16 acts).

The ZipML weight channel stores W as int8 codes + per-output-channel scales
(precision/qat.py). This kernel streams the int8 blocks HBM→VMEM (half the
bytes of bf16 — the memory-roofline win), dequantizes in VMEM, and feeds the
MXU with fp32 accumulation:

    y[M, N] = x[M, K] · (codes[K, N] ⊙ scale[1, N])

Blocking: (bm, bk)×(bk, bn) with bm=bn=256, bk=512 → VMEM working set
bm·bk·2 + bk·bn·1 + bm·bn·4 ≈ 0.6 MiB; K is the sequential grid axis so the
fp32 accumulator tile lives across the K loop. All dims padded to multiples
of 128 by the caller (ops.py) — MXU-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _qmm_kernel(x_ref, w_ref, scale_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    w = (w_ref[...].astype(jnp.float32)
         * scale_ref[...].astype(jnp.float32)).astype(x.dtype)
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)


def _qmv_kernel(c_ref, v_ref, o_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    c = c_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(c, v_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("br", "bc", "interpret"))
def qmv(codes: jax.Array, v: jax.Array, *, br: int = 256, bc: int = 512,
        interpret: bool = True) -> jax.Array:
    """int8 codes (R, C) · f32 v (C, 1) → (R, 1) f32, fp32 accumulation.

    The double-sampling gradient q₁ᵀ(q₂x − b) reduces to two of these matvecs
    on raw code planes (scales factor out), so the samples stream HBM→VMEM as
    int8 — 4× fewer bytes than the dequantized-f32 two-pass path. Dims must be
    block multiples; ops.int8_matvec is the padded entry point.
    """
    r, c = codes.shape
    br = min(br, r)
    bc = min(bc, c)
    grid = (pl.cdiv(r, br), pl.cdiv(c, bc))
    return pl.pallas_call(
        _qmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, k: (i, k)),
            pl.BlockSpec((bc, 1), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((br, 1), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, 1), jnp.float32),
        interpret=interpret,
    )(codes, v)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bk", "bn", "interpret"))
def qmm(x: jax.Array, codes: jax.Array, scale: jax.Array, *,
        bm: int = 256, bk: int = 512, bn: int = 256,
        interpret: bool = True) -> jax.Array:
    """x: (M, K) bf16/f32 · int8 codes (K, N) with scale (1, N) → (M, N) f32.

    Dims must be multiples of the block sizes' gcd with 128 — use
    ops.quantized_matmul for the padded general entry point.
    """
    m, k = x.shape
    k2, n = codes.shape
    assert k == k2, (x.shape, codes.shape)
    bm = min(bm, m)
    bk = min(bk, k)
    bn = min(bn, n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk))
    return pl.pallas_call(
        _qmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, codes, scale)
