"""jit'd public wrappers around the Pallas kernels (padding, reshaping,
interpret-mode selection). ``INTERPRET`` flips to False on real TPU backends.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import qmm as qmm_mod
from . import ssd as ssd_mod
from . import stoch_quant as sq_mod

INTERPRET = jax.default_backend() != "tpu"


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def quantize_rows(x: jax.Array, s: int, key: jax.Array):
    """Row-scaled stochastic quantization via the Pallas pipeline.

    x: (R, C) → (codes int8 in [-s, s], scale (R, 1) f32). Unbiased:
    E[codes/s·scale] = x.
    """
    assert x.ndim == 2
    scale = sq_mod.row_absmax(x, interpret=INTERPRET)
    rand = jax.random.bits(key, x.shape, jnp.uint32)
    codes = sq_mod.stoch_quant(x, rand, scale, s=s, interpret=INTERPRET)
    return codes, scale


def dequantize_rows(codes: jax.Array, scale: jax.Array, s: int) -> jax.Array:
    return codes.astype(jnp.float32) / s * scale


def quantized_matmul(x: jax.Array, codes: jax.Array, scale: jax.Array) -> jax.Array:
    """General y = x · dequant(codes, scale); pads all dims to 128 multiples
    for MXU alignment, slices the result back."""
    m0, k0 = x.shape
    _, n0 = codes.shape
    x, _ = _pad_to(x, 128, 0)
    x, _ = _pad_to(x, 128, 1)
    codes, _ = _pad_to(codes, 128, 0)
    codes, _ = _pad_to(codes, 128, 1)
    scale, _ = _pad_to(scale, 128, 1)
    y = qmm_mod.qmm(x, codes, scale, interpret=INTERPRET)
    return y[:m0, :n0]


def ssd_chunked_kernel(xh, dt, a_log, b_mat, c_mat, chunk: int = 256):
    """Drop-in for models/ssm.ssd_chunked using the Pallas intra-chunk kernel.

    xh: (B, S, H, P); dt: (B, S, H); b/c: (B, S, G·N) with G=1.
    Returns (y (B, S, H, P), state (B, H, P, N)).
    """
    b, s, h, p = xh.shape
    L = min(chunk, s)
    if s % L:
        L = s
    nc = s // L
    a = -jnp.exp(a_log)
    logdec = (dt * a[None, None, :]).astype(jnp.float32)

    def chunked(t):
        return t.reshape(b, nc, L, *t.shape[2:])

    y, state = ssd_mod.ssd_chunk_scan(
        chunked(xh), chunked(dt), chunked(logdec),
        chunked(b_mat), chunked(c_mat), interpret=INTERPRET)
    return y.reshape(b, s, h, p), state
