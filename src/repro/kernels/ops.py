"""jit'd public wrappers around the Pallas kernels (padding, reshaping).

Interpret-mode selection lives in ONE place — :func:`repro.kernels.registry.
interpret_default` (real compile on TPU, interpret on CPU/CI or under
``ZIPML_PALLAS_INTERPRET=1``); every kernel entry point defaults
``interpret=None`` and resolves there, so no caller can silently pin
interpret-mode Pallas into a hot loop.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import paged_attn as pa_mod
from . import qmm as qmm_mod
from . import qmm_bitplane as qbp_mod
from . import quant_adamw as qa_mod
from . import ssd as ssd_mod
from . import stoch_quant as sq_mod



def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def quantize_rows(x: jax.Array, s: int, key: jax.Array):
    """Row-scaled stochastic quantization via the Pallas pipeline.

    x: (R, C) → (codes int8 in [-s, s], scale (R, 1) f32). Unbiased:
    E[codes/s·scale] = x.
    """
    assert x.ndim == 2
    scale = sq_mod.row_absmax(x)
    rand = jax.random.bits(key, x.shape, jnp.uint32)
    codes = sq_mod.stoch_quant(x, rand, scale, s=s)
    return codes, scale


def dequantize_rows(codes: jax.Array, scale: jax.Array, s: int) -> jax.Array:
    return codes.astype(jnp.float32) / s * scale


def ds_quantize(x: jax.Array, s: int, key: jax.Array,
                scale: jax.Array | None = None):
    """Fused double-sampling quantization: both Q₁/Q₂ int8 code planes from a
    single streaming pass over x (paper §2.2 — shared base + 1 extra bit).

    ``scale=None`` → per-row absmax scales (R, 1); a (C,)/(1, C) array selects
    column scaling (the data-pipeline convention); a scalar broadcasts.
    Returns (codes1, codes2, scale) with E[codesᵢ/s·scale] = x.
    """
    assert x.ndim == 2
    r, c = x.shape
    if scale is None:
        scale = sq_mod.row_absmax(x)
        scale_axis = "row"
    elif jnp.shape(scale) == (r, 1):
        scale = jnp.asarray(scale, jnp.float32)
        scale_axis = "row"
    else:
        scale = jnp.broadcast_to(jnp.asarray(scale, jnp.float32).reshape(1, -1),
                                 (1, c))
        scale_axis = "col"
    rand = jax.random.bits(key, x.shape, jnp.uint32)
    c1, c2 = sq_mod.ds_quant(x, rand, scale, s=s, scale_axis=scale_axis)
    return c1, c2, scale


def int8_matvec(codes: jax.Array, v: jax.Array) -> jax.Array:
    """General r = codes · v for int8 (R, C) codes and f32 (C,) v; pads both
    dims to 128 multiples (zero padding is exact for the dot) and slices.
    Block shapes resolve inside the kernel (registry.resolve_block: autotune
    cache → hand-picked default, fitted so every grid axis tiles exactly —
    partial blocks on a contraction axis would fold garbage into outputs).
    """
    r0, c0 = codes.shape
    codes, _ = _pad_to(codes, 128, 0)
    codes, _ = _pad_to(codes, 128, 1)
    v2, _ = _pad_to(v.reshape(-1, 1).astype(jnp.float32), 128, 0)
    out = qmm_mod.qmv(codes, v2)
    return out[:r0, 0]


def ds_gradient_from_codes(codes1: jax.Array, codes2: jax.Array,
                           x: jax.Array, b: jax.Array, scale: jax.Array,
                           s: int) -> jax.Array:
    """Symmetrized double-sampling LSQ gradient ½[q₁ᵀr₂ + q₂ᵀr₁]/B straight
    from int8 code planes + scales — no dequantized f32 sample tensor exists.

    With column scale m (broadcast over rows), qᵢ = cᵢ ⊙ m / s, so
    qᵢᵀ(qⱼx − b) = m ⊙ (cᵢᵀ rⱼ)/s with rⱼ = cⱼ(m ⊙ x)/s − b: four int8
    matvecs total, all streaming codes at 1 byte/elem.
    """
    B = codes1.shape[0]
    m = jnp.asarray(scale, jnp.float32).reshape(-1)
    xs = x.astype(jnp.float32) * m
    r1 = int8_matvec(codes1, xs) / s - b
    r2 = int8_matvec(codes2, xs) / s - b
    g = int8_matvec(codes1.T, r2) + int8_matvec(codes2.T, r1)
    return g * m / (2.0 * B * s)


def quantized_matmul(x: jax.Array, codes: jax.Array, scale: jax.Array) -> jax.Array:
    """General y = x · dequant(codes, scale); pads all dims to 128 multiples
    for MXU alignment, slices the result back."""
    m0, k0 = x.shape
    _, n0 = codes.shape
    x, _ = _pad_to(x, 128, 0)
    x, _ = _pad_to(x, 128, 1)
    codes, _ = _pad_to(codes, 128, 0)
    codes, _ = _pad_to(codes, 128, 1)
    scale, _ = _pad_to(scale, 128, 1)
    y = qmm_mod.qmm(x, codes, scale)
    return y[:m0, :n0]


def quant_dense_apply(x: jax.Array, codes: jax.Array, scale: jax.Array, *,
                      packed: bool = False,
                      transpose: bool = False) -> jax.Array:
    """General y = x · dequant(codes, scale)[ᵀ] for 2-D code planes.

    x: (*lead, K) [or (*lead, N) transposed]; codes (K, N) int8 or
    (K, N/2) packed-int4 uint8; scale (1, N) f32 (zipml grids pre-divide by
    s). Leading x dims fold into the GEMM M axis; every dim pads to 128
    multiples (zero padding is exact: padded x/g entries are 0, padded
    output rows/cols are sliced off) and packed planes pad bytewise.
    """
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    m0 = x2.shape[0]
    k0 = codes.shape[0]
    n0 = codes.shape[1] * (2 if packed else 1)
    pdiv = 2 if packed else 1
    x2, _ = _pad_to(x2, 128, 0)
    x2, _ = _pad_to(x2, 128, 1)
    codes, _ = _pad_to(codes, 128, 0)
    codes, _ = _pad_to(codes, 128 // pdiv, 1)
    scale, _ = _pad_to(scale, 128, 1)
    if transpose:
        y = qmm_mod.qmm_t(x2, codes, scale, packed=packed)
        return y[:m0, :k0].reshape(*lead, k0)
    y = qmm_mod.qmm(x2, codes, scale, packed=packed)
    return y[:m0, :n0].reshape(*lead, n0)


def quant_dense_bitplane(x: jax.Array, codes: jax.Array, scale: jax.Array,
                         n_out: int) -> jax.Array:
    """y = x · decode(bitplane codes) for a 2-D logical weight.

    x: (*lead, K); codes (P, K, W) uint32 with W = ⌈n_out/32⌉ (plane 0 =
    sign, then magnitude MSB-first); scale (1, n_out) f32. Leading x dims
    fold into the GEMM M axis; M/K pad to 128 multiples and the word axis
    to 4-word (128-column) multiples — zero words decode to +0·scale, and
    the padded output columns are sliced off.
    """
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    m0 = x2.shape[0]
    x2, _ = _pad_to(x2, 128, 0)
    x2, _ = _pad_to(x2, 128, 1)
    codes, _ = _pad_to(codes, 128, 1)
    codes, _ = _pad_to(codes, 4, 2)
    scale, _ = _pad_to(scale, 128, 1)
    y = qbp_mod.qmm_bitplane(x2, codes, scale)
    return y[:m0, :n_out].reshape(*lead, n_out)


def quant_dense_out_q(x: jax.Array, codes: jax.Array, scale: jax.Array,
                      rand: jax.Array, *, qmax: int, packed: bool = False,
                      out_dtype=jnp.bfloat16):
    """Fused GEMM + double-sampled row quantization of the output
    (kernels/qmm.qmm_qout). x: (M, K); codes (K, N[/2]); scale (1, N);
    rand (M, N) uint32. Returns (codes1, codes2 (M, N) int8, row scales
    (M, 1) f32); the full-width activation never reaches HBM. Only M/K pad
    (zero rows are exact); N stays the true output width — the row absmax
    must not see padding garbage.
    """
    m0, _ = x.shape
    x, _ = _pad_to(x, 128, 0)
    x, _ = _pad_to(x, 128, 1)
    codes, _ = _pad_to(codes, 128, 0)
    rand, _ = _pad_to(rand, 128, 0)
    c1, c2, oscale = qmm_mod.qmm_qout(
        x, codes, scale, rand, qmax=qmax, packed=packed, out_dtype=out_dtype)
    return c1[:m0], c2[:m0], oscale[:m0]


def quant_adamw_update(master, g, m_codes, m_scale, v_codes, v_scale, rand, *,
                       qmax: int, b1: float, b2: float, eps: float, wd: float,
                       lr, b1c, b2c, clip, finite, uclip: float = 0.0):
    """Fused quantized-moment AdamW leaf update via the two-pass Pallas
    pipeline (kernels/quant_adamw.py): pass 1 reduces the new-moment column
    absmaxes (→ new scales), pass 2 decodes/updates/re-encodes per VMEM tile.
    The fp32 moment tensors never hit HBM.

    master/g: (R, C) f32; codes (R, C) int8; scales (C,) f32; rand (R, C)
    uint32 (hi/lo 16 bits drive the m and √v draws). lr/b1c/b2c/clip/finite
    are traced per-step scalars. Returns
    (new_master, m_codes, m_scale_new, v_codes, v_scale_new), scales (C,).
    """
    r0, c0 = master.shape

    def pad2(t):
        t, _ = _pad_to(t, 128, 0)
        t, _ = _pad_to(t, 128, 1)
        return t

    master, g, rand = pad2(master.astype(jnp.float32)), \
        pad2(g.astype(jnp.float32)), pad2(rand)
    m_codes, v_codes = pad2(m_codes), pad2(v_codes)
    ms, _ = _pad_to(jnp.asarray(m_scale, jnp.float32).reshape(1, -1), 128, 1)
    vs, _ = _pad_to(jnp.asarray(v_scale, jnp.float32).reshape(1, -1), 128, 1)
    params = jnp.stack([
        jnp.asarray(clip, jnp.float32),
        jnp.asarray(finite, jnp.float32),
        jnp.asarray(lr, jnp.float32),
        jnp.asarray(b1c, jnp.float32),
        jnp.asarray(b2c, jnp.float32),
        jnp.float32(0), jnp.float32(0), jnp.float32(0)])
    mx, vx = qa_mod.qadamw_absmax(g, m_codes, ms, v_codes, vs, params,
                                  b1=b1, b2=b2)
    mx = jnp.max(mx, axis=0)
    vx = jnp.max(vx, axis=0)
    msn = jnp.where(mx == 0, 1.0, mx / qmax).astype(jnp.float32)
    vsn = jnp.where(vx == 0, 1.0, vx / qmax).astype(jnp.float32)
    nm, mc, vc = qa_mod.qadamw_update(
        master, g, m_codes, ms, v_codes, vs,
        msn.reshape(1, -1), vsn.reshape(1, -1), rand, params,
        b1=b1, b2=b2, eps=eps, wd=wd, qmax=qmax, uclip=uclip)
    return (nm[:r0, :c0], mc[:r0, :c0], msn[:c0], vc[:r0, :c0], vsn[:c0])


def kv_bits_of(pages: jax.Array) -> int:
    """Infer the KV quantization width from a page plane's dtype (the pool's
    storage convention): uint8 = packed int4, int8 = int8, else bf16 (0)."""
    if pages.dtype == jnp.uint8:
        return 4
    if pages.dtype == jnp.int8:
        return 8
    return 0


def paged_attention(q, k_pages, v_pages, k_scale, v_scale, block_table,
                    seq_lens, *, softmax_scale: float):
    """Paged flash-decode attention via the Pallas kernel (in-kernel int8/int4
    dequant). q: (B, H, D); pages (P, page, Hkv, D[/2]); scales may be None
    (bf16 pool). Returns (B, H, D) in q.dtype.
    """
    hkv = k_pages.shape[2]
    if k_scale is None:
        k_scale = jnp.ones((1, 1, hkv, 1), jnp.float32)
        v_scale = jnp.ones((1, 1, hkv, 1), jnp.float32)
    out = pa_mod.paged_decode_attn(
        q, k_pages, v_pages, k_scale, v_scale, block_table, seq_lens,
        softmax_scale=float(softmax_scale), kv_bits=kv_bits_of(k_pages))
    return out.astype(q.dtype)


def ssd_chunked_kernel(xh, dt, a_log, b_mat, c_mat, chunk: int = 256):
    """Drop-in for models/ssm.ssd_chunked using the Pallas intra-chunk kernel.

    xh: (B, S, H, P); dt: (B, S, H); b/c: (B, S, G·N) with G=1.
    Returns (y (B, S, H, P), state (B, H, P, N)).
    """
    b, s, h, p = xh.shape
    L = min(chunk, s)
    if s % L:
        L = s
    nc = s // L
    a = -jnp.exp(a_log)
    logdec = (dt * a[None, None, :]).astype(jnp.float32)

    def chunked(t):
        return t.reshape(b, nc, L, *t.shape[2:])

    y, state = ssd_mod.ssd_chunk_scan(
        chunked(xh), chunked(dt), chunked(logdec),
        chunked(b_mat), chunked(c_mat))
    return y.reshape(b, s, h, p), state
