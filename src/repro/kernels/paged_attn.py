"""Pallas TPU kernel: paged flash-decode attention over a quantized KV pool.

The serving engine (repro/serve) stores the KV cache as fixed-size pages of
QTensor code planes — bf16, int8, or nibble-packed int4 — indexed per
sequence by a block table. This kernel is the decode hot loop of that
layout: one query token per sequence attends to its pages, and the int8/int4
codes are **dequantized in VMEM**, so HBM traffic is the code bytes (2×/4×
fewer than bf16 — the ZipML Fig. 2 data-movement claim applied to serving;
MLWeaving's any-precision layout is the same idea in silicon). int4 pages
dequantize by **split nibbles fused into the flash inner loop**: scores and
the value accumulator work on the even/odd D-halves directly (both are
half-sum-decomposable), so the per-page stride interleave the old
unpack-then-attend path paid — the int4-slower-than-int8 regression — is
gone; the output interleaves once at the end.

Mechanics:
* grid = (B, MAXP); the page axis is the sequential minor axis, so the f32
  flash-softmax accumulators (running max / sum / weighted value) live in
  VMEM scratch across the per-sequence page loop — the same
  revisit-accumulate pattern as kernels/qmm.py's K axis.
* the block table and sequence lengths ride in as **scalar prefetch**
  operands (`pltpu.PrefetchScalarGridSpec`): the index_map of the page
  operands reads `block_table[b, p]`, so each grid step DMAs exactly the one
  page it needs — the pool itself never streams densely.
* rows past `seq_lens[b]` (allocation slack, the shared null page 0) are
  masked with the finite NEG_INF of models/attention.py and contribute
  exactly 0 probability mass.

Validated bit-for-bit against kernels/ref.paged_attention_ref in interpret
mode on CPU (tolerance: f32 flash vs one-shot softmax associativity); real
TPU lowering wants page a multiple of 8 and D a multiple of 128, which the
serving pool's defaults satisfy at production head dims.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import registry

NEG_INF = -2.0 ** 30  # matches models/attention.py: finite, exp() == 0.0 in f32


def _dequant(codes, scale, kv_bits: int):
    """(page, Hkv, D) codes + (page|1, Hkv, 1) scale → (page, Hkv, D) f32.

    bf16/int8 only: the int4 path never materializes interleaved codes —
    see the split-nibble branch in the kernel body."""
    x = codes.astype(jnp.float32)
    if kv_bits:
        x = x * scale.astype(jnp.float32)
    return x


def _nibble_halves(codes, scale):
    """(page, Hkv, D/2) packed uint8 → (lo, hi) f32 halves, each
    (page, Hkv, D/2): lo = even D-elements, hi = odd (pack_int4's layout).

    The shift+mask runs on the packed words in place — no interleave, no
    (page, Hkv, D) stride scatter. The caller keeps the two halves apart
    through the whole page loop; scores and the value accumulator are
    half-sum-decomposable, so only the final output interleaves (once)."""
    s = scale.astype(jnp.float32)
    lo = ((codes & 0xF).astype(jnp.float32) - 8.0) * s
    hi = (((codes >> 4) & 0xF).astype(jnp.float32) - 8.0) * s
    return lo, hi


def _paged_attn_kernel(bt_ref, len_ref, q_ref, kp_ref, vp_ref, ks_ref, vs_ref,
                       o_ref, m_ref, l_ref, acc_ref, *,
                       softmax_scale: float, kv_bits: int, page: int):
    b = pl.program_id(0)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                    # (H, D)
    h, d = q.shape
    if kv_bits == 4:
        # fused in-register nibble dequant: scores split over the even/odd
        # D-halves (the D-sum is permutation-invariant), V accumulated
        # de-interleaved — the interleave happens once, in _finish
        k_lo, k_hi = _nibble_halves(kp_ref[0], ks_ref[0])   # (page, G, D/2)
        v_lo, v_hi = _nibble_halves(vp_ref[0], vs_ref[0])
        g = k_lo.shape[1]
        r = h // g
        qr = q.reshape(g, r, d // 2, 2)
        s = (jnp.einsum("grd,tgd->grt", qr[..., 0], k_lo,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("grd,tgd->grt", qr[..., 1], k_hi,
                          preferred_element_type=jnp.float32)) * softmax_scale
    else:
        k = _dequant(kp_ref[0], ks_ref[0], kv_bits)     # (page, G, D)
        v = _dequant(vp_ref[0], vs_ref[0], kv_bits)
        g = k.shape[1]
        r = h // g
        qg = q.reshape(g, r, d)
        s = jnp.einsum("grd,tgd->grt", qg, k,
                       preferred_element_type=jnp.float32) * softmax_scale
    pos = p * page + jax.lax.broadcasted_iota(jnp.int32, (1, 1, page), 2)
    valid = pos < len_ref[b]                            # (1, 1, page)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                                 # (G, R)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    # explicit re-mask: on a fully-masked page m_new stays NEG_INF and
    # exp(s − m_new) would be exp(0)=1 — the where() keeps dead rows at 0
    pexp = jnp.where(valid, jnp.exp(s - m_new[..., None]), 0.0)
    m_ref[...] = m_new
    l_ref[...] = l_ref[...] * alpha + jnp.sum(pexp, axis=-1)
    acc = acc_ref[...].reshape(g, r, d) * alpha[..., None]
    if kv_bits == 4:
        # acc scratch layout for int4: [even-half | odd-half] along D
        d2 = d // 2
        acc = jnp.concatenate(
            [acc[..., :d2] + jnp.einsum("grt,tgd->grd", pexp, v_lo,
                                        preferred_element_type=jnp.float32),
             acc[..., d2:] + jnp.einsum("grt,tgd->grd", pexp, v_hi,
                                        preferred_element_type=jnp.float32)],
            axis=-1)
    else:
        acc = acc + jnp.einsum("grt,tgd->grd", pexp, v,
                               preferred_element_type=jnp.float32)
    acc_ref[...] = acc.reshape(h, d)

    @pl.when(p == pl.num_programs(1) - 1)
    def _finish():
        # empty sequences (seq_len 0: inactive slots) divide by the 1e-30
        # floor → output 0; those rows are never read by the engine
        l = jnp.maximum(l_ref[...], 1e-30)[..., None]   # (G, R, 1)
        out = acc_ref[...].reshape(g, r, d) / l
        if kv_bits == 4:
            # the one interleave: [even | odd] halves → natural D order
            out = out.reshape(g, r, 2, d // 2).swapaxes(-1, -2)
        o_ref[0] = out.reshape(h, d)


@functools.partial(jax.jit, static_argnames=("softmax_scale", "kv_bits",
                                             "interpret"))
def paged_decode_attn(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                      k_scale: jax.Array, v_scale: jax.Array,
                      block_table: jax.Array, seq_lens: jax.Array, *,
                      softmax_scale: float, kv_bits: int = 0,
                      interpret: bool | None = None) -> jax.Array:
    """q (B, H, D) × paged KV pool → (B, H, D) f32.

    k/v_pages: (P, page, Hkv, D) bf16/int8 or (P, page, Hkv, D/2) uint8
    (packed int4); k/v_scale: (P, page, Hkv, 1) f32, or (1, 1, Hkv, 1) dummy
    for bf16; block_table (B, MAXP) int32; seq_lens (B,) int32.
    """
    b, h, d = q.shape
    n_pages, page, hkv, _ = k_pages.shape
    maxp = block_table.shape[1]
    bt = block_table.astype(jnp.int32)
    lens = seq_lens.astype(jnp.int32)
    scale_blk = (1, 1, hkv, 1) if k_scale.shape[0] == 1 else (1, page, hkv, 1)

    def page_idx(bb, pp, bt_ref, len_ref):
        return (bt_ref[bb, pp], 0, 0, 0)

    def scale_idx(bb, pp, bt_ref, len_ref):
        if k_scale.shape[0] == 1:
            return (0, 0, 0, 0)
        return (bt_ref[bb, pp], 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, maxp),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda bb, pp, bt_ref, len_ref: (bb, 0, 0)),
            pl.BlockSpec((1, page, hkv, k_pages.shape[-1]), page_idx),
            pl.BlockSpec((1, page, hkv, v_pages.shape[-1]), page_idx),
            pl.BlockSpec(scale_blk, scale_idx),
            pl.BlockSpec(scale_blk, scale_idx),
        ],
        out_specs=pl.BlockSpec((1, h, d),
                               lambda bb, pp, bt_ref, len_ref: (bb, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((hkv, h // hkv), jnp.float32),   # running max
            pltpu.VMEM((hkv, h // hkv), jnp.float32),   # running denom
            pltpu.VMEM((h, d), jnp.float32),            # weighted values
        ],
    )
    kernel = functools.partial(_paged_attn_kernel, softmax_scale=softmax_scale,
                               kv_bits=kv_bits, page=page)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, d), jnp.float32),
        interpret=registry.resolve_interpret(interpret),
    )(bt, lens, q, k_pages, v_pages, k_scale, v_scale)
