"""PrecisionPlan — ONE four-channel precision config for the whole repo.

ZipML applies the same unbiased quantizer Q(v, s) to four channels (§2.2,
§3.3/§3.4): **samples**, **model**, **gradients**, **activations** — plus the
serving-side KV cache. Historically the linear suite (`core.linear.Precision`)
and the LM stack (`models.transformer.PrecisionPlan`) each grew their own
config; this class replaces both (the old names are deprecated aliases).

Canonical fields (bits per channel; 0 = full precision):

* ``sample_bits`` — Q_s on samples (column-scaled; the linear suite's double
  sampling / e2e modes consume it).
* ``model_bits``  — Q_m on the model/weights. ``model_storage`` selects QAT
  fake-quant ('fake'), real int codes at rest ('int'), or quantize-on-gather
  ('ship').
* ``grad_bits``   — Q_g on gradients (linear e2e channel and the C3
  compressed collective).
* ``act_bits``    — double-sampled activation quantization in MLP blocks
  (§3.4 beyond-paper channel).
* ``kv_bits``     — serving KV-cache quantization.

``mode`` picks the linear-suite estimator ('full'/'naive'/'double'/'e2e'/
'nearest'); the LM stack ignores it. ``optimal_levels`` swaps the uniform
grid for the C4 variance-optimal levels where supported.

Legacy keyword arguments (``bits_sample``, ``weight_bits``, ``act_ds_bits``,
``use_optimal_levels``, ``weight_storage``, …) and the matching legacy
attribute reads still work but emit ``DeprecationWarning``.
"""
from __future__ import annotations

import dataclasses
import warnings


_LEGACY_KWARGS = {
    "bits_sample": "sample_bits",
    "bits_model": "model_bits",
    "bits_grad": "grad_bits",
    "weight_bits": "model_bits",
    "act_ds_bits": "act_bits",
    "use_optimal_levels": "optimal_levels",
    "weight_storage": "model_storage",
}


def _warn_legacy(old: str, new: str):
    warnings.warn(
        f"PrecisionPlan.{old} is deprecated; use PrecisionPlan.{new} "
        f"(see the README deprecation table)",
        DeprecationWarning, stacklevel=3)


@dataclasses.dataclass(frozen=True, init=False)
class PrecisionPlan:
    mode: str = "full"
    sample_bits: int = 5
    model_bits: int = 0
    grad_bits: int = 0
    act_bits: int = 0
    kv_bits: int = 0
    model_storage: str = "fake"     # 'fake' | 'int' | 'ship'
    optimal_levels: bool = False
    optimal_method: str = "discretized"
    backend: str | None = None      # kernel backend; None = registry default

    def __init__(self, mode: str = "full", **kw):
        legacy = [k for k in kw if k in _LEGACY_KWARGS]
        for k in legacy:
            if _LEGACY_KWARGS[k] in kw:
                raise TypeError(
                    f"PrecisionPlan got both {k!r} (deprecated) and its "
                    f"canonical spelling {_LEGACY_KWARGS[k]!r}")
            _warn_legacy(k, _LEGACY_KWARGS[k])
            kw[_LEGACY_KWARGS[k]] = kw.pop(k)
        fields = {f.name: f.default for f in dataclasses.fields(self)}
        unknown = set(kw) - set(fields)
        if unknown:
            raise TypeError(f"unknown PrecisionPlan field(s): {sorted(unknown)}")
        fields["mode"] = mode
        fields.update(kw)
        for name, value in fields.items():
            object.__setattr__(self, name, value)

    # ------------------------------------------------------- derived views --
    @property
    def s_sample(self) -> int:
        """Interval count of the sample channel (s = 2^bits − 1)."""
        return 2 ** self.sample_bits - 1

    def ds_config(self):
        """The DSConfig consumed by core/double_sampling (lazy import: quant
        is the base layer and must not import core at module scope)."""
        from repro.core.double_sampling import DSConfig
        return DSConfig(
            s_sample=self.s_sample,
            s_model=2 ** self.model_bits - 1 if self.model_bits else 0,
            s_grad=2 ** self.grad_bits - 1 if self.grad_bits else 0,
        )

    def to_dict(self) -> dict:
        """JSON-safe form (checkpoint manifests record the training plan)."""
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self)}

    @classmethod
    def from_dict(cls, d: dict) -> "PrecisionPlan":
        return cls(**d)

    # ------------------------------------------- deprecated attribute reads --
    @property
    def bits_sample(self) -> int:
        _warn_legacy("bits_sample", "sample_bits")
        return self.sample_bits

    @property
    def bits_model(self) -> int:
        _warn_legacy("bits_model", "model_bits")
        return self.model_bits

    @property
    def bits_grad(self) -> int:
        _warn_legacy("bits_grad", "grad_bits")
        return self.grad_bits

    @property
    def weight_bits(self) -> int:
        _warn_legacy("weight_bits", "model_bits")
        return self.model_bits

    @property
    def act_ds_bits(self) -> int:
        _warn_legacy("act_ds_bits", "act_bits")
        return self.act_bits

    @property
    def use_optimal_levels(self) -> bool:
        _warn_legacy("use_optimal_levels", "optimal_levels")
        return self.optimal_levels

    @property
    def weight_storage(self) -> str:
        _warn_legacy("weight_storage", "model_storage")
        return self.model_storage
