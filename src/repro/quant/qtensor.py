"""QTensor — the one canonical quantized-tensor storage format.

A ``QTensor`` is a registered JAX pytree holding ``codes`` (the integer
storage), ``scale`` (the decode multiplier family of its ``QScheme``), an
optional second double-sampling plane ``codes2`` (§2.2 — Q₁/Q₂ share the base
level, so the pair costs +1 bit, not 2×), and an optional ``levels`` table
(C4 variance-optimal grids). The ``QScheme`` rides as static aux data, so
QTensors flow through ``jit``/``vmap``/``lax.scan``/``shard_map`` and
checkpoint save/restore like any other pytree.

This module is also the **single implementation** of each rounding mode —
``stochastic_round`` (floor + Bernoulli up-bit, unbiased by Lemma 6),
``nearest_round`` (the §5.4 deterministic straw man), and level-table
rounding — which the former copies in ``precision/act_quant``,
``precision/gradcomp``, ``precision/qat`` and ``optim/adamw`` now all
delegate to.

The public entry points ``encode`` / ``decode`` / ``ds_pair`` / ``dot``
dispatch through :mod:`repro.kernels.registry`, so the pure-jnp ``ref``
backend and the fused Pallas pipeline share this one storage format.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp

from .scheme import QScheme


def _code_dtype(s: int):
    return jnp.int8 if s <= 127 else jnp.int32


# ---------------------------------------------------------------------------
# Rounding modes — exactly one implementation of each lives here.
# ---------------------------------------------------------------------------

def stochastic_round(t: jax.Array, key: jax.Array) -> jax.Array:
    """Unbiased stochastic rounding: ⌊t⌋ + Bernoulli(t − ⌊t⌋) (Lemma 6)."""
    lo = jnp.floor(t)
    u = jax.random.uniform(key, t.shape, dtype=jnp.float32)
    return lo + (u < (t - lo)).astype(jnp.float32)


def nearest_round(t: jax.Array) -> jax.Array:
    """Deterministic nearest rounding — the paper's §5.4 biased straw man."""
    return jnp.round(t)


def _round(t: jax.Array, key: jax.Array | None) -> jax.Array:
    return nearest_round(t) if key is None else stochastic_round(t, key)


# ---------------------------------------------------------------------------
# int4 nibble packing — the one implementation (models/attention and the
# paged serving KV pool both delegate here).
# ---------------------------------------------------------------------------

def pack_int4(codes: jax.Array) -> jax.Array:
    """int codes in [-7, 7], last dim even → uint8 (…, D/2): offset-binary
    nibbles (c+8 ∈ [1, 15]; 0 reserved ⇒ unpack is branch-free)."""
    if codes.shape[-1] % 2:
        raise ValueError(f"packed int4 needs an even last dim, got {codes.shape}")
    c = (codes.astype(jnp.int32) + 8).astype(jnp.uint8)
    lo = c[..., 0::2]
    hi = c[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_int4(packed: jax.Array) -> jax.Array:
    """uint8 (…, D/2) → f32 codes (…, D) in [-7, 7] (inverse of pack_int4)."""
    lo = (packed & 0xF).astype(jnp.float32) - 8.0
    hi = ((packed >> 4) & 0xF).astype(jnp.float32) - 8.0
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)


# ---------------------------------------------------------------------------
# Bit-plane (MLWeaving) packing — the generalization of the nibble machinery
# above to any 1..8-bit precision: one sign plane + B magnitude planes, MSB
# first, each plane packing 32 elements per uint32 word. This module is the
# ONE home of the packing convention; kernels/qmm_bitplane.py reconstructs
# the identical planes in-register from the same words.
# ---------------------------------------------------------------------------

def pack_bitplanes(planes: jax.Array) -> jax.Array:
    """0/1 planes ``(…, D)`` → uint32 words ``(…, ⌈D/32⌉)``.

    Bit ``j`` of word ``w`` holds element ``32·w + j`` — consecutive elements
    share a word, so unpacking is a contiguous reshape, never a stride
    interleave. The tail word zero-pads."""
    d = planes.shape[-1]
    pad = (-d) % 32
    b = planes.astype(jnp.uint32)
    if pad:
        b = jnp.pad(b, [(0, 0)] * (b.ndim - 1) + [(0, pad)])
    b = b.reshape(*b.shape[:-1], -1, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return (b << shifts).sum(axis=-1).astype(jnp.uint32)


def unpack_bitplanes(words: jax.Array, d: int) -> jax.Array:
    """uint32 words ``(…, ⌈d/32⌉)`` → int32 0/1 planes ``(…, d)``
    (inverse of :func:`pack_bitplanes`)."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(*words.shape[:-1], words.shape[-1] * 32)
    return bits[..., :d].astype(jnp.int32)


def _encode_bitplane(x: jax.Array, scheme: QScheme,
                     scale: jax.Array) -> "QTensor":
    """Bit-serial encode: codes ``(*lead, B+1, R, ⌈D/32⌉)`` for x
    ``(*lead, R, D)`` — plane axis at −3 so stacked layers still
    ``lax.scan`` over their leading axis.

    The magnitude is TRUNCATED (⌊|x|·2^B/scale⌋, not nearest): truncation
    nests under right-shift (⌊⌊u·2^B⌋/2^(B−k)⌋ = ⌊u·2^k⌋, and the clip
    commutes because (2^B−1)≫(B−k) = 2^k−1), so decoding the top-k planes
    is value-identical to direct k-bit encoding — the any-precision
    invariant. 2^B/2^k are exact in fp32, so the identity is exact."""
    if x.ndim < 2:
        raise ValueError(
            f"bitplane layout packs matrices (ndim >= 2), got {x.shape}")
    b = scheme.bits
    x32 = x.astype(jnp.float32)
    u = jnp.abs(x32) / scale
    mag = jnp.clip(jnp.floor(u * (2.0 ** b)), 0.0,
                   float(2 ** b - 1)).astype(jnp.uint32)
    sign = (x32 < 0).astype(jnp.uint32)
    planes = [sign] + [(mag >> (b - 1 - p)) & jnp.uint32(1) for p in range(b)]
    codes = pack_bitplanes(jnp.stack(planes, axis=-3))
    scheme = dataclasses.replace(scheme, vec_dim=int(x.shape[-1]))
    return QTensor(codes, scale, scheme)


# ---------------------------------------------------------------------------
# Scale families
# ---------------------------------------------------------------------------

def _reduce_axes(scheme: QScheme, ndim: int):
    if scheme.scaling == "tensor":
        return None, False
    if scheme.scaling == "row":
        return -1, True
    if scheme.scaling == "column":
        return tuple(range(ndim - 1)), False
    return scheme.channel_axis, True      # 'channel'


def compute_scale(x: jax.Array, scheme: QScheme) -> jax.Array:
    """The decode multiplier for ``x`` under ``scheme``'s scaling family.

    zipml grid → M with |x|/M ≤ 1 (the paper's row/column scale);
    int grid   → absmax/qmax (one code step). Zeros map to scale 1 so decode
    of an all-zero tensor is exact. Scales never carry gradients.
    """
    x32 = jax.lax.stop_gradient(x.astype(jnp.float32))
    axes, keepdims = _reduce_axes(scheme, x.ndim)
    m = jnp.max(jnp.abs(x32), axis=axes, keepdims=keepdims)
    if scheme.grid == "int":
        if scheme.layout == "bitplane":
            # bitplane magnitudes live on [0, 1): the scale is the absmax
            # itself, independent of bits, so every plane slice shares it
            return jnp.where(m == 0, 1.0, m).astype(jnp.float32)
        qmax = float(scheme.qmax)
        return jnp.where(m == 0, 1.0, m / qmax).astype(jnp.float32)
    return jnp.where(m == 0, 1.0, m).astype(jnp.float32)


# ---------------------------------------------------------------------------
# QTensor
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
class QTensor:
    """codes + scale(s) (+ optional second DS plane / level table) + scheme."""

    __slots__ = ("codes", "scale", "scheme", "codes2", "levels")

    def __init__(self, codes: jax.Array, scale: jax.Array, scheme: QScheme,
                 codes2: jax.Array | None = None,
                 levels: jax.Array | None = None):
        self.codes = codes
        self.scale = scale
        self.scheme = scheme
        self.codes2 = codes2
        self.levels = levels

    # ------------------------------------------------------------- pytree --
    def tree_flatten(self):
        return (self.codes, self.scale, self.codes2, self.levels), self.scheme

    @classmethod
    def tree_unflatten(cls, scheme, children):
        codes, scale, codes2, levels = children
        return cls(codes, scale, scheme, codes2=codes2, levels=levels)

    # -------------------------------------------------------------- shape --
    @property
    def shape(self):
        """LOGICAL shape — bitplane codes (*lead, B+1, R, W) report the
        decoded (*lead, R, vec_dim), so matmul equations see a matrix."""
        if self.scheme.layout == "bitplane":
            s = self.codes.shape
            return (*s[:-3], s[-2], self.scheme.vec_dim)
        return self.codes.shape

    @property
    def ndim(self):
        return len(self.shape)

    @property
    def size(self):
        return int(np.prod(self.shape)) if self.shape else 1

    @property
    def is_ds(self) -> bool:
        return self.codes2 is not None

    # legacy `Quantized` surface --------------------------------------------
    @property
    def s(self) -> int:
        return self.scheme.s

    @property
    def signed(self) -> bool:
        return self.scheme.signed

    @property
    def bits(self) -> int:
        return self.scheme.bits

    @property
    def nbits(self) -> int:
        """Storage bits per element, host-side. A double-sampled pair costs
        +1 bit on top of the base code width (§2.2 — the same accounting as
        ``benchmarks/bench_bandwidth_model.wire_bytes``)."""
        return self.scheme.code_bits + (1 if self.is_ds else 0)

    @property
    def nbytes(self) -> int:
        """Logical HBM/wire bytes: packed codes + scales + level table.
        Bitplane storage counts its uint32 words directly — exactly
        (bits+1) planes' worth, so a ``slice_planes(k)`` view costs bytes
        linear in k."""
        if self.scheme.layout == "bitplane":
            total = int(np.prod(self.codes.shape)) * 4        # uint32 words
            total += int(np.prod(self.scale.shape)
                         if self.scale.shape else 1) * \
                np.dtype(jnp.float32).itemsize
            return int(total)
        n = int(np.prod(self.codes.shape)) if self.codes.shape else 1
        if self.scheme.packed:
            n *= 2                               # two logical codes per byte
        total = -(-n * self.nbits // 8)          # ceil(n · nbits / 8)
        total += int(np.prod(self.scale.shape) if self.scale.shape else 1) * \
            np.dtype(jnp.float32).itemsize
        if self.levels is not None:
            total += int(np.prod(self.levels.shape)) * \
                np.dtype(jnp.float32).itemsize
        return int(total)

    # ------------------------------------------------------------- decode --
    def _decode_plane(self, codes, dtype=None):
        sch = self.scheme
        if sch.grid == "levels":
            lv = self.levels
            c32 = codes.astype(jnp.int32)
            if lv.ndim == 1:
                out = jnp.take(lv, c32)
            else:
                # per-slice tables: levels (*lead, n_levels) pairs with codes
                # (*lead, ...) — the stacked-layer layout that lets a QTensor
                # ride through lax.scan over layers (each slice gets its table)
                lead = int(np.prod(lv.shape[:-1]))
                out = jax.vmap(jnp.take)(
                    lv.reshape(lead, lv.shape[-1]),
                    c32.reshape(lead, -1)).reshape(codes.shape)
            return out.astype(dtype) if dtype is not None else out
        ct = jnp.float32 if dtype is None else dtype
        if sch.grid == "int":
            if sch.layout == "bitplane":
                # self-describing: k comes from the plane axis, so the same
                # decode serves every slice_planes(k) view
                k = codes.shape[-3] - 1
                bits = jnp.moveaxis(
                    unpack_bitplanes(codes, sch.vec_dim), -3, 0).astype(ct)
                sign = 1.0 - 2.0 * bits[0]
                w = (2.0 ** (k - 1 - jnp.arange(k))).astype(ct)
                mag = jnp.tensordot(w, bits[1:], axes=(0, 0))
                return sign * mag * self.scale.astype(ct) * (2.0 ** -k)
            if sch.packed:
                codes = unpack_int4(codes)
            return codes.astype(ct) * self.scale.astype(ct)
        return codes.astype(ct) / sch.s * self.scale.astype(ct)

    def decode(self, dtype=None) -> jax.Array:
        """Dequantize the (first) code plane. ``dtype`` selects the multiply
        dtype for the int grid (e.g. bf16 weight dequant); default fp32."""
        return self._decode_plane(self.codes, dtype)

    def decode2(self, dtype=None) -> jax.Array:
        """Dequantize the second double-sampling plane (Q₂)."""
        if self.codes2 is None:
            raise ValueError("QTensor has no second double-sampling plane")
        return self._decode_plane(self.codes2, dtype)

    def dequantize(self) -> jax.Array:   # old Quantized/IntTensor spelling
        return self.decode()

    # ---------------------------------------------------------- bitplane --
    def slice_planes(self, k: int) -> "QTensor":
        """Top-k-bit view of a bitplane QTensor: the sign plane + the k most
        significant magnitude planes. A pure slice — zero repacking, bytes
        streamed linear in k — whose decode is value-identical to encoding
        the original tensor directly at k bits (truncation nests; the scale
        is bits-independent)."""
        if self.scheme.layout != "bitplane":
            raise ValueError("slice_planes needs layout='bitplane', got "
                             f"{self.scheme.layout!r}")
        if not 1 <= k <= self.scheme.bits:
            raise ValueError(
                f"k must be in 1..{self.scheme.bits}, got {k}")
        if k == self.scheme.bits:
            return self
        scheme = dataclasses.replace(self.scheme, bits=k)
        return QTensor(self.codes[..., :k + 1, :, :], self.scale, scheme)

    def dot(self, v: jax.Array, backend: str | None = None) -> jax.Array:
        """decode(self) @ v, dispatched through the kernel-backend registry
        (the Pallas backend streams int8 codes instead of materializing f32)."""
        return dot(self, v, backend=backend)

    def __repr__(self):
        extra = "+ds" if self.is_ds else ""
        return (f"QTensor({self.codes.shape}, {self.scheme.grid}{extra}, "
                f"bits={self.scheme.bits}, scaling={self.scheme.scaling})")


def tree_nbytes(tree) -> int:
    """Logical HBM/wire bytes of a pytree: QTensor leaves contribute their
    packed ``.nbytes`` (codes + scales + level tables, §2.2 pair accounting),
    dense array / ShapeDtypeStruct leaves their ``size × itemsize``. The
    byte model behind the train-step bench and the dry-run channel-state
    line items."""
    total = 0
    for leaf in jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, QTensor)):
        if isinstance(leaf, QTensor):
            total += leaf.nbytes
        else:
            n = int(np.prod(leaf.shape)) if len(leaf.shape) else 1
            total += n * np.dtype(leaf.dtype).itemsize
    return int(total)


# ---------------------------------------------------------------------------
# Pure-jnp encode implementations (what the 'ref' backend runs; the Pallas
# backend is tested bit-exact/distribution-identical against these).
# ---------------------------------------------------------------------------

def encode_jnp(x: jax.Array, scheme: QScheme, key: jax.Array | None = None,
               scale: jax.Array | None = None,
               levels: jax.Array | None = None) -> QTensor:
    """Reference encoder for every grid/rounding — the seed numerics."""
    if scheme.rounding == "stochastic" and key is None:
        raise ValueError("stochastic rounding requires a PRNG key")
    rkey = None if scheme.rounding == "nearest" else key
    if scheme.grid == "levels":
        if levels is None:
            raise ValueError("grid='levels' requires a level table")
        codes, _ = quantize_to_levels_jnp(x, levels, rkey)
        return QTensor(codes, jnp.ones((), jnp.float32), scheme, levels=levels)
    if scale is None:
        scale = compute_scale(x, scheme)
    else:
        scale = jnp.asarray(scale, jnp.float32)
    if scheme.layout == "bitplane":
        return _encode_bitplane(x, scheme, scale)
    if scheme.grid == "zipml":
        s = scheme.s
        xn = (jnp.asarray(x) / scale).astype(jnp.float32)
        mag = jnp.clip(jnp.abs(xn) if scheme.signed else xn, 0.0, 1.0)
        codes = _round(mag * s, rkey)
        if scheme.signed:
            codes = codes * jnp.sign(xn)
        return QTensor(codes.astype(_code_dtype(s)), scale, scheme)
    # symmetric int grid (int8 up to 8 bits, int32 above — no silent overflow)
    qmax = float(scheme.qmax)
    t = x.astype(jnp.float32) / scale
    codes = jnp.clip(_round(t, rkey), -qmax, qmax).astype(_code_dtype(scheme.qmax))
    if scheme.packed:
        codes = pack_int4(codes)
    return QTensor(codes, scale, scheme)


def ds_pair_jnp(x: jax.Array, scheme: QScheme, key: jax.Array,
                scale: jax.Array | None = None) -> QTensor:
    """Two independent stochastic planes from one split key — the reference
    double-sampling draw (the fused Pallas path shares the base level)."""
    if key is None:
        raise ValueError("double-sampling ('ds' rounding) requires a PRNG key")
    if scale is None:
        scale = compute_scale(x, scheme)
    one = scheme.with_rounding("stochastic")
    k1, k2 = jax.random.split(key)
    q1 = encode_jnp(x, one, k1, scale=scale)
    q2 = encode_jnp(x, one, k2, scale=scale)
    return QTensor(q1.codes, q1.scale, scheme.with_rounding("ds"),
                   codes2=q2.codes)


def quantize_to_levels_jnp(
    v: jax.Array, levels: jax.Array, key: jax.Array | None = None
) -> tuple[jax.Array, jax.Array]:
    """Stochastic (or nearest, ``key=None``) rounding onto a sorted 1-D level
    set — unbiased inside the level range. Returns (codes, values)."""
    levels = jnp.asarray(levels, jnp.float32)
    v32 = jnp.asarray(v, jnp.float32)
    k = levels.shape[0]
    vc = jnp.clip(v32, levels[0], levels[-1])
    hi_idx = jnp.clip(jnp.searchsorted(levels, vc, side="right"), 1, k - 1)
    lo_idx = hi_idx - 1
    lo = levels[lo_idx]
    hi = levels[hi_idx]
    width = jnp.maximum(hi - lo, 1e-30)
    p_up = (vc - lo) / width
    if key is None:
        up = p_up >= 0.5
    else:
        up = jax.random.uniform(key, v32.shape, dtype=jnp.float32) < p_up
    codes = jnp.where(up, hi_idx, lo_idx)
    values = jnp.where(up, hi, lo)
    return codes.astype(_code_dtype(k - 1)), values


# ---------------------------------------------------------------------------
# Public entry points — dispatch through the kernel-backend registry.
# ---------------------------------------------------------------------------

def _backend(backend):
    from repro.kernels import registry
    return registry.resolve(backend)


def encode(x: jax.Array, scheme: QScheme, key: jax.Array | None = None,
           scale: jax.Array | None = None, levels: jax.Array | None = None,
           backend: "str | Any | None" = None) -> QTensor:
    """Quantize ``x`` under ``scheme``. ``rounding='ds'`` draws both planes."""
    if scheme.rounding == "ds":
        return ds_pair(x, scheme, key, scale=scale, backend=backend)
    return _backend(backend).encode(x, scheme, key, scale=scale, levels=levels)


def decode(qt: QTensor, dtype=None,
           backend: "str | Any | None" = None) -> jax.Array:
    return _backend(backend).decode(qt, dtype=dtype)


def ds_pair(x: jax.Array, scheme: QScheme, key: jax.Array,
            scale: jax.Array | None = None,
            backend: "str | Any | None" = None) -> QTensor:
    """Draw the §2.2 double-sampling pair as one QTensor (codes + codes2)."""
    if key is None:
        raise ValueError("double-sampling ('ds' rounding) requires a PRNG key")
    return _backend(backend).ds_pair(x, scheme, key, scale=scale)


def dot(qt: QTensor, v: jax.Array,
        backend: "str | Any | None" = None) -> jax.Array:
    """decode(qt) @ v — backends may compute it from codes without ever
    materializing the dequantized tensor."""
    return _backend(backend).qt_dot(qt, v)
