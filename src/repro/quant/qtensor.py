"""QTensor — the one canonical quantized-tensor storage format.

A ``QTensor`` is a registered JAX pytree holding ``codes`` (the integer
storage), ``scale`` (the decode multiplier family of its ``QScheme``), an
optional second double-sampling plane ``codes2`` (§2.2 — Q₁/Q₂ share the base
level, so the pair costs +1 bit, not 2×), and an optional ``levels`` table
(C4 variance-optimal grids). The ``QScheme`` rides as static aux data, so
QTensors flow through ``jit``/``vmap``/``lax.scan``/``shard_map`` and
checkpoint save/restore like any other pytree.

This module is also the **single implementation** of each rounding mode —
``stochastic_round`` (floor + Bernoulli up-bit, unbiased by Lemma 6),
``nearest_round`` (the §5.4 deterministic straw man), and level-table
rounding — which the former copies in ``precision/act_quant``,
``precision/gradcomp``, ``precision/qat`` and ``optim/adamw`` now all
delegate to.

The public entry points ``encode`` / ``decode`` / ``ds_pair`` / ``dot``
dispatch through :mod:`repro.kernels.registry`, so the pure-jnp ``ref``
backend and the fused Pallas pipeline share this one storage format.
"""
from __future__ import annotations

from typing import Any

import numpy as np
import jax
import jax.numpy as jnp

from .scheme import QScheme


def _code_dtype(s: int):
    return jnp.int8 if s <= 127 else jnp.int32


# ---------------------------------------------------------------------------
# Rounding modes — exactly one implementation of each lives here.
# ---------------------------------------------------------------------------

def stochastic_round(t: jax.Array, key: jax.Array) -> jax.Array:
    """Unbiased stochastic rounding: ⌊t⌋ + Bernoulli(t − ⌊t⌋) (Lemma 6)."""
    lo = jnp.floor(t)
    u = jax.random.uniform(key, t.shape, dtype=jnp.float32)
    return lo + (u < (t - lo)).astype(jnp.float32)


def nearest_round(t: jax.Array) -> jax.Array:
    """Deterministic nearest rounding — the paper's §5.4 biased straw man."""
    return jnp.round(t)


def _round(t: jax.Array, key: jax.Array | None) -> jax.Array:
    return nearest_round(t) if key is None else stochastic_round(t, key)


# ---------------------------------------------------------------------------
# int4 nibble packing — the one implementation (models/attention and the
# paged serving KV pool both delegate here).
# ---------------------------------------------------------------------------

def pack_int4(codes: jax.Array) -> jax.Array:
    """int codes in [-7, 7], last dim even → uint8 (…, D/2): offset-binary
    nibbles (c+8 ∈ [1, 15]; 0 reserved ⇒ unpack is branch-free)."""
    if codes.shape[-1] % 2:
        raise ValueError(f"packed int4 needs an even last dim, got {codes.shape}")
    c = (codes.astype(jnp.int32) + 8).astype(jnp.uint8)
    lo = c[..., 0::2]
    hi = c[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_int4(packed: jax.Array) -> jax.Array:
    """uint8 (…, D/2) → f32 codes (…, D) in [-7, 7] (inverse of pack_int4)."""
    lo = (packed & 0xF).astype(jnp.float32) - 8.0
    hi = ((packed >> 4) & 0xF).astype(jnp.float32) - 8.0
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)


# ---------------------------------------------------------------------------
# Scale families
# ---------------------------------------------------------------------------

def _reduce_axes(scheme: QScheme, ndim: int):
    if scheme.scaling == "tensor":
        return None, False
    if scheme.scaling == "row":
        return -1, True
    if scheme.scaling == "column":
        return tuple(range(ndim - 1)), False
    return scheme.channel_axis, True      # 'channel'


def compute_scale(x: jax.Array, scheme: QScheme) -> jax.Array:
    """The decode multiplier for ``x`` under ``scheme``'s scaling family.

    zipml grid → M with |x|/M ≤ 1 (the paper's row/column scale);
    int grid   → absmax/qmax (one code step). Zeros map to scale 1 so decode
    of an all-zero tensor is exact. Scales never carry gradients.
    """
    x32 = jax.lax.stop_gradient(x.astype(jnp.float32))
    axes, keepdims = _reduce_axes(scheme, x.ndim)
    m = jnp.max(jnp.abs(x32), axis=axes, keepdims=keepdims)
    if scheme.grid == "int":
        qmax = float(scheme.qmax)
        return jnp.where(m == 0, 1.0, m / qmax).astype(jnp.float32)
    return jnp.where(m == 0, 1.0, m).astype(jnp.float32)


# ---------------------------------------------------------------------------
# QTensor
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
class QTensor:
    """codes + scale(s) (+ optional second DS plane / level table) + scheme."""

    __slots__ = ("codes", "scale", "scheme", "codes2", "levels")

    def __init__(self, codes: jax.Array, scale: jax.Array, scheme: QScheme,
                 codes2: jax.Array | None = None,
                 levels: jax.Array | None = None):
        self.codes = codes
        self.scale = scale
        self.scheme = scheme
        self.codes2 = codes2
        self.levels = levels

    # ------------------------------------------------------------- pytree --
    def tree_flatten(self):
        return (self.codes, self.scale, self.codes2, self.levels), self.scheme

    @classmethod
    def tree_unflatten(cls, scheme, children):
        codes, scale, codes2, levels = children
        return cls(codes, scale, scheme, codes2=codes2, levels=levels)

    # -------------------------------------------------------------- shape --
    @property
    def shape(self):
        return self.codes.shape

    @property
    def ndim(self):
        return self.codes.ndim

    @property
    def size(self):
        return self.codes.size

    @property
    def is_ds(self) -> bool:
        return self.codes2 is not None

    # legacy `Quantized` surface --------------------------------------------
    @property
    def s(self) -> int:
        return self.scheme.s

    @property
    def signed(self) -> bool:
        return self.scheme.signed

    @property
    def bits(self) -> int:
        return self.scheme.bits

    @property
    def nbits(self) -> int:
        """Storage bits per element, host-side. A double-sampled pair costs
        +1 bit on top of the base code width (§2.2 — the same accounting as
        ``benchmarks/bench_bandwidth_model.wire_bytes``)."""
        return self.scheme.code_bits + (1 if self.is_ds else 0)

    @property
    def nbytes(self) -> int:
        """Logical HBM/wire bytes: packed codes + scales + level table."""
        n = int(np.prod(self.codes.shape)) if self.codes.shape else 1
        if self.scheme.packed:
            n *= 2                               # two logical codes per byte
        total = -(-n * self.nbits // 8)          # ceil(n · nbits / 8)
        total += int(np.prod(self.scale.shape) if self.scale.shape else 1) * \
            np.dtype(jnp.float32).itemsize
        if self.levels is not None:
            total += int(np.prod(self.levels.shape)) * \
                np.dtype(jnp.float32).itemsize
        return int(total)

    # ------------------------------------------------------------- decode --
    def _decode_plane(self, codes, dtype=None):
        sch = self.scheme
        if sch.grid == "levels":
            lv = self.levels
            c32 = codes.astype(jnp.int32)
            if lv.ndim == 1:
                out = jnp.take(lv, c32)
            else:
                # per-slice tables: levels (*lead, n_levels) pairs with codes
                # (*lead, ...) — the stacked-layer layout that lets a QTensor
                # ride through lax.scan over layers (each slice gets its table)
                lead = int(np.prod(lv.shape[:-1]))
                out = jax.vmap(jnp.take)(
                    lv.reshape(lead, lv.shape[-1]),
                    c32.reshape(lead, -1)).reshape(codes.shape)
            return out.astype(dtype) if dtype is not None else out
        ct = jnp.float32 if dtype is None else dtype
        if sch.grid == "int":
            if sch.packed:
                codes = unpack_int4(codes)
            return codes.astype(ct) * self.scale.astype(ct)
        return codes.astype(ct) / sch.s * self.scale.astype(ct)

    def decode(self, dtype=None) -> jax.Array:
        """Dequantize the (first) code plane. ``dtype`` selects the multiply
        dtype for the int grid (e.g. bf16 weight dequant); default fp32."""
        return self._decode_plane(self.codes, dtype)

    def decode2(self, dtype=None) -> jax.Array:
        """Dequantize the second double-sampling plane (Q₂)."""
        if self.codes2 is None:
            raise ValueError("QTensor has no second double-sampling plane")
        return self._decode_plane(self.codes2, dtype)

    def dequantize(self) -> jax.Array:   # old Quantized/IntTensor spelling
        return self.decode()

    def dot(self, v: jax.Array, backend: str | None = None) -> jax.Array:
        """decode(self) @ v, dispatched through the kernel-backend registry
        (the Pallas backend streams int8 codes instead of materializing f32)."""
        return dot(self, v, backend=backend)

    def __repr__(self):
        extra = "+ds" if self.is_ds else ""
        return (f"QTensor({self.codes.shape}, {self.scheme.grid}{extra}, "
                f"bits={self.scheme.bits}, scaling={self.scheme.scaling})")


def tree_nbytes(tree) -> int:
    """Logical HBM/wire bytes of a pytree: QTensor leaves contribute their
    packed ``.nbytes`` (codes + scales + level tables, §2.2 pair accounting),
    dense array / ShapeDtypeStruct leaves their ``size × itemsize``. The
    byte model behind the train-step bench and the dry-run channel-state
    line items."""
    total = 0
    for leaf in jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, QTensor)):
        if isinstance(leaf, QTensor):
            total += leaf.nbytes
        else:
            n = int(np.prod(leaf.shape)) if len(leaf.shape) else 1
            total += n * np.dtype(leaf.dtype).itemsize
    return int(total)


# ---------------------------------------------------------------------------
# Pure-jnp encode implementations (what the 'ref' backend runs; the Pallas
# backend is tested bit-exact/distribution-identical against these).
# ---------------------------------------------------------------------------

def encode_jnp(x: jax.Array, scheme: QScheme, key: jax.Array | None = None,
               scale: jax.Array | None = None,
               levels: jax.Array | None = None) -> QTensor:
    """Reference encoder for every grid/rounding — the seed numerics."""
    if scheme.rounding == "stochastic" and key is None:
        raise ValueError("stochastic rounding requires a PRNG key")
    rkey = None if scheme.rounding == "nearest" else key
    if scheme.grid == "levels":
        if levels is None:
            raise ValueError("grid='levels' requires a level table")
        codes, _ = quantize_to_levels_jnp(x, levels, rkey)
        return QTensor(codes, jnp.ones((), jnp.float32), scheme, levels=levels)
    if scale is None:
        scale = compute_scale(x, scheme)
    else:
        scale = jnp.asarray(scale, jnp.float32)
    if scheme.grid == "zipml":
        s = scheme.s
        xn = (jnp.asarray(x) / scale).astype(jnp.float32)
        mag = jnp.clip(jnp.abs(xn) if scheme.signed else xn, 0.0, 1.0)
        codes = _round(mag * s, rkey)
        if scheme.signed:
            codes = codes * jnp.sign(xn)
        return QTensor(codes.astype(_code_dtype(s)), scale, scheme)
    # symmetric int grid (int8 up to 8 bits, int32 above — no silent overflow)
    qmax = float(scheme.qmax)
    t = x.astype(jnp.float32) / scale
    codes = jnp.clip(_round(t, rkey), -qmax, qmax).astype(_code_dtype(scheme.qmax))
    if scheme.packed:
        codes = pack_int4(codes)
    return QTensor(codes, scale, scheme)


def ds_pair_jnp(x: jax.Array, scheme: QScheme, key: jax.Array,
                scale: jax.Array | None = None) -> QTensor:
    """Two independent stochastic planes from one split key — the reference
    double-sampling draw (the fused Pallas path shares the base level)."""
    if key is None:
        raise ValueError("double-sampling ('ds' rounding) requires a PRNG key")
    if scale is None:
        scale = compute_scale(x, scheme)
    one = scheme.with_rounding("stochastic")
    k1, k2 = jax.random.split(key)
    q1 = encode_jnp(x, one, k1, scale=scale)
    q2 = encode_jnp(x, one, k2, scale=scale)
    return QTensor(q1.codes, q1.scale, scheme.with_rounding("ds"),
                   codes2=q2.codes)


def quantize_to_levels_jnp(
    v: jax.Array, levels: jax.Array, key: jax.Array | None = None
) -> tuple[jax.Array, jax.Array]:
    """Stochastic (or nearest, ``key=None``) rounding onto a sorted 1-D level
    set — unbiased inside the level range. Returns (codes, values)."""
    levels = jnp.asarray(levels, jnp.float32)
    v32 = jnp.asarray(v, jnp.float32)
    k = levels.shape[0]
    vc = jnp.clip(v32, levels[0], levels[-1])
    hi_idx = jnp.clip(jnp.searchsorted(levels, vc, side="right"), 1, k - 1)
    lo_idx = hi_idx - 1
    lo = levels[lo_idx]
    hi = levels[hi_idx]
    width = jnp.maximum(hi - lo, 1e-30)
    p_up = (vc - lo) / width
    if key is None:
        up = p_up >= 0.5
    else:
        up = jax.random.uniform(key, v32.shape, dtype=jnp.float32) < p_up
    codes = jnp.where(up, hi_idx, lo_idx)
    values = jnp.where(up, hi, lo)
    return codes.astype(_code_dtype(k - 1)), values


# ---------------------------------------------------------------------------
# Public entry points — dispatch through the kernel-backend registry.
# ---------------------------------------------------------------------------

def _backend(backend):
    from repro.kernels import registry
    return registry.resolve(backend)


def encode(x: jax.Array, scheme: QScheme, key: jax.Array | None = None,
           scale: jax.Array | None = None, levels: jax.Array | None = None,
           backend: "str | Any | None" = None) -> QTensor:
    """Quantize ``x`` under ``scheme``. ``rounding='ds'`` draws both planes."""
    if scheme.rounding == "ds":
        return ds_pair(x, scheme, key, scale=scale, backend=backend)
    return _backend(backend).encode(x, scheme, key, scale=scale, levels=levels)


def decode(qt: QTensor, dtype=None,
           backend: "str | Any | None" = None) -> jax.Array:
    return _backend(backend).decode(qt, dtype=dtype)


def ds_pair(x: jax.Array, scheme: QScheme, key: jax.Array,
            scale: jax.Array | None = None,
            backend: "str | Any | None" = None) -> QTensor:
    """Draw the §2.2 double-sampling pair as one QTensor (codes + codes2)."""
    if key is None:
        raise ValueError("double-sampling ('ds' rounding) requires a PRNG key")
    return _backend(backend).ds_pair(x, scheme, key, scale=scale)


def dot(qt: QTensor, v: jax.Array,
        backend: "str | Any | None" = None) -> jax.Array:
    """decode(qt) @ v — backends may compute it from codes without ever
    materializing the dequantized tensor."""
    return _backend(backend).qt_dot(qt, v)
