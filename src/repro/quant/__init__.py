"""repro.quant — the canonical quantization API (storage + plan).

One storage type, one scheme spec, one precision plan:

* :class:`QScheme`   — frozen spec: bits/levels, scaling family, rounding mode.
* :class:`QTensor`   — registered pytree: codes + scale(s) (+ DS plane /
  level table) + scheme, with ``encode``/``decode``/``dot``/``ds_pair`` entry
  points dispatching through :mod:`repro.kernels.registry`.
* :class:`PrecisionPlan` — the four-channel (sample/model/grad/activation
  + kv) training/serving plan consumed by the linear suite, the LM train
  step, serving and checkpointing. ``core.linear.Precision`` and
  ``models.transformer.PrecisionPlan`` are deprecated aliases of it.
"""
from .plan import PrecisionPlan
from .qtensor import (
    QTensor,
    compute_scale,
    decode,
    dot,
    ds_pair,
    encode,
    pack_bitplanes,
    pack_int4,
    quantize_to_levels_jnp,
    tree_nbytes,
    unpack_bitplanes,
    unpack_int4,
)
from .quant_dense import ShipWeight, quant_dense, quant_dense_q
from .scheme import QScheme

__all__ = [
    "PrecisionPlan",
    "QScheme",
    "QTensor",
    "ShipWeight",
    "compute_scale",
    "decode",
    "dot",
    "ds_pair",
    "encode",
    "pack_bitplanes",
    "pack_int4",
    "quant_dense",
    "quant_dense_q",
    "quantize_to_levels_jnp",
    "tree_nbytes",
    "unpack_bitplanes",
    "unpack_int4",
]
