"""QScheme — the frozen spec of *how* a tensor is quantized.

One scheme describes everything the paper's Q(v, s) family needs to round-trip
a tensor through integer codes:

* **bits / s** — the bit budget. Two grid conventions coexist in ZipML:
  the paper's interval grid (``grid='zipml'``: codes ∈ [-s, s] with
  s = 2^bits − 1 intervals, value = codes/s · M) and the symmetric integer
  grid used by the deep-net channels (``grid='int'``: codes ∈ [-qmax, qmax]
  with qmax = 2^(bits−1) − 1, value = codes · scale). ``grid='levels'``
  stores indices into an arbitrary (variance-optimal, C4) level table.
* **scaling family** — 'tensor' (one scalar), 'row' (per-row, last axis),
  'column' (per-feature, App. A.3), 'channel' (per-out-channel, reduction
  over ``channel_axis``).
* **rounding mode** — 'stochastic' (unbiased, Lemma 6), 'nearest'
  (deterministic, the §5.4 straw man), 'ds' (double sampling §2.2: two
  independent stochastic planes sharing one base level, +1 bit of storage).
* **packed** — physical nibble packing for the 4-bit int grid: two
  offset-binary codes per uint8 byte (what the serving KV cache stores).
  Logical semantics are identical to the unpacked int4 grid; only the
  storage bytes halve.
* **layout** — 'dense' (one code per element, the default) or 'bitplane'
  (MLWeaving bit-serial storage: a sign plane + ``bits`` magnitude planes,
  MSB first, each packed 32 elements per uint32 word). One bitplane
  artifact serves ANY precision 1..bits — ``QTensor.slice_planes(k)`` is a
  pure view whose decode is value-identical to direct k-bit encoding,
  because the magnitude is truncated (⌊|x|·2^B/scale⌋ nests under
  right-shift where nearest rounding would not).

Schemes are frozen/hashable so they ride as static pytree aux data on
``QTensor`` — ``jit``/``vmap``/``lax.scan`` treat them as compile-time
constants.
"""
from __future__ import annotations

import dataclasses

GRIDS = ("int", "zipml", "levels")
SCALINGS = ("tensor", "row", "column", "channel")
ROUNDINGS = ("stochastic", "nearest", "ds")
LAYOUTS = ("dense", "bitplane")


@dataclasses.dataclass(frozen=True)
class QScheme:
    bits: int = 8
    grid: str = "int"
    scaling: str = "tensor"
    rounding: str = "stochastic"
    signed: bool = True
    s: int = 0                 # zipml intervals; 0 → 2**bits − 1
    channel_axis: int = -2     # reduction axis for 'channel' scaling
    packed: bool = False       # nibble-packed storage (int grid, bits=4)
    layout: str = "dense"      # physical storage: 'dense' | 'bitplane'
    vec_dim: int = 0           # bitplane only: logical last-dim length
                               # (set at encode time; words lose ceil info)

    def __post_init__(self):
        if self.grid not in GRIDS:
            raise ValueError(f"unknown grid {self.grid!r}; have {GRIDS}")
        if self.scaling not in SCALINGS:
            raise ValueError(f"unknown scaling {self.scaling!r}; have {SCALINGS}")
        if self.rounding not in ROUNDINGS:
            raise ValueError(f"unknown rounding {self.rounding!r}; have {ROUNDINGS}")
        if self.packed and (self.grid != "int" or self.bits != 4 or not self.signed):
            raise ValueError("packed storage is the signed 4-bit int grid only")
        if self.layout not in LAYOUTS:
            raise ValueError(f"unknown layout {self.layout!r}; have {LAYOUTS}")
        if self.layout == "bitplane":
            if self.grid != "int" or not self.signed or self.packed:
                raise ValueError(
                    "bitplane layout is the signed int grid only (unpacked)")
            if not 1 <= self.bits <= 8:
                raise ValueError(
                    f"bitplane layout serves 1..8 bits, got {self.bits}")
            if self.rounding != "nearest":
                # bitplane magnitudes are truncated so plane slices nest;
                # stochastic/ds rounding cannot nest, so the scheme pins the
                # deterministic mode
                raise ValueError("bitplane layout requires rounding='nearest'")
        if self.grid == "zipml" and self.s == 0:
            object.__setattr__(self, "s", 2 ** self.bits - 1)

    # -- derived grid constants (all host-side Python ints: no jnp on ints) --
    @property
    def qmax(self) -> int:
        """Largest magnitude code of the symmetric int grid."""
        return 2 ** (self.bits - 1) - 1

    @property
    def code_bits(self) -> int:
        """Storage width of one code in bits (host-side; satellite of the old
        ``Quantized.nbits`` which ran ``jnp.ceil(jnp.log2(...))`` on a Python
        int). For the zipml grid this is ⌈log₂(s+1)⌉ = s.bit_length(); a
        bitplane tensor pays +1 for the sign plane."""
        if self.grid == "zipml":
            return max(int(self.s).bit_length(), 1)
        if self.layout == "bitplane":
            return self.bits + 1
        return self.bits

    def with_rounding(self, rounding: str) -> "QScheme":
        return dataclasses.replace(self, rounding=rounding)

    # -- conventional constructors ------------------------------------------
    @classmethod
    def zipml(cls, s: int, *, scaling: str = "tensor",
              rounding: str = "stochastic", signed: bool = True) -> "QScheme":
        """The paper's Q(v, s): s intervals on [0, 1] (signed: [-1, 1])."""
        return cls(bits=max(int(s).bit_length(), 1), grid="zipml",
                   scaling=scaling, rounding=rounding, signed=signed, s=int(s))

    @classmethod
    def int_symmetric(cls, bits: int, *, scaling: str = "tensor",
                      rounding: str = "stochastic",
                      channel_axis: int = -2, packed: bool = False) -> "QScheme":
        """Symmetric integer grid: value ≈ codes · scale, scale = absmax/qmax.

        ``packed=True`` (bits=4 only) stores two offset-binary nibbles per
        uint8 byte — same values, half the storage bytes."""
        return cls(bits=int(bits), grid="int", scaling=scaling,
                   rounding=rounding, channel_axis=channel_axis, packed=packed)

    @classmethod
    def bitplane(cls, bits: int = 8, *, scaling: str = "channel",
                 channel_axis: int = -2) -> "QScheme":
        """MLWeaving bit-serial storage: sign plane + ``bits`` magnitude
        planes (MSB first), 32 elements per uint32 word. One artifact serves
        any precision 1..bits via ``QTensor.slice_planes(k)``."""
        return cls(bits=int(bits), grid="int", scaling=scaling,
                   rounding="nearest", channel_axis=channel_axis,
                   layout="bitplane")

    @classmethod
    def levels(cls, n_levels: int, *, rounding: str = "nearest") -> "QScheme":
        """Arbitrary (variance-optimal) level-table storage, C4."""
        return cls(bits=max(int(n_levels - 1).bit_length(), 1), grid="levels",
                   rounding=rounding, s=int(n_levels - 1))
