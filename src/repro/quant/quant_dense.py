"""quant_dense — the one registry op every QTensor-weighted matmul routes
through (train, prefill, and decode alike).

ZipML's order-of-magnitude claim is that *code bytes*, not floats, move
through the memory hierarchy on every linear operation. ``layers.dense``
used to call ``QTensor.decode(bf16)`` and hope XLA fused the dequant into
the operand read; this op makes the data movement explicit and owns its
backward:

* **forward** — dispatches through :mod:`repro.kernels.registry`:
  the ``ref`` backend is decode-then-einsum at bf16 (bit-exact with the
  pre-op model numerics); the ``pallas`` backend streams int8 / packed-int4
  code blocks HBM→VMEM and dequantizes in VMEM (kernels/qmm.py).
* **backward** — a ``jax.custom_vjp`` in the *code domain*:
  dx = dy · (codes ⊙ scale)ᵀ via the transpose kernel, so the backward also
  streams codes instead of re-decoding a full-width weight (HALP's point:
  lose the backward and the bandwidth win evaporates). Integer code planes
  receive symbolic-zero (float0) cotangents.
* **quantize epilogue** — ``quant_dense_q(x, w, key)`` returns the §2.2
  double-sampled row-quantized QTensor of the *output* instead of the dense
  activation; the Pallas backend emits both code planes straight from the
  fp32 accumulator tile in VMEM (see ``precision.act_quant.ds_project``).

:class:`ShipWeight` carries the quantize-on-gather training form — the int
codes that moved through the FSDP all-gather *plus* the fp32/bf16 master the
straight-through gradient flows to — so the ship model channel trains while
its matmuls stream codes.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from .qtensor import QTensor


def _registry():
    from repro.kernels import registry

    return registry


@jax.tree_util.register_pytree_node_class
class ShipWeight:
    """A shipped (quantize-on-gather) weight: ``qt`` int codes for the
    matmul + the dense ``master`` the STE gradient flows back to."""

    __slots__ = ("master", "qt")

    def __init__(self, master: jax.Array, qt: QTensor):
        self.master = master
        self.qt = qt

    def tree_flatten(self):
        return (self.master, self.qt), None

    @classmethod
    def tree_unflatten(cls, _, children):
        return cls(*children)

    @property
    def shape(self):
        return self.qt.shape

    @property
    def ndim(self):
        return self.qt.ndim

    def __repr__(self):
        return f"ShipWeight({self.qt!r})"


def _qt_zero_cot(qt: QTensor) -> QTensor:
    """Cotangent for a QTensor input: float0 for the integer code planes,
    real zeros for the float children (scale / levels)."""

    def z(leaf):
        if leaf is None:
            return None
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            return jnp.zeros_like(leaf)
        return np.zeros(leaf.shape, jax.dtypes.float0)

    return QTensor(z(qt.codes), z(qt.scale), qt.scheme,
                   codes2=z(qt.codes2), levels=z(qt.levels))


def _dw_eq(x_ndim: int, w_ndim: int, transpose: bool) -> str:
    """einsum equation of the weight cotangent Σ_batch x ⊗ dy (STE: the
    gradient wrt the decoded weight passes straight to the master)."""
    s = w_ndim - 2
    stack = "abcdefg"[:s]
    if transpose:
        return f"...{stack}mk,...{stack}mn->{stack}nk" if s else \
            "...k,...n->nk"
    return f"...{stack}mk,...{stack}mn->{stack}kn" if s else "...k,...n->kn"


def _qd_impl(x, qt, backend, transpose):
    return _registry().resolve(backend).quant_dense(x, qt,
                                                    transpose=transpose)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _qd(x, qt: QTensor, backend, transpose):
    return _qd_impl(x, qt, backend, transpose)


def _qd_fwd(x, qt, backend, transpose):
    return _qd_impl(x, qt, backend, transpose), (x, qt)


def _qd_bwd(backend, transpose, res, g):
    x, qt = res
    b = _registry().resolve(backend)
    dx = b.quant_dense(g, qt, transpose=not transpose).astype(x.dtype)
    return dx, _qt_zero_cot(qt)


_qd.defvjp(_qd_fwd, _qd_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _qd_ste(x, master, qt: QTensor, backend, transpose):
    return _qd_impl(x, qt, backend, transpose)


def _qd_ste_fwd(x, master, qt, backend, transpose):
    return _qd_impl(x, qt, backend, transpose), (x, master, qt)


def _qd_ste_bwd(backend, transpose, res, g):
    x, master, qt = res
    b = _registry().resolve(backend)
    dx = b.quant_dense(g, qt, transpose=not transpose).astype(x.dtype)
    # bf16 masters get the cotangent emitted in bf16 straight out of the
    # einsum (moe._ge_bwd's trick): the cross-device psum of a sharded
    # contraction rides on the einsum OUTPUT, so a later astype would run
    # after the all-reduce and halve nothing
    pref = jnp.bfloat16 if master.dtype == jnp.bfloat16 else jnp.float32
    dw = jnp.einsum(_dw_eq(x.ndim, qt.ndim, transpose), x, g,
                    preferred_element_type=pref).astype(master.dtype)
    return dx, dw, _qt_zero_cot(qt)


_qd_ste.defvjp(_qd_ste_fwd, _qd_ste_bwd)


def quant_dense(x: jax.Array, w, *, transpose: bool = False,
                backend: str | None = None) -> jax.Array:
    """y = x · W (or x · Wᵀ) for a quantized weight, f32 result.

    ``w``: a :class:`QTensor` (codes stream through the kernel backend, the
    custom VJP keeps the backward in the code domain), a :class:`ShipWeight`
    (same, plus the straight-through master gradient), or a dense array
    (plain einsum — the unquantized path is untouched). Weight shape
    (*stack, K, N); x (*lead, *stack, M, K) — the stack dims cover MoE
    expert tables and unscanned stacked layers. ``transpose`` contracts
    against Wᵀ (tied unembed / the backward itself).
    """
    if isinstance(w, ShipWeight):
        return _qd_ste(x, w.master, w.qt, backend, transpose)
    if isinstance(w, QTensor):
        return _qd(x, w, backend, transpose)
    reg = _registry()
    return jnp.einsum(reg.matmul_eq(jnp.ndim(x), jnp.ndim(w), transpose),
                      x, w, preferred_element_type=jnp.float32)


def quant_dense_q(x: jax.Array, w, key: jax.Array, *, bits: int = 8,
                  backend: str | None = None) -> QTensor:
    """``quant_dense`` with the fused quantize epilogue: returns the §2.2
    double-sampled row-scaled int-grid pair of the output activation as one
    QTensor (codes + codes2 + row scales) — the storage a quantized
    activation channel consumes — instead of the dense y. Forward-only (the
    consumer's VJP owns the backward; see act_quant.ds_dense)."""
    if isinstance(w, ShipWeight):
        w = w.qt
    if isinstance(w, QTensor):
        return _registry().resolve(backend).quant_dense_out_q(
            x, w, key, bits=bits)
    from . import ds_pair
    from .scheme import QScheme

    reg = _registry()
    y = jnp.einsum(reg.matmul_eq(jnp.ndim(x), jnp.ndim(w), False), x, w,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return ds_pair(y, QScheme.int_symmetric(bits, scaling="row",
                                            rounding="ds"), key)
