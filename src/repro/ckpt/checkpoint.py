"""Checkpointing: async threaded save, atomic rename, elastic restore.

Format: one directory per step —
    step_000123/
      manifest.json   (step, mesh shape, pipeline cursor, PRNG key, tree def)
      arrays.npz      (flat leaves, addressable data gathered per host)
      .complete       (commit marker — written last; readers ignore dirs
                       without it, so a crash mid-save can never corrupt)

Elastic restore: arrays are saved unsharded-logical (each host writes the
global array assembled from its addressable shards; single-host here). On
restore, `jax.device_put` with the *current* mesh's shardings redistributes —
so a checkpoint written on a 16×16 mesh restores onto 2×16×16 or a single CPU
(scale-up/down). Failure-domain metadata records what wrote the checkpoint.

At 1000+ nodes each host would write only its shard set (ocdbt-style); the
single-host container exercises the same API surface.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import time
from typing import Any

import numpy as np
import jax


def jnp_cast(arr, dtype):
    import jax.numpy as jnp
    return np.asarray(jnp.asarray(arr).astype(dtype))


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, *, extra: dict | None = None,
             blocking: bool = False):
        """Snapshot on the caller thread (cheap host copies), write in a
        background thread. A second save while one is in flight blocks until
        the first commits (bounded staleness, never overlapping writers)."""
        self.wait()
        leaves, treedef = jax.tree.flatten(tree)
        # device→host snapshot; bf16 has no portable npz representation, so
        # store it viewed as uint16 (dtype recorded per leaf in the manifest)
        host_leaves = []
        leaf_dtypes = []
        for x in leaves:
            a = np.asarray(x)
            leaf_dtypes.append(str(a.dtype))
            if a.dtype.itemsize == 2 and "float" in str(a.dtype):
                a = a.view(np.uint16)
            host_leaves.append(a)
        manifest = {
            "step": int(step),
            "treedef": str(treedef),
            "leaf_dtypes": leaf_dtypes,
            "n_leaves": len(host_leaves),
            "time": time.time(),
            "n_devices": jax.device_count(),
            "process_index": jax.process_index(),
            "extra": extra or {},
        }
        self._thread = threading.Thread(
            target=self._write, args=(step, host_leaves, manifest), daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def _write(self, step: int, leaves, manifest):
        try:
            final = os.path.join(self.directory, f"step_{step:09d}")
            tmp = tempfile.mkdtemp(dir=self.directory, prefix=".tmp_")
            np.savez(os.path.join(tmp, "arrays.npz"),
                     **{f"leaf_{i}": a for i, a in enumerate(leaves)})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            open(os.path.join(tmp, ".complete"), "w").close()
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()
        except Exception as e:  # surfaced on next wait()
            self._error = e

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.directory, name, ".complete")):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: int | None = None,
                shardings: Any = None) -> tuple[Any, dict]:
        """Rebuild ``template``-structured tree. ``shardings`` (optional
        pytree matching template) redistributes onto the current mesh —
        elastic restore across different mesh shapes."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        leaves_t, treedef = jax.tree.flatten(template)
        if manifest["n_leaves"] != len(leaves_t):
            raise ValueError(
                f"checkpoint has {manifest['n_leaves']} leaves, template "
                f"{len(leaves_t)} — architecture mismatch")
        new_leaves = []
        shard_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                        else [None] * len(leaves_t))
        import ml_dtypes  # ships with jax
        for i, (t, s) in enumerate(zip(leaves_t, shard_leaves)):
            arr = data[f"leaf_{i}"]
            stored = manifest.get("leaf_dtypes", [None] * len(leaves_t))[i]
            if stored and arr.dtype == np.uint16 and "float" in stored:
                arr = arr.view(np.dtype(getattr(ml_dtypes, stored, stored)))
            if tuple(arr.shape) != tuple(t.shape):
                raise ValueError(f"leaf {i}: shape {arr.shape} != {t.shape}")
            if arr.dtype != t.dtype:
                arr = jnp_cast(arr, t.dtype)
            new_leaves.append(jax.device_put(arr, s) if s is not None
                              else jax.device_put(arr))
        return jax.tree.unflatten(treedef, new_leaves), manifest
