from . import checkpoint  # noqa: F401
from .checkpoint import CheckpointManager  # noqa: F401
