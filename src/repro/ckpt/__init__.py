from . import checkpoint, ship  # noqa: F401
from .checkpoint import CheckpointManager  # noqa: F401
from .ship import ShipArtifactError, load_ship_weights, save_ship_weights  # noqa: F401
