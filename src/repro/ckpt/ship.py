"""Ship-weight artifact: ONE bit-plane file serves every precision.

``weights-bitplane-v1`` replaces the per-bit-width ship formats (a separate
int8 artifact, a separate packed-int4 artifact, ...): the weights are stored
bit-serially (``repro.quant`` ``layout='bitplane'``, sign plane + magnitude
planes MSB-first), so one artifact on disk serves any precision
1..``bits`` — the loader takes the top-k planes via
``QTensor.slice_planes(k)`` and never touches the rest. Legacy spliced
weight dicts keep loading through
:func:`repro.precision.qat.migrate_spliced_weights`; this module is the
forward path.

Layout (the :class:`repro.ckpt.CheckpointManager` idiom — atomic tmp →
rename, ``.complete`` commit marker written last):

    <dir>/
      manifest.json   format, stored bits, per-leaf path/kind/scheme/dtype
      arrays.npz      leaf_i_codes + leaf_i_scale (QTensor) or leaf_i (array)
      .complete       readers ignore directories without it

Non-weight leaves (norm gains, embedding tables, ...) ride along unchanged;
bf16 is stored viewed as uint16 with the dtype recorded per leaf (npz has no
portable bf16). The tree structure is serialized as per-leaf key paths
(nested dicts/lists), so loading needs no template.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import tempfile
import zipfile
import zlib
from typing import Any

import numpy as np
import jax

from repro.quant import QScheme, QTensor

FORMAT = "weights-bitplane-v1"


class ShipArtifactError(RuntimeError):
    """A committed ship-weights artifact is unreadable — truncated,
    bit-rotted, or torn by a partial copy. The ``.complete`` marker guards
    against interrupted *writes*; this error covers corruption discovered
    **after** commit, and always names the fix (re-run
    :func:`save_ship_weights` / restore the artifact from a good copy)
    instead of surfacing a raw numpy/zipfile traceback."""


def _path_keys(path) -> list:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(p.key)
        elif hasattr(p, "idx"):
            out.append(int(p.idx))
        else:
            raise TypeError(f"unsupported tree path entry {p!r}")
    return out


def _host(a) -> tuple[np.ndarray, str]:
    """Device→host with the bf16-as-uint16 npz workaround."""
    a = np.asarray(a)
    dt = str(a.dtype)
    if a.dtype.itemsize == 2 and "float" in dt:
        a = a.view(np.uint16)
    return a, dt


def _unhost(a: np.ndarray, dtype: str) -> np.ndarray:
    if a.dtype == np.uint16 and "float" in dtype:
        import ml_dtypes  # ships with jax
        return a.view(np.dtype(getattr(ml_dtypes, dtype, dtype)))
    return a


def save_ship_weights(directory: str, params: Any, *,
                      extra: dict | None = None) -> str:
    """Write ``params`` (bitplane-quantized tree) as one any-precision
    artifact. Requires at least one ``layout='bitplane'`` QTensor leaf —
    use ``quantize_param_tree(..., layout='bitplane')`` first."""
    leaves = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: isinstance(x, QTensor))[0]
    manifest_leaves, arrays = [], {}
    bits = 0
    for i, (path, leaf) in enumerate(leaves):
        entry: dict = {"path": _path_keys(path)}
        if isinstance(leaf, QTensor):
            if leaf.scheme.layout != "bitplane":
                raise ValueError(
                    f"{FORMAT} stores bitplane QTensors only; leaf "
                    f"{entry['path']} has layout={leaf.scheme.layout!r} — "
                    "quantize with quantize_param_tree(..., layout='bitplane')")
            entry["kind"] = "qtensor"
            entry["scheme"] = dataclasses.asdict(leaf.scheme)
            arrays[f"leaf_{i}_codes"], _ = _host(leaf.codes)
            arrays[f"leaf_{i}_scale"], entry["scale_dtype"] = _host(leaf.scale)
            bits = max(bits, leaf.scheme.bits)
        else:
            entry["kind"] = "array"
            arrays[f"leaf_{i}"], entry["dtype"] = _host(leaf)
        manifest_leaves.append(entry)
    if bits == 0:
        raise ValueError(
            f"{FORMAT} needs at least one bitplane QTensor leaf — got none")
    manifest = {"format": FORMAT, "bits": bits, "n_leaves": len(leaves),
                "leaves": manifest_leaves, "extra": extra or {}}
    os.makedirs(directory, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=os.path.dirname(os.path.abspath(directory))
                           or ".", prefix=".tmp_ship_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        open(os.path.join(tmp, ".complete"), "w").close()
        if os.path.exists(directory):
            shutil.rmtree(directory)
        os.rename(tmp, directory)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return directory


def _insert(tree: dict, keys: list, value) -> None:
    node = tree
    for k in keys[:-1]:
        node = node.setdefault(k, {})
    node[keys[-1]] = value


def _listify(node):
    """Dicts whose keys are exactly 0..n-1 were list levels — restore them."""
    if not isinstance(node, dict):
        return node
    node = {k: _listify(v) for k, v in node.items()}
    if node and all(isinstance(k, int) for k in node):
        if sorted(node) == list(range(len(node))):
            return [node[i] for i in range(len(node))]
    return node


def load_ship_weights(directory: str, bits: int | None = None) -> Any:
    """Rebuild the param tree from a ``weights-bitplane-v1`` artifact.

    ``bits=k`` serves the top-k planes (``slice_planes`` on every bitplane
    leaf — same values as quantizing directly at k bits); ``None`` loads the
    full stored precision. Either way only one artifact exists on disk."""
    if not os.path.exists(os.path.join(directory, ".complete")):
        raise FileNotFoundError(
            f"{directory} is not a committed ship artifact (.complete "
            "missing — the save was interrupted before commit; re-run "
            "save_ship_weights)")
    try:
        with open(os.path.join(directory, "manifest.json")) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise ShipArtifactError(
            f"{directory} is corrupt: manifest.json is missing or unreadable "
            f"({e}) despite the .complete marker — restore the artifact from "
            "a good copy or re-run save_ship_weights") from e
    if manifest.get("format") != FORMAT:
        raise ValueError(
            f"{directory} has format {manifest.get('format')!r}, expected "
            f"{FORMAT!r} (legacy spliced dicts load via "
            "repro.precision.qat.migrate_spliced_weights)")
    if bits is not None and not 1 <= bits <= manifest["bits"]:
        raise ValueError(
            f"bits={bits} not servable by a {manifest['bits']}-bit artifact")
    # npz truncation surfaces differently per failure point — BadZipFile
    # (chopped central directory), EOFError/zlib.error (chopped member),
    # KeyError (missing member), ValueError (short read into the array) —
    # and all of them mean the same thing to a caller: the committed
    # artifact's data is unreadable. One clean error, one fix.
    try:
        data = np.load(os.path.join(directory, "arrays.npz"))
        tree: dict = {}
        for i, entry in enumerate(manifest["leaves"]):
            if entry["kind"] == "qtensor":
                scheme = QScheme(**entry["scheme"])
                qt = QTensor(
                    jax.numpy.asarray(data[f"leaf_{i}_codes"]),
                    jax.numpy.asarray(
                        _unhost(data[f"leaf_{i}_scale"],
                                entry["scale_dtype"])),
                    scheme)
                if bits is not None and bits < scheme.bits:
                    qt = qt.slice_planes(bits)
                leaf = qt
            else:
                leaf = jax.numpy.asarray(
                    _unhost(data[f"leaf_{i}"], entry["dtype"]))
            _insert(tree, entry["path"], leaf)
    except (zipfile.BadZipFile, OSError, EOFError, KeyError, ValueError,
            zlib.error) as e:
        raise ShipArtifactError(
            f"{directory} is corrupt or truncated: arrays.npz failed to "
            f"read ({type(e).__name__}: {e}) despite the .complete marker — "
            "the data was damaged after commit; restore the artifact from a "
            "good copy or re-run save_ship_weights") from e
    return _listify(tree)


__all__ = ["FORMAT", "ShipArtifactError", "load_ship_weights",
           "save_ship_weights"]
