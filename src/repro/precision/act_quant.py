"""§3.4 — double-sampled activation quantization for deep nets (beyond-paper).

For a linear layer y = x·W the saved activation x is consumed TWICE — forward
matmul and backward outer-product ∂W = xᵀ·δ. That is precisely the quadratic
reuse double sampling (C2) fixes for linear models: store two *independent*
stochastic quantizations Q₁(x), Q₂(x); use Q₁ in the forward, Q₂ in the
backward. Then E[∂W] = E[Q₂(x)]ᵀ·δ = xᵀ·δ — the weight gradient is unbiased
in the activation quantization (Lemma 7's argument), while the saved-
activation memory drops 2×/4× (int8/int4 codes instead of bf16).

Storage cost: per the paper §2.2, Q₁ and Q₂ share the same base level and
differ by one stochastic bit, so the second sample costs 1 extra bit — the
bandwidth model in benchmarks/bench_bandwidth_model.py accounts it that way.

``ds_dense(x, w, key)`` is a drop-in einsum with this behavior (custom_vjp);
``ds_mlp`` wires it through a gated MLP block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _quant(x, bits, key):
    """Per-tensor symmetric stochastic quantization → (codes int8, scale)."""
    x32 = x.astype(jnp.float32)
    qmax = float(2 ** (bits - 1) - 1)
    absmax = jax.lax.stop_gradient(jnp.max(jnp.abs(x32)))
    scale = jnp.where(absmax == 0, 1.0, absmax / qmax)
    t = x32 / scale
    lo = jnp.floor(t)
    codes = lo + (jax.random.uniform(key, x.shape) < (t - lo)).astype(jnp.float32)
    return jnp.clip(codes, -qmax, qmax).astype(jnp.int8), scale


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def ds_dense(x, w, key, bits: int = 8):
    """y = Q₁(x)·W with ∂W computed from the independent Q₂(x)."""
    k1, _ = jax.random.split(key)
    c1, s1 = _quant(x, bits, k1)
    xq = c1.astype(x.dtype) * s1.astype(x.dtype)
    return jnp.einsum("...i,io->...o", xq, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def _ds_fwd(x, w, key, bits):
    k1, k2 = jax.random.split(key)
    c1, s1 = _quant(x, bits, k1)
    c2, s2 = _quant(x, bits, k2)
    xq1 = c1.astype(x.dtype) * s1.astype(x.dtype)
    y = jnp.einsum("...i,io->...o", xq1, w,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    # residuals: int8 codes + scales (the memory win) + the weight reference
    return y, (c2, s2, w)


def _ds_bwd(bits, res, g):
    c2, s2, w = res
    xdt = w.dtype
    xq2 = c2.astype(xdt) * s2.astype(xdt)
    gx = jnp.einsum("...o,io->...i", g, w,
                    preferred_element_type=jnp.float32).astype(xdt)
    flat_g = g.reshape(-1, g.shape[-1])
    flat_x = xq2.reshape(-1, xq2.shape[-1])
    gw = jnp.einsum("ni,no->io", flat_x, flat_g,
                    preferred_element_type=jnp.float32).astype(w.dtype)
    return gx, gw, None


ds_dense.defvjp(_ds_fwd, _ds_bwd)


def ds_mlp(p, x, key, act: str = "silu", bits: int = 8):
    """Gated MLP with double-sampled activation quantization on all three
    matmuls (drop-in for models/layers.mlp when the plan enables act_ds)."""
    k1, k2, k3 = jax.random.split(key, 3)
    hg = ds_dense(x, p["gate"]["w"], k1, bits)
    hu = ds_dense(x, p["up"]["w"], k2, bits)
    a = jax.nn.silu(hg) if act == "silu" else jax.nn.gelu(hg, approximate=True)
    return ds_dense(a * hu, p["down"]["w"], k3, bits)
