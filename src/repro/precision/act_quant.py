"""§3.4 — double-sampled activation quantization for deep nets (beyond-paper).

For a linear layer y = x·W the saved activation x is consumed TWICE — forward
matmul and backward outer-product ∂W = xᵀ·δ. That is precisely the quadratic
reuse double sampling (C2) fixes for linear models: store two *independent*
stochastic quantizations Q₁(x), Q₂(x); use Q₁ in the forward, Q₂ in the
backward. Then E[∂W] = E[Q₂(x)]ᵀ·δ = xᵀ·δ — the weight gradient is unbiased
in the activation quantization (Lemma 7's argument), while the saved-
activation memory drops 2×/4× (int8/int4 codes instead of bf16).

Storage cost: per the paper §2.2, Q₁ and Q₂ share the same base level and
differ by one stochastic bit, so the second sample costs 1 extra bit — the
bandwidth model in benchmarks/bench_bandwidth_model.py accounts it that way,
and ``QTensor.nbits`` on the saved pair reports exactly bits+1.

The quantizer itself is the canonical :func:`repro.quant.ds_pair` (per-tensor
symmetric int grid); the former inline ``_quant`` copy is gone.

``ds_dense(x, w, key)`` is a drop-in einsum with this behavior (custom_vjp);
``ds_mlp`` wires it through a gated MLP block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import quant
from repro.quant import QScheme


def _act_scheme(bits: int) -> QScheme:
    """Per-tensor symmetric int grid, double-sampled stochastic rounding."""
    return QScheme.int_symmetric(bits, scaling="tensor", rounding="ds")


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def ds_dense(x, w, key, bits: int = 8):
    """y = Q₁(x)·W with ∂W computed from the independent Q₂(x)."""
    # primal (no grad requested): draw only the Q₁ plane, with the same split
    # key ds_pair uses for plane 1 — identical numerics, half the rounding work
    k1, _ = jax.random.split(key)
    qx = quant.encode(x, _act_scheme(bits).with_rounding("stochastic"), k1)
    xq = qx.decode(x.dtype)
    return jnp.einsum("...i,io->...o", xq, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def _ds_fwd(x, w, key, bits):
    from repro.quant import QTensor

    qx = quant.ds_pair(x, _act_scheme(bits), key)
    xq1 = qx.decode(x.dtype)
    y = jnp.einsum("...i,io->...o", xq1, w,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    # residuals: ONLY the Q₂ plane (int8 codes — the memory win) + the
    # weights; saving the consumed Q₁ plane too would double the stored
    # activation-code bytes whenever XLA can't DCE across the fwd/bwd cut
    q2 = QTensor(qx.codes2, qx.scale, qx.scheme.with_rounding("stochastic"))
    return y, (q2, w)


def _ds_bwd(bits, res, g):
    q2, w = res
    xq2 = q2.decode(w.dtype)
    gx = jnp.einsum("...o,io->...i", g, w,
                    preferred_element_type=jnp.float32).astype(w.dtype)
    flat_g = g.reshape(-1, g.shape[-1])
    flat_x = xq2.reshape(-1, xq2.shape[-1])
    gw = jnp.einsum("ni,no->io", flat_x, flat_g,
                    preferred_element_type=jnp.float32).astype(w.dtype)
    return gx, gw, None


ds_dense.defvjp(_ds_fwd, _ds_bwd)


def ds_mlp(p, x, key, act: str = "silu", bits: int = 8):
    """Gated MLP with double-sampled activation quantization on all three
    matmuls (drop-in for models/layers.mlp when the plan enables act_bits)."""
    k1, k2, k3 = jax.random.split(key, 3)
    hg = ds_dense(x, p["gate"]["w"], k1, bits)
    hu = ds_dense(x, p["up"]["w"], k2, bits)
    a = jax.nn.silu(hg) if act == "silu" else jax.nn.gelu(hg, approximate=True)
    return ds_dense(a * hu, p["down"]["w"], k3, bits)


def ds_project(x, w, key, bits: int = 8, backend: str | None = None):
    """Projection with the fused quantize epilogue: y = x·W is emitted
    directly as its §2.2 double-sampled row-quantized pair — one QTensor
    holding both int8 code planes + (…, 1) row scales — instead of a dense
    activation. This is the matmul-output mirror of the PR-1 ds_quant
    fusion: on the Pallas backend the codes come straight off the fp32
    accumulator tile in VMEM (kernels/qmm.qmm_qout), so the full-width
    activation write *and* its quantize-pass re-read both disappear; the
    ref backend is einsum → cast → ds_pair, the exact unfused numerics.

    ``w`` may be a dense array, a QTensor (int storage: the forward also
    streams weight codes), or a ShipWeight. Forward-only — the consumer of
    the pair owns the backward (e.g. ``ds_dense``'s VJP contracts the Q₂
    plane). Decode Q₁ via ``.decode(dtype)``, Q₂ via ``.decode2(dtype)``.

    Integration status: this is the exposed consumer of the epilogue (plus
    ``benchmarks/bench_qmm.py``, which pins the byte saving from the op
    I/O signatures). ``ds_mlp``'s own matmul outputs pass through
    silu/multiply before the next quantize, so the gated-MLP block has no
    direct matmul→quantize edge to fuse — wiring the epilogue into a model
    block needs an architecture with back-to-back quantized projections
    (or a fused gate+up+act kernel), which is future work.
    """
    from repro.quant import quant_dense_q

    return quant_dense_q(x, w, key, bits=bits, backend=backend)
