"""C3 — quantized gradient collectives with error feedback (the paper's
gradient channel on the TPU ICI/DCI mesh).

Two layers:

* ``compress_tree`` / ``decompress_tree`` — ZipML row-scaled stochastic
  quantization (C1, unbiased) of a gradient pytree into int8 codes + scales.
  With ``error_feedback`` state, the quantization residual is carried to the
  next step (telescoping bias cancellation — needed because an all-reduce sums
  many quantized terms per step; the single-worker analysis of App. D does
  not cover the accumulated worst case, EF restores it).

* ``make_compressed_psum(axis)`` — a shard_map-manual all-reduce over one mesh
  axis (the cross-pod 'pod' axis in production: the slowest link is exactly
  the paper's Fig. 2 gradient channel): quantize → all_gather(codes+scales) →
  dequantize → mean. Wire bytes drop 4× at 8 bits / 8× at 4 bits vs bf16.

The train driver composes: grads are already data-axis-reduced by GSPMD inside
the pod (cheap ICI); the compressed psum handles only the 'pod' axis (DCI).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class CompressedLeaf(NamedTuple):
    codes: jax.Array      # int8 in [-qmax, qmax]
    scale: jax.Array      # () fp32 per tensor


def _quantize_leaf(g: jax.Array, bits: int, key) -> CompressedLeaf:
    g32 = g.astype(jnp.float32)
    qmax = float(2 ** (bits - 1) - 1)
    absmax = jnp.max(jnp.abs(g32))
    scale = jnp.where(absmax == 0, 1.0, absmax / qmax)
    t = g32 / scale
    lo = jnp.floor(t)
    codes = lo + (jax.random.uniform(key, g.shape) < (t - lo)).astype(jnp.float32)
    return CompressedLeaf(jnp.clip(codes, -qmax, qmax).astype(jnp.int8),
                          scale.astype(jnp.float32))


def _dequantize_leaf(c: CompressedLeaf) -> jax.Array:
    return c.codes.astype(jnp.float32) * c.scale


def compress_tree(grads, bits: int, key, error: Any | None = None):
    """Quantize a gradient pytree. Returns (compressed, new_error).

    ``error``: error-feedback pytree (same structure, fp32) added before
    quantization; new_error = (g + e) − Q(g + e).
    """
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))
    err_leaves = jax.tree.leaves(error) if error is not None else [None] * len(leaves)
    comp, new_err = [], []
    for g, e, k in zip(leaves, err_leaves, keys):
        g32 = g.astype(jnp.float32)
        if e is not None:
            g32 = g32 + e
        c = _quantize_leaf(g32, bits, k)
        comp.append(c)
        new_err.append(g32 - _dequantize_leaf(c))
    return (jax.tree.unflatten(treedef, comp),
            jax.tree.unflatten(treedef, new_err))


def decompress_tree(comp):
    return jax.tree.map(_dequantize_leaf, comp,
                        is_leaf=lambda x: isinstance(x, CompressedLeaf))


def init_error_feedback(grads_like):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def compression_ratio(bits: int) -> float:
    """Wire-byte ratio vs bf16 gradients (scales amortize to ~0)."""
    return 16.0 / bits


def make_compressed_psum(axis: str, bits: int):
    """fn(grads, key) → mean over ``axis`` with int-``bits`` wire format.

    Must run inside shard_map-manual context for ``axis`` (see
    launch/train.py); implemented as all_gather of codes + scales, dequantize,
    mean — exact mean of the quantized per-member terms (unbiased for the true
    mean by C1 linearity).
    """

    def psum_compressed(grads, key):
        comp, _ = compress_tree(grads, bits, key)

        def reduce_leaf(c: CompressedLeaf):
            codes_all = jax.lax.all_gather(c.codes, axis)      # (P, …)
            scales_all = jax.lax.all_gather(c.scale, axis)     # (P,)
            vals = codes_all.astype(jnp.float32) * scales_all.reshape(
                (-1,) + (1,) * c.codes.ndim)
            return vals.mean(axis=0)

        return jax.tree.map(reduce_leaf, comp,
                            is_leaf=lambda x: isinstance(x, CompressedLeaf))

    return psum_compressed
