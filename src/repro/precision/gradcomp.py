"""C3 — quantized gradient collectives with error feedback (the paper's
gradient channel on the TPU ICI/DCI mesh).

Two layers:

* ``compress_tree`` / ``decompress_tree`` — ZipML row-scaled stochastic
  quantization (C1, unbiased) of a gradient pytree into :class:`repro.quant.
  QTensor` leaves (int8 codes + per-tensor scales). With ``error_feedback``
  state, the quantization residual is carried to the next step (telescoping
  bias cancellation — needed because an all-reduce sums many quantized terms
  per step; the single-worker analysis of App. D does not cover the
  accumulated worst case, EF restores it).

* ``make_compressed_psum(axis)`` — a shard_map-manual all-reduce over one mesh
  axis (the cross-pod 'pod' axis in production: the slowest link is exactly
  the paper's Fig. 2 gradient channel): quantize → all_gather(codes+scales) →
  dequantize → mean. Wire bytes drop 4× at 8 bits / 8× at 4 bits vs bf16.

The train driver composes: grads are already data-axis-reduced by GSPMD inside
the pod (cheap ICI); the compressed psum handles only the 'pod' axis (DCI).
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp

from repro import quant
from repro.quant import QScheme, QTensor


def CompressedLeaf(codes, scale) -> QTensor:
    """Deprecated: gradient leaves are plain :class:`repro.quant.QTensor`."""
    warnings.warn(
        "gradcomp.CompressedLeaf is deprecated; use repro.quant.QTensor "
        "with QScheme.int_symmetric(bits)", DeprecationWarning, stacklevel=2)
    return QTensor(codes, jnp.asarray(scale, jnp.float32),
                   QScheme.int_symmetric(8))


def _grad_scheme(bits: int, rounding: str = "stochastic") -> QScheme:
    return QScheme.int_symmetric(bits, scaling="tensor", rounding=rounding)


def _is_qtensor(x) -> bool:
    return isinstance(x, QTensor)


def compress_tree(grads, bits: int, key, error=None,
                  rounding: str = "stochastic"):
    """Quantize a gradient pytree. Returns (compressed, new_error).

    ``error``: error-feedback pytree (same structure, fp32) added before
    quantization; new_error = (g + e) − Q(g + e).
    ``rounding``: 'stochastic' (unbiased, C1) or 'nearest' (the §5.4 biased
    straw man — gradients below half a quantization step vanish without
    error feedback; EF's telescoping restores them).
    """
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))
    err_leaves = jax.tree.leaves(error) if error is not None else [None] * len(leaves)
    scheme = _grad_scheme(bits, rounding)
    comp, new_err = [], []
    for g, e, k in zip(leaves, err_leaves, keys):
        g32 = g.astype(jnp.float32)
        if e is not None:
            g32 = g32 + e
        c = quant.encode(g32, scheme, k)
        comp.append(c)
        new_err.append(g32 - c.decode())
    return (jax.tree.unflatten(treedef, comp),
            jax.tree.unflatten(treedef, new_err))


def decompress_tree(comp):
    return jax.tree.map(lambda c: c.decode(), comp, is_leaf=_is_qtensor)


def init_error_feedback(grads_like):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def compression_ratio(bits: int) -> float:
    """Wire-byte ratio vs bf16 gradients (scales amortize to ~0)."""
    return 16.0 / bits


def make_compressed_psum(axis: str, bits: int):
    """fn(grads, key) → mean over ``axis`` with int-``bits`` wire format.

    Must run inside shard_map-manual context for ``axis`` (see
    launch/train.py); implemented as all_gather of codes + scales, dequantize,
    mean — exact mean of the quantized per-member terms (unbiased for the true
    mean by C1 linearity).
    """

    def psum_compressed(grads, key):
        comp, _ = compress_tree(grads, bits, key)

        def reduce_leaf(c: QTensor):
            codes_all = jax.lax.all_gather(c.codes, axis)      # (P, …)
            scales_all = jax.lax.all_gather(c.scale, axis)     # (P,)
            vals = codes_all.astype(jnp.float32) * scales_all.reshape(
                (-1,) + (1,) * c.codes.ndim)
            return vals.mean(axis=0)

        return jax.tree.map(reduce_leaf, comp, is_leaf=_is_qtensor)

    return psum_compressed
