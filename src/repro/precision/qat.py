"""C5 — model quantization for deep nets (ZipML §3.3), LM-scale.

Two storages for the ZipML weight channel:

* ``quantize_param_tree(params, bits)`` — *int storage*: every matmul weight
  ``w`` becomes a :class:`repro.quant.QTensor` (int8 codes + fp32 per-out-
  channel scale). layers.dense dequantizes on the fly. This is the serving /
  dry-run format — HBM weight bytes drop 2×/4× (the paper's SampleStore
  compression mapped to TPU HBM). With ``optimal=True`` the codes live on
  variance-optimal levels (C4 DP, fitted per tensor on a sample of entries)
  instead of the uniform grid — the §3.3 "Optimal5 beats XNOR5" configuration
  — stored as a ``grid='levels'`` QTensor with its level table.

* ``fake_quant_tree(params, bits, key)`` — *QAT fake-quant* with the straight-
  through estimator: forward sees quantized values, backward passes through.
  Used inside the train step (weights stay bf16 at rest; the quantization
  noise is part of training, matching XNOR-Net-style min_W l(Q(W)) ).

All rounding goes through the canonical quantizer in :mod:`repro.quant` —
the former inline ``_int_quantize_weight`` copy is gone.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro import quant
from repro.core import optimal as opt_mod
from repro.quant import QScheme, QTensor, ShipWeight


def _is_weight(path: tuple) -> bool:
    # quantize matmul weights only: 2-D+ tensors named 'w' or 'table'
    last = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
    return last == "w"  # embedding tables stay bf16 (per-row scale gathers
    # would dominate; tables are a small share of weight bytes here)


def _weight_scheme(bits: int, rounding: str = "nearest",
                   packed: bool = False) -> QScheme:
    """Per-out-channel symmetric int grid: w is (..., d_in, d_out) → the
    absmax reduces over d_in (axis -2). ``packed`` nibble-packs 4-bit codes
    (two per byte — same values, half the storage/HBM bytes)."""
    return QScheme.int_symmetric(bits, scaling="channel", rounding=rounding,
                                 channel_axis=-2, packed=packed)


def _auto_packed(bits: int, w: jax.Array, packed: bool | None) -> bool:
    """int4 codes pack by default whenever the out-channel dim is even —
    value-identical to the unpacked grid (offset-binary nibbles round-trip
    exactly), so decode numerics are unchanged; only the bytes halve."""
    if packed is not None:
        return packed
    return bits == 4 and w.shape[-1] % 2 == 0


def _optimal_quantize_weight(w: jax.Array, bits: int, sample: int = 65536) -> QTensor:
    """C4+C5: codes snapped to the per-tensor variance-optimal symmetric level
    set (fitted on |w| with the discretized DP), stored as int16 level indices
    with a dense level table. Wins over the uniform grid exactly when the
    weight distribution is far from uniform — always, for trained nets.

    Stacked weights (ndim > 2, the scan-over-layers layout) get the table
    broadcast over the leading axes so every QTensor child carries the layer
    dim — the pre-QTensor splice format put a dim-less table next to stacked
    codes, which ``lax.scan`` over layers rejected (seed bug)."""
    w_np = np.asarray(w.astype(jnp.float32)).ravel()
    if w_np.size > sample:
        rng = np.random.default_rng(0)
        w_np = rng.choice(w_np, sample, replace=False)
    s = 2 ** (bits - 1) - 1
    hi = float(np.abs(w_np).max()) or 1.0
    lv = opt_mod.optimal_levels_discretized(np.abs(w_np) / hi, s, M=256) * hi
    levels = jnp.asarray(np.concatenate([-lv[::-1], lv[1:]]), jnp.float32)
    qt = quant.encode(w.astype(jnp.float32),
                      QScheme.levels(levels.shape[0], rounding="nearest"),
                      levels=levels)
    lead = w.shape[:-2]
    if lead:
        levels = jnp.broadcast_to(levels, (*lead, levels.shape[0]))
    scale = jnp.ones(lead, jnp.float32)
    return QTensor(qt.codes.astype(jnp.int16), scale, qt.scheme, levels=levels)


def migrate_spliced_weights(params, bits: int = 8):
    """One-shot migration of the REMOVED pre-QTensor spliced weight dicts
    (``w_q``+``w_scale`` int splices, ``w_lvl_codes``+``w_levels`` level
    splices) to a :class:`repro.quant.QTensor` at the ``"w"`` key — the
    storage ``layers.dense``/``moe`` consume. Decode numerics are identical
    (codes ⊙ scale / table lookup); dim-less level tables next to stacked
    codes get the PR-2 broadcast-per-layer layout so ``lax.scan`` accepts
    them. ``bits`` only labels the int scheme for byte accounting.

    Splice keys are consumed via ``dict.pop`` — model code reading them is
    banned by the api-surface grep; this migration shim is the one legal
    consumer."""

    def fix(node):
        if not isinstance(node, dict):
            return node
        node = {k: fix(v) for k, v in node.items()}
        if "w_q" in node:
            codes = node.pop("w_q")
            scale = jnp.asarray(node.pop("w_scale"), jnp.float32)
            node["w"] = QTensor(codes, scale, _weight_scheme(bits))
        elif "w_lvl_codes" in node:
            codes = node.pop("w_lvl_codes")
            levels = jnp.asarray(node.pop("w_levels"), jnp.float32)
            lead = codes.shape[:-2]
            if lead and levels.ndim == 1:
                levels = jnp.broadcast_to(levels, (*lead, levels.shape[0]))
            node["w"] = QTensor(codes, jnp.ones(lead, jnp.float32),
                                QScheme.levels(int(levels.shape[-1])),
                                levels=levels)
        return node

    return fix(params)


def quantize_param_tree(params, bits: int = 8, optimal: bool = False,
                        packed: bool | None = None,
                        include_embedding: bool = False,
                        layout: str = "dense"):
    """Convert every matmul weight to QTensor storage (see layers.dense).

    ``packed=None`` auto-packs 4-bit codes (two nibbles per byte) whenever
    the out-channel dim is even — decode values are identical, HBM bytes
    halve again. ``include_embedding`` also quantizes embedding tables
    (``table`` leaves) — the tied unembed then streams codes through the
    transpose kernel; ``embed``'s gather decodes row-wise.

    ``layout='bitplane'`` stores each weight bit-serially
    (:meth:`repro.quant.QScheme.bitplane`): one artifact serves any
    precision 1..``bits`` via ``QTensor.slice_planes(k)`` — the serving
    engine's ``set_weight_bits``/autoscaler path. Incompatible with
    ``optimal`` (DP level sets are not representable bit-serially) and with
    ``packed`` (planes are already uint32-packed)."""
    if layout not in ("dense", "bitplane"):
        raise ValueError(f"layout must be 'dense' or 'bitplane', got {layout!r}")
    if layout == "bitplane" and (optimal or packed):
        raise ValueError("layout='bitplane' excludes optimal= and packed=")

    def convert(path, leaf):
        last = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        is_table = include_embedding and last == "table"
        if not (_is_weight(path) or is_table) or leaf.ndim < 2:
            return leaf
        if layout == "bitplane":
            return quant.encode(leaf, QScheme.bitplane(bits))
        if optimal and not is_table:
            return _optimal_quantize_weight(leaf, bits)
        return quant.encode(
            leaf, _weight_scheme(bits, packed=_auto_packed(bits, leaf, packed)))

    return jax.tree_util.tree_map_with_path(convert, params)


# ---------------------------------------------------------------------------
# QAT straight-through fake quantization
# ---------------------------------------------------------------------------

@jax.custom_vjp
def _ste(x, xq):
    return xq


def _ste_fwd(x, xq):
    return xq, None


def _ste_bwd(_, g):
    return g, None


_ste.defvjp(_ste_fwd, _ste_bwd)


def fake_quant(w: jax.Array, bits: int, key=None) -> jax.Array:
    """Per-out-channel fake quantization with STE backward.

    Stochastic rounding when ``key`` given (unbiased E[Q(w)]=w, C1), nearest
    otherwise (XNOR-style deterministic).
    """
    rounding = "nearest" if key is None else "stochastic"
    qt = quant.encode(w, _weight_scheme(bits, rounding), key)
    return _ste(w, qt.decode().astype(w.dtype))


def fake_quant_tree(params, bits: int, key=None):
    """Apply fake_quant to every matmul weight (QAT train-step transform)."""
    i = [0]

    def go(path, leaf):
        if not _is_weight(path) or leaf.ndim < 2:
            return leaf
        k = None
        if key is not None:
            i[0] += 1
            k = jax.random.fold_in(key, i[0])
        return fake_quant(leaf, bits, k)

    return jax.tree_util.tree_map_with_path(go, params)


# ---------------------------------------------------------------------------
# C3 Q_m — "ship quantized": int8 codes through the FSDP all-gather
# ---------------------------------------------------------------------------

def ship_quant(w, bits: int, spec=None, packed: bool | None = None) -> ShipWeight:
    """Quantize per-shard, force the codes replicated (→ the all-gather moves
    int8/packed-int4), and return a :class:`repro.quant.ShipWeight` — the
    codes feed the ``quant_dense`` streaming matmul (no local dequantized
    full-width weight exists), the master rides along for the
    straight-through gradient. The wire format of the model channel drops
    4×/8× vs f32/bf16 — the paper's Q_m applied to the FSDP weight gather.

    Both sides of the reshard are pinned: codes constrained to the weight's
    own sharding first (compute stays local), then to replicated (the gather
    happens on the int tensor, not on the f32-legalized weight).
    """
    from jax.sharding import PartitionSpec as P
    from repro.models.layers import shard_hint
    scheme = _weight_scheme(bits, packed=_auto_packed(bits, w, packed))
    qt = quant.encode(jax.lax.stop_gradient(w), scheme)
    codes, scale = qt.codes, qt.scale
    if spec is not None:
        codes = shard_hint(codes, spec)               # pin: local quantize
    codes = jax.lax.optimization_barrier(codes)
    rep = P(*([None] * w.ndim))
    codes = shard_hint(codes, rep)                    # pin: int all-gather
    scale = shard_hint(scale, rep)
    return ShipWeight(w, QTensor(codes, scale, scheme))


def ship_quant_tree(params, bits: int, min_size: int = 1 << 16):
    """Apply ship_quant to every large matmul weight (specs from the
    launcher's sharding rules, so the local-quantize pin matches reality).

    Works on scanned stacked layer weights too: a (L, d_in, d_out) leaf gets
    per-layer (L, 1, d_out) channel scales from the channel_axis=-2 scheme —
    the same broadcast-over-leading-dims layout as the stacked level tables —
    so each scanned slice dequantizes against its own layer's scales.
    ``min_size`` skips weights too small to be worth the gather pin (the
    reduced smoke configs set it to 0 in tests).
    """
    from repro.launch.sharding import param_spec

    def go(path, leaf):
        if not _is_weight(path) or leaf.ndim < 2 or leaf.size < min_size:
            return leaf
        return ship_quant(leaf, bits, param_spec(path, leaf))

    return jax.tree_util.tree_map_with_path(go, params)
