"""ZipML precision channels at LM scale: QAT/int weight storage, double-sampled
activations, quantized KV cache (models/attention.py), gradient compression.
All storage is repro.quant.QTensor; all rounding delegates to repro.quant."""
from . import qat  # noqa: F401
from . import act_quant, gradcomp  # noqa: F401
