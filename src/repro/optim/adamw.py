"""AdamW with fp32 master weights and optional ZipML-quantized moments.

The optimizer state is the dominant HBM resident at scale (3 fp32 tensors per
bf16 param). ZipML's model-channel compression (C1+C4) applies directly:
``moment_bits=8`` stores m/v as int8 codes + per-tensor scales with stochastic
rounding on update — E[m̂]=m keeps the update unbiased, the same argument as
the paper's gradient quantization (App. D).

Pure-pytree implementation: state mirrors the param tree, so the launcher's
param sharding rules apply verbatim to the state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    moment_bits: int = 0        # 0 = fp32 moments; 8 = int8+scale storage


class MomentQ(NamedTuple):
    codes: Any
    scale: Any


class OptState(NamedTuple):
    step: jax.Array
    m: Any            # fp32 tree, or MomentQ tree when moment_bits > 0
    v: Any
    master: Any       # fp32 master copy of params


def _q_moment(x: jax.Array, bits: int, key, positive: bool = False) -> MomentQ:
    """Per-row (last-axis-block) stochastic quantization of a moment tensor.

    ``positive`` (second moment): quantize √v on the unsigned grid — a
    symmetric per-tensor scheme zeroes small v entries and 1/√v explodes.
    """
    from repro.quant.qtensor import stochastic_round

    qmax = float(2 ** (bits - 1) - 1)
    t0 = jnp.sqrt(x) if positive else x
    red_axis = tuple(range(x.ndim - 1)) if x.ndim > 1 else None
    absmax = jnp.max(jnp.abs(t0), axis=red_axis, keepdims=x.ndim > 1)
    scale = jnp.where(absmax == 0, 1.0, absmax / qmax)
    codes = stochastic_round(t0 / scale, key)
    lo_clip = 0.0 if positive else -qmax
    return MomentQ(jnp.clip(codes, lo_clip, qmax).astype(jnp.int8),
                   scale.astype(jnp.float32))


def _deq_moment(q: MomentQ, positive: bool = False) -> jax.Array:
    v = q.codes.astype(jnp.float32) * q.scale
    return v * v if positive else v


def init(params, cfg: AdamWConfig) -> OptState:
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    if cfg.moment_bits:
        zq = jax.tree.map(
            lambda p: MomentQ(jnp.zeros(p.shape, jnp.int8),
                              jnp.ones((), jnp.float32)), params)
        return OptState(jnp.zeros((), jnp.int32), zq, zq, master)
    return OptState(jnp.zeros((), jnp.int32), zeros, zeros, master)


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step_f = step.astype(jnp.float32)
    warm = jnp.minimum(step_f / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step_f - cfg.warmup_steps)
                    / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params, grads, state: OptState, cfg: AdamWConfig,
                  key: jax.Array | None = None):
    """One AdamW step. Returns (new_params, new_state, metrics).

    NaN/inf gradients skip the update entirely (fault tolerance: a poisoned
    microbatch or a flaky host cannot corrupt the master weights).
    """
    gnorm = global_norm(grads)
    finite = jnp.isfinite(gnorm)
    clip = jnp.where(gnorm > cfg.grad_clip, cfg.grad_clip / (gnorm + 1e-9), 1.0)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    keys = {}
    if cfg.moment_bits and key is not None:
        flat, treedef = jax.tree.flatten(state.master)
        ks = jax.random.split(key, 2 * len(flat))
        keys = {"m": jax.tree.unflatten(treedef, list(ks[: len(flat)])),
                "v": jax.tree.unflatten(treedef, list(ks[len(flat):]))}

    def upd(p_master, g, m_old, v_old, km=None, kv=None):
        g32 = g.astype(jnp.float32) * clip
        m_prev = _deq_moment(m_old) if cfg.moment_bits else m_old
        v_prev = _deq_moment(v_old, positive=True) if cfg.moment_bits else v_old
        m = cfg.b1 * m_prev + (1 - cfg.b1) * g32
        v = cfg.b2 * v_prev + (1 - cfg.b2) * g32 * g32
        update = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        decay = cfg.weight_decay * p_master if p_master.ndim >= 2 else 0.0
        new_master = p_master - lr * (update + decay)
        new_master = jnp.where(finite, new_master, p_master)
        if cfg.moment_bits:
            m_store = _q_moment(jnp.where(finite, m, m_prev), cfg.moment_bits, km)
            v_store = _q_moment(jnp.where(finite, v, v_prev), cfg.moment_bits, kv,
                                positive=True)
        else:
            m_store = jnp.where(finite, m, m_prev)
            v_store = jnp.where(finite, v, v_prev)
        return new_master, m_store, v_store

    if cfg.moment_bits and key is not None:
        out = jax.tree.map(upd, state.master, grads, state.m, state.v,
                           keys["m"], keys["v"],
                           is_leaf=lambda x: isinstance(x, MomentQ))
    else:
        out = jax.tree.map(upd, state.master, grads, state.m, state.v,
                           is_leaf=lambda x: isinstance(x, MomentQ))
    is3 = lambda x: isinstance(x, tuple) and len(x) == 3 and not isinstance(x, MomentQ)
    new_master = jax.tree.map(lambda t: t[0], out, is_leaf=is3)
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=is3)
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=is3)
    new_params = jax.tree.map(lambda mst, p: mst.astype(p.dtype), new_master, params)
    metrics = {"grad_norm": gnorm, "lr": lr, "skipped": 1.0 - finite.astype(jnp.float32)}
    return new_params, OptState(step, new_m, new_v, new_master), metrics
