"""AdamW with fp32 master weights and optional ZipML-quantized moments.

The optimizer state is the dominant HBM resident at scale (3 fp32 tensors per
bf16 param). ZipML's model-channel compression (C1+C4) applies directly:
``moment_bits=8`` stores m/v as :class:`repro.quant.QTensor` leaves (int8
codes + per-out-feature fp32 scales) with stochastic rounding on update —
E[m̂]=m keeps the update unbiased, the same argument as the paper's gradient
quantization (App. D). The second moment is stored in the √v domain: a
symmetric grid on v itself would zero small entries and 1/√v explodes.

Pure-pytree implementation: state mirrors the param tree, so the launcher's
param sharding rules apply verbatim — QTensor code planes shard like the
dense weight they shadow (``launch.sharding.make_opt_shardings``).

The quantized update dispatches through the kernel-backend registry
(``quant_adamw_update``): the ``ref`` backend runs the pure-jnp
decode→update→re-encode below (bit-exact with the seed numerics); the
``pallas`` backend fuses all three into one VMEM pass per tile
(kernels/quant_adamw.py) so the per-step optimizer sweep stops being three
full-tree HBM round-trips.

``MomentQ`` — the module's former private codes+scale NamedTuple — is kept
as a deprecation-warning alias constructing a QTensor.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro import quant
from repro.quant import QScheme, QTensor


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    moment_bits: int = 0        # 0 = fp32 moments; 8 = int8 QTensor storage
    update_clip: float = 10.0   # per-coordinate |update| bound on the
    # quantized-moment path (0 disables). Quantizing √v can round a small
    # second moment to exactly 0 while m stays nonzero — the update then
    # degenerates to m/eps and one step can throw a master weight by O(1e3·lr)
    # (observed on embedding rows of rare tokens under grad_bits=8 +
    # moment_bits=8). Exact Adam keeps |update| ≈ O(1), so a loose bound
    # only clips the quantization pathology. fp32 moments are untouched.


def MomentQ(codes, scale) -> QTensor:
    """Deprecated: optimizer moments are plain :class:`repro.quant.QTensor`
    leaves (int8 codes + fp32 scales) since the Trainer refactor."""
    warnings.warn(
        "adamw.MomentQ is deprecated; optimizer moments are repro.quant."
        "QTensor leaves (see the README deprecation table)",
        DeprecationWarning, stacklevel=2)
    codes = jnp.asarray(codes)
    return QTensor(codes, jnp.asarray(scale, jnp.float32),
                   moment_scheme(8, codes.ndim))


class OptState(NamedTuple):
    step: jax.Array
    m: Any            # fp32 tree, or QTensor tree when moment_bits > 0
    v: Any            # (QTensor v stores √v codes — decode_moment squares)
    master: Any       # fp32 master copy of params


def moment_scheme(bits: int, ndim: int) -> QScheme:
    """Per-out-feature (last-axis) scales for matrices, one scalar for
    vectors/scalars — the same reduction the former ``_q_moment`` used."""
    return QScheme.int_symmetric(
        bits, scaling="column" if ndim > 1 else "tensor", rounding="stochastic")


def encode_moment(x: jax.Array, bits: int, key,
                  positive: bool = False) -> QTensor:
    """Stochastically quantize a moment tensor to a QTensor.

    ``positive`` (second moment): encode √v on the grid; the QTensor holds
    √v-domain codes and :func:`decode_moment` squares on the way out.
    """
    t0 = jnp.sqrt(x) if positive else x
    return quant.encode(t0, moment_scheme(bits, x.ndim), key)


def decode_moment(q, positive: bool = False) -> jax.Array:
    if not isinstance(q, QTensor):
        return q
    val = q.decode()
    return val * val if positive else val


def init(params, cfg: AdamWConfig) -> OptState:
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    if cfg.moment_bits:
        def zq(p):
            # scales get their steady-state shape up front so the state pytree
            # is stable across steps (jit caches, checkpoint templates)
            sshape = p.shape[-1:] if p.ndim > 1 else ()
            return QTensor(jnp.zeros(p.shape, jnp.int8),
                           jnp.ones(sshape, jnp.float32),
                           moment_scheme(cfg.moment_bits, p.ndim))
        zq_tree = jax.tree.map(zq, params)
        return OptState(jnp.zeros((), jnp.int32), zq_tree, zq_tree, master)
    return OptState(jnp.zeros((), jnp.int32), zeros, zeros, master)


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step_f = step.astype(jnp.float32)
    warm = jnp.minimum(step_f / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step_f - cfg.warmup_steps)
                    / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params, grads, state: OptState, cfg: AdamWConfig,
                  key: jax.Array | None = None):
    """One AdamW step. Returns (new_params, new_state, metrics).

    NaN/inf gradients skip the update entirely (fault tolerance: a poisoned
    microbatch or a flaky host cannot corrupt the master weights).
    """
    from repro.kernels import registry

    gnorm = global_norm(grads)
    finite = jnp.isfinite(gnorm)
    clip = jnp.where(gnorm > cfg.grad_clip, cfg.grad_clip / (gnorm + 1e-9), 1.0)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    keys = {}
    if cfg.moment_bits and key is not None:
        flat, treedef = jax.tree.flatten(state.master)
        ks = jax.random.split(key, 2 * len(flat))
        keys = {"m": jax.tree.unflatten(treedef, list(ks[: len(flat)])),
                "v": jax.tree.unflatten(treedef, list(ks[len(flat):]))}

    backend = registry.resolve(None)

    def upd(p_master, g, m_old, v_old, km=None, kv=None):
        if cfg.moment_bits:
            return backend.quant_adamw_update(
                p_master, g, m_old, v_old, km, kv, bits=cfg.moment_bits,
                b1=cfg.b1, b2=cfg.b2, eps=cfg.eps, b1c=b1c, b2c=b2c, lr=lr,
                clip=clip, finite=finite,
                wd=cfg.weight_decay if p_master.ndim >= 2 else 0.0,
                uclip=cfg.update_clip)
        g32 = g.astype(jnp.float32) * clip
        m = cfg.b1 * m_old + (1 - cfg.b1) * g32
        v = cfg.b2 * v_old + (1 - cfg.b2) * g32 * g32
        update = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        decay = cfg.weight_decay * p_master if p_master.ndim >= 2 else 0.0
        new_master = p_master - lr * (update + decay)
        new_master = jnp.where(finite, new_master, p_master)
        m_store = jnp.where(finite, m, m_old)
        v_store = jnp.where(finite, v, v_old)
        return new_master, m_store, v_store

    is_q = lambda x: isinstance(x, QTensor)
    if cfg.moment_bits and key is not None:
        out = jax.tree.map(upd, state.master, grads, state.m, state.v,
                           keys["m"], keys["v"], is_leaf=is_q)
    else:
        out = jax.tree.map(upd, state.master, grads, state.m, state.v,
                           is_leaf=is_q)
    is3 = lambda x: isinstance(x, tuple) and len(x) == 3
    new_master = jax.tree.map(lambda t: t[0], out, is_leaf=is3)
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=is3)
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=is3)
    new_params = jax.tree.map(lambda mst, p: mst.astype(p.dtype), new_master, params)
    metrics = {"grad_norm": gnorm, "lr": lr, "skipped": 1.0 - finite.astype(jnp.float32)}
    return new_params, OptState(step, new_m, new_v, new_master), metrics


# ---------------------------------------------------------------------------
# Legacy-checkpoint migration
# ---------------------------------------------------------------------------

def legacy_moment_template(opt_state: OptState) -> OptState:
    """The pre-QTensor shape of ``opt_state``: every QTensor moment leaf
    becomes a plain ``(codes, scale)`` pair with the old scalar scale — the
    flat-leaf layout of checkpoints written before the Trainer refactor.
    Feed the result to ``CheckpointManager.restore`` as the template, then
    convert back with :func:`migrate_legacy_moments`.
    """
    def to_pair(q):
        if not isinstance(q, QTensor):
            return q            # fp32 moments stored as-is in both formats
        shp = q.codes.shape
        sshape = (1,) * (len(shp) - 1) + shp[-1:] if len(shp) > 1 else ()
        return (jax.ShapeDtypeStruct(shp, jnp.int8),
                jax.ShapeDtypeStruct(sshape, jnp.float32))
    is_q = lambda x: isinstance(x, QTensor)
    return OptState(opt_state.step,
                    jax.tree.map(to_pair, opt_state.m, is_leaf=is_q),
                    jax.tree.map(to_pair, opt_state.v, is_leaf=is_q),
                    opt_state.master)


def migrate_legacy_moments(opt_state: OptState, bits: int) -> OptState:
    """Convert a restored legacy opt state — (codes, scale) moment pairs —
    to QTensor leaves (the load-time shim for old MomentQ checkpoints)."""
    warnings.warn(
        "restored a legacy MomentQ checkpoint; converting m/v to QTensor "
        "leaves (re-save to upgrade the on-disk format)",
        DeprecationWarning, stacklevel=2)

    def conv(pair):
        codes, scale = pair
        sshape = codes.shape[-1:] if codes.ndim > 1 else ()
        scale = jnp.broadcast_to(jnp.asarray(scale, jnp.float32).reshape(
            sshape if jnp.size(scale) > 1 else ()), sshape)
        return QTensor(codes, scale, moment_scheme(bits, codes.ndim))
    is_pair = lambda x: isinstance(x, tuple) and len(x) == 2
    return OptState(opt_state.step,
                    jax.tree.map(conv, opt_state.m, is_leaf=is_pair),
                    jax.tree.map(conv, opt_state.v, is_leaf=is_pair),
                    opt_state.master)
