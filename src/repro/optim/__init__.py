from . import adamw  # noqa: F401
from .adamw import AdamWConfig, OptState  # noqa: F401
