"""Block-shape autotuner for the Pallas kernels + the persisted winner cache.

Every hot kernel (``qmm``, ``qmm_t``, ``qmm_qout``, ``qmm_bitplane``,
``ds_quant``, ``paged_attn``, ``quant_adamw``) ships hand-picked block
sizes. This module
sweeps a small candidate space per (op, dtype, shape-bucket), times each
candidate on representative shapes, and persists the winners to a JSON cache
keyed by :func:`~repro.perf.fingerprint.fingerprint_key`. The kernel entry
points then resolve ``block=None`` through
:func:`repro.kernels.registry.resolve_block` → :func:`lookup` here → the
hand-picked default on a miss.

Guarantees the CI gate leans on:

* the hand-picked default is ALWAYS a candidate, and the winner is the
  argmin over candidates measured in the same sweep — so the recorded
  ``ms ≤ default_ms`` holds exactly (the ``autotune_no_worse`` CHECK).
* a cache from different hardware, a corrupt file, or a disabled env
  (``ZIPML_AUTOTUNE=0``) is a clean miss — kernels fall back to defaults
  and stay bit-exact with an explicit-default call.

Shape bucketing: each logical dim rounds down to a power of two
(``m=300 → m256``), so one tuned entry serves the whole bucket — block
shapes are a coarse function of problem size, not of every last dim.

``paged_attn`` rides along with a singleton candidate space: its grid is
fully determined by (batch, pages-per-sequence) and the pool's page size,
so there is no free block axis yet — the tuner still measures it so the
roofline report covers all seven kernels.
"""
from __future__ import annotations

import json
import os
import time
import warnings

import numpy as np

from repro.perf import fingerprint as fpr

CACHE_ENV = "ZIPML_AUTOTUNE_CACHE"       # explicit cache-file override
DISABLE_ENV = "ZIPML_AUTOTUNE"           # "0" → lookups always miss
CACHE_VERSION = 1

OPS = ("qmm", "qmm_t", "qmm_qout", "qmm_bitplane", "ds_quant", "paged_attn",
       "quant_adamw")

# candidate block spaces — the hand-picked default is element 0 of each
SPACES = {
    "qmm": [
        {"bm": 256, "bk": 512, "bn": 256},
        {"bm": 128, "bk": 512, "bn": 256},
        {"bm": 256, "bk": 256, "bn": 256},
        {"bm": 128, "bk": 256, "bn": 128},
        {"bm": 256, "bk": 512, "bn": 128},
    ],
    "qmm_t": [
        {"bm": 256, "bk": 256, "bn": 512},
        {"bm": 128, "bk": 256, "bn": 512},
        {"bm": 256, "bk": 128, "bn": 512},
        {"bm": 256, "bk": 256, "bn": 256},
    ],
    "qmm_qout": [
        {"bm": 256, "bk": 512},
        {"bm": 128, "bk": 512},
        {"bm": 128, "bk": 256},
    ],
    "qmm_bitplane": [
        {"bm": 256, "bk": 512, "bn": 256},
        {"bm": 128, "bk": 512, "bn": 256},
        {"bm": 256, "bk": 256, "bn": 256},
        {"bm": 128, "bk": 256, "bn": 128},
    ],
    "ds_quant": [
        {"br": 256, "bc": 512},
        {"br": 128, "bc": 512},
        {"br": 256, "bc": 256},
        {"br": 128, "bc": 256},
    ],
    "quant_adamw": [
        {"br": 256, "bc": 512},
        {"br": 128, "bc": 512},
        {"br": 256, "bc": 256},
    ],
    "paged_attn": [{}],                  # grid fixed by (batch, pages)
}

# smoke keeps the default + the two nearest alternates per op
SMOKE_CANDIDATES = 3


def bucket_dim(v: int) -> int:
    """Power-of-two floor: one tuned entry serves the whole bucket."""
    return 1 << max(0, int(np.floor(np.log2(max(1, v)))))


def bucket_key(dims: dict[str, int]) -> str:
    return "_".join(f"{k}{bucket_dim(v)}" for k, v in sorted(dims.items()))


def entry_key(op: str, dtype: str, dims: dict[str, int]) -> str:
    return f"{op}/{dtype}/{bucket_key(dims)}"


# --------------------------------------------------------------- the cache --
_STATE: dict = {"path": None, "entries": None}


def cache_path() -> str:
    env = os.environ.get(CACHE_ENV)
    if env:
        return env
    return os.path.join(fpr.cache_dir(), f"autotune_{fpr.fingerprint_key()}.json")


def reload() -> None:
    """Drop the in-process cache view (tests; after an external tune run).
    NB: kernels already traced with ``block=None`` keep their resolved
    blocks — ``jax.clear_caches()`` forces re-resolution."""
    _STATE["path"] = None
    _STATE["entries"] = None


def _load() -> dict:
    path = cache_path()
    if _STATE["entries"] is not None and _STATE["path"] == path:
        return _STATE["entries"]
    entries: dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
            if not isinstance(data, dict) or "entries" not in data:
                raise ValueError("not an autotune cache")
            if data.get("key") != fpr.fingerprint_key():
                warnings.warn(
                    f"autotune cache {path} was tuned on different hardware "
                    f"(key {data.get('key')!r} != {fpr.fingerprint_key()!r}); "
                    "ignoring it — kernels use hand-picked defaults",
                    stacklevel=2)
            elif data.get("version") != CACHE_VERSION:
                warnings.warn(
                    f"autotune cache {path} has version "
                    f"{data.get('version')!r} != {CACHE_VERSION}; ignoring",
                    stacklevel=2)
            else:
                entries = data["entries"]
        except (json.JSONDecodeError, OSError, ValueError, TypeError) as e:
            warnings.warn(
                f"autotune cache {path} is unreadable ({e}); ignoring it — "
                "kernels use hand-picked defaults", stacklevel=2)
    _STATE["path"] = path
    _STATE["entries"] = entries
    return entries


def lookup(op: str, dtype: str, dims: dict[str, int]) -> dict | None:
    """Tuned block dict for (op, dtype, bucket-of-dims), or None (→ default).

    Called at kernel trace time through registry.resolve_block, so the file
    is read once per process and the hit is a dict lookup.
    """
    if os.environ.get(DISABLE_ENV, "1") in ("0", "false", ""):
        return None
    ent = _load().get(entry_key(op, dtype, dims))
    return dict(ent["block"]) if ent and ent.get("block") else None


def save(entries: dict, path: str | None = None) -> str:
    """Merge ``entries`` into the cache file (atomic replace) and reload."""
    path = path or cache_path()
    merged: dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                old = json.load(f)
            if old.get("key") == fpr.fingerprint_key() \
                    and old.get("version") == CACHE_VERSION:
                merged = old.get("entries", {})
        except (json.JSONDecodeError, OSError, TypeError):
            pass                          # overwrite a corrupt file
    merged.update(entries)
    payload = {"version": CACHE_VERSION, "key": fpr.fingerprint_key(),
               "fingerprint": fpr.hardware_fingerprint(), "entries": merged}
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=2)
    os.replace(tmp, path)
    reload()
    return path


# ------------------------------------------------------------- the sweeps --
def _best_ms(fn, reps: int) -> float:
    fn()                                  # compile + warm
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts)) * 1e3


def _cases(smoke: bool):
    """(op, dtype, dims, bytes_moved, bench(block) -> timed-call) tuples.

    Shapes are 128-multiples (the kernels' alignment contract). bytes_moved
    is the per-call HBM traffic of the op's I/O signature — what the
    roofline fraction divides by the measured peak.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import paged_attn as pa_mod
    from repro.kernels import qmm as qmm_mod
    from repro.kernels import qmm_bitplane as qbp_mod
    from repro.kernels import quant_adamw as qa_mod
    from repro.kernels import stoch_quant as sq_mod

    key = jax.random.PRNGKey(0)
    cases = []

    m, k, n = (256, 512, 256) if smoke else (512, 2048, 1024)
    x = jax.random.normal(key, (m, k)).astype(jnp.bfloat16)
    g = jax.random.normal(jax.random.fold_in(key, 1), (m, n)).astype(jnp.bfloat16)
    scale = jnp.full((1, n), 0.01, jnp.float32)
    codes8 = jax.random.randint(jax.random.fold_in(key, 2), (k, n), -127, 128,
                                jnp.int8)
    codes4 = jax.random.randint(jax.random.fold_in(key, 3), (k, n // 2), 0, 256,
                                jnp.uint32).astype(jnp.uint8)
    rand = jax.random.bits(jax.random.fold_in(key, 4), (m, n), jnp.uint32)

    for dtype, codes, packed in (("int8", codes8, False), ("int4", codes4, True)):
        cbytes = codes.size
        cases.append((
            "qmm", dtype, {"m": m, "k": k, "n": n},
            2 * m * k + cbytes + 4 * n + 4 * m * n,
            lambda b, codes=codes, packed=packed: jax.block_until_ready(
                qmm_mod.qmm(x, codes, scale, packed=packed, **b)),
        ))
        cases.append((
            "qmm_t", dtype, {"m": m, "k": k, "n": n},
            2 * m * n + cbytes + 4 * n + 4 * m * k,
            lambda b, codes=codes, packed=packed: jax.block_until_ready(
                qmm_mod.qmm_t(g, codes, scale, packed=packed, **b)),
        ))
    cases.append((
        "qmm_qout", "int8", {"m": m, "k": k, "n": n},
        2 * m * k + codes8.size + 4 * n + 4 * m * n + 2 * m * n + 4 * m,
        lambda b: jax.block_until_ready(
            qmm_mod.qmm_qout(x, codes8, scale, rand, qmax=127, **b)),
    ))

    planes = jax.random.bits(jax.random.fold_in(key, 9), (9, k, n // 32),
                             jnp.uint32)          # sign + 8 magnitude planes
    cases.append((
        "qmm_bitplane", "uint32", {"m": m, "k": k, "n": n},
        2 * m * k + planes.size * 4 + 4 * n + 4 * m * n,
        lambda b: jax.block_until_ready(
            qbp_mod.qmm_bitplane(x, planes, scale, **b)),
    ))

    r, c = (256, 512) if smoke else (1024, 2048)
    xq = jax.random.normal(key, (r, c), jnp.float32)
    randq = jax.random.bits(jax.random.fold_in(key, 5), (r, c), jnp.uint32)
    rscale = jnp.max(jnp.abs(xq), axis=1, keepdims=True)
    cases.append((
        "ds_quant", "f32", {"r": r, "c": c},
        4 * r * c + 4 * r * c + 4 * r + 2 * r * c,
        lambda b: jax.block_until_ready(
            sq_mod.ds_quant(xq, randq, rscale, s=127,
                            block=(b["br"], b["bc"]))[0]),
    ))

    mst = jax.random.normal(key, (r, c), jnp.float32)
    gq = jax.random.normal(jax.random.fold_in(key, 6), (r, c), jnp.float32) * .1
    mcodes = jax.random.randint(jax.random.fold_in(key, 7), (r, c), -127, 128,
                                jnp.int8)
    cscale = jnp.ones((1, c), jnp.float32)
    params = jnp.array([1.0, 1.0, 1e-3, 0.1, 0.05, 0, 0, 0], jnp.float32)
    cases.append((
        "quant_adamw", "f32", {"r": r, "c": c},
        # pass2 I/O: master r/w + g + rand + both code planes r/w + scales
        4 * r * c * 3 + 4 * r * c + 2 * r * c * 2 + 4 * c * 4,
        lambda b: jax.block_until_ready(
            qa_mod.qadamw_update(mst, gq, mcodes, cscale, mcodes, cscale,
                                 cscale, cscale, randq, params, b1=0.9,
                                 b2=0.95, eps=1e-8, wd=0.1, qmax=127,
                                 block=(b["br"], b["bc"]))[0]),
    ))

    b_sz, page, hkv, h, d, maxp = 4, 8, 2, 4, 64, 4
    q = jax.random.normal(key, (b_sz, h, d)).astype(jnp.bfloat16)
    kp = jax.random.randint(jax.random.fold_in(key, 8),
                            (b_sz * maxp + 1, page, hkv, d), -127, 128, jnp.int8)
    ks = jnp.full((b_sz * maxp + 1, page, hkv, 1), 0.02, jnp.float32)
    bt = jnp.arange(1, b_sz * maxp + 1, dtype=jnp.int32).reshape(b_sz, maxp)
    lens = jnp.full((b_sz,), page * maxp, jnp.int32)
    cases.append((
        "paged_attn", "int8", {"b": b_sz, "p": maxp, "d": d},
        2 * q.size + 2 * (b_sz * maxp * page * hkv * d) + 4 * q.size,
        lambda b: jax.block_until_ready(
            pa_mod.paged_decode_attn(q, kp, kp, ks, ks, bt, lens,
                                     softmax_scale=0.125, kv_bits=8)),
    ))
    return cases


def tune(ops=None, *, smoke: bool = True, peaks: dict | None = None,
         path: str | None = None, persist: bool = True):
    """Sweep candidates, persist winners, return per-bucket report rows.

    Every row carries bytes_moved / achieved GB/s / roofline_fraction (from
    ``peaks``, defaulting to the cached probe) and the ``autotune_no_worse``
    bool the CI lane gates on — exact by construction, since the default is
    candidate 0 of the same measured sweep.
    """
    from repro.perf import probe, report

    peaks = peaks or probe.get_peaks(smoke=smoke)
    reps = 2 if smoke else 5
    rows, entries = [], {}
    for op, dtype, dims, bytes_moved, bench in _cases(smoke):
        if ops and op not in ops:
            continue
        space = SPACES[op][:SMOKE_CANDIDATES] if smoke else SPACES[op]
        timed = [(blk, _best_ms(lambda b=blk: bench(b), reps)) for blk in space]
        default_ms = timed[0][1]
        best_blk, best_ms = min(timed, key=lambda t: t[1])
        ek = entry_key(op, dtype, dims)
        entries[ek] = {"op": op, "dtype": dtype, "bucket": bucket_key(dims),
                       "block": best_blk, "ms": round(best_ms, 4),
                       "default_ms": round(default_ms, 4),
                       "bytes_moved": bytes_moved,
                       "candidates": len(space)}
        row = {"case": f"autotune_{op}_{dtype}", "op": op, "dtype": dtype,
               "bucket": bucket_key(dims),
               "block": json.dumps(best_blk, sort_keys=True),
               "default_ms": round(default_ms, 3), "best_ms": round(best_ms, 3),
               "candidates": len(space),
               "autotune_no_worse": bool(best_ms <= default_ms)}
        report.annotate_row(row, bytes_moved=bytes_moved, ms=best_ms,
                            peaks=peaks)
        rows.append(row)
    if persist and entries:
        save(entries, path)
    return rows
