"""Roofline reporting — bytes-moved / achieved GB/s / fraction-of-peak.

The one place that turns (bytes, milliseconds) into the numbers every
BENCH_*.json row carries. Fraction-of-peak is the machine-portable perf
metric the smoke gate compares: wall-clock divides out the machine via the
measured peak, so a committed baseline from one box transfers to another —
what raw step-ms never did.
"""
from __future__ import annotations

import os


def achieved_gbps(bytes_moved: float, ms: float) -> float:
    return bytes_moved / (ms * 1e-3) / 1e9 if ms > 0 else 0.0


def annotate_row(row: dict, *, bytes_moved: float, ms: float,
                 peaks: dict | None = None) -> dict:
    """Attach the roofline triple to a bench row, in place."""
    if peaks is None:
        from repro.perf import probe

        peaks = probe.get_peaks(smoke=True)
    gbps = achieved_gbps(bytes_moved, ms)
    peak = float(peaks["peak_gbps"])
    row["bytes_moved"] = int(bytes_moved)
    row["achieved_gbps"] = round(gbps, 4)
    row["peak_gbps"] = round(peak, 3)
    row["roofline_fraction"] = round(gbps / peak, 6) if peak else 0.0
    return row


def markdown_table(rows: list[dict]) -> str:
    """Per-kernel roofline table (the $GITHUB_STEP_SUMMARY payload)."""
    cols = ("case", "dtype", "bucket", "block", "best_ms", "default_ms",
            "achieved_gbps", "peak_gbps", "roofline_fraction",
            "autotune_no_worse")
    keep = [c for c in cols if any(c in r for r in rows)]
    lines = ["| " + " | ".join(keep) + " |",
             "|" + "---|" * len(keep)]
    for r in rows:
        lines.append("| " + " | ".join(str(r.get(c, "")) for c in keep) + " |")
    return "\n".join(lines)


def write_step_summary(text: str) -> bool:
    """Append to $GITHUB_STEP_SUMMARY when running under Actions."""
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path:
        return False
    with open(path, "a") as f:
        f.write(text + "\n")
    return True
