"""repro.perf — empirical roofline probe, kernel autotuner, perf reporting.

Three layers (see each module's docstring):

* :mod:`repro.perf.probe` — ERT-style measured peak HBM GB/s + FLOP/s per
  backend, cached per hardware fingerprint.
* :mod:`repro.perf.autotune` — block-shape sweeps per (op, dtype,
  shape-bucket) for the seven Pallas kernels, winners persisted to a JSON
  cache the kernel entry points resolve ``block=None`` through
  (:func:`repro.kernels.registry.resolve_block`).
* :mod:`repro.perf.report` — bytes-moved → achieved GB/s →
  fraction-of-roofline annotation for every BENCH_*.json row; the smoke
  gate compares the fraction, which is machine-portable.

``python -m repro.perf {probe,autotune,gate}`` is the CI entry point.
"""
from repro.perf.autotune import lookup, tune
from repro.perf.fingerprint import fingerprint_key, hardware_fingerprint
from repro.perf.probe import analytic_peaks, get_peaks, measure_peaks
from repro.perf.report import achieved_gbps, annotate_row, markdown_table

__all__ = [
    "achieved_gbps", "analytic_peaks", "annotate_row", "fingerprint_key",
    "get_peaks", "hardware_fingerprint", "lookup", "markdown_table",
    "measure_peaks", "tune",
]
