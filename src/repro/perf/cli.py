"""CLI for the perf harness — what the ``perf-roofline`` CI lane drives.

Subcommands:

* ``probe``    — run/load the ERT roofline probe, write the JSON artifact.
* ``autotune`` — sweep kernel block shapes, persist winners, write the cache
  artifact + a per-kernel table (stdout and $GITHUB_STEP_SUMMARY).
* ``gate``     — compare a BENCH_qmm.json run against the committed baseline:
  every ``autotune_no_worse`` CHECK must hold, and each per-kernel
  ``roofline_fraction`` must stay within ``--tol`` of the baseline's.

Usage: PYTHONPATH=src python -m repro.perf <subcommand> [options]
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys

from repro.perf import autotune, probe, report


def _cmd_probe(args) -> int:
    peaks = probe.get_peaks(smoke=args.smoke, refresh=args.refresh)
    print(f"peak_gbps={peaks['peak_gbps']} peak_gflops={peaks['peak_gflops']} "
          f"key={peaks['key']}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(peaks, f, indent=2)
        print(f"wrote {args.out}")
    return 0


def _cmd_autotune(args) -> int:
    rows = autotune.tune(ops=args.ops or None, smoke=args.smoke)
    table = report.markdown_table(rows)
    print(table)
    report.write_step_summary("## Kernel autotune (roofline)\n\n" + table)
    if args.out:
        shutil.copyfile(autotune.cache_path(), args.out)
        print(f"wrote {args.out}")
    bad = [r for r in rows if not r["autotune_no_worse"]]
    for r in bad:
        print(f"AUTOTUNE FAIL: {r['case']} best {r['best_ms']}ms > "
              f"default {r['default_ms']}ms")
    return 1 if bad else 0


def _rf_rows(payload: dict) -> dict[str, dict]:
    return {r.get("case", str(i)): r
            for i, r in enumerate(payload.get("rows", []))
            if "roofline_fraction" in r}


def _cmd_gate(args) -> int:
    with open(args.bench) as f:
        now = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)
    now_rf, base_rf = _rf_rows(now), _rf_rows(base)
    fails, table_rows = [], []
    for case, b in base_rf.items():
        r = now_rf.get(case)
        if r is None:
            fails.append(f"{case}: baseline roofline row missing from this "
                         "run — regenerate baselines if intentional")
            continue
        if b.get("autotune_no_worse") and not r.get("autotune_no_worse", True):
            fails.append(f"{case}: autotune_no_worse regressed (was PASS)")
        floor = b["roofline_fraction"] * (1 - args.tol)
        if r["roofline_fraction"] < floor:
            fails.append(
                f"{case}: roofline_fraction {r['roofline_fraction']:.4g} < "
                f"{floor:.4g} (baseline {b['roofline_fraction']:.4g} "
                f"− {args.tol:.0%})")
        table_rows.append({**r, "baseline_fraction": b["roofline_fraction"]})
    table = report.markdown_table(table_rows)
    print(table)
    verdict = "**FAIL**\n" + "\n".join(f"- {m}" for m in fails) if fails \
        else "PASS — every kernel within tolerance of the committed baseline"
    print(verdict)
    report.write_step_summary(
        "## Roofline fraction-of-peak gate\n\n" + table + "\n\n" + verdict)
    for m in fails:
        print(f"::error::perf-roofline gate: {m}")
    return 1 if fails else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.perf", description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("probe", help="ERT roofline probe")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--refresh", action="store_true",
                   help="re-measure even with a fresh cache")
    p.add_argument("--out", default=None)
    p.set_defaults(fn=_cmd_probe)

    p = sub.add_parser("autotune", help="sweep kernel block shapes")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--ops", nargs="*", choices=autotune.OPS)
    p.add_argument("--out", default=None)
    p.set_defaults(fn=_cmd_autotune)

    p = sub.add_parser("gate", help="fraction-of-peak gate vs baseline")
    p.add_argument("--bench", required=True)
    p.add_argument("--baseline", required=True)
    p.add_argument("--tol", type=float, default=0.75,
                   help="allowed relative drop in roofline_fraction "
                        "(interpret-mode CPU lanes are noisy; tighten on TPU)")
    p.set_defaults(fn=_cmd_gate)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
