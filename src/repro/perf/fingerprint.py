"""Hardware fingerprint — the cache key for measured-peak and autotune data.

A roofline number or a tuned block shape is only meaningful on the hardware
it was measured on, so every perf cache file (probe results, autotune
winners) is keyed by a fingerprint of the accelerator: backend kind, device
model, device count, and host architecture. The key is a short stable hash
of that dict — same machine + same jax topology → same key across processes
(pinned by tests/test_perf.py), different machine → guaranteed cache miss
and fallback to the hand-picked defaults.

Deliberately NOT in the fingerprint: jax/jaxlib versions (a pip upgrade
shouldn't orphan a week of tuning data; re-tune explicitly when kernels
change) and clock speed (the probe measures it instead).
"""
from __future__ import annotations

import hashlib
import json
import os
import platform

import jax


def hardware_fingerprint() -> dict:
    """The identity of the accelerator this process sees."""
    devs = jax.devices()
    return {
        "backend": jax.default_backend(),
        "device_kind": devs[0].device_kind if devs else "none",
        "n_devices": len(devs),
        "machine": platform.machine(),
        "cpu_count": os.cpu_count() or 1,
    }


def fingerprint_key(fp: dict | None = None) -> str:
    """Short stable hash of a fingerprint dict (sorted-JSON sha256/12)."""
    fp = fp if fp is not None else hardware_fingerprint()
    blob = json.dumps(fp, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:12]


def cache_dir() -> str:
    """Where perf caches live: ``ZIPML_PERF_CACHE_DIR`` or ~/.cache/zipml."""
    d = os.environ.get("ZIPML_PERF_CACHE_DIR")
    if not d:
        d = os.path.join(os.path.expanduser("~"), ".cache", "zipml")
    os.makedirs(d, exist_ok=True)
    return d
