"""Empirical roofline probe — measured peak HBM GB/s and FLOP/s per backend.

Berkeley-ERT methodology (the SNIPPETS.md reference): instead of trusting a
datasheet constant, run a sweep of microkernels that are *constructed* to sit
at the two extremes of arithmetic intensity and report the best sustained
rate each achieves:

* **streaming** — ``y = a·x + b`` over working sets from ~1 MiB up past any
  cache (ERT's working-set sweep); 8 bytes moved per f32 element, ~0 useful
  reuse. The max across sizes is the measured peak memory bandwidth.
* **FMA chain** — ``x ← a·x + b`` iterated in-register/in-cache on a small
  buffer via ``lax.fori_loop``; 2 FLOP per element per iteration, ~0 bytes
  per FLOP. The max is the measured peak FLOP rate.

Results are cached per :func:`~repro.perf.fingerprint.hardware_fingerprint`
(``roofline_<key>.json`` under the perf cache dir), so the probe runs once
per machine, not once per bench. ``analytic_peaks()`` exposes the TPU-v5e
datasheet model from launch/hlostats for comparison — the composition the
roofline report prints is measured-peak for the denominator, analytic model
for the per-op expectation.

The probe measures whatever backend jax resolves — on the CPU CI lane that
is honest host memory bandwidth, and the kernels it normalizes run in
interpret mode there, so CPU fractions are trend numbers; on TPU both sides
are the real hardware claim.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.perf import fingerprint as fpr

# f32 elements per streaming working set: ~1 MiB → 64 MiB (smoke stops at
# 4 MiB — past L2 on every machine we run, cheap enough for CI)
STREAM_SIZES = tuple(1 << p for p in (18, 20, 22, 24))
STREAM_SIZES_SMOKE = tuple(1 << p for p in (18, 20))
FMA_SHAPE = (256, 256)          # in-cache buffer for the FLOP probe
FMA_ITERS = (512, 2048)
FMA_ITERS_SMOKE = (256,)

PROBE_VERSION = 1


def _best_ms(fn, reps: int) -> float:
    fn()                                    # compile + warm
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts)) * 1e3


def _stream_gbps(n: int, reps: int) -> float:
    x = jnp.arange(n, dtype=jnp.float32)    # data-dependent: nothing folds
    a = jnp.float32(1.0009)
    b = jnp.float32(0.1)
    f = jax.jit(lambda x: a * x + b)
    ms = _best_ms(lambda: f(x).block_until_ready(), reps)
    return (8.0 * n) / (ms * 1e-3) / 1e9    # read 4n + write 4n bytes


def _fma_gflops(iters: int, reps: int) -> float:
    x = jnp.ones(FMA_SHAPE, jnp.float32) * 0.5
    a = jnp.float32(0.999)
    b = jnp.float32(1e-3)

    @jax.jit
    def chain(x):
        return jax.lax.fori_loop(0, iters, lambda _, v: a * v + b, x)

    ms = _best_ms(lambda: chain(x).block_until_ready(), reps)
    flops = 2.0 * x.size * iters
    return flops / (ms * 1e-3) / 1e9


def measure_peaks(smoke: bool = False) -> dict:
    """Run the ERT sweep now; returns the peak dict (no cache involved)."""
    reps = 3 if smoke else 7
    sizes = STREAM_SIZES_SMOKE if smoke else STREAM_SIZES
    iters = FMA_ITERS_SMOKE if smoke else FMA_ITERS
    stream = {str(n): round(_stream_gbps(n, reps), 3) for n in sizes}
    fma = {str(i): round(_fma_gflops(i, reps), 3) for i in iters}
    return {
        "version": PROBE_VERSION,
        "fingerprint": fpr.hardware_fingerprint(),
        "key": fpr.fingerprint_key(),
        "smoke": smoke,
        "peak_gbps": max(stream.values()),
        "peak_gflops": max(fma.values()),
        "stream_sweep_gbps": stream,
        "fma_sweep_gflops": fma,
    }


def _cache_path() -> str:
    return os.path.join(fpr.cache_dir(), f"roofline_{fpr.fingerprint_key()}.json")


def get_peaks(smoke: bool = True, refresh: bool = False) -> dict:
    """Measured peaks for THIS machine, from cache when fresh.

    A cached result from a different fingerprint, a corrupt file, or an older
    probe version is discarded and re-measured. A full (non-smoke) cached
    result satisfies a smoke request; the reverse re-measures only on
    explicit ``refresh``.
    """
    path = _cache_path()
    if not refresh and os.path.exists(path):
        try:
            with open(path) as f:
                cached = json.load(f)
            if (cached.get("version") == PROBE_VERSION
                    and cached.get("key") == fpr.fingerprint_key()
                    and cached.get("peak_gbps", 0) > 0):
                return cached
        except (json.JSONDecodeError, OSError, TypeError):
            pass                             # corrupt → re-measure below
    peaks = measure_peaks(smoke=smoke)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(peaks, f, indent=2)
    os.replace(tmp, path)
    return peaks


def analytic_peaks() -> dict:
    """The TPU-v5e datasheet model (launch/hlostats) in the same units —
    what the compositional bench_roofline terms divide by."""
    from repro.launch import hlostats as H

    return {"peak_gbps": H.HBM_BW / 1e9, "peak_gflops": H.PEAK_FLOPS / 1e9,
            "source": "hlostats (TPU v5e datasheet)"}
