import sys

from repro.perf.cli import main

sys.exit(main())
