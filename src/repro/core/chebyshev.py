"""C6/C7 — Chebyshev gradient approximation for non-linear losses (ZipML §4).

Smooth losses: approximate ℓ'(z) on z ∈ [-R, R] by a degree-d Chebyshev
polynomial P (|P - ℓ'| ≤ ε), then estimate b·P(b·aᵀx)·a unbiasedly from d+1
independent quantizations of a (§4.2 protocol: Q₁..Q_d feed the polynomial
estimator of double_sampling.polynomial_estimator, Q_{d+1} carries the outer a).

Non-smooth losses (SVM / hinge): the step function H is approximated on
[-R, R] \\ [-δ, δ] (§4.3); inside the δ-gap the gradient can flip sign, handled
by the refetching heuristics in core/linear.py.

Chebyshev fitting is done numerically (Chebyshev–Gauss quadrature) — equivalent
to the Vlcek (2012) closed forms for the sigmoid but applicable to any ℓ'.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import numpy as np
import jax

from .double_sampling import polynomial_estimator
from .quantize import stochastic_quantize


def chebyshev_coeffs(f: Callable[[np.ndarray], np.ndarray], degree: int,
                     R: float, n_nodes: int = 513) -> np.ndarray:
    """Monomial coefficients of the degree-d Chebyshev approximation of f on [-R, R].

    Fits in the Chebyshev basis via Gauss–Chebyshev quadrature, then converts to
    the monomial basis in the *scaled* variable and unmaps to z-units. Returns
    (degree+1,) monomial coefficients m_i with P(z) = Σ m_i z^i.
    """
    k = np.arange(n_nodes)
    t = np.cos(np.pi * (k + 0.5) / n_nodes)          # Chebyshev nodes in [-1,1]
    fz = f(t * R)
    # Chebyshev coefficients c_j = (2 - [j==0])/n Σ f(t_k) T_j(t_k)
    j = np.arange(degree + 1)
    Tjk = np.cos(np.outer(j, np.pi * (k + 0.5) / n_nodes))
    c = (2.0 / n_nodes) * Tjk @ fz
    c[0] *= 0.5
    # convert Σ c_j T_j(u) to monomials in u via numpy's cheb2poly equivalent
    cheb = np.polynomial.chebyshev.Chebyshev(c)
    mono_u = cheb.convert(kind=np.polynomial.Polynomial).coef  # coeffs in u = z/R
    if len(mono_u) < degree + 1:
        mono_u = np.pad(mono_u, (0, degree + 1 - len(mono_u)))
    scale = float(R) ** -np.arange(degree + 1)
    return mono_u * scale


def sigmoid_prime_coeffs(degree: int, R: float) -> np.ndarray:
    """ℓ'(z) for logistic loss ℓ(z) = log(1+e^{-z}): ℓ'(z) = -sigmoid(-z)."""
    return chebyshev_coeffs(lambda z: -1.0 / (1.0 + np.exp(z)), degree, R)


def step_coeffs(degree: int, R: float, delta: float = 0.05) -> np.ndarray:
    """Heaviside approximation for hinge loss, fitted away from the δ-gap.

    Weighted fit: nodes inside [-δ, δ] are dropped (the paper's guarantee is on
    [-R, R] \\ [-δ, δ]; Allen-Zhu & Li style). Simple least-squares on the
    remaining Chebyshev nodes in the monomial basis of degree d.
    """
    n_nodes = 1025
    k = np.arange(n_nodes)
    z = np.cos(np.pi * (k + 0.5) / n_nodes) * R
    mask = np.abs(z) > delta
    z = z[mask]
    y = (z >= 0).astype(np.float64)
    V = np.vander(z / R, degree + 1, increasing=True)
    coef, *_ = np.linalg.lstsq(V, y, rcond=None)
    return coef * float(R) ** -np.arange(degree + 1)


class ChebGradConfig(NamedTuple):
    degree: int = 15
    R: float = 4.0
    s: int = 15          # quantization intervals per independent sample (4-bit)
    delta: float = 0.05  # hinge-only: half-width of the unapproximated gap


def quantized_poly_gradient(
    coeffs: jax.Array, x: jax.Array, a: jax.Array, b: jax.Array,
    s: int, key: jax.Array, scale: jax.Array | None = None,
) -> jax.Array:
    """§4.2 protocol: g = b · Q(P)(b·aᵀx) · Q_{d+1}(a), averaged over the batch.

    Bias ≤ ε sup|a| (from |P − ℓ'| ≤ ε); every quantization is independent so
    the polynomial estimator is unbiased for P.
    """
    k_poly, k_outer = jax.random.split(key)
    # evaluate P at b ⊙ (aᵀx): we absorb the label by scaling the sample batch,
    # since P(b·aᵀx) with b ∈ {-1, +1} equals P((b·a)ᵀ x).
    ab = a * b[:, None]
    pb = polynomial_estimator(coeffs, ab, x, s, k_poly, scale=scale)  # (B,)
    qa = stochastic_quantize(a, s, k_outer, scale=scale)
    return (qa * (b * pb)[:, None]).mean(axis=0)


def poly_eval(coeffs: np.ndarray, z: np.ndarray) -> np.ndarray:
    """Horner evaluation, for tests of the approximation error."""
    out = np.zeros_like(z, dtype=np.float64)
    for c in coeffs[::-1]:
        out = out * z + c
    return out
