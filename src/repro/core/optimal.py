"""C4 — Variance-optimal quantization levels (ZipML §3, App. H/I).

Given an empirical distribution Ω = {x_1..x_N} ⊂ [0,1], choose s+1 quantization
points (s intervals) minimizing the mean stochastic-rounding variance

    MV(I) = (1/N) Σ_j Σ_{x∈I_j} (b_j - x)(x - a_j).

Three solvers, matching the paper:

* ``optimal_levels_exact``       — O(kN²) DP over data points (Lemma 3: an optimal
                                   solution has endpoints in Ω ∪ {0,1}).
* ``optimal_levels_discretized`` — the practical one-pass heuristic: histogram
                                   the data into M buckets, run the DP on the M
                                   candidate points (Thm 2: error O(1/Mk)).
* ``adaquant``                   — App. I greedy merge: 2(1+γ)k+δ intervals with
                                   err ≤ (1+1/γ)·OPT_k in O(N log N · rounds).

All solvers use prefix sums so interval variance V(j, m) is O(1):
    err(Ω,[a,b]) = Σ (b-x)(x-a) = (a+b)Σx - ab·cnt - Σx².

These run in NumPy at setup time (the paper computes levels once per feature /
layer, off the training hot path); ``fit_levels`` is the user-facing entry that
normalizes arbitrary-range data, solves, and returns levels in original units.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class _Prefix(NamedTuple):
    xs: np.ndarray       # sorted values
    c1: np.ndarray       # prefix sum of x    (c1[i] = sum xs[:i])
    c2: np.ndarray       # prefix sum of x^2


def _prefix(xs: np.ndarray) -> _Prefix:
    xs = np.sort(np.asarray(xs, np.float64))
    return _Prefix(xs, np.concatenate([[0.0], np.cumsum(xs)]),
                   np.concatenate([[0.0], np.cumsum(xs * xs)]))


def _interval_err(p: _Prefix, i: int, j: int, a: float, b: float) -> float:
    """Σ_{x in xs[i:j]} (b-x)(x-a) using prefix sums — O(1)."""
    cnt = j - i
    if cnt <= 0:
        return 0.0
    s1 = p.c1[j] - p.c1[i]
    s2 = p.c2[j] - p.c2[i]
    return (a + b) * s1 - a * b * cnt - s2


def mean_variance(xs: np.ndarray, levels: np.ndarray) -> float:
    """MV(I): mean stochastic-quantization variance of xs under ``levels``."""
    p = _prefix(xs)
    levels = np.sort(np.asarray(levels, np.float64))
    total = 0.0
    idx = np.searchsorted(p.xs, levels)
    for k in range(len(levels) - 1):
        total += _interval_err(p, idx[k], idx[k + 1], levels[k], levels[k + 1])
    return total / max(len(p.xs), 1)


def optimal_levels_exact(xs: np.ndarray, s: int) -> np.ndarray:
    """Exact O(kN²) DP (App. H). Returns s+1 levels in [0,1] ⊇ endpoints ⊂ Ω∪{0,1}.

    T(k, m) = min_j T(k-1, j) + V(j, m) over candidate endpoints d_0=0, d_i=x_i,
    d_{N+1}=1.
    """
    p = _prefix(np.clip(xs, 0.0, 1.0))
    cand = np.concatenate([[0.0], p.xs, [1.0]])
    # dedupe while keeping order (equal points make zero-width intervals = fine,
    # but dedupe keeps the DP small)
    cand = np.unique(cand)
    C = len(cand)
    cidx = np.searchsorted(p.xs, cand)  # data index at/after each candidate

    def V(j: int, m: int) -> float:
        return _interval_err(p, cidx[j], cidx[m], cand[j], cand[m])

    INF = float("inf")
    T = np.full((s + 1, C), INF)
    parent = np.zeros((s + 1, C), np.int64)
    T[0, 0] = 0.0
    for k in range(1, s + 1):
        for m in range(1, C):
            best, bestj = INF, 0
            for j in range(0, m):
                if T[k - 1, j] == INF:
                    continue
                val = T[k - 1, j] + V(j, m)
                if val < best:
                    best, bestj = val, j
            T[k, m] = best
            parent[k, m] = bestj
    # backtrack from T(s, C-1)
    levels = [cand[C - 1]]
    m = C - 1
    for k in range(s, 0, -1):
        m = parent[k, m]
        levels.append(cand[m])
    levels = np.array(levels[::-1])
    levels[0], levels[-1] = 0.0, 1.0  # cover the full range
    return levels


def optimal_levels_discretized(xs: np.ndarray, s: int, M: int = 256) -> np.ndarray:
    """§3.2 heuristic: one pass to histogram into M buckets, DP over M candidates.

    Complexity O((s+1)M² + N); approximation error O(1/Ms) by Thm 2.
    """
    xs = np.clip(np.asarray(xs, np.float64), 0.0, 1.0)
    if len(xs) == 0:
        return np.linspace(0.0, 1.0, s + 1)
    # single pass: bucket counts + bucket sums (for interval error via moments)
    edges = np.linspace(0.0, 1.0, M + 1)
    which = np.clip((xs * M).astype(np.int64), 0, M - 1)
    cnt = np.bincount(which, minlength=M).astype(np.float64)
    s1 = np.bincount(which, weights=xs, minlength=M)
    s2 = np.bincount(which, weights=xs * xs, minlength=M)
    C1 = np.concatenate([[0.0], np.cumsum(s1)])
    C2 = np.concatenate([[0.0], np.cumsum(s2)])
    CN = np.concatenate([[0.0], np.cumsum(cnt)])

    def V(j: int, m: int) -> float:  # err over buckets [j, m) vs interval [edges[j], edges[m]]
        a, b = edges[j], edges[m]
        n = CN[m] - CN[j]
        return (a + b) * (C1[m] - C1[j]) - a * b * n - (C2[m] - C2[j])

    INF = float("inf")
    T = np.full((s + 1, M + 1), INF)
    parent = np.zeros((s + 1, M + 1), np.int64)
    T[0, 0] = 0.0
    for k in range(1, s + 1):
        for m in range(1, M + 1):
            best, bestj = INF, 0
            lo = k - 1
            for j in range(lo, m):
                tv = T[k - 1, j]
                if tv == INF:
                    continue
                val = tv + V(j, m)
                if val < best:
                    best, bestj = val, j
            T[k, m] = best
            parent[k, m] = bestj
    levels = [1.0]
    m = M
    for k in range(s, 0, -1):
        m = parent[k, m]
        levels.append(edges[m])
    return np.array(levels[::-1])


def adaquant(xs: np.ndarray, k: int, gamma: float = 1.0, delta: int = 2) -> np.ndarray:
    """App. I greedy merging — ADAQUANT(Ω, k, γ, δ).

    Repeatedly pair up consecutive intervals; unmerge the (1+γ)k most expensive.
    Stops at ≤ 2(1+γ)k + δ intervals with err ≤ (1+1/γ)·OPT_k (Thm 9). Returns
    the endpoint array; feed as candidates into the DP for a strict-k solution.
    """
    p = _prefix(np.clip(xs, 0.0, 1.0))
    pts = np.unique(np.concatenate([[0.0], p.xs, [1.0]]))
    target = int(2 * (1 + gamma) * k + delta)
    while len(pts) - 1 > target:
        # pair up consecutive intervals -> candidate merged intervals
        ends = pts
        merged_err = []
        merged = []  # (start_idx_in_pts, end_idx_in_pts)
        i = 0
        while i + 2 < len(ends):
            a, b = ends[i], ends[i + 2]
            ia, ib = np.searchsorted(p.xs, a), np.searchsorted(p.xs, b)
            merged_err.append(_interval_err(p, ia, ib, a, b))
            merged.append((i, i + 2))
            i += 2
        if not merged:
            break
        merged_err = np.asarray(merged_err)
        keep_split = set()
        n_split = min(int((1 + gamma) * k), len(merged))
        for idx in np.argsort(merged_err)[::-1][:n_split]:
            keep_split.add(idx)
        new_pts = [ends[0]]
        for mi, (i0, i1) in enumerate(merged):
            if mi in keep_split:
                new_pts.append(ends[i0 + 1])
            new_pts.append(ends[i1])
        if len(ends) % 2 == 0:  # odd interval count leaves a trailing interval
            new_pts.append(ends[-1])
        pts = np.unique(np.asarray(new_pts))
    return pts


def optimal_levels_2approx(xs: np.ndarray, s: int, gamma: float = 1.0) -> np.ndarray:
    """ADAQUANT candidates + DP restricted to them: 2-approx in O(N log N + s·c²)."""
    cand = adaquant(xs, s, gamma=gamma)
    p = _prefix(np.clip(xs, 0.0, 1.0))
    cidx = np.searchsorted(p.xs, cand)
    C = len(cand)

    def V(j: int, m: int) -> float:
        return _interval_err(p, cidx[j], cidx[m], cand[j], cand[m])

    INF = float("inf")
    T = np.full((s + 1, C), INF)
    parent = np.zeros((s + 1, C), np.int64)
    T[0, 0] = 0.0
    for k in range(1, s + 1):
        for m in range(1, C):
            best, bestj = INF, 0
            for j in range(0, m):
                if T[k - 1, j] == INF:
                    continue
                val = T[k - 1, j] + V(j, m)
                if val < best:
                    best, bestj = val, j
            T[k, m] = best
            parent[k, m] = bestj
    levels = [cand[-1]]
    m = C - 1
    for k in range(s, 0, -1):
        m = parent[k, m]
        levels.append(cand[m])
    levels = np.array(levels[::-1])
    levels[0], levels[-1] = 0.0, 1.0
    return levels


def fit_levels(
    data, s: int, method: str = "discretized", M: int = 256, symmetric: bool = False
) -> np.ndarray:
    """User entry: fit s-interval optimal levels to arbitrary-range data.

    Returns levels in *original* units (affine unmap of the [0,1] solution).
    ``symmetric=True`` mirrors the solution around 0 (for weight distributions;
    this is what the Optimal5 deep-learning quantizer uses, §3.3).
    """
    x = np.asarray(data, np.float64).ravel()
    x = x[np.isfinite(x)]
    if x.size == 0:
        return np.linspace(-1.0 if symmetric else 0.0, 1.0, s + 1)
    if symmetric:
        hi = np.max(np.abs(x)) or 1.0
        z = np.abs(x) / hi  # fold; fit half the levels on |x|
        half = max(s // 2, 1)
        solver = {"exact": optimal_levels_exact,
                  "discretized": lambda d, k: optimal_levels_discretized(d, k, M),
                  "2approx": optimal_levels_2approx}[method]
        lv = solver(z, half)
        pos = lv * hi
        return np.unique(np.concatenate([-pos[::-1], pos]))
    lo, hi = float(np.min(x)), float(np.max(x))
    span = (hi - lo) or 1.0
    z = (x - lo) / span
    solver = {"exact": optimal_levels_exact,
              "discretized": lambda d, k: optimal_levels_discretized(d, k, M),
              "2approx": optimal_levels_2approx}[method]
    lv = solver(z, s)
    return lv * span + lo


def uniform_levels(s: int, lo: float = 0.0, hi: float = 1.0) -> np.ndarray:
    """The baseline the paper beats: s+1 uniformly spaced levels."""
    return np.linspace(lo, hi, s + 1)
