"""C1 — Unbiased stochastic quantization (ZipML §2.1, App. A.3).

Implements the paper's Q(v, s) with both scaling families:

* **row scaling**  — M_i(v) = ||v||_2 (or max|v|), one scalar per vector; used for
  gradients and models whose dynamic range moves during training.
* **column scaling** — M_i(v) = max over the dataset of |v_i| per coordinate;
  shared across all samples, computed once (paper App. A.3 "Column Scaling").

The quantizer maps v/M into [-1, 1], snaps each coordinate stochastically to one
of the two nearest of ``s+1`` uniformly spaced levels (l = 0..s), such that
E[Q(v, s)] = v exactly (Lemma 6, unbiasedness).

Storage lives in :class:`repro.quant.QTensor` — the one canonical quantized
pytree — and the rounding implementations live in :mod:`repro.quant.qtensor`;
this module keeps the paper-notation entry points (and the deprecated
``Quantized``/``IntTensor`` constructors) on top of them.

Everything is pure jnp and jit/vmap/pjit friendly; randomness always enters via
an explicit PRNG key (never global state) so kernels and hosts stay reproducible.
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp

from repro.quant import QScheme, QTensor
from repro.quant.qtensor import encode_jnp, quantize_to_levels_jnp


def Quantized(codes, scale, s: int, signed: bool = True) -> QTensor:
    """Deprecated: construct a :class:`repro.quant.QTensor` instead."""
    warnings.warn(
        "core.quantize.Quantized is deprecated; use repro.quant.QTensor "
        "with QScheme.zipml(s)", DeprecationWarning, stacklevel=2)
    return QTensor(codes, jnp.asarray(scale),
                   QScheme.zipml(s, signed=signed))


def IntTensor(codes, scale, bits: int) -> QTensor:
    """Deprecated: construct a :class:`repro.quant.QTensor` instead."""
    warnings.warn(
        "core.quantize.IntTensor is deprecated; use repro.quant.QTensor "
        "with QScheme.int_symmetric(bits)", DeprecationWarning, stacklevel=2)
    return QTensor(codes, jnp.asarray(scale), QScheme.int_symmetric(bits))


def row_scale(v: jax.Array, norm: str = "linf") -> jax.Array:
    """M(v) per the paper: a scalar bound with |v|/M <= 1.

    ``linf`` (max|v|) gives tighter levels than the paper's WLOG ||v||_2 and is
    what the FPGA implementation uses in practice; both are supported.
    """
    if norm == "l2":
        m = jnp.linalg.norm(v)
    elif norm == "linf":
        m = jnp.max(jnp.abs(v))
    else:
        raise ValueError(f"unknown norm {norm!r}")
    return jnp.where(m == 0, 1.0, m).astype(jnp.float32)


def column_scale(data: jax.Array) -> jax.Array:
    """Per-feature M_i = max(|min_i|, |max_i|) over a (K, n) dataset (App. A.3)."""
    m = jnp.max(jnp.abs(data), axis=0)
    return jnp.where(m == 0, 1.0, m).astype(jnp.float32)


def quantize(
    v: jax.Array,
    s: int,
    key: jax.Array,
    scale: jax.Array | None = None,
    signed: bool = True,
) -> QTensor:
    """Stochastic uniform quantization Q(v, s) — unbiased (Lemma 6).

    Faithful to App. A.3 Eq. (10): Q_i = M_i · sgn(v_i) · μ_i where μ_i rounds
    |v_i|/M_i ∈ [0,1] stochastically onto the grid {0, 1/s, …, 1}. Signed codes
    are sign·level ∈ [-s, s] (s=1 gives the ternary {-M, 0, M} of QSGD).
    """
    if scale is None:
        scale = row_scale(jnp.asarray(v))
    return encode_jnp(v, QScheme.zipml(s, signed=signed), key, scale=scale)


def quantize_nearest(
    v: jax.Array, s: int, scale: jax.Array | None = None, signed: bool = True
) -> QTensor:
    """Deterministic nearest rounding — the §5.4 straw man (biased)."""
    if scale is None:
        scale = row_scale(jnp.asarray(v))
    return encode_jnp(v, QScheme.zipml(s, signed=signed, rounding="nearest"),
                      scale=scale)


def dequantize(q: QTensor) -> jax.Array:
    return q.decode()


def stochastic_quantize(
    v: jax.Array,
    s: int,
    key: jax.Array,
    scale: jax.Array | None = None,
    signed: bool = True,
) -> jax.Array:
    """quantize → dequantize in one step: returns the low-precision *values*.

    This is the form used in the double-sampling gradient math, where we care
    about the quantized real values, not the storage codes.
    """
    return quantize(v, s, key, scale=scale, signed=signed).decode()


# ---------------------------------------------------------------------------
# Arbitrary (variance-optimal) level sets — C4 consumer.
# ---------------------------------------------------------------------------

def quantize_to_levels(
    v: jax.Array, levels: jax.Array, key: jax.Array | None = None
) -> tuple[jax.Array, jax.Array]:
    """Stochastically quantize v onto a sorted 1-D level set (unbiased).

    For v in [levels[j], levels[j+1]], rounds up with p = (v-lo)/(hi-lo), so
    E[Q(v)] = v for v inside the level range (values outside are clamped —
    callers scale into range first). Returns (codes, values).

    With ``key=None`` does deterministic nearest-level rounding.
    """
    return quantize_to_levels_jnp(v, levels, key)


# ---------------------------------------------------------------------------
# Convenience: per-channel int8 affine storage used by qmm / kv-cache paths.
# ---------------------------------------------------------------------------

def int_quantize(
    v: jax.Array, bits: int, axis: int | tuple | None, key: jax.Array | None = None
) -> QTensor:
    """Symmetric per-channel quantization to ``bits`` (stochastic if key given).

    ``axis``: reduction axes for the absmax scale (None = per-tensor). The scale
    keeps those axes with size 1 so dequantize broadcasts.
    """
    rounding = "nearest" if key is None else "stochastic"
    if axis is None:
        scheme = QScheme.int_symmetric(bits, rounding=rounding)
    else:
        scheme = QScheme.int_symmetric(bits, scaling="channel", rounding=rounding,
                                       channel_axis=axis)
    return encode_jnp(v, scheme, key)


def tv_variance(v: jax.Array, s: int, scale: jax.Array | None = None) -> jax.Array:
    """TV(v) = E||Q(v) - v||² in closed form (no sampling needed).

    For level width w = scale·(hi-lo): Var = (hi-v)(v-lo) per coordinate — the
    same err(x, I) the optimal-levels DP minimizes. Used by tests to check the
    Lemma 2 bound TV_s(v) <= min(n/s², √n/s)·||v||².
    """
    v32 = jnp.asarray(v, jnp.float32)
    if scale is None:
        scale = row_scale(v32)
    x = jnp.clip(jnp.abs(v32 / scale), 0.0, 1.0)
    t = x * s
    lo = jnp.clip(jnp.floor(t), 0, s - 1)
    frac = t - lo
    # variance in code units, scaled back: one interval of |v|/M has width scale/s
    w = scale / s
    return jnp.sum(frac * (1.0 - frac) * (w**2))


@functools.partial(jax.jit, static_argnames=("s", "signed"))
def _jit_roundtrip(v, key, s, signed):  # pragma: no cover - used in benches
    return stochastic_quantize(v, s, key, signed=signed)
