"""C1 — Unbiased stochastic quantization (ZipML §2.1, App. A.3).

Implements the paper's Q(v, s) with both scaling families:

* **row scaling**  — M_i(v) = ||v||_2 (or max|v|), one scalar per vector; used for
  gradients and models whose dynamic range moves during training.
* **column scaling** — M_i(v) = max over the dataset of |v_i| per coordinate;
  shared across all samples, computed once (paper App. A.3 "Column Scaling").

The quantizer maps v/M into [-1, 1], snaps each coordinate stochastically to one
of the two nearest of ``s+1`` uniformly spaced levels (l = 0..s), such that
E[Q(v, s)] = v exactly (Lemma 6, unbiasedness).

Also provides:
* ``quantize_to_levels`` — stochastic quantization onto an *arbitrary* sorted
  level set (used with the variance-optimal levels of core/optimal.py, C4).
* ``dequantize`` / packed integer codes — the storage format used by the data
  pipeline, the QAT path, and the Pallas kernels.
* deterministic nearest-rounding (the paper's §5.4 "straw man").

Everything is pure jnp and jit/vmap/pjit friendly; randomness always enters via
an explicit PRNG key (never global state) so kernels and hosts stay reproducible.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class Quantized(NamedTuple):
    """Storage format: integer codes + the scale(s) + the level count.

    ``codes`` are int8 (s <= 255) or int32 level indices in [0, s].
    ``scale`` broadcasts against the decoded array: scalar for row scaling,
    per-column vector for column scaling.
    ``signed`` quantizers map codes to [-1, 1]; unsigned to [0, 1].
    """

    codes: jax.Array
    scale: jax.Array
    s: int
    signed: bool = True

    @property
    def nbits(self) -> int:
        return int(jnp.ceil(jnp.log2(self.s + 1))) if self.s > 0 else 1

    def dequantize(self) -> jax.Array:
        return dequantize(self)


def _code_dtype(s: int):
    return jnp.int8 if s <= 127 else jnp.int32


def row_scale(v: jax.Array, norm: str = "linf") -> jax.Array:
    """M(v) per the paper: a scalar bound with |v|/M <= 1.

    ``linf`` (max|v|) gives tighter levels than the paper's WLOG ||v||_2 and is
    what the FPGA implementation uses in practice; both are supported.
    """
    if norm == "l2":
        m = jnp.linalg.norm(v)
    elif norm == "linf":
        m = jnp.max(jnp.abs(v))
    else:
        raise ValueError(f"unknown norm {norm!r}")
    return jnp.where(m == 0, 1.0, m).astype(jnp.float32)


def column_scale(data: jax.Array) -> jax.Array:
    """Per-feature M_i = max(|min_i|, |max_i|) over a (K, n) dataset (App. A.3)."""
    m = jnp.max(jnp.abs(data), axis=0)
    return jnp.where(m == 0, 1.0, m).astype(jnp.float32)


def quantize(
    v: jax.Array,
    s: int,
    key: jax.Array,
    scale: jax.Array | None = None,
    signed: bool = True,
) -> Quantized:
    """Stochastic uniform quantization Q(v, s) — unbiased (Lemma 6).

    Faithful to App. A.3 Eq. (10): Q_i = M_i · sgn(v_i) · μ_i where μ_i rounds
    |v_i|/M_i ∈ [0,1] stochastically onto the grid {0, 1/s, …, 1}. Signed codes
    are sign·level ∈ [-s, s] (s=1 gives the ternary {-M, 0, M} of QSGD).
    """
    v = jnp.asarray(v)
    if scale is None:
        scale = row_scale(v)
    x = (v / scale).astype(jnp.float32)
    mag = jnp.clip(jnp.abs(x) if signed else x, 0.0, 1.0)
    t = mag * s  # in [0, s]
    lo = jnp.clip(jnp.floor(t), 0, s - 1)  # lower level index
    p_up = t - lo  # P(round up), exactly unbiased
    u = jax.random.uniform(key, v.shape, dtype=jnp.float32)
    codes = lo + (u < p_up).astype(jnp.float32)
    if signed:
        codes = codes * jnp.sign(x)
    return Quantized(codes.astype(_code_dtype(s)), jnp.asarray(scale), s, signed)


def quantize_nearest(
    v: jax.Array, s: int, scale: jax.Array | None = None, signed: bool = True
) -> Quantized:
    """Deterministic nearest rounding — the §5.4 straw man (biased)."""
    v = jnp.asarray(v)
    if scale is None:
        scale = row_scale(v)
    x = (v / scale).astype(jnp.float32)
    mag = jnp.clip(jnp.abs(x) if signed else x, 0.0, 1.0)
    codes = jnp.round(mag * s)
    if signed:
        codes = codes * jnp.sign(x)
    return Quantized(codes.astype(_code_dtype(s)), jnp.asarray(scale), s, signed)


def dequantize(q: Quantized) -> jax.Array:
    return q.codes.astype(jnp.float32) / q.s * q.scale


def stochastic_quantize(
    v: jax.Array,
    s: int,
    key: jax.Array,
    scale: jax.Array | None = None,
    signed: bool = True,
) -> jax.Array:
    """quantize → dequantize in one step: returns the low-precision *values*.

    This is the form used in the double-sampling gradient math, where we care
    about the quantized real values, not the storage codes.
    """
    return dequantize(quantize(v, s, key, scale=scale, signed=signed))


# ---------------------------------------------------------------------------
# Arbitrary (variance-optimal) level sets — C4 consumer.
# ---------------------------------------------------------------------------

def quantize_to_levels(
    v: jax.Array, levels: jax.Array, key: jax.Array | None = None
) -> tuple[jax.Array, jax.Array]:
    """Stochastically quantize v onto a sorted 1-D level set (unbiased).

    For v in [levels[j], levels[j+1]], rounds up with p = (v-lo)/(hi-lo), so
    E[Q(v)] = v for v inside the level range (values outside are clamped —
    callers scale into range first). Returns (codes, values).

    With ``key=None`` does deterministic nearest-level rounding.
    """
    levels = jnp.asarray(levels, jnp.float32)
    v32 = jnp.asarray(v, jnp.float32)
    k = levels.shape[0]
    vc = jnp.clip(v32, levels[0], levels[-1])
    # searchsorted: index of the interval's upper endpoint
    hi_idx = jnp.clip(jnp.searchsorted(levels, vc, side="right"), 1, k - 1)
    lo_idx = hi_idx - 1
    lo = levels[lo_idx]
    hi = levels[hi_idx]
    width = jnp.maximum(hi - lo, 1e-30)
    p_up = (vc - lo) / width
    if key is None:
        up = p_up >= 0.5
    else:
        up = jax.random.uniform(key, v32.shape, dtype=jnp.float32) < p_up
    codes = jnp.where(up, hi_idx, lo_idx)
    values = jnp.where(up, hi, lo)
    return codes.astype(_code_dtype(k - 1)), values


# ---------------------------------------------------------------------------
# Convenience: per-channel int8 affine storage used by qmm / kv-cache paths.
# ---------------------------------------------------------------------------

class IntTensor(NamedTuple):
    """Symmetric per-channel int storage: value ≈ codes * scale.

    ``codes``: int8 in [-2^(b-1)+1, 2^(b-1)-1]; ``scale``: fp32, broadcastable
    along ``axis``. This is the on-HBM format consumed by kernels/qmm.py.
    """

    codes: jax.Array
    scale: jax.Array
    bits: int

    def dequantize(self) -> jax.Array:
        return self.codes.astype(jnp.float32) * self.scale


def int_quantize(
    v: jax.Array, bits: int, axis: int | tuple | None, key: jax.Array | None = None
) -> IntTensor:
    """Symmetric per-channel quantization to ``bits`` (stochastic if key given).

    ``axis``: reduction axes for the absmax scale (None = per-tensor). The scale
    keeps those axes with size 1 so dequantize broadcasts.
    """
    v32 = jnp.asarray(v, jnp.float32)
    qmax = float(2 ** (bits - 1) - 1)
    absmax = jnp.max(jnp.abs(v32), axis=axis, keepdims=axis is not None)
    scale = jnp.where(absmax == 0, 1.0, absmax / qmax).astype(jnp.float32)
    t = v32 / scale
    if key is None:
        codes = jnp.round(t)
    else:
        lo = jnp.floor(t)
        p_up = t - lo
        u = jax.random.uniform(key, v32.shape, dtype=jnp.float32)
        codes = lo + (u < p_up).astype(jnp.float32)
    codes = jnp.clip(codes, -qmax, qmax).astype(jnp.int8)
    return IntTensor(codes, scale, bits)


def tv_variance(v: jax.Array, s: int, scale: jax.Array | None = None) -> jax.Array:
    """TV(v) = E||Q(v) - v||² in closed form (no sampling needed).

    For level width w = scale·(hi-lo): Var = (hi-v)(v-lo) per coordinate — the
    same err(x, I) the optimal-levels DP minimizes. Used by tests to check the
    Lemma 2 bound TV_s(v) <= min(n/s², √n/s)·||v||².
    """
    v32 = jnp.asarray(v, jnp.float32)
    if scale is None:
        scale = row_scale(v32)
    x = jnp.clip(jnp.abs(v32 / scale), 0.0, 1.0)
    t = x * s
    lo = jnp.clip(jnp.floor(t), 0, s - 1)
    frac = t - lo
    # variance in code units, scaled back: one interval of |v|/M has width scale/s
    w = scale / s
    return jnp.sum(frac * (1.0 - frac) * (w**2))


@functools.partial(jax.jit, static_argnames=("s", "signed"))
def _jit_roundtrip(v, key, s, signed):  # pragma: no cover - used in benches
    return stochastic_quantize(v, s, key, signed=signed)
