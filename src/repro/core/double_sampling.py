"""C2/C3 — Double sampling and end-to-end quantized gradients (ZipML §2.2, App. B/E).

For least-squares-family losses the stochastic gradient g = a(aᵀx − b) is
*quadratic* in the sample a, so E[Q(a)Q(a)ᵀ] = aaᵀ + D_a ≠ aaᵀ — naive sample
quantization is biased (App. B.1) and SGD diverges when minimizers are large.

Double sampling draws two *independent* quantizations and uses

    g = ½ [ Q₁(a)(Q₂(a)ᵀx − b) + Q₂(a)(Q₁(a)ᵀx − b) ]

(the symmetrized estimator of the paper's footnote 2 — same unbiasedness, lower
variance by a constant). Independence gives E[g] = a(aᵀx − b) exactly.

The end-to-end variant (App. E) additionally quantizes the model (Q₃, row-scaled)
and the produced gradient (Q₄, row-scaled):

    g = Q₄( ½[Q₁(a)(Q₂(a)ᵀQ₃(x) − b) + Q₂(a)(Q₁(a)ᵀQ₃(x) − b)] ).

Model quantization commutes with the (linear) gradient → still unbiased (App. C);
gradient quantization is unbiased by Lemma 6 (App. D).

Everything here is vectorized over a minibatch: ``a`` has shape (B, n).

The sample-quantization hot path (the pair draw and the LSQ gradients built
from it) dispatches through ``kernels.registry``: the ``ref`` backend keeps
the original pure-jnp numerics bit-exactly, the ``pallas`` backend runs the
fused single-read ds_quant kernel and computes gradients from int8 codes.
Pass ``backend=`` explicitly, or control it globally via ``registry.select``
/ the ``ZIPML_KERNEL_BACKEND`` env var.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import registry

from .quantize import row_scale, stochastic_quantize


class DSConfig(NamedTuple):
    """Bit/level budget of each channel. s = #intervals (levels = s+1).

    ``s_sample``  — Q₁/Q₂ on samples (column-scaled by the data pipeline).
    ``s_model``   — Q₃ on the model (row-scaled), 0 = full precision.
    ``s_grad``    — Q₄ on the produced gradient (row-scaled), 0 = full precision.
    """

    s_sample: int = 15
    s_model: int = 0
    s_grad: int = 0


def double_sample_pair(a: jax.Array, s: int, key: jax.Array,
                       scale: jax.Array | None = None,
                       backend: str | None = None) -> tuple[jax.Array, jax.Array]:
    """Two independent unbiased quantizations of the same sample batch.

    Note on storage (paper §2.2 'Overhead of Storing Samples'): Q₁ and Q₂ share
    the same base level ⌊a·s⌋ and differ only in the up/down bit, so shipping
    both costs log₂(2)=1 extra bit, not 2×. The ``pallas`` backend realizes
    exactly that layout (one fused read → shared base + two up-bits, int8 code
    planes); the ``ref`` backend draws two independent dequantized tensors.
    """
    return registry.resolve(backend).ds_quant_values(a, s, key, scale=scale)


def lsq_gradient_fullprec(x: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """g^(full): mean over batch of a(aᵀx − b)."""
    resid = a @ x - b  # (B,)
    return a.T @ resid / a.shape[0]


def lsq_gradient_naive_quant(
    x: jax.Array, a: jax.Array, b: jax.Array, s: int, key: jax.Array,
    scale: jax.Array | None = None,
) -> jax.Array:
    """The *broken* estimator (App. B.1): one quantization used twice. Biased by
    D_a x; kept as a baseline so tests/benches can demonstrate the divergence."""
    qa = stochastic_quantize(a, s, key, scale=scale)
    resid = qa @ x - b
    return qa.T @ resid / a.shape[0]


def lsq_gradient_double_sampling(
    x: jax.Array, a: jax.Array, b: jax.Array, s: int, key: jax.Array,
    scale: jax.Array | None = None, backend: str | None = None,
) -> jax.Array:
    """Unbiased double-sampling gradient (symmetrized form, §2.2 + footnote 2).

    Dispatches through the kernel registry: ``ref`` computes q₁ᵀ(q₂x−b) on
    dequantized f32 tensors (the seed numerics); ``pallas`` never leaves the
    int8 code domain until the final (n,) gradient.
    """
    return registry.resolve(backend).lsq_ds_gradient(x, a, b, s, key, scale=scale)


def lsq_gradient_e2e(
    x: jax.Array, a: jax.Array, b: jax.Array, cfg: DSConfig, key: jax.Array,
    sample_scale: jax.Array | None = None, backend: str | None = None,
) -> jax.Array:
    """End-to-end quantized gradient (App. E, Eq. 13): samples + model + gradient.

    Update itself stays full precision (Eq. 14), matching the paper.
    """
    k_s, k_m, k_g = jax.random.split(key, 3)
    xq = x
    if cfg.s_model > 0:
        xq = stochastic_quantize(x, cfg.s_model, k_m, scale=row_scale(x))
    g = lsq_gradient_double_sampling(xq, a, b, cfg.s_sample, k_s,
                                     scale=sample_scale, backend=backend)
    if cfg.s_grad > 0:
        g = stochastic_quantize(g, cfg.s_grad, k_g, scale=row_scale(g))
    return g


def polynomial_estimator(
    coeffs: jax.Array, a: jax.Array, x: jax.Array, s: int, key: jax.Array,
    scale: jax.Array | None = None,
) -> jax.Array:
    """C6 helper — §4.1: unbiased estimator of P(aᵀx) = Σ m_i (aᵀx)^i using
    i independent quantizations per monomial: Π_{j≤i} Q_j(a)ᵀx.

    ``coeffs``: (d+1,) monomial coefficients m_0..m_d. Returns (B,) estimates.
    Variance grows with degree (Lemma 4) — the price of unbiasedness the paper's
    negative result (§5.4) is about.
    """
    d = coeffs.shape[0] - 1
    keys = jax.random.split(key, max(d, 1))
    B = a.shape[0]
    # products[i] = Π_{j<=i} Q_j(a)ᵀx ; build progressively
    out = jnp.full((B,), coeffs[0], jnp.float32)
    prod = jnp.ones((B,), jnp.float32)
    for i in range(1, d + 1):
        qa = stochastic_quantize(a, s, keys[i - 1], scale=scale)
        prod = prod * (qa @ x)
        out = out + coeffs[i] * prod
    return out
