"""ZipML core: the paper's contribution as composable JAX modules.

C1 quantize — unbiased stochastic quantization (row/column scaling, int storage)
C2 double_sampling — unbiased low-precision gradients for linear models
C3 linear.Precision(mode='e2e') — end-to-end sample+model+gradient quantization
C4 optimal — variance-optimal level DP / discretized / 2-approx solvers
C6 chebyshev — polynomial gradient approximation for non-linear losses
"""
from repro.quant import PrecisionPlan  # noqa: F401
from . import chebyshev, double_sampling, linear, optimal, quantize  # noqa: F401
from .linear import Dataset, TrainResult, make_dataset, train_linear  # noqa: F401
from .quantize import IntTensor, Quantized, int_quantize, stochastic_quantize  # noqa: F401


def __getattr__(name):
    if name == "Precision":
        import warnings

        warnings.warn(
            "repro.core.Precision is deprecated; use repro.quant.PrecisionPlan "
            "(same class, canonical field names)", DeprecationWarning,
            stacklevel=2)
        return PrecisionPlan
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
