"""ZipML linear-model suite — the paper's experimental core (§2, §5, App. F/G/J).

Implements, faithfully to Eq. (1)–(2):

    min_x  1/(2K) Σ l(a_kᵀx, b_k)² + R(x)
    x_{t+1} ← prox_{γR}( x_t − γ Q_g(g_k(Q_m(x_t), Q_s(a_t))) )

with the models the paper trains:

* linear regression      (least squares)
* least-squares SVM      (App. F.1 — identical gradient + c·x ridge term)
* SVM (hinge)            (App. G — Chebyshev step approx + ℓ₁/ℓ₂ refetching)
* logistic regression    (§4.2 — Chebyshev sigmoid approx; plus the §5.4
                           naive-rounding straw man)

Training drivers are jit-compiled with `lax.scan` over steps; quantization modes
are selected by a `Precision` config. Datasets are synthetic with controlled
spectrum/noise (the paper's public datasets aren't available offline — proxies
match dimensionality; see DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.quant import PrecisionPlan

from . import optimal
from .chebyshev import ChebGradConfig, quantized_poly_gradient, sigmoid_prime_coeffs, step_coeffs
from .double_sampling import (
    lsq_gradient_double_sampling,
    lsq_gradient_e2e,
    lsq_gradient_fullprec,
    lsq_gradient_naive_quant,
)
from .quantize import quantize_nearest, quantize_to_levels, stochastic_quantize


def __getattr__(name):
    if name == "Precision":
        import warnings

        warnings.warn(
            "core.linear.Precision is deprecated; use repro.quant.PrecisionPlan "
            "(same class, canonical field names)", DeprecationWarning,
            stacklevel=2)
        return PrecisionPlan
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


# ---------------------------------------------------------------------------
# Datasets
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Dataset:
    a_train: np.ndarray
    b_train: np.ndarray
    a_test: np.ndarray
    b_test: np.ndarray
    name: str = "synthetic"

    @property
    def n_features(self) -> int:
        return self.a_train.shape[1]


def make_dataset(
    kind: str, n_train: int = 10_000, n_test: int = 10_000, n_features: int = 100,
    noise: float = 0.1, seed: int = 0, classification: bool = False,
) -> Dataset:
    """Synthetic proxies for Table 1 datasets.

    ``kind``: 'synthetic10'/'synthetic100'/'synthetic1000' (regression),
    'yearprediction' (90 features), 'cadata'(8), 'cpusmall'(12),
    'cod-rna'(8, classification), 'gisette'(5000, classification).
    """
    presets = {
        "synthetic10": (10, False), "synthetic100": (100, False),
        "synthetic1000": (1000, False), "yearprediction": (90, False),
        "cadata": (8, False), "cpusmall": (12, False),
        "cod-rna": (8, True), "gisette": (5000, True),
    }
    if kind in presets:
        n_features, classification = presets[kind]
        if kind == "gisette":
            n_train, n_test = 6000, 1000
        if kind == "cod-rna":
            n_train, n_test = 20000, 10000
    rng = np.random.default_rng(seed)
    n = n_train + n_test
    # anisotropic features in [-1, 1] (column scaling is then the identity —
    # matching the paper's normalized-samples assumption ||a|| ≤ 1 after scale)
    spectrum = np.linspace(1.0, 0.2, n_features)
    a = rng.uniform(-1, 1, (n, n_features)) * spectrum
    x_true = rng.normal(0, 1, n_features) / np.sqrt(n_features)
    logits = a @ x_true
    if classification:
        p = 1 / (1 + np.exp(-4 * logits / max(np.std(logits), 1e-9)))
        b = (rng.uniform(size=n) < p).astype(np.float64) * 2 - 1
    else:
        b = logits + noise * rng.normal(size=n)
    return Dataset(a[:n_train], b[:n_train], a[n_train:], b[n_train:], name=kind)


# ---------------------------------------------------------------------------
# Regularizers / prox operators (Eq. 2)
# ---------------------------------------------------------------------------

def prox_none(x, gamma):
    return x


def prox_l2(x, gamma, lam=1e-4):
    return x / (1.0 + gamma * lam)


def prox_l1(x, gamma, lam=1e-4):
    t = gamma * lam
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - t, 0.0)


def prox_l2_ball(x, gamma, radius=10.0):
    nrm = jnp.linalg.norm(x)
    return jnp.where(nrm > radius, x * (radius / nrm), x)


PROX = {"none": prox_none, "l2": prox_l2, "l1": prox_l1, "ball": prox_l2_ball}


# ---------------------------------------------------------------------------
# Precision configuration — repro.quant.PrecisionPlan (the one four-channel
# plan; `Precision` is its deprecated alias via module __getattr__).
#
#   mode:
#     'full'    — fp32 SGD (baseline)
#     'naive'   — single quantization reused (the biased straw man of App. B.1)
#     'double'  — double sampling (C2)
#     'e2e'     — samples+model+gradient all quantized (C3 / App. E)
#     'nearest' — deterministic nearest-rounding of samples (§5.4 straw man)
#   *_bits: bit budget per channel; s = 2^bits − 1 intervals.
# ---------------------------------------------------------------------------

def fit_feature_levels(a_train: np.ndarray, bits: int, method: str = "discretized",
                       max_features_exact: int = 2000) -> np.ndarray:
    """Per-feature variance-optimal levels (Fig. 7a setup: 'quantization points
    are calculated for each feature'). Returns (n_features, s+1) in [0,1] units
    of the column scale."""
    s = 2**bits - 1
    scale = np.maximum(np.abs(a_train).max(axis=0), 1e-12)
    z = np.abs(a_train) / scale  # fold to [0,1]; signed handled by symmetric map
    out = np.zeros((a_train.shape[1], s + 1))
    for f in range(a_train.shape[1]):
        out[f] = optimal.optimal_levels_discretized(z[:, f], s, M=128)
    return out


# ---------------------------------------------------------------------------
# Gradients per model family
# ---------------------------------------------------------------------------

def _sample_batch(a, b, key, batch):
    idx = jax.random.randint(key, (batch,), 0, a.shape[0])
    return a[idx], b[idx]


def _quantize_with_levels(a, levels, scale, key):
    """Per-feature optimal-level quantization (signed, folded): unbiased."""
    sign = jnp.sign(a)
    z = jnp.abs(a) / scale  # (B, n) in [0,1]
    keys = jax.random.fold_in(key, 7)
    # vectorized per-feature searchsorted via vmap over feature axis
    def perf(zf, lf, kf):
        _, vals = quantize_to_levels(zf, lf, kf)
        return vals
    ks = jax.random.split(keys, z.shape[1])
    vals = jax.vmap(perf, in_axes=(1, 0, 0), out_axes=1)(z, levels, ks)
    return sign * vals * scale


def make_lsq_grad(prec: PrecisionPlan, sample_scale, levels=None):
    """Gradient fn(x, a, b, key) for least-squares objectives under ``prec``."""

    def grad(x, a, b, key):
        if prec.mode == "full":
            return lsq_gradient_fullprec(x, a, b)
        if prec.mode == "naive":
            return lsq_gradient_naive_quant(x, a, b, prec.s_sample, key, scale=sample_scale)
        if prec.mode == "nearest":
            qa = quantize_nearest(a, prec.s_sample, scale=sample_scale).dequantize()
            return lsq_gradient_fullprec(x, qa, b)
        if prec.mode == "double":
            if levels is not None:
                k1, k2 = jax.random.split(key)
                q1 = _quantize_with_levels(a, levels, sample_scale, k1)
                q2 = _quantize_with_levels(a, levels, sample_scale, k2)
                B = a.shape[0]
                return (q1.T @ (q2 @ x - b) + q2.T @ (q1 @ x - b)) / (2.0 * B)
            return lsq_gradient_double_sampling(x, a, b, prec.s_sample, key,
                                                scale=sample_scale,
                                                backend=prec.backend)
        if prec.mode == "e2e":
            return lsq_gradient_e2e(x, a, b, prec.ds_config(), key,
                                    sample_scale=sample_scale,
                                    backend=prec.backend)
        raise ValueError(prec.mode)

    return grad


# ---------------------------------------------------------------------------
# Training drivers
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TrainResult:
    x: np.ndarray
    losses: np.ndarray          # training loss per epoch
    extra: dict | None = None


def _epoch_losses(loss_fn, xs_per_epoch, a, b):
    return jax.vmap(lambda x: loss_fn(x, a, b))(xs_per_epoch)


def train_linear(
    ds: Dataset, prec: PrecisionPlan = PrecisionPlan(), *, model: str = "linreg",
    epochs: int = 20, batch: int = 16, lr: float = 0.1, reg: str = "none",
    ridge_c: float = 1e-3, seed: int = 0, cheb: ChebGradConfig | None = None,
    refetch: str | None = None,
) -> TrainResult:
    """Unified SGD driver for the paper's four models.

    model ∈ {'linreg', 'lssvm', 'svm', 'logistic'}.
    * linreg/lssvm use the (optionally double-sampled/e2e) LSQ gradient.
    * svm/logistic in low-precision mode use Chebyshev polynomial gradients
      (cheb config), or full-precision subgradients otherwise. ``refetch``
      ∈ {None, 'l1'} enables the App. G.4 bound check + full-precision refetch.

    Steps use diminishing stepsize lr/epoch_idx (paper §5 setup).
    """
    a_np, b_np = ds.a_train, ds.b_train
    a = jnp.asarray(a_np, jnp.float32)
    b = jnp.asarray(b_np, jnp.float32)
    col_scale = jnp.asarray(np.maximum(np.abs(a_np).max(axis=0), 1e-12), jnp.float32)
    prox = PROX[reg]

    # Per-model Chebyshev defaults: the logistic optimum can have large ‖x‖, so
    # it needs a wide fit range (R=16 matches fp32 loss on the proxy datasets);
    # the SVM step-function fit degrades on wide ranges, so it keeps R=4.
    if cheb is None:
        cheb = ChebGradConfig(R=16.0) if model == "logistic" else ChebGradConfig(R=4.0)

    # §4.2 assumption for polynomial gradients: constrain ‖x‖₂ so |aᵀx| ≤ R —
    # otherwise the degree-d Chebyshev polynomial diverges outside its range.
    if model in ("logistic", "svm") and prec.mode in ("double", "e2e"):
        a_norm_max = float(np.linalg.norm(a_np, axis=1).max())
        radius = cheb.R / max(a_norm_max, 1e-9)
        inner_prox = prox
        prox = lambda x, g: prox_l2_ball(inner_prox(x, g), g, radius=radius)  # noqa: E731

    levels = None
    if prec.optimal_levels and prec.mode in ("double",):
        levels = jnp.asarray(
            fit_feature_levels(a_np, prec.sample_bits, prec.optimal_method), jnp.float32
        )

    if model in ("linreg", "lssvm"):
        # LS-SVM (App. F.1) reduces to ridge linear regression on ±1 labels.
        base_grad = make_lsq_grad(prec, col_scale, levels)
        ridge = ridge_c if model == "lssvm" else 0.0

        def grad_fn(x, ab, bb, key):
            g = base_grad(x, ab, bb, key)
            return g + ridge * x

        def loss_fn(x, aa, bb):
            r = aa @ x - bb
            return 0.5 * jnp.mean(r * r) + 0.5 * ridge * jnp.sum(x * x)

    elif model == "logistic":
        # wide fit range: the unconstrained logistic optimum can have large ‖x‖,
        # and the R-ball projection (below) must not bind — R=16 empirically
        # matches full-precision loss to 3 decimals on the proxy datasets.
        assert cheb is not None
        coeffs = jnp.asarray(sigmoid_prime_coeffs(cheb.degree, cheb.R), jnp.float32)

        def grad_fn(x, ab, bb, key):
            if prec.mode == "full":
                z = bb * (ab @ x)
                return (ab * (bb * (-jax.nn.sigmoid(-z)))[:, None]).mean(0)
            if prec.mode in ("nearest", "naive"):
                k = jax.random.fold_in(key, 3)
                if prec.mode == "nearest":
                    qa = quantize_nearest(ab, prec.s_sample, scale=col_scale).dequantize()
                else:
                    qa = stochastic_quantize(ab, prec.s_sample, k, scale=col_scale)
                z = bb * (qa @ x)
                return (qa * (bb * (-jax.nn.sigmoid(-z)))[:, None]).mean(0)
            return quantized_poly_gradient(coeffs, x, ab, bb, cheb.s, key, scale=col_scale)

        def loss_fn(x, aa, bb):
            z = bb * (aa @ x)
            return jnp.mean(jnp.logaddexp(0.0, -z))

    elif model == "svm":
        assert cheb is not None
        coeffs = jnp.asarray(
            -step_coeffs(cheb.degree, cheb.R, cheb.delta), jnp.float32
        )  # ℓ'(z) = -H(1-z) in z = b·aᵀx ⇒ fit on shifted arg below

        def grad_fn(x, ab, bb, key):
            if prec.mode == "full":
                z = bb * (ab @ x)
                active = (z < 1.0).astype(jnp.float32)
                return (ab * (-bb * active)[:, None]).mean(0)
            if prec.mode in ("nearest", "naive"):
                # §5.4 straw man: quantize samples, plain subgradient
                kq2 = jax.random.fold_in(key, 5)
                if prec.mode == "nearest":
                    qa = quantize_nearest(ab, prec.s_sample, scale=col_scale
                                          ).dequantize()
                else:
                    qa = stochastic_quantize(ab, prec.s_sample, kq2,
                                             scale=col_scale)
                z = bb * (qa @ x)
                active = (z < 1.0).astype(jnp.float32)
                return (qa * (-bb * active)[:, None]).mean(0)
            k_q, k_p = jax.random.split(key)
            if refetch == "l1":
                # App. G.4: bounds on 1 − b aᵀx from a single quantization
                qa = stochastic_quantize(ab, prec.s_sample, k_q, scale=col_scale)
                margin_q = 1.0 - bb * (qa @ x)
                slack = jnp.sum(jnp.abs(x) * col_scale) / prec.s_sample
                certain = jnp.abs(margin_q) > slack
                # certain rows: use quantized subgradient; others: full precision
                active_q = (margin_q > 0).astype(jnp.float32)
                g_q = qa * (-bb * active_q)[:, None]
                z = bb * (ab @ x)
                g_f = ab * (-bb * (z < 1.0).astype(jnp.float32))[:, None]
                g = jnp.where(certain[:, None], g_q, g_f)
                return g.mean(0), (1.0 - certain.astype(jnp.float32)).mean()
            # Chebyshev on H(1 − z): evaluate P at (1 − b aᵀx) via shifted samples
            # P(1 − z) with z = b aᵀx: use polynomial in b·aᵀx after refit; here we
            # fit H on u = 1 − z directly by composing with sample negation.
            g = quantized_poly_gradient(coeffs, x, ab, bb, cheb.s, k_p, scale=col_scale)
            return g

        def loss_fn(x, aa, bb):
            return jnp.mean(jnp.maximum(0.0, 1.0 - bb * (aa @ x)))

    else:
        raise ValueError(model)

    # --- scan-based epoch loop ---------------------------------------------
    steps_per_epoch = max(a.shape[0] // batch, 1)
    x0 = jnp.zeros((ds.n_features,), jnp.float32)
    refetch_mode = model == "svm" and refetch == "l1" and prec.mode != "full"

    @jax.jit
    def run_epoch(x, key, gamma):
        def step(carry, k):
            x, rf = carry
            kb, kg = jax.random.split(k)
            ab, bb = _sample_batch(a, b, kb, batch)
            if refetch_mode:
                g, frac = grad_fn(x, ab, bb, kg)
                rf = rf + frac
            else:
                g = grad_fn(x, ab, bb, kg)
            x = prox(x - gamma * g, gamma)
            return (x, rf), None

        keys = jax.random.split(key, steps_per_epoch)
        (x, rf), _ = jax.lax.scan(step, (x, 0.0), keys)
        return x, rf / steps_per_epoch

    losses, x = [], x0
    key = jax.random.PRNGKey(seed)
    refetch_fracs = []
    loss_j = jax.jit(loss_fn)
    for ep in range(epochs):
        key, sub = jax.random.split(key)
        gamma = lr / (ep + 1.0)  # paper's diminishing stepsize α/k
        x, rf = run_epoch(x, sub, gamma)
        refetch_fracs.append(float(rf))
        losses.append(float(loss_j(x, a, b)))
    extra = {"refetch_frac": refetch_fracs} if refetch_mode else None
    return TrainResult(np.asarray(x), np.asarray(losses), extra)


def eval_accuracy(ds: Dataset, x: np.ndarray) -> float:
    pred = np.sign(ds.a_test @ x)
    return float((pred == np.sign(ds.b_test)).mean())


def eval_mse(ds: Dataset, x: np.ndarray) -> float:
    r = ds.a_test @ x - ds.b_test
    return float(0.5 * np.mean(r * r))
