"""ZipML end-to-end low-precision training, reproduced on JAX/Pallas."""
from . import quant  # noqa: F401  (canonical quantization API)
