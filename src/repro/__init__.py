"""ZipML end-to-end low-precision training, reproduced on JAX/Pallas."""
