"""Data pipeline: deterministic sharded token streams with resumable cursors.

The paper's SampleStore (Fig. 2) with its "quantize during epoch 0, stream
int4/int8 afterwards" design maps to: an int8/int4-quantized sample store whose
column scales are computed on the first pass, and a loader that emits
pre-quantized batches. For LM training the stream is synthetic (offline
container) but the machinery — per-host sharding, skip-ahead cursors,
checkpointable state — is the production part.

Determinism contract: batch i of host h is a pure function of (seed, i, h), so
restore-from-checkpoint = set cursor; elastic re-sharding = recompute host
assignment. No state lives outside ``Cursor``.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Cursor:
    """Checkpointable pipeline position."""
    step: int = 0
    epoch: int = 0

    def to_dict(self):
        return {"step": self.step, "epoch": self.epoch}

    @staticmethod
    def from_dict(d):
        return Cursor(int(d["step"]), int(d["epoch"]))


@dataclasses.dataclass(frozen=True)
class TokenStreamConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    # synthetic stream statistics: zipf-ish unigram + short-range repetition,
    # so the loss actually has learnable structure in examples/tests
    zipf_a: float = 1.2
    repeat_p: float = 0.3


class TokenStream:
    """Deterministic, host-sharded synthetic LM token stream."""

    def __init__(self, cfg: TokenStreamConfig, cursor: Cursor = Cursor()):
        self.cfg = cfg
        self.cursor = cursor
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = ranks ** -cfg.zipf_a
        self._probs = probs / probs.sum()
        if cfg.global_batch % cfg.n_hosts:
            raise ValueError("global_batch must divide across hosts")
        self._host_batch = cfg.global_batch // cfg.n_hosts

    def _batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.host_id]))
        b, s = self._host_batch, cfg.seq_len
        toks = rng.choice(cfg.vocab_size, size=(b, s + 1), p=self._probs)
        # short-range repetition: with prob p, copy the token 2 back
        rep = rng.random((b, s + 1)) < cfg.repeat_p
        toks[:, 2:] = np.where(rep[:, 2:], toks[:, :-2], toks[:, 2:])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "targets": toks[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()

    def next_batch(self) -> dict:
        batch = self._batch_at(self.cursor.step)
        self.cursor = Cursor(self.cursor.step + 1, self.cursor.epoch)
        return batch

    def skip_to(self, cursor: Cursor):
        self.cursor = cursor


@dataclasses.dataclass
class QuantizedSampleStore:
    """The paper's pre-quantized sample store (linear models).

    First pass computes per-feature column scales (App. A.3); samples are then
    held as int codes. ``draw(step, batch, n_samples)`` reproduces the FPGA
    pipeline's read path: ship codes (+1 bit per extra double-sampling draw,
    §2.2) and dequantize at the consumer.
    """

    codes: np.ndarray          # (K, n) int8 level indices of |a| (sign folded in)
    scale: np.ndarray          # (n,) column scales
    labels: np.ndarray         # (K,)
    s: int                     # levels

    @staticmethod
    def build(a: np.ndarray, b: np.ndarray, bits: int, seed: int = 0):
        s = 2**bits - 1
        scale = np.maximum(np.abs(a).max(axis=0), 1e-12)
        t = a / scale * s                     # in [-s, s]
        rng = np.random.default_rng(seed)
        lo = np.floor(t)
        codes = lo + (rng.random(a.shape) < (t - lo))
        return QuantizedSampleStore(codes.astype(np.int8), scale.astype(np.float32),
                                    b.astype(np.float32), s)

    def bytes_per_sample(self) -> float:
        bits = np.ceil(np.log2(2 * self.s + 1))
        return bits * self.codes.shape[1] / 8.0

    def draw(self, step: int, batch: int):
        """Deterministic minibatch of dequantized samples + labels."""
        rng = np.random.default_rng(np.random.SeedSequence([17, step]))
        idx = rng.integers(0, self.codes.shape[0], batch)
        a = self.codes[idx].astype(np.float32) / self.s * self.scale
        return jnp.asarray(a), jnp.asarray(self.labels[idx])
