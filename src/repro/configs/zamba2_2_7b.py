"""zamba2-2.7b [arXiv:2411.15242; hf] — hybrid: 54 Mamba2 layers with a single
SHARED full-attention block applied every 9 layers (6 applications; the
published model alternates two shared blocks — collapsed to one, recorded in
DESIGN.md). long_500k runs: SSM state decode + O(S)-per-token shared attention."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=10240, vocab_size=32000, ssm_state=64, ssm_head_dim=64,
    shared_attn_every=9, mlp_act="gelu", attn_shard="heads",
)

REDUCED = ModelConfig(
    name="zamba2-2.7b-reduced", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256, ssm_state=16, ssm_head_dim=16,
    shared_attn_every=2, mlp_act="gelu", attn_shard="heads",
    q_chunk=16, logit_chunk=16,
)
