"""qwen2.5-14b [hf:Qwen] — dense GQA with QKV bias. H=40 does not divide the
16-way model axis → sequence-sharded attention activations."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=13824, vocab_size=152064, qkv_bias=True, mlp_act="silu",
    attn_shard="seq",
)

REDUCED = ModelConfig(
    name="qwen2.5-14b-reduced", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, qkv_bias=True, mlp_act="silu", attn_shard="seq",
    q_chunk=16, logit_chunk=16,
)
