"""llama-3.2-vision-11b [hf:meta-llama; unverified] — text backbone with a
cross-attention layer after every 5 self-attention layers. The vision tower is
a STUB: input_specs supplies precomputed patch embeddings (B, 4096, d_model)."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=128256, cross_attn_every=5, n_vis_tokens=4096,
    mlp_act="silu", rope_theta=5e5, attn_shard="heads",
)

REDUCED = ModelConfig(
    name="llama-3.2-vision-11b-reduced", family="vlm",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, cross_attn_every=2, n_vis_tokens=16,
    mlp_act="silu", attn_shard="heads", q_chunk=16, logit_chunk=16,
)
