"""granite-moe-3b-a800m [hf:ibm-granite] — 40-expert top-8 fine-grained MoE
(d_ff=512 per expert). H=24 does not divide the 16-way model axis → sequence-
sharded attention activations (DESIGN.md §3.1)."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155, n_experts=40, top_k=8,
    mlp_act="silu", attn_shard="seq",
)

REDUCED = ModelConfig(
    name="granite-moe-3b-a800m-reduced", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=32, vocab_size=256, n_experts=8, top_k=4,
    mlp_act="silu", attn_shard="seq", q_chunk=16, logit_chunk=16,
)
