"""musicgen-medium [arXiv:2306.05284; hf] — decoder-only over EnCodec tokens
(vocab 2048). Frontend (EnCodec + delay pattern) is a stub: input_specs feeds
flattened codebook token ids. H=24 → sequence-sharded attention."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
    d_ff=6144, vocab_size=2048, mlp_act="gelu", attn_shard="seq",
)

REDUCED = ModelConfig(
    name="musicgen-medium-reduced", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=128, mlp_act="gelu", attn_shard="seq",
    q_chunk=16, logit_chunk=16,
)
