"""gemma-2b [arXiv:2403.08295; hf] — MQA (kv=1), GeGLU, head_dim=256, 256k
vocab. H=8 < model axis → sequence-sharded attention."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=256000, mlp_act="gelu", attn_shard="seq",
)

REDUCED = ModelConfig(
    name="gemma-2b-reduced", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab_size=512, mlp_act="gelu", attn_shard="seq",
    q_chunk=16, logit_chunk=16,
)
