"""granite-3-8b [hf:ibm-granite] — dense GQA decoder (kv=8)."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=12800, vocab_size=49155, mlp_act="silu", attn_shard="heads",
)

REDUCED = ModelConfig(
    name="granite-3-8b-reduced", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, mlp_act="silu", attn_shard="heads",
    q_chunk=16, logit_chunk=16,
)
