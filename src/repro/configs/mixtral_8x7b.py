"""mixtral-8x7b [arXiv:2401.04088; hf] — 8-expert top-2 MoE with sliding-window
attention (W=4096). SWA makes long_500k runnable via a ring KV cache."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000, n_experts=8, top_k=2,
    window=4096, mlp_act="silu", rope_theta=1e6, attn_shard="heads",
)

REDUCED = ModelConfig(
    name="mixtral-8x7b-reduced", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, n_experts=4, top_k=2,
    window=32, mlp_act="silu", attn_shard="heads", q_chunk=16, logit_chunk=16,
)
