"""Architecture registry + assigned input shapes.

Each assigned architecture has its own module defining:
  CONFIG   — the full published configuration (exercised via dry-run only)
  REDUCED  — a same-family miniature for CPU smoke tests

``get_config(name)`` / ``get_reduced(name)`` look them up; ``--arch`` flags in
the launchers resolve through here. ``SHAPES`` are the assigned input shapes;
``supported_shapes(cfg)`` applies the long_500k sub-quadratic rule
(DESIGN.md §6): SSM/hybrid/windowed-attention architectures run it, pure
full-attention architectures skip it.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.quant import PrecisionPlan  # noqa: F401  (canonical plan)
from repro.models.transformer import ModelConfig  # noqa: F401

ARCH_IDS = (
    "mixtral-8x7b",
    "granite-moe-3b-a800m",
    "gemma-7b",
    "granite-3-8b",
    "qwen2.5-14b",
    "gemma-2b",
    "zamba2-2.7b",
    "llama-3.2-vision-11b",
    "musicgen-medium",
    "mamba2-780m",
)

_MODULES = {name: name.replace("-", "_").replace(".", "_") for name in ARCH_IDS}


def get_config(name: str, **overrides) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    cfg = mod.CONFIG
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def get_reduced(name: str, **overrides) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    cfg = mod.REDUCED
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str           # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def supported_shapes(cfg: ModelConfig) -> list[str]:
    names = ["train_4k", "prefill_32k", "decode_32k"]
    subquadratic = cfg.family in ("ssm", "hybrid") or cfg.window > 0
    if subquadratic:
        names.append("long_500k")
    return names


def all_cells() -> list[tuple[str, str]]:
    """Every live (arch, shape) cell for the dry-run matrix."""
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in supported_shapes(cfg):
            cells.append((arch, shape))
    return cells
