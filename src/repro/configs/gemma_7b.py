"""gemma-7b [arXiv:2403.08295; hf] — GeGLU MLP, head_dim=256, 16 MHA heads,
256k vocabulary (vocab-parallel readout + chunked cross-entropy)."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24576, vocab_size=256000, mlp_act="gelu", attn_shard="heads",
)

REDUCED = ModelConfig(
    name="gemma-7b-reduced", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512, mlp_act="gelu", attn_shard="heads",
    q_chunk=16, logit_chunk=16,
)
