"""mamba2-780m [arXiv:2405.21060; unverified] — attention-free SSD stack.
d_inner=3072 (expand 2), 48 SSM heads of dim 64, state 128. long_500k decode
is O(1)-state. The paper's KV-channel quantization is inapplicable (no KV
cache); the SSM state is the analogous quantization target (DESIGN.md §5)."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
    d_ff=0, vocab_size=50280, ssm_state=128, ssm_head_dim=64,
    attn_shard="none",
)

REDUCED = ModelConfig(
    name="mamba2-780m-reduced", family="ssm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=0, vocab_size=256, ssm_state=16, ssm_head_dim=16,
    attn_shard="none", q_chunk=16, logit_chunk=16,
)
