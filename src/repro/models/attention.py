"""Attention: GQA/MQA, sliding-window (SWA), chunked-query training attention,
and single-token decode against a (possibly quantized) KV cache.

Design notes (TPU adaptation):
* Training/prefill attention is *query-chunked*: a `lax.scan` over query blocks
  computes full softmax per block against the (optionally windowed) KV range.
  Peak score memory is (B, H, Cq, Skv_range) per block instead of O(S²); with
  SWA the KV range is a static-size dynamic slice → sub-quadratic compute.
* Decode attention relies on GSPMD: the KV cache is sharded over sequence on
  the `model` axis; softmax over the sharded axis becomes tiny stat reductions.
* KV cache storage supports the ZipML int8/int4 path (precision/kvcache.py);
  here we accept either raw bf16 caches or `QuantKV` wrappers.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import Params, apply_rope, dense, init_dense

NEG_INF = -2.0 ** 30  # large-but-finite: keeps fully-masked rows NaN-free


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int, head_dim: int,
                   *, qkv_bias: bool = False, dtype=jnp.bfloat16) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "q": init_dense(kq, d_model, n_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "k": init_dense(kk, d_model, n_kv_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "v": init_dense(kv, d_model, n_kv_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "o": init_dense(ko, n_heads * head_dim, d_model, dtype=dtype,
                        scale=(n_heads * head_dim) ** -0.5),
    }


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    window: int = 0            # 0 = full causal
    rope_theta: float = 10_000.0
    q_chunk: int = 1024        # query block length for chunked attention
    shard: str = "heads"       # 'heads' | 'seq' | 'none' — activation sharding
    softmax_scale: float | None = None
    unroll: bool = False       # python-loop the q-block scan (dry-run cost accounting)
    dp: tuple = ("data",)      # data-parallel mesh axes (('pod','data') multi-pod)

    @property
    def scale(self) -> float:
        return self.softmax_scale or self.head_dim ** -0.5


def _repeat_kv(x: jax.Array, n_rep: int) -> jax.Array:
    """(B, S, Hkv, D) → (B, S, Hkv*n_rep, D) for GQA score einsums."""
    if n_rep == 1:
        return x
    b, s, h, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(
        b, s, h * n_rep, d)


def _q_spec(spec: AttnSpec):
    dp = spec.dp if len(spec.dp) > 1 else spec.dp[0]
    if spec.shard == "heads":
        return P(dp, None, "model", None)
    if spec.shard == "seq":
        return P(dp, "model", None, None)
    return P(dp, None, None, None)


def _attend_block(q, k, v, scale, mask):
    """Grouped-query attention block without materializing repeated KV.

    q: (B,Cq,H,D)  k/v: (B,Skv,G,D) with G=Hkv, H=G·R  mask: (Cq,Skv) bool,
    or (B,Cq,Skv) when each sequence masks its own context (the serving
    engine's batched verify step — every slot sits at a different length).
    The (B,S,G,R,D) repeat broadcast would cost n_rep× KV memory and bait
    GSPMD into awkward G-way shardings — the grouped einsum avoids both.
    """
    b, cq, h, d = q.shape
    g = k.shape[2]
    r = h // g
    qg = q.reshape(b, cq, g, r, d)
    scores = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    mask_b = mask[:, None, None] if mask.ndim == 3 else mask[None, None, None]
    scores = jnp.where(mask_b, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, cq, h, d).astype(q.dtype)


def chunked_attention(q, k, v, spec: AttnSpec, *, positions=None,
                      causal: bool = True) -> jax.Array:
    """Causal (optionally sliding-window) attention over query blocks.

    q: (B, S, H, D); k/v: (B, S, Hkv, D) — pre-RoPE'd. Returns (B, S, H, D).

    Query blocks are pre-stacked and scanned over the *leading* axis — a
    dynamic_slice with a loop-carried start on the (sharded) sequence dim
    would force GSPMD to fully replicate q. With ``spec.window > 0`` the
    overlapping KV windows are pre-gathered per block (static shapes) ⇒
    O(S·W) compute and memory.
    """
    b, s, h, d = q.shape
    kf, vf = k, v
    cq = min(spec.q_chunk, s)
    # pad the query tail to a block multiple instead of collapsing to one
    # block — the old `cq = s` fallback silently disabled chunking (and its
    # O(S·W) memory bound) for ANY length not divisible by q_chunk. Padded
    # query rows attend causally to real keys only (their positions are
    # ≥ every real k_pos, so no mask row is empty) and are sliced off.
    s_pad = -(-s // cq) * cq
    if s_pad != s:
        q = jnp.pad(q, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
    n_blocks = s_pad // cq
    windowed = causal and spec.window > 0 and spec.window < s
    kv_span = min(spec.window + cq, s) if windowed else s

    def attend(q_blk, k_blk, v_blk, q_pos, k_pos):
        if causal:
            mask = q_pos[:, None] >= k_pos[None, :]
            if spec.window > 0:
                mask &= q_pos[:, None] - k_pos[None, :] < spec.window
        else:
            mask = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
        return _attend_block(q_blk, k_blk, v_blk, spec.scale, mask)

    if n_blocks == 1:
        return attend(q, kf, vf, jnp.arange(s), jnp.arange(kf.shape[1]))

    qb = q.reshape(b, n_blocks, cq, h, d).transpose(1, 0, 2, 3, 4)
    q_pos_b = (jnp.arange(n_blocks)[:, None] * cq + jnp.arange(cq)[None])

    if windowed:
        starts = jnp.clip(jnp.arange(n_blocks) * cq + cq - kv_span, 0, s - kv_span)
        idx = starts[:, None] + jnp.arange(kv_span)[None]        # (nb, span)
        kb = jnp.moveaxis(kf[:, idx], 1, 0)                      # (nb, B, span, H, D)
        vb = jnp.moveaxis(vf[:, idx], 1, 0)
        xs = (qb, kb, vb, q_pos_b, idx)
        body = lambda _, t: (None, attend(t[0], t[1], t[2], t[3], t[4]))
    else:
        xs = (qb, q_pos_b)
        k_pos = jnp.arange(kf.shape[1])
        body = lambda _, t: (None, attend(t[0], kf, vf, t[1], k_pos))

    # remat each q-block: otherwise the block scan stacks every block's
    # (Cq, Skv) probs as bwd residuals — O(S²) memory, exactly what the
    # chunking exists to avoid
    body = jax.checkpoint(body)
    if spec.unroll:
        outs = jnp.stack([body(None, jax.tree.map(lambda t: t[i], xs))[1]
                          for i in range(n_blocks)])
    else:
        _, outs = jax.lax.scan(body, None, xs)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, s_pad, h, d)
    return out[:, :s] if s_pad != s else out


def decode_attention(q, k_cache, v_cache, spec: AttnSpec, *, kv_len) -> jax.Array:
    """One-token attention: q (B, 1, H, D) vs cache (B, Smax, Hkv, D).

    ``kv_len``: number of valid cache entries (scalar or (B,)). The cache seq
    axis is expected sharded over 'model' (launcher sets it); the masked
    softmax over that axis lowers to per-shard work + small stat reductions.
    """
    b, _, h, d = q.shape
    smax = k_cache.shape[1]
    g = k_cache.shape[2]
    r = h // g
    qg = q.reshape(b, 1, g, r, d)
    scores = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k_cache,
                        preferred_element_type=jnp.float32) * spec.scale
    pos = jnp.arange(smax)
    valid = pos[None, :] < jnp.reshape(jnp.asarray(kv_len), (-1, 1))  # (B, Smax)
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", probs.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, d).astype(q.dtype)


class KVCache(NamedTuple):
    """Ring-buffer KV cache. ``k``/``v``: (B, Smax, Hkv, D) in bf16; int8 codes
    when quantized (scale set); **uint8 = packed int4** — two offset-binary
    4-bit codes per byte, (B, Smax, Hkv, D/2). ``length``: filled entries (B,)
    int32 — also the write cursor modulo Smax for SWA rings."""

    k: jax.Array
    v: jax.Array
    length: jax.Array
    k_scale: jax.Array | None = None   # (B, Smax, Hkv, 1) fp32 when quantized
    v_scale: jax.Array | None = None

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None

    @property
    def packed(self) -> bool:
        return self.quantized and self.k.dtype == jnp.uint8

    def materialize(self):
        if not self.quantized:
            return self.k, self.v
        if self.packed:
            kc = _unpack_int4(self.k)
            vc = _unpack_int4(self.v)
        else:
            kc, vc = self.k.astype(jnp.float32), self.v.astype(jnp.float32)
        k = (kc * self.k_scale).astype(jnp.bfloat16)
        v = (vc * self.v_scale).astype(jnp.bfloat16)
        return k, v


def _pack_int4(codes: jax.Array) -> jax.Array:
    """Delegates to the canonical :func:`repro.quant.pack_int4`."""
    from repro.quant import pack_int4

    return pack_int4(codes)


def _unpack_int4(packed: jax.Array) -> jax.Array:
    from repro.quant import unpack_int4

    return unpack_int4(packed)


def init_kv_cache(batch: int, smax: int, n_kv: int, head_dim: int,
                  *, kv_bits: int = 0, dtype=jnp.bfloat16) -> KVCache:
    if kv_bits == 4:
        return KVCache(
            k=jnp.zeros((batch, smax, n_kv, head_dim // 2), jnp.uint8),
            v=jnp.zeros((batch, smax, n_kv, head_dim // 2), jnp.uint8),
            length=jnp.zeros((batch,), jnp.int32),
            k_scale=jnp.ones((batch, smax, n_kv, 1), jnp.float32),
            v_scale=jnp.ones((batch, smax, n_kv, 1), jnp.float32),
        )
    if kv_bits:
        return KVCache(
            k=jnp.zeros((batch, smax, n_kv, head_dim), jnp.int8),
            v=jnp.zeros((batch, smax, n_kv, head_dim), jnp.int8),
            length=jnp.zeros((batch,), jnp.int32),
            k_scale=jnp.ones((batch, smax, n_kv, 1), jnp.float32),
            v_scale=jnp.ones((batch, smax, n_kv, 1), jnp.float32),
        )
    return KVCache(
        k=jnp.zeros((batch, smax, n_kv, head_dim), dtype),
        v=jnp.zeros((batch, smax, n_kv, head_dim), dtype),
        length=jnp.zeros((batch,), jnp.int32),
    )


def _quant_rows(x: jax.Array, bits: int):
    """Per-(token, head) symmetric int8 quantization of new KV rows.

    x: (B, 1, Hkv, D) → codes int8 + scale (B, 1, Hkv, 1). Deterministic
    nearest rounding: KV entries are read many times — stochastic rounding
    would add variance per read without an unbiasedness payoff (the attention
    nonlinearity already breaks strict unbiasedness; see DESIGN.md §5).
    Delegates to the canonical quantizer (row-scaled symmetric int grid).
    """
    from repro import quant
    from repro.quant import QScheme

    qt = quant.encode(x, QScheme.int_symmetric(bits, scaling="row",
                                               rounding="nearest"))
    return qt.codes, qt.scale


def update_kv_cache(cache: KVCache, k_new, v_new, *, window: int = 0,
                    kv_bits: int = 0) -> KVCache:
    """Append one token's K/V at the cursor (ring-buffer when ``window``>0)."""
    smax = cache.k.shape[1]
    cursor = cache.length % smax if window else jnp.minimum(cache.length, smax - 1)
    def write(buf, new):
        # per-batch dynamic index write at (i, cursor_i)
        return jax.vmap(
            lambda row, n, c: jax.lax.dynamic_update_slice_in_dim(row, n, c, axis=0)
        )(buf, new, cursor)

    if cache.quantized:
        kc, ks = _quant_rows(k_new, kv_bits or 8)
        vc, vs = _quant_rows(v_new, kv_bits or 8)
        if cache.packed:
            kc, vc = _pack_int4(kc), _pack_int4(vc)
        return cache._replace(
            k=write(cache.k, kc), v=write(cache.v, vc),
            k_scale=write(cache.k_scale, ks), v_scale=write(cache.v_scale, vs),
            length=cache.length + 1)
    return cache._replace(k=write(cache.k, k_new), v=write(cache.v, v_new),
                          length=cache.length + 1)


def attention_block(p: Params, x: jax.Array, spec: AttnSpec, *,
                    positions: jax.Array | None = None,
                    kv_tokens: jax.Array | None = None,
                    return_kv: bool = False):
    """Full training/prefill self-attention (or cross-attention when
    ``kv_tokens`` is given — no causal mask, no RoPE on keys).

    ``return_kv=True`` additionally returns the (post-RoPE) K/V — exactly what
    the decode cache stores, so prefill can fill caches for free.
    """
    b, s, _ = x.shape
    q = dense(p["q"], x).reshape(b, s, spec.n_heads, spec.head_dim)
    kv_src = x if kv_tokens is None else kv_tokens
    sk = kv_src.shape[1]
    k = dense(p["k"], kv_src).reshape(b, sk, spec.n_kv_heads, spec.head_dim)
    v = dense(p["v"], kv_src).reshape(b, sk, spec.n_kv_heads, spec.head_dim)
    if kv_tokens is None:
        pos = positions if positions is not None else jnp.arange(s)
        q = apply_rope(q, pos, spec.rope_theta)
        k = apply_rope(k, pos, spec.rope_theta)
        out = chunked_attention(q, k, v, spec)
    else:
        # cross-attention: every query sees every kv token (vision/audio stub);
        # q-chunked — a single block would materialize (B,H,S,Skv) scores
        out = chunked_attention(q, k, v, spec, causal=False)
    y = dense(p["o"], out.reshape(b, s, spec.n_heads * spec.head_dim))
    if return_kv:
        return y, (k, v)
    return y


def prefill_cache_from_kv(k: jax.Array, v: jax.Array, *, window: int = 0,
                          kv_bits: int = 0, pad_to: int = 0) -> KVCache:
    """Package full-sequence K/V into the decode cache layout.

    With a sliding window, keep the last ``window`` rows; when window divides
    the absolute positions (true for the assigned shapes) the ring layout is
    the identity ordering. ``pad_to`` reserves extra cache rows so decode can
    append past the prompt.
    """
    b, s, hkv, d = k.shape
    length = jnp.full((b,), s, jnp.int32)
    if window and window < s:
        k, v = k[:, -window:], v[:, -window:]
    if pad_to and pad_to > k.shape[1] and not (window and window < s):
        padn = pad_to - k.shape[1]
        k = jnp.pad(k, ((0, 0), (0, padn), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, padn), (0, 0), (0, 0)))
    if kv_bits:
        kc, ks = _quant_rows(k, kv_bits)
        vc, vs = _quant_rows(v, kv_bits)
        if kv_bits == 4:
            kc, vc = _pack_int4(kc), _pack_int4(vc)
        return KVCache(kc, vc, length, ks, vs)
    return KVCache(k, v, length)


def decode_qkv(p: Params, x: jax.Array, spec: AttnSpec, pos: jax.Array):
    """Single-token q/k/v projections + RoPE at absolute positions ``pos``
    (B, 1) — shared by the ring-buffer decode step and the paged serving
    engine, so both quantize/attend over identical rows."""
    b = x.shape[0]
    q = dense(p["q"], x).reshape(b, 1, spec.n_heads, spec.head_dim)
    k = dense(p["k"], x).reshape(b, 1, spec.n_kv_heads, spec.head_dim)
    v = dense(p["v"], x).reshape(b, 1, spec.n_kv_heads, spec.head_dim)
    q = apply_rope(q, pos, spec.rope_theta)
    k = apply_rope(k, pos, spec.rope_theta)
    return q, k, v


def attention_decode_step(p: Params, x: jax.Array, cache: KVCache, spec: AttnSpec,
                          *, kv_bits: int = 0) -> tuple[jax.Array, KVCache]:
    """x: (B, 1, d). Appends to cache and attends. Returns (out, new_cache)."""
    b = x.shape[0]
    q, k, v = decode_qkv(p, x, spec, cache.length[:, None])
    cache = update_kv_cache(cache, k, v, window=spec.window, kv_bits=kv_bits)
    kc, vc = cache.materialize()
    smax = kc.shape[1]
    kv_len = jnp.minimum(cache.length, smax)
    out = decode_attention(q, kc, vc, spec, kv_len=kv_len)
    return dense(p["o"], out.reshape(b, 1, spec.n_heads * spec.head_dim)), cache
