"""Mixture-of-Experts block: top-k routing with capacity-bounded local dispatch.

Two execution paths, selected statically by token count:

* ``dispatch`` (training / prefill): tokens are dispatched to per-expert
  capacity buffers via scatter-add, experts run as one batched einsum, results
  gathered back. Dispatch is *local per data shard* — the block is wrapped in a
  partial-manual `jax.shard_map` over the DP axes (the `model` axis stays in
  GSPMD-auto mode, so expert-internal d_ff tensor parallelism and the FSDP
  all-gather of expert tables over `data` are still inserted automatically).
  This mirrors production MoE: local routing + capacity drop, no global cumsum.

* ``dense`` (decode / tiny token counts): compute every expert on every token
  and combine with the (renormalized) top-k gate weights. For a decode batch
  of 128 tokens with top-2-of-8, every expert is touched w.h.p. anyway, so the
  dense path reads the same weight bytes the dispatch path would — it is the
  memory-roofline-faithful decode implementation, and it sidesteps the
  batch-divisibility constraint for global_batch=1 long-context decode.

Expert MLPs are gated (SwiGLU/GeGLU) like the host architectures' dense MLPs.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.quant import QTensor, ShipWeight, quant_dense

from .layers import _SPLICE_ERROR, Params, init_dense, shard_hint


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _gexpert_einsum(eq: str, x, w):
    return jnp.einsum(eq, x, w, preferred_element_type=jnp.float32)


def _ge_fwd(eq, x, w):
    return _gexpert_einsum(eq, x, w), (x, w)


def _ge_bwd(eq, res, g):
    """bf16 weight-gradient emission (ZipML C3 gradient channel).

    The f32 partial dW of each expert matrix is the dominant cross-device
    all-reduce payload in MoE training; emitting it in bf16 halves the wire
    bytes. dx keeps f32 accumulation → x.dtype. The optimizer's f32
    accumulator absorbs the rounding (and grad-clip runs after the reduce).
    """
    x, w = res
    in1, in2_arrow = eq.split(",")
    in2, out = in2_arrow.split("->")
    g = g.astype(x.dtype)
    dx = jnp.einsum(f"{out},{in2}->{in1}", g, w,
                    preferred_element_type=jnp.float32).astype(x.dtype)
    # bf16 straight out of the einsum: the cross-device psum of the sharded
    # contraction happens on the einsum OUTPUT — a later astype would ride
    # after the all-reduce and save nothing
    dw = jnp.einsum(f"{in1},{out}->{in2}", x, g,
                    preferred_element_type=jnp.bfloat16)
    return dx, dw.astype(w.dtype) if w.dtype != jnp.bfloat16 else dw


_gexpert_einsum.defvjp(_ge_fwd, _ge_bwd)


def _qeinsum(eq: str, x: jax.Array, sub: Params) -> jax.Array:
    """Expert/router matmul dispatching on the weight storage: QTensor /
    ShipWeight route through the ``quant_dense`` registry op (ref backend =
    exact decode-then-einsum numerics; Pallas streams int8 / packed-int4
    codes with the code-domain backward), dense weights keep the plain
    einsum. All MoE contractions are of quant_dense's canonical form
    (x (*lead, *stack, M, K) · w (*stack, K, N)), so ``eq`` only drives the
    dense path."""
    if "w_q" in sub or "w_lvl_codes" in sub:
        raise ValueError(_SPLICE_ERROR)
    w = sub["w"]
    if isinstance(w, (QTensor, ShipWeight)):
        return quant_dense(x, w)
    return jnp.einsum(eq, x, w, preferred_element_type=jnp.float32)


def _gq_einsum(eq: str, x: jax.Array, sub: Params) -> jax.Array:
    """The grouped-dispatch variant of :func:`_qeinsum`: the dense-weight
    path keeps ``_gexpert_einsum``'s custom VJP (bf16 dW emission on the
    cross-device all-reduce); quantized storages go through quant_dense."""
    if "w_q" in sub or "w_lvl_codes" in sub:
        raise ValueError(_SPLICE_ERROR)
    w = sub["w"]
    if isinstance(w, (QTensor, ShipWeight)):
        return quant_dense(x, w)
    return _gexpert_einsum(eq, x, w)


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int
    act: str = "silu"
    capacity_factor: float = 1.25
    dense_path_max_tokens: int = 512   # ≤ this many tokens per step → dense path
    dp_axes: tuple = ("data",)         # manual axes for the dispatch shard_map
    router_jitter: float = 0.0


def init_moe(key, spec: MoESpec, dtype=jnp.bfloat16) -> Params:
    kr, kg, ku, kd = jax.random.split(key, 4)
    e, d, f = spec.n_experts, spec.d_model, spec.d_ff
    def expert_mat(k, din, dout, scale):
        return (jax.random.normal(k, (e, din, dout), jnp.float32) * scale).astype(dtype)
    return {
        "router": init_dense(kr, d, e, dtype=jnp.float32, scale=d**-0.5),
        "gate": {"w": expert_mat(kg, d, f, d**-0.5)},
        "up": {"w": expert_mat(ku, d, f, d**-0.5)},
        "down": {"w": expert_mat(kd, f, d, f**-0.5)},
    }


def _router_probs(p: Params, x: jax.Array, spec: MoESpec):
    # bf16 operands + f32 accumulation: an x.astype(f32) here would materialize
    # a full-token fp32 copy (and its cotangent) per MoE layer
    if isinstance(p["router"].get("w"), (QTensor, ShipWeight)) \
            or "w_q" in p["router"] or "w_lvl_codes" in p["router"]:
        logits = _qeinsum("...d,de->...e", x, p["router"])
    else:
        logits = jnp.einsum("...d,de->...e", x,
                            p["router"]["w"].astype(x.dtype),
                            preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, spec.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    return top_p, top_i, probs


def _expert_ffn(p: Params, h: jax.Array, spec: MoESpec) -> jax.Array:
    """h: (E, C, d) → (E, C, d). Batched gated MLP over the expert dim."""
    g = _qeinsum("ecd,edf->ecf", h, p["gate"]).astype(h.dtype)
    u = _qeinsum("ecd,edf->ecf", h, p["up"]).astype(h.dtype)
    a = jax.nn.silu(g) if spec.act == "silu" else jax.nn.gelu(g, approximate=True)
    return _qeinsum("ecf,efd->ecd", a * u, p["down"]).astype(h.dtype)


def moe_dense(p: Params, x: jax.Array, spec: MoESpec) -> jax.Array:
    """All-experts path: y = Σ_e gate_e(x)·FFN_e(x); exact for kept tokens."""
    b, s, d = x.shape
    top_p, top_i, _ = _router_probs(p, x, spec)                     # (B,S,k)
    onehot = jax.nn.one_hot(top_i, spec.n_experts, dtype=jnp.float32)  # (B,S,k,E)
    weights = (onehot * top_p[..., None]).sum(-2)                    # (B,S,E)
    # every expert on every token: (E, B*S, d)
    flat = x.reshape(1, b * s, d)
    h = jnp.broadcast_to(flat, (spec.n_experts, b * s, d))
    y = _expert_ffn(p, h, spec)                                      # (E, N, d)
    y = jnp.einsum("end,ne->nd", y.astype(jnp.float32),
                   weights.reshape(b * s, spec.n_experts))
    return y.reshape(b, s, d).astype(x.dtype)


def moe_dispatch_local(p: Params, x: jax.Array, spec: MoESpec) -> jax.Array:
    """Single-group dispatch (smoke tests / unsharded runs)."""
    b, s, d = x.shape
    return moe_dispatch_grouped(p, x.reshape(1, b * s, d), spec).reshape(b, s, d)


def moe_dispatch_grouped(p: Params, xg: jax.Array, spec: MoESpec) -> jax.Array:
    """Capacity-bounded dispatch with an explicit group dim.

    xg: (G, N, d) — G routing groups (one per data shard in production; the
    group dim is sharded over the DP axes so routing, capacity and the expert
    buffers are all shard-local). Earlier tokens win capacity ties;
    over-capacity choices are dropped (standard Switch/GShard semantics).
    """
    g, n, d = xg.shape
    e, k = spec.n_experts, spec.top_k
    cap = max(int(n * k / e * spec.capacity_factor), 1)
    dp_spec = spec.dp_axes if len(spec.dp_axes) > 1 else spec.dp_axes[0]

    def hint(t, *rest):
        return shard_hint(t, P(dp_spec, *rest))

    top_p, top_i, _ = _router_probs(p, xg, spec)                # (G, N, k)
    flat_e = top_i.reshape(g, n * k)                            # choice → expert
    flat_p = top_p.reshape(g, n * k).astype(jnp.float32)
    token_of = jnp.broadcast_to(
        jnp.repeat(jnp.arange(n), k)[None], (g, n * k))
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)         # (G, N·k, E)
    onehot = hint(onehot, None, None)
    pos = jnp.cumsum(onehot, axis=1) - onehot                   # per-group prefix
    my_pos = jnp.take_along_axis(pos, flat_e[..., None], axis=2)[..., 0]
    keep = my_pos < cap
    slot = jnp.where(keep, flat_e * cap + my_pos, e * cap)      # (G, N·k)
    # scatter into per-group expert buffers (overflow row e*cap absorbs drops)
    rows = e * cap + 1
    src = jnp.take_along_axis(xg, token_of[..., None], axis=1)  # (G, N·k, d)
    src = hint(src, None, None)
    buf = jnp.zeros((g, rows, d), xg.dtype)
    buf = hint(buf, None, None)
    gi = jnp.broadcast_to(jnp.arange(g)[:, None], (g, n * k))
    # scatter-add with by-construction-unique (expert, position) slots; the
    # overflow row absorbs capacity drops. (XLA CPU promotes bf16 scatter-add
    # buffers to f32 — a CPU-backend artifact absent on TPU; scatter-set makes
    # GSPMD fall back to full replication, which is far worse.)
    buf = buf.at[gi, slot].add(src)
    # FFN runs with the capacity dim TP-sharded over 'model' — the f32 bwd
    # cotangents of these (G, E, cap, ·) tensors are the MoE's peak residents
    expert_in = hint(buf[:, : e * cap].reshape(g, e, cap, d), None, "model", None)
    # batched gated MLP over (G, E): d_ff stays TP-sharded over 'model'
    up = _gq_einsum("gecd,edf->gecf", expert_in, p["up"]).astype(xg.dtype)
    gate = _gq_einsum("gecd,edf->gecf", expert_in, p["gate"]).astype(xg.dtype)
    act = jax.nn.silu(gate) if spec.act == "silu" else jax.nn.gelu(gate, approximate=True)
    out = _gq_einsum("gecf,efd->gecd", act * up, p["down"]).astype(xg.dtype)
    out = hint(out, None, "model", None)
    out_flat = jnp.concatenate(
        [out.reshape(g, e * cap, d), jnp.zeros((g, 1, d), xg.dtype)], axis=1)
    gathered = jnp.take_along_axis(out_flat, slot[..., None], axis=1)
    gathered = gathered * (flat_p * keep)[..., None].astype(xg.dtype)
    gathered = hint(gathered, None, None)
    # choices are (token-major, k-minor) ordered → combine is a plain k-sum,
    # no scatter-add needed
    y = gathered.reshape(g, n, k, d).sum(axis=2).astype(xg.dtype)
    return hint(y, None, None)


def _mesh_axis_sizes(axes: tuple) -> int | None:
    try:
        am = jax.sharding.get_abstract_mesh()   # jax>=0.4.35 only
    except AttributeError:
        return None
    if am is None or not am.shape:
        return None
    sizes = dict(am.shape)
    if not set(axes) <= set(sizes):
        return None
    total = 1
    for a in axes:
        total *= sizes[a]
    return total


def moe_block(p: Params, x: jax.Array, spec: MoESpec) -> jax.Array:
    """Entry point: dense path for tiny token counts, else *group-local*
    dispatch.

    Group-local = production MoE semantics: each data shard routes its own
    tokens with its own capacity, no global cumsum across shards. Expressed as
    a vmap over an explicit group dim sharded on the DP axes (a partial-manual
    shard_map would be equivalent, but its transpose currently trips an XLA
    CPU AllReducePromotion bug — vmap grouping lowers to clean per-shard HLO).
    """
    b, s, _ = x.shape
    d = x.shape[-1]
    tokens = b * s
    if tokens <= spec.dense_path_max_tokens:
        return moe_dense(p, x, spec)
    dp = _mesh_axis_sizes(spec.dp_axes)
    if dp is None or dp == 1 or b % dp != 0:
        return moe_dispatch_local(p, x, spec)
    dp_spec = spec.dp_axes if len(spec.dp_axes) > 1 else spec.dp_axes[0]
    xg = x.reshape(dp, (b // dp) * s, d)
    xg = shard_hint(xg, P(dp_spec, None, None))
    yg = moe_dispatch_grouped(p, xg, spec)
    return yg.reshape(b, s, d)


def load_balance_loss(p: Params, x: jax.Array, spec: MoESpec) -> jax.Array:
    """Auxiliary load-balancing loss (Switch-style): E·Σ_e f_e·P_e."""
    _, top_i, probs = _router_probs(p, x, spec)
    e = spec.n_experts
    frac = jax.nn.one_hot(top_i[..., 0], e, dtype=jnp.float32).reshape(-1, e).mean(0)
    imp = probs.reshape(-1, e).mean(0)
    return e * jnp.sum(frac * imp)
