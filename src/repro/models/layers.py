"""Shared transformer building blocks (pure-function style, dict pytree params).

Conventions:
* params are nested dicts of jnp arrays; init_* functions build them, apply
  functions are pure. No framework dependency — pjit/shard_map see plain pytrees.
* compute dtype is bf16 (MXU-native), accumulations & normalizations in fp32,
  params stored in ``cfg.param_dtype`` (bf16 by default; the ZipML weight path
  stores int8 codes + scales instead — see repro/precision/qat.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant import QTensor, ShipWeight, quant_dense

Params = dict

_SPLICE_ERROR = (
    "the spliced weight formats (w_q+w_scale / w_lvl_codes+w_levels) were "
    "removed after their one-release compatibility window; run the param "
    "tree through repro.precision.qat.migrate_spliced_weights(params) once "
    "(decode-identical QTensor at the 'w' key), or re-quantize the bf16 "
    "masters with precision.qat.quantize_param_tree")


def _as_dtype(x, dtype):
    return x.astype(dtype) if x.dtype != dtype else x


# ---------------------------------------------------------------------------
# Dense / embedding
# ---------------------------------------------------------------------------

def init_dense(key, d_in: int, d_out: int, *, bias: bool = False,
               dtype=jnp.bfloat16, scale: float | None = None) -> Params:
    scale = scale if scale is not None else d_in ** -0.5
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
    p = {"w": w.astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: jax.Array) -> jax.Array:
    """Matmul supporting three weight storages:

    * ``w``: bf16/fp32 dense weight — the plain einsum path, untouched.
    * ``w``: a :class:`repro.quant.QTensor` (ZipML C1/C5 storage: int8 or
      packed-int4 codes + fp32 scales, or C4 level-table codes) — routed
      through the ``quant_dense`` registry op: the ref backend is the exact
      decode-then-einsum numerics, the Pallas backend streams the code bytes
      HBM→VMEM (``QTensor.nbytes`` of traffic) and keeps the backward in the
      code domain.
    * ``w``: a :class:`repro.quant.ShipWeight` (quantize-on-gather training
      form) — same streaming matmul, straight-through gradient to the master.
    """
    if "w_q" in p or "w_lvl_codes" in p:
        raise ValueError(_SPLICE_ERROR)
    w = p["w"]
    if isinstance(w, (QTensor, ShipWeight)):
        y = quant_dense(x, w).astype(x.dtype)
    else:
        y = jnp.einsum("...i,io->...o", x, w,
                       preferred_element_type=jnp.float32).astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def init_embedding(key, vocab: int, d_model: int, dtype=jnp.bfloat16) -> Params:
    # d^-0.5 keeps tied-readout logits O(1) at init (loss ≈ log V)
    return {"table": (jax.random.normal(key, (vocab, d_model), jnp.float32)
                      .astype(dtype)) * d_model**-0.5}


def embed(p: Params, ids: jax.Array) -> jax.Array:
    table = p["table"]
    if isinstance(table, QTensor):
        # gather the CODE rows first, decode only the gathered handful —
        # decoding the whole (V, d) table per step would materialize a full
        # bf16 vocab table just to read a few rows. Falls back to a full
        # decode only when the scale/level tables themselves carry the
        # vocab dim (no such scheme is produced by quantize_param_tree).
        vdim = table.shape[0]
        scale_rowed = jnp.ndim(table.scale) > 0 and \
            table.scale.shape[0] == vdim
        levels_rowed = table.levels is not None and table.levels.ndim > 1
        if scale_rowed or levels_rowed:
            return jnp.take(table.decode(jnp.bfloat16), ids, axis=0)
        rows = QTensor(jnp.take(table.codes, ids, axis=0), table.scale,
                       table.scheme, levels=table.levels)
        return rows.decode(jnp.bfloat16)
    return jnp.take(table, ids, axis=0)


def unembed(p: Params, x: jax.Array) -> jax.Array:
    """Tied readout: logits = x @ tableᵀ (vocab-parallel under TP). A
    QTensor table streams its codes through the transpose kernel of the
    ``quant_dense`` op family."""
    table = p["table"]
    if isinstance(table, (QTensor, ShipWeight)):
        return quant_dense(x, table, transpose=True)
    return jnp.einsum("...d,vd->...v", x, table,
                      preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.bfloat16) -> Params:
    return {"g": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["g"].astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10_000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10_000.0) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                      # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                     # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, *, dtype=jnp.bfloat16) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "up": init_dense(k1, d_model, d_ff, dtype=dtype),
        "gate": init_dense(k2, d_model, d_ff, dtype=dtype),
        "down": init_dense(k3, d_ff, d_model, dtype=dtype, scale=d_ff**-0.5),
    }


def mlp(p: Params, x: jax.Array, act: str = "silu") -> jax.Array:
    h_gate = dense(p["gate"], x)
    h_up = dense(p["up"], x)
    a = jax.nn.silu(h_gate) if act == "silu" else jax.nn.gelu(h_gate, approximate=True)
    return dense(p["down"], a * h_up)


# ---------------------------------------------------------------------------
# Sharding helper — a soft constraint that is a no-op outside a mesh context.
# ---------------------------------------------------------------------------

def shard_hint(x: jax.Array, spec) -> jax.Array:
    """with_sharding_constraint if a mesh is active, else identity.

    Model code calls this at activation boundaries; the launcher's mesh context
    makes it bind. ``spec`` is a PartitionSpec.
    """
    try:
        env_mesh = jax.sharding.get_abstract_mesh()  # jax>=0.4.35
        if env_mesh is None or not env_mesh.shape:
            return x
        # only apply when every named axis in the spec exists in the mesh
        names = set()
        for part in spec:
            if part is None:
                continue
            parts = part if isinstance(part, (tuple, list)) else (part,)
            names.update(parts)
        if not names <= set(env_mesh.shape.keys()):
            return x
        # drop axes whose size does not divide the dim? leave to caller; jax
        # raises a clear error which the dry-run surfaces as a config bug.
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x
