"""Model assembly: dense / MoE / SSM / hybrid / VLM / audio backbones.

One ``ModelConfig`` describes any of the 10 assigned architectures. Depth is
executed as `lax.scan` over stacked layer params (HLO size O(1) in layers) with
`jax.checkpoint` remat per layer. Heterogeneous stacks (hybrid shared-attention,
VLM cross-attention cadence) scan over *blocks* whose structure is homogeneous.

Entry points:
  init_params(key, cfg)                  — real weights (smoke tests / examples)
  param_specs(cfg)                       — ShapeDtypeStructs only (dry-run)
  forward(params, tokens, cfg, extras)   — logits-producing full forward
  loss_fn(params, batch, cfg)            — chunked softmax-xent (+ MoE aux)
  init_decode_state(cfg, batch, smax)    — KV/SSM caches
  decode_step(params, state, tokens,cfg) — one-token serve step
"""
from __future__ import annotations

import dataclasses
import typing
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.quant import PrecisionPlan as _PrecisionPlan

from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import (Params, dense, embed, init_dense, init_embedding,
                     init_mlp, init_rmsnorm, mlp, rmsnorm, shard_hint, unembed)


# The ZipML channel plan for LM-scale training/serving is the one canonical
# repro.quant.PrecisionPlan (model_bits/model_storage/kv_bits/grad_bits/
# act_bits/optimal_levels). `transformer.PrecisionPlan` is its deprecated
# alias, served via module __getattr__ so access warns.
def __getattr__(name):
    if name == "PrecisionPlan":
        import warnings

        warnings.warn(
            "models.transformer.PrecisionPlan is deprecated; use "
            "repro.quant.PrecisionPlan (same class, canonical field names)",
            DeprecationWarning, stacklevel=2)
        return _PrecisionPlan
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # moe
    n_experts: int = 0
    top_k: int = 0
    # attention details
    window: int = 0
    qkv_bias: bool = False
    mlp_act: str = "silu"
    rope_theta: float = 10_000.0
    attn_shard: str = "heads"   # 'heads' | 'seq' | 'none'
    q_chunk: int = 1024
    # ssm / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssd_chunk: int = 256
    shared_attn_every: int = 0  # hybrid: apply shared attn block every k layers
    # vlm / audio stubs
    cross_attn_every: int = 0
    n_vis_tokens: int = 0
    # numerics & loss
    dtype: Any = jnp.bfloat16
    logit_chunk: int = 512
    tie_embeddings: bool = True
    precision: _PrecisionPlan = _PrecisionPlan()
    remat: bool = True
    scan_layers: bool = True    # False: unroll (dry-run — exact cost analysis,
                                # per-layer collectives; XLA counts scan bodies once)
    dp_axes: tuple = ("data",)

    @property
    def vocab_padded(self) -> int:
        """Embedding-table vocab padded to 256 so the vocab-parallel sharding
        divides the 16-way model axis (padded logits are masked in _readout).
        Standard practice (MaxText et al.); cfg.vocab_size stays the exact
        published value and is what the loss/targets see."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def attn_spec(self) -> attn.AttnSpec:
        return attn.AttnSpec(self.n_heads, self.n_kv_heads, self.head_dim,
                             window=self.window, rope_theta=self.rope_theta,
                             q_chunk=self.q_chunk, shard=self.attn_shard,
                             unroll=not self.scan_layers, dp=tuple(self.dp_axes))

    @property
    def moe_spec(self) -> moe_mod.MoESpec:
        return moe_mod.MoESpec(self.n_experts, self.top_k, self.d_model,
                               self.d_ff, act=self.mlp_act, dp_axes=self.dp_axes)

    @property
    def ssm_spec(self) -> ssm_mod.SSMSpec:
        return ssm_mod.SSMSpec(self.d_model, d_state=self.ssm_state,
                               head_dim=self.ssm_head_dim, chunk=self.ssd_chunk,
                               unroll=not self.scan_layers)

    def n_params(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS roofline accounting)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        qkv = d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim \
            + self.n_heads * self.head_dim * d
        dense_mlp = 3 * d * f
        per_layer = 0
        if self.family in ("dense", "vlm", "audio"):
            per_layer = qkv + dense_mlp
        elif self.family == "moe":
            per_layer = qkv + 3 * d * f * self.n_experts + d * self.n_experts
        elif self.family in ("ssm", "hybrid"):
            spec = self.ssm_spec
            din = 2 * spec.d_inner + 2 * spec.n_groups * spec.d_state + spec.n_heads
            per_layer = d * din + spec.d_inner * d
        total = self.n_layers * per_layer + v * d
        if self.family == "hybrid" and self.shared_attn_every:
            total += qkv + dense_mlp  # one shared block
        if self.family == "vlm" and self.cross_attn_every:
            n_cross = self.n_layers // self.cross_attn_every
            total += n_cross * qkv
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.family != "moe":
            return self.n_params()
        d, f = self.d_model, self.d_ff
        qkv = d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim \
            + self.n_heads * self.head_dim * d
        per_layer = qkv + 3 * d * f * self.top_k + d * self.n_experts
        return self.n_layers * per_layer + self.vocab_size * d


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_layer(cfg: ModelConfig, key) -> Params:
    """One homogeneous decoder layer for the family."""
    ka, km, k1, k2 = jax.random.split(key, 4)
    if cfg.family in ("ssm",):
        return {"norm": init_rmsnorm(cfg.d_model, cfg.dtype),
                "mamba": ssm_mod.init_mamba2(km, cfg.ssm_spec, cfg.dtype)}
    if cfg.family == "hybrid":
        return {"norm": init_rmsnorm(cfg.d_model, cfg.dtype),
                "mamba": ssm_mod.init_mamba2(km, cfg.ssm_spec, cfg.dtype)}
    layer = {
        "ln1": init_rmsnorm(cfg.d_model, cfg.dtype),
        "attn": attn.init_attention(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                    cfg.head_dim, qkv_bias=cfg.qkv_bias,
                                    dtype=cfg.dtype),
        "ln2": init_rmsnorm(cfg.d_model, cfg.dtype),
    }
    if cfg.family == "moe":
        layer["moe"] = moe_mod.init_moe(km, cfg.moe_spec, cfg.dtype)
    else:
        layer["mlp"] = init_mlp(km, cfg.d_model, cfg.d_ff, dtype=cfg.dtype)
    return layer


def _init_attn_block(cfg: ModelConfig, key, cross: bool = False) -> Params:
    ka, km = jax.random.split(key)
    blk = {
        "ln1": init_rmsnorm(cfg.d_model, cfg.dtype),
        "attn": attn.init_attention(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                    cfg.head_dim, qkv_bias=cfg.qkv_bias,
                                    dtype=cfg.dtype),
    }
    if not cross:
        blk["ln2"] = init_rmsnorm(cfg.d_model, cfg.dtype)
        blk["mlp"] = init_mlp(km, cfg.d_model, cfg.d_ff, dtype=cfg.dtype)
    return blk


def init_params(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, 4)
    params: Params = {
        "embed": init_embedding(keys[0], cfg.vocab_padded, cfg.d_model, cfg.dtype),
        "final_norm": init_rmsnorm(cfg.d_model, cfg.dtype),
    }
    if cfg.family == "vlm":
        n_cross = cfg.n_layers // cfg.cross_attn_every
        per = cfg.cross_attn_every
        lkeys = jax.random.split(keys[1], n_cross * (per + 1)).reshape(n_cross, per + 1, 2)
        params["blocks"] = jax.vmap(
            lambda ks: {
                "self": jax.vmap(lambda k: _init_layer(cfg, k))(ks[:per]),
                "cross": _init_attn_block(cfg, ks[per], cross=True),
            })(lkeys)
    elif cfg.family == "hybrid":
        lkeys = jax.random.split(keys[1], cfg.n_layers)
        params["layers"] = jax.vmap(lambda k: _init_layer(cfg, k))(lkeys)
        params["shared_attn"] = _init_attn_block(cfg, keys[2])
    else:
        lkeys = jax.random.split(keys[1], cfg.n_layers)
        params["layers"] = jax.vmap(lambda k: _init_layer(cfg, k))(lkeys)
    if not cfg.tie_embeddings:
        params["unembed"] = init_dense(keys[3], cfg.d_model, cfg.vocab_padded,
                                       dtype=cfg.dtype)
    return params


def param_specs(cfg: ModelConfig) -> Params:
    """Shape/dtype skeleton without allocation — the dry-run's param stand-in."""
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _act_spec(cfg: ModelConfig):
    """Residual-stream sharding. Sequence-sharded over 'model' (Megatron-SP):
    the remat-saved per-layer carry (L, B, S, d) is the dominant training
    resident — leaving it replicated over the model axis costs 16× the HBM.
    SSM/hybrid archs shard d instead (their chunk scan iterates S)."""
    dp = tuple(cfg.dp_axes)
    dp = dp if len(dp) > 1 else dp[0]
    if cfg.family in ("ssm", "hybrid"):
        return P(dp, None, "model")
    return P(dp, "model", None)


def _layer_fwd(cfg: ModelConfig, layer: Params, x: jax.Array) -> jax.Array:
    if cfg.family in ("ssm", "hybrid"):
        return x + ssm_mod.mamba2_forward(layer["mamba"], rmsnorm(layer["norm"], x),
                                          cfg.ssm_spec)
    h = x + attn.attention_block(layer["attn"], rmsnorm(layer["ln1"], x),
                                 cfg.attn_spec)
    h = shard_hint(h, _act_spec(cfg))
    z = rmsnorm(layer["ln2"], h)
    if cfg.family == "moe":
        y = moe_mod.moe_block(layer["moe"], z, cfg.moe_spec)
    else:
        y = mlp(layer["mlp"], z, cfg.mlp_act)
    return shard_hint(h + y, _act_spec(cfg))


def _attn_block_fwd(cfg: ModelConfig, blk: Params, x: jax.Array,
                    kv_tokens=None) -> jax.Array:
    h = x + attn.attention_block(blk["attn"], rmsnorm(blk["ln1"], x),
                                 cfg.attn_spec, kv_tokens=kv_tokens)
    if "mlp" in blk:
        h = h + mlp(blk["mlp"], rmsnorm(blk["ln2"], h), cfg.mlp_act)
    return shard_hint(h, _act_spec(cfg))


def _unstack(tree, n: int):
    return [jax.tree.map(lambda a: a[i], tree) for i in range(n)]


def _stack_trees(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


@jax.custom_vjp
def _fwd_barrier(x):
    """optimization_barrier on the forward pass only; identity for gradients
    (jax<0.5 has no differentiation rule for the barrier primitive)."""
    return jax.lax.optimization_barrier(x)


def _fwd_barrier_fwd(x):
    return jax.lax.optimization_barrier(x), None


def _fwd_barrier_bwd(_, g):
    return (g,)


_fwd_barrier.defvjp(_fwd_barrier_fwd, _fwd_barrier_bwd)


def _scan_layers(cfg: ModelConfig, stacked: Params, x: jax.Array) -> jax.Array:
    body = lambda carry, layer: (_layer_fwd(cfg, layer, carry), None)
    if cfg.remat:
        inner = jax.checkpoint(body)

        def body(carry, layer):  # noqa: F811
            out, _ = inner(carry, layer)
            # barrier outside the checkpoint: stops XLA hoisting the bwd's
            # bf16→f32 convert into the fwd save (doubles stacked-carry memory)
            return _fwd_barrier(out), None
    if not cfg.scan_layers:
        n = jax.tree.leaves(stacked)[0].shape[0]
        for layer in _unstack(stacked, n):
            x, _ = body(x, layer)
        return x
    x, _ = jax.lax.scan(body, x, stacked)
    return x


def _maybe_scan(cfg: ModelConfig, body, carry, xs):
    """lax.scan, or an unrolled python loop when cfg.scan_layers=False."""
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for item in _unstack(xs, n):
        carry, y = body(carry, item)
        ys.append(y)
    if ys and jax.tree.leaves(ys[0]):
        return carry, _stack_trees(ys)
    return carry, None


def forward(params: Params, tokens: jax.Array, cfg: ModelConfig,
            vision_tokens: jax.Array | None = None) -> jax.Array:
    """tokens: (B, S) int32 → final hidden states (B, S, d). Call
    ``logits_chunked``/``loss_fn`` for the readout (full logits may be huge)."""
    x = embed(params["embed"], tokens).astype(cfg.dtype)
    x = shard_hint(x, _act_spec(cfg))
    if cfg.family == "vlm":
        vis = vision_tokens.astype(cfg.dtype)

        def block_fwd(carry, blk):
            h = _scan_layers(cfg, blk["self"], carry)
            h = _attn_block_fwd(cfg, blk["cross"], h, kv_tokens=vis)
            return h, None
        if cfg.remat:
            block_fwd = jax.checkpoint(block_fwd)
        if not cfg.scan_layers:
            n = jax.tree.leaves(params["blocks"])[0].shape[0]
            for blk in _unstack(params["blocks"], n):
                x, _ = block_fwd(x, blk)
        else:
            x, _ = jax.lax.scan(block_fwd, x, params["blocks"])
    elif cfg.family == "hybrid":
        k = cfg.shared_attn_every
        n_seg = cfg.n_layers // k
        seg_params = jax.tree.map(
            lambda a: a.reshape(n_seg, k, *a.shape[1:]), params["layers"])

        def seg_fwd(carry, seg):
            h = _scan_layers(cfg, seg, carry)
            h = _attn_block_fwd(cfg, params["shared_attn"], h)
            return h, None
        if not cfg.scan_layers:
            for seg in _unstack(seg_params, n_seg):
                x, _ = seg_fwd(x, seg)
        else:
            x, _ = jax.lax.scan(seg_fwd, x, seg_params)
    else:
        x = _scan_layers(cfg, params["layers"], x)
    return rmsnorm(params["final_norm"], x)


def _readout(params: Params, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], h)
    else:
        logits = dense(params["unembed"], h).astype(jnp.float32)
    if cfg.vocab_padded != cfg.vocab_size:
        # mask the padding tail so softmax/argmax never select a pad id
        pad_mask = jnp.arange(cfg.vocab_padded) >= cfg.vocab_size
        logits = jnp.where(pad_mask, jnp.float32(-1e30), logits)
    return logits


def final_logits(params: Params, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    """Final-norm + unembed readout over a (B, S, d) hidden stream →
    (B, S, V) f32 logits at **every** position — the multi-position variant
    every decode-shaped caller shares: single-token decode (S=1), chunked
    prefill (one chunk), and the speculative-decode verify step, which scores
    all k+1 window positions from one forward. One definition keeps the
    per-position math bit-identical across those paths."""
    return _readout(params, cfg, rmsnorm(params["final_norm"], h))


def loss_fn(params: Params, tokens: jax.Array, targets: jax.Array,
            cfg: ModelConfig, vision_tokens=None) -> jax.Array:
    """Mean next-token cross-entropy, computed in sequence chunks so the
    (B, S, V) logits tensor never fully materializes (vocab up to 256k)."""
    h = forward(params, tokens, cfg, vision_tokens)
    b, s, d = h.shape
    cs = min(cfg.logit_chunk, s)
    if s % cs:
        cs = s
    n_chunks = s // cs

    dp = tuple(cfg.dp_axes)
    logits_spec = P(dp if len(dp) > 1 else dp[0], None, "model")

    def chunk_loss(carry, i):
        hc = jax.lax.dynamic_slice_in_dim(h, i * cs, cs, axis=1)
        tc = jax.lax.dynamic_slice_in_dim(targets, i * cs, cs, axis=1)
        logits = _readout(params, cfg, hc)                      # (B, cs, V) f32
        logits = shard_hint(logits, logits_spec)
        logz = jax.nn.logsumexp(logits, axis=-1)
        # gold logit via masked reduction — take_along_axis over the
        # vocab-sharded dim would force an all-gather of the logits
        vpos = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        gold = jnp.sum(jnp.where(vpos == tc[..., None], logits, 0.0), axis=-1)
        return carry + jnp.sum(logz - gold), None

    if cfg.scan_layers:
        total, _ = jax.lax.scan(chunk_loss, jnp.float32(0.0), jnp.arange(n_chunks))
    else:
        total = jnp.float32(0.0)
        for i in range(n_chunks):
            total, _ = chunk_loss(total, i)
    return total / (b * s)


# ---------------------------------------------------------------------------
# Prefill: forward + cache collection (the inference-prefill shape)
# ---------------------------------------------------------------------------

def prefill(params: Params, tokens: jax.Array, cfg: ModelConfig,
            vision_tokens: jax.Array | None = None, pad_to: int = 0,
            last_pos: jax.Array | None = None):
    """Process a full prompt; return (last-token logits (B, V), DecodeState).

    Caches are sized to the prompt length (the decode_* shapes measure one
    step against a cache of exactly seq_len).

    ``last_pos``: optional (traced) index of the position to read logits
    from, instead of the final one — lets callers right-pad prompts to a
    bucketed length (one jit compile per bucket, not per exact length)
    while still reading the true last-token logits; causality keeps
    positions ≤ last_pos unaffected by the padding garbage behind them.
    """
    kvb = cfg.precision.kv_bits
    x = embed(params["embed"], tokens).astype(cfg.dtype)
    x = shard_hint(x, _act_spec(cfg))

    def attn_layer_collect(layer, h):
        a_out, (k, v) = attn.attention_block(
            layer["attn"], rmsnorm(layer["ln1"], h), cfg.attn_spec, return_kv=True)
        h = h + a_out
        z = rmsnorm(layer["ln2"], h)
        if cfg.family == "moe":
            y = moe_mod.moe_block(layer["moe"], z, cfg.moe_spec)
        else:
            y = mlp(layer["mlp"], z, cfg.mlp_act)
        cache = attn.prefill_cache_from_kv(k, v, window=cfg.window, kv_bits=kvb,
                                           pad_to=pad_to)
        return shard_hint(h + y, _act_spec(cfg)), cache

    if cfg.family in ("ssm", "hybrid"):
        def body(h, layer):
            out, mc = ssm_mod.mamba2_forward(
                layer["mamba"], rmsnorm(layer["norm"], h), cfg.ssm_spec,
                return_state=True)
            return h + out, mc
        if cfg.family == "ssm":
            x, caches = _maybe_scan(cfg, body, x, params["layers"])
            state = DecodeState(caches, step=tokens.shape[1])
        else:
            k = cfg.shared_attn_every
            n_seg = cfg.n_layers // k
            seg_params = jax.tree.map(
                lambda a: a.reshape(n_seg, k, *a.shape[1:]), params["layers"])

            def seg_fwd(h, seg):
                h, mcs = _maybe_scan(cfg, body, h, seg)
                blk = params["shared_attn"]
                a_out, (kk, vv) = attn.attention_block(
                    blk["attn"], rmsnorm(blk["ln1"], h), cfg.attn_spec,
                    return_kv=True)
                h = h + a_out
                h = h + mlp(blk["mlp"], rmsnorm(blk["ln2"], h), cfg.mlp_act)
                kvc = attn.prefill_cache_from_kv(kk, vv, kv_bits=kvb, pad_to=pad_to)
                return h, (mcs, kvc)
            x, (seg_caches, shared_caches) = _maybe_scan(cfg, seg_fwd, x, seg_params)
            layer_caches = jax.tree.map(
                lambda a: a.reshape(cfg.n_layers, *a.shape[2:]), seg_caches)
            state = DecodeState(layer_caches, shared=shared_caches,
                                step=tokens.shape[1])
    elif cfg.family == "vlm":
        vis = vision_tokens.astype(cfg.dtype)

        def blk_fwd(h, blk):
            h, caches = _maybe_scan(
                cfg, lambda hh, layer: attn_layer_collect(layer, hh), h, blk["self"])
            cross = blk["cross"]
            zc = rmsnorm(cross["ln1"], h)
            h = h + attn.attention_block(cross["attn"], zc, cfg.attn_spec,
                                         kv_tokens=vis)
            b = h.shape[0]
            nv = vis.shape[1]
            ck = dense(cross["attn"]["k"], vis).reshape(
                b, nv, cfg.n_kv_heads, cfg.head_dim)
            cv = dense(cross["attn"]["v"], vis).reshape(
                b, nv, cfg.n_kv_heads, cfg.head_dim)
            return h, (caches, {"k": ck, "v": cv})
        x, (blk_caches, cross_kv) = _maybe_scan(cfg, blk_fwd, x, params["blocks"])
        layer_caches = jax.tree.map(
            lambda a: a.reshape(cfg.n_layers, *a.shape[2:]), blk_caches)
        state = DecodeState(layer_caches, cross=cross_kv, step=tokens.shape[1])
    else:
        x, caches = _maybe_scan(
            cfg, lambda h, layer: attn_layer_collect(layer, h), x, params["layers"])
        state = DecodeState(caches, step=tokens.shape[1])

    if last_pos is None:
        h_sel = x[:, -1:, :]
    else:
        idx = jnp.reshape(jnp.asarray(last_pos, jnp.int32), (1,))
        h_sel = jnp.take(x, idx, axis=1)
    h_last = rmsnorm(params["final_norm"], h_sel)
    logits = _readout(params, cfg, h_last)[:, 0]
    return logits, state


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

class DecodeState(typing.NamedTuple):
    """Per-layer caches + step counter (NamedTuple → automatic pytree)."""

    layers: Any
    shared: Any = None
    cross: Any = None
    step: Any = None


def init_decode_state(cfg: ModelConfig, batch: int, smax: int,
                      params: Params | None = None,
                      vision_tokens: jax.Array | None = None) -> DecodeState:
    kvb = cfg.precision.kv_bits
    cache_len = min(cfg.window, smax) if cfg.window else smax

    def stack(n, fn):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *[fn() for _ in range(n)])

    if cfg.family in ("ssm",):
        layers = stack(cfg.n_layers, lambda: ssm_mod.init_mamba_cache(batch, cfg.ssm_spec))
        return DecodeState(layers, step=jnp.zeros((), jnp.int32))
    if cfg.family == "hybrid":
        layers = stack(cfg.n_layers, lambda: ssm_mod.init_mamba_cache(batch, cfg.ssm_spec))
        n_seg = cfg.n_layers // cfg.shared_attn_every
        shared = stack(n_seg, lambda: attn.init_kv_cache(
            batch, smax, cfg.n_kv_heads, cfg.head_dim, kv_bits=kvb, dtype=cfg.dtype))
        return DecodeState(layers, shared=shared, step=jnp.zeros((), jnp.int32))
    n_main = cfg.n_layers
    layers = stack(n_main, lambda: attn.init_kv_cache(
        batch, cache_len, cfg.n_kv_heads, cfg.head_dim, kv_bits=kvb, dtype=cfg.dtype))
    cross = None
    if cfg.family == "vlm":
        n_cross = cfg.n_layers // cfg.cross_attn_every
        if params is not None and vision_tokens is not None:
            def one(i):
                blk = jax.tree.map(lambda a: a[i], params["blocks"])["cross"]
                kv = dense(blk["attn"]["k"], vision_tokens.astype(cfg.dtype))
                vv = dense(blk["attn"]["v"], vision_tokens.astype(cfg.dtype))
                nv = vision_tokens.shape[1]
                return {"k": kv.reshape(batch, nv, cfg.n_kv_heads, cfg.head_dim),
                        "v": vv.reshape(batch, nv, cfg.n_kv_heads, cfg.head_dim)}
            cross = jax.tree.map(lambda *xs: jnp.stack(xs),
                                 *[one(i) for i in range(n_cross)])
        else:
            cross = {
                "k": jnp.zeros((n_cross, batch, cfg.n_vis_tokens, cfg.n_kv_heads,
                                cfg.head_dim), cfg.dtype),
                "v": jnp.zeros((n_cross, batch, cfg.n_vis_tokens, cfg.n_kv_heads,
                                cfg.head_dim), cfg.dtype),
            }
    return DecodeState(layers, cross=cross, step=jnp.zeros((), jnp.int32))


def decode_layer_block(cfg: ModelConfig, layer: Params, h: jax.Array,
                       attend) -> jax.Array:
    """One decoder-layer body for single-token decode: pre-norm attention
    residual, then pre-norm MLP/MoE residual. ``attend(z)`` runs attention
    of the normed stream (including its cache update) — the ring-buffer
    ``decode_step`` and the paged serving engine plug their cache layouts in
    here, so the block structure exists exactly once."""
    z = rmsnorm(layer["ln1"], h)
    h = h + attend(z)
    z2 = rmsnorm(layer["ln2"], h)
    if cfg.family == "moe":
        y = moe_mod.moe_block(layer["moe"], z2, cfg.moe_spec)
    else:
        y = mlp(layer["mlp"], z2, cfg.mlp_act)
    return h + y


def _cross_decode(cfg: ModelConfig, blk: Params, x, ck, cv):
    b = x.shape[0]
    spec = cfg.attn_spec
    q = dense(blk["attn"]["q"], rmsnorm(blk["ln1"], x)).reshape(
        b, 1, cfg.n_heads, cfg.head_dim)
    out = attn.decode_attention(q, ck, cv, spec, kv_len=ck.shape[1])
    return x + dense(blk["attn"]["o"], out.reshape(b, 1, cfg.n_heads * cfg.head_dim))


def decode_step(params: Params, state: DecodeState, tokens: jax.Array,
                cfg: ModelConfig):
    """One serve step: tokens (B, 1) → (logits (B, 1, V), new state)."""
    x = embed(params["embed"], tokens).astype(cfg.dtype)
    kvb = cfg.precision.kv_bits

    if cfg.family in ("ssm", "hybrid"):
        def body(carry, inp):
            h, = carry
            layer, cache = inp
            z = rmsnorm(layer["norm"], h)
            y, new_cache = ssm_mod.mamba2_decode_step(layer["mamba"], z, cache,
                                                      cfg.ssm_spec)
            return (h + y,), new_cache
        if cfg.family == "ssm":
            (x,), new_layers = _maybe_scan(cfg, body, (x,), (params["layers"], state.layers))
            new_state = DecodeState(new_layers, None, None, state.step + 1)
        else:
            k = cfg.shared_attn_every
            n_seg = cfg.n_layers // k
            seg_p = jax.tree.map(lambda a: a.reshape(n_seg, k, *a.shape[1:]),
                                 params["layers"])
            seg_c = jax.tree.map(lambda a: a.reshape(n_seg, k, *a.shape[1:]),
                                 state.layers)
            def seg_body(carry, inp):
                (h,) = carry
                sp, sc, shared_cache = inp
                (h,), nc = _maybe_scan(cfg, body, (h,), (sp, sc))
                z = rmsnorm(params["shared_attn"]["ln1"], h)
                a_out, new_kv = attn.attention_decode_step(
                    params["shared_attn"]["attn"], z, shared_cache, cfg.attn_spec,
                    kv_bits=kvb)
                h = h + a_out
                h = h + mlp(params["shared_attn"]["mlp"],
                            rmsnorm(params["shared_attn"]["ln2"], h), cfg.mlp_act)
                return (h,), (nc, new_kv)
            (x,), (new_seg_c, new_shared) = _maybe_scan(
                cfg, seg_body, (x,), (seg_p, seg_c, state.shared))
            new_layers = jax.tree.map(
                lambda a: a.reshape(cfg.n_layers, *a.shape[2:]), new_seg_c)
            new_state = DecodeState(new_layers, shared=new_shared, step=state.step + 1)
    elif cfg.family == "vlm":
        per = cfg.cross_attn_every
        def blk_body(carry, inp):
            (h,) = carry
            blk, caches, ck, cv = inp
            def inner(c2, inp2):
                (hh,) = c2
                layer, cache = inp2
                box = {}

                def attend(z):
                    a_out, box["cache"] = attn.attention_decode_step(
                        layer["attn"], z, cache, cfg.attn_spec, kv_bits=kvb)
                    return a_out

                hh = decode_layer_block(cfg, layer, hh, attend)
                return (hh,), box["cache"]
            (h,), new_caches = _maybe_scan(cfg, inner, (h,), (blk["self"], caches))
            h = _cross_decode(cfg, blk["cross"], h, ck, cv)
            return (h,), new_caches
        caches = jax.tree.map(lambda a: a.reshape(cfg.n_layers // per, per,
                                                  *a.shape[1:]), state.layers)
        (x,), new_c = _maybe_scan(
            cfg, blk_body, (x,), (params["blocks"], caches,
                                  state.cross["k"], state.cross["v"]))
        new_layers = jax.tree.map(lambda a: a.reshape(cfg.n_layers, *a.shape[2:]), new_c)
        new_state = DecodeState(new_layers, cross=state.cross, step=state.step + 1)
    else:
        def body(carry, inp):
            (h,) = carry
            layer, cache = inp
            box = {}

            def attend(z):
                a_out, box["cache"] = attn.attention_decode_step(
                    layer["attn"], z, cache, cfg.attn_spec, kv_bits=kvb)
                return a_out

            h = decode_layer_block(cfg, layer, h, attend)
            return (h,), box["cache"]
        (x,), new_layers = _maybe_scan(cfg, body, (x,), (params["layers"], state.layers))
        new_state = DecodeState(new_layers, None, None, state.step + 1)

    x = rmsnorm(params["final_norm"], x)
    logits = _readout(params, cfg, x)
    return logits, new_state
