"""Model stack: layers, attention, MoE, SSM, transformer assembly."""
from . import attention, layers, moe, ssm, transformer  # noqa: F401
from .transformer import ModelConfig, PrecisionPlan  # noqa: F401
