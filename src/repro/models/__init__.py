"""Model stack: layers, attention, MoE, SSM, transformer assembly."""
from . import attention, layers, moe, ssm, transformer  # noqa: F401
from repro.quant import PrecisionPlan  # noqa: F401  (canonical plan)
from .transformer import ModelConfig  # noqa: F401
