"""Mamba2 (SSD — state-space duality, arXiv:2405.21060), TPU-adapted.

The SSD recurrence  h_t = dA_t·h_{t-1} + dt_t·B_t⊗x_t,  y_t = C_t·h_t + D·x_t
is evaluated in the *chunked dual form*: within a chunk of length L the output
is an attention-like quadratic form (MXU-friendly einsums); across chunks a
`lax.scan` carries the (B, H, P, N) state. This is the standard TPU adaptation
of the CUDA kernel: the intra-chunk block becomes a dense matmul pipeline (and
the Pallas kernel in kernels/ssd.py), the inter-chunk part is a cheap scan.

Decode is the O(1) recurrence on a persistent (conv_state, ssm_state) cache —
this is what makes long_500k runnable for the ssm/hybrid architectures.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import Params, dense, init_dense, rmsnorm, init_rmsnorm


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    d_model: int
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    chunk: int = 256
    n_groups: int = 1
    dt_min: float = 0.001
    dt_max: float = 0.1
    unroll: bool = False   # python-loop the chunk scan (dry-run cost accounting)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state


def init_mamba2(key, spec: SSMSpec, dtype=jnp.bfloat16) -> Params:
    ki, kc, ko, kd = jax.random.split(key, 4)
    d_in_proj = 2 * spec.d_inner + 2 * spec.n_groups * spec.d_state + spec.n_heads
    dt = jnp.exp(jax.random.uniform(kd, (spec.n_heads,), jnp.float32)
                 * (jnp.log(spec.dt_max) - jnp.log(spec.dt_min)) + jnp.log(spec.dt_min))
    return {
        "in_proj": init_dense(ki, spec.d_model, d_in_proj, dtype=dtype),
        "conv_w": (jax.random.normal(kc, (spec.conv_kernel, spec.conv_dim), jnp.float32)
                   * spec.conv_kernel**-0.5).astype(dtype),
        "conv_b": jnp.zeros((spec.conv_dim,), dtype),
        "a_log": jnp.log(jnp.arange(1, spec.n_heads + 1, dtype=jnp.float32)),
        "d_skip": jnp.ones((spec.n_heads,), jnp.float32),
        "dt_bias": dt + jnp.log(-jnp.expm1(-dt)),  # inverse softplus init
        "norm": init_rmsnorm(spec.d_inner, dtype),
        "out_proj": init_dense(ko, spec.d_inner, spec.d_model, dtype=dtype,
                               scale=spec.d_inner**-0.5),
    }


class MambaCache(NamedTuple):
    conv: jax.Array   # (B, K-1, conv_dim) last inputs for the causal conv
    ssm: jax.Array    # (B, H, P, N) fp32 state


def init_mamba_cache(batch: int, spec: SSMSpec, dtype=jnp.bfloat16) -> MambaCache:
    return MambaCache(
        conv=jnp.zeros((batch, spec.conv_kernel - 1, spec.conv_dim), dtype),
        ssm=jnp.zeros((batch, spec.n_heads, spec.head_dim, spec.d_state), jnp.float32),
    )


def _split_proj(p: Params, spec: SSMSpec, zxbcdt: jax.Array):
    di, gn = spec.d_inner, spec.n_groups * spec.d_state
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di: di + spec.conv_dim]
    dt = zxbcdt[..., di + spec.conv_dim:]
    return z, xbc, dt


def _post_conv_split(spec: SSMSpec, xbc: jax.Array):
    di, gn = spec.d_inner, spec.n_groups * spec.d_state
    return xbc[..., :di], xbc[..., di: di + gn], xbc[..., di + gn:]


def _causal_conv(p: Params, xbc: jax.Array, spec: SSMSpec) -> jax.Array:
    """Depthwise causal conv over seq (kernel K), then SiLU."""
    k = spec.conv_kernel
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + xbc.shape[1], :] * p["conv_w"][i][None, None, :]
              for i in range(k))
    return jax.nn.silu((out + p["conv_b"][None, None, :]).astype(jnp.float32)
                       ).astype(xbc.dtype)


def ssd_chunked(xh, dt, a_log, b_mat, c_mat, spec: SSMSpec,
                init_state: jax.Array | None = None):
    """Chunked SSD scan.

    xh:   (B, S, H, P)   — per-head inputs
    dt:   (B, S, H)      — softplus'd step sizes
    b_mat/c_mat: (B, S, G, N)
    Returns y (B, S, H, P) and final state (B, H, P, N).
    """
    bsz, s, h, pdim = xh.shape
    n = spec.d_state
    L = min(spec.chunk, s)
    if s % L:
        L = s
    nc = s // L
    a = -jnp.exp(a_log)                                 # (H,) negative
    # per-step log decay: dA = exp(dt·a) → log = dt·a  (B, S, H)
    logdec = (dt * a[None, None, :]).astype(jnp.float32)

    def reshape_c(t):  # (B, S, ...) -> (nc, B, L, ...)
        return t.reshape(bsz, nc, L, *t.shape[2:]).transpose(1, 0, 2, *range(3, t.ndim + 1))

    xs, dts, lds = map(reshape_c, (xh, dt, logdec))
    bs, cs = map(reshape_c, (b_mat, c_mat))

    if init_state is None:
        init_state = jnp.zeros((bsz, h, pdim, n), jnp.float32)

    def chunk_step(state, inp):
        xc, dtc, ldc, bc, cc = inp                      # (B, L, ...)
        cum = jnp.cumsum(ldc, axis=1)                   # (B, L, H)
        # weighted inputs: dt·x
        xw = (xc.astype(jnp.float32) * dtc[..., None])  # (B, L, H, P)
        # --- intra-chunk (dual / attention-like) ---
        # decay(l, m) = exp(cum_l − cum_m) for m ≤ l. Mask BEFORE exp: the
        # upper triangle has diff > 0 → exp overflows → inf·0 = NaN in the vjp.
        diff = cum[:, :, None, :] - cum[:, None, :, :]  # (B, L, L, H)
        mask = jnp.tril(jnp.ones((L, L), bool))
        dec = jnp.exp(jnp.where(mask[None, :, :, None], diff, -jnp.inf))
        scores = jnp.einsum("blgn,bmgn->blm", cc.astype(jnp.float32),
                            bc.astype(jnp.float32))     # (B, L, L)  (G=1)
        att = scores[:, :, :, None] * dec               # (B, L, L, H)
        y_intra = jnp.einsum("blmh,bmhp->blhp", att, xw)
        # --- inter-chunk: contribution of the carried state ---
        state_dec = jnp.exp(cum)                        # (B, L, H)
        y_inter = jnp.einsum("blgn,bhpn->blhp", cc.astype(jnp.float32), state)
        y_inter = y_inter * state_dec[..., None]
        # --- state update ---
        tail = jnp.exp(cum[:, -1:, :] - cum)            # (B, L, H) decay to end
        bx = jnp.einsum("blhp,blgn->bhpn", xw * tail[..., None],
                        bc.astype(jnp.float32))
        state = state * jnp.exp(cum[:, -1])[:, :, None, None] + bx
        return state, (y_intra + y_inter)

    if spec.unroll:
        ys_list, state = [], init_state
        for c in range(nc):
            state, yc = chunk_step(state, jax.tree.map(lambda t: t[c],
                                                       (xs, dts, lds, bs, cs)))
            ys_list.append(yc)
        ys = jnp.stack(ys_list)
    else:
        state, ys = jax.lax.scan(chunk_step, init_state, (xs, dts, lds, bs, cs))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, s, h, pdim)
    return y.astype(xh.dtype), state


def mamba2_forward(p: Params, x: jax.Array, spec: SSMSpec,
                   init_state=None, return_state: bool = False):
    """Full-sequence forward. x: (B, S, d_model) → (B, S, d_model).

    ``return_state=True`` returns (out, MambaCache) — the prefill path: the
    conv cache is the last K−1 pre-conv rows, the SSM state the final chunk
    state, so decode continues exactly where prefill stopped.
    """
    bsz, s, _ = x.shape
    z, xbc, dt_raw = _split_proj(p, spec, dense(p["in_proj"], x))
    conv_tail = xbc[:, -(spec.conv_kernel - 1):, :]
    xbc = _causal_conv(p, xbc, spec)
    xi, b_mat, c_mat = _post_conv_split(spec, xbc)
    h, pd, n, g = spec.n_heads, spec.head_dim, spec.d_state, spec.n_groups
    xh = xi.reshape(bsz, s, h, pd)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None, None, :])
    y, state = ssd_chunked(xh, dt, p["a_log"], b_mat.reshape(bsz, s, g, n),
                           c_mat.reshape(bsz, s, g, n), spec, init_state)
    y = y + (p["d_skip"][None, None, :, None] * xh.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(bsz, s, spec.d_inner)
    y = rmsnorm(p["norm"], y) * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    out = dense(p["out_proj"], y)
    if return_state:
        return out, MambaCache(conv=conv_tail, ssm=state)
    return out


def mamba2_decode_step(p: Params, x: jax.Array, cache: MambaCache, spec: SSMSpec):
    """Single-token recurrent step. x: (B, 1, d_model)."""
    bsz = x.shape[0]
    z, xbc, dt_raw = _split_proj(p, spec, dense(p["in_proj"], x))
    # conv over the cached window + new input
    win = jnp.concatenate([cache.conv, xbc], axis=1)          # (B, K, conv_dim)
    conv = (jnp.einsum("bkc,kc->bc", win.astype(jnp.float32),
                       p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32))
    xbc_t = jax.nn.silu(conv)[:, None, :].astype(x.dtype)      # (B, 1, conv_dim)
    xi, b_mat, c_mat = _post_conv_split(spec, xbc_t)
    h, pd, n, g = spec.n_heads, spec.head_dim, spec.d_state, spec.n_groups
    xh = xi.reshape(bsz, h, pd).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"][None, :])
    a = -jnp.exp(p["a_log"])                                   # (H,)
    da = jnp.exp(dt * a[None, :])                              # (B, H)
    bv = b_mat.reshape(bsz, g * n).astype(jnp.float32)         # G=1 → (B, N)
    cv = c_mat.reshape(bsz, g * n).astype(jnp.float32)
    new_state = (cache.ssm * da[:, :, None, None]
                 + jnp.einsum("bhp,bn->bhpn", xh * dt[..., None], bv))
    y = jnp.einsum("bhpn,bn->bhp", new_state, cv)
    y = y + p["d_skip"][None, :, None] * xh
    y = y.reshape(bsz, 1, spec.d_inner).astype(x.dtype)
    y = rmsnorm(p["norm"], y) * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    out = dense(p["out_proj"], y)
    return out, MambaCache(conv=win[:, 1:, :], ssm=new_state)
