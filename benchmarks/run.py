"""Benchmark orchestrator — one module per paper table/figure.

Prints ``benchmark,key=value,...`` lines plus a final CHECKS summary
validating the paper's claims. Roofline extraction (which needs the
512-device placeholder env) lives in benchmarks/bench_roofline.py as its own
entry point.

Usage: PYTHONPATH=src python -m benchmarks.run [--quick | --smoke]

``--smoke`` is the CI lane: tiny shapes, only the fast hardware-claim benches
(bandwidth model + fused double sampling), and a ``BENCH_<name>.json`` file
per bench (uploaded as a workflow artifact so the perf trajectory accumulates
across PRs). ``--json-dir`` writes the same JSON files for any run.
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import sys
import time

import numpy as np

# allow `python benchmarks/run.py` (script mode) as well as `-m benchmarks.run`:
# the bench modules are imported as `benchmarks.*`, so the repo root must be
# importable regardless of how this file was invoked
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _REPO_ROOT not in sys.path:
    sys.path.insert(0, _REPO_ROOT)

BENCHES = [
    ("fig4_linear_convergence", "benchmarks.bench_linear_convergence"),
    ("fig5_bandwidth_model", "benchmarks.bench_bandwidth_model"),
    ("fig6_minibatch", "benchmarks.bench_minibatch"),
    ("fig7a_fig8_optimal_quant", "benchmarks.bench_optimal_quant"),
    ("fig7b_dl_quant", "benchmarks.bench_dl_quant"),
    ("fig9_chebyshev_negative", "benchmarks.bench_chebyshev"),
    ("fig12_refetch", "benchmarks.bench_refetch"),
    ("ds_fused", "benchmarks.bench_ds_fused"),
    ("serve_engine", "benchmarks.bench_serve_engine"),
    ("train_step", "benchmarks.bench_train_step"),
]

# fast, shape-independent claims only — what CI runs on every PR
SMOKE_BENCHES = {"fig5_bandwidth_model", "ds_fused", "serve_engine",
                 "train_step"}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced datasets/epochs (CI mode)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, fast benches only, write BENCH_*.json")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json-dir", default=None,
                    help="write BENCH_<name>.json per bench here "
                         "(default: cwd when --smoke)")
    args = ap.parse_args(argv)
    quick = args.quick or args.smoke
    json_dir = args.json_dir or ("." if args.smoke else None)

    all_checks = []
    for name, module in BENCHES:
        if args.only and args.only not in name:
            continue
        if args.smoke and not args.only and name not in SMOKE_BENCHES:
            continue
        t0 = time.time()
        mod = importlib.import_module(module)
        rows = mod.run(quick=quick)
        dt = time.time() - t0
        for row in rows:
            line = ",".join(f"{k}={v}" for k, v in row.items())
            print(f"{name},{line}")
            for k, v in row.items():
                if isinstance(v, (bool, np.bool_)):
                    all_checks.append((f"{name}/{k}", bool(v)))
        print(f"{name},_timing,seconds={dt:.1f}")
        if json_dir:
            payload = {"bench": name, "seconds": round(dt, 2), "quick": quick,
                       "rows": [{k: (bool(v) if isinstance(v, np.bool_) else v)
                                 for k, v in row.items()} for row in rows]}
            path = os.path.join(json_dir, f"BENCH_{name}.json")
            with open(path, "w") as f:
                json.dump(payload, f, indent=2, default=str)
            print(f"{name},_json,path={path}")
    print()
    n_pass = sum(1 for _, v in all_checks if v)
    for label, v in all_checks:
        print(f"CHECK {'PASS' if v else 'FAIL'}: {label}")
    print(f"\n{n_pass}/{len(all_checks)} paper-claim checks passed")
    return 0 if n_pass == len(all_checks) else 1


if __name__ == "__main__":
    sys.exit(main())
