"""Benchmark orchestrator — one module per paper table/figure.

Prints ``benchmark,key=value,...`` lines plus a final CHECKS summary
validating the paper's claims. Roofline extraction (which needs the
512-device placeholder env) lives in benchmarks/bench_roofline.py as its own
entry point.

Usage: PYTHONPATH=src python -m benchmarks.run [--quick | --smoke]

``--smoke`` is the CI lane: tiny shapes, only the fast hardware-claim benches
(bandwidth model + fused double sampling), and a ``BENCH_<name>.json`` file
per bench (uploaded as a workflow artifact so the perf trajectory accumulates
across PRs). ``--json-dir`` writes the same JSON files for any run.
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import sys
import time

import numpy as np

# allow `python benchmarks/run.py` (script mode) as well as `-m benchmarks.run`:
# the bench modules are imported as `benchmarks.*`, so the repo root must be
# importable regardless of how this file was invoked
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _REPO_ROOT not in sys.path:
    sys.path.insert(0, _REPO_ROOT)

BENCHES = [
    ("fig4_linear_convergence", "benchmarks.bench_linear_convergence"),
    ("fig5_bandwidth_model", "benchmarks.bench_bandwidth_model"),
    ("fig6_minibatch", "benchmarks.bench_minibatch"),
    ("fig7a_fig8_optimal_quant", "benchmarks.bench_optimal_quant"),
    ("fig7b_dl_quant", "benchmarks.bench_dl_quant"),
    ("fig9_chebyshev_negative", "benchmarks.bench_chebyshev"),
    ("fig12_refetch", "benchmarks.bench_refetch"),
    ("ds_fused", "benchmarks.bench_ds_fused"),
    ("qmm", "benchmarks.bench_qmm"),
    ("bitplane", "benchmarks.bench_bitplane"),
    ("serve_engine", "benchmarks.bench_serve_engine"),
    ("spec_decode", "benchmarks.bench_spec_decode"),
    ("train_step", "benchmarks.bench_train_step"),
    ("chaos", "benchmarks.bench_chaos"),
]

# fast, shape-independent claims only — what CI runs on every PR
SMOKE_BENCHES = {"fig5_bandwidth_model", "ds_fused", "qmm", "bitplane",
                 "serve_engine", "spec_decode", "train_step", "chaos"}

# committed per-bench baselines the --smoke regression gate compares against
BASELINE_DIR = os.path.join(_REPO_ROOT, "benchmarks", "baselines")


def regression_gate(payloads: dict) -> list[str]:
    """Compare this run against the committed baselines: every HBM-byte /
    parity CHECK that held in the baseline must still hold, the trainer step
    wall-clock (normalized by the in-run fp32-matmul calibration, so machine
    speed cancels) must not regress more than ``ZIPML_BENCH_WALLCLOCK_TOL``
    (default 10%), and every row's fraction-of-roofline must not collapse
    below ``baseline × (1 - ZIPML_BENCH_ROOFLINE_TOL)`` (default 0.75 — the
    fraction is machine-portable but interpret-mode-noisy, so this catches
    collapses, not drift). A wall-clock skip on a noisy machine is recorded
    as ``payload["gate_skipped"]`` (audited from the uploaded JSON artifact)
    and emitted as a ``::warning::`` GitHub annotation. Returns failure
    strings; mutates ``payloads`` only to add ``gate_skipped``.
    """
    fails = []
    wall_tol = float(os.environ.get("ZIPML_BENCH_WALLCLOCK_TOL", "0.10"))
    roof_tol = float(os.environ.get("ZIPML_BENCH_ROOFLINE_TOL", "0.75"))
    for name, payload in payloads.items():
        path = os.path.join(BASELINE_DIR, f"BENCH_{name}.json")
        if not os.path.exists(path):
            continue
        with open(path) as f:
            base = json.load(f)

        def checks(rows):
            out = {}
            for i, row in enumerate(rows):
                tag = row.get("case", str(i))
                for k, v in row.items():
                    if isinstance(v, bool):
                        out[f"{tag}/{k}"] = v
            return out

        now_checks = checks(payload["rows"])
        for key, held in checks(base["rows"]).items():
            if not held:
                continue
            if key not in now_checks:
                # a renamed/dropped CHECK must be an explicit baseline
                # update, never a silent gate bypass
                fails.append(
                    f"{name}: baseline CHECK {key} missing from this run — "
                    "regenerate benchmarks/baselines/ if intentional")
            elif now_checks[key] is False:
                fails.append(f"{name}: CHECK {key} regressed (was PASS)")
        # normalized wall-clock: rows carrying both step_ms and calib_ms
        # (min step time over the run — the stable steady-state estimator)
        base_rows = {r.get("case"): r for r in base["rows"]}
        now_cases = {r.get("case") for r in payload["rows"]}
        for case, b in base_rows.items():
            if ("calib_ms" in b or "roofline_fraction" in b) \
                    and case not in now_cases:
                fails.append(
                    f"{name}: baseline gated case {case!r} missing "
                    "from this run — regenerate benchmarks/baselines/ if "
                    "intentional")
        # fraction-of-roofline: machine-portable (achieved GB/s over THIS
        # machine's measured peak), so it gates even where wall-clock can't
        for row in payload["rows"]:
            b = base_rows.get(row.get("case"))
            if not b or "roofline_fraction" not in b:
                continue
            if "roofline_fraction" not in row:
                fails.append(
                    f"{name}/{row['case']}: baseline carries "
                    "roofline_fraction but this run doesn't — regenerate "
                    "benchmarks/baselines/ if intentional")
                continue
            floor = b["roofline_fraction"] * (1 - roof_tol)
            if row["roofline_fraction"] < floor:
                fails.append(
                    f"{name}/{row['case']}: roofline_fraction "
                    f"{row['roofline_fraction']:.4f} < baseline "
                    f"{b['roofline_fraction']:.4f} × (1 - {roof_tol:.0%})")
        for row in payload["rows"]:
            b = base_rows.get(row.get("case"))
            if not b or "step_ms" not in row or "calib_ms" not in row:
                continue
            if not b.get("calib_ms") or not row["calib_ms"]:
                continue
            end = row.get("calib_ms_end", row["calib_ms"])
            jitter = abs(end / row["calib_ms"] - 1)
            if jitter > 0.15:
                reason = (f"calibration jitter {jitter:.0%} > 15% — "
                          "wall-clock gate skipped; byte CHECKs and "
                          "roofline fractions still gate")
                payload.setdefault("gate_skipped", {})[row["case"]] = reason
                print(f"{name}/{row['case']}: {reason}")
                print("::warning title=bench wall-clock gate skipped::"
                      f"{name}/{row['case']}: {reason}")
                continue
            calib = min(row["calib_ms"], end)
            now_norm = row.get("step_ms_min", row["step_ms"]) / calib
            b_end = b.get("calib_ms_end", b["calib_ms"])
            base_norm = b.get("step_ms_min", b["step_ms"]) / \
                min(b["calib_ms"], b_end)
            if now_norm > base_norm * (1 + wall_tol):
                fails.append(
                    f"{name}/{row['case']}: normalized step wall-clock "
                    f"{now_norm:.1f} > baseline {base_norm:.1f} "
                    f"(+{wall_tol:.0%} allowed)")
    return fails


def calibration_jitter(payloads: dict) -> float | None:
    """Worst calibration jitter across wall-clock rows (None: no such row).
    The same |calib_ms_end / calib_ms - 1| the gate's noisy-machine skip
    keys off — --update-baselines refuses above 15%, because a baseline
    minted under transient load would make every future healthy run look
    like a regression (or mask a real one)."""
    worst = None
    for payload in payloads.values():
        for row in payload["rows"]:
            if "calib_ms" not in row or not row["calib_ms"]:
                continue
            end = row.get("calib_ms_end", row["calib_ms"])
            j = abs(end / row["calib_ms"] - 1)
            worst = j if worst is None else max(worst, j)
    return worst


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced datasets/epochs (CI mode)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, fast benches only, write BENCH_*.json")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json-dir", default=None,
                    help="write BENCH_<name>.json per bench here "
                         "(default: cwd when --smoke)")
    ap.add_argument("--update-baselines", action="store_true",
                    help="re-run the smoke benches and regenerate "
                         "benchmarks/baselines/BENCH_*.json in one command; "
                         "refuses on a machine the jitter guard flags noisy")
    args = ap.parse_args(argv)
    smoke = args.smoke or args.update_baselines
    quick = args.quick or smoke
    json_dir = args.json_dir or ("." if args.smoke else None)

    all_checks = []
    payloads = {}
    for name, module in BENCHES:
        if args.only and args.only not in name:
            continue
        if smoke and not args.only and name not in SMOKE_BENCHES:
            continue
        t0 = time.time()
        mod = importlib.import_module(module)
        rows = mod.run(quick=quick)
        dt = time.time() - t0
        for row in rows:
            line = ",".join(f"{k}={v}" for k, v in row.items())
            print(f"{name},{line}")
            for k, v in row.items():
                if isinstance(v, (bool, np.bool_)):
                    all_checks.append((f"{name}/{k}", bool(v)))
        print(f"{name},_timing,seconds={dt:.1f}")
        payloads[name] = {
            "bench": name, "seconds": round(dt, 2), "quick": quick,
            "rows": [{k: (bool(v) if isinstance(v, np.bool_) else v)
                      for k, v in row.items()} for row in rows]}
    print()
    n_pass = sum(1 for _, v in all_checks if v)

    if args.update_baselines:
        for label, v in all_checks:
            print(f"CHECK {'PASS' if v else 'FAIL'}: {label}")
        if n_pass != len(all_checks):
            print("\nrefusing to update baselines: "
                  f"{len(all_checks) - n_pass} CHECK(s) failing — a baseline "
                  "must only ever encode passing claims")
            return 1
        jitter = calibration_jitter(payloads)
        if jitter is not None and jitter > 0.15:
            print(f"\nrefusing to update baselines: calibration jitter "
                  f"{jitter:.0%} > 15% — this machine is too noisy for a "
                  "trustworthy wall-clock baseline; re-run when idle")
            return 2
        os.makedirs(BASELINE_DIR, exist_ok=True)
        for name, payload in payloads.items():
            path = os.path.join(BASELINE_DIR, f"BENCH_{name}.json")
            with open(path, "w") as f:
                json.dump(payload, f, indent=2, default=str)
            print(f"baseline updated: {path}")
        print(f"\n{n_pass}/{len(all_checks)} checks passed; "
              f"{len(payloads)} baselines written (jitter "
              f"{0.0 if jitter is None else jitter:.1%})")
        return 0

    # gate BEFORE writing the JSON artifacts so gate_skipped annotations
    # (noisy-machine wall-clock skips) are auditable from the uploaded files
    gate_fails = []
    if args.smoke:
        gate_fails = regression_gate(payloads)
        for msg in gate_fails:
            print(f"REGRESSION FAIL: {msg}")
        if not gate_fails and os.path.isdir(BASELINE_DIR):
            print("regression gate: no regressions vs committed baselines")
    if json_dir:
        os.makedirs(json_dir, exist_ok=True)
        for name, payload in payloads.items():
            path = os.path.join(json_dir, f"BENCH_{name}.json")
            with open(path, "w") as f:
                json.dump(payload, f, indent=2, default=str)
            print(f"{name},_json,path={path}")
    for label, v in all_checks:
        print(f"CHECK {'PASS' if v else 'FAIL'}: {label}")
    print(f"\n{n_pass}/{len(all_checks)} paper-claim checks passed")
    return 0 if n_pass == len(all_checks) and not gate_fails else 1


if __name__ == "__main__":
    sys.exit(main())
